#!/usr/bin/env python3
"""Gate CI on end-to-end bench regressions.

Compares a freshly produced ``BENCH_<target>.json`` (written by a bench
binary run with ``--json``, see rust/src/benchutil.rs) against the
committed baseline and fails when any matching benchmark regressed past
the threshold.

Usage:
    check_bench_regression.py CURRENT BASELINE [--threshold 2.0]
                              [--prefix fig8]

* Benchmarks are matched by exact name; only names starting with
  ``--prefix`` (default ``fig8``, the end-to-end figure benches) gate.
* The comparison uses ``p50_ns`` (robust center — a single descheduled CI
  sample skews the mean, not the median).
* Rows carrying an ``events_per_sec`` gauge (engine-throughput profiling,
  see rust/src/obs.rs) additionally gate raw engine throughput: a bench
  whose events/sec dropped past the same threshold fails even if its
  latency number survived (e.g. the run shrank).
* A missing baseline file is an informational pass: the first CI run
  seeds it — download the ``bench-json`` artifact and commit it at the
  baseline path (see docs/PERF.md).
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc.get("results", [])}


def main(argv):
    positional = []
    threshold = 2.0
    prefix = "fig8"
    it = iter(argv[1:])
    for a in it:
        if a in ("--threshold", "--prefix"):
            try:
                value = next(it)
            except StopIteration:
                print(f"{a} needs a value\n{__doc__}")
                return 2
            if a == "--threshold":
                threshold = float(value)
            else:
                prefix = value
        elif a.startswith("--"):
            print(f"unknown flag {a!r}\n{__doc__}")
            return 2
        else:
            positional.append(a)
    if len(positional) != 2:
        print(__doc__)
        return 2
    current_path, baseline_path = positional

    if not os.path.exists(baseline_path):
        print(
            f"no committed baseline at {baseline_path}; skipping the "
            "regression gate. Seed it by committing this run's "
            f"{os.path.basename(current_path)} (uploaded as the "
            "bench-json artifact) at that path — see docs/PERF.md."
        )
        return 0

    current = load(current_path)
    baseline = load(baseline_path)
    gated = [
        name
        for name in current
        if name.startswith(prefix) and name in baseline
    ]
    # A rename/removal must not silently disarm the gate: every baseline
    # entry has to resolve to a current bench (or the baseline must be
    # refreshed deliberately).
    missing = [
        name
        for name in baseline
        if name.startswith(prefix) and name not in current
    ]
    if missing:
        print(
            f"{len(missing)} baseline bench(es) missing from "
            f"{current_path}: {missing}; renamed or removed benches "
            "require refreshing the committed baseline."
        )
        return 1
    if not gated:
        print(
            f"no benchmarks matching prefix {prefix!r} present in both "
            f"{current_path} and {baseline_path}; nothing to gate."
        )
        return 0

    failures = []
    for name in sorted(gated):
        cur = current[name]["p50_ns"]
        base = baseline[name]["p50_ns"]
        ratio = cur / base if base > 0 else float("inf")
        marker = "FAIL" if ratio > threshold else "ok"
        print(
            f"  [{marker}] {name}: p50 {cur / 1e6:.3f} ms vs baseline "
            f"{base / 1e6:.3f} ms ({ratio:.2f}x)"
        )
        if ratio > threshold:
            failures.append((name, ratio))
        # Engine-throughput gate: only when BOTH sides carry the gauge
        # (a baseline predating the annotation stays informational).
        cur_eps = current[name].get("events_per_sec")
        base_eps = baseline[name].get("events_per_sec")
        if cur_eps and base_eps:
            eps_ratio = (
                base_eps / cur_eps if cur_eps > 0 else float("inf")
            )
            marker = "FAIL" if eps_ratio > threshold else "ok"
            print(
                f"  [{marker}] {name}: {cur_eps / 1e6:.2f}M events/s vs "
                f"baseline {base_eps / 1e6:.2f}M ({eps_ratio:.2f}x "
                "slowdown)"
            )
            if eps_ratio > threshold:
                failures.append((name + " [events/sec]", eps_ratio))

    if failures:
        print(
            f"\n{len(failures)} bench(es) regressed past {threshold}x; "
            "if intentional, refresh the committed baseline from the "
            "bench-json artifact."
        )
        return 1
    print(f"\nall {len(gated)} gated bench(es) within {threshold}x.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
