//! Quickstart: simulate the DEMS scheduler on a paper workload, then (if
//! `make artifacts` has run) load the compiled PJRT models and do one real
//! inference per DNN.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ocularone::exp::summarize;
use ocularone::fleet::Workload;
use ocularone::policy::Policy;
use ocularone::runtime::Runtime;
use ocularone::simulate;

fn main() -> ocularone::errors::Result<()> {
    // 1. Simulated study: 3 drones, Active mix (= the paper's 3D-A), DEMS.
    let wl = Workload::emulation(3, true);
    println!("workload {} ({} tasks over {} s)", wl.name, wl.total_tasks(),
             wl.duration / 1_000_000);
    for policy in [Policy::edf_ec(), Policy::dems(), Policy::gems(false)] {
        let name = policy.kind.name().to_string();
        let m = simulate(policy, &wl, 42);
        println!("  {name:10} {}", summarize(&m));
    }

    // 2. Real inference through the PJRT runtime (all three layers:
    //    Pallas kernel -> JAX model -> HLO artifact -> Rust).
    match Runtime::load("artifacts") {
        Ok(rt) => {
            println!("\nPJRT runtime on {}:", rt.platform_name());
            for kind in rt.kinds() {
                let frame = rt.synth_frame(kind, 1)?;
                let t0 = std::time::Instant::now();
                let out = rt.model(kind).unwrap().infer(&frame)?;
                println!(
                    "  {:4} -> {} outputs in {:.2} ms (first: {:.4})",
                    kind.name(),
                    out.len(),
                    t0.elapsed().as_secs_f64() * 1e3,
                    out[0]
                );
            }
        }
        Err(e) => {
            println!("\n(skipping real inference: {e}; run `make artifacts`)");
        }
    }
    Ok(())
}
