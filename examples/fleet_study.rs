//! Fleet-scale emulation study: sweep all §8.3 workloads and schedulers
//! across a 7-edge host (the paper's emulation setup), printing the
//! Fig. 9 scatter rows (tasks completed vs QoS utility).
//!
//! ```sh
//! cargo run --release --example fleet_study
//! ```

use ocularone::exec::CloudExecModel;
use ocularone::fleet::Workload;
use ocularone::net::LognormalWan;
use ocularone::platform::Platform;
use ocularone::policy::Policy;
use ocularone::sim;

fn main() {
    let seed = 7u64;
    let edges = 7;
    println!("workload,algo,edge,completed,utility");
    let mut best: Vec<(String, String, f64)> = Vec::new();
    for wl in Workload::fig8_all() {
        let mut top = ("-".to_string(), f64::MIN);
        for policy in Policy::fig8_lineup() {
            let name = policy.kind.name().to_string();
            let mut med = Vec::new();
            for e in 0..edges {
                let s = seed ^ ((e + 1) * 0x9E37);
                let platform = Platform::new(
                    policy.clone(),
                    wl.models.clone(),
                    CloudExecModel::new(Box::new(LognormalWan::default())),
                    s,
                );
                let m = sim::run(platform, &wl, s);
                println!(
                    "{},{},{},{},{:.0}",
                    wl.name,
                    name,
                    e,
                    m.completed(),
                    m.qos_utility()
                );
                med.push(m.qos_utility());
            }
            med.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let m = med[med.len() / 2];
            if m > top.1 {
                top = (name.clone(), m);
            }
        }
        best.push((wl.name.clone(), top.0, top.1));
    }
    eprintln!("\nbest median-utility scheduler per workload:");
    for (wl, algo, util) in best {
        eprintln!("  {wl}: {algo} ({util:.0})");
    }
}
