//! VIP navigation study (§8.8): fly the simulated Tello behind a scripted
//! proxy VIP using each scheduler's HV tracking completions, and compare
//! trajectory quality (jerk, yaw error, DNF) — the Fig. 17/18 scenario as
//! a runnable example.
//!
//! ```sh
//! cargo run --release --example vip_navigation
//! ```

use ocularone::exec::CloudExecModel;
use ocularone::fleet::Workload;
use ocularone::model::{orin_field, DnnKind};
use ocularone::nav::{fly, TrackingEvent};
use ocularone::net::LognormalWan;
use ocularone::platform::Platform;
use ocularone::policy::Policy;
use ocularone::sim;
use ocularone::time::ms;

fn main() {
    let seed = 42;
    for fps in [15u32, 30] {
        println!("== {fps} FPS (HV per frame, DEV/BP every 3rd frame) ==");
        for policy in [
            Policy::edge_only_field(),
            Policy::edf_ec(),
            Policy::dems(),
            Policy::gems(false),
        ] {
            let wl = Workload::field(fps, orin_field());
            let name = policy.kind.name().to_string();
            let mut platform = Platform::new(
                policy,
                wl.models.clone(),
                CloudExecModel::new(Box::new(LognormalWan::default())),
                seed,
            );
            platform.edge_exec = wl.edge_exec.clone();
            platform.metrics.record_completions = true;
            let m = sim::run(platform, &wl, seed);
            let events: Vec<TrackingEvent> = m
                .completions
                .iter()
                .filter(|c| c.model == DnnKind::Hv)
                .map(|c| TrackingEvent {
                    at: c.at,
                    success: c.success && c.latency <= ocularone::exp::FRESH,
                })
                .collect();
            let nav = fly(&events, m.duration, seed ^ fps as u64);
            print!(
                "{:10} done {:5.1}%  total-util {:8.0}  ",
                name,
                100.0 * m.completion_rate(),
                m.total_utility()
            );
            if nav.dnf {
                println!("DNF (failsafe landing at {:.0}s)", nav.dnf_at_s);
            } else {
                let (_, _, ud95) = nav.jerk_stats(2);
                let (ymean, ymed, y95) = nav.yaw_stats();
                println!(
                    "jerk-UD p95 {ud95:5.2} m/s³  yaw err mean/med/p95 \
                     {ymean:4.1}/{ymed:4.1}/{y95:5.1}°"
                );
            }
        }
        println!();
    }
}
