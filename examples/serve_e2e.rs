//! End-to-end serving driver (the repo's E2E validation): load the six
//! compiled DNN artifacts, serve two emulated drone streams through the
//! edge-EDF + cloud-offload pipeline with *real* PJRT inference on the
//! request path, and report latency/throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;
use std::time::Duration;

use ocularone::metrics::percentile;
use ocularone::serve::{calibrate, serve, ServeConfig};
use ocularone::runtime::Runtime;

fn main() -> ocularone::errors::Result<()> {
    let dir = Path::new("artifacts");
    let rt = Runtime::load(dir)?;
    println!("PJRT platform: {}", rt.platform_name());
    println!("calibrating per-model p95 latencies...");
    for (kind, p95) in calibrate(&rt, 30)? {
        println!("  {:4}: {:.2} ms", kind.name(), p95);
    }
    drop(rt);

    let cfg = ServeConfig {
        rate: 2.0,
        drones: 2,
        duration: Duration::from_secs(15),
        ..Default::default()
    };
    println!(
        "\nserving {} drones × {} segments/s for {:?} \
         (each segment fans out to 6 DNN tasks)...",
        cfg.drones, cfg.rate, cfg.duration
    );
    let report = serve(dir, &cfg)?;
    println!(
        "\nthroughput {:.1} inferences/s | completion {:.1}% | wall {:.1}s",
        report.throughput(),
        100.0 * report.completion_rate(),
        report.wall_secs
    );
    println!("| model | done | missed | dropped | cloud | p50 ms | p95 ms | post-proc p50 µs |");
    println!("|-------|------|--------|---------|-------|--------|--------|------------------|");
    for (kind, s) in &report.per_model {
        println!(
            "| {} | {} | {} | {} | {} | {:.1} | {:.1} | {:.2} |",
            kind.name(),
            s.completed,
            s.missed,
            s.dropped,
            s.on_cloud,
            percentile(&s.latency_ms, 0.5),
            percentile(&s.latency_ms, 0.95),
            percentile(&s.postproc_us, 0.5),
        );
    }
    Ok(())
}
