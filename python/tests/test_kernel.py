"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes/dtypes of the fused GEMM and the conv lowering;
every property is an ``assert_allclose`` against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (BlockConfig, conv2d, dense,
                             fused_matmul_bias_relu, im2col, max_pool)
from compile.kernels.fused_matmul import _ceil_pow2
from compile.kernels.ref import conv2d_ref, dense_ref, matmul_bias_relu_ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- fused GEMM

@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    relu=st.booleans(),
)
def test_matmul_matches_ref_shape_sweep(m, k, n, relu):
    x = _rand(m * 7 + 1, (m, k), jnp.float32)
    w = _rand(k * 7 + 2, (k, n), jnp.float32)
    b = _rand(n * 7 + 3, (n,), jnp.float32)
    out = fused_matmul_bias_relu(x, w, b, relu=relu)
    ref = matmul_bias_relu_ref(x, w, b, relu=relu)
    assert out.shape == (m, n)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@given(
    bm=st.sampled_from([8, 16, 32, 64, 128]),
    bn=st.sampled_from([8, 16, 32, 64, 128]),
    bk=st.sampled_from([8, 16, 32, 64, 128]),
)
def test_matmul_block_config_invariance(bm, bn, bk):
    """The result must be independent of the tile decomposition."""
    x = _rand(1, (70, 90), jnp.float32)
    w = _rand(2, (90, 50), jnp.float32)
    b = _rand(3, (50,), jnp.float32)
    out = fused_matmul_bias_relu(x, w, b, block=BlockConfig(bm, bn, bk))
    ref = matmul_bias_relu_ref(x, w, b)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 5e-2)])
def test_matmul_dtypes(dtype, rtol):
    x = _rand(1, (64, 64), dtype)
    w = _rand(2, (64, 64), dtype)
    b = _rand(3, (64,), dtype)
    out = fused_matmul_bias_relu(x, w, b)
    ref = matmul_bias_relu_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=rtol, atol=rtol)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))  # inner mismatch
    b = jnp.zeros((7,))
    with pytest.raises(ValueError):
        fused_matmul_bias_relu(x, w, b)
    with pytest.raises(ValueError):
        fused_matmul_bias_relu(x[0], w, b)  # bad rank


def test_matmul_relu_clamps_negative():
    x = -jnp.ones((8, 8))
    w = jnp.eye(8)
    b = jnp.zeros((8,))
    out = fused_matmul_bias_relu(x, w, b, relu=True)
    assert float(out.min()) == 0.0
    out = fused_matmul_bias_relu(x, w, b, relu=False)
    assert float(out.max()) == -1.0


def test_ceil_pow2():
    assert _ceil_pow2(1) == 8
    assert _ceil_pow2(8) == 8
    assert _ceil_pow2(9) == 16
    assert _ceil_pow2(128) == 128
    assert _ceil_pow2(129) == 256


# -------------------------------------------------------------------- conv2d

@given(
    h=st.integers(4, 24),
    c=st.integers(1, 8),
    f=st.integers(1, 8),
    kk=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    relu=st.booleans(),
)
def test_conv_matches_ref_sweep(h, c, f, kk, stride, padding, relu):
    x = _rand(h * 31 + c, (1, h, h, c), jnp.float32)
    filt = _rand(f * 13 + 5, (kk, kk, c, f), jnp.float32)
    b = _rand(f * 13 + 6, (f,), jnp.float32)
    out = conv2d(x, filt, b, stride=stride, padding=padding, relu=relu)
    ref = conv2d_ref(x, filt, b, stride=stride, padding=padding, relu=relu)
    assert out.shape == ref.shape
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_conv_batched():
    x = _rand(1, (3, 16, 16, 4), jnp.float32)
    filt = _rand(2, (3, 3, 4, 8), jnp.float32)
    b = _rand(3, (8,), jnp.float32)
    out = conv2d(x, filt, b)
    ref = conv2d_ref(x, filt, b)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_conv_channel_mismatch_raises():
    with pytest.raises(ValueError):
        conv2d(jnp.zeros((1, 8, 8, 3)), jnp.zeros((3, 3, 4, 8)),
               jnp.zeros((8,)))


def test_im2col_identity_kernel():
    """1x1/stride-1 im2col is a pure reshape of the input."""
    x = _rand(9, (1, 6, 6, 5), jnp.float32)
    cols = im2col(x, 1, 1, 1)
    np.testing.assert_allclose(cols, x.reshape(36, 5))


def test_dense_matches_ref():
    x = _rand(1, (10, 33), jnp.float32)
    w = _rand(2, (33, 7), jnp.float32)
    b = _rand(3, (7,), jnp.float32)
    np.testing.assert_allclose(dense(x, w, b), dense_ref(x, w, b),
                               rtol=1e-5, atol=1e-5)


def test_max_pool():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    out = max_pool(x)
    np.testing.assert_allclose(out.reshape(4), [5.0, 7.0, 13.0, 15.0])
