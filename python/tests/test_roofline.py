"""Roofline estimator sanity: the static model behind the §Perf L1 numbers."""

import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_matmul import BlockConfig
from compile.kernels.roofline import (VMEM_BYTES, estimate, sweep_blocks)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def test_exact_tile_fit_has_full_mxu_utilization():
    e = estimate(128, 128, 128, BlockConfig(128, 128, 128))
    assert e.mxu_utilization == pytest.approx(1.0)


def test_padding_hurts_utilization():
    exact = estimate(128, 128, 128, BlockConfig(128, 128, 128))
    padded = estimate(129, 128, 128, BlockConfig(128, 128, 128))
    assert padded.mxu_utilization < exact.mxu_utilization


def test_vmem_budget_flags_oversized_blocks():
    e = estimate(4096, 4096, 4096, BlockConfig(1024, 1024, 1024))
    assert e.vmem_bytes > VMEM_BYTES
    assert not e.vmem_ok


@given(m=st.integers(8, 2048), n=st.integers(8, 2048), k=st.integers(8, 2048))
def test_estimate_invariants(m, n, k):
    e = estimate(m, n, k)
    assert e.flops == 2 * m * n * k
    assert 0.0 < e.mxu_utilization <= 1.0
    assert e.hbm_bytes >= (m * k + k * n + m * n) * 4
    assert e.est_time_s > 0
    assert 0.0 < e.efficiency <= 1.0
    assert e.roofline_bound in ("compute", "memory")


def test_small_gemm_is_memory_bound():
    # The Ocularone conv GEMMs are small; they should sit on the memory roof.
    assert estimate(1024, 32, 144).roofline_bound == "memory"


def test_large_square_gemm_is_compute_bound():
    # bf16 with 512-edge tiles: AI ≈ 250 > peak/bw ≈ 229 under the
    # no-cross-tile-reuse traffic model, and 512 % 128 == 0 keeps MXU
    # utilization at 1.0 — so the kernel sits on the compute roof.
    e = estimate(8192, 8192, 8192, BlockConfig(512, 512, 512), dtype_bytes=2)
    assert e.vmem_ok
    assert e.roofline_bound == "compute"


def test_sweep_returns_feasible_sorted():
    out = sweep_blocks(1024, 64, 144)
    assert out, "sweep must find at least one feasible block"
    assert all(e.vmem_ok for e in out)
    effs = [e.efficiency for e in out]
    assert effs == sorted(effs, reverse=True)
