"""AOT path: HLO-text emission, manifest integrity, artifact loadability."""

import json
import os

import pytest

from compile.aot import lower_model, to_hlo_text
from compile.model import MODELS

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.parametrize("name", sorted(MODELS))
def test_lower_model_emits_hlo_text(name):
    text = lower_model(name)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # One image parameter only — weights must be constant-folded. The entry
    # layout is `{(f32[NHWC])->(f32[L])}`: a single input tuple element.
    spec = MODELS[name]
    n, h, w, c = spec.input_shape
    layout = text.splitlines()[0]
    assert f"(f32[{n},{h},{w},{c}]" in layout
    assert layout.count("f32[") == 2  # one input, one output
    # Large constants must be printed in full: the elided form
    # "constant({...})" silently parses back as ZEROS under xla_extension
    # 0.5.1, wiping the model weights (see aot.to_hlo_text).
    assert "constant({...})" not in text


def test_lowering_is_deterministic():
    assert lower_model("hv") == lower_model("hv")


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS,
                                                    "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_matches_specs():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest) == set(MODELS)
    for name, entry in manifest.items():
        spec = MODELS[name]
        assert entry["input_shape"] == list(spec.input_shape)
        assert entry["output_len"] == spec.output_len
        path = os.path.join(ARTIFACTS, entry["hlo"])
        assert os.path.exists(path)
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule")


def test_to_hlo_text_simple_fn():
    import jax
    import jax.numpy as jnp

    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
