"""L2 model contracts: shapes, determinism, value sanity per DNN."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import MODELS, run


def _img(spec, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), spec.input_shape,
                              jnp.float32)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_output_contract(name):
    spec = MODELS[name]
    out = run(name, _img(spec))
    assert out.shape == (spec.output_len,)
    assert out.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name", sorted(MODELS))
def test_deterministic(name):
    spec = MODELS[name]
    a = run(name, _img(spec, 3))
    b = run(name, _img(spec, 3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", sorted(MODELS))
def test_input_sensitivity(name):
    """Different frames must produce different inferences (non-degenerate)."""
    spec = MODELS[name]
    a = run(name, _img(spec, 1))
    b = run(name, _img(spec, 2))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_hv_box_normalized():
    out = np.asarray(run("hv", _img(MODELS["hv"])))
    assert ((out >= 0.0) & (out <= 1.0)).all()  # sigmoid box + conf


def test_dev_distance_positive_and_plausible():
    out = float(run("dev", _img(MODELS["dev"]))[0])
    assert 0.0 < out < 50.0  # metres


def test_md_class_scores_sum_to_one():
    out = np.asarray(run("md", _img(MODELS["md"]))).reshape(-1, 6)
    np.testing.assert_allclose(out[:, 4] + out[:, 5], 1.0, rtol=1e-5)


def test_bp_keypoints_in_unit_square():
    out = np.asarray(run("bp", _img(MODELS["bp"]))).reshape(18, 2)
    assert ((out >= 0.0) & (out <= 1.0)).all()


def test_cd_count_equals_density_sum():
    out = np.asarray(run("cd", _img(MODELS["cd"])))
    np.testing.assert_allclose(out[0], out[1:].sum(), rtol=1e-4)
    assert (out[1:] >= 0.0).all()  # ReLU density map


def test_deo_depths_positive():
    out = np.asarray(run("deo", _img(MODELS["deo"])))
    assert (out > 0.0).all()  # softplus
