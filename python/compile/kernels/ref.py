"""Pure-jnp oracles for the L1 Pallas kernel and the conv building blocks.

These never touch Pallas; pytest compares the kernel path against them with
``assert_allclose`` across hypothesis-driven shape/dtype sweeps — the CORE
correctness signal for layer 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_relu_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                         relu: bool = True) -> jax.Array:
    """Reference for ``fused_matmul_bias_relu``: f32-accumulated GEMM."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(
        jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def conv2d_ref(x: jax.Array, filt: jax.Array, bias: jax.Array, *,
               stride: int = 1, padding: str = "SAME",
               relu: bool = True) -> jax.Array:
    """Reference conv using ``lax.conv_general_dilated`` (NHWC/HWIO)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        filt.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + bias.astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array,
              relu: bool = True) -> jax.Array:
    """Reference dense layer (same math as the GEMM oracle)."""
    return matmul_bias_relu_ref(x, w, b, relu)
