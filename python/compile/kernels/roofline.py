"""Analytic VMEM-footprint / MXU-utilization estimates for the L1 kernel.

``interpret=True`` runs the kernel as numpy on CPU, so wallclock there says
nothing about TPU performance. Instead we estimate, per ``BlockConfig``:

* the VMEM working set (input, weight, bias, output and accumulator tiles,
  double-buffered as the Mosaic pipeliner would);
* MXU utilization: the fraction of issued 128x128x128 systolic passes doing
  useful work, given tile-edge padding;
* the HBM traffic and resulting arithmetic intensity, and the roofline-
  limited efficiency on a nominal TPU-v4-like core (275 TF/s bf16 MXU,
  1.2 TB/s HBM).

These numbers are reported by ``aot.py --report`` and recorded in
EXPERIMENTS.md §Perf; the block-shape iteration in DESIGN.md §6 optimizes
against them.
"""

from __future__ import annotations

from dataclasses import dataclass

from .fused_matmul import BlockConfig

VMEM_BYTES = 16 * 2**20  # per-core VMEM budget (v4-like)
MXU_EDGE = 128  # systolic array edge
PEAK_FLOPS = 275e12  # bf16 MXU peak, nominal
HBM_BW = 1.2e12  # bytes/s


@dataclass(frozen=True)
class KernelEstimate:
    """Static performance model of one fused-GEMM launch."""

    m: int
    n: int
    k: int
    block: BlockConfig
    vmem_bytes: int
    vmem_ok: bool
    mxu_utilization: float
    flops: int
    hbm_bytes: int
    arithmetic_intensity: float
    roofline_bound: str
    est_time_s: float
    efficiency: float  # achieved/peak at the roofline bound


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def estimate(m: int, n: int, k: int, block: BlockConfig = BlockConfig(),
             dtype_bytes: int = 4) -> KernelEstimate:
    """Estimate the kernel's TPU behaviour for an ``[m,k] @ [k,n]`` GEMM."""
    bm, bn, bk = block.bm, block.bn, block.bk
    # Active tiles: x[bm,bk], w[bk,bn], bias[1,bn], out[bm,bn], acc f32.
    single = (bm * bk + bk * bn + bn + bm * bn) * dtype_bytes + bm * bn * 4
    vmem = 2 * single  # double-buffered by the pipeliner
    # Padding waste: tiles cover ceil(dim/edge) systolic passes.
    mp, np_, kp = (_ceil_div(m, bm) * bm, _ceil_div(n, bn) * bn,
                   _ceil_div(k, bk) * bk)
    useful = m * n * k
    issued = mp * np_ * kp
    # The MXU additionally pads each tile edge to 128.
    mxu_passes = (_ceil_div(bm, MXU_EDGE) * _ceil_div(bn, MXU_EDGE)
                  * _ceil_div(bk, MXU_EDGE))
    tile_useful = min(bm, MXU_EDGE * _ceil_div(bm, MXU_EDGE)) * bn * bk
    mxu_util = (useful / issued) * (
        (bm * bn * bk) / (mxu_passes * MXU_EDGE**3)
        if mxu_passes * MXU_EDGE**3 > tile_useful else 1.0)
    mxu_util = min(mxu_util, 1.0)

    flops = 2 * useful
    # HBM traffic: x read once per N-tile sweep, w once per M-tile sweep,
    # out written once (epilogue fused).
    n_tiles_n = _ceil_div(n, bn)
    n_tiles_m = _ceil_div(m, bm)
    hbm = (m * k * n_tiles_n + k * n * n_tiles_m + m * n) * dtype_bytes
    ai = flops / hbm
    t_compute = flops / (PEAK_FLOPS * max(mxu_util, 1e-9))
    t_mem = hbm / HBM_BW
    bound = "compute" if t_compute >= t_mem else "memory"
    t = max(t_compute, t_mem)
    eff = (flops / t) / PEAK_FLOPS
    return KernelEstimate(m, n, k, block, vmem, vmem <= VMEM_BYTES,
                          mxu_util, flops, hbm, ai, bound, t, eff)


def sweep_blocks(m: int, n: int, k: int,
                 edges=(64, 128, 256, 512)) -> list[KernelEstimate]:
    """Grid-sweep block shapes, VMEM-feasible only, best efficiency first."""
    out = []
    for bm in edges:
        for bn in edges:
            for bk in edges:
                e = estimate(m, n, k, BlockConfig(bm, bn, bk))
                if e.vmem_ok:
                    out.append(e)
    return sorted(out, key=lambda e: -e.efficiency)
