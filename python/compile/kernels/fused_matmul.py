"""L1 Pallas kernel: tiled fused ``matmul + bias + ReLU``.

This is the compute hot-spot of every DNN in the Ocularone workload. Each
convolution layer is lowered to an im2col GEMM (see :mod:`.im2col`), so one
well-tiled matmul kernel carries the whole inference stack.

TPU adaptation of the paper's CUDA hot loop (DESIGN.md §2):

* The CUDA models tile for shared memory + tensor cores; here the tiling is
  expressed with ``BlockSpec`` over a ``(M/bm, N/bn, K/bk)`` grid so the
  HBM->VMEM schedule is explicit and each active tile set fits VMEM.
* Accumulation happens in an f32 VMEM scratch across the K grid axis
  (``arbitrary`` semantics on that axis); the bias add and ReLU are fused
  into the *final* K step so each output tile is written to HBM exactly
  once — no separate elementwise pass.
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls, so the kernel must lower to plain HLO. Real-TPU efficiency
  is estimated analytically in :mod:`.roofline`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


class BlockConfig(NamedTuple):
    """Tile sizes for the fused matmul grid.

    ``bm``/``bn``/``bk`` are the M/N/K tile edges. Defaults are MXU-shaped
    (128x128 systolic array) while keeping the working set small enough to
    double-buffer in a 16 MiB VMEM budget (see roofline.py).
    """

    bm: int = 128
    bn: int = 128
    bk: int = 128


DEFAULT_BLOCK = BlockConfig()


def _fused_matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                         relu: bool):
    """Grid point ``(i, j, k)``: accumulate ``x[i,k] @ w[k,j]`` into scratch.

    On the last K step the bias row is added, ReLU applied, and the tile is
    emitted. ``acc_ref`` is an f32 VMEM scratch that lives across the K axis
    of the grid (``dimension_semantics`` marks K as ``arbitrary``).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _emit():
        out = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _pad_to(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, multiples):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("relu", "block"))
def fused_matmul_bias_relu(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    relu: bool = True,
    block: BlockConfig = DEFAULT_BLOCK,
) -> jax.Array:
    """``relu(x @ w + b)`` via the tiled Pallas kernel.

    Args:
      x: ``[M, K]`` activations (f32 or bf16).
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      relu: fuse a ReLU into the epilogue (disabled for regression heads).
      block: tile configuration; shapes are zero-padded up to tile multiples
        and the result is sliced back, so arbitrary M/N/K are accepted.

    Returns:
      ``[M, N]`` array with the dtype of ``x``.
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    if x.shape[1] != w.shape[0] or w.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes: x{x.shape} w{w.shape} b{b.shape}")

    m, k = x.shape
    _, n = w.shape
    bm = min(block.bm, _ceil_pow2(m))
    bn = min(block.bn, _ceil_pow2(n))
    bk = min(block.bk, _ceil_pow2(k))

    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    bp = _pad_to(b, (bn,))[None, :]  # [1, Np] row, broadcast over the tile

    mp, kp = xp.shape
    np_ = wp.shape[1]
    n_k = kp // bk
    grid = (mp // bm, np_ // bn, n_k)

    out = pl.pallas_call(
        functools.partial(_fused_matmul_kernel, n_k=n_k, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )

    return out(xp, wp, bp)[:m, :n]


def _ceil_pow2(v: int) -> int:
    """Smallest power of two >= v (min 8) — keeps tiny shapes one-tile."""
    p = 8
    while p < v:
        p *= 2
    return p
