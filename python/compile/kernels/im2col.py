"""conv2d -> GEMM lowering: im2col patch extraction + the L1 fused kernel.

Every convolution in the six Ocularone DNNs goes through this path, so the
whole inference stack funnels into the single Pallas matmul (DESIGN.md §2).

Layout convention: NHWC activations, HWIO filters — the natural layouts for
TPU and for jax.lax conv helpers, and the ones XLA keeps without inserting
transposes (verified in the lowered HLO; see EXPERIMENTS.md §Perf L2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .fused_matmul import BlockConfig, DEFAULT_BLOCK, fused_matmul_bias_relu


def im2col(x: jax.Array, kh: int, kw: int, stride: int,
           padding: str = "SAME") -> jax.Array:
    """Extract convolution patches as a GEMM-ready matrix.

    Args:
      x: ``[N, H, W, C]`` input.
      kh, kw: filter spatial dims.
      stride: spatial stride (same for H and W).
      padding: "SAME" or "VALID".

    Returns:
      ``[N * OH * OW, KH * KW * C]`` patch matrix.
    """
    n, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns features ordered as C*KH*KW with
    # channel slowest; reorder to KH*KW*C to match a HWIO filter reshape.
    oh, ow = patches.shape[1], patches.shape[2]
    patches = patches.reshape(n, oh, ow, c, kh, kw)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)
    return patches.reshape(n * oh * ow, kh * kw * c)


def out_spatial(h: int, w: int, kh: int, kw: int, stride: int,
                padding: str) -> tuple[int, int]:
    """Output spatial dims of a conv (mirrors XLA's SAME/VALID rules)."""
    if padding == "SAME":
        return -(-h // stride), -(-w // stride)
    return (h - kh) // stride + 1, (w - kw) // stride + 1


def conv2d(
    x: jax.Array,
    filt: jax.Array,
    bias: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = True,
    block: BlockConfig = DEFAULT_BLOCK,
) -> jax.Array:
    """2-D convolution via im2col + the L1 Pallas fused GEMM.

    Args:
      x: ``[N, H, W, C]`` input.
      filt: ``[KH, KW, C, F]`` filter (HWIO).
      bias: ``[F]``.

    Returns:
      ``[N, OH, OW, F]``, ReLU-fused unless ``relu=False``.
    """
    n, h, w, c = x.shape
    kh, kw, ci, f = filt.shape
    if ci != c:
        raise ValueError(f"channel mismatch: input {c} vs filter {ci}")
    cols = im2col(x, kh, kw, stride, padding)
    wmat = filt.reshape(kh * kw * c, f)
    out = fused_matmul_bias_relu(cols, wmat, bias, relu=relu, block=block)
    oh, ow = out_spatial(h, w, kh, kw, stride, padding)
    return out.reshape(n, oh, ow, f)


def dense(x: jax.Array, w: jax.Array, b: jax.Array, *, relu: bool = True,
          block: BlockConfig = DEFAULT_BLOCK) -> jax.Array:
    """Fully-connected layer on the same fused kernel. ``x: [N, K]``."""
    return fused_matmul_bias_relu(x, w, b, relu=relu, block=block)


def max_pool(x: jax.Array, size: int = 2, stride: int = 2) -> jax.Array:
    """``[N,H,W,C]`` max pool — memory-bound, left to XLA's reduce-window."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, size, size, 1), (1, stride, stride, 1), "VALID",
    )


def global_avg_pool(x: jax.Array) -> jax.Array:
    """``[N,H,W,C] -> [N,C]`` spatial mean."""
    return jnp.mean(x, axis=(1, 2))
