# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .fused_matmul import BlockConfig, DEFAULT_BLOCK, fused_matmul_bias_relu
from .im2col import conv2d, dense, global_avg_pool, im2col, max_pool

__all__ = [
    "BlockConfig",
    "DEFAULT_BLOCK",
    "fused_matmul_bias_relu",
    "conv2d",
    "dense",
    "global_avg_pool",
    "im2col",
    "max_pool",
]
