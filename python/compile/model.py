"""L2: the six Ocularone DNN inferencing models, in JAX over the L1 kernel.

The paper's workload (Table 1) runs six vision DNNs per video segment:

=====  ============================  =================  =====================
name   paper model                   task               output contract here
=====  ============================  =================  =====================
HV     YOLOv8-nano (retrained)       hazard-vest bbox   ``[5]``  x,y,w,h,conf
DEV    YOLOv8-nano + linear reg.     distance to VIP    ``[1]``  metres
MD     SSD (AIZOOTech)               face-mask boxes    ``[G*G*6]`` grid boxes
BP     ResNet-18 pose (18 kp)        body pose          ``[36]`` kp (x,y)
CD     YOLOv8-medium                 crowd density      ``[1+G*G]`` count+map
DEO    Monodepth2                    depth to objects   ``[D*D]`` depth map
=====  ============================  =================  =====================

These are *small but real* conv nets (DESIGN.md §1 substitution table): the
scheduler treats DNNs as opaque (duration/benefit/deadline), so fidelity of
the I/O contract and of the compute structure — conv stacks funnelled through
the Pallas GEMM — is what matters, not the 100-MB weight zoos.

Weights are deterministic (seeded per model) and are closed over, so they
constant-fold into the lowered HLO: the Rust runtime feeds one image tensor
and receives one flat f32 vector per model.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv2d, dense, global_avg_pool, max_pool


@dataclass(frozen=True)
class ModelSpec:
    """Static description of one deployable model artifact."""

    name: str
    input_shape: tuple[int, int, int, int]  # NHWC
    output_len: int
    seed: int
    fn: Callable[[jax.Array], jax.Array]


def _w(key, *shape, scale=None):
    fan_in = 1
    for s in shape[:-1]:
        fan_in *= s
    scale = scale if scale is not None else (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


def _conv_params(key, kh, kw, cin, cout):
    k1, k2 = jax.random.split(key)
    return _w(k1, kh, kw, cin, cout), _w(k2, cout, scale=0.01)


def _dense_params(key, cin, cout):
    k1, k2 = jax.random.split(key)
    return _w(k1, cin, cout), _w(k2, cout, scale=0.01)


def _backbone(x, params, strides):
    """Shared conv backbone: conv(s)->relu chain via the Pallas GEMM."""
    for (f, b), s in zip(params, strides):
        x = conv2d(x, f, b, stride=s)
    return x


def _make_backbone_params(key, cins, couts):
    keys = jax.random.split(key, len(couts))
    return [_conv_params(k, 3, 3, ci, co)
            for k, ci, co in zip(keys, cins, couts)]


# --------------------------------------------------------------------------
# HV — hazard-vest detector (YOLO-nano analogue).
# Grid detector: 8x8 cells x (x,y,w,h,conf); output = best-confidence box.
# --------------------------------------------------------------------------

def _hv_fn(seed: int):
    key = jax.random.PRNGKey(seed)
    kb, kh = jax.random.split(key)
    bb = _make_backbone_params(kb, [3, 16, 32], [16, 32, 64])
    head_f, head_b = _conv_params(kh, 1, 1, 64, 5)

    def fn(img: jax.Array) -> jax.Array:
        x = _backbone(img, bb, [2, 2, 2])          # [1,8,8,64]
        g = conv2d(x, head_f, head_b, relu=False)  # [1,8,8,5]
        g = g.reshape(-1, 5)
        conf = jax.nn.sigmoid(g[:, 4])
        best = jnp.argmax(conf)
        box = g[best]
        return jnp.concatenate([jax.nn.sigmoid(box[:4]), conf[best][None]])

    return fn


# --------------------------------------------------------------------------
# DEV — distance estimation to the VIP: HV-style detector + linear
# regression over (h, w, area) of the best box, as in the paper (§7).
# --------------------------------------------------------------------------

def _dev_fn(seed: int):
    key = jax.random.PRNGKey(seed)
    kb, kh, kr = jax.random.split(key, 3)
    bb = _make_backbone_params(kb, [3, 16, 32], [16, 32, 64])
    head_f, head_b = _conv_params(kh, 1, 1, 64, 5)
    reg_w, reg_b = _dense_params(kr, 3, 1)

    def fn(img: jax.Array) -> jax.Array:
        x = _backbone(img, bb, [2, 2, 2])
        g = conv2d(x, head_f, head_b, relu=False).reshape(-1, 5)
        best = g[jnp.argmax(jax.nn.sigmoid(g[:, 4]))]
        h = jax.nn.sigmoid(best[2])
        w = jax.nn.sigmoid(best[3])
        feats = jnp.stack([h, w, h * w])[None, :]
        dist = dense(feats, reg_w, reg_b, relu=False)
        # Calibrated to metres: inverse relation to apparent height.
        return (3.0 / (h + 0.1) + 0.1 * dist[0]).reshape(1)

    return fn


# --------------------------------------------------------------------------
# MD — face-mask detection (SSD analogue): per-cell box + 2-class scores.
# --------------------------------------------------------------------------

def _md_fn(seed: int):
    key = jax.random.PRNGKey(seed)
    kb, kh = jax.random.split(key)
    bb = _make_backbone_params(kb, [3, 12, 24], [12, 24, 48])
    head_f, head_b = _conv_params(kh, 1, 1, 48, 6)

    def fn(img: jax.Array) -> jax.Array:
        x = _backbone(img, bb, [2, 2, 2])          # [1,8,8,48]
        g = conv2d(x, head_f, head_b, relu=False)  # [1,8,8,6]
        g = g.reshape(-1, 6)
        boxes = jax.nn.sigmoid(g[:, :4])
        cls = jax.nn.softmax(g[:, 4:], axis=-1)    # P(mask), P(no-mask)
        return jnp.concatenate([boxes, cls], axis=1).reshape(-1)

    return fn


# --------------------------------------------------------------------------
# BP — body-pose estimation (ResNet-18 pose analogue): 18 keypoints.
# Heatmap head + soft-argmax -> (x, y) per landmark.
# --------------------------------------------------------------------------

def _bp_fn(seed: int):
    key = jax.random.PRNGKey(seed)
    kb, kh = jax.random.split(key)
    bb = _make_backbone_params(kb, [3, 16, 32, 64], [16, 32, 64, 64])
    head_f, head_b = _conv_params(kh, 1, 1, 64, 18)

    def fn(img: jax.Array) -> jax.Array:
        x = _backbone(img, bb, [2, 2, 1, 2])        # [1,8,8,64]
        hm = conv2d(x, head_f, head_b, relu=False)  # [1,8,8,18]
        hm = hm.reshape(64, 18)
        p = jax.nn.softmax(hm, axis=0)              # per-keypoint heatmap
        idx = jnp.arange(64, dtype=jnp.float32)
        ys = (p * (idx // 8)[:, None]).sum(0) / 8.0
        xs = (p * (idx % 8)[:, None]).sum(0) / 8.0
        return jnp.stack([xs, ys], axis=1).reshape(-1)  # [36]

    return fn


# --------------------------------------------------------------------------
# CD — crowd-density estimation (YOLOv8-medium analogue): density map over a
# larger input + wider backbone; output = [count, 16x16 density map].
# --------------------------------------------------------------------------

def _cd_fn(seed: int):
    key = jax.random.PRNGKey(seed)
    kb, kh = jax.random.split(key)
    bb = _make_backbone_params(kb, [3, 24, 48, 96], [24, 48, 96, 96])
    head_f, head_b = _conv_params(kh, 1, 1, 96, 1)

    def fn(img: jax.Array) -> jax.Array:
        x = _backbone(img, bb, [2, 2, 1, 1])        # [1,24,24,96]
        x = max_pool(x)                             # [1,12,12,96]
        d = conv2d(x, head_f, head_b, relu=True)    # [1,12,12,1]
        dmap = d.reshape(-1)                        # [144]
        count = dmap.sum()[None]
        return jnp.concatenate([count, dmap])       # [145]

    return fn


# --------------------------------------------------------------------------
# DEO — depth estimation to objects (Monodepth2 analogue): encoder-decoder,
# dense depth map out. Heaviest model, matching its Table-1 durations.
# --------------------------------------------------------------------------

def _deo_fn(seed: int):
    key = jax.random.PRNGKey(seed)
    ke, kd1, kd2 = jax.random.split(key, 3)
    enc = _make_backbone_params(ke, [3, 32, 64, 128], [32, 64, 128, 128])
    dec1_f, dec1_b = _conv_params(kd1, 3, 3, 128, 64)
    dec2_f, dec2_b = _conv_params(kd2, 1, 1, 64, 1)

    def fn(img: jax.Array) -> jax.Array:
        x = _backbone(img, enc, [2, 2, 2, 1])        # [1,12,12,128]
        x = jax.image.resize(x, (1, 24, 24, 128), "nearest")
        x = conv2d(x, dec1_f, dec1_b)                # [1,24,24,64]
        d = conv2d(x, dec2_f, dec2_b, relu=False)    # [1,24,24,1]
        return jax.nn.softplus(d).reshape(-1)        # [576] positive depths

    return fn


SMALL = (1, 64, 64, 3)
MEDIUM = (1, 96, 96, 3)

MODELS: dict[str, ModelSpec] = {
    "hv": ModelSpec("hv", SMALL, 5, 11, _hv_fn(11)),
    "dev": ModelSpec("dev", SMALL, 1, 13, _dev_fn(13)),
    "md": ModelSpec("md", SMALL, 8 * 8 * 6, 17, _md_fn(17)),
    "bp": ModelSpec("bp", SMALL, 36, 19, _bp_fn(19)),
    "cd": ModelSpec("cd", MEDIUM, 145, 23, _cd_fn(23)),
    "deo": ModelSpec("deo", MEDIUM, 576, 29, _deo_fn(29)),
}


def run(name: str, img: jax.Array) -> jax.Array:
    """Execute model ``name`` eagerly (used by tests)."""
    return MODELS[name].fn(img)
