"""AOT compile path: lower every L2 model to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and EXPERIMENTS.md.

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts          # all models
    python -m compile.aot --models hv,bp --out-dir ...    # subset
    python -m compile.aot --report                        # roofline report

Each artifact is a single-parameter computation ``f32[NHWC] -> (f32[L],)``
(lowered with ``return_tuple=True``; the Rust side unwraps with
``to_tuple1``). A ``manifest.json`` records shapes so the Rust runtime can
validate what it loads.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .kernels.roofline import estimate, sweep_blocks
from .model import MODELS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is ESSENTIAL: the default printer elides
    big dense literals as ``constant({...})``, which xla_extension 0.5.1's
    text parser silently reads back as zeros — the model weights would
    vanish and every inference would return bias-only outputs.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(name: str) -> str:
    spec = MODELS[name]
    arg = jax.ShapeDtypeStruct(spec.input_shape, jax.numpy.float32)
    lowered = jax.jit(spec.fn).lower(arg)
    return to_hlo_text(lowered)


def emit_all(out_dir: str, names: list[str]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name in names:
        spec = MODELS[name]
        text = lower_model(name)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "input_shape": list(spec.input_shape),
            "output_len": spec.output_len,
            "hlo": f"{name}.hlo.txt",
            "hlo_bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest for {len(names)} models")


def report() -> None:
    """Print the §Perf roofline report for the kernel's model shapes."""
    # Representative GEMMs: the largest im2col GEMM of each model.
    shapes = {
        "hv/dev conv2 (32x32)": (32 * 32, 32, 3 * 3 * 16),
        "bp conv3 (16x16)": (16 * 16, 64, 3 * 3 * 32),
        "cd conv3 (24x24)": (24 * 24, 96, 3 * 3 * 48),
        "deo dec1 (24x24)": (24 * 24, 64, 3 * 3 * 128),
        "deo enc3 (24x24)": (24 * 24, 128, 3 * 3 * 64),
    }
    for label, (m, n, k) in shapes.items():
        best = sweep_blocks(m, n, k)[0]
        dflt = estimate(m, n, k)
        print(f"{label}: M={m} N={n} K={k}")
        print(f"  default 128^3: vmem={dflt.vmem_bytes/2**10:.0f}KiB "
              f"mxu={dflt.mxu_utilization:.3f} bound={dflt.roofline_bound} "
              f"eff={dflt.efficiency:.3f}")
        print(f"  best {best.block}: vmem={best.vmem_bytes/2**10:.0f}KiB "
              f"mxu={best.mxu_utilization:.3f} bound={best.roofline_bound} "
              f"eff={best.efficiency:.3f}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None,
                   help="compat: emit single combined artifact path marker")
    p.add_argument("--models", default=",".join(MODELS))
    p.add_argument("--report", action="store_true")
    args = p.parse_args()
    if args.report:
        report()
        return
    names = [n for n in args.models.split(",") if n]
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    emit_all(out_dir or args.out_dir, names)
    if args.out:
        # Makefile stamp target: mark completion of the full artifact set.
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
