//! Integration pins for the observability layer (`rust/src/obs.rs`):
//!
//! * **Bit-identity** — attaching a trace sink must not perturb the run:
//!   the traced engine's `ClusterMetrics` (every counter, utility sum,
//!   histogram and events-processed tick) equals the untraced engine's.
//! * **Histogram parity** — the O(1)-memory `LogHistogram` percentiles
//!   stay within ±0.5% of the exact `Vec<f64>` sample path they replaced
//!   (re-enabled via `Metrics::record_exact_samples`) on a fig8-style
//!   stress run, and the exact vectors stay empty by default so metrics
//!   memory no longer grows per task.
//! * **Timeline conservation** — the windowed time-series fold sums back
//!   to the run's ledger: generated / completed / missed / dropped /
//!   QoS utility / uplink wait across windows equal the run totals.
//! * **Writer round-trip** — a real run streamed through `JsonlSink` is
//!   valid JSON per line, and through `ChromeSink` a loadable trace-event
//!   array with balanced begin/end spans.

use std::sync::{Arc, Mutex};

use ocularone::exec::CloudExecModel;
use ocularone::fault::FaultSpec;
use ocularone::fleet::Workload;
use ocularone::metrics;
use ocularone::net::LognormalWan;
use ocularone::obs::{ChromeSink, JsonlSink, SharedSink, Timeline, VecSink};
use ocularone::platform::Platform;
use ocularone::policy::Policy;
use ocularone::report::{parse_json, JsonValue};
use ocularone::resilience::ResilienceSpec;
use ocularone::rng::Rng;
use ocularone::scenario::{
    run_cluster_observed, CloudSpec, FederationSpec,
};
use ocularone::sim;
use ocularone::time::{ms, secs};

fn wan() -> CloudExecModel {
    CloudExecModel::new(Box::new(LognormalWan::default()))
}

/// Tracing must be a pure observer: the traced run's metrics — including
/// per-model histograms, utilities and the events-processed counter —
/// are bit-identical to the untraced run's, across federation, faults
/// and the resilience layer.
#[test]
fn traced_run_is_bit_identical_to_untraced() {
    let policy = Policy::dems_a().with_resilience(ResilienceSpec {
        hedge: true,
        hedge_delay: ms(200),
        hedge_slack: 0,
        breaker: true,
        ..ResilienceSpec::default()
    });
    let wl = Workload::emulation(3, true).with_duration(secs(20));
    let fed = FederationSpec::stealing();
    let faults = FaultSpec::random(&mut Rng::new(0xF00D), 3, secs(20));
    let untraced = run_cluster_observed(
        &policy, &wl, 42, 3, &CloudSpec::NominalWan, Some(&fed),
        Some(&faults), None, None,
    );
    let sink = Arc::new(Mutex::new(VecSink::default()));
    let shared: SharedSink = sink.clone();
    let traced = run_cluster_observed(
        &policy, &wl, 42, 3, &CloudSpec::NominalWan, Some(&fed),
        Some(&faults), Some(shared), None,
    );
    assert!(
        !sink.lock().unwrap().events.is_empty(),
        "trace sink saw no events"
    );
    assert!(untraced.generated() > 0, "degenerate scenario");
    assert_eq!(traced, untraced, "tracing perturbed the run");
}

/// The streaming histograms replace the per-task sample vectors behind
/// the same `percentile` semantics: within ±0.5% of the exact value at
/// every probed quantile of a fig8-style stress run, for both the
/// all-executions and the cloud-side distributions.
#[test]
fn histogram_percentiles_track_exact_samples_on_a_fig8_run() {
    let wl = Workload::emulation(4, true);
    let mut p = Platform::new(Policy::dems(), wl.models.clone(), wan(), 3);
    p.metrics.record_exact_samples = true;
    let m = sim::run(p, &wl, 3);
    let mut checked = 0usize;
    for (kind, s) in &m.per_model {
        for (exact_ms, hist) in [
            (&s.exec_ms, &s.exec_hist),
            (&s.cloud_exec_ms, &s.cloud_exec_hist),
        ] {
            assert_eq!(
                exact_ms.len() as u64,
                hist.count(),
                "{kind:?}: exact and streaming paths saw different \
                 populations"
            );
            if exact_ms.len() < 50 {
                continue;
            }
            for pct in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let exact = metrics::percentile(exact_ms, pct);
                let approx = hist.percentile(pct);
                let rel = (approx - exact).abs() / exact;
                assert!(
                    rel <= 0.005,
                    "{kind:?} p{pct}: exact {exact} vs hist {approx} \
                     (rel {rel})"
                );
            }
            checked += 1;
        }
    }
    assert!(checked > 0, "no distribution dense enough to probe");
}

/// By default the exact sample vectors (and the completion log) stay
/// empty — per-task memory growth is opt-in, the streaming histograms
/// carry the percentiles.
#[test]
fn metrics_memory_does_not_grow_per_task_by_default() {
    let wl = Workload::emulation(4, true);
    let p = Platform::new(Policy::dems(), wl.models.clone(), wan(), 3);
    let m = sim::run(p, &wl, 3);
    assert!(m.generated() > 0);
    assert!(m.completions.is_empty(), "completion log is opt-in");
    let mut executed = 0u64;
    for (kind, s) in &m.per_model {
        assert!(
            s.exec_ms.is_empty() && s.cloud_exec_ms.is_empty(),
            "{kind:?}: exact samples recorded without opt-in"
        );
        executed += s.executed();
        if s.executed() > 0 {
            assert!(
                !s.exec_hist.is_empty(),
                "{kind:?}: streaming histogram missed executions"
            );
        }
    }
    assert!(executed > 0, "degenerate run");
}

/// The windowed time-series fold conserves the ledger: summing every
/// window reproduces the run's generated / completed / missed / dropped
/// counts, QoS utility, uplink wait, and one queue-depth sample per
/// generated task.
#[test]
fn timeline_windows_sum_to_run_totals() {
    const WINDOW: u64 = 10_000_000; // 10 s of virtual time
    let fed = FederationSpec {
        steal: true,
        uplink_bytes_per_sec: Some(2.0e6),
        ..FederationSpec::default()
    };
    let wl = Workload::emulation(4, true).with_duration(secs(30));
    let cm = run_cluster_observed(
        &Policy::dems_a(), &wl, 7, 3, &CloudSpec::NominalWan, Some(&fed),
        None, None, Some(WINDOW),
    );
    let mut tl = Timeline::new(WINDOW);
    for m in &cm.per_edge {
        tl.merge(m.windowed.as_ref().expect("timeline enabled"));
    }
    assert!(tl.windows().len() >= 3, "run spans several windows");
    let sum = |f: &dyn Fn(&ocularone::obs::WindowStats) -> u64| -> u64 {
        tl.windows().iter().map(f).sum()
    };
    assert_eq!(sum(&|w| w.generated), cm.generated(), "generated");
    assert_eq!(sum(&|w| w.completed), cm.completed(), "completed");
    assert_eq!(sum(&|w| w.dropped), cm.dropped(), "dropped");
    assert_eq!(
        sum(&|w| w.queue_samples),
        cm.generated(),
        "one queue sample per generated task"
    );
    let missed: u64 = cm
        .per_edge
        .iter()
        .flat_map(|m| m.per_model.iter())
        .map(|(_, s)| s.missed_edge + s.missed_cloud + s.missed_drone)
        .sum();
    assert_eq!(sum(&|w| w.missed), missed, "missed");
    assert_eq!(
        sum(&|w| w.uplink_wait),
        cm.uplink_wait(),
        "uplink wait"
    );
    let utility: f64 = tl.windows().iter().map(|w| w.utility).sum();
    let qos = cm.total_qos_utility();
    assert!(
        (utility - qos).abs() <= 1e-6 + 1e-9 * qos.abs(),
        "windowed utility {utility} vs ledger {qos}"
    );
    assert!(cm.events_processed() > 0, "engine profiling counter ticks");
}

/// A real run streamed through the CLI's JSONL writer: one valid JSON
/// object per line, at least a generate + finalize pair per task.
#[test]
fn jsonl_trace_of_a_run_parses_line_by_line() {
    let dir = std::path::Path::new(env!("CARGO_TARGET_TMPDIR"));
    let path = dir.join("obs_trace.jsonl");
    let wl = Workload::emulation(2, false).with_duration(secs(10));
    let file = std::io::BufWriter::new(
        std::fs::File::create(&path).expect("create trace file"),
    );
    let sink = Arc::new(Mutex::new(JsonlSink::new(file)));
    let shared: SharedSink = sink.clone();
    let cm = run_cluster_observed(
        &Policy::dems(), &wl, 11, 1, &CloudSpec::NominalWan, None, None,
        Some(shared), None,
    );
    ocularone::obs::TraceSink::finish(&mut *sink.lock().unwrap());
    let text = std::fs::read_to_string(&path).expect("read trace back");
    let lines: Vec<&str> = text.lines().collect();
    assert!(
        lines.len() as u64 >= 2 * cm.generated(),
        "fewer trace lines ({}) than generate+finalize pairs ({})",
        lines.len(),
        2 * cm.generated()
    );
    let mut generates = 0u64;
    let mut finalizes = 0u64;
    for line in &lines {
        let JsonValue::Obj(kvs) =
            parse_json(line).expect("valid JSONL line")
        else {
            panic!("trace line is not an object: {line}");
        };
        let ev = kvs
            .iter()
            .find(|(k, _)| k == "ev")
            .map(|(_, v)| v.clone())
            .expect("every event carries an ev field");
        if ev == JsonValue::Str("generate".into()) {
            generates += 1;
        }
        if ev == JsonValue::Str("finalize".into()) {
            finalizes += 1;
        }
    }
    assert_eq!(generates, cm.generated(), "one generate line per task");
    assert_eq!(finalizes, cm.generated(), "one finalize line per task");
    let _ = std::fs::remove_file(&path);
}

/// The same run through the Chrome trace-event writer: one loadable JSON
/// array whose async task spans balance (`ph:"b"` per generate,
/// `ph:"e"` per finalize).
#[test]
fn chrome_trace_of_a_run_is_a_balanced_event_array() {
    let dir = std::path::Path::new(env!("CARGO_TARGET_TMPDIR"));
    let path = dir.join("obs_trace_chrome.json");
    let wl = Workload::emulation(2, false).with_duration(secs(10));
    let file = std::io::BufWriter::new(
        std::fs::File::create(&path).expect("create trace file"),
    );
    let sink = Arc::new(Mutex::new(ChromeSink::new(file)));
    let shared: SharedSink = sink.clone();
    let cm = run_cluster_observed(
        &Policy::dems(), &wl, 11, 1, &CloudSpec::NominalWan, None, None,
        Some(shared), None,
    );
    ocularone::obs::TraceSink::finish(&mut *sink.lock().unwrap());
    let text = std::fs::read_to_string(&path).expect("read trace back");
    let JsonValue::Arr(events) =
        parse_json(text.trim()).expect("loadable trace-event JSON")
    else {
        panic!("chrome trace is not an array");
    };
    assert!(!events.is_empty());
    let ph_count = |ph: &str| -> u64 {
        events
            .iter()
            .filter(|e| {
                let JsonValue::Obj(kvs) = e else { return false };
                kvs.iter().any(|(k, v)| {
                    k == "ph" && *v == JsonValue::Str(ph.into())
                })
            })
            .count() as u64
    };
    assert_eq!(ph_count("b"), cm.generated(), "begin span per task");
    assert_eq!(ph_count("e"), cm.generated(), "end span per task");
    let _ = std::fs::remove_file(&path);
}
