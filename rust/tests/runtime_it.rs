//! Integration tests for the PJRT runtime against the real artifacts.
//! Skipped (with a message) when `make artifacts` has not been run.

use ocularone::model::DnnKind;
use ocularone::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn loads_all_six_models() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.kinds(), DnnKind::ALL.to_vec());
    assert_eq!(rt.platform_name(), "cpu");
}

#[test]
fn inference_respects_output_contracts() {
    let Some(rt) = runtime() else { return };
    let expect = [
        (DnnKind::Hv, 5),
        (DnnKind::Dev, 1),
        (DnnKind::Md, 384),
        (DnnKind::Bp, 36),
        (DnnKind::Cd, 145),
        (DnnKind::Deo, 576),
    ];
    for (kind, len) in expect {
        let frame = rt.synth_frame(kind, 1).unwrap();
        let out = rt.model(kind).unwrap().infer(&frame).unwrap();
        assert_eq!(out.len(), len, "{kind:?} output length");
        assert!(out.iter().all(|v| v.is_finite()), "{kind:?} finite");
    }
}

#[test]
fn inference_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let frame = rt.synth_frame(DnnKind::Hv, 9).unwrap();
    let a = rt.model(DnnKind::Hv).unwrap().infer(&frame).unwrap();
    let b = rt.model(DnnKind::Hv).unwrap().infer(&frame).unwrap();
    assert_eq!(a, b);
}

#[test]
fn inference_is_input_sensitive() {
    let Some(rt) = runtime() else { return };
    let f1 = rt.synth_frame(DnnKind::Bp, 1).unwrap();
    let f2 = rt.synth_frame(DnnKind::Bp, 2).unwrap();
    let a = rt.model(DnnKind::Bp).unwrap().infer(&f1).unwrap();
    let b = rt.model(DnnKind::Bp).unwrap().infer(&f2).unwrap();
    assert_ne!(a, b);
}

#[test]
fn model_contract_violations_error() {
    let Some(rt) = runtime() else { return };
    let model = rt.model(DnnKind::Hv).unwrap();
    assert!(model.infer(&[0.0; 7]).is_err(), "wrong input length");
}

#[test]
fn outputs_satisfy_app_semantics() {
    // Same invariants the Python tests assert — but through the whole
    // AOT + PJRT + Rust path, proving the layers agree.
    let Some(rt) = runtime() else { return };
    let hv = rt
        .model(DnnKind::Hv)
        .unwrap()
        .infer(&rt.synth_frame(DnnKind::Hv, 3).unwrap())
        .unwrap();
    assert!(hv.iter().all(|&v| (0.0..=1.0).contains(&v)), "HV in [0,1]");
    let dev = rt
        .model(DnnKind::Dev)
        .unwrap()
        .infer(&rt.synth_frame(DnnKind::Dev, 3).unwrap())
        .unwrap();
    assert!(dev[0] > 0.0 && dev[0] < 50.0, "DEV plausible metres");
    let cd = rt
        .model(DnnKind::Cd)
        .unwrap()
        .infer(&rt.synth_frame(DnnKind::Cd, 3).unwrap())
        .unwrap();
    let sum: f32 = cd[1..].iter().sum();
    assert!((cd[0] - sum).abs() < 1e-2 * sum.abs().max(1.0),
            "CD count equals density sum");
    let deo = rt
        .model(DnnKind::Deo)
        .unwrap()
        .infer(&rt.synth_frame(DnnKind::Deo, 3).unwrap())
        .unwrap();
    assert!(deo.iter().all(|&v| v > 0.0), "DEO positive depths");
}
