//! Integration tests for the Scenario & Report layer: JSON round-trips,
//! the fig8 markdown equivalence pin, and registry coverage.
//!
//! The fig8 golden follows the repo's self-recording pattern (see
//! `paper_shape.rs::fig8_lineup_summaries_match_golden`): the first local
//! run records `tests/golden_fig8_md.txt`; afterwards any drift in the
//! rendered markdown under the fixed seed fails. Under `CI=...` a missing
//! golden is a hard failure.

use ocularone::report::{parse_json, JsonValue, Report};
use ocularone::scenario::{self, run_scenario};

fn section_tables(rep: &Report) -> usize {
    rep.tables().len()
}

#[test]
fn t1_json_round_trips() {
    let rep = run_scenario("t1", 42).expect("t1 runs");
    let json = rep.to_json();
    let parsed = parse_json(&json).expect("t1 emits valid JSON");
    assert_eq!(parsed.dump(), json, "parse∘dump is the identity");
    assert_eq!(section_tables(&rep), 1);
}

#[test]
fn every_registered_experiment_is_dispatchable() {
    // Cheap structural check: every id resolves in run_scenario's match
    // (invalid ids error); the heavyweight entries are exercised by the
    // CLI/CI artifact path, t1/fig2 here.
    let reg = scenario::registry();
    assert!(reg.len() >= 13);
    for quick in ["t1", "fig2"] {
        let rep = run_scenario(quick, 1).expect(quick);
        assert!(parse_json(&rep.to_json()).is_ok(), "{quick} JSON");
        assert!(rep.to_markdown().starts_with("## "), "{quick} md");
    }
    assert!(run_scenario("no-such-scenario", 1).is_err());
}

#[test]
fn fig8_markdown_matches_pre_redesign_format() {
    let rep = run_scenario("fig8", 42).expect("fig8 runs");
    let md = rep.to_markdown();
    let lines: Vec<&str> = md.lines().collect();
    // Title and column header are byte-identical to the pre-redesign
    // println! harness.
    assert_eq!(
        lines[0],
        "## Fig 8/9 — DEMS vs baselines (median edge of 7; \
         utility ×10⁵)"
    );
    assert_eq!(
        lines[1],
        "| WL | algo | tasks done | done % | QoS util | util edge | \
         util cloud | min..max util |"
    );
    // Separator row (derived from header widths).
    assert!(lines[2].chars().all(|c| c == '|' || c == '-'));
    // 6 workloads × 8 policies data rows, same `| a | b | … |` shape.
    assert_eq!(lines.len(), 3 + 6 * 8);
    for row in &lines[3..] {
        assert!(row.starts_with("| ") && row.ends_with(" |"), "{row}");
        let cells: Vec<&str> =
            row.trim_matches('|').split(" | ").collect();
        assert_eq!(cells.len(), 8, "{row}");
        assert!(cells[3].trim().ends_with('%'), "done%% cell: {row}");
    }

    // Machine-readable side: same grid, typed values.
    let json = rep.to_json();
    let parsed = parse_json(&json).expect("fig8 emits valid JSON");
    assert_eq!(parsed.dump(), json);
    let tables = rep.tables();
    assert_eq!(tables.len(), 1);
    assert_eq!(tables[0].rows.len(), 48);
    assert_json_rows_typed(&parsed);

    // Self-recording golden of the full markdown (numbers included).
    let path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_fig8_md.txt");
    match std::fs::read_to_string(path) {
        Ok(golden) => assert_eq!(
            md, golden,
            "fig8 markdown drifted from the recorded golden ({path}); \
             if the change is intentional, delete the file to re-record"
        ),
        Err(_) => {
            assert!(
                std::env::var_os("CI").is_none(),
                "no fig8 markdown golden at {path}: record it locally \
                 (run this test once and commit the file) before \
                 relying on CI"
            );
            std::fs::write(path, &md).expect("record fig8 md golden");
            eprintln!("recorded new fig8 markdown golden at {path}; \
                       commit it");
        }
    }
}

/// The first table's rows in the parsed fig8 JSON carry typed values.
fn assert_json_rows_typed(parsed: &JsonValue) {
    let obj = match parsed {
        JsonValue::Obj(kvs) => kvs,
        other => panic!("expected object, got {other:?}"),
    };
    let sections = obj
        .iter()
        .find(|(k, _)| k == "sections")
        .map(|(_, v)| v)
        .expect("sections key");
    let first = match sections {
        JsonValue::Arr(xs) => &xs[0],
        other => panic!("expected array, got {other:?}"),
    };
    let table = match first {
        JsonValue::Obj(kvs) => kvs,
        other => panic!("expected object, got {other:?}"),
    };
    let rows = table
        .iter()
        .find(|(k, _)| k == "rows")
        .map(|(_, v)| v)
        .expect("rows key");
    let rows = match rows {
        JsonValue::Arr(xs) => xs,
        other => panic!("expected rows array, got {other:?}"),
    };
    assert_eq!(rows.len(), 48);
    for row in rows {
        let cells = match row {
            JsonValue::Arr(xs) => xs,
            other => panic!("expected row array, got {other:?}"),
        };
        assert_eq!(cells.len(), 8);
        // WL and algo are strings; counts and percentages numbers.
        assert!(matches!(cells[0], JsonValue::Str(_)));
        assert!(matches!(cells[1], JsonValue::Str(_)));
        assert!(matches!(cells[2], JsonValue::Num(_)));
        assert!(matches!(cells[3], JsonValue::Num(_)));
    }
}

#[test]
fn beyond_paper_scenarios_run_from_the_registry() {
    // Downscaled variants of the three new axes (the registry versions
    // run the full 7-edge, multi-seed grids — exercised via the CLI/CI
    // artifact job). Here: same builders, smaller grids.
    use ocularone::fleet::{Arrival, DroneChurn, Workload};
    use ocularone::policy::Policy;
    use ocularone::scenario::Scenario;
    use ocularone::time::secs;

    let short = || {
        Workload::emulation(2, false).with_duration(secs(40))
    };
    let sc = Scenario::new("mini-axes", "Mini beyond-paper axes")
        .workload(short().with_name("per"))
        .workload(short().with_arrival(Arrival::Poisson).with_name("poi"))
        .workload(
            short()
                .with_arrival(Arrival::Bursty {
                    on: secs(5),
                    off: secs(5),
                })
                .with_name("bur"),
        )
        .workload(
            short()
                .with_churn(DroneChurn {
                    drone: 1,
                    active_from: 0,
                    active_until: secs(20),
                })
                .with_name("chu"),
        )
        .policies(vec![Policy::dems()])
        .edges(2);
    let rep = sc.run(11).expect("mini scenario runs");
    let tables = rep.tables();
    assert_eq!(tables[0].rows.len(), 4);
    // tasks column: periodic > bursty (half duty) and periodic > churn.
    let tasks: Vec<f64> = tables[0]
        .rows
        .iter()
        .map(|r| match r[4].value {
            ocularone::report::Value::Int(v) => v as f64,
            ref other => panic!("tasks cell {other:?}"),
        })
        .collect();
    let (per, poi, bur, chu) = (tasks[0], tasks[1], tasks[2], tasks[3]);
    assert!(per > 0.0);
    assert!((bur / per - 0.5).abs() < 0.1, "bursty {bur} vs {per}");
    assert!(chu < per, "churn {chu} vs {per}");
    assert!((poi / per - 1.0).abs() < 0.35, "poisson {poi} vs {per}");
    // And the registry-level entries resolve (ids only; full runs are
    // the CI artifact's job).
    let ids: Vec<&str> =
        scenario::registry().iter().map(|e| e.id).collect();
    for id in ["poisson", "churn", "hetero-edges"] {
        assert!(ids.contains(&id), "{id} registered");
    }
}
