//! Integration tests asserting the paper's qualitative claims hold on the
//! reproduced system (DESIGN.md §5 — "expected reproduction shape").
//!
//! These run full 300 s simulated experiments, so they exercise admission,
//! migration, stealing, adaptation, the QoE monitor, both executors and
//! the network models together.

use ocularone::cloud::CloudBackend;
use ocularone::cluster::{Cluster, EDGE_SEED_PHI};
use ocularone::exec::CloudExecModel;
use ocularone::fleet::Workload;
use ocularone::model::{DnnKind, GemsWorkload, Resource};
use ocularone::net::{mobility_trace, LognormalWan, TraceBandwidth,
                     TrapeziumLatency};
use ocularone::platform::Platform;
use ocularone::policy::Policy;
use ocularone::sched::FlagBranchScheduler;
use ocularone::time::secs;
use ocularone::{sim, simulate};

fn run(policy: Policy, wl: &Workload, seed: u64)
       -> ocularone::metrics::Metrics {
    simulate(policy, wl, seed)
}

#[test]
fn task_accounting_closes() {
    // Every generated task ends in exactly one bucket.
    for policy in Policy::fig8_lineup() {
        let wl = Workload::emulation(3, true);
        let m = run(policy.clone(), &wl, 11);
        for (kind, s) in &m.per_model {
            assert_eq!(
                s.generated,
                s.executed() + s.dropped(),
                "{:?} accounting leak under {}",
                kind,
                policy.kind.name()
            );
        }
        assert_eq!(m.generated(), wl.total_tasks());
    }
}

#[test]
fn determinism_same_seed_same_metrics() {
    let wl = Workload::emulation(3, true);
    let a = run(Policy::dems(), &wl, 99);
    let b = run(Policy::dems(), &wl, 99);
    assert_eq!(a.completed(), b.completed());
    assert_eq!(a.qos_utility(), b.qos_utility());
    assert_eq!(a.stolen(), b.stolen());
}

#[test]
fn determinism_same_seed_bit_identical_metrics() {
    // Stronger than the spot checks above: the FULL metrics struct (every
    // counter, utility sum, exec-time sample) must be bit-identical across
    // two runs with the same seed, for a simple and a stateful policy.
    for policy in [Policy::edf_ec(), Policy::dems_a()] {
        let wl = Workload::emulation(3, true);
        let a = run(policy.clone(), &wl, 123);
        let b = run(policy.clone(), &wl, 123);
        assert_eq!(a, b, "non-determinism under {}", policy.kind.name());
    }
}

#[test]
fn dispatch_parity_flag_branch_vs_boxed_trait() {
    // The redesign's core claim: routing decisions through
    // `Policy::build() -> Box<dyn Scheduler>` produces bit-identical
    // metrics to the statically dispatched flag-branch reference, for
    // every fig8 policy plus the stateful DEM/DEMS-A lineage.
    let wl = Workload::emulation(3, true);
    let mut policies = Policy::fig8_lineup();
    policies.push(Policy::dem());
    policies.push(Policy::dems_a());
    for policy in policies {
        let seed = 77;
        let boxed =
            Platform::new(policy.clone(), wl.models.clone(),
                          default_wan(), seed);
        let a = sim::run(boxed, &wl, seed);
        let flat = Platform::with_scheduler(
            FlagBranchScheduler::new(),
            policy.clone(),
            wl.models.clone(),
            default_wan(),
            seed,
        );
        let b = sim::run(flat, &wl, seed);
        assert_eq!(a, b, "dispatch divergence under {}",
                   policy.kind.name());
    }
    // And the GEMS family on its own (Table 2) workload.
    let wl = Workload::gems(GemsWorkload::Wl1, 0.9);
    let policy = Policy::gems(false);
    let mut boxed = Platform::new(policy.clone(), wl.models.clone(),
                                  default_wan(), 5);
    boxed.edge_exec = wl.edge_exec.clone();
    let a = sim::run(boxed, &wl, 5);
    let mut flat = Platform::with_scheduler(
        FlagBranchScheduler::new(),
        policy.clone(),
        wl.models.clone(),
        default_wan(),
        5,
    );
    flat.edge_exec = wl.edge_exec.clone();
    let b = sim::run(flat, &wl, 5);
    assert_eq!(a, b, "dispatch divergence under GEMS");
}

fn default_wan() -> Box<dyn CloudBackend> {
    CloudExecModel::new(Box::new(LognormalWan::default())).into()
}

#[test]
fn cluster_engine_matches_independent_edge_runs() {
    // The multi-edge Cluster drives all platforms from ONE event queue;
    // per-edge results must still be bit-identical to the pre-cluster
    // independent single-edge runs with the canonical seed derivation —
    // which is what keeps fig8/fig10/fig13 outputs unchanged.
    let wl = Workload::emulation(3, false);
    let seed = 13;
    for policy in [Policy::dems(), Policy::edf_ec(), Policy::gems(false)] {
        let cm =
            Cluster::emulation(&policy, &wl, seed, 4, &default_wan).run();
        assert_eq!(cm.edges(), 4);
        for e in 0..4 {
            let s = seed ^ ((e as u64 + 1) * EDGE_SEED_PHI);
            let mut p = Platform::new(policy.clone(), wl.models.clone(),
                                      default_wan(), s);
            p.edge_exec = wl.edge_exec.clone();
            let solo = sim::run(p, &wl, s);
            assert_eq!(cm.per_edge[e], solo,
                       "cluster/solo divergence on edge {e} under {}",
                       policy.kind.name());
        }
    }
}

#[test]
fn simulate_cluster_single_edge_matches_simulate() {
    let wl = Workload::emulation(3, true);
    let solo = simulate(Policy::dems(), &wl, 42);
    let mut cm =
        ocularone::simulate_cluster(Policy::dems(), &wl, 42, 1);
    assert_eq!(cm.per_edge.pop().unwrap(), solo);
}

/// Fig-8 lineup golden summaries. On first local run (no golden file) the
/// test records `tests/golden_fig8.txt` — commit the recorded file;
/// afterwards any drift in the summary numbers — completion counts or QoS
/// utility under a fixed seed — fails. Regenerate deliberately by deleting
/// the file. Under `CI=...` a missing golden is a hard failure, so the
/// check can never pass vacuously on a fresh checkout.
#[test]
fn fig8_lineup_summaries_match_golden() {
    let wl = Workload::emulation(3, true);
    let mut lines = String::new();
    for policy in Policy::fig8_lineup() {
        let m = simulate(policy.clone(), &wl, 42);
        lines.push_str(&format!(
            "{}|{}|{}|{:.3}|{:.3}\n",
            policy.kind.name(),
            m.completed(),
            m.generated(),
            m.qos_utility(),
            m.completion_rate(),
        ));
    }
    let path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_fig8.txt");
    match std::fs::read_to_string(path) {
        Ok(golden) => assert_eq!(
            lines, golden,
            "fig8 summary numbers drifted from the recorded golden \
             ({path}); if the change is intentional, delete the file to \
             re-record"
        ),
        Err(_) => {
            assert!(
                std::env::var_os("CI").is_none(),
                "no fig8 golden at {path}: record it locally (run this \
                 test once and commit the file) before relying on CI"
            );
            std::fs::write(path, &lines).expect("record fig8 golden");
            eprintln!("recorded new fig8 golden at {path}; commit it");
        }
    }
}

#[test]
fn cld_drops_all_bp_tasks() {
    // §8.3: BP has negative cloud utility, so CLD never executes it.
    let wl = Workload::emulation(3, false);
    let m = run(Policy::cloud_only(), &wl, 3);
    let bp = m.stats(DnnKind::Bp);
    assert_eq!(bp.completed(), 0);
    assert_eq!(bp.dropped_negative, bp.generated);
    // ⇒ passive CLD completion caps at ~75%.
    assert!(m.completion_rate() < 0.78, "{}", m.completion_rate());
    assert!(m.completion_rate() > 0.60, "{}", m.completion_rate());
}

#[test]
fn edge_only_completion_collapses_with_load() {
    // §8.3: EDF ≈ 85% at 2D-P degrading steeply to ≈ 31–39% at 4D-A.
    let light = run(Policy::edge_edf(), &Workload::emulation(2, false), 5);
    let heavy = run(Policy::edge_edf(), &Workload::emulation(4, true), 5);
    assert!(light.completion_rate() > 0.80, "{}", light.completion_rate());
    assert!(heavy.completion_rate() < 0.45, "{}", heavy.completion_rate());
}

#[test]
fn edge_only_utility_grows_with_workload() {
    // §8.3: EDF's utility trends upward as the workload intensifies.
    let u2 = run(Policy::edge_edf(), &Workload::emulation(2, false), 5)
        .qos_utility();
    let u4 = run(Policy::edge_edf(), &Workload::emulation(4, false), 5)
        .qos_utility();
    assert!(u4 > u2, "u2={u2} u4={u4}");
}

#[test]
fn dems_beats_baselines_on_utility_at_stress() {
    // §8.3: at 4D-A DEMS has the best utility (and >5% over E+C, >20% over
    // the SOTA baselines in our calibration).
    let wl = Workload::emulation(4, true);
    let dems = run(Policy::dems(), &wl, 21).qos_utility();
    for p in Policy::fig8_lineup() {
        if p.kind.name() == "DEMS" {
            continue;
        }
        let name = p.kind.name();
        let u = run(p, &wl, 21).qos_utility();
        assert!(
            dems > u,
            "DEMS {dems:.0} should beat {name} {u:.0} at 4D-A"
        );
    }
}

#[test]
fn dems_completion_band() {
    // §8.4: DEMS completes 77–88% at stress workloads and more when light.
    let heavy = run(Policy::dems(), &Workload::emulation(4, true), 31);
    assert!(
        heavy.completion_rate() > 0.75 && heavy.completion_rate() < 0.97,
        "{}",
        heavy.completion_rate()
    );
    let light = run(Policy::dems(), &Workload::emulation(2, false), 31);
    assert!(light.completion_rate() > heavy.completion_rate());
}

#[test]
fn dem_sends_more_tasks_to_cloud_than_ec() {
    // §8.4: "cloud-processed tasks increase markedly for DEM over E+C".
    let wl = Workload::emulation(3, true);
    let ec = run(Policy::edf_ec(), &wl, 17);
    let dem = run(Policy::dem(), &wl, 17);
    assert!(
        dem.completed_on(Resource::Cloud) as f64
            > 1.2 * ec.completed_on(Resource::Cloud) as f64,
        "dem {} vs ec {}",
        dem.completed_on(Resource::Cloud),
        ec.completed_on(Resource::Cloud)
    );
}

#[test]
fn stealing_targets_bp_and_raises_edge_utilization() {
    // §8.4: stolen tasks are (nearly) all BP on passive workloads, and
    // DEMS's edge utilization exceeds DEM's.
    let wl = Workload::emulation(4, false);
    let dem = run(Policy::dem(), &wl, 23);
    let dems = run(Policy::dems(), &wl, 23);
    assert!(dems.stolen() > 100, "stolen {}", dems.stolen());
    let bp_share = dems.stats(DnnKind::Bp).stolen as f64
        / dems.stolen() as f64;
    assert!(bp_share > 0.9, "BP share of steals {bp_share}");
    assert!(dems.edge_utilization() > dem.edge_utilization());
    assert_eq!(dem.stolen(), 0);
}

fn latency_shaped() -> CloudExecModel {
    CloudExecModel::new(Box::new(TrapeziumLatency::paper_default(
        LognormalWan::default(),
    )))
}

fn bandwidth_shaped() -> CloudExecModel {
    CloudExecModel::new(Box::new(TraceBandwidth {
        base: LognormalWan::default(),
        samples: mobility_trace(3, 300),
        period: secs(1),
    }))
}

#[test]
fn dems_a_beats_dems_under_latency_variability() {
    // §8.5: DEMS-A improves utility by ~15–27% with similar completions.
    let wl = Workload::emulation(4, false);
    let mut totals = (0.0, 0.0);
    for seed in [1u64, 2, 3] {
        let d = sim::run(
            Platform::new(Policy::dems(), wl.models.clone(),
                          latency_shaped(), seed),
            &wl,
            seed,
        );
        let a = sim::run(
            Platform::new(Policy::dems_a(), wl.models.clone(),
                          latency_shaped(), seed),
            &wl,
            seed,
        );
        totals.0 += d.qos_utility();
        totals.1 += a.qos_utility();
    }
    assert!(
        totals.1 > totals.0 * 1.05,
        "DEMS-A {:.0} vs DEMS {:.0}",
        totals.1,
        totals.0
    );
}

#[test]
fn dems_a_beats_dems_under_bandwidth_variability() {
    let wl = Workload::emulation(4, false);
    let mut totals = (0.0, 0.0);
    for seed in [4u64, 5, 6] {
        let d = sim::run(
            Platform::new(Policy::dems(), wl.models.clone(),
                          bandwidth_shaped(), seed),
            &wl,
            seed,
        );
        let a = sim::run(
            Platform::new(Policy::dems_a(), wl.models.clone(),
                          bandwidth_shaped(), seed),
            &wl,
            seed,
        );
        totals.0 += d.qos_utility();
        totals.1 += a.qos_utility();
    }
    assert!(
        totals.1 > totals.0,
        "DEMS-A {:.0} vs DEMS {:.0}",
        totals.1,
        totals.0
    );
}

#[test]
fn weak_scaling_holds_per_edge() {
    // §8.6: per-edge completion stays ≈ constant from 7 to 28 edges.
    let wl = Workload::emulation(3, false);
    let rates: Vec<f64> = (0..2)
        .map(|h| {
            let edges = 7 * (h + 1) * 2 - 7 * (h + 1); // 7 then 14 per pass
            let _ = edges;
            let n = 7 * (1 + h * 3); // 7 and 28
            let mut done = 0u64;
            let mut gen = 0u64;
            for e in 0..n {
                let m = run(Policy::dems(), &wl, 1000 + e as u64);
                done += m.completed();
                gen += m.generated();
            }
            done as f64 / gen as f64
        })
        .collect();
    let drift = (rates[0] - rates[1]).abs();
    assert!(drift < 0.03, "per-edge completion drift {drift}");
}

#[test]
fn gems_improves_qoe_over_dems() {
    // §8.7: GEMS gains QoE utility on WL1/WL2 for α ∈ {0.9, 1.0} and its
    // total utility is at least DEMS-comparable.
    for wlk in [GemsWorkload::Wl1, GemsWorkload::Wl2] {
        for alpha in [0.9, 1.0] {
            let wl = Workload::gems(wlk, alpha);
            let dems = run(Policy::dems(), &wl, 51);
            let gems = run(Policy::gems(false), &wl, 51);
            if alpha < 1.0 {
                // §8.7: +24–75% QoE utility at α = 0.9.
                assert!(
                    gems.qoe_utility() > dems.qoe_utility() * 1.1,
                    "{:?} α={alpha}: GEMS QoE {} vs DEMS {}",
                    wlk,
                    gems.qoe_utility(),
                    dems.qoe_utility()
                );
            } else {
                // α = 1.0 is near-unachievable per window (a single missed
                // task voids it); the paper likewise reports GEMS "does not
                // accrue the full QoE utility due to the strict 1.0 rate".
                // QoE may tie near zero — total utility must not regress by
                // more than one window's benefit.
                assert!(
                    gems.qoe_utility() + 1500.0 >= dems.qoe_utility(),
                    "{:?}: GEMS QoE {} vs DEMS {}",
                    wlk,
                    gems.qoe_utility(),
                    dems.qoe_utility()
                );
                assert!(gems.gems_rescheduled() > 0);
            }
            assert!(
                gems.total_utility() >= dems.total_utility() * 0.97,
                "{:?} α={alpha}: total {} vs {}",
                wlk,
                gems.total_utility(),
                dems.total_utility()
            );
            assert!(
                gems.completed() >= dems.completed(),
                "{:?} α={alpha}: GEMS completes at least as many",
                wlk
            );
        }
    }
}

#[test]
fn gems_rescheduled_tasks_complete_on_cloud() {
    let wl = Workload::gems(GemsWorkload::Wl1, 1.0);
    let gems = run(Policy::gems(false), &wl, 53);
    assert!(
        gems.gems_rescheduled() > 0,
        "GEMS should reschedule under α=1.0"
    );
    // Rescheduled tasks are cloud completions by construction.
    assert!(gems.completed_on(Resource::Cloud) >= gems.gems_rescheduled());
}
