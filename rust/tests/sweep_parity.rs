//! Parallel-sweep parity: `--jobs N` must produce **byte-identical**
//! reports to `--jobs 1` for every experiment. The sweep engine
//! enumerates each grid into a flat job list, fans the cells out over the
//! work-stealing pool and re-assembles results in enumeration order
//! (`scenario.rs` / `pool.rs`) — these tests pin that the schedule never
//! leaks into the output, on a paper table (`t1`), the biggest paper grid
//! (`fig8`), and the heterogeneous beyond-paper scenario
//! (`hetero-edges`), plus a downscaled grid across worker counts.

use ocularone::scenario::run_scenario_jobs;

fn assert_parity(id: &str, seed: u64) {
    let seq = run_scenario_jobs(id, seed, 1).expect("sequential run");
    let par = run_scenario_jobs(id, seed, 8).expect("parallel run");
    assert_eq!(seq, par, "{id}: structured reports diverged");
    assert_eq!(seq.to_markdown(), par.to_markdown(),
               "{id}: markdown bytes diverged");
    assert_eq!(seq.to_json(), par.to_json(), "{id}: JSON bytes diverged");
}

#[test]
fn t1_parallel_parity() {
    assert_parity("t1", 42);
}

#[test]
fn fig8_parallel_parity() {
    assert_parity("fig8", 42);
}

#[test]
fn hetero_edges_parallel_parity() {
    assert_parity("hetero-edges", 42);
}

#[test]
fn cold_start_sweep_parallel_parity() {
    // FaaS backend state (warm pools, cold-start accounting) is strictly
    // per cell, so cold-start rates reproduce for any worker count.
    assert_parity("cold-start-sweep", 42);
}

#[test]
fn cost_frontier_parallel_parity() {
    // Cost accumulation (GB-seconds + per-request fees, summed as f64 in
    // event order inside each cell) must be byte-identical across
    // `--jobs` values — the dollars column is part of the JSON bytes.
    assert_parity("cost-frontier", 42);
}

#[test]
fn fed_steal_parallel_parity() {
    // Federation state (LAN model, steal RNG, shared uplink) is built
    // fresh per cluster cell, so cross-edge steal counts and transfer
    // charges reproduce for any worker count.
    assert_parity("fed-steal", 42);
}

#[test]
fn handover_churn_parallel_parity() {
    assert_parity("handover-churn", 42);
}

#[test]
fn shared_uplink_parallel_parity() {
    // The shared-uplink Mutex serializes within one cluster only; cells
    // share nothing, so the queue-delay columns are byte-identical
    // across `--jobs` values.
    assert_parity("shared-uplink", 42);
}

#[test]
fn node_crash_parallel_parity() {
    // Fault state (the FaultDriver, compiled fault events, re-home
    // bookkeeping) lives inside each cluster cell, so crash/recovery
    // outcomes and the relocation ledger reproduce for any worker
    // count.
    assert_parity("node-crash", 42);
}

#[test]
fn region_outage_parallel_parity() {
    assert_parity("region-outage", 42);
}

#[test]
fn partition_parallel_parity() {
    // Link-flap windows mutate the cell's own SharedUplink / DegradedLan
    // cells only; nothing is shared across pool jobs.
    assert_parity("partition", 42);
}

#[test]
fn split_pipeline_parallel_parity() {
    // Pipeline cells build their own cluster (drone tier, stage graphs,
    // handoff transfers) from the raw seed, so the cut sweep reproduces
    // for any worker count.
    assert_parity("split-pipeline", 42);
}

#[test]
fn partition_sweep_parallel_parity() {
    assert_parity("partition-sweep", 42);
}

#[test]
fn timeline_parallel_parity() {
    // The windowed-timeline scenario folds per-edge `Timeline`s built
    // inside each policy cell; the merge order is fixed by edge index,
    // so the rendered windows are byte-identical across `--jobs` values.
    assert_parity("timeline", 42);
}

#[test]
fn single_stage_pipeline_is_bit_identical_to_plain() {
    // The pipeline-off pin: wrapping a workload's first model in a
    // degenerate 1-stage graph (same kind, same deadline, no handoff
    // bytes, no drone tier) must leave the whole engine on the plain
    // path — identical RNG draws, identical metrics, bit for bit. This
    // is what keeps the existing goldens valid with the pipeline
    // subsystem compiled in.
    use ocularone::cloud::CloudBackend;
    use ocularone::cluster::Cluster;
    use ocularone::exec::CloudExecModel;
    use ocularone::fleet::Workload;
    use ocularone::net::LognormalWan;
    use ocularone::pipeline::{Stage, StageGraph};
    use ocularone::policy::Policy;

    fn wan() -> Box<dyn CloudBackend> {
        CloudExecModel::new(Box::new(LognormalWan::default())).into()
    }
    // One model, every tick — the plain emitter and the chain-root
    // emitter then draw identically from the arrival RNG.
    let mut base = Workload::emulation(3, true);
    base.models.truncate(1);
    base.model_every.truncate(1);
    assert_eq!(base.model_every[0], 1);
    let profile = base.models[0].clone();
    let graph = StageGraph::chain(
        "one",
        vec![Stage {
            kind: profile.kind,
            deadline_slack: 1.0,
            output_bytes: 0,
            drone_capable: false,
        }],
        profile.deadline,
    );
    for policy in [Policy::dems(), Policy::dems_a(), Policy::gems(false)]
    {
        let plain = Cluster::emulation(&policy, &base, 42, 3, &wan).run();
        let piped = Cluster::emulation(
            &policy,
            &base.clone().with_pipeline(graph.clone()),
            42,
            3,
            &wan,
        )
        .run();
        assert_eq!(plain, piped,
                   "single-stage pipeline diverged under {}",
                   policy.kind.name());
    }
}

#[test]
fn federation_off_is_bit_identical_to_unfederated() {
    // The regression pin behind "federation off changes nothing": a
    // cluster federated with the all-off config produces bit-identical
    // metrics to an unfederated run — which is also why the golden
    // fig8 summaries and `experiment all` JSON stay byte-identical.
    use ocularone::cloud::CloudBackend;
    use ocularone::cluster::{Cluster, Federation};
    use ocularone::exec::CloudExecModel;
    use ocularone::fleet::Workload;
    use ocularone::net::LognormalWan;
    use ocularone::policy::Policy;

    fn wan() -> Box<dyn CloudBackend> {
        CloudExecModel::new(Box::new(LognormalWan::default())).into()
    }
    for policy in [Policy::dems(), Policy::dems_a(), Policy::gems(false)]
    {
        let wl = Workload::emulation(3, true);
        let plain =
            Cluster::emulation(&policy, &wl, 42, 3, &wan).run();
        let federated = Cluster::emulation(&policy, &wl, 42, 3, &wan)
            .federated(Federation::default())
            .run();
        assert_eq!(plain, federated,
                   "all-off federation diverged under {}",
                   policy.kind.name());
    }
}

#[test]
fn empty_fault_spec_is_bit_identical_to_fault_free() {
    // The chaos-off pin: attaching an empty `FaultSpec` must leave the
    // whole engine on the fault-free path — no driver, no compiled
    // events, identical RNG draws, bit for bit. This is what keeps the
    // existing goldens and parity pins valid with the fault subsystem
    // compiled in.
    use ocularone::cloud::CloudBackend;
    use ocularone::cluster::Cluster;
    use ocularone::exec::CloudExecModel;
    use ocularone::fault::FaultSpec;
    use ocularone::fleet::Workload;
    use ocularone::net::LognormalWan;
    use ocularone::policy::Policy;

    fn wan() -> Box<dyn CloudBackend> {
        CloudExecModel::new(Box::new(LognormalWan::default())).into()
    }
    for policy in [Policy::dems(), Policy::dems_a(), Policy::gems(false)]
    {
        let wl = Workload::emulation(3, true);
        let plain =
            Cluster::emulation(&policy, &wl, 42, 3, &wan).run();
        let faulted = Cluster::emulation(&policy, &wl, 42, 3, &wan)
            .with_faults(FaultSpec::default())
            .run();
        assert_eq!(plain, faulted,
                   "empty fault spec diverged under {}",
                   policy.kind.name());
    }
}

#[test]
fn scenario_grid_parity_across_worker_counts() {
    use ocularone::fleet::Workload;
    use ocularone::policy::Policy;
    use ocularone::scenario::Scenario;
    use ocularone::time::secs;

    // 2 workloads × 2 policies × 3 seeds = 12 cells; more workers than
    // cells in some configurations, fewer in others.
    let sc = Scenario::new("mini-par", "Mini parallel grid")
        .workload(Workload::emulation(2, false).with_duration(secs(30)))
        .workload(Workload::emulation(2, true).with_duration(secs(30)))
        .policies(vec![Policy::edf_ec(), Policy::dems()])
        .edges(2)
        .seeds(3);
    let seq = sc.run_jobs(7, 1).expect("sequential grid");
    for jobs in [2, 4, 16, 0] {
        let par = sc.run_jobs(7, jobs).expect("parallel grid");
        assert_eq!(seq, par, "jobs={jobs} diverged from sequential");
    }
}
