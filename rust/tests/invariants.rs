//! Dependency-free seeded property-test harness: ~50 randomized
//! scenarios across arrival process × churn × cloud backend × federation
//! on/off × split-DNN pipelines × fault injection (random crash /
//! outage / link-flap schedules on ~30% of runs) × the resilience layer
//! (hedged cloud requests, circuit breakers, lite degradation), each
//! pinned to the DES conservation invariants — a crashed station may
//! lose or relocate work and a hedged task may race two cloud legs, but
//! every task still closes exactly once.
//!
//! Per run, the harness asserts:
//!
//! * **Conservation / zero in-flight at drain** — per model kind, folded
//!   across the cluster (cross-edge steals finalize at the thief, so the
//!   ledger closes cluster-wide): generated == executed + dropped over
//!   all `DropReason`s. Pipeline stage tasks are ordinary tasks of their
//!   stage's kind, so every spawned stage closes under the same ledger.
//! * **Chain causality** — a pipelined scenario never spawns more
//!   stage-1 successors than stage-0 completions (successors spawn only
//!   on upstream success; in-flight handoffs at the horizon may lower
//!   the count, never raise it).
//! * **QoS ≤ max attainable** — per-kind folded QoS utility never
//!   exceeds `generated × max(γᴱ, γᶜ, 0)`.
//! * **Monotone virtual time** — every edge's finalization log is
//!   non-decreasing in time and complete (one record per closed task);
//!   plus a direct property test on `EventQueue` under random
//!   interleaving.
//! * **Cluster fold == per-edge sum** — every `ClusterMetrics` aggregate
//!   equals the manual fold of its `per_edge` metrics.
//! * **Trace conservation** — folding the task-lifecycle trace of a
//!   federated + faulted + hedged run reproduces the `ClusterMetrics`
//!   ledger exactly, and every generated task finalizes exactly once.
//! * **Arena-backed bit-identity** — 25 all-axes scenarios each run
//!   twice from the same seed on one reused event queue: the time-wheel
//!   + task-arena core must reproduce identical `ClusterMetrics` and
//!   drain its task arena to zero both times.

use ocularone::cluster::{Cluster, ClusterMetrics, Federation, Handover};
use ocularone::fault::FaultSpec;
use ocularone::fleet::{Arrival, DroneChurn, Workload};
use ocularone::model::{DnnKind, ModelProfile};
use ocularone::pipeline::{Stage, StageGraph};
use ocularone::policy::{PipelineCut, Policy};
use ocularone::rng::Rng;
use ocularone::scenario::CloudSpec;
use ocularone::sim::{Event, EventQueue};
use ocularone::time::secs;

fn assert_invariants(cm: &ClusterMetrics, wls: &[Workload], label: &str) {
    // ---- cluster fold == sum of per-edge metrics --------------------
    let gen_sum: u64 = cm.per_edge.iter().map(|m| m.generated()).sum();
    assert_eq!(cm.generated(), gen_sum, "{label}: generated fold");
    let done_sum: u64 = cm.per_edge.iter().map(|m| m.completed()).sum();
    assert_eq!(cm.completed(), done_sum, "{label}: completed fold");
    let qos_sum: f64 =
        cm.per_edge.iter().map(|m| m.qos_utility()).sum();
    assert!(
        (cm.total_qos_utility() - qos_sum).abs() < 1e-9,
        "{label}: QoS fold {} vs {}",
        cm.total_qos_utility(),
        qos_sum
    );
    let util_sum: f64 =
        cm.per_edge.iter().map(|m| m.total_utility()).sum();
    assert!(
        (cm.total_utility() - util_sum).abs() < 1e-9,
        "{label}: total-utility fold"
    );

    // ---- per-kind conservation + QoS cap, folded across edges -------
    let mut kinds: Vec<DnnKind> = Vec::new();
    for m in &cm.per_edge {
        for (k, _) in &m.per_model {
            if !kinds.contains(k) {
                kinds.push(*k);
            }
        }
    }
    assert!(!kinds.is_empty(), "{label}: no models registered");
    for k in kinds {
        let mut gen = 0u64;
        let mut closed = 0u64;
        let mut util = 0.0f64;
        for m in &cm.per_edge {
            if let Some((_, s)) =
                m.per_model.iter().find(|(kk, _)| *kk == k)
            {
                gen += s.generated;
                closed += s.executed() + s.dropped();
                util += s.utility();
            }
        }
        assert_eq!(
            gen, closed,
            "{label}: {k:?} conservation leak (in-flight at drain)"
        );
        let prof = wls
            .iter()
            .flat_map(|w| w.models.iter())
            .find(|m| m.kind == k)
            .expect("profile for registered kind");
        let cap = gen as f64
            * prof.util_edge().max(prof.util_cloud()).max(0.0);
        assert!(
            util <= cap + 1e-6,
            "{label}: {k:?} QoS {util} exceeds attainable {cap}"
        );
    }

    // ---- monotone virtual time + complete finalization log ----------
    for (e, m) in cm.per_edge.iter().enumerate() {
        let mut last = 0;
        for c in &m.completions {
            assert!(
                c.at >= last,
                "{label}: edge {e} virtual time went backwards \
                 ({} < {last})",
                c.at
            );
            last = c.at;
        }
        let closed: u64 = m
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(
            m.completions.len() as u64,
            closed,
            "{label}: edge {e} finalization log incomplete"
        );
    }
}

/// Two-stage split-DNN chain over the first two kinds of a mix: a
/// drone-capable early stage handing 24 kB to the final stage, on a 2 s
/// end-to-end deadline split 30/70.
fn two_stage_graph(models: &[ModelProfile]) -> StageGraph {
    StageGraph::chain(
        "inv-chain",
        vec![
            Stage {
                kind: models[0].kind,
                deadline_slack: 0.3,
                output_bytes: 24_000,
                drone_capable: true,
            },
            Stage {
                kind: models[1].kind,
                deadline_slack: 0.7,
                output_bytes: 0,
                drone_capable: false,
            },
        ],
        secs(2),
    )
}

/// Randomized scenario sweep: ~50 sampled points of the
/// arrival × churn × cloud × federation × pipeline grid, every one
/// asserted against the invariants above. Fully seeded — failures
/// reproduce.
#[test]
fn randomized_scenarios_preserve_conservation_invariants() {
    let policies = [
        Policy::dems(),
        Policy::dems_a(),
        Policy::edf_ec(),
        Policy::sjf_ec(),
        Policy::cloud_only(),
        Policy::edge_edf(),
    ];
    let mut rng = Rng::new(0xC0FF_EE00);
    for iter in 0..50 {
        let n_edges = 1 + rng.below(3);
        let mut policy = policies[rng.below(policies.len())].clone();
        let duration = secs(15 + rng.below(16) as u64);
        // ~30% of scenarios swap the plain fan-out for a 2-stage
        // split-DNN chain. All pipelined edges share one mix (and so one
        // stage-kind pair), keeping the chain-causality fold well-typed
        // cluster-wide; half the pipelined runs pin a random fixed cut.
        let pipelined = rng.chance(0.3);
        let shared_active = rng.chance(0.5);
        if pipelined && rng.chance(0.5) {
            let drone = rng.below(3);
            let cloud_start = drone + rng.below(3 - drone);
            policy = policy
                .with_pipeline_cut(PipelineCut::Fixed { drone, cloud_start });
        }
        let mut wls: Vec<Workload> = Vec::new();
        for _ in 0..n_edges {
            let drones = 1 + rng.below(3) as u32;
            let active =
                if pipelined { shared_active } else { rng.chance(0.5) };
            let mut wl = Workload::emulation(drones, active)
                .with_duration(duration);
            if pipelined {
                wl = wl.with_pipeline(two_stage_graph(&wl.models));
            }
            match rng.below(3) {
                0 => {}
                1 => wl = wl.with_arrival(Arrival::Poisson),
                _ => {
                    wl = wl.with_arrival(Arrival::Bursty {
                        on: secs(1 + rng.below(4) as u64),
                        off: secs(1 + rng.below(6) as u64),
                    })
                }
            }
            if rng.chance(0.4) {
                // Window start stays below the shortest duration (15 s)
                // so even a 1-drone, 1-edge scenario generates tasks.
                let from = rng.below(10) as u64;
                let until = from + 1 + rng.below(15) as u64;
                wl = wl.with_churn(DroneChurn {
                    drone: rng.below(drones as usize) as u32,
                    active_from: secs(from),
                    active_until: secs(until),
                });
            }
            wls.push(wl);
        }
        let cloud = match rng.below(3) {
            0 => CloudSpec::NominalWan,
            1 => CloudSpec::TrapeziumLatency,
            _ => CloudSpec::faas(
                secs(rng.below(60) as u64),
                1 + rng.below(8),
            ),
        };
        // ~30% of scenarios draw a random fault schedule: 1–2 station
        // crashes (70% rebooting), maybe a region outage (a no-op
        // throttle source on non-multi-region clouds), maybe a link
        // flap, 50/50 lose-vs-requeue recovery. The invariants below
        // must hold regardless — crashed work is lost or relocated,
        // never leaked.
        let faults = if rng.chance(0.3) {
            Some(FaultSpec::random(&mut rng, n_edges, duration))
        } else {
            None
        };
        let seed = rng.next_u64();
        let mut platforms = Vec::with_capacity(n_edges);
        let mut aseeds = Vec::with_capacity(n_edges);
        for (e, wl) in wls.iter().enumerate() {
            let (mut p, s) =
                Cluster::edge_parts(&policy, wl, seed, e, cloud.build());
            p.metrics.record_completions = true;
            platforms.push(p);
            aseeds.push(s);
        }
        let mut cluster =
            Cluster::from_parts_hetero(platforms, wls.clone(), aseeds);
        if let Some(f) = &faults {
            cluster = cluster.with_faults(f.clone());
        }
        let total_drones: u32 = wls.iter().map(|w| w.drones).sum();
        let (cluster, fed_desc) = if n_edges >= 2 {
            match rng.below(4) {
                0 => (cluster, "off"),
                1 => (cluster.federated(Federation::stealing()), "steal"),
                2 => (
                    cluster.federated(
                        Federation::stealing().with_uplink(
                            (1 + rng.below(30)) as f64 * 1.0e6,
                        ),
                    ),
                    "steal+uplink",
                ),
                _ => (
                    cluster.federated(
                        Federation::default().with_handover(Handover {
                            at: secs(rng.below(25) as u64),
                            drone: rng.below(total_drones as usize)
                                as u32,
                            to_edge: rng.below(n_edges),
                        }),
                    ),
                    "handover",
                ),
            }
        } else {
            (cluster, "single-edge")
        };
        let fault_desc = match &faults {
            Some(f) => format!(
                "{}crash/{}outage/{}flap {:?}",
                f.crashes.len(),
                f.outages.len(),
                f.flaps.len(),
                f.recovery
            ),
            None => "off".to_string(),
        };
        let label = format!(
            "iter {iter} ({} edges, {}, fed={fed_desc}, \
             pipeline={pipelined}, faults={fault_desc}, seed {seed:#x})",
            n_edges,
            policy.kind.name(),
        );
        let cm = cluster.run();
        assert!(cm.generated() > 0, "{label}: degenerate scenario");
        assert_invariants(&cm, &wls, &label);
        if pipelined {
            // Chain causality: every stage-1 task was spawned by a
            // completed stage-0 task (folded cluster-wide — steals and
            // handovers move stages across edges, never mint them).
            let fold = |k: DnnKind| -> (u64, u64) {
                let mut gen = 0u64;
                let mut done = 0u64;
                for m in &cm.per_edge {
                    if let Some((_, s)) =
                        m.per_model.iter().find(|(kk, _)| *kk == k)
                    {
                        gen += s.generated;
                        done += s.completed();
                    }
                }
                (gen, done)
            };
            let (gen0, done0) = fold(wls[0].models[0].kind);
            let (gen1, _) = fold(wls[0].models[1].kind);
            assert!(gen0 > 0, "{label}: no chain roots emitted");
            assert!(
                gen1 <= done0,
                "{label}: {gen1} stage-1 tasks spawned from only \
                 {done0} stage-0 completions"
            );
        }
    }
}

/// Fault-axis sweep: 50 always-faulted randomized scenarios — every run
/// draws a random crash/outage/flap schedule (`FaultSpec::random`) on
/// top of a random workload × policy × cloud × federation point, and
/// the conservation ledger must still close cluster-wide: a crashed
/// station's work is executed, dropped as a node failure, or relocated
/// and closed at a live sibling — never silently lost.
#[test]
fn randomized_fault_scenarios_preserve_conservation_invariants() {
    let policies = [
        Policy::dems(),
        Policy::dems_a(),
        Policy::edf_ec(),
        Policy::cloud_only(),
    ];
    let mut rng = Rng::new(0xFA17_AE55);
    for iter in 0..50 {
        let n_edges = 1 + rng.below(3);
        let policy = policies[rng.below(policies.len())].clone();
        let duration = secs(15 + rng.below(16) as u64);
        let mut wls: Vec<Workload> = Vec::new();
        for _ in 0..n_edges {
            let drones = 1 + rng.below(3) as u32;
            let mut wl = Workload::emulation(drones, rng.chance(0.5))
                .with_duration(duration);
            if rng.chance(0.3) {
                wl = wl.with_arrival(Arrival::Poisson);
            }
            wls.push(wl);
        }
        let cloud = if rng.chance(0.5) {
            CloudSpec::NominalWan
        } else {
            CloudSpec::faas(secs(30), 4)
        };
        let faults = FaultSpec::random(&mut rng, n_edges, duration);
        let seed = rng.next_u64();
        let mut platforms = Vec::with_capacity(n_edges);
        let mut aseeds = Vec::with_capacity(n_edges);
        for (e, wl) in wls.iter().enumerate() {
            let (mut p, s) =
                Cluster::edge_parts(&policy, wl, seed, e, cloud.build());
            p.metrics.record_completions = true;
            platforms.push(p);
            aseeds.push(s);
        }
        let mut cluster =
            Cluster::from_parts_hetero(platforms, wls.clone(), aseeds)
                .with_faults(faults.clone());
        let federated = n_edges >= 2 && rng.chance(0.5);
        if federated {
            cluster = cluster.federated(Federation::stealing());
        }
        let label = format!(
            "fault iter {iter} ({} edges, {}, fed={federated}, \
             {}crash/{}outage/{}flap {:?}, seed {seed:#x})",
            n_edges,
            policy.kind.name(),
            faults.crashes.len(),
            faults.outages.len(),
            faults.flaps.len(),
            faults.recovery,
        );
        let cm = cluster.run();
        assert!(cm.generated() > 0, "{label}: degenerate scenario");
        assert!(cm.crashes() >= 1, "{label}: fault schedule never fired");
        assert_invariants(&cm, &wls, &label);
    }
}

/// Hedging-conservation property: with the resilience layer armed —
/// speculative cloud duplicates always on, circuit breakers and lite
/// degradation joining at random — every task still finalizes exactly
/// once. A hedged pair must collapse to one ledger entry (the winner
/// finalizes, the loser cancels silently), and random crash schedules
/// must neither double-close nor leak either leg of an in-flight pair.
#[test]
fn randomized_resilience_scenarios_finalize_exactly_once() {
    use ocularone::resilience::ResilienceSpec;
    use ocularone::time::ms;

    let policies = [
        Policy::dems_a(),
        Policy::edf_ec(),
        Policy::sjf_ec(),
        Policy::cloud_only(),
    ];
    let mut rng = Rng::new(0x4E51_713E);
    let mut launches = 0u64;
    let mut wins = 0u64;
    let mut cancels = 0u64;
    for iter in 0..50 {
        let n_edges = 1 + rng.below(3);
        // Hedging is always armed (it is the property under test, and an
        // aggressive delay + zero slack maximizes pair traffic); breaker
        // and degradation join at random so their interactions with the
        // hedge ledger are swept too.
        let spec = ResilienceSpec {
            hedge: true,
            hedge_delay: ms(50 + rng.below(400) as u64),
            hedge_slack: 0,
            breaker: rng.chance(0.5),
            degrade: rng.chance(0.5),
            degrade_queue_high: 3,
            degrade_queue_low: 1,
            ..ResilienceSpec::default()
        };
        let policy = policies[rng.below(policies.len())]
            .clone()
            .with_resilience(spec);
        let duration = secs(15 + rng.below(16) as u64);
        let mut wls: Vec<Workload> = Vec::new();
        for _ in 0..n_edges {
            let drones = 1 + rng.below(3) as u32;
            let mut wl = Workload::emulation(drones, rng.chance(0.5))
                .with_duration(duration);
            if rng.chance(0.3) {
                wl = wl.with_arrival(Arrival::Poisson);
            }
            wls.push(wl);
        }
        // Tight-concurrency FaaS accounts keep throttles and timeouts in
        // play, so cancelled, abandoned and promoted hedge legs all occur
        // across the sweep.
        let cloud = match rng.below(3) {
            0 => CloudSpec::NominalWan,
            1 => CloudSpec::faas(
                secs(1 + rng.below(30) as u64),
                1 + rng.below(6),
            ),
            _ => CloudSpec::MultiRegion {
                keep_alive: secs(30),
                concurrency: 1 + rng.below(4),
                extra_latency: ms(40),
            },
        };
        let faults = if rng.chance(0.3) {
            Some(FaultSpec::random(&mut rng, n_edges, duration))
        } else {
            None
        };
        let seed = rng.next_u64();
        let mut platforms = Vec::with_capacity(n_edges);
        let mut aseeds = Vec::with_capacity(n_edges);
        for (e, wl) in wls.iter().enumerate() {
            let (mut p, s) =
                Cluster::edge_parts(&policy, wl, seed, e, cloud.build());
            p.metrics.record_completions = true;
            platforms.push(p);
            aseeds.push(s);
        }
        let mut cluster =
            Cluster::from_parts_hetero(platforms, wls.clone(), aseeds);
        if let Some(f) = &faults {
            cluster = cluster.with_faults(f.clone());
        }
        if n_edges >= 2 && rng.chance(0.5) {
            cluster = cluster.federated(Federation::stealing());
        }
        let label = format!(
            "resilience iter {iter} ({} edges, {}, faults={}, \
             seed {seed:#x})",
            n_edges,
            policy.kind.name(),
            faults.is_some(),
        );
        let cm = cluster.run();
        assert!(cm.generated() > 0, "{label}: degenerate scenario");
        assert_invariants(&cm, &wls, &label);
        assert!(
            cm.hedge_wins() <= cm.hedge_launches(),
            "{label}: more hedge wins than launches"
        );
        assert!(
            cm.hedge_cancels() <= cm.hedge_launches(),
            "{label}: more hedge cancels than launches"
        );
        launches += cm.hedge_launches();
        wins += cm.hedge_wins();
        cancels += cm.hedge_cancels();
    }
    // The sweep must actually exercise the machinery it pins: pairs
    // raced, winners finalized, losers were cancelled.
    assert!(launches > 0, "no hedges launched across the sweep");
    assert!(wins > 0, "no hedge ever won across the sweep");
    assert!(cancels > 0, "no hedge loser was ever cancelled");
}

/// Trace-conservation property: the task-lifecycle trace is a complete,
/// exact mirror of the metrics ledger. Federated + always-faulted +
/// always-hedged clusters run with a buffering [`VecSink`]; folding the
/// captured events must reproduce every `ClusterMetrics` counter —
/// completions, misses, per-reason drops, QoS utility, hedge
/// fire/win/cancel, breaker trip/probe, crash/recover, steal
/// departures/arrivals, handovers, fault losses — and every generated
/// task must finalize exactly once (per-id generate/finalize balance),
/// even when it migrates edges or races a hedged duplicate.
#[test]
fn trace_fold_reproduces_cluster_metrics_exactly() {
    use ocularone::obs::{SharedSink, TraceKind, VecSink};
    use ocularone::resilience::ResilienceSpec;
    use ocularone::task::{DropReason, Fate};
    use ocularone::time::ms;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    let policies =
        [Policy::dems_a(), Policy::edf_ec(), Policy::cloud_only()];
    let mut rng = Rng::new(0x7AC3_F01D);
    let mut launches = 0u64;
    let mut steals = 0u64;
    for iter in 0..10 {
        let n_edges = 2 + rng.below(2);
        // Hedging always armed (aggressive delay, zero slack) and faults
        // always on, so the trace covers the richest lifecycle paths.
        let spec = ResilienceSpec {
            hedge: true,
            hedge_delay: ms(50 + rng.below(400) as u64),
            hedge_slack: 0,
            breaker: rng.chance(0.5),
            ..ResilienceSpec::default()
        };
        let policy = policies[rng.below(policies.len())]
            .clone()
            .with_resilience(spec);
        let duration = secs(15 + rng.below(11) as u64);
        let mut wls: Vec<Workload> = Vec::new();
        for _ in 0..n_edges {
            let drones = 1 + rng.below(3) as u32;
            let mut wl = Workload::emulation(drones, rng.chance(0.5))
                .with_duration(duration);
            if rng.chance(0.3) {
                wl = wl.with_arrival(Arrival::Poisson);
            }
            wls.push(wl);
        }
        let cloud = if rng.chance(0.5) {
            CloudSpec::NominalWan
        } else {
            CloudSpec::faas(secs(1 + rng.below(30) as u64), 1 + rng.below(6))
        };
        let faults = FaultSpec::random(&mut rng, n_edges, duration);
        let seed = rng.next_u64();
        let mut platforms = Vec::with_capacity(n_edges);
        let mut aseeds = Vec::with_capacity(n_edges);
        for (e, wl) in wls.iter().enumerate() {
            let (p, s) =
                Cluster::edge_parts(&policy, wl, seed, e, cloud.build());
            platforms.push(p);
            aseeds.push(s);
        }
        let mut fed = Federation::stealing();
        let total_drones: u32 = wls.iter().map(|w| w.drones).sum();
        if rng.chance(0.5) {
            fed = fed.with_handover(Handover {
                at: secs(rng.below(12) as u64),
                drone: rng.below(total_drones as usize) as u32,
                to_edge: rng.below(n_edges),
            });
        }
        let sink = Arc::new(Mutex::new(VecSink::default()));
        let shared: SharedSink = sink.clone();
        let cm = Cluster::from_parts_hetero(platforms, wls.clone(), aseeds)
            .with_faults(faults.clone())
            .federated(fed)
            .with_trace(shared)
            .run();
        let label = format!(
            "trace iter {iter} ({n_edges} edges, {}, seed {seed:#x})",
            policy.kind.name(),
        );
        assert!(cm.generated() > 0, "{label}: degenerate scenario");
        assert!(cm.crashes() >= 1, "{label}: fault schedule never fired");

        // ---- fold the captured trace --------------------------------
        let events =
            std::mem::take(&mut sink.lock().unwrap().events);
        // Task ids are per-platform counters, so an id may repeat across
        // edges; the generate/finalize *balance* per id must still close
        // at zero (a steal finalizes at the thief, a hedge pair exactly
        // once).
        let mut balance: HashMap<u64, i64> = HashMap::new();
        let mut generated = 0u64;
        let mut finalized = 0u64;
        let mut completed = 0u64;
        let mut missed = 0u64;
        let mut dropped = [0u64; 8];
        let mut utility = 0.0f64;
        let mut hedge_fire = 0u64;
        let mut hedge_win = 0u64;
        let mut hedge_cancel = 0u64;
        let mut breaker_trip = 0u64;
        let mut breaker_probe = 0u64;
        let mut crash = 0u64;
        let mut recover = 0u64;
        let mut steal_depart = 0u64;
        let mut fed_arrive = 0u64;
        let mut handover = 0u64;
        let mut fault_loss = 0u64;
        for ev in &events {
            match ev.kind {
                TraceKind::Generate { task, .. } => {
                    generated += 1;
                    *balance.entry(task).or_insert(0) += 1;
                }
                TraceKind::Finalize { task, fate, utility: u } => {
                    finalized += 1;
                    *balance.entry(task).or_insert(0) -= 1;
                    match fate {
                        Fate::Completed(_) => {
                            completed += 1;
                            utility += u;
                        }
                        Fate::Missed(_) => {
                            missed += 1;
                            utility += u;
                        }
                        Fate::Dropped(r) => {
                            let i = DropReason::ALL
                                .iter()
                                .position(|&x| x == r)
                                .expect("reason in ALL");
                            dropped[i] += 1;
                        }
                    }
                }
                TraceKind::HedgeFire { .. } => hedge_fire += 1,
                TraceKind::HedgeWin { .. } => hedge_win += 1,
                TraceKind::HedgeCancel { .. } => hedge_cancel += 1,
                TraceKind::BreakerTrip => breaker_trip += 1,
                TraceKind::BreakerProbe => breaker_probe += 1,
                TraceKind::Crash => crash += 1,
                TraceKind::Recover => recover += 1,
                TraceKind::StealDepart { .. } => steal_depart += 1,
                TraceKind::FedArrive { .. } => fed_arrive += 1,
                TraceKind::Handover { .. } => handover += 1,
                TraceKind::FaultLoss { .. } => fault_loss += 1,
                TraceKind::Admit { .. }
                | TraceKind::Enqueue { .. }
                | TraceKind::Dispatch { .. } => {}
            }
        }

        // ---- the fold must equal the metrics ledger -----------------
        assert_eq!(generated, cm.generated(), "{label}: generate events");
        assert_eq!(
            finalized,
            cm.generated(),
            "{label}: every generated task finalizes"
        );
        for (id, b) in &balance {
            assert_eq!(
                *b, 0i64,
                "{label}: task {id} generate/finalize imbalance"
            );
        }
        assert_eq!(completed, cm.completed(), "{label}: completions");
        let missed_metric: u64 = cm
            .per_edge
            .iter()
            .flat_map(|m| m.per_model.iter())
            .map(|(_, s)| s.missed_edge + s.missed_cloud + s.missed_drone)
            .sum();
        assert_eq!(missed, missed_metric, "{label}: misses");
        for (i, &r) in DropReason::ALL.iter().enumerate() {
            assert_eq!(
                dropped[i],
                cm.dropped_by(r),
                "{label}: {r:?} drops"
            );
        }
        let qos = cm.total_qos_utility();
        assert!(
            (utility - qos).abs() <= 1e-6 + 1e-9 * qos.abs(),
            "{label}: trace utility {utility} vs ledger {qos}"
        );
        assert_eq!(hedge_fire, cm.hedge_launches(), "{label}: hedge fires");
        assert_eq!(hedge_win, cm.hedge_wins(), "{label}: hedge wins");
        assert_eq!(
            hedge_cancel,
            cm.hedge_cancels(),
            "{label}: hedge cancels"
        );
        assert_eq!(
            breaker_trip,
            cm.breaker_trips(),
            "{label}: breaker trips"
        );
        assert_eq!(
            breaker_probe,
            cm.breaker_probes(),
            "{label}: breaker probes"
        );
        assert_eq!(crash, cm.crashes(), "{label}: crashes");
        assert_eq!(recover, cm.recoveries(), "{label}: recoveries");
        assert_eq!(
            steal_depart,
            cm.fed_offers(),
            "{label}: steal departures"
        );
        assert_eq!(fed_arrive, cm.fed_steals(), "{label}: steal arrivals");
        assert_eq!(handover, cm.handovers(), "{label}: handovers");
        assert_eq!(
            fault_loss,
            cm.dropped_by(DropReason::NodeFailure),
            "{label}: fault losses"
        );
        launches += cm.hedge_launches();
        steals += cm.fed_steals();
    }
    // The sweep must exercise the machinery whose trace it pins.
    assert!(launches > 0, "no hedges launched across the trace sweep");
    assert!(steals > 0, "no steals occurred across the trace sweep");
}

/// Arena-backed determinism sweep for the time-wheel event core: 25
/// random scenarios spanning every axis at once — federation on/off,
/// random fault schedules, the resilience layer (hedges, breakers,
/// degradation), split-DNN pipelines, and all four cloud-backend
/// families — each built twice from the same sampled point and run
/// back-to-back on ONE reused [`EventQueue`]. Every run must satisfy
/// the conservation invariants, drain the task arena to zero, and the
/// two same-seed runs must produce bit-identical [`ClusterMetrics`]: a
/// leaked arena slot, a stale wheel cursor, or a `clear()` that forgot
/// state would all surface here before reaching the goldens.
#[test]
fn arena_backed_scenarios_are_run_twice_bit_identical() {
    use ocularone::resilience::ResilienceSpec;
    use ocularone::time::ms;

    let policies = [
        Policy::dems(),
        Policy::dems_a(),
        Policy::edf_ec(),
        Policy::sjf_ec(),
        Policy::cloud_only(),
        Policy::edge_edf(),
    ];
    let mut rng = Rng::new(0x0A2E_4A10);
    let mut q = EventQueue::new();
    for iter in 0..25 {
        // ---- sample the whole scenario up front, then build twice ----
        let n_edges = 1 + rng.below(3);
        let mut policy = policies[rng.below(policies.len())].clone();
        let duration = secs(15 + rng.below(11) as u64);
        let pipelined = rng.chance(0.4);
        let shared_active = rng.chance(0.5);
        let resilient = rng.chance(0.5);
        if resilient {
            policy = policy.with_resilience(ResilienceSpec {
                hedge: true,
                hedge_delay: ms(50 + rng.below(400) as u64),
                hedge_slack: 0,
                breaker: rng.chance(0.5),
                degrade: rng.chance(0.5),
                degrade_queue_high: 3,
                degrade_queue_low: 1,
                ..ResilienceSpec::default()
            });
        }
        let mut wls: Vec<Workload> = Vec::new();
        for _ in 0..n_edges {
            let drones = 1 + rng.below(3) as u32;
            let active =
                if pipelined { shared_active } else { rng.chance(0.5) };
            let mut wl = Workload::emulation(drones, active)
                .with_duration(duration);
            if pipelined {
                wl = wl.with_pipeline(two_stage_graph(&wl.models));
            }
            if rng.chance(0.3) {
                wl = wl.with_arrival(Arrival::Poisson);
            }
            wls.push(wl);
        }
        let cloud = match rng.below(4) {
            0 => CloudSpec::NominalWan,
            1 => CloudSpec::TrapeziumLatency,
            2 => CloudSpec::faas(
                secs(1 + rng.below(30) as u64),
                1 + rng.below(6),
            ),
            _ => CloudSpec::MultiRegion {
                keep_alive: secs(30),
                concurrency: 1 + rng.below(4),
                extra_latency: ms(40),
            },
        };
        let faults = if rng.chance(0.4) {
            Some(FaultSpec::random(&mut rng, n_edges, duration))
        } else {
            None
        };
        let fed_mode = if n_edges >= 2 { rng.below(3) } else { 0 };
        let seed = rng.next_u64();
        let build = || {
            let mut platforms = Vec::with_capacity(n_edges);
            let mut aseeds = Vec::with_capacity(n_edges);
            for (e, wl) in wls.iter().enumerate() {
                let (mut p, s) =
                    Cluster::edge_parts(&policy, wl, seed, e, cloud.build());
                p.metrics.record_completions = true;
                platforms.push(p);
                aseeds.push(s);
            }
            let mut cluster =
                Cluster::from_parts_hetero(platforms, wls.clone(), aseeds);
            if let Some(f) = &faults {
                cluster = cluster.with_faults(f.clone());
            }
            match fed_mode {
                1 => cluster = cluster.federated(Federation::stealing()),
                2 => {
                    cluster = cluster.federated(
                        Federation::stealing().with_uplink(10.0e6),
                    )
                }
                _ => {}
            }
            cluster
        };
        let label = format!(
            "arena iter {iter} ({n_edges} edges, {}, \
             pipeline={pipelined}, resilience={resilient}, \
             fed={fed_mode}, faults={}, seed {seed:#x})",
            policy.kind.name(),
            faults.is_some(),
        );
        let cm1 = build().run_with(&mut q);
        assert_eq!(
            q.tasks_in_flight(),
            0,
            "{label}: task arena leaked a slot (run 1)"
        );
        let cm2 = build().run_with(&mut q);
        assert_eq!(
            q.tasks_in_flight(),
            0,
            "{label}: task arena leaked a slot (run 2)"
        );
        assert!(cm1.generated() > 0, "{label}: degenerate scenario");
        assert_invariants(&cm1, &wls, &label);
        assert_eq!(cm1, cm2, "{label}: same-seed runs diverged");
    }
}

/// Direct DES-primitive property: under random interleavings of pops
/// and future-only pushes, popped timestamps never go backwards.
#[test]
fn event_queue_time_is_monotone_under_random_interleaving() {
    let mut rng = Rng::new(42);
    for round in 0..50 {
        let mut q = EventQueue::new();
        for _ in 0..(1 + rng.below(20)) {
            q.push(rng.below(1_000) as u64, Event::EdgeDone);
        }
        let mut now = 0u64;
        let mut pops = 0usize;
        while let Some((t, _)) = q.pop() {
            assert!(
                t >= now,
                "round {round}: virtual time went backwards ({t} < {now})"
            );
            now = t;
            pops += 1;
            // Handlers only ever schedule into the future.
            if rng.chance(0.6) {
                q.push(now + rng.below(500) as u64, Event::CloudTrigger);
            }
            if pops > 10_000 {
                break; // safety valve; subcritical pushes end well before
            }
        }
    }
}

/// Scope stamps never perturb the (time, push order) contract, even for
/// interleaved multi-edge streams — the determinism backbone the
/// federation layer rides on.
#[test]
fn scoped_streams_interleave_deterministically() {
    let mut rng = Rng::new(7);
    for _ in 0..20 {
        let mut q = EventQueue::new();
        let mut expect: Vec<(u64, u32)> = Vec::new();
        for i in 0..40u32 {
            let at = rng.below(100) as u64;
            let scope = rng.below(4) as u32;
            q.set_scope(scope);
            q.push(at, Event::Segment { drone: i, tick: 0 });
            expect.push((at, scope));
        }
        // Stable sort by time models the FIFO-among-equals contract.
        expect.sort_by_key(|&(at, _)| at);
        let mut got = Vec::new();
        while let Some((t, s, _)) = q.pop_scoped() {
            got.push((t, s));
        }
        assert_eq!(got, expect);
    }
}
