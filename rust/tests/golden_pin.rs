//! Golden bit-identity pins for the arena-backed event core: the three
//! scenarios that lean hardest on the refactored paths — `fed-steal`
//! (cross-edge task handles through the steal/transfer path),
//! `node-crash` (fault relocation re-stashing tasks under a foreign
//! scope), and `split-pipeline` (drone/edge/cloud stage handoffs via
//! `StageArrive`/`DroneDone` slots) — rendered to markdown and compared
//! byte-for-byte against committed goldens.
//!
//! The time-wheel + arena refactor is required to be *bit-identical* to
//! the heap it replaced, so these files must never change for a pure
//! event-core change. They follow the repo's self-recording pattern
//! (see `report_api.rs::fig8_markdown_matches_pre_redesign_format`):
//! the first local run records the file; afterwards any drift fails.
//! Under `CI=...` a missing golden is a hard failure.

use ocularone::scenario::run_scenario;

/// Seed shared by all three pins (same fixed seed the report-layer
/// tests use, so a drift here cross-checks against their goldens).
const SEED: u64 = 42;

fn pin_markdown(id: &str, file: &str) {
    let rep = run_scenario(id, SEED)
        .unwrap_or_else(|e| panic!("{id} runs: {e:?}"));
    let md = rep.to_markdown();
    let path = format!(
        "{}/tests/{file}",
        env!("CARGO_MANIFEST_DIR")
    );
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            md, golden,
            "{id} markdown drifted from the recorded golden ({path}); \
             the event core must stay bit-identical — if the change is \
             an intentional semantic change elsewhere, delete the file \
             to re-record"
        ),
        Err(_) => {
            assert!(
                std::env::var_os("CI").is_none(),
                "no {id} markdown golden at {path}: record it locally \
                 (run this test once and commit the file) before \
                 relying on CI"
            );
            std::fs::write(&path, &md)
                .unwrap_or_else(|e| panic!("record {id} golden: {e}"));
            eprintln!("recorded new {id} markdown golden at {path}; \
                       commit it");
        }
    }
}

#[test]
fn fed_steal_markdown_matches_golden() {
    pin_markdown("fed-steal", "golden_pin_fed_steal_md.txt");
}

#[test]
fn node_crash_markdown_matches_golden() {
    pin_markdown("node-crash", "golden_pin_node_crash_md.txt");
}

#[test]
fn split_pipeline_markdown_matches_golden() {
    pin_markdown("split-pipeline", "golden_pin_split_pipeline_md.txt");
}
