//! Differential determinism harness for the event core: the time-wheel
//! [`EventQueue`] must be *stream-identical* to the comparison-based
//! reference [`HeapQueue`] it replaced — same `(at, scope, event)`
//! triple from every pop, including equal-timestamp push-order
//! tie-breaks, across randomized seeded push/pop/clear sequences and
//! the wheel's structural corners (bucket boundaries, overflow
//! promotion, window wraparound, mid-sequence clears).
//!
//! The engine-level counterpart — identical `ClusterMetrics` and report
//! bytes on the real simulator — lives in `tests/invariants.rs` and
//! `tests/golden_pin.rs`; this file isolates the queue itself, so an
//! ordering regression pinpoints the data structure rather than
//! surfacing as a drifted golden three layers up.

use ocularone::cluster::{Cluster, Federation};
use ocularone::fleet::Workload;
use ocularone::policy::Policy;
use ocularone::rng::Rng;
use ocularone::scenario::CloudSpec;
use ocularone::sim::{Event, EventQueue, HeapQueue, QUANTUM_US,
                     WHEEL_SLOTS};
use ocularone::time::secs;

/// A queue-shape-diverse event sampler (no task-carrying variants: those
/// need arena slots, and the slot allocation itself is pinned by the
/// engine-level tests).
fn sample_event(rng: &mut Rng, i: u64) -> Event {
    match rng.below(7) {
        0 => Event::Segment { drone: rng.below(8) as u32, tick: i },
        1 => Event::EdgeDone,
        2 => Event::CloudTrigger,
        3 => Event::CloudDone { key: rng.next_u64() % 1_000 },
        4 => Event::WindowClose { model_idx: rng.below(6) },
        5 => Event::Handover {
            drone: rng.below(8) as u32,
            to_edge: rng.below(4) as u32,
        },
        _ => Event::HedgeFire { key: rng.next_u64() % 1_000 },
    }
}

/// Push-time sampler spanning every wheel tier: same-tick, in-window,
/// far-future (overflow), and occasionally before the current virtual
/// time (a heap accepts any timestamp; the wheel must too).
fn sample_at(rng: &mut Rng, now: u64) -> u64 {
    match rng.below(10) {
        // Same quantum / same microsecond — tie-break territory.
        0 | 1 => now + rng.below(3) as u64,
        // Within a few buckets.
        2..=5 => now + rng.below(50_000) as u64,
        // Deep into the window.
        6 | 7 => now + rng.below((WHEEL_SLOTS / 2) * 1_000) as u64,
        // Beyond the window → overflow list.
        8 => now + QUANTUM_US * WHEEL_SLOTS as u64
            + rng.below(5_000_000) as u64,
        // Behind the clock (stale pushes must still order exactly).
        _ => rng.below((now + 1).min(100_000) as usize) as u64,
    }
}

fn assert_streams_match(seed: u64, ops: usize, clear_chance: f64) {
    let mut rng = Rng::new(seed);
    let mut heap = HeapQueue::new();
    let mut wheel = EventQueue::new();
    let mut now = 0u64;
    let mut clears = 0u32;
    for i in 0..ops as u64 {
        if clear_chance > 0.0 && rng.chance(clear_chance) {
            heap.clear();
            wheel.clear();
            now = 0;
            clears += 1;
            continue;
        }
        if rng.chance(0.6) {
            let at = sample_at(&mut rng, now);
            let scope = rng.below(4) as u32;
            let ev = sample_event(&mut rng, i);
            heap.set_scope(scope);
            wheel.set_scope(scope);
            heap.push(at, ev);
            wheel.push(at, ev);
        } else {
            // Alternate the two pop flavors; both must agree exactly.
            if rng.chance(0.5) {
                let a = heap.pop_scoped();
                let b = wheel.pop_scoped();
                assert_eq!(a, b, "seed {seed:#x} op {i}: scoped pop");
                if let Some((t, _, _)) = a {
                    now = t;
                }
            } else {
                let a = heap.pop();
                let b = wheel.pop();
                assert_eq!(a, b, "seed {seed:#x} op {i}: pop");
                if let Some((t, _)) = a {
                    now = t;
                }
            }
            assert_eq!(heap.len(), wheel.len(),
                       "seed {seed:#x} op {i}: len");
            assert_eq!(heap.is_empty(), wheel.is_empty());
        }
    }
    // Drain both to exhaustion: the tails must agree too.
    loop {
        let a = heap.pop_scoped();
        let b = wheel.pop_scoped();
        assert_eq!(a, b, "seed {seed:#x}: drain tail");
        if a.is_none() {
            break;
        }
    }
    if clear_chance > 0.0 {
        assert!(clears > 0, "seed {seed:#x}: clear never sampled");
    }
}

#[test]
fn randomized_streams_match_the_heap_reference() {
    // ≥1000 randomized operations per seed, several seeds, no clears —
    // pure ordering equivalence.
    for seed in [0xD1FF_0001u64, 0xD1FF_0002, 0xD1FF_0003, 0xD1FF_0004,
                 0xD1FF_0005] {
        assert_streams_match(seed, 2_000, 0.0);
    }
}

#[test]
fn randomized_streams_match_across_mid_sequence_clears() {
    // clear() resets the FIFO tie-break counter and the wheel position;
    // the post-clear stream must replay bit-identically to a fresh
    // queue on both implementations.
    for seed in [0xC1EA_0001u64, 0xC1EA_0002, 0xC1EA_0003] {
        assert_streams_match(seed, 2_000, 0.01);
    }
}

#[test]
fn equal_timestamp_bursts_preserve_push_order() {
    // Dense tie storm: many events on few distinct microseconds across
    // bucket boundaries — the pure FIFO-among-equals stress.
    let mut rng = Rng::new(0x7135_70B1);
    let mut heap = HeapQueue::new();
    let mut wheel = EventQueue::new();
    let instants = [0u64, 999, 1_000, 1_001, 2_000,
                    QUANTUM_US * WHEEL_SLOTS as u64 + 5];
    for i in 0..600u64 {
        let at = instants[rng.below(instants.len())];
        let scope = rng.below(3) as u32;
        let ev = Event::Segment { drone: scope, tick: i };
        heap.set_scope(scope);
        wheel.set_scope(scope);
        heap.push(at, ev);
        wheel.push(at, ev);
    }
    loop {
        let a = heap.pop_scoped();
        let b = wheel.pop_scoped();
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn overflow_promotion_under_interleaved_pops() {
    // March virtual time through many window re-bases while far-future
    // events are pending, popping as we go — the overflow promotion
    // path under realistic interleaving rather than a one-shot drain.
    let mut rng = Rng::new(0x0F10_3357);
    let mut heap = HeapQueue::new();
    let mut wheel = EventQueue::new();
    let span = QUANTUM_US * WHEEL_SLOTS as u64;
    // Sparse far-future schedule (fault/window-close shaped).
    for k in 1..=12u64 {
        let at = k * span + rng.below(1_000_000) as u64;
        heap.push(at, Event::CloudTrigger);
        wheel.push(at, Event::CloudTrigger);
    }
    let mut now = 0u64;
    for i in 0..3_000u64 {
        // Dense near-term chatter riding over the sparse schedule.
        let at = now + rng.below(40_000) as u64;
        let ev = Event::Segment { drone: 0, tick: i };
        heap.push(at, ev);
        wheel.push(at, ev);
        let a = heap.pop();
        let b = wheel.pop();
        assert_eq!(a, b, "op {i}");
        now = a.expect("queues non-empty").0;
    }
    loop {
        let a = heap.pop();
        let b = wheel.pop();
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

/// Satellite fix pin: `EventQueue::clear` + the thread-local reuse in
/// `Cluster::run` carry over to the wheel — two consecutive identical
/// cluster runs on ONE queue allocation produce identical metrics *and*
/// an identical allocation footprint (no per-run bucket/arena regrowth),
/// and the task arena fully drains.
#[test]
fn queue_reuse_keeps_allocation_footprint() {
    let build = || {
        let policy = Policy::dems();
        let wls: Vec<Workload> = (0..2)
            .map(|_| Workload::emulation(3, true).with_duration(secs(20)))
            .collect();
        let mut platforms = Vec::new();
        let mut aseeds = Vec::new();
        for (e, wl) in wls.iter().enumerate() {
            let (p, s) = Cluster::edge_parts(
                &policy, wl, 0xA110C, e, CloudSpec::NominalWan.build());
            platforms.push(p);
            aseeds.push(s);
        }
        Cluster::from_parts_hetero(platforms, wls, aseeds)
            .federated(Federation::stealing())
    };
    let mut q = EventQueue::new();
    let cm1 = build().run_with(&mut q);
    assert_eq!(q.tasks_in_flight(), 0, "task arena leaked a slot");
    let after_first = q.allocation_footprint();
    assert!(after_first > 0);
    let cm2 = build().run_with(&mut q);
    assert_eq!(q.tasks_in_flight(), 0, "task arena leaked a slot");
    assert_eq!(
        q.allocation_footprint(),
        after_first,
        "second identical run re-grew the queue's allocations"
    );
    // Reuse is also bit-identical (the clear() contract).
    assert_eq!(cm1, cm2, "queue reuse perturbed the simulation");
}
