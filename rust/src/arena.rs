//! Slot-reusing arena: index handles instead of owned values in motion.
//!
//! The hot paths of the engine used to move whole [`Task`](crate::task)
//! structs (and ~100-byte queue entries) through the event heap and the
//! priority rings. An [`Arena`] parks the value once and hands back a
//! `u32` slot index; everything downstream shuffles 4-byte handles. Slots
//! freed by [`Arena::remove`] are recycled LIFO, so a steady-state run
//! settles into a fixed allocation footprint — [`Arena::clear`] keeps the
//! backing capacity, which is what lets one event queue be reused across
//! cluster runs without re-growing (see
//! [`EventQueue::clear`](crate::sim::EventQueue::clear)).
//!
//! Deliberately minimal: no generation counters. The engine's handles are
//! single-owner — a slot is stashed by exactly one producer and taken by
//! exactly one consumer (the conservation invariants in
//! `tests/invariants.rs` pin that every task closes exactly once), so ABA
//! safety comes from the protocol, not the container. `remove` of a dead
//! slot panics loudly rather than aliasing.

/// A slab of `T` with `u32` handles and LIFO slot reuse.
#[derive(Debug)]
pub struct Arena<T> {
    slots: Vec<Option<T>>,
    free: Vec<u32>,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena { slots: Vec::new(), free: Vec::new() }
    }
}

impl<T> Arena<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Park a value; returns its slot handle.
    pub fn insert(&mut self, value: T) -> u32 {
        match self.free.pop() {
            Some(h) => {
                debug_assert!(self.slots[h as usize].is_none());
                self.slots[h as usize] = Some(value);
                h
            }
            None => {
                let h = self.slots.len() as u32;
                self.slots.push(Some(value));
                h
            }
        }
    }

    /// Take the value back, freeing the slot for reuse. Panics on a dead
    /// slot — a double-take is a protocol bug, never silent aliasing.
    pub fn remove(&mut self, handle: u32) -> T {
        let v = self.slots[handle as usize]
            .take()
            .expect("arena slot taken twice");
        self.free.push(handle);
        v
    }

    /// Borrow a live slot.
    pub fn get(&self, handle: u32) -> Option<&T> {
        self.slots.get(handle as usize).and_then(|s| s.as_ref())
    }

    /// Mutably borrow a live slot.
    pub fn get_mut(&mut self, handle: u32) -> Option<&mut T> {
        self.slots.get_mut(handle as usize).and_then(|s| s.as_mut())
    }

    /// Number of live values.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every value but keep both backing allocations, so a reused
    /// arena re-fills without touching the allocator.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.free.clear();
    }

    /// Reserved slot capacity (allocation-footprint accounting; see the
    /// queue-reuse pin in `sim.rs`).
    pub fn capacity(&self) -> usize {
        self.slots.capacity() + self.free.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_round_trips() {
        let mut a = Arena::new();
        let h1 = a.insert("one");
        let h2 = a.insert("two");
        assert_ne!(h1, h2);
        assert_eq!(a.len(), 2);
        assert_eq!(a.get(h1), Some(&"one"));
        assert_eq!(a.remove(h2), "two");
        assert_eq!(a.remove(h1), "one");
        assert!(a.is_empty());
        assert_eq!(a.get(h1), None);
    }

    #[test]
    fn freed_slots_are_reused_lifo() {
        let mut a = Arena::new();
        let h1 = a.insert(1u64);
        let h2 = a.insert(2);
        a.remove(h1);
        a.remove(h2);
        // LIFO reuse: the most recently freed slot comes back first.
        assert_eq!(a.insert(3), h2);
        assert_eq!(a.insert(4), h1);
        // No new slots were grown.
        assert_eq!(a.insert(5), 2);
    }

    #[test]
    #[should_panic(expected = "arena slot taken twice")]
    fn double_remove_panics() {
        let mut a = Arena::new();
        let h = a.insert(9u8);
        a.remove(h);
        a.remove(h);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut a = Arena::new();
        let handles: Vec<u32> = (0..64).map(|i| a.insert(i)).collect();
        for h in handles {
            a.remove(h);
        }
        let cap = a.capacity();
        assert!(cap >= 128, "64 slots + 64 free entries reserved");
        a.clear();
        assert!(a.is_empty());
        assert_eq!(a.capacity(), cap, "clear must not shrink");
        for i in 0..64 {
            a.insert(i);
        }
        assert_eq!(a.capacity(), cap, "refill within retained capacity");
    }
}
