//! Edge and cloud task queues (§3.3, §5).
//!
//! The paper implements these as custom priority queues over a doubly linked
//! list; here each queue is an [`Arena`] slab of entries plus a sorted ring
//! of `u32` handles (`VecDeque`: cache-friendly, O(log n) position search +
//! O(n) insert — queues hold at most a few dozen entries at the paper's
//! workloads — and **O(1) head pops**: `pop`/`pop_due` fire on every
//! executor/trigger event). The slab/handle split means an ordered insert
//! shifts 4-byte handles, not ~100-byte `EdgeEntry`/`CloudEntry` structs,
//! and a popped entry moves out of the slab exactly once — the same
//! zero-copy discipline as the event queue's task arena (see
//! docs/ARCHITECTURE.md "Event core" and docs/PERF.md).
//!
//! Ring positions are the public indices: `get(idx)`/`remove_at(idx)`
//! address the idx-th entry *in priority order*, exactly as the previous
//! entry-ring representation did, so DEM victim indices and steal indices
//! carry over unchanged.
//!
//! * [`EdgeQueue`] — priority-ordered pending tasks for the single-lane edge
//!   executor. The priority key is pluggable ([`EdgeOrder`]): EDF for
//!   DEMS/E+C, shortest-job-first for SJF/Dedas, utility-per-time for HPF.
//!   It exposes the *feasibility scan* that drives admission (§5.1) and the
//!   DEM migration decision (§5.2).
//! * [`CloudQueue`] — trigger-time ordered deferred tasks (§5.3): each entry
//!   is sent to the FaaS only when its trigger time arrives, giving the edge
//!   a window to steal it.

use std::collections::VecDeque;

use crate::arena::Arena;
use crate::model::DnnKind;
use crate::task::{Task, TaskId};
use crate::time::{Micros, MicrosDelta};

/// Priority regime for the edge queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EdgeOrder {
    /// Earliest absolute deadline first (t′ⱼ + δᵢ) — DEMS and E+C.
    #[default]
    Edf,
    /// Shortest expected edge execution first — SJF (E+C) and SOTA 2.
    Sjf,
    /// Highest utility per unit edge time first — HPF.
    Hpf,
    /// Plain FIFO (arrival order).
    Fifo,
}

/// One queued edge task with its cached scheduling attributes.
#[derive(Clone, Debug)]
pub struct EdgeEntry {
    pub task: Task,
    /// Absolute deadline t′ⱼ + δᵢ.
    pub abs_deadline: Micros,
    /// Expected execution duration on the edge (possibly adapted).
    pub t_edge: Micros,
    /// Priority key (lower = runs earlier); derived from `EdgeOrder`.
    pub key: u64,
    /// Monotonic tiebreaker preserving FIFO among equal keys.
    pub seq: u64,
    /// Set when GEMS moved the task here / marked it (§6).
    pub gems_rescheduled: bool,
}

/// Result of probing an insertion into the edge queue (§5.2).
#[derive(Debug)]
pub struct InsertProbe {
    /// Position the new task would occupy.
    pub pos: usize,
    /// Expected completion time of the new task if inserted.
    pub completion: Micros,
    /// Indices (into the current queue) of existing tasks that would miss
    /// their deadlines as a consequence of the insertion.
    pub victims: Vec<usize>,
}

#[derive(Default, Debug)]
pub struct EdgeQueue {
    slab: Arena<EdgeEntry>,
    /// Priority order, head first; each element is a slab handle.
    ring: VecDeque<u32>,
    seq: u64,
    order: EdgeOrder,
}

impl EdgeQueue {
    pub fn new(order: EdgeOrder) -> Self {
        EdgeQueue {
            slab: Arena::new(),
            ring: VecDeque::new(),
            seq: 0,
            order,
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    #[inline]
    fn entry(&self, handle: u32) -> &EdgeEntry {
        self.slab.get(handle).expect("edge-queue handle live")
    }

    pub fn iter(&self) -> impl Iterator<Item = &EdgeEntry> {
        self.ring.iter().map(|&h| self.entry(h))
    }

    /// Compute the priority key for a prospective entry.
    pub fn key_for(&self, abs_deadline: Micros, t_edge: Micros,
                   hpf_priority: f64) -> u64 {
        match self.order {
            EdgeOrder::Edf => abs_deadline,
            EdgeOrder::Sjf => t_edge,
            // Higher utility/time first → invert into an ascending key.
            EdgeOrder::Hpf => (1e12 / hpf_priority.max(1e-9)) as u64,
            EdgeOrder::Fifo => 0,
        }
    }

    fn position_for(&self, key: u64) -> usize {
        // Insert after all entries with key <= new key (FIFO among equals).
        self.ring.partition_point(|&h| self.entry(h).key <= key)
    }

    /// Probe the effect of inserting a task *without* mutating the queue.
    ///
    /// `busy_until` is when the edge executor frees up (now if idle). The
    /// expected completion of entry k is `busy_until + Σ t_edge` over all
    /// entries at positions ≤ k (with the new task occupying `pos`).
    pub fn probe_insert(&self, abs_deadline: Micros, t_edge: Micros,
                        hpf_priority: f64, busy_until: Micros) -> InsertProbe {
        let key = self.key_for(abs_deadline, t_edge, hpf_priority);
        let pos = self.position_for(key);
        let mut t = busy_until;
        for e in self.iter().take(pos) {
            t += e.t_edge;
        }
        t += t_edge;
        let completion = t;
        let mut victims = Vec::new();
        for (i, e) in self.iter().enumerate().skip(pos) {
            t += e.t_edge;
            if t > e.abs_deadline {
                victims.push(i);
            }
        }
        InsertProbe { pos, completion, victims }
    }

    /// Expected completion time of the queue's last task (for slack math).
    pub fn backlog_until(&self, busy_until: Micros) -> Micros {
        busy_until + self.iter().map(|e| e.t_edge).sum::<Micros>()
    }

    /// Would appending this task (per its priority) meet `abs_deadline`?
    pub fn feasible(&self, abs_deadline: Micros, t_edge: Micros,
                    hpf_priority: f64, busy_until: Micros) -> bool {
        self.probe_insert(abs_deadline, t_edge, hpf_priority, busy_until)
            .completion
            <= abs_deadline
    }

    /// Insert an entry at its priority position — the slab takes the
    /// entry once; only a 4-byte handle shifts in the ring.
    pub fn insert(&mut self, task: Task, abs_deadline: Micros, t_edge: Micros,
                  hpf_priority: f64) -> usize {
        let key = self.key_for(abs_deadline, t_edge, hpf_priority);
        let pos = self.position_for(key);
        self.seq += 1;
        let handle = self.slab.insert(EdgeEntry {
            task,
            abs_deadline,
            t_edge,
            key,
            seq: self.seq,
            gems_rescheduled: false,
        });
        self.ring.insert(pos, handle);
        pos
    }

    /// Pop the highest-priority entry — O(1) on the handle ring (this
    /// fires once per edge execution).
    pub fn pop(&mut self) -> Option<EdgeEntry> {
        self.ring.pop_front().map(|h| self.slab.remove(h))
    }

    /// Peek the head entry.
    pub fn peek(&self) -> Option<&EdgeEntry> {
        self.ring.front().map(|&h| self.entry(h))
    }

    /// Direct index access, in priority order (perf: DEM victim scoring
    /// is O(victims), not O(n·victims) — see EXPERIMENTS.md §Perf L3).
    #[inline]
    pub fn get(&self, idx: usize) -> Option<&EdgeEntry> {
        self.ring.get(idx).map(|&h| self.entry(h))
    }

    /// Remove an entry by index (used by DEM migration).
    pub fn remove_at(&mut self, idx: usize) -> EdgeEntry {
        let h = self.ring.remove(idx).expect("edge-queue index in range");
        self.slab.remove(h)
    }

    /// Remove an entry by task id (used by GEMS rescheduling).
    pub fn remove_task(&mut self, id: TaskId) -> Option<EdgeEntry> {
        let idx =
            self.ring.iter().position(|&h| self.entry(h).task.id == id)?;
        Some(self.remove_at(idx))
    }

    /// Snapshot of (index, task-id, model) for tasks of one model, head
    /// first — the GEMS edge-queue scan (§6.1, Alg. 1 lines 9–14).
    pub fn tasks_of_model(&self, model: DnnKind) -> Vec<(usize, TaskId)> {
        self.iter()
            .enumerate()
            .filter(|(_, e)| e.task.model == model)
            .map(|(i, e)| (i, e.task.id))
            .collect()
    }
}

/// One deferred cloud task (§5.3).
#[derive(Clone, Debug)]
pub struct CloudEntry {
    pub task: Task,
    pub abs_deadline: Micros,
    /// Expected end-to-end cloud duration at admission time (adaptive).
    pub t_cloud: Micros,
    /// Expected *edge* duration — needed for steal feasibility.
    pub t_edge: Micros,
    /// When the cloud executor must dispatch it (deadline − t̂ − margin),
    /// or, for negative-utility entries, the latest edge start (§5.3).
    pub trigger: Micros,
    /// γᶜ ≤ 0: kept only as a steal candidate; dropped at trigger.
    pub negative_utility: bool,
    /// Set when GEMS moved the task here (§6).
    pub gems_rescheduled: bool,
    /// Fixed-cut pipeline stage: the partition policy placed it on the
    /// cloud, so it is never a steal candidate (local or federated).
    pub pinned: bool,
}

/// Trigger-time priority queue for the cloud executor — the same
/// slab + sorted handle-ring layout as [`EdgeQueue`].
#[derive(Default, Debug)]
pub struct CloudQueue {
    slab: Arena<CloudEntry>,
    /// Trigger order ascending, head first; slab handles.
    ring: VecDeque<u32>,
}

impl CloudQueue {
    pub fn new() -> Self {
        CloudQueue { slab: Arena::new(), ring: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    #[inline]
    fn entry(&self, handle: u32) -> &CloudEntry {
        self.slab.get(handle).expect("cloud-queue handle live")
    }

    pub fn iter(&self) -> impl Iterator<Item = &CloudEntry> {
        self.ring.iter().map(|&h| self.entry(h))
    }

    pub fn insert(&mut self, e: CloudEntry) {
        let pos =
            self.ring.partition_point(|&h| {
                self.entry(h).trigger <= e.trigger
            });
        let handle = self.slab.insert(e);
        self.ring.insert(pos, handle);
    }

    /// Earliest trigger time, if any.
    pub fn next_trigger(&self) -> Option<Micros> {
        self.ring.front().map(|&h| self.entry(h).trigger)
    }

    /// Pop the head entry if its trigger time has arrived — O(1) on the
    /// handle ring (this fires once per trigger event *and* once more to
    /// detect "nothing due", so it is the hottest cloud-queue op).
    pub fn pop_due(&mut self, now: Micros) -> Option<CloudEntry> {
        if self
            .ring
            .front()
            .map(|&h| self.entry(h).trigger <= now)
            .unwrap_or(false)
        {
            self.ring.pop_front().map(|h| self.slab.remove(h))
        } else {
            None
        }
    }

    /// Work-stealing candidate selection (§5.3): among entries whose edge
    /// execution fits `slack` and completes before their deadline, pick the
    /// best by (negative-cloud-utility first, then steal-rank descending).
    /// Returns the index of the chosen entry.
    pub fn best_steal(&self, now: Micros, slack: MicrosDelta,
                      rank: impl Fn(&CloudEntry) -> f64) -> Option<usize> {
        if slack <= 0 {
            return None;
        }
        let mut best: Option<(usize, bool, f64)> = None;
        for (i, e) in self.iter().enumerate() {
            if e.pinned {
                continue; // fixed-cut pipeline stages stay on the cloud
            }
            if e.t_edge as i64 > slack {
                continue;
            }
            // Would miss its deadline even if stolen now. For
            // negative-utility entries this is also the steal-vs-drop
            // boundary: their trigger is clamped to ≥ deadline − t_edge
            // (§5.3), so past the trigger instant this check always
            // skips them and the just-in-time drop at the pending
            // trigger event wins (pinned by the trigger-boundary tests).
            if now + e.t_edge > e.abs_deadline {
                continue;
            }
            let r = rank(e);
            let cand = (i, e.negative_utility, r);
            best = Some(match best {
                None => cand,
                Some(b) => {
                    // Priority: negative-utility entries first, then rank.
                    let better = (cand.1 && !b.1)
                        || (cand.1 == b.1 && cand.2 > b.2);
                    if better {
                        cand
                    } else {
                        b
                    }
                }
            });
        }
        best.map(|(i, _, _)| i)
    }

    pub fn remove_at(&mut self, idx: usize) -> CloudEntry {
        let h = self.ring.remove(idx).expect("cloud-queue index in range");
        self.slab.remove(h)
    }
}

impl CloudEntry {
    /// Convert a stolen cloud entry into an edge-queue entry (§5.3). The
    /// priority key/seq are zeroed: the entry bypasses the queue and goes
    /// straight to the executor.
    pub fn into_edge_entry(self) -> EdgeEntry {
        EdgeEntry {
            abs_deadline: self.abs_deadline,
            t_edge: self.t_edge,
            key: 0,
            seq: 0,
            gems_rescheduled: self.gems_rescheduled,
            task: self.task,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DnnKind;
    use crate::task::VideoSegment;
    use crate::time::ms;

    fn task(id: TaskId, created: Micros) -> Task {
        Task {
            id,
            model: DnnKind::Hv,
            segment: VideoSegment {
                id,
                drone: 0,
                created_at: created,
                bytes: 38_000,
            },
            pipeline: None,
        }
    }

    #[test]
    fn edf_orders_by_deadline() {
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        q.insert(task(1, 0), ms(900), ms(100), 1.0);
        q.insert(task(2, 0), ms(500), ms(100), 1.0);
        q.insert(task(3, 0), ms(700), ms(100), 1.0);
        assert_eq!(q.pop().unwrap().task.id, 2);
        assert_eq!(q.pop().unwrap().task.id, 3);
        assert_eq!(q.pop().unwrap().task.id, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn sjf_orders_by_exec_time() {
        let mut q = EdgeQueue::new(EdgeOrder::Sjf);
        q.insert(task(1, 0), ms(900), ms(300), 1.0);
        q.insert(task(2, 0), ms(500), ms(100), 1.0);
        assert_eq!(q.pop().unwrap().task.id, 2);
    }

    #[test]
    fn hpf_orders_by_utility_per_time() {
        let mut q = EdgeQueue::new(EdgeOrder::Hpf);
        q.insert(task(1, 0), ms(900), ms(100), 0.5);
        q.insert(task(2, 0), ms(900), ms(100), 2.0);
        assert_eq!(q.pop().unwrap().task.id, 2);
    }

    #[test]
    fn fifo_among_equal_keys() {
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        q.insert(task(1, 0), ms(500), ms(100), 1.0);
        q.insert(task(2, 0), ms(500), ms(100), 1.0);
        assert_eq!(q.pop().unwrap().task.id, 1);
        assert_eq!(q.pop().unwrap().task.id, 2);
    }

    #[test]
    fn probe_detects_victims() {
        // Fig. 5 scenario 2: inserting an early-deadline task starves τ₃.
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        q.insert(task(1, 0), ms(300), ms(150), 1.0);
        q.insert(task(3, 0), ms(500), ms(200), 1.0); // completes at 350 now
        // New task: deadline 400, t=100 → slots between τ₁ and τ₃, pushing
        // τ₃'s completion to 450 < 500 (fine), then tighten:
        let p = q.probe_insert(ms(400), ms(100), 1.0, 0);
        assert_eq!(p.pos, 1);
        assert_eq!(p.completion, ms(250));
        assert!(p.victims.is_empty());
        // A heavier insert (t=200) pushes τ₃ to 550 > 500 → victim.
        let p = q.probe_insert(ms(400), ms(200), 1.0, 0);
        assert_eq!(p.victims, vec![1]);
    }

    #[test]
    fn probe_accounts_for_busy_executor() {
        let q = EdgeQueue::new(EdgeOrder::Edf);
        let p = q.probe_insert(ms(400), ms(100), 1.0, ms(350));
        assert_eq!(p.completion, ms(450));
        assert!(!q.feasible(ms(400), ms(100), 1.0, ms(350)));
        assert!(q.feasible(ms(400), ms(100), 1.0, ms(250)));
    }

    #[test]
    fn remove_task_by_id() {
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        q.insert(task(1, 0), ms(500), ms(100), 1.0);
        q.insert(task(2, 0), ms(600), ms(100), 1.0);
        assert!(q.remove_task(2).is_some());
        assert!(q.remove_task(2).is_none());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn tasks_of_model_orders_head_first() {
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        q.insert(task(1, 0), ms(500), ms(100), 1.0);
        q.insert(task(2, 0), ms(300), ms(100), 1.0);
        let ids: Vec<TaskId> =
            q.tasks_of_model(DnnKind::Hv).into_iter().map(|(_, id)| id).collect();
        assert_eq!(ids, vec![2, 1]);
    }

    #[test]
    fn slab_reuses_slots_across_churn() {
        // Heavy insert/pop churn must not grow the slab past the peak
        // population — freed handles recycle (the zero-alloc contract).
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        for round in 0..50u64 {
            for i in 0..4 {
                let id = round * 4 + i;
                q.insert(task(id, 0), ms(500 + id), ms(10), 1.0);
            }
            for _ in 0..4 {
                q.pop().unwrap();
            }
        }
        assert!(q.is_empty());
        // Peak population was 4, so at most a handful of slots exist.
        let probe = q.insert(task(999, 0), ms(100), ms(10), 1.0);
        assert_eq!(probe, 0);
        assert_eq!(q.pop().unwrap().task.id, 999);
    }

    fn centry(id: TaskId, trigger: Micros, t_edge: Micros,
              abs_deadline: Micros, neg: bool) -> CloudEntry {
        CloudEntry {
            task: task(id, 0),
            abs_deadline,
            t_cloud: ms(400),
            t_edge,
            trigger,
            negative_utility: neg,
            gems_rescheduled: false,
            pinned: false,
        }
    }

    #[test]
    fn cloud_queue_trigger_order() {
        let mut q = CloudQueue::new();
        q.insert(centry(1, ms(300), ms(100), ms(900), false));
        q.insert(centry(2, ms(100), ms(100), ms(900), false));
        assert_eq!(q.next_trigger(), Some(ms(100)));
        assert!(q.pop_due(ms(50)).is_none());
        assert_eq!(q.pop_due(ms(100)).unwrap().task.id, 2);
        assert_eq!(q.pop_due(ms(500)).unwrap().task.id, 1);
    }

    #[test]
    fn steal_prefers_negative_utility_then_rank() {
        // Fig. 6 instance 1: τ₅ (positive) and τ₆ (negative) both fit; the
        // negative-utility task is stolen.
        let mut q = CloudQueue::new();
        q.insert(centry(5, ms(500), ms(100), ms(900), false));
        q.insert(centry(6, ms(600), ms(100), ms(900), true));
        let idx = q.best_steal(0, ms(150) as i64, |_| 1.0).unwrap();
        assert_eq!(q.remove_at(idx).task.id, 6);
        // With only positive entries, highest rank wins.
        let mut q = CloudQueue::new();
        q.insert(centry(7, ms(500), ms(100), ms(900), false));
        q.insert(centry(8, ms(600), ms(100), ms(900), false));
        let idx = q
            .best_steal(0, ms(150) as i64, |e| if e.task.id == 8 { 2.0 } else { 1.0 })
            .unwrap();
        assert_eq!(q.remove_at(idx).task.id, 8);
    }

    #[test]
    fn steal_vs_drop_at_the_trigger_boundary() {
        // A negative-utility entry's trigger is its latest edge start
        // (deadline − t_edge, §5.3): stealing must be legal up to and at
        // exactly the trigger instant, and lost one microsecond later —
        // from there the just-in-time drop at the trigger event wins.
        let (t_edge, dl) = (ms(100), ms(900));
        let trigger = dl - t_edge;
        let mut q = CloudQueue::new();
        q.insert(centry(1, trigger, t_edge, dl, true));
        let slack = ms(500) as i64;
        assert_eq!(q.best_steal(trigger - 1, slack, |_| 1.0), Some(0));
        assert_eq!(q.best_steal(trigger, slack, |_| 1.0), Some(0),
                   "the boundary instant still steals");
        assert_eq!(q.best_steal(trigger + 1, slack, |_| 1.0), None,
                   "past the boundary the drop wins");
    }

    #[test]
    fn expired_negative_candidates_do_not_shadow_stealable_entries() {
        let mut q = CloudQueue::new();
        // A negative-utility entry past its latest edge start (awaiting
        // its trigger-time drop)...
        q.insert(centry(1, ms(100), ms(300), ms(350), true));
        // ...must not shadow a live positive-utility candidate.
        q.insert(centry(2, ms(700), ms(100), ms(900), false));
        let idx = q.best_steal(ms(400), ms(500) as i64, |_| 1.0).unwrap();
        assert_eq!(q.remove_at(idx).task.id, 2);
    }

    #[test]
    fn pinned_entries_are_never_stolen() {
        // A fixed-cut pipeline stage placed on the cloud is invisible to
        // the steal scan even when it fits and out-ranks everything.
        let mut q = CloudQueue::new();
        let mut pinned = centry(1, ms(500), ms(100), ms(900), true);
        pinned.pinned = true;
        q.insert(pinned);
        assert!(q.best_steal(0, ms(400) as i64, |_| 10.0).is_none());
        q.insert(centry(2, ms(600), ms(100), ms(900), false));
        let idx = q.best_steal(0, ms(400) as i64, |_| 1.0).unwrap();
        assert_eq!(q.remove_at(idx).task.id, 2);
    }

    #[test]
    fn steal_respects_slack_and_deadline() {
        let mut q = CloudQueue::new();
        q.insert(centry(1, ms(500), ms(200), ms(900), false));
        // Not enough slack for t_edge=200.
        assert!(q.best_steal(0, ms(150) as i64, |_| 1.0).is_none());
        // Enough slack but deadline already unreachable.
        q.insert(centry(2, ms(500), ms(100), ms(50), false));
        let idx = q.best_steal(ms(100), ms(250) as i64, |_| 1.0).unwrap();
        assert_eq!(q.remove_at(idx).task.id, 1);
    }

    #[test]
    fn middle_removal_keeps_ring_order() {
        // remove_at on a middle index must keep the surviving entries'
        // priority order intact (handles shift; slab slots recycle).
        let mut q = CloudQueue::new();
        q.insert(centry(1, ms(100), ms(10), ms(900), false));
        q.insert(centry(2, ms(200), ms(10), ms(900), false));
        q.insert(centry(3, ms(300), ms(10), ms(900), false));
        assert_eq!(q.remove_at(1).task.id, 2);
        // The freed slot is recycled by the next insert, but order is
        // still by trigger.
        q.insert(centry(4, ms(250), ms(10), ms(900), false));
        let ids: Vec<TaskId> = q.iter().map(|e| e.task.id).collect();
        assert_eq!(ids, vec![1, 4, 3]);
    }
}
