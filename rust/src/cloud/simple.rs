//! The default backend: the calibrated [`CloudExecModel`] sampler,
//! unchanged — one warm flag per model, coin-flip re-colds, no
//! concurrency ceiling, no billing. Every pre-subsystem experiment runs
//! through this adapter bit-identically (same RNG draw sequence).

use crate::cloud::{Attempt, CloudBackend, CloudStats, Invocation};
use crate::exec::CloudExecModel;
use crate::model::ModelProfile;
use crate::rng::Rng;
use crate::time::Micros;

/// [`CloudExecModel`] behind the [`CloudBackend`] trait.
pub struct SimpleBackend {
    model: CloudExecModel,
    stats: CloudStats,
}

impl SimpleBackend {
    pub fn new(model: CloudExecModel) -> Self {
        SimpleBackend { model, stats: CloudStats::default() }
    }
}

impl From<CloudExecModel> for Box<dyn CloudBackend> {
    fn from(model: CloudExecModel) -> Box<dyn CloudBackend> {
        Box::new(SimpleBackend::new(model))
    }
}

impl CloudBackend for SimpleBackend {
    fn name(&self) -> &'static str {
        "simple"
    }

    fn invoke(&mut self, profile: &ModelProfile, now: Micros, bytes: u64,
              concurrent: usize, rng: &mut Rng) -> Attempt {
        let (duration, timed_out) =
            self.model.sample(profile, now, bytes, concurrent, rng);
        self.stats.invocations += 1;
        Attempt::Run(Invocation {
            duration,
            timed_out,
            // The legacy sampler folds cold starts into the duration
            // internally; it does not expose which draws were cold.
            cold: false,
            cost: 0.0,
            token: 0,
        })
    }

    fn stats(&self) -> CloudStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::time::ms;

    /// The adapter draws exactly what the raw sampler draws: the same
    /// seed yields the same (duration, timeout) sequence.
    #[test]
    fn bit_identical_to_raw_sampler() {
        let mk = || {
            CloudExecModel::new(Box::new(ConstantNet {
                latency: ms(40),
                bandwidth: 10.0e6,
            }))
        };
        let mut raw = mk();
        let mut rng_a = Rng::new(9);
        let mut be = SimpleBackend::new(mk());
        let mut rng_b = Rng::new(9);
        let m = &table1()[2];
        for _ in 0..200 {
            let want = raw.sample(m, 0, 38_000, 1, &mut rng_a);
            match be.invoke(m, 0, 38_000, 1, &mut rng_b) {
                Attempt::Run(inv) => {
                    assert_eq!((inv.duration, inv.timed_out), want);
                    assert_eq!(inv.cost, 0.0);
                }
                Attempt::Throttle { .. } => {
                    panic!("simple backend never throttles")
                }
            }
        }
        assert_eq!(be.stats().invocations, 200);
        assert_eq!(be.stats().dollars, 0.0);
    }
}
