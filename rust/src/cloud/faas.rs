//! A faithful FaaS account model (AWS-Lambda-shaped, §3.2):
//! warm-container pools per model with keep-alive expiry, deterministic
//! cold starts on pool miss, a per-account concurrency ceiling with
//! throttle semantics, and per-invocation billing (GB-seconds plus a
//! per-request fee).
//!
//! One `FaasBackend` instance **is** one account. The platform core owns
//! one backend per edge station, so cluster scenarios model one account
//! per edge (N-edge cluster = N independent ceilings/pools/bills) —
//! which also keeps sweep cells shared-nothing and `--jobs`-parallel
//! byte-identical.
//!
//! Differences from the legacy [`CloudExecModel`] sampler it supersedes:
//!
//! * **Container lifecycle** — each completed invocation parks its
//!   container in the model's warm pool until `now + keep_alive`; an
//!   invocation is cold exactly when the pool holds no live container at
//!   invoke time (no `cold_prob` coin flip). Concurrency-driven pool
//!   growth falls out naturally: N overlapping invocations leave N warm
//!   containers behind.
//! * **Concurrency ceiling** — at most `concurrency` invocations may be
//!   in flight account-wide; excess attempts are throttled with a
//!   deterministic `retry_after` backoff instead of queueing silently.
//! * **Billing** — compute time (cold start included, network excluded,
//!   rounded up to `billing_quantum`) × memory × GB-second price, plus a
//!   flat per-request fee. Timed-out requests still bill: the function
//!   keeps running after the client hangs up.
//!
//! [`CloudExecModel`]: crate::exec::CloudExecModel

use std::collections::VecDeque;

use crate::cloud::{Attempt, CloudBackend, CloudStats, Invocation};
use crate::exec::{sample_cloud_compute, sample_cold_start,
                  shared_uplink_bytes, CLOUD_COLD_START_MS,
                  CLOUD_HOST_EDGES, CLOUD_NOMINAL_NET_MS, CLOUD_SIGMA,
                  CLOUD_TIMEOUT_MS};
use crate::model::{DnnKind, ModelProfile};
use crate::net::NetworkModel;
use crate::rng::Rng;
use crate::time::{ms_f, Micros};

/// Invocation token marking a client-abandoned (timed-out) request: the
/// function keeps running server-side, so [`FaasBackend::complete`] (which
/// fires at the client timeout) must NOT release the slot — the backend
/// drains it itself once the true duration elapses.
const TOKEN_ABANDONED: u32 = 1;

/// Declarative FaaS account parameters.
#[derive(Clone, Debug)]
pub struct FaasConfig {
    /// Idle warm containers survive this long after their last release.
    pub keep_alive: Micros,
    /// Per-account in-flight invocation ceiling (AWS default: 1000).
    pub concurrency: usize,
    /// Earliest-retry backoff handed to throttled callers.
    pub retry_after: Micros,
    /// Cold-start penalty; jittered ×[0.6, 1.4) per cold invocation.
    pub cold_start: Micros,
    /// Lognormal sigma of the FaaS compute time (Fig. 1b).
    pub sigma: f64,
    /// Nominal network overhead folded into the Table-1 t̂ values.
    pub nominal_net: Micros,
    /// HTTP client timeout (the platform abandons slower requests).
    pub timeout: Micros,
    /// Edge containers sharing this host's uplink (§8.1).
    pub host_edges: usize,
    /// Allocated function memory, in GB.
    pub memory_gb: f64,
    /// Dollars per GB-second of billed compute.
    pub gb_second_price: f64,
    /// Flat dollars per request.
    pub request_price: f64,
    /// Billed durations round up to this quantum (1 ms on Lambda).
    pub billing_quantum: Micros,
}

impl Default for FaasConfig {
    /// Lambda-shaped defaults over the `exec.rs` calibration: 5 min
    /// keep-alive, the 1000-slot account ceiling (unreachable under the
    /// default 16-thread edge pool — ceilings matter only when scenarios
    /// lower them), 1.5 GB functions at public list prices.
    fn default() -> Self {
        FaasConfig {
            keep_alive: ms_f(300_000.0),
            concurrency: 1000,
            retry_after: ms_f(200.0),
            // Calibration numbers come from the exec.rs shared consts so
            // the two samplers can never drift apart.
            cold_start: ms_f(CLOUD_COLD_START_MS),
            sigma: CLOUD_SIGMA,
            nominal_net: ms_f(CLOUD_NOMINAL_NET_MS),
            timeout: ms_f(CLOUD_TIMEOUT_MS),
            host_edges: CLOUD_HOST_EDGES,
            memory_gb: 1.5,
            gb_second_price: 0.000_016_666_7,
            request_price: 0.000_000_2,
            billing_quantum: ms_f(1.0),
        }
    }
}

impl FaasConfig {
    /// Dollars billed for one invocation running `billed` of compute.
    pub fn invocation_cost(&self, billed: Micros) -> (f64, f64) {
        let q = self.billing_quantum.max(1);
        let rounded = billed.div_ceil(q) * q;
        let gb_s = rounded as f64 / 1e6 * self.memory_gb;
        (gb_s, gb_s * self.gb_second_price + self.request_price)
    }
}

/// One FaaS account/region: per-model warm pools + concurrency ceiling +
/// cost meter, over a pluggable [`NetworkModel`].
pub struct FaasBackend {
    pub cfg: FaasConfig,
    net: Box<dyn NetworkModel>,
    /// Expiry timestamps of idle warm containers, per model. Not
    /// sorted — abandoned-request drains park out of release order, so
    /// eviction scans the whole (small) pool.
    pools: [VecDeque<Micros>; DnnKind::COUNT],
    /// Client-abandoned invocations still running server-side:
    /// `(model index, true end time)`. Each holds a concurrency slot
    /// until its true end, then parks its container warm.
    draining: Vec<(usize, Micros)>,
    in_flight: usize,
    stats: CloudStats,
}

impl FaasBackend {
    pub fn new(cfg: FaasConfig, net: Box<dyn NetworkModel>) -> Self {
        FaasBackend {
            cfg,
            net,
            pools: std::array::from_fn(|_| VecDeque::new()),
            draining: Vec::new(),
            in_flight: 0,
            stats: CloudStats::default(),
        }
    }

    /// Invocations currently holding a concurrency slot (abandoned
    /// requests included until their functions really finish).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Live (unexpired, idle) warm containers for `kind` at time `now`.
    pub fn warm_containers(&self, kind: DnnKind, now: Micros) -> usize {
        self.pools[kind.index()].iter().filter(|&&e| e > now).count()
    }

    /// Release abandoned invocations whose functions have finished by
    /// `now`: free the slot and park the container warm from its true
    /// end (not the client timeout).
    fn reap_abandoned(&mut self, now: Micros) {
        let keep_alive = self.cfg.keep_alive;
        let mut i = 0;
        while i < self.draining.len() {
            let (idx, end) = self.draining[i];
            if end <= now {
                self.draining.swap_remove(i);
                self.in_flight = self.in_flight.saturating_sub(1);
                self.pools[idx].push_back(end + keep_alive);
            } else {
                i += 1;
            }
        }
    }
}

impl CloudBackend for FaasBackend {
    fn name(&self) -> &'static str {
        "faas"
    }

    fn invoke(&mut self, profile: &ModelProfile, now: Micros, bytes: u64,
              concurrent: usize, rng: &mut Rng) -> Attempt {
        self.reap_abandoned(now);
        if self.in_flight >= self.cfg.concurrency {
            self.stats.throttles += 1;
            return Attempt::Throttle { retry_after: self.cfg.retry_after };
        }
        // Evict expired containers, then take any live one (pools are
        // not expiry-sorted; see the field docs).
        let pool = &mut self.pools[profile.kind.index()];
        pool.retain(|&expiry| expiry > now);
        let warm = pool.pop_front().is_some();
        // The exec.rs calibration helpers are the single home of the
        // sampling formulas (shared with the legacy CloudExecModel).
        let compute = sample_cloud_compute(profile, self.cfg.sigma,
                                           self.cfg.nominal_net, rng);
        let cold_penalty = if warm {
            0
        } else {
            sample_cold_start(self.cfg.cold_start, rng)
        };
        let payload =
            shared_uplink_bytes(bytes, concurrent, self.cfg.host_edges);
        let transfer = self.net.transfer_time(now, payload, rng);
        let d = compute + cold_penalty + transfer;
        let (duration, timed_out) = if d >= self.cfg.timeout {
            (self.cfg.timeout, true)
        } else {
            (d, false)
        };
        // Billing covers the function's own runtime (init included,
        // network excluded) — even when the client times out.
        let (gb_s, cost) = self.cfg.invocation_cost(compute + cold_penalty);
        self.in_flight += 1;
        self.stats.invocations += 1;
        self.stats.cold_starts += !warm as u64;
        self.stats.gb_seconds += gb_s;
        self.stats.dollars += cost;
        if timed_out {
            // The client hangs up at the timeout, but the function keeps
            // running: the slot stays held and the container parks warm
            // only at the TRUE end (reaped on later invokes/completes).
            self.draining.push((profile.kind.index(), now + d));
        }
        Attempt::Run(Invocation {
            duration,
            timed_out,
            cold: !warm,
            cost,
            token: if timed_out { TOKEN_ABANDONED } else { 0 },
        })
    }

    fn probe(&self, _now: Micros) -> bool {
        // Advisory, so it cannot reap abandoned invocations (`&self`):
        // a drained-but-unreaped slot may make this pessimistic, never
        // optimistic — which is the safe direction for hedging.
        self.in_flight < self.cfg.concurrency
    }

    fn complete(&mut self, kind: DnnKind, token: u32, now: Micros) {
        self.reap_abandoned(now);
        if token == TOKEN_ABANDONED {
            // Client-side timeout event: the server-side function still
            // runs; `draining` owns the slot release.
            return;
        }
        debug_assert!(self.in_flight > 0, "complete without invoke");
        self.in_flight = self.in_flight.saturating_sub(1);
        self.pools[kind.index()].push_back(now + self.cfg.keep_alive);
    }

    fn stats(&self) -> CloudStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::time::{ms, secs};

    /// Deterministic backend: sigma 0 (compute = calibrated median) over
    /// a constant network, so durations are exactly reproducible.
    fn backend(cfg: FaasConfig) -> FaasBackend {
        FaasBackend::new(cfg, Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 10.0e6,
        }))
    }

    fn det_cfg() -> FaasConfig {
        FaasConfig { sigma: 0.0, keep_alive: secs(5), ..FaasConfig::default() }
    }

    fn run(be: &mut FaasBackend, now: Micros, rng: &mut Rng) -> Invocation {
        let m = &table1()[0]; // HV
        match be.invoke(m, now, 38_000, 0, rng) {
            Attempt::Run(inv) => inv,
            Attempt::Throttle { .. } => panic!("unexpected throttle"),
        }
    }

    #[test]
    fn keep_alive_expiry_warm_to_cold_exactly_at_expiry() {
        let mut be = backend(det_cfg());
        let mut rng = Rng::new(1);
        // First invocation: pool miss → cold.
        let first = run(&mut be, 0, &mut rng);
        assert!(first.cold);
        let done = first.duration;
        be.complete(DnnKind::Hv, 0, done);
        assert_eq!(be.warm_containers(DnnKind::Hv, done), 1);
        // One microsecond before expiry: still warm.
        let last_warm = done + secs(5) - 1;
        let second = run(&mut be, last_warm, &mut rng);
        assert!(!second.cold, "container must be warm right before expiry");
        be.complete(DnnKind::Hv, 0, last_warm + second.duration);
        // Exactly at expiry (expiry <= now): cold again.
        let released = last_warm + second.duration;
        let third = run(&mut be, released + secs(5), &mut rng);
        assert!(third.cold, "container must expire exactly at keep-alive");
        assert_eq!(be.stats().cold_starts, 2);
        assert_eq!(be.stats().invocations, 3);
    }

    #[test]
    fn warm_pools_are_per_model() {
        let mut be = backend(det_cfg());
        let mut rng = Rng::new(2);
        let hv = run(&mut be, 0, &mut rng);
        be.complete(DnnKind::Hv, 0, hv.duration);
        // A different model finds no warm container.
        let m = &table1()[3]; // BP
        match be.invoke(m, hv.duration + 1, 38_000, 0, &mut rng) {
            Attempt::Run(inv) => assert!(inv.cold, "pools are per model"),
            Attempt::Throttle { .. } => panic!("unexpected throttle"),
        }
    }

    #[test]
    fn concurrency_ceiling_throttles_n_plus_first() {
        let cfg = FaasConfig { concurrency: 3, ..det_cfg() };
        let mut be = backend(cfg);
        let mut rng = Rng::new(3);
        for _ in 0..3 {
            run(&mut be, 0, &mut rng);
        }
        assert_eq!(be.in_flight(), 3);
        // The N+1st in-flight invocation is throttled.
        let m = &table1()[0];
        match be.invoke(m, 0, 38_000, 0, &mut rng) {
            Attempt::Throttle { retry_after } => {
                assert_eq!(retry_after, ms(200));
            }
            Attempt::Run(_) => panic!("4th concurrent invoke must throttle"),
        }
        assert_eq!(be.stats().throttles, 1);
        // Releasing one slot re-admits.
        be.complete(DnnKind::Hv, 0, ms(500));
        match be.invoke(m, ms(500), 38_000, 0, &mut rng) {
            Attempt::Run(inv) => assert!(!inv.cold, "reuses the container"),
            Attempt::Throttle { .. } => panic!("slot was released"),
        }
    }

    #[test]
    fn cost_is_gb_seconds_plus_request_fee() {
        let cfg = FaasConfig {
            keep_alive: secs(60),
            ..det_cfg()
        };
        let gb_price = cfg.gb_second_price;
        let req_price = cfg.request_price;
        let mem = cfg.memory_gb;
        let mut be = backend(cfg);
        let mut rng = Rng::new(4);
        let first = run(&mut be, 0, &mut rng); // cold
        be.complete(DnnKind::Hv, 0, first.duration);
        let second = run(&mut be, first.duration, &mut rng); // warm
        // Warm billed compute: exactly the sigma-0 median, rounded up to
        // the 1 ms quantum. HV: (398 − 84) ms.
        let billed_ms = (ms(398 - 84)).div_ceil(ms(1));
        let want_gb_s = (billed_ms * ms(1)) as f64 / 1e6 * mem;
        let want = want_gb_s * gb_price + req_price;
        assert!((second.cost - want).abs() < 1e-12,
                "warm cost {} want {want}", second.cost);
        // The cold invocation billed its init too.
        assert!(first.cost > second.cost);
        let s = be.stats();
        assert!((s.dollars - (first.cost + second.cost)).abs() < 1e-12);
        assert!(s.gb_seconds > want_gb_s);
    }

    #[test]
    fn timeout_still_bills_and_flags() {
        let cfg = FaasConfig { timeout: ms(100), ..det_cfg() };
        let mut be = backend(cfg);
        let mut rng = Rng::new(5);
        let inv = run(&mut be, 0, &mut rng);
        assert!(inv.timed_out);
        assert_eq!(inv.duration, ms(100));
        assert!(inv.cost > 0.0, "abandoned requests still bill");
    }

    #[test]
    fn timed_out_invocation_holds_slot_until_true_end() {
        // sigma 0, no cold penalty: true duration = (398−84) ms compute
        // + 2×40 ms latency + 3.8 ms transfer = 397.8 ms, but the client
        // abandons at 100 ms.
        let mut be = backend(FaasConfig {
            timeout: ms(100),
            concurrency: 1,
            cold_start: 0,
            ..det_cfg()
        });
        let mut rng = Rng::new(8);
        let inv = run(&mut be, 0, &mut rng);
        assert!(inv.timed_out);
        // The platform completes at the client timeout; the function is
        // still running server-side, so the slot stays held…
        be.complete(DnnKind::Hv, inv.token, ms(100));
        let m = &table1()[0];
        match be.invoke(m, ms(150), 38_000, 0, &mut rng) {
            Attempt::Throttle { .. } => {}
            Attempt::Run(_) => {
                panic!("slot must stay held until the function really ends")
            }
        }
        // …and frees once the true duration (397.8 ms) elapses, parking
        // the container warm from its true end.
        match be.invoke(m, ms(398), 38_000, 0, &mut rng) {
            Attempt::Run(inv2) => {
                assert!(!inv2.cold, "drained container parks warm")
            }
            Attempt::Throttle { .. } => {
                panic!("slot must free at the function's true end")
            }
        }
        assert_eq!(be.stats().throttles, 1);
    }

    #[test]
    fn default_retry_after_is_pinned_at_200ms() {
        // The CLI/CloudSpec now expose `retry_after`; the default must
        // stay bit-identical to the pre-knob engine.
        assert_eq!(FaasConfig::default().retry_after, ms(200));
    }

    #[test]
    fn cancel_bills_in_full_and_releases_the_slot() {
        let cfg = FaasConfig { concurrency: 1, ..det_cfg() };
        let mut be = backend(cfg);
        let mut rng = Rng::new(9);
        let inv = run(&mut be, 0, &mut rng);
        let billed = be.stats().dollars;
        assert!(billed > 0.0);
        // Client-side cancel of the losing hedge leg: the function ran
        // anyway, so the cost stands, but the slot frees and the
        // container parks warm.
        be.cancel(DnnKind::Hv, inv.token, ms(50));
        assert_eq!(be.in_flight(), 0);
        assert!((be.stats().dollars - billed).abs() < 1e-15,
                "cancel must not refund");
        let again = run(&mut be, ms(60), &mut rng);
        assert!(!again.cold, "cancelled leg parks its container warm");
    }

    #[test]
    fn probe_tracks_concurrency_headroom() {
        let cfg = FaasConfig { concurrency: 2, ..det_cfg() };
        let mut be = backend(cfg);
        let mut rng = Rng::new(10);
        assert!(be.probe(0));
        run(&mut be, 0, &mut rng);
        assert!(be.probe(0), "one slot left");
        run(&mut be, 0, &mut rng);
        assert!(!be.probe(0), "ceiling reached");
        be.complete(DnnKind::Hv, 0, ms(500));
        assert!(be.probe(ms(500)));
    }

    #[test]
    fn overlapping_invocations_grow_the_pool() {
        let cfg = FaasConfig { concurrency: 8, ..det_cfg() };
        let mut be = backend(cfg);
        let mut rng = Rng::new(6);
        for _ in 0..3 {
            run(&mut be, 0, &mut rng);
        }
        for _ in 0..3 {
            be.complete(DnnKind::Hv, 0, ms(700));
        }
        assert_eq!(be.warm_containers(DnnKind::Hv, ms(701)), 3);
        // Three warm slots serve three overlapping invocations cold-free.
        for _ in 0..3 {
            let inv = run(&mut be, ms(800), &mut rng);
            assert!(!inv.cold);
        }
        assert_eq!(be.stats().cold_starts, 3);
    }
}
