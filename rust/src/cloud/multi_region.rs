//! Two FaaS regions with distinct network paths and latency-based
//! failover: invocations route to the region whose recent end-to-end
//! durations look fastest (EWMA), and a throttle at the chosen region
//! fails over to the other before giving up. The A²-UAV line of work
//! (arXiv 2301.06363) motivates exactly this application-aware
//! network/compute split — which offload wins depends on where it runs.

use crate::cloud::{Attempt, CloudBackend, CloudStats, FaasBackend};
use crate::model::{DnnKind, ModelProfile};
use crate::rng::Rng;
use crate::time::Micros;

/// EWMA smoothing for the per-region duration estimate.
const ALPHA: f64 = 0.2;

/// A primary + secondary FaaS region pair behind one [`CloudBackend`].
pub struct MultiRegionBackend {
    regions: [FaasBackend; 2],
    /// EWMA of observed duration *inflation* per region — each sample is
    /// `duration / profile.t_cloud`, so the comparison measures the
    /// region rather than the model mix it happened to serve (per-model
    /// cloud times differ by >2×; raw durations would confound them).
    /// `None` until a region has served once.
    ewma: [Option<f64>; 2],
    /// Invocations served by the non-preferred region after a throttle.
    failovers: u64,
    /// Fault injection: a region refuses invocations while
    /// `now < outage_until[region]` (see [`crate::fault`]). Refusals are
    /// shaped as throttles, so the ordinary failover and scheduler
    /// adaptation paths react to the outage.
    outage_until: [Micros; 2],
}

impl MultiRegionBackend {
    pub fn new(primary: FaasBackend, secondary: FaasBackend) -> Self {
        MultiRegionBackend {
            regions: [primary, secondary],
            ewma: [None, None],
            failovers: 0,
            outage_until: [0, 0],
        }
    }

    /// Preferred region right now: the lower inflation EWMA; unobserved
    /// regions are tried first (optimistic discovery), ties and the
    /// initial state go to region 0 (the nominal primary).
    pub fn preferred(&self) -> usize {
        match self.ewma {
            [None, _] => 0,
            [_, None] => 1,
            [Some(a), Some(b)] => usize::from(b < a),
        }
    }

    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// Record one served invocation: `duration` normalized by the
    /// model's expected cloud time (see the `ewma` field docs).
    fn observe(&mut self, region: usize, duration: Micros,
               expected: Micros) {
        let d = duration as f64 / expected.max(1) as f64;
        self.ewma[region] = Some(match self.ewma[region] {
            None => d,
            Some(e) => e + ALPHA * (d - e),
        });
    }
}

impl CloudBackend for MultiRegionBackend {
    fn name(&self) -> &'static str {
        "multi-region"
    }

    fn invoke(&mut self, profile: &ModelProfile, now: Micros, bytes: u64,
              concurrent: usize, rng: &mut Rng) -> Attempt {
        let first = self.preferred();
        let mut retry = Micros::MAX;
        for region in [first, 1 - first] {
            // A region dark under fault injection refuses the attempt
            // outright, shaped as a throttle that clears when the
            // outage does — the failover below and the scheduler's
            // adaptation window both see it as cloud degradation.
            if now < self.outage_until[region] {
                retry = retry.min(self.outage_until[region] - now);
                continue;
            }
            match self.regions[region]
                .invoke(profile, now, bytes, concurrent, rng)
            {
                Attempt::Run(mut inv) => {
                    self.observe(region, inv.duration, profile.t_cloud);
                    self.failovers += (region != first) as u64;
                    // Region in bit 0; the region's own token (e.g. its
                    // abandoned-request marker) shifted above it.
                    inv.token = (inv.token << 1) | region as u32;
                    return Attempt::Run(inv);
                }
                Attempt::Throttle { retry_after } => {
                    retry = retry.min(retry_after);
                }
            }
        }
        Attempt::Throttle { retry_after: retry }
    }

    fn complete(&mut self, kind: DnnKind, token: u32, now: Micros) {
        self.regions[(token & 1) as usize].complete(kind, token >> 1, now);
    }

    fn cancel(&mut self, kind: DnnKind, token: u32, now: Micros) {
        self.regions[(token & 1) as usize].cancel(kind, token >> 1, now);
    }

    fn probe(&self, now: Micros) -> bool {
        // Some region is both outside its outage window and under its
        // concurrency ceiling.
        (0..2).any(|r| now >= self.outage_until[r]
                       && self.regions[r].probe(now))
    }

    fn fault_outage(&mut self, region: usize, until: Micros) {
        if let Some(slot) = self.outage_until.get_mut(region) {
            *slot = until;
        }
    }

    fn stats(&self) -> CloudStats {
        let mut s = self.regions[0].stats();
        s.merge(&self.regions[1].stats());
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::FaasConfig;
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::time::{ms, secs};

    /// Deterministic region: sigma-0 compute, no cold-start jitter, over
    /// a constant network with the given one-way latency.
    fn region(latency: Micros, concurrency: usize) -> FaasBackend {
        FaasBackend::new(
            FaasConfig {
                sigma: 0.0,
                cold_start: 0,
                keep_alive: secs(60),
                concurrency,
                ..FaasConfig::default()
            },
            Box::new(ConstantNet { latency, bandwidth: 10.0e6 }),
        )
    }

    fn invoke(be: &mut MultiRegionBackend, now: Micros,
              rng: &mut Rng) -> (Micros, u32) {
        let m = &table1()[0];
        match be.invoke(m, now, 38_000, 0, rng) {
            Attempt::Run(inv) => (inv.duration, inv.token),
            Attempt::Throttle { .. } => panic!("unexpected throttle"),
        }
    }

    #[test]
    fn routes_to_lower_latency_region_after_discovery() {
        // Region 0 is 5× slower than region 1.
        let mut be =
            MultiRegionBackend::new(region(ms(200), 16), region(ms(40), 16));
        let mut rng = Rng::new(1);
        let (_, t0) = invoke(&mut be, 0, &mut rng);
        assert_eq!(t0, 0, "nominal primary is discovered first");
        be.complete(DnnKind::Hv, t0, ms(900));
        let (_, t1) = invoke(&mut be, secs(1), &mut rng);
        assert_eq!(t1, 1, "unobserved secondary tried next");
        be.complete(DnnKind::Hv, t1, secs(1) + ms(900));
        // Both observed: every further call steers to the fast region.
        for i in 2..6u64 {
            let (_, t) = invoke(&mut be, secs(i), &mut rng);
            assert_eq!(t, 1, "EWMA must prefer the fast region");
            be.complete(DnnKind::Hv, t, secs(i) + ms(900));
        }
        assert_eq!(be.failovers(), 0);
    }

    #[test]
    fn throttle_fails_over_then_gives_up() {
        // Preferred region admits only one in-flight invocation.
        let mut be =
            MultiRegionBackend::new(region(ms(40), 1), region(ms(40), 1));
        let mut rng = Rng::new(2);
        let (_, t0) = invoke(&mut be, 0, &mut rng);
        assert_eq!(t0, 0);
        // Second overlapping call: region 0 throttles → failover to 1.
        let (_, t1) = invoke(&mut be, 0, &mut rng);
        assert_eq!(t1, 1, "throttle must fail over");
        assert_eq!(be.failovers(), 1);
        // Third: both full → throttled for real.
        let m = &table1()[0];
        match CloudBackend::invoke(&mut be, m, 0, 38_000, 0, &mut rng) {
            Attempt::Throttle { retry_after } => {
                assert_eq!(retry_after, ms(200));
            }
            Attempt::Run(_) => panic!("both regions are saturated"),
        }
        // Stats aggregate across regions (2 runs + the 2 inner throttles).
        let s = be.stats();
        assert_eq!(s.invocations, 2);
        assert_eq!(s.throttles, 2);
    }

    #[test]
    fn outage_darkens_region_and_early_clear_restores_it() {
        let mut be =
            MultiRegionBackend::new(region(ms(40), 16), region(ms(200), 16));
        let mut rng = Rng::new(4);
        be.fault_outage(0, secs(10));
        // Region 0 dark: the call fails over to 1 despite 0 being the
        // nominal primary.
        let (_, t0) = invoke(&mut be, 0, &mut rng);
        assert_eq!(t0, 1, "dark region must refuse");
        assert_eq!(be.failovers(), 1);
        be.complete(DnnKind::Hv, t0, ms(900));
        // Both dark: throttle-shaped refusal until the nearer outage ends.
        be.fault_outage(1, secs(5));
        let m = &table1()[0];
        match CloudBackend::invoke(&mut be, m, secs(1), 38_000, 0, &mut rng)
        {
            Attempt::Throttle { retry_after } => {
                assert_eq!(retry_after, secs(4));
            }
            Attempt::Run(_) => panic!("both regions are dark"),
        }
        // An early clear restores service before the scheduled end.
        be.fault_outage(0, 0);
        let (_, t) = invoke(&mut be, secs(2), &mut rng);
        assert_eq!(t & 1, 0, "cleared region serves again");
    }

    #[test]
    fn probe_reports_headroom_across_outages_and_ceilings() {
        let mut be =
            MultiRegionBackend::new(region(ms(40), 1), region(ms(40), 1));
        let mut rng = Rng::new(5);
        assert!(be.probe(0));
        // Both regions dark → no headroom until the nearer outage ends.
        be.fault_outage(0, secs(10));
        be.fault_outage(1, secs(10));
        assert!(!be.probe(secs(1)));
        assert!(be.probe(secs(10)), "outage end restores headroom");
        be.fault_outage(0, 0);
        be.fault_outage(1, 0);
        // Fill both single-slot regions → ceiling-driven denial.
        invoke(&mut be, secs(11), &mut rng);
        invoke(&mut be, secs(11), &mut rng);
        assert!(!be.probe(secs(11)));
        // Cancel routes to the serving region and frees its slot.
        be.cancel(DnnKind::Hv, 1, secs(12));
        assert!(be.probe(secs(12)));
    }

    #[test]
    fn completion_releases_the_serving_region() {
        let mut be =
            MultiRegionBackend::new(region(ms(40), 1), region(ms(40), 1));
        let mut rng = Rng::new(3);
        let (_, t0) = invoke(&mut be, 0, &mut rng);
        let (_, t1) = invoke(&mut be, 0, &mut rng);
        assert_eq!((t0, t1), (0, 1));
        be.complete(DnnKind::Hv, 1, ms(900));
        // Region 1 freed; region 0 still full → next run lands on 1.
        let (_, t2) = invoke(&mut be, ms(901), &mut rng);
        assert_eq!(t2, 1);
    }
}
