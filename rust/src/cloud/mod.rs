//! Pluggable cloud FaaS backend subsystem.
//!
//! The paper's cloud tier is AWS Lambda (§3.2), and its headline
//! adaptation result (§5.4, Fig. 12) hinges on cloud variability. The
//! original harness modelled that tier as a single hard-coded sampler
//! ([`CloudExecModel`](crate::exec::CloudExecModel)); this module turns
//! "the cloud" into an extensible backend API every scheduler and
//! scenario can target:
//!
//! * [`CloudBackend`] — the trait: `invoke` (admission + service-time
//!   sampling at virtual time), `complete` (container release), `stats`
//!   (cost/cold-start/throttle accounting).
//! * [`SimpleBackend`] — wraps the calibrated [`CloudExecModel`]
//!   unchanged; the default path is bit-identical to the pre-subsystem
//!   engine (pinned by the golden/parity tests).
//! * [`FaasBackend`] — a faithful FaaS account: per-model warm-container
//!   pools with keep-alive expiry, deterministic cold starts on pool
//!   miss, a per-account concurrency ceiling with throttle/retry
//!   semantics, and per-invocation cost accounting (GB-seconds + a
//!   per-request fee).
//! * [`MultiRegionBackend`] — two FaaS regions with distinct network
//!   models and latency-based failover.
//!
//! Event flow: the platform's cloud trigger calls
//! [`CloudBackend::invoke`]; an [`Attempt::Run`] schedules `CloudDone`
//! at `now + duration` (whose handler calls [`CloudBackend::complete`],
//! returning the container to its warm pool), while an
//! [`Attempt::Throttle`] is routed back to the scheduler through the
//! `on_cloud_report` hook (so DEMS-A genuinely reacts to throttling)
//! and retried or dropped by deadline feasibility.

mod faas;
mod multi_region;
mod simple;

pub use faas::{FaasBackend, FaasConfig};
pub use multi_region::MultiRegionBackend;
pub use simple::SimpleBackend;

use crate::model::{DnnKind, ModelProfile};
use crate::rng::Rng;
use crate::time::Micros;

/// One admitted cloud invocation, as sampled by a backend.
#[derive(Clone, Copy, Debug)]
pub struct Invocation {
    /// End-to-end duration t̂ᵢʲ (compute + cold start + network transfer;
    /// clamped to the client timeout when `timed_out`).
    pub duration: Micros,
    /// The HTTP client abandoned the request (no usable output).
    pub timed_out: bool,
    /// The invocation paid a cold start (no warm container available).
    pub cold: bool,
    /// Dollars billed for this invocation (0 for uncosted backends).
    pub cost: f64,
    /// Backend-private routing token (e.g. the region index), handed back
    /// verbatim to [`CloudBackend::complete`].
    pub token: u32,
}

/// Outcome of asking a backend to start an invocation.
#[derive(Clone, Copy, Debug)]
pub enum Attempt {
    /// Admitted: the request is in flight for `Invocation::duration`.
    Run(Invocation),
    /// Rejected at the account concurrency ceiling; the caller may retry
    /// no earlier than `now + retry_after`.
    Throttle { retry_after: Micros },
}

/// Cumulative per-backend accounting, merged into
/// [`Metrics`](crate::metrics::Metrics) at the end of a run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CloudStats {
    /// Admitted invocations (throttled attempts excluded).
    pub invocations: u64,
    /// Invocations that paid a cold start.
    pub cold_starts: u64,
    /// Rejected (throttled) invocation attempts.
    pub throttles: u64,
    /// Billed compute, in GB-seconds.
    pub gb_seconds: f64,
    /// Total dollars billed (GB-seconds + per-request fees).
    pub dollars: f64,
}

impl CloudStats {
    /// Fold another backend's accounting into this one (multi-region /
    /// cluster aggregation).
    pub fn merge(&mut self, other: &CloudStats) {
        self.invocations += other.invocations;
        self.cold_starts += other.cold_starts;
        self.throttles += other.throttles;
        self.gb_seconds += other.gb_seconds;
        self.dollars += other.dollars;
    }

    /// Cold starts per admitted invocation (0 when idle).
    pub fn cold_start_rate(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.cold_starts as f64 / self.invocations as f64
        }
    }
}

/// A cloud execution backend driven by virtual time.
///
/// Implementations are deterministic: all randomness comes from the
/// caller's seeded [`Rng`], and all state advances only through `invoke`
/// and `complete`, so whole runs reproduce from a single seed (and sweep
/// cells stay byte-identical for any `--jobs` value).
pub trait CloudBackend: Send {
    /// Short backend tag for reports and logs ("simple", "faas", …).
    fn name(&self) -> &'static str;

    /// Try to start one invocation of `profile`'s model at virtual time
    /// `now`, carrying `bytes` up the shared uplink with `concurrent`
    /// transfers already in flight on this edge.
    fn invoke(&mut self, profile: &ModelProfile, now: Micros, bytes: u64,
              concurrent: usize, rng: &mut Rng) -> Attempt;

    /// An invocation admitted earlier (for `kind`, with `token`) finished
    /// at `now`: release its concurrency slot and return its container to
    /// the warm pool. Backends without container state ignore this.
    fn complete(&mut self, _kind: DnnKind, _token: u32, _now: Micros) {}

    /// An invocation admitted earlier was cancelled client-side at `now`
    /// (the losing leg of a hedged pair, see [`crate::resilience`]). FaaS
    /// semantics: a client-side cancel cannot claw back a running
    /// function — it runs to completion and bills in full — so the
    /// default (and the FaaS implementation) releases bookkeeping exactly
    /// like [`complete`](Self::complete) and the cost recorded at
    /// `invoke` stands.
    fn cancel(&mut self, kind: DnnKind, token: u32, now: Micros) {
        self.complete(kind, token, now);
    }

    /// Would an invocation attempted at `now` plausibly be admitted?
    /// Advisory (used by resilience probes/hedges to avoid pointless
    /// attempts); never mutates state and never draws RNG.
    fn probe(&self, _now: Micros) -> bool {
        true
    }

    /// Fault injection (see [`crate::fault`]): region `region` is dark
    /// until `until` (0 clears an outage early). A dark region refuses
    /// invocations, shaped as throttles so the scheduler's adaptation
    /// path reacts. Backends without regions ignore this.
    fn fault_outage(&mut self, _region: usize, _until: Micros) {}

    /// Cumulative accounting snapshot.
    fn stats(&self) -> CloudStats {
        CloudStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge_and_rate() {
        let mut a = CloudStats {
            invocations: 10,
            cold_starts: 2,
            throttles: 1,
            gb_seconds: 1.5,
            dollars: 0.25,
        };
        let b = CloudStats {
            invocations: 5,
            cold_starts: 1,
            throttles: 0,
            gb_seconds: 0.5,
            dollars: 0.05,
        };
        a.merge(&b);
        assert_eq!(a.invocations, 15);
        assert_eq!(a.cold_starts, 3);
        assert_eq!(a.throttles, 1);
        assert!((a.gb_seconds - 2.0).abs() < 1e-12);
        assert!((a.dollars - 0.30).abs() < 1e-12);
        assert!((a.cold_start_rate() - 0.2).abs() < 1e-12);
        assert_eq!(CloudStats::default().cold_start_rate(), 0.0);
    }
}
