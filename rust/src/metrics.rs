//! Per-run accounting: everything the paper's figures report (§8).

use crate::cloud::CloudStats;
use crate::model::{DnnKind, Resource};
use crate::obs::{LogHistogram, Timeline};
use crate::task::{DropReason, Fate, TaskOutcome};
use crate::time::{to_ms, Micros};

/// Counters for one DNN model within a run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelStats {
    pub generated: u64,
    pub completed_edge: u64,
    pub completed_cloud: u64,
    /// Pipeline stages executed on the drone's companion computer
    /// (always zero without pipeline workloads).
    pub completed_drone: u64,
    pub missed_edge: u64,
    pub missed_cloud: u64,
    pub missed_drone: u64,
    pub dropped_infeasible: u64,
    pub dropped_negative: u64,
    pub dropped_jit: u64,
    pub dropped_trigger: u64,
    pub dropped_shed: u64,
    pub dropped_timeout: u64,
    pub dropped_throttled: u64,
    /// Lost to an injected node failure (edge crash, see
    /// [`crate::fault`]): in-flight or queued work whose substrate died
    /// and could not be relocated.
    pub dropped_node_failure: u64,
    /// Dispatch attempts the cloud backend throttled (each either retried
    /// later or counted once more under `dropped_throttled`).
    pub throttled: u64,
    pub utility_edge: f64,
    pub utility_cloud: f64,
    pub utility_drone: f64,
    pub qoe_utility: f64,
    pub windows_total: u64,
    pub windows_met: u64,
    pub stolen: u64,
    pub gems_rescheduled: u64,
    /// Execution-duration distribution of executed tasks (ms), always on:
    /// O(1) memory per task (log-scale buckets, ≤ 0.5% percentile error —
    /// see [`LogHistogram`]).
    pub exec_hist: LogHistogram,
    /// Cloud-side latency distribution (ms): completed/missed cloud
    /// executions plus timed-out invocations — the population whose tail
    /// hedged requests ([`crate::resilience`]) are meant to cut.
    pub cloud_exec_hist: LogHistogram,
    /// Exact per-task samples behind `Metrics::record_exact_samples`
    /// (default off, so metrics memory no longer grows per task); the
    /// histogram parity tests diff these against the streaming path.
    pub exec_ms: Vec<f64>,
    /// Exact counterpart of `cloud_exec_hist` (same gate).
    pub cloud_exec_ms: Vec<f64>,
}

impl ModelStats {
    pub fn completed(&self) -> u64 {
        self.completed_edge + self.completed_cloud + self.completed_drone
    }

    pub fn executed(&self) -> u64 {
        self.completed()
            + self.missed_edge
            + self.missed_cloud
            + self.missed_drone
    }

    pub fn dropped(&self) -> u64 {
        self.dropped_infeasible
            + self.dropped_negative
            + self.dropped_jit
            + self.dropped_trigger
            + self.dropped_shed
            + self.dropped_timeout
            + self.dropped_throttled
            + self.dropped_node_failure
    }

    pub fn utility(&self) -> f64 {
        self.utility_edge + self.utility_cloud + self.utility_drone
    }
}

/// A point on the Fig.-12 style timeline: one cloud (or edge) execution.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelinePoint {
    pub at: Micros,
    pub model: DnnKind,
    pub observed_ms: f64,
    pub expected_ms: f64,
    pub success: bool,
}

/// One finalized task event, for per-window drilldowns (Fig. 15) and the
/// navigation coupling (Fig. 17/18).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompletionRecord {
    pub at: Micros,
    pub model: DnnKind,
    pub success: bool,
    /// End-to-end latency from segment creation to finalization.
    pub latency: Micros,
}

/// Full metrics for one platform run.
///
/// Derives `PartialEq` so determinism and dispatch-parity tests can assert
/// *bit-identical* runs (every counter, utility sum and record).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    pub per_model: Vec<(DnnKind, ModelStats)>,
    /// Optional per-execution timeline (enabled for the Fig. 12 harness).
    pub timeline: Vec<TimelinePoint>,
    pub record_timeline: bool,
    /// Optional per-task finalization log (Fig. 15 / Fig. 17–18 harnesses).
    pub completions: Vec<CompletionRecord>,
    pub record_completions: bool,
    /// Keep the exact `exec_ms`/`cloud_exec_ms` sample vectors alongside
    /// the streaming histograms (parity tests and offline drilldowns;
    /// off by default to bound memory).
    pub record_exact_samples: bool,
    /// Optional windowed time-series fold (`experiment timeline`): set to
    /// `Some(Timeline::new(window))` before the run to enable.
    pub windowed: Option<Timeline>,
    /// Discrete events this edge's engine processed (throughput profiling;
    /// see `BenchSuite` events/sec gauges).
    pub events_processed: u64,
    /// Edge executor busy time (for the §8.4 utilization numbers).
    pub edge_busy: Micros,
    pub duration: Micros,
    /// Fleet-federation accounting (all zero unless the cluster runs a
    /// [`Federation`](crate::cluster::Federation) layer): cross-edge
    /// steal arrivals this edge executed-side received.
    pub fed_steals_in: u64,
    /// Deferred cloud entries this edge offered away to sibling edges.
    pub fed_steals_out: u64,
    /// Drones re-homed *to* this edge mid-run (fleet handover).
    pub handovers: u64,
    /// Total shared-uplink queueing delay charged to this edge's cloud
    /// dispatches (fleet federation's contention model).
    pub uplink_wait: Micros,
    /// Cloud dispatches that had to queue on the shared uplink.
    pub uplink_queued: u64,
    /// Cloud backend accounting. The default
    /// [`SimpleBackend`](crate::cloud::SimpleBackend) path only counts
    /// invocations (no cost, cold-start or throttle accounting).
    pub cloud: CloudStats,
    /// Fault-injection accounting (all zero without a
    /// [`FaultSpec`](crate::fault::FaultSpec)): times this edge crashed.
    pub crashes: u64,
    /// Times this edge came back up.
    pub recoveries: u64,
    /// Queued entries this (crashed) edge relocated to live siblings via
    /// the federation steal path ([`Recovery::Requeue`]
    /// semantics — the lost ones land in `dropped_node_failure`).
    ///
    /// [`Recovery::Requeue`]: crate::fault::Recovery::Requeue
    pub fault_relocated: u64,
    /// Total virtual time this edge spent dark (crash → recovery, or to
    /// the horizon when it never recovered).
    pub downtime: Micros,
    /// Resilience-layer accounting (all zero unless the policy opts into a
    /// [`ResilienceSpec`](crate::resilience::ResilienceSpec)): times the
    /// cloud circuit breaker tripped open.
    pub breaker_trips: u64,
    /// Cloud dispatches short-circuited by an open breaker (re-planned to
    /// edge/federation without touching the backend).
    pub breaker_shorted: u64,
    /// Half-open probe invocations sent to test backend recovery.
    pub breaker_probes: u64,
    /// Speculative duplicate cloud invocations launched (hedged requests).
    pub hedge_launches: u64,
    /// Hedged tasks where the speculative duplicate finished first (or the
    /// primary timed out and the duplicate survived).
    pub hedge_wins: u64,
    /// Hedge legs cancelled after their partner won the race.
    pub hedge_cancels: u64,
    /// Edge tasks executed on the lite model variant under graceful
    /// degradation.
    pub degraded_tasks: u64,
    /// Utility forfeited to the lite-variant discount on successful
    /// degraded completions (full-variant utility minus earned).
    pub degraded_utility_lost: f64,
}

impl Metrics {
    pub fn new(models: &[DnnKind]) -> Self {
        Metrics {
            per_model: models.iter().map(|k| (*k, ModelStats::default())).collect(),
            ..Default::default()
        }
    }

    pub fn stats_mut(&mut self, kind: DnnKind) -> &mut ModelStats {
        &mut self
            .per_model
            .iter_mut()
            .find(|(k, _)| *k == kind)
            .expect("model registered")
            .1
    }

    pub fn stats(&self, kind: DnnKind) -> &ModelStats {
        &self
            .per_model
            .iter()
            .find(|(k, _)| *k == kind)
            .expect("model registered")
            .1
    }

    /// Record a finalized task outcome (Eqn 1 accounting).
    pub fn record(&mut self, o: &TaskOutcome) {
        let s = self.stats_mut(o.model);
        match o.fate {
            Fate::Completed(Resource::Edge) => {
                s.completed_edge += 1;
                s.utility_edge += o.utility;
            }
            Fate::Completed(Resource::Cloud) => {
                s.completed_cloud += 1;
                s.utility_cloud += o.utility;
            }
            Fate::Completed(Resource::Drone) => {
                s.completed_drone += 1;
                s.utility_drone += o.utility;
            }
            Fate::Missed(Resource::Edge) => {
                s.missed_edge += 1;
                s.utility_edge += o.utility;
            }
            Fate::Missed(Resource::Cloud) => {
                s.missed_cloud += 1;
                s.utility_cloud += o.utility;
            }
            Fate::Missed(Resource::Drone) => {
                s.missed_drone += 1;
                s.utility_drone += o.utility;
            }
            Fate::Dropped(r) => match r {
                DropReason::Infeasible => s.dropped_infeasible += 1,
                DropReason::NegativeCloudUtility => s.dropped_negative += 1,
                DropReason::JitExpired => s.dropped_jit += 1,
                DropReason::TriggerExpired => s.dropped_trigger += 1,
                DropReason::Shed => s.dropped_shed += 1,
                DropReason::Timeout => s.dropped_timeout += 1,
                DropReason::Throttled => s.dropped_throttled += 1,
                DropReason::NodeFailure => s.dropped_node_failure += 1,
            },
        }
        if o.stolen {
            s.stolen += 1;
        }
        if o.gems_rescheduled && !matches!(o.fate, Fate::Dropped(_)) {
            s.gems_rescheduled += 1;
        }
        if o.exec_duration > 0 {
            let ms = to_ms(o.exec_duration);
            let cloud_side = matches!(
                o.fate,
                Fate::Completed(Resource::Cloud)
                    | Fate::Missed(Resource::Cloud)
                    | Fate::Dropped(DropReason::Timeout)
            );
            s.exec_hist.record(ms);
            if cloud_side {
                s.cloud_exec_hist.record(ms);
            }
            if self.record_exact_samples {
                let s = self.stats_mut(o.model);
                s.exec_ms.push(ms);
                if cloud_side {
                    s.cloud_exec_ms.push(ms);
                }
            }
        }
        if let Some(tl) = &mut self.windowed {
            tl.observe_outcome(o);
        }
        if self.record_completions {
            self.completions.push(CompletionRecord {
                at: o.at,
                model: o.model,
                success: o.success(),
                latency: o.at.saturating_sub(o.created_at),
            });
        }
    }

    // ---------------------------------------------------- aggregate views

    pub fn generated(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.generated).sum()
    }

    pub fn completed(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.completed()).sum()
    }

    pub fn completed_on(&self, r: Resource) -> u64 {
        self.per_model
            .iter()
            .map(|(_, s)| match r {
                Resource::Edge => s.completed_edge,
                Resource::Cloud => s.completed_cloud,
                Resource::Drone => s.completed_drone,
            })
            .sum()
    }

    /// On-time completion rate over all generated tasks.
    pub fn completion_rate(&self) -> f64 {
        let g = self.generated();
        if g == 0 {
            0.0
        } else {
            self.completed() as f64 / g as f64
        }
    }

    pub fn qos_utility(&self) -> f64 {
        self.per_model.iter().map(|(_, s)| s.utility()).sum()
    }

    pub fn qos_utility_on(&self, r: Resource) -> f64 {
        self.per_model
            .iter()
            .map(|(_, s)| match r {
                Resource::Edge => s.utility_edge,
                Resource::Cloud => s.utility_cloud,
                Resource::Drone => s.utility_drone,
            })
            .sum()
    }

    pub fn qoe_utility(&self) -> f64 {
        self.per_model.iter().map(|(_, s)| s.qoe_utility).sum()
    }

    /// Total utility γ = Σ QoS + Σ QoE (§4).
    pub fn total_utility(&self) -> f64 {
        self.qos_utility() + self.qoe_utility()
    }

    pub fn stolen(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.stolen).sum()
    }

    pub fn gems_rescheduled(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.gems_rescheduled).sum()
    }

    /// Throttled dispatch attempts across all models (platform-observed;
    /// `cloud.throttles` is the backend-side count, which can differ
    /// under multi-region failover).
    pub fn throttled(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.throttled).sum()
    }

    /// Tasks lost to injected node failures across all models.
    pub fn node_failures(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.dropped_node_failure).sum()
    }

    /// Tasks dropped for `reason` across all models (the drop-breakdown
    /// column group; see [`DropReason::ALL`] for the canonical order).
    pub fn dropped_by(&self, reason: DropReason) -> u64 {
        self.per_model
            .iter()
            .map(|(_, s)| match reason {
                DropReason::Infeasible => s.dropped_infeasible,
                DropReason::NegativeCloudUtility => s.dropped_negative,
                DropReason::JitExpired => s.dropped_jit,
                DropReason::TriggerExpired => s.dropped_trigger,
                DropReason::Shed => s.dropped_shed,
                DropReason::Timeout => s.dropped_timeout,
                DropReason::Throttled => s.dropped_throttled,
                DropReason::NodeFailure => s.dropped_node_failure,
            })
            .sum()
    }

    /// Total dropped tasks across all models and reasons.
    pub fn dropped(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.dropped()).sum()
    }

    /// Edge utilization: busy time / run duration.
    pub fn edge_utilization(&self) -> f64 {
        if self.duration == 0 {
            0.0
        } else {
            self.edge_busy as f64 / self.duration as f64
        }
    }
}

/// Percentile over a sample set (p in [0,1]). Uses IEEE total ordering,
/// so NaN samples sort to the top instead of panicking mid-experiment.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    v[((v.len() - 1) as f64 * p).round() as usize]
}

/// Median-by-QoS-utility element of a slice of per-edge runs — "a median
/// edge base station" as the paper reports (upper median for even counts).
/// `None` on an empty slice; NaN utilities order via `total_cmp` instead
/// of panicking.
pub fn median_by_qos_utility(runs: &[Metrics]) -> Option<&Metrics> {
    if runs.is_empty() {
        return None;
    }
    let mut idx: Vec<usize> = (0..runs.len()).collect();
    idx.sort_by(|&a, &b| {
        runs[a].qos_utility().total_cmp(&runs[b].qos_utility())
    });
    Some(&runs[idx[idx.len() / 2]])
}

/// (min, max) QoS utility across per-edge runs; `(+inf, -inf)` on an
/// empty slice (the fold identities, as the pre-redesign harness used).
pub fn minmax_qos_utility(runs: &[Metrics]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for m in runs {
        let u = m.qos_utility();
        lo = lo.min(u);
        hi = hi.max(u);
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::ms;

    fn outcome(model: DnnKind, fate: Fate, utility: f64) -> TaskOutcome {
        TaskOutcome {
            task_id: 0,
            model,
            drone: 0,
            fate,
            at: ms(100),
            created_at: ms(20),
            exec_duration: ms(50),
            utility,
            gems_rescheduled: false,
            stolen: false,
        }
    }

    #[test]
    fn record_routes_to_buckets() {
        let mut m = Metrics::new(&[DnnKind::Hv, DnnKind::Bp]);
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge), 124.0));
        m.record(&outcome(DnnKind::Hv, Fate::Missed(Resource::Cloud), -25.0));
        m.record(&outcome(DnnKind::Bp, Fate::Dropped(DropReason::JitExpired), 0.0));
        assert_eq!(m.completed(), 1);
        assert_eq!(m.completed_on(Resource::Edge), 1);
        assert_eq!(m.stats(DnnKind::Hv).missed_cloud, 1);
        assert_eq!(m.stats(DnnKind::Bp).dropped_jit, 1);
        assert_eq!(m.qos_utility(), 99.0);
        assert_eq!(m.qos_utility_on(Resource::Edge), 124.0);
        assert_eq!(m.qos_utility_on(Resource::Cloud), -25.0);
    }

    #[test]
    fn drone_bucket_counts_like_the_others() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Drone),
                          124.0));
        m.record(&outcome(DnnKind::Hv, Fate::Missed(Resource::Drone),
                          -1.0));
        let s = m.stats(DnnKind::Hv);
        assert_eq!((s.completed_drone, s.missed_drone), (1, 1));
        assert_eq!(s.completed(), 1);
        assert_eq!(s.executed(), 2);
        assert_eq!(m.completed_on(Resource::Drone), 1);
        assert_eq!(m.qos_utility_on(Resource::Drone), 123.0);
        assert_eq!(m.qos_utility(), 123.0);
    }

    #[test]
    fn completion_rate_over_generated() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.stats_mut(DnnKind::Hv).generated = 4;
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge), 1.0));
        assert_eq!(m.completion_rate(), 0.25);
    }

    #[test]
    fn total_utility_includes_qoe() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge), 10.0));
        m.stats_mut(DnnKind::Hv).qoe_utility = 5.0;
        assert_eq!(m.total_utility(), 15.0);
        assert_eq!(m.qoe_utility(), 5.0);
    }

    #[test]
    fn stolen_and_rescheduled_counts() {
        let mut m = Metrics::new(&[DnnKind::Bp]);
        let mut o = outcome(DnnKind::Bp, Fate::Completed(Resource::Edge), 38.0);
        o.stolen = true;
        m.record(&o);
        let mut o2 = outcome(DnnKind::Bp, Fate::Completed(Resource::Cloud), -3.0);
        o2.gems_rescheduled = true;
        m.record(&o2);
        assert_eq!(m.stolen(), 1);
        assert_eq!(m.gems_rescheduled(), 1);
    }

    #[test]
    fn cloud_exec_samples_cover_cloud_and_timeout_fates() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Cloud),
                          100.0));
        m.record(&outcome(DnnKind::Hv, Fate::Missed(Resource::Cloud),
                          -25.0));
        m.record(&outcome(DnnKind::Hv, Fate::Dropped(DropReason::Timeout),
                          0.0));
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge),
                          124.0));
        let s = m.stats(DnnKind::Hv);
        // Cloud completions, cloud misses and invocation timeouts feed the
        // hedging tail population; the edge completion only feeds exec.
        assert_eq!(s.cloud_exec_hist.count(), 3);
        assert_eq!(s.exec_hist.count(), 4);
        // All samples are 50 ms; the 1% buckets resolve them within 0.5%.
        let p50 = s.cloud_exec_hist.percentile(0.5);
        assert!((p50 - 50.0).abs() <= 50.0 * 0.005, "{p50}");
        // Exact per-task vectors stay empty unless explicitly enabled —
        // default metrics memory no longer grows with the task count.
        assert!(s.exec_ms.is_empty() && s.cloud_exec_ms.is_empty());
    }

    #[test]
    fn exact_samples_are_opt_in_and_mirror_the_histograms() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.record_exact_samples = true;
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Cloud),
                          1.0));
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge),
                          1.0));
        let s = m.stats(DnnKind::Hv);
        assert_eq!(s.exec_ms.len(), 2);
        assert_eq!(s.cloud_exec_ms.len(), 1);
        assert_eq!(s.exec_hist.count(), 2);
        assert_eq!(s.cloud_exec_hist.count(), 1);
    }

    #[test]
    fn windowed_timeline_folds_outcomes_when_enabled() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.windowed = Some(Timeline::new(ms(60)));
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge),
                          2.0)); // at = 100 ms → window 1
        m.record(&outcome(DnnKind::Hv,
                          Fate::Dropped(DropReason::Shed), 0.0));
        let tl = m.windowed.as_ref().unwrap();
        assert_eq!(tl.windows().len(), 2);
        assert_eq!(tl.windows()[1].completed, 1);
        assert_eq!(tl.windows()[1].dropped, 1);
        assert!((tl.windows()[1].utility - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=101).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 51.0);
        assert_eq!(percentile(&xs, 1.0), 101.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    /// A Metrics whose QoS utility is exactly `u` (one edge completion).
    fn with_utility(u: f64) -> Metrics {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.record(&outcome(DnnKind::Hv, Fate::Completed(Resource::Edge), u));
        m
    }

    #[test]
    fn median_by_qos_utility_picks_upper_median() {
        let runs: Vec<Metrics> =
            [30.0, 10.0, 20.0].into_iter().map(with_utility).collect();
        let med = median_by_qos_utility(&runs).unwrap();
        assert_eq!(med.qos_utility(), 20.0);
        // Even count: the upper of the two middles (index len/2 of the
        // sorted order), matching the pre-redesign helper.
        let runs4: Vec<Metrics> = [40.0, 10.0, 30.0, 20.0]
            .into_iter()
            .map(with_utility)
            .collect();
        assert_eq!(median_by_qos_utility(&runs4).unwrap().qos_utility(),
                   30.0);
        assert!(median_by_qos_utility(&[]).is_none());
    }

    #[test]
    fn median_tolerates_nan_utilities() {
        // A NaN utility (e.g. a degenerate 0-task edge elsewhere summing
        // with inf) must not panic the sort; total_cmp puts NaN last.
        let runs: Vec<Metrics> = [f64::NAN, 10.0, 20.0]
            .into_iter()
            .map(with_utility)
            .collect();
        let med = median_by_qos_utility(&runs).unwrap();
        assert_eq!(med.qos_utility(), 20.0);
    }

    #[test]
    fn minmax_qos_utility_bounds() {
        let runs: Vec<Metrics> =
            [15.0, -5.0, 40.0].into_iter().map(with_utility).collect();
        assert_eq!(minmax_qos_utility(&runs), (-5.0, 40.0));
        let (lo, hi) = minmax_qos_utility(&[]);
        assert!(lo.is_infinite() && lo > 0.0);
        assert!(hi.is_infinite() && hi < 0.0);
    }

    #[test]
    fn percentile_handles_nan_samples() {
        let xs = [1.0, f64::NAN, 3.0];
        // NaN sorts last under total_cmp; lower percentiles stay finite.
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert!(percentile(&xs, 1.0).is_nan());
    }

    #[test]
    fn edge_utilization_ratio() {
        let mut m = Metrics::new(&[DnnKind::Hv]);
        m.edge_busy = ms(300);
        m.duration = ms(1000);
        assert!((m.edge_utilization() - 0.3).abs() < 1e-12);
    }
}
