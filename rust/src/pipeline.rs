//! Split-DNN pipeline workloads: linear stage graphs partitioned across
//! drone, edge and cloud (the ROADMAP's "DAG tasks" open item).
//!
//! The paper's VIP applications are naturally chains — detect → track →
//! describe — with one *end-to-end* deadline; LLHR and "Distributed CNN
//! Inference on Resource-Constrained UAVs" (PAPERS.md) both show the
//! *partition point* of such a chain dominates latency and reliability.
//! This module defines the workload side: a [`StageGraph`] is a linear
//! chain of [`Stage`]s whose per-stage deadlines are derived from the
//! end-to-end deadline via `deadline_slack` weights, and a task carries a
//! [`PipelineRef`] (graph handle + stage index + planned drone prefix)
//! through the engine. Mechanics live in `platform.rs`/`cluster.rs`
//! (stage completion enqueues the successor at its placed tier, charging
//! the drone↔edge wireless link through `net.rs` when the handoff leaves
//! the drone); the partition decision lives in the schedulers
//! (stage-aware κ via [`chain_util_cloud`], fixed cuts via
//! [`crate::policy::PipelineCut`]).
//!
//! Single-stage graphs degenerate to today's engine bit-identically:
//! the stage deadline equals the end-to-end deadline, the payload is the
//! raw segment, and [`chain_util_cloud`] returns exactly the profile's
//! γᶜ (pinned by `tests/sweep_parity.rs`).

use std::sync::Arc;

use crate::model::{DnnKind, ModelProfile};
use crate::time::Micros;

/// One stage of a split-DNN chain.
#[derive(Clone, Debug)]
pub struct Stage {
    /// Which DNN runs this stage (its [`ModelProfile`] supplies the
    /// service times and κ/κ̂ costs; the *final* stage's β is the chain's
    /// benefit).
    pub kind: DnnKind,
    /// Share of the end-to-end deadline budgeted to this stage (the
    /// weights are normalized, so any positive numbers work).
    pub deadline_slack: f64,
    /// Intermediate tensor size handed to the successor stage — the
    /// transfer payload whenever the handoff crosses a tier boundary.
    pub output_bytes: u64,
    /// May this stage run on the drone's companion computer? Early
    /// backbone layers can; late heads generally cannot.
    pub drone_capable: bool,
}

/// A linear chain of stages with one end-to-end deadline.
///
/// Per-stage deadlines are *cumulative* offsets from segment creation:
/// stage *i* must finish by `stage_deadline(i)`, and the last stage's
/// deadline is exactly the end-to-end deadline.
#[derive(Clone, Debug)]
pub struct StageGraph {
    pub name: String,
    pub stages: Vec<Stage>,
    pub e2e_deadline: Micros,
    /// Cumulative per-stage deadlines (relative to segment creation).
    offsets: Vec<Micros>,
}

impl StageGraph {
    /// Build a chain, deriving per-stage deadlines from the slack
    /// weights: stage *i*'s deadline offset is the end-to-end deadline
    /// scaled by the cumulative normalized slack through stage *i* (the
    /// final stage lands exactly on `e2e_deadline`).
    pub fn chain(name: impl Into<String>, stages: Vec<Stage>,
                 e2e_deadline: Micros) -> StageGraph {
        assert!(!stages.is_empty(), "a stage graph needs >= 1 stage");
        let total: f64 = stages.iter().map(|s| s.deadline_slack).sum();
        assert!(total > 0.0, "slack weights must be positive");
        let mut offsets = Vec::with_capacity(stages.len());
        let mut cum = 0.0;
        for (i, s) in stages.iter().enumerate() {
            cum += s.deadline_slack / total;
            offsets.push(if i + 1 == stages.len() {
                e2e_deadline
            } else {
                (e2e_deadline as f64 * cum).round() as Micros
            });
        }
        StageGraph { name: name.into(), stages, e2e_deadline, offsets }
    }

    /// Absolute-offset deadline of stage `i` (from segment creation);
    /// the last stage's equals the end-to-end deadline.
    #[inline]
    pub fn stage_deadline(&self, i: usize) -> Micros {
        self.offsets[i]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    #[inline]
    pub fn is_final(&self, i: usize) -> bool {
        i + 1 == self.stages.len()
    }

    /// The chain's output model — whose β the chain earns on completion
    /// and whose QoE window the chain's verdict lands in.
    #[inline]
    pub fn final_kind(&self) -> DnnKind {
        self.stages[self.stages.len() - 1].kind
    }
}

/// A task's position within a chain: the shared graph, the stage this
/// task executes, and the drone prefix planned at chain admission (the
/// first `drone_prefix` stages run on the drone's companion computer).
#[derive(Clone, Debug)]
pub struct PipelineRef {
    pub graph: Arc<StageGraph>,
    pub stage: usize,
    pub drone_prefix: usize,
}

impl PipelineRef {
    /// Is this the chain's final stage?
    #[inline]
    pub fn is_final(&self) -> bool {
        self.graph.is_final(self.stage)
    }
}

/// Stage-aware cloud utility γᶜ for the κ̂ ranking (§5 extended to
/// chains): the utility of sending *this* task to the cloud is the
/// remaining chain's — the final stage's β minus the κ̂ of every stage
/// still to run — not just the current stage's own γᶜ.
///
/// Non-pipeline tasks (and final stages) return exactly the profile's
/// `util_cloud()`, so the single-stage path is bit-identical to the
/// pre-pipeline engine.
pub fn chain_util_cloud(pr: Option<&PipelineRef>, profile: &ModelProfile,
                        models: &[ModelProfile]) -> f64 {
    match pr {
        None => profile.util_cloud(),
        Some(p) if p.is_final() => profile.util_cloud(),
        Some(p) => {
            let g = &p.graph;
            let benefit = models
                .iter()
                .find(|m| m.kind == g.final_kind())
                .map_or(0.0, |m| m.benefit);
            let mut util = benefit;
            for s in &g.stages[p.stage..] {
                if let Some(m) = models.iter().find(|m| m.kind == s.kind) {
                    util -= m.cost_cloud;
                }
            }
            util
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::table1;
    use crate::time::ms;

    fn three_stage() -> StageGraph {
        StageGraph::chain(
            "t",
            vec![
                Stage {
                    kind: DnnKind::Hv,
                    deadline_slack: 0.16,
                    output_bytes: 24_000,
                    drone_capable: true,
                },
                Stage {
                    kind: DnnKind::Md,
                    deadline_slack: 0.16,
                    output_bytes: 16_000,
                    drone_capable: true,
                },
                Stage {
                    kind: DnnKind::Deo,
                    deadline_slack: 0.68,
                    output_bytes: 0,
                    drone_capable: false,
                },
            ],
            ms(2_000),
        )
    }

    #[test]
    fn stage_deadlines_are_cumulative_and_end_on_e2e() {
        let g = three_stage();
        assert_eq!(g.stage_deadline(0), ms(320));
        assert_eq!(g.stage_deadline(1), ms(640));
        assert_eq!(g.stage_deadline(2), ms(2_000));
        assert_eq!(g.len(), 3);
        assert!(!g.is_final(0) && !g.is_final(1) && g.is_final(2));
        assert_eq!(g.final_kind(), DnnKind::Deo);
    }

    #[test]
    fn slack_weights_are_normalized() {
        // Un-normalized weights (sum 4) derive the same deadlines as
        // the equivalent fractions.
        let g = StageGraph::chain(
            "n",
            vec![
                Stage {
                    kind: DnnKind::Hv,
                    deadline_slack: 1.0,
                    output_bytes: 0,
                    drone_capable: false,
                },
                Stage {
                    kind: DnnKind::Deo,
                    deadline_slack: 3.0,
                    output_bytes: 0,
                    drone_capable: false,
                },
            ],
            ms(1_000),
        );
        assert_eq!(g.stage_deadline(0), ms(250));
        assert_eq!(g.stage_deadline(1), ms(1_000));
    }

    #[test]
    fn single_stage_deadline_is_the_e2e_deadline() {
        let g = StageGraph::chain(
            "s",
            vec![Stage {
                kind: DnnKind::Hv,
                deadline_slack: 1.0,
                output_bytes: 0,
                drone_capable: false,
            }],
            ms(650),
        );
        assert_eq!(g.stage_deadline(0), ms(650));
        assert!(g.is_final(0));
    }

    #[test]
    fn chain_util_cloud_matches_profile_for_plain_and_final() {
        let models = table1();
        let hv = models.iter().find(|m| m.kind == DnnKind::Hv).unwrap();
        // Non-pipeline: exactly the profile's own γᶜ.
        assert_eq!(chain_util_cloud(None, hv, &models), hv.util_cloud());
        // Final stage of a chain: same.
        let g = Arc::new(three_stage());
        let deo = models.iter().find(|m| m.kind == DnnKind::Deo).unwrap();
        let pr = PipelineRef { graph: g.clone(), stage: 2, drone_prefix: 0 };
        assert_eq!(chain_util_cloud(Some(&pr), deo, &models),
                   deo.util_cloud());
        // Intermediate stage: the remaining chain's utility — final β
        // minus every remaining stage's κ̂.
        let md = models.iter().find(|m| m.kind == DnnKind::Md).unwrap();
        let pr1 = PipelineRef { graph: g, stage: 1, drone_prefix: 0 };
        let expect = deo.benefit - md.cost_cloud - deo.cost_cloud;
        assert_eq!(chain_util_cloud(Some(&pr1), md, &models), expect);
    }
}
