//! Service-time models for the edge accelerator and the cloud FaaS (§3.2,
//! Fig. 1, Appendix A) — the calibrated substitute for Jetson + AWS Lambda
//! hardware (DESIGN.md §1).
//!
//! Calibration contract: the Table-1 `t` is the paper's *p99* edge latency
//! and `t̂` the *p95* cloud end-to-end latency. The samplers here are tuned
//! so those percentiles land on the table values under the default network,
//! keeping every JIT/feasibility decision numerically faithful.

use crate::model::{DnnKind, ModelProfile};
use crate::net::NetworkModel;
use crate::rng::Rng;
use crate::time::{ms_f, Micros};

/// z-scores used to back out medians from the tabulated percentiles.
const Z99: f64 = 2.326;
const Z95: f64 = 1.645;

// ----------------------------------------------------- shared calibration
//
// The FaaS cloud calibration is used by two samplers: the legacy
// [`CloudExecModel`] below and the warm-pool
// [`FaasBackend`](crate::cloud::FaasBackend). These constants and helpers
// are the single home of the numbers AND the formulas so a recalibration
// can never desynchronize them. Each helper draws exactly the RNG values
// its formula needs, so callers control the overall draw order (the
// legacy order is pinned by the golden tests).

/// Lognormal sigma of the FaaS compute time (wider than edge; Fig. 1b).
pub(crate) const CLOUD_SIGMA: f64 = 0.20;
/// Nominal network overhead assumed inside the Table-1 t̂ values
/// (2×40 ms latency + 38 kB at 10 MB/s ≈ 84 ms), in ms.
pub(crate) const CLOUD_NOMINAL_NET_MS: f64 = 84.0;
/// Cold-start penalty (§4 cites FaaS cold starts), in ms.
pub(crate) const CLOUD_COLD_START_MS: f64 = 900.0;
/// HTTP client timeout: ~2.5× the longest deadline (§8.3), in ms.
pub(crate) const CLOUD_TIMEOUT_MS: f64 = 2_500.0;
/// Edge containers sharing one host's uplink (§8.1 runs 7 per host).
pub(crate) const CLOUD_HOST_EDGES: usize = 7;

/// Sample the FaaS compute time: lognormal around the median backed out
/// of the profile's tabulated p95 t̂ minus the nominal network share.
pub(crate) fn sample_cloud_compute(profile: &ModelProfile, sigma: f64,
                                   nominal_net: Micros,
                                   rng: &mut Rng) -> Micros {
    let compute_p95 = profile.t_cloud.saturating_sub(nominal_net) as f64;
    let median = compute_p95 / (sigma * Z95).exp();
    rng.lognormal(median.max(1.0), sigma) as Micros
}

/// Cold-start penalty with the §4 jitter: `cold_start × U[0.6, 1.4)`.
pub(crate) fn sample_cold_start(cold_start: Micros,
                                rng: &mut Rng) -> Micros {
    (cold_start as f64 * rng.range_f64(0.6, 1.4)) as Micros
}

/// Effective transfer payload on the shared host uplink: `concurrent`
/// in-flight transfers across `host_edges` peer stations shrink each
/// transfer's bandwidth share (§8.6), modeled as a payload multiplier.
pub(crate) fn shared_uplink_bytes(bytes: u64, concurrent: usize,
                                  host_edges: usize) -> u64 {
    bytes * (1 + concurrent * host_edges) as u64
}

// ------------------------------------------------ graceful degradation

/// A degraded ("lite") variant of one DNN: a cheaper checkpoint of the
/// same task — fewer parameters, lower input resolution — traded for
/// output quality. Used by the resilience layer's overload controller
/// ([`crate::resilience::DegradeController`]): under queue pressure the
/// edge swaps to the lite checkpoint, finishing in
/// `time_factor × t` and earning `utility_discount × γ` on success.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LiteVariant {
    /// Execution-time multiplier vs. the full model (< 1).
    pub time_factor: f64,
    /// Success-utility multiplier vs. the full model (< 1).
    pub utility_discount: f64,
}

/// The lite-variant profile per [`DnnKind`]. The heavy models (YOLOv8m
/// crowd density, Monodepth2 depth) have the most to shed — swapping to
/// their nano-class checkpoints more than halves the latency — while the
/// already-nano detectors gain less and lose less.
pub fn lite_variant(kind: DnnKind) -> LiteVariant {
    let (time_factor, utility_discount) = match kind {
        // YOLOv8n-class detectors: already small; modest shrink.
        DnnKind::Hv | DnnKind::Dev => (0.75, 0.92),
        // SSD mask detection / ResNet-18 pose: mid-size backbones.
        DnnKind::Md | DnnKind::Bp => (0.70, 0.90),
        // YOLOv8m crowd density / Monodepth2 depth: the heavy pair.
        DnnKind::Cd | DnnKind::Deo => (0.55, 0.82),
    };
    LiteVariant { time_factor, utility_discount }
}

/// Edge accelerator service-time model: tight lognormal whose p99 equals
/// the profile's `t_edge` (Fig. 1a shows low variance — the edge has no
/// network in the path and runs single-threaded).
///
/// Two regimes:
/// * `sigma > 0` — benchmark-calibrated lognormal (Table-1 studies).
/// * `sigma == 0` — the §8.7 *sleep semantics*: the task takes exactly its
///   nominal duration **plus** a uniform platform overhead in
///   `[overhead.0, overhead.1]` (thread wakeups, queue polling, GC — slop
///   the paper's Java platform pays but its scheduler's expected times do
///   not include). This drift is what makes edge-queued tasks expire and
///   gives GEMS its rescue window (Fig. 14/15).
#[derive(Clone, Debug)]
pub struct EdgeExecModel {
    pub sigma: f64,
    pub overhead: (Micros, Micros),
}

impl Default for EdgeExecModel {
    /// σ = 0.22: Table 1's `t` is the p99 averaged over the 1- and
    /// 3-client benchmark scenarios (Appendix A), so the typical draw sits
    /// well below it — the slack pool that work stealing (§5.3) exploits.
    fn default() -> Self {
        EdgeExecModel { sigma: 0.22, overhead: (0, 0) }
    }
}

impl EdgeExecModel {
    /// The §8.7 sleep-function regime (see struct docs).
    pub fn sleep_semantics() -> Self {
        EdgeExecModel { sigma: 0.0, overhead: (ms_f(5.0), ms_f(45.0)) }
    }

    /// Sample an actual execution duration t̄ᵢʲ for this model's task.
    pub fn sample(&self, profile: &ModelProfile, rng: &mut Rng) -> Micros {
        if self.sigma == 0.0 {
            let (lo, hi) = self.overhead;
            let oh = if hi > lo {
                lo + (rng.f64() * (hi - lo) as f64) as Micros
            } else {
                lo
            };
            return profile.t_edge + oh;
        }
        let median = profile.t_edge as f64 / (self.sigma * Z99).exp();
        rng.lognormal(median, self.sigma) as Micros
    }
}

/// Drone companion-computer service-time model for split-DNN pipeline
/// prefixes (see [`crate::pipeline`]): the early backbone layers run on a
/// lighter accelerator, modeled as a constant slowdown of the profile's
/// edge p99 with the same lognormal shape as [`EdgeExecModel`]. Only
/// tasks carrying a `PipelineRef` with a planned drone prefix ever sample
/// this, so non-pipeline runs draw nothing from it (bit-identity).
#[derive(Clone, Debug)]
pub struct DroneExecModel {
    /// Companion-computer slowdown vs. the edge accelerator (p99 ratio).
    pub slowdown: f64,
    /// Lognormal sigma; 0 collapses to the exact p99 (deterministic).
    pub sigma: f64,
}

impl Default for DroneExecModel {
    /// 2× the edge p99 — between the paper's Jetson Nano edge and a
    /// typical companion-computer class device — with the edge's σ.
    fn default() -> Self {
        DroneExecModel { slowdown: 2.0, sigma: 0.22 }
    }
}

impl DroneExecModel {
    /// Expected (p99) duration of one stage on the drone — what the
    /// prefix planner budgets against per-stage deadlines.
    pub fn expected(&self, profile: &ModelProfile) -> Micros {
        (profile.t_edge as f64 * self.slowdown).round() as Micros
    }

    /// Sample an actual on-drone execution duration.
    pub fn sample(&self, profile: &ModelProfile, rng: &mut Rng) -> Micros {
        let p99 = self.expected(profile);
        if self.sigma == 0.0 {
            return p99;
        }
        let median = p99 as f64 / (self.sigma * Z99).exp();
        rng.lognormal(median, self.sigma) as Micros
    }
}

/// Cloud FaaS service-time model: per-invocation compute sample + cold
/// starts + network transfer via the pluggable [`NetworkModel`].
pub struct CloudExecModel {
    pub net: Box<dyn NetworkModel>,
    /// Lognormal sigma of the FaaS compute time (wider than edge; Fig. 1b).
    pub sigma: f64,
    /// Nominal network overhead assumed *inside* the Table-1 t̂ values
    /// (2×40 ms latency + 38 kB at 10 MB/s ≈ 84 ms). The compute median is
    /// backed out by subtracting this.
    pub nominal_net: Micros,
    /// Cold-start penalty and probability (§4 cites FaaS cold starts).
    pub cold_start: Micros,
    pub cold_prob: f64,
    /// Per-model warm state: first invocation is always cold.
    warm: [bool; DnnKind::COUNT],
    /// HTTP client timeout: the platform never waits longer than ~2.5× the
    /// longest deadline (the paper observes WAN timeouts for several tasks
    /// at 4D loads; timed-out requests yield no usable output).
    pub timeout: Micros,
    /// Edge containers sharing this host's uplink (§8.1 runs 7 per host);
    /// concurrent transfers across them contend for the WAN bandwidth —
    /// the mechanism behind the ≈60% CLD completion at 4D loads (§8.3) and
    /// the weak-scaling bandwidth ceiling (§8.6).
    pub host_edges: usize,
}

impl CloudExecModel {
    pub fn new(net: Box<dyn NetworkModel>) -> Self {
        CloudExecModel {
            net,
            sigma: CLOUD_SIGMA,
            nominal_net: ms_f(CLOUD_NOMINAL_NET_MS),
            cold_start: ms_f(CLOUD_COLD_START_MS),
            cold_prob: 0.002,
            warm: [false; DnnKind::COUNT],
            timeout: ms_f(CLOUD_TIMEOUT_MS),
            host_edges: CLOUD_HOST_EDGES,
        }
    }

    /// Sample the actual end-to-end duration t̂ᵢʲ of a cloud invocation at
    /// virtual time `now`, with `concurrent` transfers already in flight on
    /// this edge. Returns `(duration, timed_out)`.
    pub fn sample(&mut self, profile: &ModelProfile, now: Micros, bytes: u64,
                  concurrent: usize, rng: &mut Rng) -> (Micros, bool) {
        let mut d =
            sample_cloud_compute(profile, self.sigma, self.nominal_net, rng);
        // Uplink contention: the host's WAN bandwidth is shared by all
        // edges' in-flight transfers (this edge is representative of its
        // host peers). Effective per-transfer share shrinks accordingly,
        // which at CLD-style offload rates snowballs into deadline misses.
        let payload = shared_uplink_bytes(bytes, concurrent, self.host_edges);
        d += self.net.transfer_time(now, payload, rng);
        let idx = profile.kind.index();
        if !self.warm[idx] || rng.chance(self.cold_prob) {
            d += sample_cold_start(self.cold_start, rng);
            self.warm[idx] = true;
        }
        if d >= self.timeout {
            (self.timeout, true)
        } else {
            (d, false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::time::{ms, to_ms};

    fn pctile(xs: &mut [f64], p: f64) -> f64 {
        xs.sort_by(f64::total_cmp);
        xs[((xs.len() - 1) as f64 * p) as usize]
    }

    #[test]
    fn edge_p99_matches_table() {
        let m = &table1()[0]; // HV: t = 174 ms
        let em = EdgeExecModel::default();
        let mut rng = Rng::new(1);
        let mut xs: Vec<f64> = (0..40_000)
            .map(|_| to_ms(em.sample(m, &mut rng)))
            .collect();
        let p99 = pctile(&mut xs, 0.99);
        assert!((p99 - 174.0).abs() < 12.0, "p99 = {p99}");
        // And the typical draw is *below* the p99 estimate — the slack the
        // work-stealing heuristic exploits (§5.3).
        let p50 = pctile(&mut xs, 0.50);
        assert!(p50 < 174.0 * 0.85, "p50 = {p50}");
    }

    #[test]
    fn drone_p99_is_slowdown_times_edge() {
        let m = &table1()[0]; // HV: t = 174 ms
        let dm = DroneExecModel::default();
        assert_eq!(dm.expected(m), ms(348));
        let mut rng = Rng::new(5);
        let mut xs: Vec<f64> = (0..40_000)
            .map(|_| to_ms(dm.sample(m, &mut rng)))
            .collect();
        let p99 = pctile(&mut xs, 0.99);
        assert!((p99 - 348.0).abs() < 24.0, "p99 = {p99}");
        // sigma = 0 collapses to the exact p99.
        let det = DroneExecModel { slowdown: 2.0, sigma: 0.0 };
        assert_eq!(det.sample(m, &mut rng), ms(348));
    }

    #[test]
    fn cloud_p95_matches_table_warm() {
        let m = &table1()[0]; // HV: t̂ = 398 ms
        let mut cm = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 10.0e6,
        }));
        cm.cold_prob = 0.0;
        let mut rng = Rng::new(2);
        let _ = cm.sample(m, 0, 38_000, 0, &mut rng); // warm it up (cold draw)
        let mut xs: Vec<f64> = (0..40_000)
            .map(|_| to_ms(cm.sample(m, 0, 38_000, 0, &mut rng).0))
            .collect();
        let p95 = pctile(&mut xs, 0.95);
        assert!((p95 - 398.0).abs() < 25.0, "p95 = {p95}");
    }

    #[test]
    fn first_invocation_is_cold() {
        let m = &table1()[0];
        let mut cm = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 10.0e6,
        }));
        cm.cold_prob = 0.0;
        let mut rng = Rng::new(3);
        let (first, _) = cm.sample(m, 0, 38_000, 0, &mut rng);
        let (second, _) = cm.sample(m, 0, 38_000, 0, &mut rng);
        assert!(first > second + ms(300), "cold {first} warm {second}");
    }

    #[test]
    fn timeout_boundary_is_inclusive() {
        // Pin the `(timeout, true)` edge: a draw landing EXACTLY on the
        // timeout is clamped and flagged (`d >= timeout`), one microsecond
        // of headroom is not. sigma = 0 makes the lognormal collapse to
        // its median, so the warm duration is exactly computable:
        // (398 − 84) ms compute + 2×40 ms latency + 38 kB / 10 MB/s.
        let m = &table1()[0];
        let exact = ms(398 - 84) + ms(80) + 3_800;
        for (timeout, want_flag) in
            [(exact, true), (exact + 1, false), (exact - 1, true)]
        {
            let mut cm = CloudExecModel::new(Box::new(ConstantNet {
                latency: ms(40),
                bandwidth: 10.0e6,
            }));
            cm.sigma = 0.0;
            cm.cold_prob = 0.0;
            cm.cold_start = 0;
            cm.timeout = timeout;
            let mut rng = Rng::new(6);
            let (d, to) = cm.sample(m, 0, 38_000, 0, &mut rng);
            assert_eq!(to, want_flag, "timeout {timeout}");
            assert_eq!(d, if want_flag { timeout } else { exact });
        }
    }

    #[test]
    fn cold_start_jitter_stays_in_range_bounds() {
        // Pin the cold-start `range_f64(0.6, 1.4)` jitter: with sigma 0
        // and a constant network, every draw is warm-duration + jitter ×
        // cold_start, so the added penalty must stay in [0.6, 1.4) and
        // actually exercise both halves of the range.
        let m = &table1()[0];
        let mut cm = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 10.0e6,
        }));
        cm.sigma = 0.0;
        cm.cold_prob = 1.0; // every invocation re-colds
        cm.timeout = ms(1_000_000);
        let warm = ms(398 - 84) + ms(80) + 3_800;
        let mut rng = Rng::new(7);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for _ in 0..2_000 {
            let (d, to) = cm.sample(m, 0, 38_000, 0, &mut rng);
            assert!(!to);
            let jitter = (d - warm) as f64 / cm.cold_start as f64;
            assert!((0.6..1.4).contains(&jitter), "jitter {jitter}");
            lo = lo.min(jitter);
            hi = hi.max(jitter);
        }
        assert!(lo < 0.7, "lower half unexercised: min {lo}");
        assert!(hi > 1.3, "upper half unexercised: max {hi}");
    }

    #[test]
    fn lite_variants_are_strict_discounts_and_heaviest_shed_most() {
        use crate::model::DnnKind;
        for kind in DnnKind::ALL {
            let v = lite_variant(kind);
            assert!(v.time_factor > 0.0 && v.time_factor < 1.0,
                    "{kind:?} time_factor {}", v.time_factor);
            assert!(v.utility_discount > 0.0 && v.utility_discount < 1.0,
                    "{kind:?} discount {}", v.utility_discount);
        }
        // The heavy models shed the most time (that is the point of the
        // downshift) and pay the largest quality discount for it.
        assert!(lite_variant(DnnKind::Cd).time_factor
                < lite_variant(DnnKind::Hv).time_factor);
        assert!(lite_variant(DnnKind::Deo).utility_discount
                < lite_variant(DnnKind::Md).utility_discount);
    }

    #[test]
    fn timeout_is_flagged() {
        let m = &table1()[0];
        let mut cm = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 10.0e6,
        }));
        cm.timeout = ms(100); // everything times out
        let mut rng = Rng::new(4);
        let (d, to) = cm.sample(m, 0, 38_000, 0, &mut rng);
        assert!(to);
        assert_eq!(d, ms(100));
    }
}
