//! Observability layer: task-lifecycle tracing, O(1)-memory streaming
//! metrics, and trace writers.
//!
//! Three pieces, all opt-in and all pinned bit-identical to the bare
//! engine when unused (`tests/observability.rs`):
//!
//! * **Tracing** — [`TraceSink`] receives typed [`TraceEvent`]s from the
//!   hook points in `platform.rs` / `cluster.rs`: one event per lifecycle
//!   transition (generate → admit/enqueue → dispatch → … → finalize).
//!   The engine holds an `Option<TraceHandle>`; `None` (the default)
//!   constructs nothing on the hot path. [`VecSink`] buffers in memory
//!   (tests, conservation checks), [`JsonlSink`] streams one JSON object
//!   per line, [`ChromeSink`] writes the Chrome trace-event JSON array
//!   that Perfetto / `chrome://tracing` load directly
//!   (`simulate --trace FILE --trace-format jsonl|chrome`).
//! * **[`LogHistogram`]** — fixed-bucket log-scale latency histogram
//!   (1% bucket growth ⇒ ≤ 0.5% relative error at the geometric bucket
//!   midpoint) replacing the unbounded per-task `Vec<f64>` sample logs
//!   in [`crate::metrics::ModelStats`] behind the same rank-selection
//!   `percentile` semantics.
//! * **[`Timeline`]** — windowed time-series fold: completions, drops,
//!   utility, uplink wait and queue-depth samples bucketed into fixed
//!   virtual-time windows. Memory is O(duration / window), independent
//!   of task count; rendered by `experiment timeline`.

use std::io::Write;
use std::sync::{Arc, Mutex};

use crate::model::{DnnKind, Resource};
use crate::report::JsonValue;
use crate::task::{DropReason, Fate, TaskId, TaskOutcome};
use crate::time::Micros;

// ---------------------------------------------------------------- events

/// One task-lifecycle (or engine-state) transition.
///
/// `edge` is the station whose engine emitted the event — for a
/// federated steal the departure carries the victim edge and the arrival
/// the thief, so a task's migration is reconstructible from its events.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub at: Micros,
    pub edge: u32,
    pub kind: TraceKind,
}

/// Typed event payloads. Task-scoped variants carry the [`TaskId`];
/// engine-scoped variants (breaker, crash) are instantaneous markers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceKind {
    /// A segment produced this task at the base station (`§3.3`).
    Generate { task: TaskId, model: DnnKind, drone: u32 },
    /// The task entered the scheduler's admission test.
    Admit { task: TaskId },
    /// The task was queued for `queue` (edge HPF queue or cloud ledger).
    Enqueue { task: TaskId, queue: Resource },
    /// Execution started on `on` (edge slot, cloud invocation, or the
    /// drone's companion computer for pipeline stage 0).
    Dispatch { task: TaskId, on: Resource },
    /// Federation: the task left its home edge toward a sibling.
    StealDepart { task: TaskId },
    /// Federation: the task arrived at the thief edge over the LAN.
    FedArrive { task: TaskId },
    /// A drone re-homed to this edge (dynamic router handover).
    Handover { drone: u32 },
    /// Resilience: a speculative duplicate was launched for `task`.
    HedgeFire { task: TaskId },
    /// Resilience: the hedge duplicate beat the primary.
    HedgeWin { task: TaskId },
    /// Resilience: the losing leg of a resolved hedge pair was cancelled.
    HedgeCancel { task: TaskId },
    /// Resilience: the cloud circuit breaker tripped Closed→Open.
    BreakerTrip,
    /// Resilience: a half-open probe dispatch was allowed through.
    BreakerProbe,
    /// Fault injection: this station crashed.
    Crash,
    /// Fault injection: this station rebooted.
    Recover,
    /// Fault injection: the task was lost to a node failure.
    FaultLoss { task: TaskId },
    /// Terminal transition — exactly once per generated task
    /// (`trace_conservation` in `tests/invariants.rs`).
    Finalize { task: TaskId, fate: Fate, utility: f64 },
}

impl TraceKind {
    /// Stable serialization name (JSONL `ev` field, Chrome event name).
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Generate { .. } => "generate",
            TraceKind::Admit { .. } => "admit",
            TraceKind::Enqueue { .. } => "enqueue",
            TraceKind::Dispatch { .. } => "dispatch",
            TraceKind::StealDepart { .. } => "steal-depart",
            TraceKind::FedArrive { .. } => "fed-arrive",
            TraceKind::Handover { .. } => "handover",
            TraceKind::HedgeFire { .. } => "hedge-fire",
            TraceKind::HedgeWin { .. } => "hedge-win",
            TraceKind::HedgeCancel { .. } => "hedge-cancel",
            TraceKind::BreakerTrip => "breaker-trip",
            TraceKind::BreakerProbe => "breaker-probe",
            TraceKind::Crash => "crash",
            TraceKind::Recover => "recover",
            TraceKind::FaultLoss { .. } => "fault-loss",
            TraceKind::Finalize { .. } => "finalize",
        }
    }

    /// The task this event concerns, when task-scoped.
    pub fn task(&self) -> Option<TaskId> {
        match self {
            TraceKind::Generate { task, .. }
            | TraceKind::Admit { task }
            | TraceKind::Enqueue { task, .. }
            | TraceKind::Dispatch { task, .. }
            | TraceKind::StealDepart { task }
            | TraceKind::FedArrive { task }
            | TraceKind::HedgeFire { task }
            | TraceKind::HedgeWin { task }
            | TraceKind::HedgeCancel { task }
            | TraceKind::FaultLoss { task }
            | TraceKind::Finalize { task, .. } => Some(*task),
            TraceKind::Handover { .. }
            | TraceKind::BreakerTrip
            | TraceKind::BreakerProbe
            | TraceKind::Crash
            | TraceKind::Recover => None,
        }
    }
}

/// Stable lowercase name for a [`Resource`].
pub fn resource_name(r: Resource) -> &'static str {
    match r {
        Resource::Edge => "edge",
        Resource::Cloud => "cloud",
        Resource::Drone => "drone",
    }
}

/// Stable lowercase name for a [`DropReason`].
pub fn reason_name(r: DropReason) -> &'static str {
    match r {
        DropReason::Infeasible => "infeasible",
        DropReason::NegativeCloudUtility => "negative-utility",
        DropReason::JitExpired => "jit-expired",
        DropReason::TriggerExpired => "trigger-expired",
        DropReason::Shed => "shed",
        DropReason::Timeout => "timeout",
        DropReason::Throttled => "throttled",
        DropReason::NodeFailure => "node-failure",
    }
}

// ----------------------------------------------------------------- sinks

/// Receiver of trace events. Implementations must be `Send`: a shared
/// sink crosses thread boundaries with the platforms the parallel sweep
/// runner moves between workers.
pub trait TraceSink: Send {
    fn emit(&mut self, ev: &TraceEvent);
    /// Flush / close the underlying writer (end of run).
    fn finish(&mut self) {}
}

/// A sink shared by every edge of a cluster.
pub type SharedSink = Arc<Mutex<dyn TraceSink>>;

/// Per-edge handle onto a shared sink. The engine stores
/// `Option<TraceHandle>`; emission is two loads and a branch when absent.
#[derive(Clone)]
pub struct TraceHandle {
    edge: u32,
    sink: SharedSink,
}

impl std::fmt::Debug for TraceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceHandle(edge {})", self.edge)
    }
}

impl TraceHandle {
    pub fn new(edge: u32, sink: SharedSink) -> TraceHandle {
        TraceHandle { edge, sink }
    }

    /// The same sink re-badged for another edge (cluster construction).
    pub fn for_edge(&self, edge: u32) -> TraceHandle {
        TraceHandle { edge, sink: Arc::clone(&self.sink) }
    }

    pub fn emit(&self, at: Micros, kind: TraceKind) {
        self.sink
            .lock()
            .expect("trace sink poisoned")
            .emit(&TraceEvent { at, edge: self.edge, kind });
    }

    /// Flush the underlying sink (once, after the run).
    pub fn finish(&self) {
        self.sink.lock().expect("trace sink poisoned").finish();
    }
}

/// In-memory sink: buffers every event (tests, conservation folds).
#[derive(Debug, Default)]
pub struct VecSink {
    pub events: Vec<TraceEvent>,
}

impl TraceSink for VecSink {
    fn emit(&mut self, ev: &TraceEvent) {
        self.events.push(*ev);
    }
}

/// The event as a JSON object — the JSONL line and the Chrome `args`
/// payload share this shape.
pub fn event_json(ev: &TraceEvent) -> JsonValue {
    let mut kvs = vec![
        ("at_us".into(), JsonValue::Num(ev.at as f64)),
        ("edge".into(), JsonValue::Num(ev.edge as f64)),
        ("ev".into(), JsonValue::Str(ev.kind.name().into())),
    ];
    if let Some(task) = ev.kind.task() {
        kvs.push(("task".into(), JsonValue::Num(task as f64)));
    }
    match ev.kind {
        TraceKind::Generate { model, drone, .. } => {
            kvs.push(("model".into(), JsonValue::Str(model.name().into())));
            kvs.push(("drone".into(), JsonValue::Num(drone as f64)));
        }
        TraceKind::Enqueue { queue, .. } => {
            kvs.push((
                "queue".into(),
                JsonValue::Str(resource_name(queue).into()),
            ));
        }
        TraceKind::Dispatch { on, .. } => {
            kvs.push(("on".into(), JsonValue::Str(resource_name(on).into())));
        }
        TraceKind::Handover { drone } => {
            kvs.push(("drone".into(), JsonValue::Num(drone as f64)));
        }
        TraceKind::Finalize { fate, utility, .. } => {
            match fate {
                Fate::Completed(r) => {
                    kvs.push((
                        "fate".into(),
                        JsonValue::Str("completed".into()),
                    ));
                    kvs.push((
                        "on".into(),
                        JsonValue::Str(resource_name(r).into()),
                    ));
                }
                Fate::Missed(r) => {
                    kvs.push(("fate".into(), JsonValue::Str("missed".into())));
                    kvs.push((
                        "on".into(),
                        JsonValue::Str(resource_name(r).into()),
                    ));
                }
                Fate::Dropped(reason) => {
                    kvs.push((
                        "fate".into(),
                        JsonValue::Str("dropped".into()),
                    ));
                    kvs.push((
                        "reason".into(),
                        JsonValue::Str(reason_name(reason).into()),
                    ));
                }
            }
            kvs.push(("utility".into(), JsonValue::Num(utility)));
        }
        _ => {}
    }
    JsonValue::Obj(kvs)
}

/// Streaming JSONL writer: one compact JSON object per line.
pub struct JsonlSink<W: Write + Send> {
    w: W,
}

impl<W: Write + Send> JsonlSink<W> {
    pub fn new(w: W) -> JsonlSink<W> {
        JsonlSink { w }
    }
}

impl<W: Write + Send> TraceSink for JsonlSink<W> {
    fn emit(&mut self, ev: &TraceEvent) {
        let line = event_json(ev).dump();
        let _ = writeln!(self.w, "{line}");
    }

    fn finish(&mut self) {
        let _ = self.w.flush();
    }
}

/// Chrome trace-event writer (the JSON-array flavor Perfetto and
/// `chrome://tracing` load directly). Each task renders as a nestable
/// async span (`ph:"b"` at generate, `ph:"e"` at finalize, `id` = task
/// id) on a process track per edge; every other event is an instant
/// marker. `ts` is virtual microseconds — the trace's time axis is the
/// simulation clock.
pub struct ChromeSink<W: Write + Send> {
    w: W,
    first: bool,
}

impl<W: Write + Send> ChromeSink<W> {
    pub fn new(mut w: W) -> ChromeSink<W> {
        let _ = w.write_all(b"[");
        ChromeSink { w, first: true }
    }

    fn entry(&mut self, obj: JsonValue) {
        let sep = if self.first { "\n" } else { ",\n" };
        self.first = false;
        let _ = write!(self.w, "{sep}{}", obj.dump());
    }
}

impl<W: Write + Send> TraceSink for ChromeSink<W> {
    fn emit(&mut self, ev: &TraceEvent) {
        let (ph, name) = match ev.kind {
            TraceKind::Generate { .. } => ("b", "task"),
            TraceKind::Finalize { .. } => ("e", "task"),
            _ => ("i", ev.kind.name()),
        };
        let mut kvs = vec![
            ("name".into(), JsonValue::Str(name.into())),
            ("cat".into(), JsonValue::Str("task".into())),
            ("ph".into(), JsonValue::Str(ph.into())),
            ("ts".into(), JsonValue::Num(ev.at as f64)),
            ("pid".into(), JsonValue::Num(ev.edge as f64)),
            ("tid".into(), JsonValue::Num(0.0)),
        ];
        if let Some(task) = ev.kind.task() {
            kvs.push(("id".into(), JsonValue::Num(task as f64)));
        }
        if ph == "i" {
            // Instant scope: process track.
            kvs.push(("s".into(), JsonValue::Str("p".into())));
        }
        kvs.push(("args".into(), event_json(ev)));
        self.entry(JsonValue::Obj(kvs));
    }

    fn finish(&mut self) {
        let _ = self.w.write_all(b"\n]\n");
        let _ = self.w.flush();
    }
}

// ------------------------------------------------------------- histogram

/// Per-bucket growth factor: 1% wide log buckets keep the rank-selected
/// percentile within ±0.5% of the exact sample at the geometric bucket
/// midpoint (`histogram_percentiles_track_exact_samples`).
const HIST_GROWTH: f64 = 1.01;
/// Lowest resolvable sample: one virtual-clock tick, in milliseconds.
const HIST_MIN: f64 = 0.001;

/// Fixed-bucket log-scale histogram over positive millisecond samples.
///
/// Memory is O(log(range)/log(1.01)) ≈ 2.1 k buckets for the full
/// 1 µs – 1000 s span — grown lazily, bounded, and independent of the
/// sample count, unlike the `Vec<f64>` per-task logs it replaces.
/// Exact `min`/`max` are tracked so the p0/p100 extremes are exact and
/// every interior percentile is clamped into the observed range.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    n: u64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram {
            counts: Vec::new(),
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    fn bucket_of(v: f64) -> usize {
        if v <= HIST_MIN {
            return 0;
        }
        ((v / HIST_MIN).ln() / HIST_GROWTH.ln()).floor() as usize
    }

    /// Geometric midpoint of bucket `i` (the representative value).
    fn bucket_mid(i: usize) -> f64 {
        HIST_MIN * HIST_GROWTH.powf(i as f64 + 0.5)
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = Self::bucket_of(v);
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
        self.n += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Fold another histogram in (cluster-level aggregation).
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Rank-selected percentile with the same semantics as the exact
    /// [`crate::metrics::percentile`]: rank `round((n−1)·p)`, NaN when
    /// empty. The returned value is the rank's bucket midpoint clamped
    /// to the observed `[min, max]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let rank = ((self.n - 1) as f64 * p.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

// -------------------------------------------------------------- timeline

/// One fixed window's fold of the run (all counters are totals within
/// the window).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowStats {
    /// Tasks generated (admitted to the platform) in the window.
    pub generated: u64,
    /// Tasks completed within deadline.
    pub completed: u64,
    /// Tasks executed but stale (deadline missed).
    pub missed: u64,
    /// Tasks dropped (any [`DropReason`]).
    pub dropped: u64,
    /// QoS utility accrued by tasks finalized in the window.
    pub utility: f64,
    /// Total shared-uplink wait charged in the window (µs).
    pub uplink_wait: Micros,
    /// Sum of queue-depth samples (edge + cloud queue lengths)…
    pub queue_depth_sum: u64,
    /// …over this many samples (one per generated task).
    pub queue_samples: u64,
}

impl WindowStats {
    /// Mean sampled queue depth, NaN when unsampled.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_samples == 0 {
            f64::NAN
        } else {
            self.queue_depth_sum as f64 / self.queue_samples as f64
        }
    }
}

/// O(1)-memory-per-task windowed time series: everything folds into
/// `duration / window` fixed [`WindowStats`] cells keyed by virtual time.
#[derive(Clone, Debug, PartialEq)]
pub struct Timeline {
    window: Micros,
    windows: Vec<WindowStats>,
}

impl Timeline {
    pub fn new(window: Micros) -> Timeline {
        assert!(window > 0, "zero-width timeline window");
        Timeline { window, windows: Vec::new() }
    }

    pub fn window(&self) -> Micros {
        self.window
    }

    pub fn windows(&self) -> &[WindowStats] {
        &self.windows
    }

    fn cell(&mut self, at: Micros) -> &mut WindowStats {
        let idx = (at / self.window) as usize;
        if idx >= self.windows.len() {
            self.windows.resize(idx + 1, WindowStats::default());
        }
        &mut self.windows[idx]
    }

    /// Fold a terminal task outcome into its window (keyed by the
    /// decision time `at`, like every latency metric in the repo).
    pub fn observe_outcome(&mut self, o: &TaskOutcome) {
        let w = self.cell(o.at);
        match o.fate {
            Fate::Completed(_) => w.completed += 1,
            Fate::Missed(_) => w.missed += 1,
            Fate::Dropped(_) => w.dropped += 1,
        }
        w.utility += o.utility;
    }

    /// A task was generated at `at`; `queue_depth` samples the edge +
    /// cloud queue lengths at the arrival instant (before admission
    /// routes the task).
    pub fn observe_generated(&mut self, at: Micros, queue_depth: usize) {
        let w = self.cell(at);
        w.generated += 1;
        w.queue_depth_sum += queue_depth as u64;
        w.queue_samples += 1;
    }

    /// Shared-uplink wait charged at `at`.
    pub fn observe_uplink_wait(&mut self, at: Micros, wait: Micros) {
        self.cell(at).uplink_wait += wait;
    }

    /// Merge a sibling edge's timeline (cluster-level view).
    pub fn merge(&mut self, other: &Timeline) {
        assert_eq!(self.window, other.window, "timeline window mismatch");
        if other.windows.len() > self.windows.len() {
            self.windows.resize(other.windows.len(), WindowStats::default());
        }
        for (i, w) in other.windows.iter().enumerate() {
            let s = &mut self.windows[i];
            s.generated += w.generated;
            s.completed += w.completed;
            s.missed += w.missed;
            s.dropped += w.dropped;
            s.utility += w.utility;
            s.uplink_wait += w.uplink_wait;
            s.queue_depth_sum += w.queue_depth_sum;
            s.queue_samples += w.queue_samples;
        }
    }
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::rng::Rng;

    #[test]
    fn histogram_matches_exact_percentiles_within_half_percent() {
        let mut rng = Rng::new(0x0B5E_5EED);
        let mut xs = Vec::new();
        let mut h = LogHistogram::default();
        // Log-uniform samples over 0.1 ms – 10 s: the span exec/cloud
        // latencies actually cover.
        for _ in 0..5000 {
            let v = 0.1 * 10f64.powf(rng.f64() * 5.0);
            xs.push(v);
            h.record(v);
        }
        assert_eq!(h.count(), 5000);
        for p in [0.0, 0.05, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let exact = metrics::percentile(&xs, p);
            let approx = h.percentile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(
                rel <= 0.005,
                "p{p}: exact {exact} vs hist {approx} (rel {rel})"
            );
        }
    }

    #[test]
    fn histogram_extremes_are_exact() {
        let mut h = LogHistogram::default();
        for v in [3.25, 17.0, 940.0] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), 3.25);
        assert_eq!(h.percentile(1.0), 940.0);
        // Single sample: every percentile is that sample.
        let mut one = LogHistogram::default();
        one.record(42.0);
        assert_eq!(one.percentile(0.5), 42.0);
    }

    #[test]
    fn histogram_empty_is_nan_and_default_allocates_nothing() {
        let h = LogHistogram::default();
        assert!(h.percentile(0.5).is_nan());
        assert!(h.is_empty());
        assert_eq!(h.counts.capacity(), 0);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let (mut a, mut b, mut both) = (
            LogHistogram::default(),
            LogHistogram::default(),
            LogHistogram::default(),
        );
        let mut rng = Rng::new(7);
        for i in 0..400 {
            let v = 0.5 + rng.f64() * 800.0;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
    }

    #[test]
    fn histogram_bucket_monotone_and_sub_tick_clamps() {
        assert_eq!(LogHistogram::bucket_of(0.0005), 0);
        assert_eq!(LogHistogram::bucket_of(HIST_MIN), 0);
        let (a, b) = (
            LogHistogram::bucket_of(10.0),
            LogHistogram::bucket_of(10.2),
        );
        assert!(b > a, "1% apart ⇒ distinct buckets ({a} vs {b})");
    }

    #[test]
    fn timeline_folds_into_fixed_windows() {
        use crate::model::Resource;
        let mut tl = Timeline::new(crate::time::secs(10));
        tl.observe_generated(0, 3);
        tl.observe_generated(9_999_999, 5);
        tl.observe_generated(10_000_000, 0);
        let mk = |at, fate| TaskOutcome {
            task_id: 1,
            model: DnnKind::Hv,
            drone: 0,
            fate,
            at,
            created_at: 0,
            exec_duration: 0,
            utility: 1.5,
            gems_rescheduled: false,
            stolen: false,
        };
        tl.observe_outcome(&mk(5_000_000, Fate::Completed(Resource::Edge)));
        tl.observe_outcome(&mk(
            25_000_000,
            Fate::Dropped(DropReason::Timeout),
        ));
        tl.observe_uplink_wait(25_000_000, 1234);
        let w = tl.windows();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].generated, 2);
        assert_eq!(w[0].completed, 1);
        assert!((w[0].mean_queue_depth() - 4.0).abs() < 1e-12);
        assert_eq!(w[1].generated, 1);
        assert_eq!(w[2].dropped, 1);
        assert_eq!(w[2].uplink_wait, 1234);
        assert!((w[2].utility - 1.5).abs() < 1e-12);
        assert!(w[1].mean_queue_depth().is_nan());
    }

    #[test]
    fn timeline_merge_is_cellwise() {
        let mut a = Timeline::new(1000);
        let mut b = Timeline::new(1000);
        a.observe_generated(500, 1);
        b.observe_generated(2500, 7);
        a.merge(&b);
        assert_eq!(a.windows().len(), 3);
        assert_eq!(a.windows()[0].generated, 1);
        assert_eq!(a.windows()[2].queue_depth_sum, 7);
    }

    fn ev(at: Micros, kind: TraceKind) -> TraceEvent {
        TraceEvent { at, edge: 0, kind }
    }

    #[test]
    fn jsonl_sink_writes_one_object_per_line() {
        let mut sink = JsonlSink::new(Vec::new());
        sink.emit(&ev(
            1000,
            TraceKind::Generate { task: 7, model: DnnKind::Cd, drone: 2 },
        ));
        sink.emit(&ev(
            2000,
            TraceKind::Finalize {
                task: 7,
                fate: Fate::Dropped(DropReason::Shed),
                utility: 0.0,
            },
        ));
        sink.finish();
        let text = String::from_utf8(sink.w).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            "{\"at_us\":1000,\"edge\":0,\"ev\":\"generate\",\"task\":7,\
             \"model\":\"cd\",\"drone\":2}"
        );
        assert!(lines[1].contains("\"reason\":\"shed\""), "{}", lines[1]);
        for l in lines {
            crate::report::parse_json(l).expect("valid JSONL line");
        }
    }

    #[test]
    fn chrome_sink_emits_a_loadable_event_array() {
        let mut sink = ChromeSink::new(Vec::new());
        sink.emit(&ev(
            1000,
            TraceKind::Generate { task: 3, model: DnnKind::Hv, drone: 0 },
        ));
        sink.emit(&ev(1500, TraceKind::HedgeFire { task: 3 }));
        sink.emit(&ev(
            9000,
            TraceKind::Finalize {
                task: 3,
                fate: Fate::Completed(Resource::Cloud),
                utility: 2.0,
            },
        ));
        sink.finish();
        let text = String::from_utf8(sink.w).unwrap();
        let parsed = crate::report::parse_json(text.trim()).unwrap();
        let JsonValue::Arr(events) = parsed else {
            panic!("expected array")
        };
        assert_eq!(events.len(), 3);
        let ph_of = |e: &JsonValue| {
            let JsonValue::Obj(kvs) = e else { panic!() };
            kvs.iter()
                .find(|(k, _)| k == "ph")
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        assert_eq!(ph_of(&events[0]), JsonValue::Str("b".into()));
        assert_eq!(ph_of(&events[1]), JsonValue::Str("i".into()));
        assert_eq!(ph_of(&events[2]), JsonValue::Str("e".into()));
    }

    #[test]
    fn vec_sink_through_a_handle_captures_edge_badging() {
        let sink = Arc::new(Mutex::new(VecSink::default()));
        let handle = TraceHandle::new(0, sink.clone());
        let h2 = handle.for_edge(3);
        handle.emit(100, TraceKind::BreakerTrip);
        h2.emit(200, TraceKind::Crash);
        let evs = &sink.lock().unwrap().events;
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].edge, 0);
        assert_eq!(evs[1].edge, 3);
        assert_eq!(evs[1].kind, TraceKind::Crash);
    }

    #[test]
    fn event_names_and_task_ids_are_stable() {
        let k = TraceKind::Enqueue { task: 9, queue: Resource::Cloud };
        assert_eq!(k.name(), "enqueue");
        assert_eq!(k.task(), Some(9));
        assert_eq!(TraceKind::BreakerTrip.task(), None);
        assert_eq!(reason_name(DropReason::NodeFailure), "node-failure");
        assert_eq!(resource_name(Resource::Drone), "drone");
    }
}
