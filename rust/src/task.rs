//! Tasks and video segments — the scheduler's unit of work (§3.3, §4).

use crate::model::{DnnKind, Resource};
use crate::pipeline::PipelineRef;
use crate::time::Micros;

/// Globally unique task id within one platform run.
pub type TaskId = u64;

/// A fixed-duration video segment received from one drone (§3.3). Only the
/// metadata travels through the scheduler; the frame tensor lives in the
/// video repository (or is synthesized on demand by the fleet emulator).
#[derive(Clone, Debug)]
pub struct VideoSegment {
    pub id: u64,
    pub drone: u32,
    /// Timestamp t′ⱼ at which the segment was created at the base station.
    pub created_at: Micros,
    /// Encoded size (the paper's 1 s segments are ≈ 38 kB) — drives the
    /// cloud transfer time under the network model.
    pub bytes: u64,
}

/// One DNN inferencing task τᵢʲ = (model μᵢ, segment vⱼ).
///
/// A split-DNN pipeline stage is a full task too: `pipeline` carries the
/// chain handle + stage index, and the deadline/payload accessors below
/// become stage-aware. `pipeline: None` is the classic single-stage task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub model: DnnKind,
    pub segment: VideoSegment,
    /// Chain position for split-DNN pipeline stages; `None` for the
    /// classic single-stage tasks (bit-identical legacy path).
    pub pipeline: Option<PipelineRef>,
}

impl Task {
    /// Absolute deadline: t′ⱼ + δᵢ for plain tasks; for a pipeline stage
    /// the per-stage deadline derived from the chain's end-to-end
    /// deadline (`t′ⱼ + stage_deadline(i)` — the slack-weighted cut of
    /// the e2e budget, see [`crate::pipeline::StageGraph`]).
    #[inline]
    pub fn absolute_deadline(&self, deadline: Micros) -> Micros {
        match &self.pipeline {
            Some(pr) => {
                self.segment.created_at + pr.graph.stage_deadline(pr.stage)
            }
            None => self.segment.created_at + deadline,
        }
    }

    /// Transfer payload when this task crosses a tier boundary: the raw
    /// segment for plain tasks and stage 0, the predecessor stage's
    /// intermediate tensor for later stages.
    #[inline]
    pub fn payload_bytes(&self) -> u64 {
        match &self.pipeline {
            Some(pr) if pr.stage > 0 => {
                pr.graph.stages[pr.stage - 1].output_bytes
            }
            _ => self.segment.bytes,
        }
    }
}

/// Terminal state of a task (drives Eqn 1 accounting and the QoE monitor).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fate {
    /// Executed and completed within its deadline.
    Completed(Resource),
    /// Executed but the output was stale (deadline expired) — still billed.
    Missed(Resource),
    /// Dropped without execution (zero utility).
    Dropped(DropReason),
}

/// Why a task was dropped (observability; the paper's schedulers drop at
/// several distinct points).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// Rejected at admission: infeasible on both edge and cloud.
    Infeasible,
    /// Negative expected utility on the cloud and no edge slot.
    NegativeCloudUtility,
    /// Just-in-time check failed at the executor.
    JitExpired,
    /// Deferred negative-utility task hit its trigger time un-stolen.
    TriggerExpired,
    /// GEMS/migration decided to shed it.
    Shed,
    /// Cloud request abandoned at the HTTP client timeout (§8.3's network
    /// timeouts); no usable output, utility 0.
    Timeout,
    /// Rejected at the FaaS account's concurrency ceiling with no retry
    /// window left before the deadline (see [`crate::cloud`]).
    Throttled,
    /// The node owning the task crashed (fault injection, see
    /// [`crate::fault`]): work in flight or queued on a failed edge that
    /// could not be relocated to a live sibling.
    NodeFailure,
}

impl DropReason {
    /// Every variant, in declaration order — drives the drop-breakdown
    /// column groups and the trace-fold conservation test.
    pub const ALL: [DropReason; 8] = [
        DropReason::Infeasible,
        DropReason::NegativeCloudUtility,
        DropReason::JitExpired,
        DropReason::TriggerExpired,
        DropReason::Shed,
        DropReason::Timeout,
        DropReason::Throttled,
        DropReason::NodeFailure,
    ];
}

/// Completion record appended to the results queue.
#[derive(Clone, Debug)]
pub struct TaskOutcome {
    pub task_id: TaskId,
    pub model: DnnKind,
    pub drone: u32,
    pub fate: Fate,
    /// When the fate was decided (completion or drop time).
    pub at: Micros,
    /// Segment creation time t′ⱼ (so end-to-end latency = at − created_at).
    pub created_at: Micros,
    /// Actual execution duration (t̄ᵢʲ or t̂ᵢʲ), zero for drops.
    pub exec_duration: Micros,
    /// QoS utility accrued by this task (Eqn 1).
    pub utility: f64,
    /// True if the task reached the executor via a GEMS reschedule.
    pub gems_rescheduled: bool,
    /// True if the task was stolen from the cloud queue by the edge.
    pub stolen: bool,
}

impl TaskOutcome {
    /// Did the task complete within its deadline?
    #[inline]
    pub fn success(&self) -> bool {
        matches!(self.fate, Fate::Completed(_))
    }

    /// Was it executed (successfully or not) on the given resource?
    pub fn ran_on(&self, r: Resource) -> bool {
        matches!(self.fate, Fate::Completed(x) | Fate::Missed(x) if x == r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::ms;

    fn seg(created: Micros) -> VideoSegment {
        VideoSegment { id: 1, drone: 0, created_at: created, bytes: 38_000 }
    }

    #[test]
    fn absolute_deadline_offsets_from_creation() {
        let t = Task {
            id: 1,
            model: DnnKind::Hv,
            segment: seg(ms(100)),
            pipeline: None,
        };
        assert_eq!(t.absolute_deadline(ms(650)), ms(750));
        assert_eq!(t.payload_bytes(), 38_000);
    }

    #[test]
    fn pipeline_stage_deadline_and_payload() {
        use crate::pipeline::{PipelineRef, Stage, StageGraph};
        use std::sync::Arc;
        let g = Arc::new(StageGraph::chain(
            "c",
            vec![
                Stage {
                    kind: DnnKind::Hv,
                    deadline_slack: 0.25,
                    output_bytes: 9_000,
                    drone_capable: true,
                },
                Stage {
                    kind: DnnKind::Deo,
                    deadline_slack: 0.75,
                    output_bytes: 0,
                    drone_capable: false,
                },
            ],
            ms(1_000),
        ));
        let mk = |stage| Task {
            id: 1,
            model: DnnKind::Hv,
            segment: seg(ms(100)),
            pipeline: Some(PipelineRef {
                graph: g.clone(),
                stage,
                drone_prefix: 0,
            }),
        };
        // Stage deadlines override the per-model δ entirely.
        assert_eq!(mk(0).absolute_deadline(ms(650)), ms(100) + ms(250));
        assert_eq!(mk(1).absolute_deadline(ms(650)), ms(100) + ms(1_000));
        // Stage 0 ships the raw segment; stage 1 the intermediate tensor.
        assert_eq!(mk(0).payload_bytes(), 38_000);
        assert_eq!(mk(1).payload_bytes(), 9_000);
    }

    #[test]
    fn outcome_predicates() {
        let mut o = TaskOutcome {
            task_id: 1,
            model: DnnKind::Hv,
            drone: 0,
            fate: Fate::Completed(Resource::Edge),
            at: 0,
            created_at: 0,
            exec_duration: 0,
            utility: 124.0,
            gems_rescheduled: false,
            stolen: false,
        };
        assert!(o.success());
        assert!(o.ran_on(Resource::Edge));
        assert!(!o.ran_on(Resource::Cloud));
        o.fate = Fate::Missed(Resource::Cloud);
        assert!(!o.success());
        assert!(o.ran_on(Resource::Cloud));
        o.fate = Fate::Dropped(DropReason::Infeasible);
        assert!(!o.ran_on(Resource::Edge) && !o.ran_on(Resource::Cloud));
    }
}
