//! Declarative experiment scenarios and the scenario registry.
//!
//! A [`Scenario`] composes the evaluation axes of §8 — workload grid,
//! policy grid, network/cloud model, edge count, seed sweep — plus the
//! beyond-paper axes the [`Workload`] builder exposes (Poisson/bursty
//! arrivals, mid-run drone churn, heterogeneous per-edge fleets and
//! hardware) into one runnable spec that returns a structured
//! [`Report`].
//!
//! The [`registry`] names every runnable experiment: the paper's
//! tables/figures (implemented in [`crate::exp`] on the same Report API)
//! and the beyond-paper scenarios defined here (`poisson`, `churn`,
//! `hetero-edges`). `ocularone experiment <id> [--format md|json]` is the
//! CLI surface; `ocularone experiment list` prints this registry.

use crate::bail;
use crate::cloud::{CloudBackend, FaasBackend, FaasConfig,
                   MultiRegionBackend};
use crate::cluster::{Cluster, ClusterMetrics, Federation, Handover};
use crate::errors::Result;
use crate::exec::CloudExecModel;
use crate::exp;
use crate::fault::{FaultSpec, FlapLink, Recovery};
use crate::fleet::{Arrival, DroneChurn, Workload};
use crate::metrics::Metrics;
use crate::model::{ModelProfile, Resource};
use crate::net::{mobility_trace, LognormalWan, TraceBandwidth,
                 TrapeziumLatency};
use crate::obs::{SharedSink, Timeline};
use crate::task::DropReason;
use crate::policy::{PipelineCut, Policy};
use crate::pool::Pool;
use crate::report::{Cell, Report, Table, Value};
use crate::resilience::ResilienceSpec;
use crate::time::{ms, ms_f, secs, Micros};

/// Stride between seeds of a sweep (a large odd constant so derived seeds
/// do not collide with the per-edge `EDGE_SEED_PHI` derivation).
pub const SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

// ------------------------------------------------------------ cloud specs

/// Declarative choice of the cloud backend / WAN model an experiment
/// runs against (see [`crate::cloud`] for the backend subsystem).
#[derive(Clone, Debug)]
pub enum CloudSpec {
    /// Calibrated nominal AWS WAN (lognormal latency + bandwidth)
    /// behind the legacy sampler — the bit-identical default path.
    NominalWan,
    /// §8.5 latency shaping: trapezium 0→400 ms ramp over the run.
    TrapeziumLatency,
    /// §8.5 bandwidth shaping: 4G mobility-trace replay for one device.
    MobilityBandwidth { device: u64 },
    /// FaaS account over the nominal WAN: per-model warm pools with
    /// `keep_alive` expiry, a `concurrency` ceiling (throttle + retry),
    /// Lambda-shaped GB-second billing. `retry_after` is the throttle
    /// backoff handed to rejected callers (the [`FaasConfig`] default,
    /// 200 ms, keeps pre-knob runs bit-identical). [`CloudSpec::build`]
    /// runs once per platform, so **each edge station holds its own
    /// account** — the ceiling, pools and bill are per edge, and an
    /// N-edge cluster has N independent accounts.
    Faas { keep_alive: Micros, concurrency: usize, retry_after: Micros },
    /// Two FaaS regions with latency-based failover: the nominal-WAN
    /// primary plus a secondary whose median latency is `extra_latency`
    /// higher; each region has its own `concurrency` ceiling (and, as
    /// with [`CloudSpec::Faas`], each edge station its own region pair).
    MultiRegion {
        keep_alive: Micros,
        concurrency: usize,
        extra_latency: Micros,
    },
}

impl CloudSpec {
    /// A FaaS account with the default 200 ms throttle backoff
    /// ([`FaasConfig::default`]); the CLI's `--retry-after` overrides it.
    pub fn faas(keep_alive: Micros, concurrency: usize) -> Self {
        CloudSpec::Faas {
            keep_alive,
            concurrency,
            retry_after: FaasConfig::default().retry_after,
        }
    }

    /// Instantiate a fresh cloud backend for one platform.
    pub fn build(&self) -> Box<dyn CloudBackend> {
        match self {
            CloudSpec::NominalWan => {
                CloudExecModel::new(Box::new(LognormalWan::default()))
                    .into()
            }
            CloudSpec::TrapeziumLatency => CloudExecModel::new(Box::new(
                TrapeziumLatency::paper_default(LognormalWan::default()),
            ))
            .into(),
            CloudSpec::MobilityBandwidth { device } => {
                CloudExecModel::new(Box::new(TraceBandwidth {
                    base: LognormalWan {
                        // Latency stays nominal; bandwidth is replayed
                        // from the 4G trace.
                        median_bandwidth: f64::INFINITY,
                        ..LognormalWan::default()
                    },
                    samples: mobility_trace(*device, 300),
                    period: secs(1),
                }))
                .into()
            }
            CloudSpec::Faas { keep_alive, concurrency, retry_after } => {
                Box::new(FaasBackend::new(
                    FaasConfig {
                        keep_alive: *keep_alive,
                        concurrency: *concurrency,
                        retry_after: *retry_after,
                        ..FaasConfig::default()
                    },
                    Box::new(LognormalWan::default()),
                ))
            }
            CloudSpec::MultiRegion {
                keep_alive,
                concurrency,
                extra_latency,
            } => {
                let cfg = FaasConfig {
                    keep_alive: *keep_alive,
                    concurrency: *concurrency,
                    ..FaasConfig::default()
                };
                let primary = FaasBackend::new(
                    cfg.clone(),
                    Box::new(LognormalWan::default()),
                );
                let secondary = FaasBackend::new(
                    cfg,
                    Box::new(LognormalWan {
                        median_latency: LognormalWan::default()
                            .median_latency
                            + extra_latency,
                        ..LognormalWan::default()
                    }),
                );
                Box::new(MultiRegionBackend::new(primary, secondary))
            }
        }
    }
}

// ------------------------------------------------------ federation specs

/// Declarative fleet-federation choice for a scenario (the runtime
/// coordinator is [`crate::cluster::Federation`]): cross-edge work
/// stealing, scheduled drone handovers and/or a shared uplink budget.
/// [`FederationSpec::build`] instantiates a *fresh* coordinator per
/// cluster, so sweep cells stay shared-nothing and `--jobs` reports are
/// byte-identical (`tests/sweep_parity.rs`).
#[derive(Clone, Debug, Default)]
pub struct FederationSpec {
    /// Cross-edge §5.3 work stealing between sibling edges.
    pub steal: bool,
    /// Scheduled drone re-homes.
    pub handovers: Vec<Handover>,
    /// Shared backhaul bandwidth in bytes/s serializing the sibling
    /// edges' cloud transfers; `None` = independent uplinks.
    pub uplink_bytes_per_sec: Option<f64>,
}

impl FederationSpec {
    /// Cross-edge stealing on, everything else off.
    pub fn stealing() -> Self {
        FederationSpec { steal: true, ..Default::default() }
    }

    /// Does this spec turn any federation mechanism on?
    pub fn enabled(&self) -> bool {
        self.steal
            || !self.handovers.is_empty()
            || self.uplink_bytes_per_sec.is_some()
    }

    /// Instantiate the runtime coordinator for one cluster.
    pub fn build(&self) -> Federation {
        let mut f = if self.steal {
            Federation::stealing()
        } else {
            Federation::default()
        };
        for h in &self.handovers {
            f = f.with_handover(*h);
        }
        if let Some(bw) = self.uplink_bytes_per_sec {
            f = f.with_uplink(bw);
        }
        f
    }
}

// ------------------------------------------------------------ edge specs

/// Per-edge override for heterogeneous clusters: its own workload plus a
/// hardware slowdown factor scaling every model's expected (and sampled)
/// edge service time — >1 models weaker-than-Nano stations, <1 stronger.
#[derive(Clone, Debug)]
pub struct EdgeSpec {
    pub workload: Workload,
    pub slowdown: f64,
}

/// Scale every profile's expected edge service time by `factor` (the
/// schedulers see the scaled t, so feasibility stays calibrated).
pub fn scale_edge_times(models: &[ModelProfile],
                        factor: f64) -> Vec<ModelProfile> {
    models
        .iter()
        .map(|m| {
            let mut m = m.clone();
            m.t_edge = ((m.t_edge as f64) * factor).round() as Micros;
            m
        })
        .collect()
}

// -------------------------------------------------------------- scenario

/// A declarative experiment: run every workload × policy × seed cell on
/// an `edges`-station cluster and tabulate the results.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub id: String,
    pub title: String,
    /// Workload axis (ignored when `per_edge` is set).
    pub workloads: Vec<Workload>,
    /// Policy axis.
    pub policies: Vec<Policy>,
    pub cloud: CloudSpec,
    /// Stations per cluster (uniform runs; `per_edge.len()` otherwise).
    pub edges: usize,
    /// Seed-sweep width (≥ 1); seed *i* is `base + i·SEED_STRIDE`.
    pub seeds: u64,
    /// Heterogeneous per-edge overrides; non-empty switches the run into
    /// hetero mode (one cluster per policy × seed).
    pub per_edge: Vec<EdgeSpec>,
    /// Fleet-federation layer applied to every cluster of the grid
    /// (`None` — the default — runs the edges fully isolated).
    pub federation: Option<FederationSpec>,
    /// Fault-injection schedule applied to every cluster of the grid
    /// (`None` or an empty spec keeps the engine untouched).
    pub faults: Option<FaultSpec>,
    /// Free-text notes appended to the report.
    pub notes: Vec<String>,
}

impl Scenario {
    pub fn new(id: impl Into<String>, title: impl Into<String>) -> Self {
        Scenario {
            id: id.into(),
            title: title.into(),
            workloads: Vec::new(),
            policies: Vec::new(),
            cloud: CloudSpec::NominalWan,
            edges: 1,
            seeds: 1,
            per_edge: Vec::new(),
            federation: None,
            faults: None,
            notes: Vec::new(),
        }
    }

    pub fn workload(mut self, wl: Workload) -> Self {
        self.workloads.push(wl);
        self
    }

    pub fn policy(mut self, p: Policy) -> Self {
        self.policies.push(p);
        self
    }

    pub fn policies(mut self, ps: Vec<Policy>) -> Self {
        self.policies.extend(ps);
        self
    }

    pub fn cloud(mut self, c: CloudSpec) -> Self {
        self.cloud = c;
        self
    }

    pub fn edges(mut self, n: usize) -> Self {
        self.edges = n;
        self
    }

    pub fn seeds(mut self, n: u64) -> Self {
        self.seeds = n;
        self
    }

    pub fn hetero_edge(mut self, workload: Workload,
                       slowdown: f64) -> Self {
        self.per_edge.push(EdgeSpec { workload, slowdown });
        self
    }

    /// Run every cluster of the grid under this fleet-federation spec.
    pub fn federation(mut self, f: FederationSpec) -> Self {
        self.federation = Some(f);
        self
    }

    /// Inject this deterministic fault schedule into every cluster of
    /// the grid. An empty spec is equivalent to no spec at all.
    pub fn faults(mut self, spec: FaultSpec) -> Self {
        self.faults = Some(spec);
        self
    }

    pub fn note(mut self, s: impl Into<String>) -> Self {
        self.notes.push(s.into());
        self
    }

    // ------------------------------------------------------------ running

    /// Execute the whole grid sequentially; returns the structured
    /// report. Equivalent to `run_jobs(seed, 1)`.
    pub fn run(&self, seed: u64) -> Result<Report> {
        self.run_jobs(seed, 1)
    }

    /// Execute the whole grid on `jobs` worker threads (`0` = auto, `1` =
    /// inline sequential).
    ///
    /// The sweep engine: the grid is first *enumerated* into a flat job
    /// list in report order, the cells are executed on a work-stealing
    /// [`Pool`] (each cell builds its own cluster from its own derived
    /// seed, so cells share nothing), and the results are re-assembled in
    /// enumeration order — reports are **byte-identical** to the
    /// sequential path for every `jobs` value (`tests/sweep_parity.rs`).
    pub fn run_jobs(&self, seed: u64, jobs: usize) -> Result<Report> {
        if self.policies.is_empty() {
            bail!("scenario {:?} has no policies", self.id);
        }
        let pool = Pool::new(jobs);
        let mut rep =
            Report::new(self.id.as_str(), self.title.as_str(), seed);
        if self.per_edge.is_empty() {
            if self.workloads.is_empty() {
                bail!("scenario {:?} has no workloads", self.id);
            }
            if self.edges == 0 {
                bail!("scenario {:?} needs at least one edge", self.id);
            }
            self.run_uniform(seed, &mut rep, &pool);
        } else {
            self.run_hetero(seed, &mut rep, &pool);
        }
        for n in &self.notes {
            rep.text(n.clone());
        }
        Ok(rep)
    }

    fn sweep_seed(&self, base: u64, i: u64) -> u64 {
        base.wrapping_add(i.wrapping_mul(SEED_STRIDE))
    }

    fn run_uniform(&self, seed: u64, rep: &mut Report, pool: &Pool) {
        let mut t = Table::new(&[
            "WL", "algo", "seed#", "edges", "tasks", "done", "done %",
            "QoS util (med)", "min..max util", "cloud done", "stolen",
        ]);
        // Enumerate workload × policy × seed into a flat job list (report
        // row order), fan out, re-assemble in enumeration order.
        let mut cells: Vec<(&Workload, &Policy, u64)> = Vec::new();
        for wl in &self.workloads {
            for policy in &self.policies {
                for i in 0..self.seeds.max(1) {
                    cells.push((wl, policy, i));
                }
            }
        }
        let metrics = pool.run(cells.len(), |j| {
            let (wl, policy, i) = cells[j];
            run_cluster_faulted(policy, wl, self.sweep_seed(seed, i),
                                self.edges, &self.cloud,
                                self.federation.as_ref(),
                                self.faults.as_ref())
        });
        for ((wl, policy, i), cm) in cells.iter().zip(&metrics) {
            t.push_row(summary_row(wl, policy, *i, cm));
        }
        rep.table(t);
    }

    fn run_hetero(&self, seed: u64, rep: &mut Report, pool: &Pool) {
        let mut summary = Table::new(&[
            "algo", "seed#", "edges", "tasks", "done", "done %",
            "QoS util (med)", "min..max util", "cloud done", "stolen",
        ]);
        let mut cells: Vec<(&Policy, u64)> = Vec::new();
        for policy in &self.policies {
            for i in 0..self.seeds.max(1) {
                cells.push((policy, i));
            }
        }
        let metrics = pool.run(cells.len(), |j| {
            let (policy, i) = cells[j];
            self.run_hetero_cluster(policy, self.sweep_seed(seed, i))
        });
        let mut details: Vec<(String, Table)> = Vec::new();
        for ((policy, i), cm) in cells.iter().zip(&metrics) {
            let mut row =
                summary_row(&self.per_edge[0].workload, policy, *i, cm);
            // The WL column does not apply to a mixed cluster.
            row.remove(0);
            summary.push_row(row);
            if *i == 0 {
                details.push((
                    format!("### per-edge — {}", policy.kind.name()),
                    per_edge_table(&self.per_edge, cm),
                ));
            }
        }
        rep.table(summary);
        for (heading, table) in details {
            rep.text(heading);
            rep.table(table);
        }
    }

    fn run_hetero_cluster(&self, policy: &Policy,
                          seed: u64) -> ClusterMetrics {
        let mut platforms = Vec::with_capacity(self.per_edge.len());
        let mut workloads = Vec::with_capacity(self.per_edge.len());
        let mut arrival_seeds = Vec::with_capacity(self.per_edge.len());
        for (e, spec) in self.per_edge.iter().enumerate() {
            let mut wl = spec.workload.clone();
            wl.models = scale_edge_times(&wl.models, spec.slowdown);
            // The canonical §8.1 per-edge seed derivation, shared with
            // Cluster::emulation.
            let (p, aseed) = Cluster::edge_parts(policy, &wl, seed, e,
                                                 self.cloud.build());
            platforms.push(p);
            workloads.push(wl);
            arrival_seeds.push(aseed);
        }
        let mut cluster =
            Cluster::from_parts_hetero(platforms, workloads,
                                       arrival_seeds);
        if let Some(f) = &self.faults {
            if f.enabled() {
                cluster = cluster.with_faults(f.clone());
            }
        }
        match &self.federation {
            Some(f) if f.enabled() => cluster.federated(f.build()).run(),
            _ => cluster.run(),
        }
    }
}

/// Run one uniform workload × policy cell (the canonical §8.1 per-edge
/// seed derivation for multi-edge clusters, the raw seed for one edge).
pub fn run_cluster(policy: &Policy, wl: &Workload, seed: u64,
                   edges: usize, cloud: &CloudSpec) -> ClusterMetrics {
    run_cluster_federated(policy, wl, seed, edges, cloud, None)
}

/// [`run_cluster`] with an optional fleet-federation layer. With `None`
/// (or an all-off spec) the run is bit-identical to the unfederated
/// engine.
pub fn run_cluster_federated(policy: &Policy, wl: &Workload, seed: u64,
                             edges: usize, cloud: &CloudSpec,
                             fed: Option<&FederationSpec>)
                             -> ClusterMetrics {
    run_cluster_faulted(policy, wl, seed, edges, cloud, fed, None)
}

/// [`run_cluster_federated`] with an optional fault-injection schedule
/// (see [`crate::fault`]). With `None` — or an empty spec — the run is
/// bit-identical to the fault-free engine.
pub fn run_cluster_faulted(policy: &Policy, wl: &Workload, seed: u64,
                           edges: usize, cloud: &CloudSpec,
                           fed: Option<&FederationSpec>,
                           faults: Option<&FaultSpec>)
                           -> ClusterMetrics {
    run_cluster_observed(policy, wl, seed, edges, cloud, fed, faults,
                         None, None)
}

/// [`run_cluster_faulted`] with the observability layer attached: an
/// optional task-lifecycle [`TraceSink`] (every edge badged through one
/// shared sink) and an optional windowed-[`Timeline`] width. Both `None`
/// is bit-identical to [`run_cluster_faulted`] — the hooks stay inert.
///
/// [`TraceSink`]: crate::obs::TraceSink
/// [`Timeline`]: crate::obs::Timeline
#[allow(clippy::too_many_arguments)]
pub fn run_cluster_observed(policy: &Policy, wl: &Workload, seed: u64,
                            edges: usize, cloud: &CloudSpec,
                            fed: Option<&FederationSpec>,
                            faults: Option<&FaultSpec>,
                            trace: Option<SharedSink>,
                            timeline_window: Option<Micros>)
                            -> ClusterMetrics {
    let mut cluster = if edges <= 1 {
        Cluster::single(policy, wl, seed, cloud.build())
    } else {
        Cluster::emulation(policy, wl, seed, edges, &|| cloud.build())
    };
    if let Some(f) = faults {
        if f.enabled() {
            cluster = cluster.with_faults(f.clone());
        }
    }
    if let Some(sink) = trace {
        cluster = cluster.with_trace(sink);
    }
    if let Some(w) = timeline_window {
        cluster = cluster.with_timeline(w);
    }
    match fed {
        Some(f) if f.enabled() => cluster.federated(f.build()).run(),
        _ => cluster.run(),
    }
}

fn summary_row(wl: &Workload, policy: &Policy, seed_i: u64,
               cm: &ClusterMetrics) -> Vec<Cell> {
    let med = cm.median_edge();
    let (lo, hi) = cm.minmax_utility();
    let cloud_done: u64 = cm
        .per_edge
        .iter()
        .map(|m| m.completed_on(Resource::Cloud))
        .sum();
    let stolen: u64 = cm.per_edge.iter().map(Metrics::stolen).sum();
    vec![
        Cell::str(wl.name.as_str()),
        Cell::str(policy.kind.name()),
        Cell::uint(seed_i),
        Cell::uint(cm.edges() as u64),
        Cell::uint(cm.generated()),
        Cell::uint(cm.completed()),
        Cell::percent(100.0 * cm.completion_rate(), 1),
        Cell::float(med.qos_utility() / 1e5, 2),
        Cell::str(format!("{:.2}..{:.2}", lo / 1e5, hi / 1e5)),
        Cell::uint(cloud_done),
        Cell::uint(stolen),
    ]
}

fn per_edge_table(specs: &[EdgeSpec], cm: &ClusterMetrics) -> Table {
    let mut t = Table::new(&[
        "edge", "WL", "slowdown", "tasks", "done", "done %", "QoS util",
    ]);
    for (e, (spec, m)) in specs.iter().zip(&cm.per_edge).enumerate() {
        t.push_row(vec![
            Cell::uint(e as u64),
            Cell::str(spec.workload.name.as_str()),
            Cell::fmt(Value::Float(spec.slowdown),
                      format!("×{}", spec.slowdown)),
            Cell::uint(m.generated()),
            Cell::uint(m.completed()),
            Cell::percent(100.0 * m.completion_rate(), 1),
            Cell::float(m.qos_utility() / 1e5, 2),
        ]);
    }
    t
}

// --------------------------------------------- beyond-paper scenarios

/// `poisson`: the arrival-process axis — the paper's fixed-rate segments
/// vs Poisson arrivals at the same mean rate vs a 10 s on / 10 s off
/// bursty duty cycle, on the 3D-A mix across a 7-station host.
pub fn poisson_scenario() -> Scenario {
    Scenario::new(
        "poisson",
        "Poisson & bursty arrivals — beyond fixed-rate segments (3D-A)",
    )
    .workload(Workload::emulation(3, true).with_name("3D-A-per"))
    .workload(
        Workload::emulation(3, true)
            .with_arrival(Arrival::Poisson)
            .with_name("3D-A-poi"),
    )
    .workload(
        Workload::emulation(3, true)
            .with_arrival(Arrival::Bursty {
                on: secs(10),
                off: secs(10),
            })
            .with_name("3D-A-bur"),
    )
    .policies(vec![Policy::edf_ec(), Policy::dems(), Policy::dems_a()])
    .edges(exp::EDGES_PER_HOST)
    .seeds(3)
    .note(
        "(per = the paper's fixed-rate segments; poi = Poisson arrivals \
         at the same mean rate; bur = 10 s on / 10 s off duty cycle — \
         burst peaks stress admission, idle troughs starve stealing)",
    )
}

/// `churn`: mid-run drone churn — one buddy drone leaves at 150 s and a
/// late drone joins at 120 s, against the steady 4D-P baseline.
pub fn churn_scenario() -> Scenario {
    let churned = Workload::emulation(4, false)
        .with_name("4D-P-churn")
        .with_churn(DroneChurn {
            drone: 2,
            active_from: 0,
            active_until: secs(150),
        })
        .with_churn(DroneChurn {
            drone: 3,
            active_from: secs(120),
            active_until: secs(300),
        });
    Scenario::new(
        "churn",
        "Mid-run drone churn — fleet join/leave on the 4D-P mix",
    )
    .workload(Workload::emulation(4, false))
    .workload(churned)
    .policies(vec![Policy::edf_ec(), Policy::dems()])
    .edges(exp::EDGES_PER_HOST)
    .seeds(2)
    .note(
        "(4D-P-churn: drone 2 leaves at 150 s, drone 3 joins at 120 s — \
         30 s of 4-drone overlap, then a 3-drone tail; total load sits \
         between 3D-P and 4D-P)",
    )
}

/// `hetero-edges`: heterogeneous stations — mixed fleet sizes and app
/// mixes per edge plus non-uniform hardware (×1.3 ≈ weaker-than-Nano,
/// ×0.7 ≈ Orin-class edge times).
pub fn hetero_scenario() -> Scenario {
    Scenario::new(
        "hetero-edges",
        "Heterogeneous edges — mixed fleets and hardware per station",
    )
    .policies(vec![Policy::edf_ec(), Policy::dems()])
    .hetero_edge(Workload::emulation(2, false), 1.0)
    .hetero_edge(Workload::emulation(3, false), 1.0)
    .hetero_edge(Workload::emulation(3, true), 1.0)
    .hetero_edge(Workload::emulation(4, false), 1.3)
    .hetero_edge(Workload::emulation(4, true), 1.3)
    .hetero_edge(Workload::emulation(3, false), 0.7)
    .hetero_edge(Workload::emulation(2, true), 1.0)
    .seeds(2)
    .note(
        "(7 stations, one host: three Nano-class references, two \
         overloaded ×1.3 slow stations, one ×0.7 Orin-class, one light \
         active mix — per-edge tables show where DEMS's offload headroom \
         goes)",
    )
}

// ------------------------------------------- FaaS backend scenarios

/// Stations per cluster for the FaaS scenarios (kept below the §8.1 host
/// width so the keep-alive × concurrency grids stay cheap to sweep).
const FAAS_EDGES: usize = 3;

/// Column labels shared by every FaaS scenario table (appended after the
/// scenario's own axis columns).
const FAAS_TAIL_COLS: [&str; 8] = [
    "tasks", "done %", "QoS util", "cloud done", "cold %", "throttled",
    "GB-s", "cloud $",
];

/// Completion/utility next to the backend's cost, cold-start and
/// throttle accounting — the row tail under [`FAAS_TAIL_COLS`].
fn faas_row_tail(cm: &ClusterMetrics) -> Vec<Cell> {
    let s = cm.cloud_stats();
    let cloud_done: u64 = cm
        .per_edge
        .iter()
        .map(|m| m.completed_on(Resource::Cloud))
        .sum();
    vec![
        Cell::uint(cm.generated()),
        Cell::percent(100.0 * cm.completion_rate(), 1),
        Cell::float(cm.total_qos_utility() / 1e5, 2),
        Cell::uint(cloud_done),
        Cell::percent(100.0 * s.cold_start_rate(), 1),
        Cell::uint(cm.throttled()),
        Cell::float(s.gb_seconds, 1),
        Cell::dollars(s.dollars),
    ]
}

fn faas_table(axis_cols: &[&str]) -> Table {
    let cols: Vec<&str> =
        axis_cols.iter().chain(FAAS_TAIL_COLS.iter()).copied().collect();
    Table::new(&cols)
}

/// Human label for a keep-alive axis value.
fn keep_alive_label(ka: Micros) -> String {
    format!("{}s", ka / 1_000_000)
}

/// `cold-start-sweep`: the container keep-alive axis — from
/// expire-immediately (every invocation cold) to Lambda-like 120 s — for
/// DEMS and DEMS-A on the 3D-A mix. Cold starts inflate observed cloud
/// durations, so DEMS-A's §5.4 window reacts exactly as it does to WAN
/// variability.
pub fn cold_start_sweep_report(seed: u64, pool: &Pool) -> Result<Report> {
    let keep_alives =
        [0, secs(1), secs(5), secs(30), secs(120)];
    let policies = [Policy::dems(), Policy::dems_a()];
    let wl = Workload::emulation(3, true);
    let mut cells: Vec<(Micros, &Policy)> = Vec::new();
    for &ka in &keep_alives {
        for policy in &policies {
            cells.push((ka, policy));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let (ka, policy) = cells[j];
        run_cluster(
            policy,
            &wl,
            seed,
            FAAS_EDGES,
            &CloudSpec::faas(ka, 64),
        )
    });
    let mut rep = Report::new(
        "cold-start-sweep",
        "FaaS keep-alive sweep — cold-start rate vs cloud cost (3D-A)",
        seed,
    );
    let mut t = faas_table(&["keep-alive", "algo"]);
    for ((ka, policy), cm) in cells.iter().zip(&metrics) {
        let mut row = vec![
            Cell::str(keep_alive_label(*ka)),
            Cell::str(policy.kind.name()),
        ];
        row.extend(faas_row_tail(cm));
        t.push_row(row);
    }
    rep.table(t);
    rep.text(
        "(keep-alive 0 s expires every container immediately — the \
         all-cold ceiling; longer keep-alives trade idle container \
         lifetime for cold-start rate. cold % = cold starts per admitted \
         invocation; cloud $ = GB-seconds + per-request fees.)"
            .to_string(),
    );
    Ok(rep)
}

/// `throttled-cloud`: the per-edge-account concurrency axis on the
/// cloud-heavy 4D-A mix, CLD vs DEMS vs DEMS-A, plus a single-region vs
/// two-region failover comparison. Throttles are reported through
/// `on_cloud_report`, so DEMS-A backs off the cloud instead of burning
/// retries (LLHR, arXiv 2305.15858, motivates exactly this
/// reliability-aware placement under constrained backends).
pub fn throttled_cloud_report(seed: u64, pool: &Pool) -> Result<Report> {
    let concs = [1usize, 2, 4, 16];
    let policies =
        [Policy::cloud_only(), Policy::dems(), Policy::dems_a()];
    let wl = Workload::emulation(4, true);
    let mut cells: Vec<(usize, &Policy)> = Vec::new();
    for &c in &concs {
        for policy in &policies {
            cells.push((c, policy));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let (conc, policy) = cells[j];
        run_cluster(
            policy,
            &wl,
            seed,
            FAAS_EDGES,
            &CloudSpec::faas(secs(300), conc),
        )
    });
    let mut rep = Report::new(
        "throttled-cloud",
        "FaaS concurrency ceiling — throttle/retry vs adaptation (4D-A)",
        seed,
    );
    let mut t = faas_table(&["conc", "algo"]);
    for ((conc, policy), cm) in cells.iter().zip(&metrics) {
        let mut row = vec![
            Cell::uint(*conc as u64),
            Cell::str(policy.kind.name()),
        ];
        row.extend(faas_row_tail(cm));
        t.push_row(row);
    }
    rep.table(t);
    // Failover study: the same starved ceilings, DEMS-A, one region vs
    // two regions (secondary +40 ms median latency, own ceiling).
    let fo_cells: Vec<(usize, bool)> = [1usize, 2, 4]
        .iter()
        .flat_map(|&c| [(c, false), (c, true)])
        .collect();
    let fo_metrics = pool.run(fo_cells.len(), |j| {
        let (conc, multi) = fo_cells[j];
        let spec = if multi {
            CloudSpec::MultiRegion {
                keep_alive: secs(300),
                concurrency: conc,
                extra_latency: ms_f(40.0),
            }
        } else {
            CloudSpec::faas(secs(300), conc)
        };
        run_cluster(&Policy::dems_a(), &wl, seed, FAAS_EDGES, &spec)
    });
    rep.text("### two-region failover (DEMS-A)".to_string());
    let mut t = faas_table(&["backend", "conc"]);
    for ((conc, multi), cm) in fo_cells.iter().zip(&fo_metrics) {
        let mut row = vec![
            Cell::str(if *multi { "2-region" } else { "faas" }),
            Cell::uint(*conc as u64),
        ];
        row.extend(faas_row_tail(cm));
        t.push_row(row);
    }
    rep.table(t);
    rep.text(
        "(conc = in-flight ceiling of each edge station's own FaaS \
         account — one account per edge, so this 3-edge cluster holds 3 \
         independent ceilings; throttled counts dispatch attempts \
         rejected at a ceiling — each is retried while its deadline \
         allows, else dropped. The 2-region backend fails a throttled \
         attempt over to a +40 ms secondary before giving up.)"
            .to_string(),
    );
    Ok(rep)
}

/// `cost-frontier`: keep-alive × concurrency grid under DEMS-A — where
/// QoS utility is bought cheapest. The frontier column reports utility
/// per cloud dollar.
pub fn cost_frontier_report(seed: u64, pool: &Pool) -> Result<Report> {
    let keep_alives = [0, secs(5), secs(60)];
    let concs = [2usize, 8, 64];
    let wl = Workload::emulation(3, true);
    let mut cells: Vec<(Micros, usize)> = Vec::new();
    for &ka in &keep_alives {
        for &c in &concs {
            cells.push((ka, c));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let (ka, conc) = cells[j];
        run_cluster(
            &Policy::dems_a(),
            &wl,
            seed,
            FAAS_EDGES,
            &CloudSpec::faas(ka, conc),
        )
    });
    let mut rep = Report::new(
        "cost-frontier",
        "FaaS cost frontier — keep-alive × concurrency vs QoS utility \
         (DEMS-A, 3D-A)",
        seed,
    );
    let mut t = faas_table(&["keep-alive", "conc"]);
    t.columns.push("util / $".to_string());
    for ((ka, conc), cm) in cells.iter().zip(&metrics) {
        let mut row = vec![
            Cell::str(keep_alive_label(*ka)),
            Cell::uint(*conc as u64),
        ];
        row.extend(faas_row_tail(cm));
        let dollars = cm.cloud_stats().dollars;
        row.push(if dollars > 0.0 {
            Cell::float(cm.total_qos_utility() / 1e5 / dollars, 1)
        } else {
            Cell::fmt(Value::Null, "—")
        });
        t.push_row(row);
    }
    rep.table(t);
    rep.text(
        "(util / $ = QoS utility (×1e5) per cloud dollar — the frontier \
         metric: tight ceilings throttle offloads and waste deadline \
         headroom, short keep-alives re-bill cold starts; the knee is \
         where extra spend stops buying utility.)"
            .to_string(),
    );
    Ok(rep)
}

// ------------------------------------------------ federation scenarios

/// Build and run one cluster over explicit per-edge workloads, federated
/// or isolated — the cell runner of the federation scenarios (canonical
/// §8.1 per-edge seed derivation via [`Cluster::edge_parts`]).
fn run_fed_cell(policy: &Policy, wls: &[Workload], seed: u64,
                cloud: &CloudSpec, fed: Option<Federation>)
                -> ClusterMetrics {
    run_fault_cell(policy, wls, seed, cloud, fed, &FaultSpec::default())
}

/// [`run_fed_cell`] with a fault-injection schedule layered on (an empty
/// spec leaves the engine untouched) — the cell runner of the chaos
/// scenarios.
fn run_fault_cell(policy: &Policy, wls: &[Workload], seed: u64,
                  cloud: &CloudSpec, fed: Option<Federation>,
                  faults: &FaultSpec) -> ClusterMetrics {
    let mut platforms = Vec::with_capacity(wls.len());
    let mut arrival_seeds = Vec::with_capacity(wls.len());
    for (e, wl) in wls.iter().enumerate() {
        let (p, aseed) =
            Cluster::edge_parts(policy, wl, seed, e, cloud.build());
        platforms.push(p);
        arrival_seeds.push(aseed);
    }
    let mut cluster =
        Cluster::from_parts_hetero(platforms, wls.to_vec(), arrival_seeds);
    if faults.enabled() {
        cluster = cluster.with_faults(faults.clone());
    }
    match fed {
        Some(f) => cluster.federated(f).run(),
        None => cluster.run(),
    }
}

/// The `fed-steal` mix: one overloaded 4D-A station flanked by two light
/// bursty 2D-P stations whose idle troughs (2 s on / 8 s off) are where
/// the cross-edge steals happen.
fn fed_steal_workloads() -> Vec<Workload> {
    let light = |n: u32| {
        Workload::emulation(2, false)
            .with_arrival(Arrival::Bursty { on: secs(2), off: secs(8) })
            .with_name(format!("2D-P-bur{n}"))
    };
    vec![Workload::emulation(4, true), light(1), light(2)]
}

/// `fed-steal`: fleet-level work stealing under imbalanced bursty load —
/// with federation off the stations are the paper's isolated §8.1 setup;
/// with stealing on, an idle light station pulls deadline-viable
/// deferred tasks from the overloaded sibling's cloud queue (LAN
/// transfer charged, κ/κ̂-ranked), so completions and total utility
/// strictly improve (pinned by a scenario test).
pub fn fed_steal_report(seed: u64, pool: &Pool) -> Result<Report> {
    let policies = [Policy::dems(), Policy::dems_a()];
    let wls = fed_steal_workloads();
    let mut cells: Vec<(&Policy, bool)> = Vec::new();
    for policy in &policies {
        for fed_on in [false, true] {
            cells.push((policy, fed_on));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let (policy, fed_on) = cells[j];
        let fed = if fed_on { Some(Federation::stealing()) } else { None };
        run_fed_cell(policy, &wls, seed, &CloudSpec::NominalWan, fed)
    });
    let mut rep = Report::new(
        "fed-steal",
        "Fleet federation — cross-edge work stealing under imbalanced \
         bursty load (4D-A + 2×2D-P bursty)",
        seed,
    );
    let mut t = Table::new(&[
        "algo", "federation", "tasks", "done", "done %", "QoS util",
        "total util", "x-edge steals", "local steals",
    ]);
    for ((policy, fed_on), cm) in cells.iter().zip(&metrics) {
        let local: u64 = cm.per_edge.iter().map(Metrics::stolen).sum();
        t.push_row(vec![
            Cell::str(policy.kind.name()),
            Cell::str(if *fed_on { "steal" } else { "off" }),
            Cell::uint(cm.generated()),
            Cell::uint(cm.completed()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_qos_utility() / 1e5, 2),
            Cell::float(cm.total_utility() / 1e5, 2),
            Cell::uint(cm.fed_steals()),
            Cell::uint(local),
        ]);
    }
    rep.table(t);
    rep.text(
        "(federation=steal: when a station goes fully idle it pulls the \
         best deadline-viable entry from a sibling's deferred cloud \
         queue — negative-utility candidates first, then κ/κ̂ steal rank \
         — paying a 2 ms/125 MB/s LAN transfer; x-edge steals counts \
         arrivals at the thief. federation=off is the paper's isolated \
         §8.1 setup.)"
            .to_string(),
    );
    Ok(rep)
}

/// `handover-churn`: drone→edge handover on the dynamic router — a buddy
/// drone of the overloaded station re-homes to the light sibling
/// mid-run (while another drone churns out entirely), with in-flight
/// tasks finishing at the old edge.
pub fn handover_churn_report(seed: u64, pool: &Pool) -> Result<Report> {
    // Edge 0: overloaded 4D-A whose drone 3 churns out at 200 s; edge 1:
    // light 2D-A (same six-model mix, so the handed-over drone keeps its
    // apps). Global drone 2 re-homes to edge 1 at 150 s.
    let wls = vec![
        Workload::emulation(4, true)
            .with_name("4D-A-churn")
            .with_churn(DroneChurn {
                drone: 3,
                active_from: 0,
                active_until: secs(200),
            }),
        Workload::emulation(2, true),
    ];
    let handover = Handover { at: secs(150), drone: 2, to_edge: 1 };
    let policies = [Policy::dems(), Policy::dems_a()];
    let mut cells: Vec<(&Policy, bool)> = Vec::new();
    for policy in &policies {
        for fed_on in [false, true] {
            cells.push((policy, fed_on));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let (policy, fed_on) = cells[j];
        let fed = if fed_on {
            Some(Federation::default().with_handover(handover))
        } else {
            None
        };
        run_fed_cell(policy, &wls, seed, &CloudSpec::NominalWan, fed)
    });
    let mut rep = Report::new(
        "handover-churn",
        "Fleet federation — drone handover at the churn boundary \
         (4D-A-churn + 2D-A)",
        seed,
    );
    let mut t = Table::new(&[
        "algo", "federation", "handovers", "tasks", "done", "done %",
        "QoS util", "total util", "edge0 done %", "edge1 done %",
    ]);
    for ((policy, fed_on), cm) in cells.iter().zip(&metrics) {
        t.push_row(vec![
            Cell::str(policy.kind.name()),
            Cell::str(if *fed_on { "handover" } else { "off" }),
            Cell::uint(cm.handovers()),
            Cell::uint(cm.generated()),
            Cell::uint(cm.completed()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_qos_utility() / 1e5, 2),
            Cell::float(cm.total_utility() / 1e5, 2),
            Cell::percent(100.0 * cm.per_edge[0].completion_rate(), 1),
            Cell::percent(100.0 * cm.per_edge[1].completion_rate(), 1),
        ]);
    }
    rep.table(t);
    rep.text(
        "(at 150 s the dynamic Router re-homes global drone 2 from the \
         overloaded station to the light one — its stream emits there \
         from the exact boundary tick on, while tasks already admitted \
         at edge 0 finish at edge 0; drone 3 churns out at 200 s in both \
         rows. Task totals are identical across rows — handover moves \
         load, it never creates or destroys it.)"
            .to_string(),
    );
    Ok(rep)
}

/// `shared-uplink`: sibling stations on one backhaul — concurrent cloud
/// dispatches serialize through a shared bandwidth budget and inflate
/// each other's observed durations, which DEMS-A's §5.4 window adapts
/// t̂ to while plain DEMS keeps over-committing the cloud.
pub fn shared_uplink_report(seed: u64, pool: &Pool) -> Result<Report> {
    let uplinks: [(&str, Option<f64>); 3] = [
        ("own", None),
        ("25 MB/s", Some(25.0e6)),
        ("4 MB/s", Some(4.0e6)),
    ];
    let policies = [Policy::dems(), Policy::dems_a()];
    let wl = Workload::emulation(3, true);
    let mut cells: Vec<((&str, Option<f64>), &Policy)> = Vec::new();
    for u in uplinks {
        for policy in &policies {
            cells.push((u, policy));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let ((_, bw), policy) = cells[j];
        let fed = bw.map(|b| FederationSpec {
            uplink_bytes_per_sec: Some(b),
            ..Default::default()
        });
        run_cluster_federated(policy, &wl, seed, 3,
                              &CloudSpec::NominalWan, fed.as_ref())
    });
    let mut rep = Report::new(
        "shared-uplink",
        "Fleet federation — shared-uplink contention across 3 stations \
         (3D-A)",
        seed,
    );
    let mut t = Table::new(&[
        "uplink", "algo", "tasks", "done %", "QoS util", "cloud done",
        "queued", "uplink delay (s)",
    ]);
    for (((label, _), policy), cm) in cells.iter().zip(&metrics) {
        let cloud_done: u64 = cm
            .per_edge
            .iter()
            .map(|m| m.completed_on(Resource::Cloud))
            .sum();
        t.push_row(vec![
            Cell::str(*label),
            Cell::str(policy.kind.name()),
            Cell::uint(cm.generated()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_qos_utility() / 1e5, 2),
            Cell::uint(cloud_done),
            Cell::uint(cm.uplink_queued()),
            Cell::seconds(cm.uplink_wait(), 1),
        ]);
    }
    rep.table(t);
    rep.text(
        "(uplink=own is the paper's independent-backhaul assumption; a \
         shared budget serializes the stations' cloud transfers, so \
         concurrent dispatches queue — the delay lands in each \
         invocation's observed duration, which is exactly what DEMS-A's \
         adaptation window reacts to. queued / delay total the \
         contention across all three stations.)"
            .to_string(),
    );
    Ok(rep)
}

// ---------------------------------------------------- chaos scenarios

/// `timeline`: the windowed time-series fold — every per-task outcome,
/// arrival-instant queue depth and uplink wait folded into fixed 30 s
/// virtual-time windows by the O(1)-memory [`Timeline`], DEMS vs DEMS-A
/// on the 4-drone analytics mix across 3 stations. Where `fig8` reports
/// one aggregate number per run, this shows *when* the completions,
/// drops and queue pressure happened — the §8 QoS story as a time
/// series instead of a total.
pub fn timeline_report(seed: u64, pool: &Pool) -> Result<Report> {
    const WINDOW: Micros = secs(30);
    let wl = Workload::emulation(4, true);
    let policies = [Policy::dems(), Policy::dems_a()];
    let metrics = pool.run(policies.len(), |j| {
        run_cluster_observed(&policies[j], &wl, seed, 3,
                             &CloudSpec::NominalWan, None, None, None,
                             Some(WINDOW))
    });
    let mut rep = Report::new(
        "timeline",
        "Observability — windowed time-series metrics \
         (30 s windows, DEMS vs DEMS-A, 4D-A × 3 edges)",
        seed,
    );
    for (policy, cm) in policies.iter().zip(&metrics) {
        let mut tl = Timeline::new(WINDOW);
        for m in &cm.per_edge {
            tl.merge(m.windowed.as_ref().expect("timeline enabled"));
        }
        rep.text(format!("### {}", policy.kind.name()));
        let mut t = Table::new(&[
            "window", "start (s)", "tasks", "done", "missed", "dropped",
            "mean queue", "uplink wait (s)", "QoS util",
        ]);
        for (i, w) in tl.windows().iter().enumerate() {
            let queue = if w.queue_samples == 0 {
                Cell::fmt(Value::Null, "-")
            } else {
                Cell::float(w.mean_queue_depth(), 2)
            };
            t.push_row(vec![
                Cell::uint(i as u64),
                Cell::uint(i as u64 * (WINDOW / 1_000_000)),
                Cell::uint(w.generated),
                Cell::uint(w.completed),
                Cell::uint(w.missed),
                Cell::uint(w.dropped),
                queue,
                Cell::seconds(w.uplink_wait, 2),
                Cell::float(w.utility / 1e5, 2),
            ]);
        }
        rep.table(t);
    }
    rep.text(
        "(each row folds every task finalized inside one 30 s \
         virtual-time window, merged across the 3 stations; `mean \
         queue` averages the edge+cloud queue depth sampled at each \
         arrival instant in the window. Memory is O(windows), not \
         O(tasks) — see docs/OBSERVABILITY.md.)"
            .to_string(),
    );
    Ok(rep)
}

/// Drop-breakdown column group for the chaos reports: appends one
/// `<reason> %` column (share of generated tasks) per [`DropReason`]
/// observed anywhere in `metrics`, plus the matching cells on every
/// row. Columns go AFTER the existing ones, so positional pins on the
/// base tables stay valid, and reasons nobody hit add no noise.
fn push_drop_breakdown(t: &mut Table, metrics: &[ClusterMetrics]) {
    let reasons: Vec<DropReason> = DropReason::ALL
        .iter()
        .copied()
        .filter(|&r| metrics.iter().any(|cm| cm.dropped_by(r) > 0))
        .collect();
    for &r in &reasons {
        t.columns
         .push(format!("{} %", crate::obs::reason_name(r)));
    }
    for (row, cm) in t.rows.iter_mut().zip(metrics) {
        let g = cm.generated();
        for &r in &reasons {
            let pct = if g == 0 {
                0.0
            } else {
                100.0 * cm.dropped_by(r) as f64 / g as f64
            };
            row.push(Cell::percent(pct, 1));
        }
    }
}

/// Crash/recovery schedule shared by the `node-crash` rows and the
/// scenario pin test: the overloaded station dies at 120 s and reboots
/// at 210 s.
fn node_crash_spec(recovery: Recovery) -> FaultSpec {
    FaultSpec::default()
        .crash(0, secs(120), Some(secs(210)))
        .with_recovery(recovery)
}

/// `node-crash`: a mid-run station crash under the `fed-steal` imbalance
/// — the overloaded 4D-A station dies at 120 s and reboots at 210 s,
/// its drones re-homing to a live sibling in between. Isolated edges
/// lose everything the dead station held; federated stealing keeps
/// draining its backlog beforehand; `requeue` recovery additionally
/// relocates the orphaned queue over the federation LAN at the crash
/// instant. A scenario test pins that federated requeue strictly beats
/// the isolated fleet on completion rate and total utility.
pub fn node_crash_report(seed: u64, pool: &Pool) -> Result<Report> {
    let wls = fed_steal_workloads();
    // (recovery label, federated, crash schedule applied)
    let cells: [(&str, bool, Option<Recovery>); 4] = [
        ("no fault", false, None),
        ("lose", false, Some(Recovery::Lose)),
        ("lose", true, Some(Recovery::Lose)),
        ("requeue", true, Some(Recovery::Requeue)),
    ];
    let metrics = pool.run(cells.len(), |j| {
        let (_, fed_on, rec) = cells[j];
        let fed = if fed_on { Some(Federation::stealing()) } else { None };
        let spec = match rec {
            Some(r) => node_crash_spec(r),
            None => FaultSpec::default(),
        };
        run_fault_cell(&Policy::dems_a(), &wls, seed,
                       &CloudSpec::NominalWan, fed, &spec)
    });
    let mut rep = Report::new(
        "node-crash",
        "Chaos — mid-run station crash + recovery under imbalanced load \
         (DEMS-A, 4D-A + 2×2D-P bursty)",
        seed,
    );
    let mut t = Table::new(&[
        "recovery", "federation", "tasks", "done", "done %",
        "total util", "crashes", "relocated", "node-failed",
        "downtime (s)",
    ]);
    for ((label, fed_on, _), cm) in cells.iter().zip(&metrics) {
        t.push_row(vec![
            Cell::str(*label),
            Cell::str(if *fed_on { "steal" } else { "off" }),
            Cell::uint(cm.generated()),
            Cell::uint(cm.completed()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_utility() / 1e5, 2),
            Cell::uint(cm.crashes()),
            Cell::uint(cm.fault_relocated()),
            Cell::uint(cm.node_failures()),
            Cell::seconds(cm.downtime(), 1),
        ]);
    }
    push_drop_breakdown(&mut t, &metrics);
    rep.table(t);
    rep.text(
        "(the overloaded station crashes at 120 s and reboots at 210 s; \
         its drones re-home to a live sibling for the 90 s of downtime \
         in every faulted row, and task totals stay identical — faults \
         change outcomes, never generation. recovery=lose drops the dead \
         station's queued and in-flight work as node failures; \
         recovery=requeue relocates the still-feasible queued entries to \
         a live sibling over the federation LAN at the crash instant — \
         in-flight work is always lost.)"
            .to_string(),
    );
    Ok(rep)
}

/// `region-outage`: the primary FaaS region goes dark for a 100 s
/// window on the two-region backend — refusals are shaped as throttles,
/// so invocations fail over to the +40 ms secondary and DEMS-A's §5.4
/// adaptation window backs off the cloud exactly as it does under WAN
/// degradation; plain DEMS keeps dispatching into the squeezed path.
pub fn region_outage_report(seed: u64, pool: &Pool) -> Result<Report> {
    let wl = Workload::emulation(4, true);
    let cloud = CloudSpec::MultiRegion {
        keep_alive: secs(300),
        concurrency: 4,
        extra_latency: ms_f(40.0),
    };
    let policies = [Policy::dems(), Policy::dems_a()];
    let mut cells: Vec<(&Policy, bool)> = Vec::new();
    for policy in &policies {
        for outage in [false, true] {
            cells.push((policy, outage));
        }
    }
    let metrics = pool.run(cells.len(), |j| {
        let (policy, outage) = cells[j];
        let spec = if outage {
            FaultSpec::default().outage(0, secs(100), secs(200))
        } else {
            FaultSpec::default()
        };
        run_cluster_faulted(policy, &wl, seed, FAAS_EDGES, &cloud, None,
                            Some(&spec))
    });
    let mut rep = Report::new(
        "region-outage",
        "Chaos — primary FaaS region outage with two-region failover \
         (4D-A)",
        seed,
    );
    let mut t = faas_table(&["algo", "outage"]);
    for ((policy, outage), cm) in cells.iter().zip(&metrics) {
        let mut row = vec![
            Cell::str(policy.kind.name()),
            Cell::str(if *outage { "100-200 s" } else { "none" }),
        ];
        row.extend(faas_row_tail(cm));
        t.push_row(row);
    }
    rep.table(t);
    rep.text(
        "(outage: region 0 refuses every invocation during the window, \
         shaped as a throttle that clears with the outage — attempts \
         fail over to the +40 ms secondary and only count as throttled \
         when the secondary's own ceiling is full too, in which case the \
         dispatch retries while its deadline allows. The refusals land \
         in the stations' observed durations — the signal DEMS-A's \
         adaptation window reacts to.)"
            .to_string(),
    );
    Ok(rep)
}

/// `partition`: backhaul and LAN degradation windows ("link flaps") on
/// the federated fleet — the shared uplink collapses to a trickle, the
/// steal LAN degrades, or both at once: a soft network partition of the
/// sibling stations.
pub fn partition_report(seed: u64, pool: &Pool) -> Result<Report> {
    let wls = fed_steal_workloads();
    let (from, until) = (secs(100), secs(200));
    // (label, uplink flapped, LAN flapped)
    let cells: [(&str, bool, bool); 4] = [
        ("none", false, false),
        ("uplink", true, false),
        ("lan", false, true),
        ("both", true, true),
    ];
    let metrics = pool.run(cells.len(), |j| {
        let (_, up, lan) = cells[j];
        let mut spec = FaultSpec::default();
        if up {
            spec = spec.flap(FlapLink::Uplink, from, until, 1.0e6);
        }
        if lan {
            spec = spec.flap(FlapLink::Lan, from, until, 1.0e6);
        }
        run_fault_cell(&Policy::dems_a(), &wls, seed,
                       &CloudSpec::NominalWan,
                       Some(Federation::stealing().with_uplink(25.0e6)),
                       &spec)
    });
    let mut rep = Report::new(
        "partition",
        "Chaos — backhaul/LAN degradation windows on the federated \
         fleet (DEMS-A, 4D-A + 2×2D-P bursty)",
        seed,
    );
    let mut t = Table::new(&[
        "degraded", "tasks", "done", "done %", "QoS util", "total util",
        "x-edge steals", "queued", "uplink delay (s)",
    ]);
    for ((label, _, _), cm) in cells.iter().zip(&metrics) {
        t.push_row(vec![
            Cell::str(*label),
            Cell::uint(cm.generated()),
            Cell::uint(cm.completed()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_qos_utility() / 1e5, 2),
            Cell::float(cm.total_utility() / 1e5, 2),
            Cell::uint(cm.fed_steals()),
            Cell::uint(cm.uplink_queued()),
            Cell::seconds(cm.uplink_wait(), 1),
        ]);
    }
    rep.table(t);
    rep.text(
        "(between 100 s and 200 s the flapped link drops to 1 MB/s: \
         uplink squeezes the 25 MB/s shared backhaul every cloud \
         transfer serializes through — the queueing delay inflates \
         observed durations, which DEMS-A's adaptation window backs off \
         from; lan makes cross-edge steal transfers expensive, so \
         fewer stolen entries stay deadline-viable. Both links restore \
         to nominal when the window closes.)"
            .to_string(),
    );
    Ok(rep)
}

// ----------------------------------------------- resilience scenarios

/// Degradation arming shared by the resilience scenarios: thresholds
/// tuned for DEMS-A's shallow edge queues (its admission offloads before
/// the queue ever reaches the conservative defaults).
fn overload_degrade() -> ResilienceSpec {
    ResilienceSpec {
        degrade: true,
        degrade_queue_high: 3,
        degrade_queue_low: 1,
        ..ResilienceSpec::default()
    }
}

/// Resilience arming for the `breaker-outage` rows and pin test:
/// circuit breaker + graceful degradation. Hedging is deliberately left
/// off — under a capacity outage, duplicates would compete with
/// primaries for the scarce surviving slots (`hedged-tail` studies
/// hedging where it helps: the latency tail).
fn breaker_outage_resilience() -> ResilienceSpec {
    ResilienceSpec { breaker: true, ..overload_degrade() }
}

/// One `breaker-outage` cell: the region-outage configuration (§ the
/// `region-outage` scenario) under a plain or resilience-armed policy.
fn run_breaker_outage_cell(policy: &Policy, outage: bool,
                           seed: u64) -> ClusterMetrics {
    let wl = Workload::emulation(4, true);
    let cloud = CloudSpec::MultiRegion {
        keep_alive: secs(300),
        concurrency: 4,
        extra_latency: ms_f(40.0),
    };
    let spec = if outage {
        FaultSpec::default().outage(0, secs(100), secs(200))
    } else {
        FaultSpec::default()
    };
    run_cluster_faulted(policy, &wl, seed, FAAS_EDGES, &cloud, None,
                        Some(&spec))
}

/// `breaker-outage`: the region-outage chaos configuration with the
/// resilience layer armed — circuit breakers short-circuit dispatches
/// into the dead region's throttle storm so DEMS-A re-plans to the edge
/// immediately, and graceful degradation converts the resulting edge
/// pressure into discounted completions. A scenario test pins that
/// DEMS-A+resilience strictly beats plain DEMS-A on completion rate and
/// total utility under the outage.
pub fn breaker_outage_report(seed: u64, pool: &Pool) -> Result<Report> {
    let plain = Policy::dems_a();
    let armed = Policy::dems_a()
        .with_resilience(breaker_outage_resilience());
    let cells: Vec<(&str, &Policy, bool)> = vec![
        ("dems-a", &plain, false),
        ("dems-a", &plain, true),
        ("dems-a+resil", &armed, false),
        ("dems-a+resil", &armed, true),
    ];
    let metrics = pool.run(cells.len(), |j| {
        let (_, policy, outage) = cells[j];
        run_breaker_outage_cell(policy, outage, seed)
    });
    let mut rep = Report::new(
        "breaker-outage",
        "Resilience — circuit breaker + degradation under a primary \
         FaaS region outage (DEMS-A, 4D-A)",
        seed,
    );
    let mut t = Table::new(&[
        "algo", "outage", "tasks", "done", "done %", "total util",
        "trips", "shorted", "probes", "degraded", "throttled",
    ]);
    for ((label, _, outage), cm) in cells.iter().zip(&metrics) {
        t.push_row(vec![
            Cell::str(*label),
            Cell::str(if *outage { "100-200 s" } else { "none" }),
            Cell::uint(cm.generated()),
            Cell::uint(cm.completed()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_utility() / 1e5, 2),
            Cell::uint(cm.breaker_trips()),
            Cell::uint(cm.breaker_shorted()),
            Cell::uint(cm.breaker_probes()),
            Cell::uint(cm.degraded_tasks()),
            Cell::uint(cm.throttled()),
        ]);
    }
    push_drop_breakdown(&mut t, &metrics);
    rep.table(t);
    rep.text(
        "(same outage as `region-outage`: region 0 refuses every \
         invocation between 100 s and 200 s, shaped as throttles. Plain \
         DEMS-A burns deadline headroom retrying into the storm; with \
         the breaker armed, the failure-rate window trips per edge, \
         open breakers short-circuit further dispatches so the \
         scheduler re-plans immediately, and half-open probes detect \
         the recovery. Degradation (lite model variants at a utility \
         discount) absorbs the extra edge pressure. Hedging is off — \
         duplicates would fight primaries for the surviving region's \
         slots.)"
            .to_string(),
    );
    Ok(rep)
}

/// Hedge arming for the `hedged-tail` rows and pin test: a 300 ms fire
/// delay with no extra slack screen, so every cloud invocation whose
/// sampled duration exceeds the delay launches a deadline-feasible
/// speculative duplicate.
fn hedged_tail_resilience() -> ResilienceSpec {
    ResilienceSpec {
        hedge: true,
        hedge_delay: ms(300),
        hedge_slack: 0,
        ..ResilienceSpec::default()
    }
}

/// One `hedged-tail` cell: a 1 s keep-alive FaaS account (heavy
/// cold-start tail mass) under plain or hedged DEMS-A.
fn run_hedged_tail_cell(hedge: bool, seed: u64) -> ClusterMetrics {
    let policy = if hedge {
        Policy::dems_a().with_resilience(hedged_tail_resilience())
    } else {
        Policy::dems_a()
    };
    run_cluster(&policy, &Workload::emulation(4, true), seed, FAAS_EDGES,
                &CloudSpec::faas(secs(1), 64))
}

/// `hedged-tail`: speculative duplicates against the cloud latency tail
/// — a short keep-alive makes cold starts frequent, so the p99 cloud
/// leg is dominated by 900 ms-class init penalties; a hedge fired
/// 300 ms in races a fresh draw against the straggler and the first
/// usable completion wins (the loser is cancelled client-side and
/// bills in full). A scenario test pins that hedging strictly reduces
/// the p99 cloud latency.
pub fn hedged_tail_report(seed: u64, pool: &Pool) -> Result<Report> {
    let cells = [false, true];
    let metrics = pool.run(cells.len(), |j| {
        run_hedged_tail_cell(cells[j], seed)
    });
    let mut rep = Report::new(
        "hedged-tail",
        "Resilience — hedged requests vs the cold-start latency tail \
         (DEMS-A, 4D-A, 1 s keep-alive FaaS)",
        seed,
    );
    let mut t = Table::new(&[
        "hedging", "tasks", "done %", "QoS util", "cloud p50 (ms)",
        "cloud p99 (ms)", "hedges", "wins", "cancels", "cloud $",
    ]);
    for (hedge, cm) in cells.iter().zip(&metrics) {
        t.push_row(vec![
            Cell::str(if *hedge { "300 ms" } else { "off" }),
            Cell::uint(cm.generated()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_qos_utility() / 1e5, 2),
            Cell::float(cm.cloud_latency_percentile(0.50), 0),
            Cell::float(cm.cloud_latency_percentile(0.99), 0),
            Cell::uint(cm.hedge_launches()),
            Cell::uint(cm.hedge_wins()),
            Cell::uint(cm.hedge_cancels()),
            Cell::dollars(cm.cloud_stats().dollars),
        ]);
    }
    rep.table(t);
    rep.text(
        "(cloud p50/p99 = percentiles of the usable cloud-leg latency \
         across completed, missed and timed-out cloud tasks. A hedged \
         task's recorded latency is the winning leg's — effectively \
         min(primary, 300 ms + duplicate) — so the tail compresses \
         while the median barely moves. The price is the losing leg's \
         bill: hedging buys latency with dollars, never with \
         correctness (each task still finalizes exactly once).)"
            .to_string(),
    );
    Ok(rep)
}

/// One `degraded-overload` cell: the overloaded 4D-A mix under plain or
/// degradation-armed DEMS-A.
fn run_degraded_overload_cell(degrade: bool, seed: u64) -> ClusterMetrics {
    let policy = if degrade {
        Policy::dems_a().with_resilience(overload_degrade())
    } else {
        Policy::dems_a()
    };
    run_cluster(&policy, &Workload::emulation(4, true), seed, FAAS_EDGES,
                &CloudSpec::NominalWan)
}

/// `degraded-overload`: graceful degradation on the overloaded 4D-A mix
/// — when the edge queue crosses the high-water mark the controller
/// switches the station to lite model variants (faster, slightly less
/// accurate, utility discounted), and hysteresis switches back only
/// after the queue drains below the low-water mark. A scenario test
/// pins that degradation strictly improves the completion rate.
pub fn degraded_overload_report(seed: u64, pool: &Pool) -> Result<Report> {
    let cells = [false, true];
    let metrics = pool.run(cells.len(), |j| {
        run_degraded_overload_cell(cells[j], seed)
    });
    let mut rep = Report::new(
        "degraded-overload",
        "Resilience — graceful degradation under edge overload \
         (DEMS-A, 4D-A)",
        seed,
    );
    let mut t = Table::new(&[
        "degradation", "tasks", "done", "done %", "QoS util",
        "total util", "degraded", "util lost",
    ]);
    for (degrade, cm) in cells.iter().zip(&metrics) {
        t.push_row(vec![
            Cell::str(if *degrade { "3/1 hysteresis" } else { "off" }),
            Cell::uint(cm.generated()),
            Cell::uint(cm.completed()),
            Cell::percent(100.0 * cm.completion_rate(), 1),
            Cell::float(cm.total_qos_utility() / 1e5, 2),
            Cell::float(cm.total_utility() / 1e5, 2),
            Cell::uint(cm.degraded_tasks()),
            Cell::float(cm.degraded_utility_lost() / 1e5, 2),
        ]);
    }
    rep.table(t);
    rep.text(
        "(degraded counts edge executions run as lite variants — e.g. \
         Cd at 0.55× its service time for 0.82× its utility; util lost \
         totals the discount forfeited on successful lite completions. \
         Under overload the throughput gained outweighs the discount: \
         more tasks finish inside their deadlines, at slightly lower \
         per-task utility.)"
            .to_string(),
    );
    Ok(rep)
}

// ------------------------------------------------- pipeline scenarios

/// Stations per cluster for the split-DNN pipeline scenarios.
const PIPELINE_EDGES: usize = 2;

/// Run one partition-cut cell of the pipeline scenarios: the VIP
/// split-DNN chain ([`Workload::vip_pipeline`]) under DEMS with the
/// given partition decision. Each cell builds its own cluster from the
/// raw seed, so the sweep stays shared-nothing and `--jobs` reports are
/// byte-identical.
fn run_pipeline_cell(cut: PipelineCut, seed: u64) -> ClusterMetrics {
    let wl = Workload::vip_pipeline();
    let policy = Policy::dems().with_pipeline_cut(cut);
    run_cluster(&policy, &wl, seed, PIPELINE_EDGES,
                &CloudSpec::NominalWan)
}

/// Summary row shared by the pipeline scenario tables: stage-task
/// totals, end-to-end completion and QoS utility, and where the stages
/// ran.
fn pipeline_row(label: &str, cm: &ClusterMetrics) -> Vec<Cell> {
    let on = |r: Resource| -> u64 {
        cm.per_edge.iter().map(|m| m.completed_on(r)).sum()
    };
    vec![
        Cell::str(label),
        Cell::uint(cm.generated()),
        Cell::uint(cm.completed()),
        Cell::percent(100.0 * cm.completion_rate(), 1),
        Cell::float(cm.total_qos_utility() / 1e5, 2),
        Cell::uint(on(Resource::Drone)),
        Cell::uint(on(Resource::Edge)),
        Cell::uint(on(Resource::Cloud)),
    ]
}

const PIPELINE_COLS: [&str; 8] = [
    "cut", "stage tasks", "done", "done %", "QoS util", "drone done",
    "edge done", "cloud done",
];

/// `split-pipeline`: the partition point of the 3-stage VIP chain
/// (Hv → Md → Deo) as a scheduling decision — adaptive DEMS (drone
/// prefix planned against per-stage deadlines, tail stages placed by
/// κ-ranked admission) against representative fixed cuts. A scenario
/// test pins that adaptive strictly beats both the edge-only and the
/// cloud-only fixed cut on end-to-end QoS utility.
pub fn split_pipeline_report(seed: u64, pool: &Pool) -> Result<Report> {
    let cuts: [(&str, PipelineCut); 5] = [
        ("adaptive", PipelineCut::Adaptive),
        ("edge-only", PipelineCut::Fixed { drone: 0, cloud_start: 3 }),
        ("cloud-only", PipelineCut::Fixed { drone: 0, cloud_start: 0 }),
        ("drone+edge", PipelineCut::Fixed { drone: 2, cloud_start: 3 }),
        ("drone+cloud", PipelineCut::Fixed { drone: 2, cloud_start: 2 }),
    ];
    let metrics =
        pool.run(cuts.len(), |j| run_pipeline_cell(cuts[j].1, seed));
    let mut rep = Report::new(
        "split-pipeline",
        "Split-DNN pipeline — adaptive vs fixed partition cuts \
         (Hv → Md → Deo chain)",
        seed,
    );
    let mut t = Table::new(&PIPELINE_COLS);
    for ((label, _), cm) in cuts.iter().zip(&metrics) {
        t.push_row(pipeline_row(label, cm));
    }
    rep.table(t);
    rep.text(
        "(Each chain is one Hv → Md → Deo split-DNN inference with an \
         end-to-end deadline; stage tasks counts every spawned stage. \
         edge-only runs all three stages at the station, cloud-only \
         pins all three to the cloud — its first stage cannot meet its \
         per-stage deadline over the WAN; drone+X runs the first two \
         stages on the capturing drone and the tail at X. adaptive \
         plans the drone prefix against per-stage deadlines and leaves \
         the tail to DEMS's κ-ranked edge/cloud admission.)"
            .to_string(),
    );
    Ok(rep)
}

/// `partition-sweep`: the full fixed-cut grid of the 3-stage chain —
/// every `(drone prefix d, first cloud stage c)` with `d ≤ c` — next to
/// the adaptive policy, mapping where each placement's QoS comes from.
pub fn partition_sweep_report(seed: u64, pool: &Pool) -> Result<Report> {
    let mut cuts: Vec<(String, PipelineCut)> = Vec::new();
    for d in 0..=2usize {
        for c in d..=3usize {
            cuts.push((
                format!("d<{d} c>={c}"),
                PipelineCut::Fixed { drone: d, cloud_start: c },
            ));
        }
    }
    cuts.push(("adaptive".to_string(), PipelineCut::Adaptive));
    let metrics =
        pool.run(cuts.len(), |j| run_pipeline_cell(cuts[j].1, seed));
    let mut rep = Report::new(
        "partition-sweep",
        "Split-DNN pipeline — fixed-cut grid vs the adaptive partition \
         (Hv → Md → Deo chain)",
        seed,
    );
    let mut t = Table::new(&PIPELINE_COLS);
    for ((label, _), cm) in cuts.iter().zip(&metrics) {
        t.push_row(pipeline_row(label.as_str(), cm));
    }
    rep.table(t);
    rep.text(
        "(cut d<N c>=M: stages below N run on the capturing drone, \
         stages at or above M are pinned to the cloud, the rest run at \
         the edge station. Stage 2 (Deo) is not drone-capable, so the \
         drone prefix tops out at 2. The adaptive row is the same \
         partition decision made by DEMS at admission time.)"
            .to_string(),
    );
    Ok(rep)
}

// --------------------------------------------------------------- registry

/// One runnable experiment in the registry.
pub struct ScenarioEntry {
    pub id: &'static str,
    pub about: &'static str,
    /// Reproduces a paper table/figure (vs a beyond-paper scenario).
    pub paper: bool,
}

/// Every runnable experiment, paper order first, beyond-paper last.
pub fn registry() -> Vec<ScenarioEntry> {
    fn e(id: &'static str, about: &'static str,
         paper: bool) -> ScenarioEntry {
        ScenarioEntry { id, about, paper }
    }
    vec![
        e("t1", "Table 1 — workload configuration", true),
        e("fig1", "Fig 1 — inferencing time distributions", true),
        e("fig2", "Fig 2 — network characteristics", true),
        e("fig8", "Fig 8/9 — DEMS vs baselines across workloads", true),
        e("fig10", "Fig 10 — DEM/DEMS incremental benefits", true),
        e("fig11", "Fig 11/12 — DEMS-A under network variability", true),
        e("fig13", "Fig 13 — weak scaling, 7→28 edges", true),
        e("fig14", "Fig 14/15 — GEMS vs DEMS QoE study", true),
        e("fig17", "Fig 17 — field validation + post-processing", true),
        e("fig18", "Fig 18 — drone mobility error metrics", true),
        e("poisson", "arrival processes: periodic vs Poisson vs bursty",
          false),
        e("churn", "mid-run drone join/leave on 4D-P", false),
        e("hetero-edges", "mixed per-edge fleets and hardware", false),
        e("cold-start-sweep",
          "FaaS keep-alive sweep: cold-start rate vs cloud cost", false),
        e("throttled-cloud",
          "FaaS concurrency ceiling: throttling vs adaptation + failover",
          false),
        e("cost-frontier",
          "FaaS keep-alive x concurrency vs QoS utility per dollar",
          false),
        e("fed-steal",
          "fleet federation: cross-edge work stealing under imbalance",
          false),
        e("handover-churn",
          "fleet federation: drone handover at the churn boundary",
          false),
        e("shared-uplink",
          "fleet federation: shared-backhaul contention vs adaptation",
          false),
        e("split-pipeline",
          "split-DNN pipelines: adaptive vs fixed drone/edge/cloud cuts",
          false),
        e("partition-sweep",
          "split-DNN pipelines: the full fixed-cut grid vs adaptive",
          false),
        e("node-crash",
          "chaos: mid-run station crash — lose vs federated requeue",
          false),
        e("region-outage",
          "chaos: primary FaaS region outage with two-region failover",
          false),
        e("partition",
          "chaos: backhaul/LAN degradation windows on the federated fleet",
          false),
        e("breaker-outage",
          "resilience: circuit breaker + degradation vs a region outage",
          false),
        e("hedged-tail",
          "resilience: hedged requests vs the cold-start latency tail",
          false),
        e("degraded-overload",
          "resilience: graceful degradation under edge overload",
          false),
        e("timeline",
          "observability: windowed time-series metrics over one run",
          false),
    ]
}

/// Run one registered experiment by id (paper aliases like `fig9`,
/// `fig23` resolve to their canonical entry, as the CLI always has).
/// Sequential; the CLI's `--jobs` surface is [`run_scenario_jobs`].
pub fn run_scenario(id: &str, seed: u64) -> Result<Report> {
    run_scenario_jobs(id, seed, 1)
}

/// [`run_scenario`] with an explicit worker count (`0` = auto).
///
/// Grid-shaped experiments (fig8/fig10/fig13 and every [`Scenario`]) fan
/// their cells out over a [`Pool`]; the rest are single runs or
/// interleaved timelines where parallelism has nothing to grab, and run
/// unchanged. Reports are byte-identical for every `jobs` value.
pub fn run_scenario_jobs(id: &str, seed: u64, jobs: usize) -> Result<Report> {
    let pool = Pool::new(jobs);
    match id {
        "t1" => exp::t1_report(seed),
        "fig1" => exp::fig1_report(seed),
        "fig2" => exp::fig2_report(seed),
        "fig8" | "fig9" | "fig23" => exp::fig8_report(seed, &pool),
        "fig10" | "fig24" => exp::fig10_report(seed, &pool),
        "fig11" | "fig12" | "fig25" => exp::fig11_report(seed, "4D-P"),
        "fig21" | "fig22" | "fig26" => exp::fig11_report(seed, "3D-P"),
        "fig13" | "fig27" => exp::fig13_report(seed, &pool),
        "fig14" | "fig15" => exp::fig14_report(seed),
        "fig17" => exp::fig17_report(seed),
        "fig18" => exp::fig18_report(seed),
        "poisson" => poisson_scenario().run_jobs(seed, jobs),
        "churn" => churn_scenario().run_jobs(seed, jobs),
        "hetero-edges" => hetero_scenario().run_jobs(seed, jobs),
        "cold-start-sweep" => cold_start_sweep_report(seed, &pool),
        "throttled-cloud" => throttled_cloud_report(seed, &pool),
        "cost-frontier" => cost_frontier_report(seed, &pool),
        "fed-steal" => fed_steal_report(seed, &pool),
        "handover-churn" => handover_churn_report(seed, &pool),
        "shared-uplink" => shared_uplink_report(seed, &pool),
        "split-pipeline" => split_pipeline_report(seed, &pool),
        "partition-sweep" => partition_sweep_report(seed, &pool),
        "node-crash" => node_crash_report(seed, &pool),
        "region-outage" => region_outage_report(seed, &pool),
        "partition" => partition_report(seed, &pool),
        "breaker-outage" => breaker_outage_report(seed, &pool),
        "hedged-tail" => hedged_tail_report(seed, &pool),
        "degraded-overload" => degraded_overload_report(seed, &pool),
        "timeline" => timeline_report(seed, &pool),
        other => {
            let known: Vec<&str> =
                registry().iter().map(|e| e.id).collect();
            bail!("unknown experiment {other:?}; known: {known:?} or all")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_workload() -> Workload {
        Workload::emulation(2, false).with_duration(secs(20))
    }

    #[test]
    fn builder_composes_axes() {
        let sc = Scenario::new("x", "X")
            .workload(mini_workload())
            .workload(mini_workload().with_arrival(Arrival::Poisson))
            .policy(Policy::dems())
            .edges(2)
            .seeds(2)
            .cloud(CloudSpec::TrapeziumLatency)
            .note("n");
        assert_eq!(sc.workloads.len(), 2);
        assert_eq!(sc.policies.len(), 1);
        assert_eq!(sc.edges, 2);
        assert_eq!(sc.seeds, 2);
        assert_eq!(sc.notes.len(), 1);
    }

    #[test]
    fn uniform_run_tabulates_the_full_grid() {
        let sc = Scenario::new("mini", "Mini grid")
            .workload(mini_workload())
            .workload(
                mini_workload()
                    .with_arrival(Arrival::Poisson)
                    .with_name("2D-P-poi"),
            )
            .policies(vec![Policy::edf_ec(), Policy::dems()])
            .edges(2)
            .seeds(2);
        let rep = sc.run(7).expect("runs");
        let tables = rep.tables();
        assert_eq!(tables.len(), 1);
        // 2 workloads × 2 policies × 2 seeds.
        assert_eq!(tables[0].rows.len(), 8);
        // Determinism: the whole report reproduces from the same seed.
        assert_eq!(rep, sc.run(7).unwrap());
        // And a different base seed changes at least the id-stamped seed.
        let other = sc.run(8).unwrap();
        assert_eq!(other.seed, 8);
    }

    #[test]
    fn hetero_run_reports_per_edge_tables() {
        let sc = Scenario::new("mini-het", "Mini hetero")
            .policies(vec![Policy::dems()])
            .hetero_edge(mini_workload(), 1.0)
            .hetero_edge(
                Workload::emulation(3, false).with_duration(secs(20)),
                1.5,
            );
        let rep = sc.run(3).expect("runs");
        let tables = rep.tables();
        // One summary + one per-edge detail table.
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 1);
        assert_eq!(tables[1].rows.len(), 2);
        // The slow edge carries the 3-drone workload's task count.
        let gen_row1 = &tables[1].rows[1];
        match gen_row1[3].value {
            Value::Int(v) => assert_eq!(
                v as u64,
                Workload::emulation(3, false)
                    .with_duration(secs(20))
                    .total_tasks()
            ),
            ref other => panic!("expected Int, got {other:?}"),
        }
    }

    #[test]
    fn scale_edge_times_scales_expectations() {
        let base = Workload::emulation(2, false).models;
        let slow = scale_edge_times(&base, 1.5);
        for (a, b) in base.iter().zip(&slow) {
            assert_eq!(b.t_edge, ((a.t_edge as f64) * 1.5).round()
                as Micros);
            // Utilities are a property of the model, not the hardware.
            assert_eq!(a.util_edge(), b.util_edge());
        }
    }

    #[test]
    fn empty_scenarios_are_rejected() {
        assert!(Scenario::new("x", "X").run(1).is_err());
        assert!(Scenario::new("x", "X")
            .policy(Policy::dems())
            .run(1)
            .is_err());
        assert!(Scenario::new("x", "X")
            .workload(mini_workload())
            .policy(Policy::dems())
            .edges(0)
            .run(1)
            .is_err());
    }

    #[test]
    fn cloud_specs_build_their_backends() {
        assert_eq!(CloudSpec::NominalWan.build().name(), "simple");
        assert_eq!(CloudSpec::TrapeziumLatency.build().name(), "simple");
        let faas = CloudSpec::Faas {
            keep_alive: secs(30),
            concurrency: 8,
            retry_after: ms_f(350.0),
        };
        assert_eq!(faas.build().name(), "faas");
        // The convenience constructor pins the backend default backoff.
        match CloudSpec::faas(secs(30), 8) {
            CloudSpec::Faas { retry_after, .. } => {
                assert_eq!(retry_after, ms_f(200.0));
            }
            other => panic!("expected Faas, got {other:?}"),
        }
        let mr = CloudSpec::MultiRegion {
            keep_alive: secs(30),
            concurrency: 8,
            extra_latency: ms_f(40.0),
        };
        assert_eq!(mr.build().name(), "multi-region");
    }

    #[test]
    fn faas_keep_alive_reduces_cold_rate_and_bills() {
        let wl = Workload::emulation(3, true).with_duration(secs(60));
        let all_cold = run_cluster(
            &Policy::dems(),
            &wl,
            5,
            1,
            &CloudSpec::faas(0, 64),
        );
        let kept_warm = run_cluster(
            &Policy::dems(),
            &wl,
            5,
            1,
            &CloudSpec::faas(secs(120), 64),
        );
        let (c, w) = (all_cold.cloud_stats(), kept_warm.cloud_stats());
        assert!(c.invocations > 0, "DEMS offloads to the cloud");
        assert_eq!(c.cold_start_rate(), 1.0,
                   "keep-alive 0 makes every invocation cold");
        assert!(w.cold_start_rate() < c.cold_start_rate(),
                "keep-alive must reduce cold starts: {} vs {}",
                w.cold_start_rate(), c.cold_start_rate());
        assert!(c.dollars > 0.0 && w.dollars > 0.0);
        // Cold inits bill extra: cost per invocation is strictly higher
        // when every invocation pays its init.
        assert!(c.gb_seconds / c.invocations as f64
                    > w.gb_seconds / w.invocations as f64,
                "per-invocation GB-s must shrink with warm reuse");
        // A generous ceiling never throttles.
        assert_eq!(all_cold.throttled(), 0);
        assert_eq!(kept_warm.throttled(), 0);
    }

    #[test]
    fn registry_covers_paper_and_beyond() {
        let reg = registry();
        let ids: Vec<&str> = reg.iter().map(|e| e.id).collect();
        for id in ["t1", "fig8", "fig17", "poisson", "churn",
                   "hetero-edges", "fed-steal", "handover-churn",
                   "shared-uplink"] {
            assert!(ids.contains(&id), "{id} missing from registry");
        }
        assert!(reg.iter().filter(|e| !e.paper).count() >= 3,
                "at least three beyond-paper scenarios");
        assert!(run_scenario("nope", 1).is_err());
    }

    #[test]
    fn fed_steal_strictly_improves_over_isolated_dems_a() {
        // The acceptance pin: under the imbalanced bursty fed-steal mix,
        // cross-edge stealing strictly improves task completion AND
        // total utility over edge-isolated DEMS-A — idle light stations
        // rescue the overloaded sibling's deferred (and about-to-drop
        // negative-utility) tasks at full edge utility.
        let wls = fed_steal_workloads();
        let iso = run_fed_cell(&Policy::dems_a(), &wls, 42,
                               &CloudSpec::NominalWan, None);
        let fed = run_fed_cell(&Policy::dems_a(), &wls, 42,
                               &CloudSpec::NominalWan,
                               Some(Federation::stealing()));
        assert!(fed.fed_steals() > 0, "steals must occur");
        assert_eq!(fed.generated(), iso.generated(),
                   "stealing moves work, it never creates it");
        assert!(
            fed.completed() > iso.completed(),
            "federated completion must strictly improve: {} vs {}",
            fed.completed(),
            iso.completed()
        );
        assert!(
            fed.total_utility() > iso.total_utility(),
            "federated total utility must strictly improve: {:.0} vs {:.0}",
            fed.total_utility(),
            iso.total_utility()
        );
    }

    #[test]
    fn crash_recovery_federated_requeue_beats_isolated() {
        // The acceptance pin: with the overloaded station crashing
        // mid-run (120 s → 210 s), federated DEMS-A with requeue
        // recovery strictly beats edge-isolated DEMS-A on completion
        // rate AND total utility — stealing drains the doomed backlog
        // before the crash, and requeue relocates the still-feasible
        // orphaned queue over the LAN at the crash instant, while the
        // isolated fleet loses everything the dead station held.
        let wls = fed_steal_workloads();
        let iso = run_fault_cell(&Policy::dems_a(), &wls, 42,
                                 &CloudSpec::NominalWan, None,
                                 &node_crash_spec(Recovery::Lose));
        let fed = run_fault_cell(&Policy::dems_a(), &wls, 42,
                                 &CloudSpec::NominalWan,
                                 Some(Federation::stealing()),
                                 &node_crash_spec(Recovery::Requeue));
        assert_eq!(iso.crashes(), 1);
        assert_eq!(fed.crashes(), 1);
        assert_eq!(fed.recoveries(), 1);
        assert_eq!(fed.generated(), iso.generated(),
                   "faults and stealing never change generation");
        assert!(fed.fault_relocated() + fed.node_failures() > 0,
                "the crashed overloaded station must have held work");
        assert!(
            fed.completion_rate() > iso.completion_rate(),
            "federated requeue completion must strictly improve: {} vs {}",
            fed.completed(),
            iso.completed()
        );
        assert!(
            fed.total_utility() > iso.total_utility(),
            "federated requeue total utility must strictly improve: \
             {:.0} vs {:.0}",
            fed.total_utility(),
            iso.total_utility()
        );
    }

    #[test]
    fn empty_fault_spec_keeps_scenario_runs_bit_identical() {
        assert!(!FaultSpec::default().enabled());
        assert!(FaultSpec::default().crash(0, secs(5), None).enabled());
        // An empty spec must leave run_cluster_faulted on the
        // bit-identical fault-free path.
        let wl = mini_workload();
        let a = run_cluster(&Policy::dems(), &wl, 5, 2,
                            &CloudSpec::NominalWan);
        let b = run_cluster_faulted(&Policy::dems(), &wl, 5, 2,
                                    &CloudSpec::NominalWan, None,
                                    Some(&FaultSpec::default()));
        assert_eq!(a, b);
    }

    #[test]
    fn node_crash_report_conserves_generation_across_rows() {
        let rep = node_crash_report(7, &Pool::new(1)).expect("runs");
        let tables = rep.tables();
        assert_eq!(tables.len(), 1);
        let rows = &tables[0].rows;
        // no-fault + isolated-lose + federated-lose + federated-requeue.
        assert_eq!(rows.len(), 4);
        // Task totals (column 2) identical in every row — crashes change
        // outcomes, never generation; the crash itself (column 6) shows
        // in exactly the three faulted rows.
        for r in &rows[1..] {
            assert_eq!(r[2].value, rows[0][2].value,
                       "faults must not change generation totals");
        }
        assert_eq!(rows[0][6].value, Value::Int(0));
        for r in &rows[1..] {
            assert_eq!(r[6].value, Value::Int(1));
        }
    }

    fn cluster_closed(cm: &ClusterMetrics) -> u64 {
        cm.per_edge
            .iter()
            .flat_map(|m| m.per_model.iter())
            .map(|(_, s)| s.executed() + s.dropped())
            .sum()
    }

    #[test]
    fn breaker_outage_resilience_strictly_beats_plain_dems_a() {
        // The acceptance pin: under the 100–200 s primary-region outage,
        // DEMS-A with breaker+degradation armed strictly beats plain
        // DEMS-A on completion rate AND total utility — open breakers
        // stop dispatches burning deadline headroom in the throttle
        // storm, and lite variants absorb the diverted edge pressure.
        let plain =
            run_breaker_outage_cell(&Policy::dems_a(), true, 42);
        let armed = run_breaker_outage_cell(
            &Policy::dems_a()
                .with_resilience(breaker_outage_resilience()),
            true, 42);
        assert_eq!(armed.generated(), plain.generated(),
                   "resilience never changes what is generated");
        assert_eq!(armed.generated(), cluster_closed(&armed),
                   "conservation closes with resilience armed");
        assert!(armed.breaker_trips() > 0,
                "the outage's throttle storm must trip a breaker");
        assert!(armed.breaker_shorted() > 0,
                "open breakers must short-circuit dispatches");
        assert!(
            armed.completion_rate() > plain.completion_rate(),
            "armed completion must strictly improve: {} vs {}",
            armed.completed(),
            plain.completed()
        );
        assert!(
            armed.total_utility() > plain.total_utility(),
            "armed total utility must strictly improve: {:.0} vs {:.0}",
            armed.total_utility(),
            plain.total_utility()
        );
    }

    #[test]
    fn hedged_tail_reduces_cloud_p99_latency() {
        // The acceptance pin: on the 1 s keep-alive account, hedging
        // strictly reduces the p99 cloud-leg latency — the tail is
        // cold-start stragglers, and min(primary, 300 ms + duplicate)
        // beats them.
        let plain = run_hedged_tail_cell(false, 42);
        let hedged = run_hedged_tail_cell(true, 42);
        assert_eq!(hedged.generated(), plain.generated());
        assert_eq!(hedged.generated(), cluster_closed(&hedged),
                   "every hedged task finalizes exactly once");
        assert!(hedged.hedge_launches() > 0, "hedges must fire");
        assert!(hedged.hedge_wins() > 0,
                "some duplicates must beat their stragglers");
        assert!(hedged.hedge_cancels() > 0,
                "losing legs must be cancelled");
        let (p99_plain, p99_hedged) = (
            plain.cloud_latency_percentile(0.99),
            hedged.cloud_latency_percentile(0.99),
        );
        assert!(
            p99_hedged < p99_plain,
            "hedging must compress the tail: p99 {p99_hedged:.0} ms vs \
             {p99_plain:.0} ms"
        );
    }

    #[test]
    fn degraded_overload_strictly_improves_completion() {
        // The acceptance pin: under the overloaded 4D-A mix, lite-variant
        // degradation strictly improves the completion rate — throughput
        // bought with the utility discount.
        let plain = run_degraded_overload_cell(false, 42);
        let degraded = run_degraded_overload_cell(true, 42);
        assert_eq!(degraded.generated(), plain.generated());
        assert_eq!(degraded.generated(), cluster_closed(&degraded));
        assert!(degraded.degraded_tasks() > 0,
                "overload must engage the lite variants");
        assert!(degraded.degraded_utility_lost() > 0.0);
        assert!(
            degraded.completion_rate() > plain.completion_rate(),
            "degradation must strictly improve completion: {} vs {}",
            degraded.completed(),
            plain.completed()
        );
    }

    #[test]
    fn all_off_resilience_spec_is_bit_identical() {
        // A policy carrying the default (all-off) ResilienceSpec builds
        // no state machines and must reproduce the plain engine bit for
        // bit — goldens and --jobs parity stay untouched.
        let wl = mini_workload();
        let a = run_cluster(&Policy::dems_a(), &wl, 5, 2,
                            &CloudSpec::NominalWan);
        let b = run_cluster(
            &Policy::dems_a().with_resilience(ResilienceSpec::default()),
            &wl, 5, 2, &CloudSpec::NominalWan);
        assert_eq!(a, b, "all-off resilience must change nothing");
    }

    #[test]
    fn resilience_reports_tabulate_their_rows() {
        let rep = breaker_outage_report(7, &Pool::new(1)).expect("runs");
        assert_eq!(rep.tables()[0].rows.len(), 4);
        let rep = degraded_overload_report(7, &Pool::new(1))
            .expect("runs");
        assert_eq!(rep.tables()[0].rows.len(), 2);
    }

    #[test]
    fn split_pipeline_adaptive_beats_fixed_cuts() {
        // The acceptance pin: on the VIP split-DNN chain, the
        // stage-aware adaptive partition (drone prefix planned against
        // per-stage deadlines, tail placed by DEMS) strictly beats both
        // degenerate fixed cuts on end-to-end QoS utility — edge-only
        // overloads the station with the full chain's work, cloud-only
        // dies on the first stage's per-stage deadline over the WAN.
        let adaptive = run_pipeline_cell(PipelineCut::Adaptive, 42);
        let edge_only = run_pipeline_cell(
            PipelineCut::Fixed { drone: 0, cloud_start: 3 }, 42);
        let cloud_only = run_pipeline_cell(
            PipelineCut::Fixed { drone: 0, cloud_start: 0 }, 42);
        let drone_done: u64 = adaptive
            .per_edge
            .iter()
            .map(|m| m.completed_on(Resource::Drone))
            .sum();
        assert!(drone_done > 0,
                "adaptive must run early stages on the drone tier");
        assert!(
            adaptive.total_qos_utility() > edge_only.total_qos_utility(),
            "adaptive must strictly beat the edge-only cut: {:.0} vs {:.0}",
            adaptive.total_qos_utility(),
            edge_only.total_qos_utility()
        );
        assert!(
            adaptive.total_qos_utility() > cloud_only.total_qos_utility(),
            "adaptive must strictly beat the cloud-only cut: {:.0} vs {:.0}",
            adaptive.total_qos_utility(),
            cloud_only.total_qos_utility()
        );
    }

    #[test]
    fn pipeline_reports_tabulate_every_cut() {
        let rep = split_pipeline_report(7, &Pool::new(1)).expect("runs");
        let tables = rep.tables();
        assert_eq!(tables.len(), 1);
        // adaptive + 4 fixed cuts.
        assert_eq!(tables[0].rows.len(), 5);
        let rep = partition_sweep_report(7, &Pool::new(1)).expect("runs");
        // 4 + 3 + 2 fixed cells + the adaptive row.
        assert_eq!(rep.tables()[0].rows.len(), 10);
    }

    #[test]
    fn federation_spec_builds_and_gates() {
        assert!(!FederationSpec::default().enabled());
        assert!(FederationSpec::stealing().enabled());
        assert!(FederationSpec {
            uplink_bytes_per_sec: Some(1.0e6),
            ..Default::default()
        }
        .enabled());
        let spec = FederationSpec {
            steal: true,
            handovers: vec![Handover { at: secs(10), drone: 0, to_edge: 1 }],
            uplink_bytes_per_sec: Some(2.0e6),
        };
        let fed = spec.build();
        assert!(fed.steal && fed.enabled());
        assert_eq!(fed.handovers.len(), 1);
        assert_eq!(fed.uplink_bytes_per_sec, Some(2.0e6));
        // An all-off spec must leave run_cluster_federated on the
        // bit-identical unfederated path.
        let wl = mini_workload();
        let a = run_cluster(&Policy::dems(), &wl, 5, 2,
                            &CloudSpec::NominalWan);
        let b = run_cluster_federated(&Policy::dems(), &wl, 5, 2,
                                      &CloudSpec::NominalWan,
                                      Some(&FederationSpec::default()));
        assert_eq!(a, b);
    }

    #[test]
    fn handover_moves_load_without_changing_totals() {
        let rep = handover_churn_report(7, &Pool::new(1)).expect("runs");
        let tables = rep.tables();
        assert_eq!(tables.len(), 1);
        // 2 policies × {off, handover}.
        assert_eq!(tables[0].rows.len(), 4);
        // Task totals identical within each policy pair (column 3), and
        // handover rows record exactly one handover (column 2).
        for pair in tables[0].rows.chunks(2) {
            assert_eq!(pair[0][3].value, pair[1][3].value,
                       "handover must not change generation totals");
            assert_eq!(pair[0][2].value, Value::Int(0));
            assert_eq!(pair[1][2].value, Value::Int(1));
        }
    }

    #[test]
    fn shared_uplink_contention_shows_in_the_report() {
        let rep = shared_uplink_report(7, &Pool::new(1)).expect("runs");
        let tables = rep.tables();
        let rows = &tables[0].rows;
        // 3 uplinks × 2 policies; "own" rows never queue, the 4 MB/s
        // rows always do.
        assert_eq!(rows.len(), 6);
        for r in &rows[0..2] {
            assert_eq!(r[6].value, Value::Int(0),
                       "own uplink never queues");
        }
        for r in &rows[4..6] {
            match &r[6].value {
                Value::Int(v) => assert!(*v > 0,
                    "4 MB/s shared uplink must queue dispatches"),
                other => panic!("expected Int, got {other:?}"),
            }
        }
    }
}
