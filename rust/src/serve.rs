//! Real-time serving path: the Fig. 4 architecture as actual threads, with
//! *real* PJRT inference on the request path.
//!
//! Thread-for-thread mirror of the paper's Java platform: a splitter/task
//! creation thread per drone stream, the task-scheduler + edge-executor
//! lane (single-threaded, synchronous — §3.3), a cloud executor thread
//! pool (FaaS latency simulated, inference executed locally on the same
//! compiled artifacts), and a results collector that runs the VIP app's
//! post-processing (PD offsets, pose classes, distances).
//!
//! Since the scheduler-API redesign the edge lane makes *every* decision
//! through the same [`Scheduler`](crate::sched::Scheduler) hooks the
//! simulation uses: arrivals go through `admit` against a live
//! [`Core`](crate::platform::Core) (whose wall-clock profiles are
//! calibrated at startup), deferred cloud entries are forwarded to the
//! FaaS pool when their trigger time arrives, an idle executor asks
//! `on_edge_idle` for a steal before popping its queue, and FaaS workers
//! report completed durations back to `on_cloud_report`, so `--policy
//! dems-a` genuinely adapts its expected cloud times to observed wall
//! clock. One caveat: the self-calibrated live profiles carry no QoE
//! targets (`qoe_rate = 0`), so GEMS' window monitor is inert here and
//! `--policy gems` behaves as DEMS plus the shared hooks.
//!
//! Unlike the DES engine (virtual time, sampled durations — used for the
//! paper-figure reproductions), this path measures *wall-clock* PJRT
//! latencies of the L1/L2 artifacts, self-calibrates deadlines from them,
//! and reports serving latency/throughput — the end-to-end proof that all
//! three layers compose.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::errors::Result;
use crate::exec::CloudExecModel;
use crate::metrics::{percentile, Metrics};
use crate::model::{DnnKind, ModelProfile};
use crate::nav::{bbox_offset, classify_pose};
use crate::net::ConstantNet;
use crate::platform::Core;
use crate::policy::Policy;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sched::{CloudReport, SchedCtx, Scheduler};
use crate::sim::EventQueue;
use crate::task::{Task, VideoSegment};
use crate::time::{ms, ms_f, Micros};

/// Serving configuration.
pub struct ServeConfig {
    /// Scheduling policy driving the edge lane (resolved via
    /// [`Policy::build`]); defaults to the EDF E+C hybrid the original
    /// serving loop hard-coded.
    pub policy: Policy,
    /// Segments per second per drone.
    pub rate: f64,
    pub drones: u32,
    pub duration: Duration,
    /// Cloud FaaS simulation: extra latency added on top of local
    /// execution of the same artifact.
    pub cloud_extra_ms: (f64, f64), // (median, sigma) lognormal
    pub cloud_pool: usize,
    /// Offload to the simulated cloud when the edge lane is infeasible.
    pub use_cloud: bool,
    /// Deadline as a multiple of the calibrated *whole-segment* p95 work
    /// (Σ per-model p95) — every model must fit its deadline even behind a
    /// full segment's worth of queued work, like the paper's Table-1
    /// deadlines (~1.3–6× the segment's total edge time).
    pub deadline_factor: f64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            policy: Policy::edf_ec(),
            rate: 2.0,
            drones: 2,
            duration: Duration::from_secs(10),
            cloud_extra_ms: (40.0, 0.3),
            cloud_pool: 4,
            use_cloud: true,
            deadline_factor: 1.5,
            seed: 42,
        }
    }
}

/// Wall-clock measurements for one model.
#[derive(Clone, Debug, Default)]
pub struct ModelServeStats {
    pub completed: u64,
    pub missed: u64,
    pub dropped: u64,
    pub on_cloud: u64,
    /// Completions executed on the edge after being stolen back from the
    /// deferred cloud queue (§5.3; only under stealing policies).
    pub stolen: u64,
    pub latency_ms: Vec<f64>,
    /// Post-processing wall-clock (Fig. 17b analogue), microseconds.
    pub postproc_us: Vec<f64>,
}

/// Full serving report.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub per_model: Vec<(DnnKind, ModelServeStats)>,
    pub wall_secs: f64,
    pub generated: u64,
    /// Calibrated per-model p95 edge latencies (ms).
    pub calibrated_ms: Vec<(DnnKind, f64)>,
}

impl ServeReport {
    pub fn completed(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.completed).sum()
    }

    pub fn throughput(&self) -> f64 {
        self.completed() as f64 / self.wall_secs
    }

    pub fn completion_rate(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.completed() as f64 / self.generated as f64
        }
    }
}

struct Shared {
    stats: Mutex<Vec<(DnnKind, ModelServeStats)>>,
    stop: AtomicBool,
    generated: AtomicU64,
}

fn bump(shared: &Shared, kind: DnnKind,
        f: impl FnOnce(&mut ModelServeStats)) {
    let mut stats = shared.stats.lock().unwrap();
    f(&mut stats.iter_mut().find(|(k, _)| *k == kind).unwrap().1)
}

/// Calibrate each loaded model: run it `n` times, return p95 wall ms.
pub fn calibrate(rt: &Runtime, n: usize) -> Result<Vec<(DnnKind, f64)>> {
    let mut out = Vec::new();
    for kind in rt.kinds() {
        let model = rt.model(kind).unwrap();
        let frame = rt.synth_frame(kind, 7)?;
        let mut lat = Vec::with_capacity(n);
        // One warm-up run (first execution touches cold code paths).
        let _ = model.infer(&frame)?;
        for _ in 0..n {
            let t0 = Instant::now();
            let _ = model.infer(&frame)?;
            lat.push(t0.elapsed().as_secs_f64() * 1_000.0);
        }
        out.push((kind, percentile(&lat, 0.95)));
    }
    Ok(out)
}

/// Run the serving loop; returns the wall-clock report.
///
/// Each executor thread loads its *own* PJRT runtime from `artifacts_dir`
/// (the `xla` crate's client is thread-local, exactly like the paper's
/// per-process gRPC inference service and per-Lambda model loads).
pub fn serve(artifacts_dir: &Path, cfg: &ServeConfig) -> Result<ServeReport> {
    let dir: PathBuf = artifacts_dir.to_path_buf();
    let rt = Runtime::load(&dir)?;
    let kinds = rt.kinds();
    let calibrated = calibrate(&rt, 20)?;
    // Build live profiles: deadline = factor × Σp95, edge cost 1.
    let segment_work_ms: f64 = calibrated.iter().map(|&(_, p)| p).sum();
    let profiles: Vec<ModelProfile> = calibrated
        .iter()
        .map(|&(kind, p95)| ModelProfile {
            kind,
            benefit: 100.0,
            deadline: ms_f(segment_work_ms * cfg.deadline_factor),
            t_edge: ms_f(p95),
            t_cloud: ms_f(p95 + 2.0 * cfg.cloud_extra_ms.0),
            cost_edge: 1.0,
            cost_cloud: 10.0,
            qoe_benefit: 0.0,
            qoe_rate: 0.0,
            qoe_window: ms_f(20_000.0),
        })
        .collect();

    // The edge lane's decision substrate: a live core + the configured
    // scheduler. The core's own cloud-exec model is inert (the worker pool
    // below simulates FaaS latency); it only backs the queue mechanics.
    let mut policy = cfg.policy.clone();
    policy.use_cloud = policy.use_cloud && cfg.use_cloud;
    let mut sched = policy.build();
    let mut core = Core::new(
        policy,
        profiles.clone(),
        CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        })),
        cfg.seed,
    );
    sched.bind(&core);

    let shared = Arc::new(Shared {
        stats: Mutex::new(
            kinds.iter().map(|&k| (k, ModelServeStats::default())).collect(),
        ),
        stop: AtomicBool::new(false),
        generated: AtomicU64::new(0),
    });

    // All executor threads compile their own PJRT runtimes (seconds of
    // startup); the serving clock starts only once everyone is ready.
    let barrier = Arc::new(Barrier::new(cfg.cloud_pool + 3));
    let epoch = Instant::now();
    let now_us = move || -> Micros { epoch.elapsed().as_micros() as Micros };

    // Cloud pool: FaaS latency simulated, inference executed locally.
    let (cloud_tx, cloud_rx) = mpsc::channel::<(Task, Micros)>();
    let cloud_rx = Arc::new(Mutex::new(cloud_rx));
    // Completed FaaS durations flow back to the edge lane so the
    // scheduler's §5.4 adaptation observes real samples.
    let (report_tx, report_rx) = mpsc::channel::<CloudReport>();
    let mut cloud_handles = Vec::new();
    for w in 0..cfg.cloud_pool {
        let rx = Arc::clone(&cloud_rx);
        let report_tx2 = report_tx.clone();
        let dir2 = dir.clone();
        let shared2 = Arc::clone(&shared);
        let profiles2 = profiles.clone();
        let (med, sigma) = cfg.cloud_extra_ms;
        let seed = cfg.seed ^ (w as u64) << 32;
        let epoch2 = epoch;
        let barrier2 = Arc::clone(&barrier);
        cloud_handles.push(std::thread::spawn(move || {
            let rt2 = Runtime::load(&dir2).expect("cloud worker runtime");
            barrier2.wait();
            let mut rng = Rng::new(seed);
            loop {
                let job = { rx.lock().unwrap().recv() };
                let Ok((task, abs_deadline)) = job else { break };
                // JIT check before spending network+compute (§3.3); also
                // fast-drains any backlog once the run is stopping.
                let now = epoch2.elapsed().as_micros() as Micros;
                let dispatched_at = now;
                let p = profiles2
                    .iter()
                    .find(|p| p.kind == task.model)
                    .unwrap();
                if now + p.t_cloud > abs_deadline
                    || shared2.stop.load(Ordering::Relaxed)
                {
                    bump(&shared2, task.model, |s| s.dropped += 1);
                    continue;
                }
                // Simulated WAN + FaaS overhead, then real inference.
                let extra = rng.lognormal(med, sigma);
                std::thread::sleep(Duration::from_secs_f64(extra / 1_000.0));
                let model = rt2.model(task.model).unwrap();
                let frame =
                    rt2.synth_frame(task.model, task.segment.id).unwrap();
                let out = model.infer(&frame);
                let done = epoch2.elapsed().as_micros() as Micros;
                let success = out.is_ok() && done <= abs_deadline;
                // Observed dispatch→completion duration: the wall-clock
                // analogue of the DES engine's t̂ᵢʲ sample.
                let _ = report_tx2.send(CloudReport {
                    kind: task.model,
                    duration: done - dispatched_at,
                    timed_out: false,
                    success,
                    throttled: false,
                });
                let lat_ms =
                    (done - task.segment.created_at) as f64 / 1_000.0;
                bump(&shared2, task.model, |s| {
                    s.on_cloud += 1;
                    if success {
                        s.completed += 1;
                        s.latency_ms.push(lat_ms);
                    } else {
                        s.missed += 1;
                    }
                });
            }
        }));
    }
    // Workers hold the only live senders; the edge lane's receiver sees
    // Disconnected once they all exit.
    drop(report_tx);

    // Generator: splitter + task-creation threads folded into one.
    let (task_tx, task_rx) = mpsc::channel::<Task>();
    let gen_shared = Arc::clone(&shared);
    let gen_kinds = kinds.clone();
    let gen_cfg_rate = cfg.rate;
    let gen_drones = cfg.drones;
    let gen_seed = cfg.seed;
    let gen_epoch = epoch;
    let gen_barrier = Arc::clone(&barrier);
    let generator = std::thread::spawn(move || {
        gen_barrier.wait();
        let mut rng = Rng::new(gen_seed ^ 0xD20_4E5);
        let period = Duration::from_secs_f64(1.0 / gen_cfg_rate);
        let mut next_id: u64 = 0;
        let mut tick: u64 = 0;
        while !gen_shared.stop.load(Ordering::Relaxed) {
            for drone in 0..gen_drones {
                let seg = VideoSegment {
                    id: tick * gen_drones as u64 + drone as u64,
                    drone,
                    created_at: gen_epoch.elapsed().as_micros() as Micros,
                    bytes: 38_000,
                };
                let mut order: Vec<DnnKind> = gen_kinds.clone();
                rng.shuffle(&mut order);
                for kind in order {
                    next_id += 1;
                    gen_shared.generated.fetch_add(1, Ordering::Relaxed);
                    let _ = task_tx.send(Task {
                        id: next_id,
                        model: kind,
                        segment: seg.clone(),
                    });
                }
            }
            tick += 1;
            std::thread::sleep(period);
        }
    });

    // Edge lane: task scheduler + synchronous single-threaded executor.
    // Admission, deferral and stealing all run through the Scheduler trait
    // against the live core; the executor pops (or steals) and runs real
    // PJRT inference inline.
    let edge_dir = dir.clone();
    let edge_shared = Arc::clone(&shared);
    let edge_barrier = Arc::clone(&barrier);
    let edge = std::thread::spawn(move || -> Metrics {
        let edge_rt = Runtime::load(&edge_dir).expect("edge runtime");
        edge_barrier.wait();
        // Sink for virtual trigger events: the lane polls the cloud queue
        // by wall clock instead of replaying the event heap.
        let mut evq = EventQueue::new();
        loop {
            // Discard accumulated sink events so the heap stays bounded
            // over long serving runs (they are never replayed).
            if !evq.is_empty() {
                evq = EventQueue::new();
            }
            // Deliver FaaS observations to the scheduler before admitting
            // new work (§5.4: adaptation sees the sample first).
            while let Ok(report) = report_rx.try_recv() {
                let now = now_us();
                let mut ctx =
                    SchedCtx { now, core: &mut core, q: &mut evq };
                sched.on_cloud_report(&mut ctx, &report);
            }
            // Drain arrivals (non-blocking once stopped) through `admit`.
            loop {
                match task_rx.try_recv() {
                    Ok(task) => {
                        let now = now_us();
                        let mut ctx =
                            SchedCtx { now, core: &mut core, q: &mut evq };
                        sched.admit(&mut ctx, task);
                        sched.drain_done(&mut ctx);
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => break,
                }
            }
            // Forward due (triggered) cloud entries to the FaaS pool.
            {
                let now = now_us();
                while let Some(e) = core.cloud_q.pop_due(now) {
                    if e.negative_utility
                        && !core.policy.cloud_accepts_negative
                    {
                        // Un-stolen steal candidate: just-in-time drop.
                        bump(&edge_shared, e.task.model,
                             |s| s.dropped += 1);
                        continue;
                    }
                    let _ = cloud_tx.send((e.task, e.abs_deadline));
                }
            }
            let stopping = edge_shared.stop.load(Ordering::Relaxed);
            // Executor pick-next: steal hook first, then the queue head.
            let now = now_us();
            let steal = {
                let mut ctx = SchedCtx { now, core: &mut core, q: &mut evq };
                sched.on_edge_idle(&mut ctx)
            };
            let next = match steal {
                Some(idx) => {
                    Some((core.cloud_q.remove_at(idx).into_edge_entry(),
                          true))
                }
                None => core.edge_q.pop().map(|e| (e, false)),
            };
            match next {
                Some((entry, stolen)) => {
                    let t = now_us();
                    // JIT check (§3.3).
                    if core.policy.edge_jit_drop
                        && t + entry.t_edge > entry.abs_deadline
                    {
                        bump(&edge_shared, entry.task.model,
                             |s| s.dropped += 1);
                        continue;
                    }
                    let model = edge_rt.model(entry.task.model).unwrap();
                    let frame = edge_rt
                        .synth_frame(entry.task.model, entry.task.segment.id)
                        .unwrap();
                    let out = model.infer(&frame);
                    let done = now_us();
                    // VIP-app post-processing on the real outputs.
                    let pp0 = Instant::now();
                    if let Ok(v) = &out {
                        match entry.task.model {
                            DnnKind::Hv => {
                                let _ = bbox_offset(v);
                            }
                            DnnKind::Bp => {
                                let _ = classify_pose(v);
                            }
                            DnnKind::Dev => {
                                // DEV's artifact outputs the distance
                                // directly; sanity-clamp it like the app.
                                let _ = (v[0] as f64).clamp(0.0, 50.0);
                            }
                            _ => {}
                        }
                    }
                    let pp_us = pp0.elapsed().as_secs_f64() * 1e6;
                    let lat_ms =
                        (done - entry.task.segment.created_at) as f64
                            / 1_000.0;
                    bump(&edge_shared, entry.task.model, |s| {
                        if out.is_ok() && done <= entry.abs_deadline {
                            s.completed += 1;
                            if stolen {
                                s.stolen += 1;
                            }
                            s.latency_ms.push(lat_ms);
                            s.postproc_us.push(pp_us);
                        } else {
                            s.missed += 1;
                        }
                    });
                }
                None if stopping => break,
                None => std::thread::sleep(Duration::from_micros(200)),
            }
        }
        // Shutdown: deferred entries whose trigger never arrived count as
        // dropped, so the report's accounting closes.
        while !core.cloud_q.is_empty() {
            let e = core.cloud_q.remove_at(0);
            bump(&edge_shared, e.task.model, |s| s.dropped += 1);
        }
        drop(cloud_tx); // close the cloud channel → workers exit
        core.metrics
    });

    barrier.wait(); // all runtimes compiled — start the serving clock
    let serve_start = Instant::now();
    std::thread::sleep(cfg.duration);
    shared.stop.store(true, Ordering::Relaxed);
    generator.join().expect("generator thread");
    let core_metrics = edge.join().expect("edge thread");
    for h in cloud_handles {
        h.join().expect("cloud worker");
    }

    let generated = shared.generated.load(Ordering::Relaxed);
    let mut stats = Arc::try_unwrap(shared)
        .map_err(|_| crate::err!("dangling shared refs"))?
        .stats
        .into_inner()
        .unwrap();
    // Fold in admission-time drops the scheduler finalized inside the core
    // (infeasible / negative-utility rejections).
    for (kind, s) in stats.iter_mut() {
        s.dropped += core_metrics.stats(*kind).dropped();
    }
    Ok(ServeReport {
        per_model: stats,
        wall_secs: serve_start.elapsed().as_secs_f64(),
        generated,
        calibrated_ms: calibrated,
    })
}
