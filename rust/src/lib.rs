//! # Ocularone-RS
//!
//! A from-scratch reproduction of *"Adaptive Heuristics for Scheduling DNN
//! Inferencing on Edge and Cloud for Personalized UAV Fleets"* (Raj et al.)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the Ocularone
//!   scheduling platform with the DEMS / DEMS-A / GEMS heuristics, all
//!   baselines, the edge/cloud executors, FaaS + WAN simulation, the
//!   drone-fleet emulator and the VIP navigation application.
//! * **Layer 2 (`python/compile/model.py`)** — the six DNN models in JAX,
//!   lowered once to HLO text under `artifacts/`.
//! * **Layer 1 (`python/compile/kernels/`)** — the Pallas fused-GEMM kernel
//!   every model funnels through.
//!
//! ## Architecture: mechanism vs. policy vs. orchestration
//!
//! Since the scheduler-API redesign the crate is split into three layers
//! (see `docs/ARCHITECTURE.md` for the full tour and how to add a new
//! heuristic):
//!
//! * [`platform`] — *mechanism only*: one edge base station's queues,
//!   executors, cloud pool, metrics and QoE window accounting
//!   ([`platform::Core`]), paired with a scheduler in a
//!   [`platform::Platform`].
//! * [`sched`] — *policy*: the [`sched::Scheduler`] trait with explicit
//!   decision hooks (`admit`/`place`, `on_edge_idle` stealing,
//!   `on_cloud_report` adaptation, `on_task_done`/`on_window_close` QoE),
//!   implemented per heuristic family — [`sched::baselines`],
//!   [`sched::dems`], [`sched::gems`], [`sched::sota`]. A declarative
//!   [`policy::Policy`] resolves to a boxed scheduler via
//!   [`policy::Policy::build`].
//! * [`cluster`] — *orchestration*: N platforms plus a drone→edge
//!   [`cluster::Router`] driven by ONE scope-tagged
//!   [`sim::EventQueue`], with aggregated [`cluster::ClusterMetrics`] —
//!   the §8.1 multi-edge emulation as a first-class API
//!   (`ocularone simulate --edges 7`). A [`cluster::Federation`] layer
//!   optionally lets the stations cooperate: cross-edge work stealing
//!   (κ/κ̂-ranked, LAN-transfer charged), mid-run drone handover on the
//!   now-dynamic router, and shared-uplink contention
//!   ([`net::SharedUplink`]); all off by default and bit-identical to
//!   the isolated engine when off. The [`fault`] chaos layer injects
//!   deterministic edge crashes, region outages and link flaps on the
//!   same event queue (`simulate --fault crash:0@60-120`), with
//!   conservation-audited recovery semantics. The [`resilience`] layer
//!   closes the loop on those faults: per-backend circuit breakers,
//!   hedged (speculative duplicate) cloud requests and lite-variant
//!   graceful degradation, all opt-in per policy
//!   (`simulate --resilience breaker,hedge,degrade`) and bit-identical
//!   to the plain engine when off.
//! * [`cloud`] — the pluggable cloud tier behind
//!   [`cloud::CloudBackend`]: [`cloud::SimpleBackend`] (the calibrated
//!   legacy sampler, bit-identical default), [`cloud::FaasBackend`]
//!   (warm-container pools with keep-alive expiry, per-account
//!   concurrency throttling, GB-second + per-request billing) and
//!   [`cloud::MultiRegionBackend`] (two regions, latency-based
//!   failover).
//!
//! On top of the engine sits the **scenario & report layer**:
//! [`scenario::Scenario`] declaratively composes workload × policy ×
//! network × edge-count × seed grids — including beyond-paper axes
//! (Poisson/bursty arrivals, mid-run drone churn, heterogeneous per-edge
//! fleets and hardware) — and every experiment returns a structured
//! [`report::Report`] that renders to markdown or JSON
//! (`ocularone experiment all --format json --out reports/`). The
//! paper's tables/figures are named entries in
//! [`scenario::registry`]. Sweeps execute on the dependency-free
//! [`pool`] worker engine (`--jobs N`): grids are enumerated into flat
//! job lists, fanned out over work-stealing `std::thread` workers and
//! re-assembled in enumeration order, so parallel reports are
//! byte-identical to sequential ones (see docs/PERF.md).
//!
//! Python never runs on the request path: with the `pjrt` feature the
//! `runtime` module loads the artifacts through the PJRT C API and `serve`
//! drives real inferences through the same `Scheduler` decisions. The
//! default build is offline and dependency-free ([`errors`] replaces
//! `anyhow`; the XLA-backed modules are feature-gated).
//!
//! Start with [`policy::Policy`] + [`fleet::Workload`] + [`simulate`] for
//! single-edge studies, [`simulate_cluster`] (or [`cluster::Cluster`]
//! directly) for fleet-scale ones, [`scenario::run_scenario`] for named
//! experiments, and `serve` for the real-inference serving loop.

pub mod adapt;
pub mod arena;
pub mod benchutil;
pub mod cloud;
pub mod cluster;
pub mod errors;
pub mod exec;
pub mod exp;
pub mod fault;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod nav;
pub mod net;
pub mod obs;
pub mod pipeline;
pub mod platform;
pub mod policy;
pub mod pool;
pub mod qoe;
pub mod queues;
pub mod report;
pub mod resilience;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod sched;
#[cfg(feature = "pjrt")]
pub mod serve;
pub mod sim;
pub mod task;
pub mod time;

use crate::cluster::{Cluster, ClusterMetrics};

fn default_wan_cloud() -> Box<dyn cloud::CloudBackend> {
    exec::CloudExecModel::new(Box::new(net::LognormalWan::default())).into()
}

/// Convenience: run one simulated single-edge experiment with the default
/// WAN model (a one-edge [`Cluster`] under the hood).
pub fn simulate(policy: policy::Policy, workload: &fleet::Workload,
                seed: u64) -> metrics::Metrics {
    let cluster =
        Cluster::single(&policy, workload, seed, default_wan_cloud());
    let mut cm = cluster.run();
    cm.per_edge.pop().expect("one edge")
}

/// Convenience: run the §8.1 multi-edge emulation — `edges` base stations,
/// each serving `workload.drones` drones — through one cluster event
/// engine with the default WAN model.
///
/// With `edges == 1` the seed is used directly (same results as
/// [`simulate`]); otherwise per-edge seeds follow the canonical
/// `seed ^ ((e+1)·φ)` derivation ([`cluster::EDGE_SEED_PHI`]).
pub fn simulate_cluster(policy: policy::Policy, workload: &fleet::Workload,
                        seed: u64, edges: usize) -> ClusterMetrics {
    if edges <= 1 {
        Cluster::single(&policy, workload, seed, default_wan_cloud()).run()
    } else {
        Cluster::emulation(&policy, workload, seed, edges,
                           &default_wan_cloud)
            .run()
    }
}
