//! # Ocularone-RS
//!
//! A from-scratch reproduction of *"Adaptive Heuristics for Scheduling DNN
//! Inferencing on Edge and Cloud for Personalized UAV Fleets"* (Raj et al.)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the Ocularone
//!   scheduling platform with the DEMS / DEMS-A / GEMS heuristics, all
//!   baselines, the edge/cloud executors, FaaS + WAN simulation, the
//!   drone-fleet emulator and the VIP navigation application.
//! * **Layer 2 (`python/compile/model.py`)** — the six DNN models in JAX,
//!   lowered once to HLO text under `artifacts/`.
//! * **Layer 1 (`python/compile/kernels/`)** — the Pallas fused-GEMM kernel
//!   every model funnels through.
//!
//! Python never runs on the request path: [`runtime`] loads the artifacts
//! through the PJRT C API (`xla` crate) and serves inferences natively.
//!
//! Start with [`policy::Policy`] + [`fleet::Workload`] + [`sim::run`] for
//! simulated studies, or [`serve`] for the real-inference serving loop.

pub mod adapt;
pub mod benchutil;
pub mod exec;
pub mod exp;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod nav;
pub mod net;
pub mod platform;
pub mod policy;
pub mod qoe;
pub mod queues;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod task;
pub mod time;

/// Convenience: run one simulated experiment with the default WAN model.
pub fn simulate(policy: policy::Policy, workload: &fleet::Workload,
                seed: u64) -> metrics::Metrics {
    let cloud = exec::CloudExecModel::new(Box::new(
        net::LognormalWan::default(),
    ));
    let mut platform =
        platform::Platform::new(policy, workload.models.clone(), cloud, seed);
    platform.edge_exec = workload.edge_exec.clone();
    sim::run(platform, workload, seed)
}
