//! Deterministic PRNG and distributions (offline build: no `rand` crate).
//!
//! Every stochastic component in the emulator (execution-time sampling, task
//! shuffle order, network traces, navigation noise) draws from this
//! xoshiro256++ generator so whole experiment runs are reproducible from a
//! single seed — a property the paper's wall-clock testbed cannot offer and
//! which our integration tests rely on.

/// xoshiro256++ 1.0 (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the last Box–Muller draw.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.f64() * n as f64) as usize).min(n - 1)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Lognormal with the given *median* and sigma (of the underlying
    /// normal). Used for service-time sampling: medians calibrate to the
    /// benchmarked tables while the long tail mimics the paper's Figs 1–2.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential deviate with the given mean — inter-arrival times of a
    /// Poisson process (the beyond-paper arrival model in
    /// [`crate::fleet::Arrival`]).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // 1 − U ∈ (0, 1], so ln() is finite; clamp guards the pathological
        // all-zero draw anyway.
        let u = (1.0 - self.f64()).max(1e-300);
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle (the paper inserts each segment's tasks in
    /// randomized order to avoid favouring any model — §3.3).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(13);
        let mut xs: Vec<f64> =
            (0..50_001).map(|_| r.lognormal(100.0, 0.2)).collect();
        xs.sort_by(f64::total_cmp);
        let med = xs[25_000];
        assert!((med - 100.0).abs() < 3.0, "median={med}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean_and_positivity() {
        let mut r = Rng::new(21);
        let n = 100_000;
        let mean = 250_000.0; // 250 ms in µs
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.exponential(mean);
            assert!(x >= 0.0);
            sum += x;
        }
        let m = sum / n as f64;
        assert!((m / mean - 1.0).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
