//! PJRT runtime: load the AOT-compiled HLO artifacts and serve inferences
//! natively from Rust — Python never runs on the request path.
//!
//! `make artifacts` lowers each L2 JAX model (which funnels through the L1
//! Pallas kernel) to HLO *text* under `artifacts/`; this module compiles
//! them once on the PJRT CPU client (`xla` crate) and executes them per
//! request. Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::errors::{Context, Result};
use crate::{bail, err};

use crate::model::DnnKind;

/// Input/output contract of one compiled model (from `manifest.json`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub kind: DnnKind,
    /// NHWC input shape.
    pub input_shape: [usize; 4],
    /// Flat f32 output length.
    pub output_len: usize,
    pub hlo_path: PathBuf,
}

/// Minimal JSON scanner for the tiny flat manifest `aot.py` writes
/// (offline build: no serde). Grammar: two-level object with string /
/// integer / integer-array leaves.
pub fn parse_manifest(text: &str, dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    let mut rest = text;
    while let Some(start) = rest.find('"') {
        let rest2 = &rest[start + 1..];
        let end = rest2.find('"').ok_or_else(|| err!("bad manifest"))?;
        let name = &rest2[..end];
        let after = &rest2[end + 1..];
        // Only treat it as a model entry if it is followed by ": {".
        let trimmed = after.trim_start_matches([':', ' ', '\n']);
        if !trimmed.starts_with('{') {
            rest = after;
            continue;
        }
        let body_end =
            trimmed.find('}').ok_or_else(|| err!("bad manifest"))?;
        let body = &trimmed[..body_end];
        if let Some(kind) = DnnKind::from_name(name) {
            let shape = extract_array(body, "input_shape")?;
            if shape.len() != 4 {
                bail!("{name}: input_shape must be rank 4");
            }
            let output_len = extract_int(body, "output_len")? as usize;
            let hlo = extract_string(body, "hlo")?;
            specs.push(ArtifactSpec {
                kind,
                input_shape: [
                    shape[0] as usize,
                    shape[1] as usize,
                    shape[2] as usize,
                    shape[3] as usize,
                ],
                output_len,
                hlo_path: dir.join(hlo),
            });
        }
        rest = &trimmed[body_end..];
    }
    if specs.is_empty() {
        bail!("manifest contained no known models");
    }
    specs.sort_by_key(|s| s.kind);
    Ok(specs)
}

fn extract_field<'a>(body: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\"");
    let at = body
        .find(&pat)
        .ok_or_else(|| err!("manifest missing {key}"))?;
    let after = &body[at + pat.len()..];
    Ok(after.trim_start_matches([':', ' ']))
}

fn extract_int(body: &str, key: &str) -> Result<i64> {
    let v = extract_field(body, key)?;
    let digits: String =
        v.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().context("bad int in manifest")
}

fn extract_array(body: &str, key: &str) -> Result<Vec<i64>> {
    let v = extract_field(body, key)?;
    let v = v.strip_prefix('[').ok_or_else(|| err!("expected ["))?;
    let end = v.find(']').ok_or_else(|| err!("expected ]"))?;
    v[..end]
        .split(',')
        .map(|s| s.trim().parse::<i64>().context("bad array item"))
        .collect()
}

fn extract_string(body: &str, key: &str) -> Result<String> {
    let v = extract_field(body, key)?;
    let v = v.strip_prefix('"').ok_or_else(|| err!("expected string"))?;
    let end = v.find('"').ok_or_else(|| err!("unterminated string"))?;
    Ok(v[..end].to_string())
}

/// One compiled, executable model.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Run inference on a flat NHWC f32 frame; returns the flat output.
    pub fn infer(&self, frame: &[f32]) -> Result<Vec<f32>> {
        let n: usize = self.spec.input_shape.iter().product();
        if frame.len() != n {
            bail!(
                "{}: expected {n} input floats, got {}",
                self.spec.kind.name(),
                frame.len()
            );
        }
        let dims: Vec<i64> =
            self.spec.input_shape.iter().map(|&d| d as i64).collect();
        let input = xla::Literal::vec1(frame).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != self.spec.output_len {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.kind.name(),
                self.spec.output_len,
                values.len()
            );
        }
        Ok(values)
    }
}

/// The model registry: a PJRT client plus every compiled artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    models: HashMap<DnnKind, LoadedModel>,
}

impl Runtime {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let specs = parse_manifest(&manifest, dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut models = HashMap::new();
        for spec in specs {
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .ok_or_else(|| err!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            models.insert(spec.kind, LoadedModel { spec, exe });
        }
        Ok(Runtime { client, models })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn model(&self, kind: DnnKind) -> Option<&LoadedModel> {
        self.models.get(&kind)
    }

    pub fn kinds(&self) -> Vec<DnnKind> {
        let mut v: Vec<DnnKind> = self.models.keys().copied().collect();
        v.sort();
        v
    }

    /// Synthesize a deterministic pseudo-frame for a model (the fleet
    /// emulator's stand-in for a real camera frame): low-amplitude noise
    /// plus a bright Gaussian blob whose position depends on the seed —
    /// the "VIP in the field of view" that gives detector/pose outputs
    /// something spatial to respond to.
    pub fn synth_frame(&self, kind: DnnKind, seed: u64) -> Result<Vec<f32>> {
        let spec = &self
            .models
            .get(&kind)
            .ok_or_else(|| err!("model not loaded"))?
            .spec;
        let [_, h, w, c] = spec.input_shape;
        let mut rng = crate::rng::Rng::new(seed);
        let cx = rng.range_f64(0.2, 0.8) * w as f64;
        let cy = rng.range_f64(0.2, 0.8) * h as f64;
        let sigma = 0.12 * w as f64;
        // Perf (§Perf L3/runtime): one RNG draw and one exp() per pixel
        // (not per channel), and the row term of the Gaussian hoisted out
        // of the inner loop — synth_frame sits on the serving hot path.
        let inv2s2 = 1.0 / (2.0 * sigma * sigma);
        let mut out = Vec::with_capacity(h * w * c);
        for y in 0..h {
            let dy2 = (y as f64 - cy).powi(2);
            for x in 0..w {
                let d2 = ((x as f64 - cx).powi(2) + dy2) * inv2s2;
                let blob = if d2 < 12.0 { (-d2).exp() } else { 0.0 };
                let noise = 0.15 * rng.f64();
                for ch in 0..c {
                    // Channel-tinted blob (hazard-vest orange-ish) + noise.
                    let tint = [1.0, 0.6, 0.15][ch % 3];
                    out.push((noise + blob * tint) as f32);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
  "bp": {
    "hlo": "bp.hlo.txt",
    "hlo_bytes": 67445,
    "input_shape": [1, 64, 64, 3],
    "output_len": 36
  },
  "hv": {
    "hlo": "hv.hlo.txt",
    "hlo_bytes": 52084,
    "input_shape": [1, 64, 64, 3],
    "output_len": 5
  }
}"#;

    #[test]
    fn parse_manifest_extracts_specs() {
        let specs =
            parse_manifest(MANIFEST, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(specs.len(), 2);
        let hv = specs.iter().find(|s| s.kind == DnnKind::Hv).unwrap();
        assert_eq!(hv.input_shape, [1, 64, 64, 3]);
        assert_eq!(hv.output_len, 5);
        assert_eq!(hv.hlo_path, Path::new("/tmp/artifacts/hv.hlo.txt"));
    }

    #[test]
    fn parse_manifest_rejects_garbage() {
        assert!(parse_manifest("{}", Path::new("/tmp")).is_err());
        assert!(parse_manifest("not json at all", Path::new("/tmp")).is_err());
    }

    #[test]
    fn parse_manifest_ignores_unknown_models() {
        let text = r#"{"zz": {"hlo": "zz.hlo.txt", "input_shape": [1,2,3,4],
            "output_len": 9}, "hv": {"hlo": "hv.hlo.txt",
            "input_shape": [1, 64, 64, 3], "output_len": 5}}"#;
        let specs = parse_manifest(text, Path::new("/a")).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].kind, DnnKind::Hv);
    }
}
