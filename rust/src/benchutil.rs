//! Tiny benchmarking harness for `cargo bench` targets (offline build: no
//! criterion). Warms up, runs timed iterations, reports mean ± sd and
//! throughput, criterion-style.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub sd_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn print(&self) {
        let (v, unit) = humanize(self.mean_ns);
        let (sd, sd_unit) = humanize(self.sd_ns);
        println!(
            "{:40} {:>10.3} {:<3} ± {:>8.3} {:<3}  ({} iters)",
            self.name, v, unit, sd, sd_unit, self.iters
        );
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1_000.0 {
        (ns, "ns")
    } else if ns < 1_000_000.0 {
        (ns / 1_000.0, "µs")
    } else if ns < 1_000_000_000.0 {
        (ns / 1_000_000.0, "ms")
    } else {
        (ns / 1_000_000_000.0, "s")
    }
}

/// Benchmark `f`, auto-scaling iterations to fill ~`budget_ms` per sample.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // Warm-up + calibration.
    let t0 = Instant::now();
    let mut calib = 0u64;
    while t0.elapsed().as_millis() < (budget_ms / 4).max(1) as u128 {
        f();
        calib += 1;
    }
    let per_iter = t0.elapsed().as_nanos() as f64 / calib as f64;
    let samples = 10usize;
    let iters_per_sample =
        ((budget_ms as f64 * 1e6 / samples as f64) / per_iter).max(1.0) as u64;

    let mut means = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        means.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    let mean = means.iter().sum::<f64>() / samples as f64;
    let var = means.iter().map(|m| (m - mean).powi(2)).sum::<f64>()
        / samples as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        sd_ns: var.sqrt(),
        iters: iters_per_sample * samples as u64,
    };
    r.print();
    r
}

/// `std::hint::black_box` passthrough for bench bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 5, || {
            black_box(1 + 1);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn humanize_units() {
        assert_eq!(humanize(10.0).1, "ns");
        assert_eq!(humanize(10_000.0).1, "µs");
        assert_eq!(humanize(10_000_000.0).1, "ms");
        assert_eq!(humanize(2e9).1, "s");
    }
}
