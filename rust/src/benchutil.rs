//! Tiny benchmarking harness for `cargo bench` targets (offline build: no
//! criterion). Warms up, runs timed iterations, reports mean ± sd, p50 and
//! min, criterion-style — and serializes machine-readable
//! `BENCH_<target>.json` so CI can track the perf trajectory
//! (docs/PERF.md).
//!
//! Bench binaries (`harness = false`) drive it through [`BenchSuite`]:
//!
//! ```text
//! cargo bench --bench end_to_end -- --quick --json --out bench-out
//! ```
//!
//! * `--quick` divides every budget by 10 — the CI smoke mode.
//! * `--json` writes `BENCH_<target>.json` on [`BenchSuite::finish`].
//! * `--out DIR` picks the output directory (default `.`).
//!
//! Unrecognized flags (cargo's own `--bench`, libtest filters) are
//! ignored, so the targets stay runnable under plain `cargo bench`.

use std::time::Instant;

use crate::report::JsonValue;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub sd_ns: f64,
    /// Median of the per-sample means — the robust center CI thresholds
    /// compare (a single descheduled sample skews the mean, not the p50).
    pub p50_ns: f64,
    /// Fastest sample — the contention-free floor.
    pub min_ns: f64,
    pub iters: u64,
    /// Simulation events one iteration processes (engine-throughput
    /// profiling, see [`BenchSuite::annotate_events`]); `None` for
    /// benches with no event-loop interpretation.
    pub events: Option<u64>,
}

impl BenchResult {
    pub fn print(&self) {
        let (v, unit) = humanize(self.mean_ns);
        let (sd, sd_unit) = humanize(self.sd_ns);
        let (p50, p50_unit) = humanize(self.p50_ns);
        println!(
            "{:40} {:>10.3} {:<3} ± {:>8.3} {:<3} p50 {:>10.3} {:<3} \
             ({} iters)",
            self.name, v, unit, sd, sd_unit, p50, p50_unit, self.iters
        );
    }

    /// Events/sec gauge for annotated benches: per-iteration event
    /// count over the p50 per-iteration time (the same robust center
    /// the regression gate compares). `None` without an annotation.
    pub fn events_per_sec(&self) -> Option<f64> {
        self.events
            .filter(|_| self.p50_ns > 0.0)
            .map(|e| e as f64 * 1e9 / self.p50_ns)
    }

    /// The `BENCH_<target>.json` row schema.
    pub fn to_json_value(&self) -> JsonValue {
        let mut obj = vec![
            ("name".into(), JsonValue::Str(self.name.clone())),
            ("mean_ns".into(), JsonValue::Num(self.mean_ns)),
            ("sd_ns".into(), JsonValue::Num(self.sd_ns)),
            ("p50_ns".into(), JsonValue::Num(self.p50_ns)),
            ("min_ns".into(), JsonValue::Num(self.min_ns)),
            ("iters".into(), JsonValue::Num(self.iters as f64)),
        ];
        if let Some(e) = self.events {
            obj.push(("events".into(), JsonValue::Num(e as f64)));
        }
        if let Some(eps) = self.events_per_sec() {
            obj.push(("events_per_sec".into(), JsonValue::Num(eps)));
        }
        JsonValue::Obj(obj)
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1_000.0 {
        (ns, "ns")
    } else if ns < 1_000_000.0 {
        (ns / 1_000.0, "µs")
    } else if ns < 1_000_000_000.0 {
        (ns / 1_000_000.0, "ms")
    } else {
        (ns / 1_000_000_000.0, "s")
    }
}

/// Benchmark `f`, auto-scaling iterations to fill ~`budget_ms` per sample.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // Warm-up + calibration.
    let t0 = Instant::now();
    let mut calib = 0u64;
    while t0.elapsed().as_millis() < (budget_ms / 4).max(1) as u128 {
        f();
        calib += 1;
    }
    let per_iter = t0.elapsed().as_nanos() as f64 / calib as f64;
    let samples = 10usize;
    let iters_per_sample =
        ((budget_ms as f64 * 1e6 / samples as f64) / per_iter).max(1.0) as u64;

    let mut means = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        means.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    let mean = means.iter().sum::<f64>() / samples as f64;
    let var = means.iter().map(|m| (m - mean).powi(2)).sum::<f64>()
        / samples as f64;
    let mut sorted = means.clone();
    sorted.sort_by(f64::total_cmp);
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        sd_ns: var.sqrt(),
        p50_ns: sorted[sorted.len() / 2],
        min_ns: sorted[0],
        iters: iters_per_sample * samples as u64,
        events: None,
    };
    r.print();
    r
}

/// `std::hint::black_box` passthrough for bench bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ------------------------------------------------------------------ suite

/// CLI configuration of one bench target (see the module docs for the
/// flag set). Unknown arguments are ignored.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub quick: bool,
    pub json: bool,
    pub out_dir: String,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { quick: false, json: false, out_dir: ".".into() }
    }
}

impl BenchConfig {
    /// Parse the process arguments (skipping argv[0]).
    pub fn from_args() -> BenchConfig {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&args)
    }

    pub fn parse(args: &[String]) -> BenchConfig {
        let mut cfg = BenchConfig::default();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--quick" => cfg.quick = true,
                "--json" => cfg.json = true,
                "--out" => {
                    if let Some(d) = args.get(i + 1) {
                        cfg.out_dir = d.clone();
                        i += 1;
                    }
                }
                _ => {} // cargo's --bench, libtest filters, …
            }
            i += 1;
        }
        cfg
    }
}

/// One bench target's run: applies quick-mode budget scaling, records
/// every [`BenchResult`] and serializes `BENCH_<target>.json` on
/// [`finish`](BenchSuite::finish).
pub struct BenchSuite {
    target: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    /// Suite configured from the process arguments.
    pub fn new(target: &str) -> BenchSuite {
        Self::with_config(target, BenchConfig::from_args())
    }

    pub fn with_config(target: &str, cfg: BenchConfig) -> BenchSuite {
        BenchSuite { target: target.into(), cfg, results: Vec::new() }
    }

    pub fn quick(&self) -> bool {
        self.cfg.quick
    }

    /// Budget actually used for a nominal per-bench budget: quick mode
    /// divides by 10 (floor 20 ms keeps the calibration phase sane).
    fn budget(&self, budget_ms: u64) -> u64 {
        if self.cfg.quick {
            (budget_ms / 10).max(20)
        } else {
            budget_ms
        }
    }

    /// Run and record one benchmark.
    pub fn bench(&mut self, name: &str, budget_ms: u64,
                 f: impl FnMut()) -> &BenchResult {
        let r = bench(name, self.budget(budget_ms), f);
        self.results.push(r);
        self.results.last().expect("just pushed")
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Attach a per-iteration engine event count to the most recent
    /// result (measure it on one un-timed run of the same closure via
    /// `Metrics::events_processed` / `ClusterMetrics::events_processed`).
    /// The JSON row then carries `events` + an `events_per_sec` gauge,
    /// which `tools/check_bench_regression.py` gates alongside latency.
    pub fn annotate_events(&mut self, events: u64) {
        if let Some(r) = self.results.last_mut() {
            r.events = Some(events);
        }
    }

    /// The whole suite as the `BENCH_<target>.json` document.
    pub fn to_json_value(&self) -> JsonValue {
        JsonValue::Obj(vec![
            ("target".into(), JsonValue::Str(self.target.clone())),
            ("quick".into(), JsonValue::Bool(self.cfg.quick)),
            (
                "results".into(),
                JsonValue::Arr(
                    self.results
                        .iter()
                        .map(BenchResult::to_json_value)
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `BENCH_<target>.json` into the configured directory when
    /// `--json` was requested. Returns the path the file lives (or would
    /// live) at.
    pub fn finish(self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::Path::new(&self.cfg.out_dir)
            .join(format!("BENCH_{}.json", self.target));
        if self.cfg.json {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(&path, self.to_json_value().dump())?;
            println!("wrote {}", path.display());
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::parse_json;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 5, || {
            black_box(1 + 1);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.min_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns, "min {} p50 {}", r.min_ns, r.p50_ns);
        // p50 sits inside the sample envelope around the mean.
        assert!(r.p50_ns <= r.mean_ns + 6.0 * r.sd_ns + 1.0);
    }

    #[test]
    fn humanize_units() {
        assert_eq!(humanize(10.0).1, "ns");
        assert_eq!(humanize(10_000.0).1, "µs");
        assert_eq!(humanize(10_000_000.0).1, "ms");
        assert_eq!(humanize(2e9).1, "s");
    }

    #[test]
    fn result_json_round_trips() {
        let r = BenchResult {
            name: "fig8 demo".into(),
            mean_ns: 123.5,
            sd_ns: 4.25,
            p50_ns: 120.0,
            min_ns: 117.0,
            iters: 1000,
            events: None,
        };
        let json = r.to_json_value().dump();
        let parsed = parse_json(&json).expect("valid JSON");
        assert_eq!(parsed.dump(), json, "parse∘dump identity");
        assert!(json.contains("\"name\":\"fig8 demo\""));
        assert!(json.contains("\"p50_ns\":120"));
        assert!(json.contains("\"min_ns\":117"));
        assert!(!json.contains("events"), "no gauge without annotation");
    }

    #[test]
    fn annotated_events_surface_an_events_per_sec_gauge() {
        let mut r = BenchResult {
            name: "engine".into(),
            mean_ns: 2e6,
            sd_ns: 0.0,
            p50_ns: 2e6, // 2 ms per iteration…
            min_ns: 2e6,
            iters: 10,
            events: Some(1000), // …over 1000 events = 500k events/s
        };
        assert_eq!(r.events_per_sec(), Some(500_000.0));
        let json = r.to_json_value().dump();
        assert!(json.contains("\"events\":1000"), "{json}");
        assert!(json.contains("\"events_per_sec\":500000"), "{json}");
        r.events = None;
        assert_eq!(r.events_per_sec(), None);
    }

    #[test]
    fn suite_records_and_serializes() {
        let cfg = BenchConfig { quick: true, json: false, out_dir: ".".into() };
        let mut suite = BenchSuite::with_config("unit", cfg);
        suite.bench("a", 5, || {
            black_box(2 * 2);
        });
        suite.bench("b", 5, || {
            black_box(3 * 3);
        });
        assert_eq!(suite.results().len(), 2);
        let json = suite.to_json_value().dump();
        assert!(parse_json(&json).is_ok());
        assert!(json.contains("\"target\":\"unit\""));
        assert!(json.contains("\"quick\":true"));
        // Not --json: finish writes nothing but still names the path.
        let path = suite.finish().expect("finish");
        assert!(path.ends_with("BENCH_unit.json"));
        assert!(!path.exists(), "no file without --json");
    }

    #[test]
    fn config_parses_known_flags_and_ignores_the_rest() {
        let args: Vec<String> =
            ["--bench", "--quick", "--out", "somewhere", "--json", "junk"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = BenchConfig::parse(&args);
        assert!(cfg.quick);
        assert!(cfg.json);
        assert_eq!(cfg.out_dir, "somewhere");
        let none = BenchConfig::parse(&[]);
        assert!(!none.quick && !none.json);
        assert_eq!(none.out_dir, ".");
    }

    #[test]
    fn quick_mode_scales_budgets() {
        let cfg = BenchConfig { quick: true, ..BenchConfig::default() };
        let s = BenchSuite::with_config("q", cfg);
        assert_eq!(s.budget(1200), 120);
        assert_eq!(s.budget(50), 20, "floor keeps calibration sane");
        let s2 = BenchSuite::with_config("nq", BenchConfig::default());
        assert_eq!(s2.budget(1200), 1200);
    }
}
