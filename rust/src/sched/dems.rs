//! The DEM / DEMS / DEMS-A family (§5): migration scoring on admission,
//! deferred cloud triggers with work stealing, and sliding-window
//! adaptation of the expected cloud durations. Which rungs of the ladder
//! are active comes from the declarative [`Policy`](crate::policy::Policy)
//! flags (`migration`, `stealing`, `defer_cloud`, `adaptive`).
//!
//! Fleet federation hooks in here for free: DEMS's `stealing` +
//! `defer_cloud` flags satisfy the default
//! [`Scheduler::federates`](crate::sched::Scheduler::federates) gate, so
//! a federated cluster may offer this edge's deferred entries to idle
//! siblings; and shared-uplink queueing delay arrives through the same
//! `on_cloud_report` observations, so DEMS-A's §5.4 window adapts t̂ to
//! backhaul contention exactly as it does to WAN slowdown.

use crate::adapt::ModelAdapt;
use crate::model::DnnKind;
use crate::platform::Core;
use crate::sched::{dem_admit, steal_candidate, CloudReport, SchedCtx,
                   Scheduler};
use crate::task::Task;
use crate::time::Micros;

/// §5.4 per-model expected-cloud-duration estimator, shared by DEMS-A and
/// GEMS-A. Inactive (static Table-1 t̂) unless the policy is adaptive.
#[derive(Clone, Debug, Default)]
pub(crate) struct CloudEstimator {
    kinds: Vec<DnnKind>,
    adapt: Vec<ModelAdapt>,
}

impl CloudEstimator {
    pub(crate) fn bind(&mut self, core: &Core) {
        self.kinds = core.models.iter().map(|m| m.kind).collect();
        self.adapt = core
            .models
            .iter()
            .map(|m| ModelAdapt::new(m.t_cloud, core.policy.adapt_window))
            .collect();
    }

    fn idx(&self, kind: DnnKind) -> Option<usize> {
        self.kinds.iter().position(|&k| k == kind)
    }

    pub(crate) fn expected(&self, core: &Core, kind: DnnKind) -> Micros {
        if core.policy.adaptive {
            if let Some(i) = self.idx(kind) {
                return self.adapt[i].expected();
            }
        }
        core.profile(kind).t_cloud
    }

    pub(crate) fn observe(&mut self, core: &Core, kind: DnnKind,
                          duration: Micros) {
        if core.policy.adaptive {
            if let Some(i) = self.idx(kind) {
                self.adapt[i].observe(duration, core.policy.adapt_epsilon);
            }
        }
    }

    pub(crate) fn on_skip(&mut self, core: &Core, now: Micros,
                          kind: DnnKind) {
        if core.policy.adaptive {
            if let Some(i) = self.idx(kind) {
                self.adapt[i].on_skip(now, core.policy.cooling_period);
            }
        }
    }
}

/// DEM, DEMS and DEMS-A (§5.2–§5.4).
#[derive(Clone, Debug, Default)]
pub struct Dems {
    pub(crate) est: CloudEstimator,
}

impl Dems {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for Dems {
    fn family(&self) -> &'static str {
        "dems"
    }

    fn bind(&mut self, core: &Core) {
        self.est.bind(core);
    }

    fn admit(&mut self, ctx: &mut SchedCtx<'_>, task: Task) {
        dem_admit(self, ctx, task);
    }

    fn on_edge_idle(&mut self, ctx: &mut SchedCtx<'_>) -> Option<usize> {
        steal_candidate(ctx.core, ctx.now)
    }

    fn expected_cloud(&self, core: &Core, kind: DnnKind) -> Micros {
        self.est.expected(core, kind)
    }

    fn on_cloud_skip(&mut self, core: &Core, now: Micros, kind: DnnKind) {
        self.est.on_skip(core, now, kind);
    }

    fn on_cloud_report(&mut self, ctx: &mut SchedCtx<'_>,
                       report: &CloudReport) {
        self.est.observe(ctx.core, report.kind, report.duration);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::CloudExecModel;
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::policy::Policy;
    use crate::time::ms;

    fn core(policy: Policy) -> Core {
        let cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        Core::new(policy, table1(), cloud, 1)
    }

    #[test]
    fn estimator_static_unless_adaptive() {
        let c = core(Policy::dems());
        let mut est = CloudEstimator::default();
        est.bind(&c);
        // Observations are ignored while the policy is non-adaptive.
        est.observe(&c, DnnKind::Hv, ms(2_000));
        assert_eq!(est.expected(&c, DnnKind::Hv), ms(398));
    }

    #[test]
    fn estimator_adapts_upward_under_dems_a() {
        let c = core(Policy::dems_a());
        let mut est = CloudEstimator::default();
        est.bind(&c);
        for _ in 0..c.policy.adapt_window {
            est.observe(&c, DnnKind::Hv, ms(1_000));
        }
        assert_eq!(est.expected(&c, DnnKind::Hv), ms(1_000));
        // And the other models stay at their static defaults.
        assert_eq!(est.expected(&c, DnnKind::Deo), ms(832));
    }
}
