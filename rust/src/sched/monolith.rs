//! Statically dispatched flag-branch scheduler — the shape of the
//! pre-split `platform.rs` monolith, kept as (a) the dispatch-parity
//! reference (`tests/paper_shape.rs` asserts it produces bit-identical
//! metrics to the `Box<dyn Scheduler>` path for every `PolicyKind`) and
//! (b) the benchmark baseline that bounds the cost of dynamic dispatch on
//! the submit/steal hot path (`benches/scheduler.rs`).
//!
//! Every hook routes on `core.policy.kind` with a plain `match` — no
//! vtable — into the same family implementations `Policy::build` boxes.

use crate::model::DnnKind;
use crate::platform::Core;
use crate::policy::PolicyKind;
use crate::sched::{CloudOnly, CloudReport, Dems, EcBaseline, EdgeOnly,
                   Gems, Placement, SchedCtx, Scheduler, Sota1, Sota2};
use crate::task::Task;
use crate::time::Micros;

/// One instance of every heuristic family, routed per call by the policy
/// kind (the pre-refactor `if policy.flag` shape, minus the spaghetti).
#[derive(Default)]
pub struct FlagBranchScheduler {
    edge_only: EdgeOnly,
    cloud_only: CloudOnly,
    ec: EcBaseline,
    dems: Dems,
    gems: Gems,
    sota1: Sota1,
    sota2: Sota2,
}

impl FlagBranchScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Route one hook invocation by policy kind. `$kind` must be read out of
/// the core *before* the mutable contexts are built.
macro_rules! route {
    ($self:ident, $kind:expr, $m:ident ( $($a:expr),* )) => {
        match $kind {
            PolicyKind::EdgeEdf | PolicyKind::EdgeHpf => {
                $self.edge_only.$m($($a),*)
            }
            PolicyKind::CloudOnly => $self.cloud_only.$m($($a),*),
            PolicyKind::EdfEC | PolicyKind::SjfEC => $self.ec.$m($($a),*),
            PolicyKind::Dem | PolicyKind::Dems | PolicyKind::DemsA => {
                $self.dems.$m($($a),*)
            }
            PolicyKind::Gems => $self.gems.$m($($a),*),
            PolicyKind::Sota1 => $self.sota1.$m($($a),*),
            PolicyKind::Sota2 => $self.sota2.$m($($a),*),
        }
    };
}

impl Scheduler for FlagBranchScheduler {
    fn family(&self) -> &'static str {
        "flag-branch"
    }

    fn bind(&mut self, core: &Core) {
        // Bind every family: only the active one is routed to afterwards,
        // and binding is cheap.
        self.edge_only.bind(core);
        self.cloud_only.bind(core);
        self.ec.bind(core);
        self.dems.bind(core);
        self.gems.bind(core);
        self.sota1.bind(core);
        self.sota2.bind(core);
    }

    fn place(&mut self, ctx: &mut SchedCtx<'_>, task: &Task) -> Placement {
        let kind = ctx.core.policy.kind;
        route!(self, kind, place(ctx, task))
    }

    fn admit(&mut self, ctx: &mut SchedCtx<'_>, task: Task) {
        let kind = ctx.core.policy.kind;
        route!(self, kind, admit(ctx, task))
    }

    fn on_edge_idle(&mut self, ctx: &mut SchedCtx<'_>) -> Option<usize> {
        let kind = ctx.core.policy.kind;
        route!(self, kind, on_edge_idle(ctx))
    }

    fn expected_cloud(&self, core: &Core, model: DnnKind) -> Micros {
        route!(self, core.policy.kind, expected_cloud(core, model))
    }

    fn on_cloud_skip(&mut self, core: &Core, now: Micros, model: DnnKind) {
        route!(self, core.policy.kind, on_cloud_skip(core, now, model))
    }

    fn on_cloud_report(&mut self, ctx: &mut SchedCtx<'_>,
                       report: &CloudReport) {
        let kind = ctx.core.policy.kind;
        route!(self, kind, on_cloud_report(ctx, report))
    }

    fn on_task_done(&mut self, ctx: &mut SchedCtx<'_>, model: DnnKind,
                    success: bool) {
        let kind = ctx.core.policy.kind;
        route!(self, kind, on_task_done(ctx, model, success))
    }

    fn on_window_close(&mut self, ctx: &mut SchedCtx<'_>,
                       model_idx: usize) {
        let kind = ctx.core.policy.kind;
        route!(self, kind, on_window_close(ctx, model_idx))
    }
}
