//! GEMS(-A) — DEMS plus the QoE window monitor of Algorithm 1 (§6).
//!
//! Admission, stealing and adaptation are exactly the DEMS family's
//! (shared via [`dem_admit`] / [`steal_candidate`] / the estimator); the
//! addition is the per-completion hook: when a model's incremental window
//! completion rate α̂ falls behind its target α, the scheduler greedily
//! reschedules that model's pending edge tasks to the cloud (lines 8–14).
//!
//! Under a fleet [`Federation`](crate::cluster::Federation) GEMS behaves
//! like DEMS: its policy flags pass the default
//! [`Scheduler::federates`] gate, so rescheduled-to-cloud tasks parked in
//! the deferred queue are steal candidates for idle sibling edges too.

use crate::model::DnnKind;
use crate::platform::Core;
use crate::queues::CloudEntry;
use crate::sched::dems::CloudEstimator;
use crate::sched::{dem_admit, steal_candidate, CloudReport, SchedCtx,
                   Scheduler};
use crate::sim::Event;
use crate::task::Task;
use crate::time::Micros;

/// GEMS (and GEMS-A when the policy is adaptive).
#[derive(Clone, Debug, Default)]
pub struct Gems {
    est: CloudEstimator,
}

impl Gems {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for Gems {
    fn family(&self) -> &'static str {
        "gems"
    }

    fn bind(&mut self, core: &Core) {
        self.est.bind(core);
    }

    fn admit(&mut self, ctx: &mut SchedCtx<'_>, task: Task) {
        dem_admit(self, ctx, task);
    }

    fn on_edge_idle(&mut self, ctx: &mut SchedCtx<'_>) -> Option<usize> {
        steal_candidate(ctx.core, ctx.now)
    }

    fn expected_cloud(&self, core: &Core, kind: DnnKind) -> Micros {
        self.est.expected(core, kind)
    }

    fn on_cloud_skip(&mut self, core: &Core, now: Micros, kind: DnnKind) {
        self.est.on_skip(core, now, kind);
    }

    fn on_cloud_report(&mut self, ctx: &mut SchedCtx<'_>,
                       report: &CloudReport) {
        self.est.observe(ctx.core, report.kind, report.duration);
    }

    /// Algorithm 1, per-completion trigger: the core has already updated
    /// α̂; when the model falls behind, greedily reschedule its pending
    /// edge tasks to the cloud (lines 8–14).
    fn on_task_done(&mut self, ctx: &mut SchedCtx<'_>, kind: DnnKind,
                    _success: bool) {
        let now = ctx.now;
        let i = ctx.core.idx(kind);
        if !ctx.core.qoe[i].enabled() {
            return;
        }
        if !(ctx.core.policy.gems && ctx.core.qoe[i].falling_behind()) {
            return;
        }
        if ctx.core.profile(kind).util_cloud() <= 0.0 {
            return; // GEMS only helps via positive-utility cloud runs (§6)
        }
        let t_hat = self.est.expected(ctx.core, kind);
        let fixed_cut = matches!(ctx.core.policy.pipeline,
                                 crate::policy::PipelineCut::Fixed { .. });
        let pending = ctx.core.edge_q.tasks_of_model(kind);
        for (_, tid) in pending {
            // Re-find by id: earlier removals shift indices.
            let Some((abs_deadline, pipelined)) = ctx
                .core
                .edge_q
                .iter()
                .find(|e| e.task.id == tid)
                .map(|e| (e.abs_deadline, e.task.pipeline.is_some()))
            else {
                continue;
            };
            // Under a fixed partition the cut is the experiment's control
            // variable: GEMS must not move pipeline stages across it.
            if fixed_cut && pipelined {
                continue;
            }
            if now + t_hat <= abs_deadline {
                let e = ctx.core.edge_q.remove_task(tid).unwrap();
                ctx.core.cloud_q.insert(CloudEntry {
                    task: e.task,
                    abs_deadline: e.abs_deadline,
                    t_cloud: t_hat,
                    t_edge: e.t_edge,
                    trigger: now,
                    negative_utility: false,
                    gems_rescheduled: true,
                    pinned: false,
                });
                ctx.q.push(now, Event::CloudTrigger);
            }
        }
    }
}
