//! The simple baselines of §8.2: edge-only (EDF/HPF), cloud-only and the
//! two E+C hybrids (EDF/SJF). The queue *ordering* differences live in
//! [`Policy::edge_order`](crate::policy::Policy); these schedulers only
//! decide placement.

use crate::sched::{Placement, SchedCtx, Scheduler};
use crate::task::Task;

/// Edge-only execution (EO-EDF / EO-HPF): every task joins the edge queue
/// unconditionally — there is no cloud to shed to. Whether stale tasks are
/// JIT-dropped at the executor is the platform's `edge_jit_drop` switch
/// (§8.8's field configuration runs them regardless).
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeOnly;

impl Scheduler for EdgeOnly {
    fn family(&self) -> &'static str {
        "edge-only"
    }

    fn place(&mut self, _ctx: &mut SchedCtx<'_>, _task: &Task) -> Placement {
        Placement::Edge
    }
}

/// Cloud-only FaaS scheduling (CLD): every task is offered to the cloud;
/// negative-utility models are dropped there (§8.3's BP behaviour).
#[derive(Clone, Copy, Debug, Default)]
pub struct CloudOnly;

impl Scheduler for CloudOnly {
    fn family(&self) -> &'static str {
        "cloud-only"
    }

    fn place(&mut self, _ctx: &mut SchedCtx<'_>, _task: &Task) -> Placement {
        Placement::Cloud
    }
}

/// E+C admission (§5.1): edge if self-feasible, else offer to cloud.
/// Covers both EDF (E+C) and SJF (E+C) — the queue order and whether the
/// cloud accepts negative-utility tasks come from the policy.
#[derive(Clone, Copy, Debug, Default)]
pub struct EcBaseline;

impl Scheduler for EcBaseline {
    fn family(&self) -> &'static str {
        "e+c"
    }

    fn place(&mut self, ctx: &mut SchedCtx<'_>, task: &Task) -> Placement {
        let p = ctx.core.profile(task.model);
        let dl = task.absolute_deadline(p.deadline);
        let (te, hp) = (p.t_edge, p.hpf_priority());
        let busy = ctx.core.edge_busy_until(ctx.now);
        if ctx.core.edge_q.feasible(dl, te, hp, busy) {
            Placement::Edge
        } else {
            Placement::Cloud
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::CloudExecModel;
    use crate::model::{table1, DnnKind};
    use crate::net::ConstantNet;
    use crate::platform::Platform;
    use crate::policy::Policy;
    use crate::sim::EventQueue;
    use crate::task::VideoSegment;
    use crate::time::ms;

    fn cloud() -> CloudExecModel {
        CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }))
    }

    fn task(p: &mut Platform, kind: DnnKind) -> Task {
        let id = p.fresh_task_id();
        Task {
            id,
            model: kind,
            segment: VideoSegment { id, drone: 0, created_at: 0,
                                    bytes: 38_000 },
        }
    }

    #[test]
    fn cloud_only_never_touches_the_edge_queue() {
        let mut p = Platform::new(Policy::cloud_only(), table1(), cloud(), 1);
        let mut q = EventQueue::new();
        let t = task(&mut p, DnnKind::Hv);
        p.submit_task(0, t, &mut q);
        assert_eq!(p.edge_queue_len(), 0);
        assert_eq!(p.cloud_queue_len(), 1);
    }

    #[test]
    fn edge_only_queues_unconditionally() {
        let mut p = Platform::new(Policy::edge_edf(), table1(), cloud(), 1);
        let mut q = EventQueue::new();
        for _ in 0..5 {
            let t = task(&mut p, DnnKind::Deo);
            p.submit_task(0, t, &mut q);
        }
        // One executing + four queued; nothing offloaded or dropped yet.
        assert_eq!(p.edge_queue_len(), 4);
        assert_eq!(p.cloud_queue_len(), 0);
        assert_eq!(p.metrics.generated(), 5);
    }

    #[test]
    fn ec_offloads_when_infeasible() {
        let mut p = Platform::new(Policy::edf_ec(), table1(), cloud(), 1);
        let mut q = EventQueue::new();
        let deo = task(&mut p, DnnKind::Deo);
        p.submit_task(0, deo, &mut q); // occupies the executor for ~739 ms
        let hv = task(&mut p, DnnKind::Hv);
        p.submit_task(0, hv, &mut q); // 650 ms deadline behind the DEO
        assert_eq!(p.edge_queue_len(), 0);
        assert_eq!(p.cloud_queue_len(), 1, "HV must offload");
    }
}
