//! Pluggable scheduling heuristics — the paper's *policy* layer, split out
//! of the platform mechanism.
//!
//! The paper contributes a family of heuristics (DEMS → DEMS-A → GEMS plus
//! seven baselines, §5–§6). Each family implements the [`Scheduler`] trait
//! against the mechanism substrate ([`Core`]): queues, executors and
//! metrics stay in [`crate::platform`], while every decision — admission,
//! migration scoring, deferral, stealing, adaptation, the QoE monitor —
//! lives here. [`Policy::build`](crate::policy::Policy::build) resolves a
//! declarative [`Policy`](crate::policy::Policy) into a boxed scheduler.
//!
//! Decision hooks and the paper sections they implement:
//!
//! | hook               | fires when                          | paper |
//! |--------------------|-------------------------------------|-------|
//! | [`Scheduler::admit`] / [`Scheduler::place`] | a task arrives | §5.1–§5.2 |
//! | [`Scheduler::on_edge_idle`] | the edge executor picks next work | §5.3 |
//! | [`Scheduler::on_cloud_report`] | a FaaS invocation finished | §5.4 |
//! | [`Scheduler::on_cloud_skip`] | a task was skipped for the cloud | §5.4 |
//! | [`Scheduler::on_task_done`] | any task finalized | §6 Alg. 1 l. 3–14 |
//! | [`Scheduler::on_window_close`] | a QoE window tumbled | §6 Alg. 1 l. 16–21 |
//!
//! Families:
//!
//! * [`baselines`] — EO(EDF/HPF), CLD, E+C (EDF/SJF): [`EdgeOnly`],
//!   [`CloudOnly`], [`EcBaseline`].
//! * [`dems`] — DEM / DEMS / DEMS-A: [`Dems`].
//! * [`gems`] — GEMS(-A): [`Gems`].
//! * [`sota`] — the two SOTA baselines: [`Sota1`], [`Sota2`].
//! * [`monolith`] — [`FlagBranchScheduler`], a statically dispatched
//!   flag-branch router over all families; the dispatch-parity reference
//!   and benchmark baseline for `Box<dyn Scheduler>`.

pub mod baselines;
pub mod dems;
pub mod gems;
pub mod monolith;
pub mod sota;

pub use baselines::{CloudOnly, EcBaseline, EdgeOnly};
pub use dems::Dems;
pub use gems::Gems;
pub use monolith::FlagBranchScheduler;
pub use sota::{Sota1, Sota2};

use crate::model::DnnKind;
use crate::platform::Core;
use crate::queues::CloudEntry;
use crate::sim::EventQueue;
use crate::task::{DropReason, Task};
use crate::time::Micros;

/// Everything a scheduler may touch while making a decision: the mechanism
/// core, the event queue (for trigger events) and the current virtual time.
pub struct SchedCtx<'a> {
    pub now: Micros,
    pub core: &'a mut Core,
    pub q: &'a mut EventQueue,
}

/// A completed (or throttled) FaaS invocation, reported to the scheduler
/// before the outcome is finalized (so §5.4 adaptation sees the sample
/// first).
#[derive(Clone, Copy, Debug)]
pub struct CloudReport {
    pub kind: DnnKind,
    /// Actual end-to-end duration (includes the timeout value when
    /// `timed_out`; for throttled attempts, the retry backoff plus the
    /// expectation at the time of the attempt — the effective delay the
    /// throttle imposed).
    pub duration: Micros,
    pub timed_out: bool,
    pub success: bool,
    /// The attempt never ran: the backend's per-account concurrency
    /// ceiling rejected it (see [`crate::cloud`]). Adaptive schedulers
    /// fold these into their estimates like any slow observation.
    pub throttled: bool,
}

/// Where a simple (non-mutating) admission decision sends a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Insert into the edge queue under the profile deadline.
    Edge,
    /// Insert into the edge queue under an explicit absolute deadline
    /// (SOTA 1's stretched deadlines).
    EdgeWithDeadline(Micros),
    /// Offer to the cloud path (deferral and utility rules apply).
    Cloud,
    /// Refuse outright.
    Drop(DropReason),
}

/// A scheduling heuristic. Implementations are deterministic and
/// side-effect-free outside the [`SchedCtx`] they are handed.
///
/// Simple heuristics implement [`place`](Scheduler::place) and inherit the
/// default [`admit`](Scheduler::admit); heuristics that mutate the queues
/// during admission (DEM's migration) override `admit` wholesale.
///
/// `Send` is a supertrait so the real-time serving lane can own a boxed
/// scheduler on its executor thread.
pub trait Scheduler: Send {
    /// Short family tag for reports and logs ("dems", "e+c", …).
    fn family(&self) -> &'static str;

    /// One-time hookup to a freshly built core (e.g. sizing per-model
    /// adaptation state). Default: nothing.
    fn bind(&mut self, _core: &Core) {}

    /// Pure placement decision for one arriving task (§5.1). Only consulted
    /// by the default [`admit`](Scheduler::admit).
    fn place(&mut self, _ctx: &mut SchedCtx<'_>, _task: &Task) -> Placement {
        Placement::Edge
    }

    /// Admission (§5.1–§5.2): route the task to the edge queue, the cloud
    /// path or a drop. The platform calls `try_start_edge` afterwards.
    fn admit(&mut self, ctx: &mut SchedCtx<'_>, task: Task) {
        match self.place(ctx, &task) {
            Placement::Edge => {
                let (dl, te, hp) = {
                    let p = ctx.core.profile(task.model);
                    (task.absolute_deadline(p.deadline), p.t_edge,
                     p.hpf_priority())
                };
                ctx.core.enqueue_edge(ctx.now, task, dl, te, hp);
            }
            Placement::EdgeWithDeadline(dl) => {
                let (te, hp) = {
                    let p = ctx.core.profile(task.model);
                    (p.t_edge, p.hpf_priority())
                };
                ctx.core.enqueue_edge(ctx.now, task, dl, te, hp);
            }
            Placement::Cloud => {
                self.offer_cloud(ctx, task, false);
            }
            Placement::Drop(reason) => {
                ctx.core.drop_task(ctx.now, task, reason);
                self.drain_done(ctx);
            }
        }
    }

    /// The edge executor is idle and about to pick work: return the index
    /// of a cloud-queue entry to steal (§5.3), or `None` to run the edge
    /// queue head.
    fn on_edge_idle(&mut self, _ctx: &mut SchedCtx<'_>) -> Option<usize> {
        None
    }

    /// Expected cloud duration t̂ᵢ used for admission/JIT/trigger math —
    /// the static Table-1 value unless the heuristic adapts it (§5.4).
    fn expected_cloud(&self, core: &Core, kind: DnnKind) -> Micros {
        core.profile(kind).t_cloud
    }

    /// A task of `kind` was skipped for the cloud because the expected
    /// duration made it infeasible (the §5.4 cooling-reset signal).
    fn on_cloud_skip(&mut self, _core: &Core, _now: Micros,
                     _kind: DnnKind) {
    }

    /// A FaaS invocation finished (fires before the outcome is finalized).
    fn on_cloud_report(&mut self, _ctx: &mut SchedCtx<'_>,
                       _report: &CloudReport) {
    }

    /// A task of `kind` was finalized with `success` (Alg. 1 lines 3–14;
    /// the window counters have already been updated by the core).
    fn on_task_done(&mut self, _ctx: &mut SchedCtx<'_>, _kind: DnnKind,
                    _success: bool) {
    }

    /// A model's tumbling QoE window closed (after the core accrued the
    /// window's QoE utility).
    fn on_window_close(&mut self, _ctx: &mut SchedCtx<'_>,
                       _model_idx: usize) {
    }

    /// Fleet federation (cross-edge §5.3): may this edge's deferred
    /// cloud entries be offered to sibling edges? The default follows
    /// the local steal gate — only deferring, stealing policies
    /// participate, so federation *extends* §5.3 rather than overruling
    /// a policy that never steals. DEMS/GEMS inherit this (their
    /// `stealing`+`defer_cloud` flags opt them in); the candidate itself
    /// is then ranked with the same κ/κ̂ machinery as
    /// [`steal_candidate`].
    fn federates(&self, core: &Core) -> bool {
        core.policy.stealing && core.policy.defer_cloud
    }

    // ------------------------------------------------- provided machinery

    /// Deliver buffered task-done reports (from finalizes performed inside
    /// core or scheduler code) to [`on_task_done`](Scheduler::on_task_done).
    /// Called by the platform right after every finalize point, and by the
    /// provided helpers below, so hook ordering matches the pre-split
    /// monolith exactly.
    fn drain_done(&mut self, ctx: &mut SchedCtx<'_>) {
        while let Some((kind, success)) = ctx.core.pop_done() {
            self.on_task_done(ctx, kind, success);
        }
    }

    /// Offer a task to the cloud scheduler (§5.1/§5.3). Returns true if it
    /// was queued; otherwise its drop has been finalized.
    ///
    /// Shared across every hybrid family: JIT-infeasible tasks are dropped
    /// (with the §5.4 skip signal), negative-utility tasks are either kept
    /// as steal candidates until their latest edge start (§5.3, when the
    /// policy defers and steals) or dropped, and positive-utility tasks get
    /// a deferred trigger under DEMS. The deferral headroom is
    /// 1.5·t̂ + margin: t̂ is a p95, so leaving only t̂ of runway turns every
    /// above-p95 draw (and any transfer contention from synchronized
    /// triggers) into a miss billed at κ̂. In practice this defers only
    /// long-deadline/short-t̂ tasks — the same population §5.3 observes
    /// being stolen.
    fn offer_cloud(&mut self, ctx: &mut SchedCtx<'_>, task: Task,
                   gems: bool) -> bool {
        if !ctx.core.policy.use_cloud {
            ctx.core.drop_task(ctx.now, task, DropReason::Infeasible);
            self.drain_done(ctx);
            return false;
        }
        // Read the profile scalars the decision needs up front (no
        // per-offer profile clone on this hot path). For pipeline stages
        // the cloud utility is stage-aware: the *remaining chain's*
        // utility — the final stage's β minus every remaining κ̂ — not
        // just this stage's own γᶜ, so DEMS ranks the cut by what the
        // whole suffix earns (exact profile γᶜ for plain tasks).
        let (dl, t_edge, util_cloud) = {
            let p = ctx.core.profile(task.model);
            (task.absolute_deadline(p.deadline), p.t_edge,
             crate::pipeline::chain_util_cloud(task.pipeline.as_ref(), p,
                                               &ctx.core.models))
        };
        let t_hat = self.expected_cloud(ctx.core, task.model);
        if ctx.now + t_hat > dl {
            self.on_cloud_skip(ctx.core, ctx.now, task.model);
            ctx.core.drop_task(ctx.now, task, DropReason::Infeasible);
            self.drain_done(ctx);
            return false;
        }
        let negative = util_cloud <= 0.0;
        if negative && !ctx.core.policy.cloud_accepts_negative {
            if ctx.core.policy.defer_cloud && ctx.core.policy.stealing {
                // §5.3: keep as a steal candidate until the latest time it
                // could still start on the edge.
                let trigger = dl.saturating_sub(t_edge).max(ctx.now);
                let entry = CloudEntry {
                    task,
                    abs_deadline: dl,
                    t_cloud: t_hat,
                    t_edge,
                    trigger,
                    negative_utility: true,
                    gems_rescheduled: gems,
                    pinned: false,
                };
                ctx.core.push_cloud(ctx.now, entry, ctx.q);
                return true;
            }
            ctx.core.drop_task(ctx.now, task,
                               DropReason::NegativeCloudUtility);
            self.drain_done(ctx);
            return false;
        }
        // Positive-utility path: deferred trigger under DEMS, immediate
        // dispatch otherwise (and always immediate for GEMS reschedules).
        let trigger = if ctx.core.policy.defer_cloud && !gems {
            dl.saturating_sub(
                t_hat + t_hat / 2 + ctx.core.policy.safety_margin,
            )
            .max(ctx.now)
        } else {
            ctx.now
        };
        let entry = CloudEntry {
            task,
            abs_deadline: dl,
            t_cloud: t_hat,
            t_edge,
            trigger,
            negative_utility: negative,
            gems_rescheduled: gems,
            pinned: false,
        };
        ctx.core.push_cloud(ctx.now, entry, ctx.q);
        true
    }
}

/// Forward the trait through a box so `Platform<Box<dyn Scheduler>>` (the
/// default) works. Only the required/overridable hooks are forwarded; the
/// provided machinery (`offer_cloud`, `drain_done`) composes through the
/// forwarded primitives.
impl Scheduler for Box<dyn Scheduler> {
    fn family(&self) -> &'static str {
        (**self).family()
    }

    fn bind(&mut self, core: &Core) {
        (**self).bind(core)
    }

    fn place(&mut self, ctx: &mut SchedCtx<'_>, task: &Task) -> Placement {
        (**self).place(ctx, task)
    }

    fn admit(&mut self, ctx: &mut SchedCtx<'_>, task: Task) {
        (**self).admit(ctx, task)
    }

    fn on_edge_idle(&mut self, ctx: &mut SchedCtx<'_>) -> Option<usize> {
        (**self).on_edge_idle(ctx)
    }

    fn expected_cloud(&self, core: &Core, kind: DnnKind) -> Micros {
        (**self).expected_cloud(core, kind)
    }

    fn on_cloud_skip(&mut self, core: &Core, now: Micros, kind: DnnKind) {
        (**self).on_cloud_skip(core, now, kind)
    }

    fn on_cloud_report(&mut self, ctx: &mut SchedCtx<'_>,
                       report: &CloudReport) {
        (**self).on_cloud_report(ctx, report)
    }

    fn on_task_done(&mut self, ctx: &mut SchedCtx<'_>, kind: DnnKind,
                    success: bool) {
        (**self).on_task_done(ctx, kind, success)
    }

    fn on_window_close(&mut self, ctx: &mut SchedCtx<'_>,
                       model_idx: usize) {
        (**self).on_window_close(ctx, model_idx)
    }

    fn federates(&self, core: &Core) -> bool {
        (**self).federates(core)
    }
}

/// §5.3 steal-candidate selection shared by DEMS and GEMS: only when the
/// policy steals, only when the queued tasks leave more slack than the
/// smallest model's edge time, best candidate by (negative-utility first,
/// then steal rank).
pub(crate) fn steal_candidate(core: &Core, now: Micros) -> Option<usize> {
    if !core.policy.stealing {
        return None;
    }
    let slack = core.edge_min_slack(now);
    if slack <= core.min_t_edge as i64 {
        return None;
    }
    let models = &core.models;
    core.cloud_q.best_steal(now, slack, |e| {
        models
            .iter()
            .find(|m| m.kind == e.task.model)
            .map(|m| m.steal_rank())
            .unwrap_or(f64::MIN)
    })
}

/// DEM/DEMS admission with migration scoring (§5.2, Fig. 5), shared by the
/// DEMS and GEMS families. Generic over the scheduler so the cloud offers
/// run through the caller's own `expected_cloud` / skip hooks.
pub(crate) fn dem_admit<S: Scheduler + ?Sized>(s: &mut S,
                                               ctx: &mut SchedCtx<'_>,
                                               task: Task) {
    // Profile scalars via a short borrow — admission runs per task, and
    // the old per-admission profile clone showed up in the benches.
    let (dl, t_edge, hpf) = {
        let p = ctx.core.profile(task.model);
        (task.absolute_deadline(p.deadline), p.t_edge, p.hpf_priority())
    };
    let busy = ctx.core.edge_busy_until(ctx.now);
    let probe = ctx.core.edge_q.probe_insert(dl, t_edge, hpf, busy);
    if probe.completion > dl {
        // Scenario "own deadline missed": redirect to cloud.
        s.offer_cloud(ctx, task, false);
        return;
    }
    if !probe.victims.is_empty() && ctx.core.policy.migration {
        // Eqn 3 scores for the victims and the incoming task.
        let t_hat_in = s.expected_cloud(ctx.core, task.model);
        let s_in = ctx
            .core
            .profile(task.model)
            .migration_score(ctx.now + t_hat_in <= dl);
        let mut s_victims = 0.0;
        for &vi in &probe.victims {
            let (vmodel, vcreated) = {
                let e = &ctx.core.edge_q.get(vi).unwrap().task;
                (e.model, e.segment.created_at)
            };
            let vp_deadline = ctx.core.profile(vmodel).deadline;
            let t_hat = s.expected_cloud(ctx.core, vmodel);
            let feasible = ctx.now + t_hat <= vcreated + vp_deadline;
            s_victims += ctx.core.profile(vmodel).migration_score(feasible);
        }
        if s_victims < s_in {
            // Migrate the victims (rear-first so indices stay valid),
            // then insert the incoming task (Fig. 5, scenario 2).
            for &vi in probe.victims.iter().rev() {
                let victim = ctx.core.edge_q.remove_at(vi);
                s.offer_cloud(ctx, victim.task, false);
            }
            ctx.core.enqueue_edge(ctx.now, task, dl, t_edge, hpf);
        } else {
            // Retain existing tasks; incoming goes to the cloud
            // (Fig. 5, scenario 3).
            s.offer_cloud(ctx, task, false);
        }
    } else {
        ctx.core.enqueue_edge(ctx.now, task, dl, t_edge, hpf);
    }
}
