//! The two state-of-the-art baselines of §8.2.

use crate::sched::{Placement, SchedCtx, Scheduler};
use crate::task::Task;
use crate::time::Micros;

/// SOTA 1 (Kalmia + D3 hybrid): urgent tasks never wait for a stretched
/// deadline; non-urgent tasks get a one-shot 10% deadline extension before
/// being offloaded.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sota1;

impl Scheduler for Sota1 {
    fn family(&self) -> &'static str {
        "sota1"
    }

    fn place(&mut self, ctx: &mut SchedCtx<'_>, task: &Task) -> Placement {
        let (deadline, te, hp) = {
            let p = ctx.core.profile(task.model);
            (p.deadline, p.t_edge, p.hpf_priority())
        };
        let dl = task.absolute_deadline(deadline);
        let busy = ctx.core.edge_busy_until(ctx.now);
        if ctx.core.edge_q.feasible(dl, te, hp, busy) {
            return Placement::Edge;
        }
        let urgent = deadline < ctx.core.policy.sota1_urgent_below;
        if !urgent {
            let stretched = dl
                + (deadline as f64 * ctx.core.policy.sota1_extension)
                    as Micros;
            if ctx.core.edge_q.feasible(stretched, te, hp, busy) {
                return Placement::EdgeWithDeadline(stretched);
            }
        }
        Placement::Cloud
    }
}

/// SOTA 2 (Dedas-style): exec-time priority; reject to cloud when more
/// than one queued task would miss its deadline, otherwise keep the
/// schedule with the lower average completion time.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sota2;

impl Scheduler for Sota2 {
    fn family(&self) -> &'static str {
        "sota2"
    }

    fn place(&mut self, ctx: &mut SchedCtx<'_>, task: &Task) -> Placement {
        let (te, hp, dl) = {
            let p = ctx.core.profile(task.model);
            (p.t_edge, p.hpf_priority(), task.absolute_deadline(p.deadline))
        };
        let busy = ctx.core.edge_busy_until(ctx.now);
        let probe = ctx.core.edge_q.probe_insert(dl, te, hp, busy);
        let accept = if probe.completion > dl || probe.victims.len() > 1 {
            false
        } else if probe.victims.is_empty() {
            true
        } else {
            // One victim: compare ACT of the two candidate schedules.
            let act_without = ctx.core.edge_act(busy, None);
            let act_with = ctx.core.edge_act(busy, Some((probe.pos, te)));
            act_with <= act_without + te as f64
        };
        if accept {
            Placement::Edge
        } else {
            Placement::Cloud
        }
    }
}
