//! Fault-injection & chaos subsystem (beyond-paper).
//!
//! The paper's DEMS-A/GEMS heuristics promise QoS under *cloud
//! variability*, but real fleets also lose whole substrates: an edge
//! station reboots, a FaaS region goes dark, a backhaul link degrades.
//! This module gives the engine a deterministic failure model:
//!
//! * [`FaultSpec`] — a declarative, seed-free schedule of
//!   [`EdgeCrash`]es, [`RegionOutage`]s and [`LinkFlap`]s. It is
//!   *compiled* at cluster setup into [`Event::Fault`](crate::sim::Event)
//!   entries on the existing scope-tagged event queue, so faults ride the
//!   same `(time, push order)` determinism contract as everything else —
//!   and, being pushed before handovers and all in-run events, a fault at
//!   `t` strictly precedes any same-instant event.
//! * [`FaultDriver`] — the runtime state the cluster loop consults:
//!   which edges are down, since when, which drones were re-homed away
//!   from a crashed edge (restored at recovery), and the shared
//!   degraded-bandwidth cell behind [`DegradedLan`].
//! * [`Recovery`] — what a crashed edge does with its *queued* work:
//!   [`Recovery::Lose`] drops it with
//!   [`DropReason::NodeFailure`](crate::task::DropReason), while
//!   [`Recovery::Requeue`] pushes still-feasible entries through the
//!   fleet-federation steal path (`Event::FedArrive` after a LAN
//!   transfer) to live siblings. Work already *executing* on the dead
//!   substrate (the edge slot, in-flight cloud invocations it would have
//!   received) is always lost — you cannot steal from a corpse.
//!
//! The empty spec is inert by construction: [`FaultSpec::enabled`] gates
//! every hook in `cluster.rs`, so faults-off runs stay bit-identical to
//! the pre-subsystem engine (pinned by the sweep-parity tests).

use std::sync::{Arc, Mutex};

use crate::net::NetworkModel;
use crate::rng::Rng;
use crate::sim::{Event, EventQueue};
use crate::time::Micros;

/// Policy knob: what a crashed edge does with its recoverable (queued,
/// not-yet-executing) work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Recovery {
    /// Queued work dies with the node (`DropReason::NodeFailure`).
    #[default]
    Lose,
    /// Still-feasible queued entries are re-queued through the
    /// fleet-federation steal path to live siblings (a LAN transfer plus
    /// the thief's own just-in-time admission). Degrades to [`Lose`]
    /// when the cluster is not federated — there is no path to a
    /// sibling without one.
    ///
    /// [`Lose`]: Recovery::Lose
    Requeue,
}

/// One edge station failing at `at` (and optionally rebooting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeCrash {
    pub edge: usize,
    pub at: Micros,
    /// Reboot instant; `None` = the edge stays dark to the horizon.
    pub recover_at: Option<Micros>,
}

/// One FaaS region dark over `[from, until)`; layers onto
/// [`MultiRegionBackend`](crate::cloud::MultiRegionBackend) failover and
/// surfaces as throttle-shaped reports, so DEMS-A's §5.4 adaptation
/// window reacts to it like any other cloud degradation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionOutage {
    pub region: usize,
    pub from: Micros,
    pub until: Micros,
}

/// Which shared link a [`LinkFlap`] degrades.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlapLink {
    /// The cluster's shared cloud uplink ([`crate::net::SharedUplink`]).
    Uplink,
    /// The inter-edge LAN the federation steals over.
    Lan,
}

/// A link-bandwidth flap: over `[from, until)` the link runs at
/// `degraded_bps` bytes/second instead of its nominal rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFlap {
    pub link: FlapLink,
    pub from: Micros,
    pub until: Micros,
    pub degraded_bps: f64,
}

/// Deterministic fault schedule for one cluster run. Empty = inert
/// (bit-identical engine, see module docs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub crashes: Vec<EdgeCrash>,
    pub outages: Vec<RegionOutage>,
    pub flaps: Vec<LinkFlap>,
    pub recovery: Recovery,
}

impl FaultSpec {
    /// Add an edge crash (recovering at `recover_at`, or never).
    pub fn crash(mut self, edge: usize, at: Micros,
                 recover_at: Option<Micros>) -> Self {
        self.crashes.push(EdgeCrash { edge, at, recover_at });
        self
    }

    /// Add a region outage over `[from, until)`.
    pub fn outage(mut self, region: usize, from: Micros,
                  until: Micros) -> Self {
        self.outages.push(RegionOutage { region, from, until });
        self
    }

    /// Add a link flap over `[from, until)`.
    pub fn flap(mut self, link: FlapLink, from: Micros, until: Micros,
                degraded_bps: f64) -> Self {
        self.flaps.push(LinkFlap { link, from, until, degraded_bps });
        self
    }

    /// Set the crashed-edge recovery policy.
    pub fn with_recovery(mut self, recovery: Recovery) -> Self {
        self.recovery = recovery;
        self
    }

    /// Does this spec inject anything at all? The all-empty spec leaves
    /// the engine untouched (bit-identity pin).
    pub fn enabled(&self) -> bool {
        !(self.crashes.is_empty()
            && self.outages.is_empty()
            && self.flaps.is_empty())
    }

    /// Largest edge index referenced by a crash (setup validation).
    pub fn max_edge(&self) -> Option<usize> {
        self.crashes.iter().map(|c| c.edge).max()
    }

    /// Compile the schedule into `Event::Fault` entries. Called at
    /// cluster setup *before* handovers and segment seeds are pushed, so
    /// at equal timestamps a fault wins the tie by push order (the
    /// "crash at exactly a handover boundary tick" contract).
    pub fn compile(&self, q: &mut EventQueue) {
        for c in &self.crashes {
            q.set_scope(c.edge as u32);
            q.push(c.at, Event::Fault(FaultAction::Crash { edge: c.edge }));
            if let Some(r) = c.recover_at {
                q.push(r, Event::Fault(FaultAction::Recover {
                    edge: c.edge,
                }));
            }
        }
        q.set_scope(0);
        for o in &self.outages {
            q.push(o.from, Event::Fault(FaultAction::OutageStart {
                region: o.region,
                until: o.until,
            }));
            q.push(o.until, Event::Fault(FaultAction::OutageEnd {
                region: o.region,
            }));
        }
        for f in &self.flaps {
            q.push(f.from, Event::Fault(FaultAction::FlapStart {
                link: f.link,
                degraded_bps: f.degraded_bps,
            }));
            q.push(f.until, Event::Fault(FaultAction::FlapEnd {
                link: f.link,
            }));
        }
    }

    /// Draw a random, internally consistent spec for the chaos axis of
    /// the invariants harness: 1–2 crashes (70% recovering), an optional
    /// outage and an optional flap, random recovery policy. All indices
    /// stay within `n_edges`/`duration`.
    pub fn random(rng: &mut Rng, n_edges: usize, duration: Micros) -> Self {
        let mut spec = FaultSpec::default();
        for _ in 0..(1 + rng.below(2)) {
            let at = duration / 10 + rng.below((duration / 2) as usize) as u64;
            let recover_at = if rng.chance(0.7) {
                Some(at + 1 + rng.below((duration / 3).max(1) as usize) as u64)
            } else {
                None
            };
            spec = spec.crash(rng.below(n_edges), at, recover_at);
        }
        if rng.chance(0.3) {
            let from = rng.below(duration as usize / 2) as u64;
            let until = from + 1 + rng.below(duration as usize / 3) as u64;
            spec = spec.outage(rng.below(2), from, until);
        }
        if rng.chance(0.3) {
            let from = rng.below(duration as usize / 2) as u64;
            let until = from + 1 + rng.below(duration as usize / 3) as u64;
            let link = if rng.chance(0.5) {
                FlapLink::Uplink
            } else {
                FlapLink::Lan
            };
            spec = spec.flap(link, from, until,
                             (1 + rng.below(20)) as f64 * 1.0e6);
        }
        if rng.chance(0.5) {
            spec = spec.with_recovery(Recovery::Requeue);
        }
        spec
    }
}

/// One compiled fault firing, carried by [`Event::Fault`](crate::sim::Event).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Edge `edge` dies: its in-flight work is lost, its queued work is
    /// lost or relocated per [`Recovery`], its drones re-home.
    Crash { edge: usize },
    /// Edge `edge` reboots: empty queues, drones re-homed back.
    Recover { edge: usize },
    /// Region dark until `until` (the backend refuses invocations,
    /// shaped as throttles).
    OutageStart { region: usize, until: Micros },
    /// Region back up (defensive clear; `invoke` also checks `until`).
    OutageEnd { region: usize },
    /// Link degraded to `degraded_bps` bytes/second.
    FlapStart { link: FlapLink, degraded_bps: f64 },
    /// Link back to nominal bandwidth.
    FlapEnd { link: FlapLink },
}

/// Runtime fault state the cluster loop consults. Only constructed when
/// the spec is [`enabled`](FaultSpec::enabled) — faults-off runs never
/// touch it.
pub struct FaultDriver {
    pub recovery: Recovery,
    down: Vec<bool>,
    down_since: Vec<Micros>,
    /// Per crashed edge: the drones re-homed away from it, with the
    /// router override they had *before* the crash (restored verbatim at
    /// recovery — unless a planned handover retargeted the drone while
    /// the edge was dark, see `forget_rehome`).
    rehomed: Vec<Vec<(u32, Option<u32>)>>,
    /// Degraded-bandwidth cell shared with [`DegradedLan`]; `None` in
    /// the cell = nominal.
    pub lan_degraded: Arc<Mutex<Option<f64>>>,
    /// Nominal shared-uplink bandwidth saved at flap start.
    pub uplink_nominal: Option<f64>,
}

impl FaultDriver {
    pub fn new(n_edges: usize, recovery: Recovery) -> Self {
        FaultDriver {
            recovery,
            down: vec![false; n_edges],
            down_since: vec![0; n_edges],
            rehomed: vec![Vec::new(); n_edges],
            lan_degraded: Arc::new(Mutex::new(None)),
            uplink_nominal: None,
        }
    }

    #[inline]
    pub fn is_down(&self, e: usize) -> bool {
        self.down.get(e).copied().unwrap_or(false)
    }

    /// Lowest-index live edge, skipping `except` — the deterministic
    /// re-home / relocation fallback target.
    pub fn live_edge(&self, except: usize) -> Option<usize> {
        (0..self.down.len()).find(|&e| e != except && !self.down[e])
    }

    /// Mark `e` down at `now`; returns false if it already was (a
    /// double-crash in a random spec is a no-op, not a double sweep).
    pub fn mark_down(&mut self, e: usize, now: Micros) -> bool {
        if self.down[e] {
            return false;
        }
        self.down[e] = true;
        self.down_since[e] = now;
        true
    }

    /// Mark `e` up at `now`; returns the downtime just ended (`None` if
    /// it was not down).
    pub fn mark_up(&mut self, e: usize, now: Micros) -> Option<Micros> {
        if !self.down[e] {
            return None;
        }
        self.down[e] = false;
        Some(now.saturating_sub(self.down_since[e]))
    }

    /// Downtime still open at the horizon for a never-recovered edge.
    pub fn residual_downtime(&self, e: usize, horizon: Micros) -> Micros {
        if self.down[e] {
            horizon.saturating_sub(self.down_since[e])
        } else {
            0
        }
    }

    /// Remember a drone re-homed away from crashed edge `e` (`prev` =
    /// its router override before the crash).
    pub fn save_rehome(&mut self, e: usize, drone: u32,
                       prev: Option<u32>) {
        self.rehomed[e].push((drone, prev));
    }

    /// A planned handover retargeted `drone` mid-downtime: its pre-crash
    /// home is stale, so recovery must not undo the handover.
    pub fn forget_rehome(&mut self, drone: u32) {
        for v in &mut self.rehomed {
            v.retain(|&(d, _)| d != drone);
        }
    }

    /// Take the re-home list saved for edge `e` (at recovery).
    pub fn take_rehomed(&mut self, e: usize) -> Vec<(u32, Option<u32>)> {
        std::mem::take(&mut self.rehomed[e])
    }
}

/// Federation-LAN wrapper that overrides bandwidth while a
/// [`FlapLink::Lan`] flap is active. Installed once at cluster setup
/// (only when the spec contains a LAN flap); the driver toggles the
/// shared cell at `FlapStart`/`FlapEnd`.
pub struct DegradedLan {
    pub inner: Box<dyn NetworkModel>,
    pub degraded: Arc<Mutex<Option<f64>>>,
}

impl NetworkModel for DegradedLan {
    fn latency(&mut self, now: Micros, rng: &mut Rng) -> Micros {
        self.inner.latency(now, rng)
    }
    fn bandwidth(&mut self, now: Micros, rng: &mut Rng) -> f64 {
        match *self.degraded.lock().expect("lan flap cell") {
            Some(bw) => bw,
            None => self.inner.bandwidth(now, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::ConstantNet;
    use crate::time::{ms, secs};

    #[test]
    fn empty_spec_is_disabled_and_compiles_to_nothing() {
        let spec = FaultSpec::default();
        assert!(!spec.enabled());
        let mut q = EventQueue::new();
        spec.compile(&mut q);
        assert!(q.is_empty());
    }

    #[test]
    fn compile_pushes_crash_recover_outage_and_flap_events() {
        let spec = FaultSpec::default()
            .crash(1, secs(10), Some(secs(20)))
            .outage(0, secs(5), secs(15))
            .flap(FlapLink::Uplink, secs(2), secs(4), 1.0e6);
        assert!(spec.enabled());
        assert_eq!(spec.max_edge(), Some(1));
        let mut q = EventQueue::new();
        spec.compile(&mut q);
        let mut got = Vec::new();
        while let Some((at, ev)) = q.pop() {
            let Event::Fault(a) = ev else {
                panic!("non-fault event compiled")
            };
            got.push((at, a));
        }
        assert_eq!(got, vec![
            (secs(2), FaultAction::FlapStart {
                link: FlapLink::Uplink,
                degraded_bps: 1.0e6,
            }),
            (secs(4), FaultAction::FlapEnd { link: FlapLink::Uplink }),
            (secs(5), FaultAction::OutageStart {
                region: 0,
                until: secs(15),
            }),
            (secs(10), FaultAction::Crash { edge: 1 }),
            (secs(15), FaultAction::OutageEnd { region: 0 }),
            (secs(20), FaultAction::Recover { edge: 1 }),
        ]);
    }

    #[test]
    fn driver_tracks_downtime_and_rehomes() {
        let mut d = FaultDriver::new(3, Recovery::Requeue);
        assert!(!d.is_down(1));
        assert!(d.mark_down(1, secs(10)));
        assert!(!d.mark_down(1, secs(11)), "double crash is a no-op");
        assert!(d.is_down(1));
        assert_eq!(d.live_edge(1), Some(0));
        assert!(d.mark_down(0, secs(12)));
        assert_eq!(d.live_edge(1), Some(2));
        d.save_rehome(1, 4, None);
        d.save_rehome(1, 5, Some(2));
        d.forget_rehome(4);
        assert_eq!(d.take_rehomed(1), vec![(5, Some(2))]);
        assert!(d.take_rehomed(1).is_empty());
        assert_eq!(d.mark_up(1, secs(25)), Some(secs(15)));
        assert_eq!(d.mark_up(1, secs(26)), None, "double recover no-op");
        assert_eq!(d.residual_downtime(0, secs(30)), secs(18));
        assert_eq!(d.residual_downtime(1, secs(30)), 0);
    }

    #[test]
    fn all_down_has_no_live_edge() {
        let mut d = FaultDriver::new(2, Recovery::Lose);
        d.mark_down(0, 0);
        assert_eq!(d.live_edge(0), Some(1));
        d.mark_down(1, 0);
        assert_eq!(d.live_edge(0), None);
    }

    #[test]
    fn degraded_lan_overrides_bandwidth_only_while_flapped() {
        let cell = Arc::new(Mutex::new(None));
        let mut lan = DegradedLan {
            inner: Box::new(ConstantNet {
                latency: ms(2),
                bandwidth: 125.0e6,
            }),
            degraded: cell.clone(),
        };
        let mut rng = Rng::new(1);
        assert_eq!(lan.bandwidth(0, &mut rng), 125.0e6);
        let nominal = lan.transfer_time(0, 1_250_000, &mut rng);
        *cell.lock().unwrap() = Some(1.0e6);
        assert_eq!(lan.bandwidth(0, &mut rng), 1.0e6);
        assert!(lan.transfer_time(0, 1_250_000, &mut rng) > nominal);
        *cell.lock().unwrap() = None;
        assert_eq!(lan.bandwidth(0, &mut rng), 125.0e6);
        // Latency passes through untouched.
        assert_eq!(lan.latency(0, &mut rng), ms(2));
    }

    #[test]
    fn random_specs_are_well_formed() {
        let mut rng = Rng::new(0xFA017);
        for _ in 0..200 {
            let n = 1 + rng.below(3);
            let spec = FaultSpec::random(&mut rng, n, secs(20));
            assert!(spec.enabled());
            for c in &spec.crashes {
                assert!(c.edge < n);
                assert!(c.at > 0);
                if let Some(r) = c.recover_at {
                    assert!(r > c.at);
                }
            }
            for o in &spec.outages {
                assert!(o.region < 2);
                assert!(o.until > o.from);
            }
            for f in &spec.flaps {
                assert!(f.until > f.from);
                assert!(f.degraded_bps > 0.0);
            }
        }
    }
}
