//! Resilience layer: circuit breaking, request hedging and graceful
//! degradation — three cooperating deterministic state machines over
//! virtual time (see docs/ARCHITECTURE.md, "Resilience layer").
//!
//! The paper's DEMS-A *adapts* to cloud variability through its §5.4
//! sliding window; this module adds the *active* recovery loop on top
//! (the ROADMAP's graceful-degradation gap, following A3D / A²-UAV):
//!
//! * [`CircuitBreaker`] — closed/open/half-open per cloud backend. A
//!   sliding failure-rate window is fed by timeouts, throttles and
//!   outage refusals (a dark region surfaces as throttle-shaped
//!   refusals, so PR 7 outages feed the same window). An open breaker
//!   short-circuits `dispatch_cloud` *before* the backend is invoked, so
//!   DEMS/GEMS see a throttle-shaped report immediately and re-plan to
//!   edge/federation instead of burning deadline on doomed invocations.
//!   After the cooldown one half-open probe invocation tests recovery.
//! * Hedged requests ([`HedgePlan`]) — a cloud task whose remaining
//!   slack exceeds the hedge threshold schedules a
//!   [`HedgeFire`](crate::sim::Event::HedgeFire) after a deterministic
//!   delay; if the primary invocation is still in flight when it fires,
//!   a speculative duplicate is launched. First usable completion wins
//!   and cancels the loser (correct FaaS billing/concurrency; exactly
//!   one finalization per task — the conservation contract).
//! * [`DegradeController`] — a hysteresis-guarded overload controller
//!   that downshifts execution to per-`DnnKind` *lite* model variants
//!   ([`crate::exec::lite_variant`]) when queue pressure or an open
//!   breaker threatens deadlines, and upshifts when pressure clears.
//!
//! Everything is opt-in through [`ResilienceSpec`] on
//! [`Policy`](crate::policy::Policy); the all-off default constructs no
//! state machines, draws no RNG and pushes no events, keeping
//! resilience-off runs bit-identical to the plain engine (same gating
//! contract as `Federation::default()` and the empty `FaultSpec`).

use crate::time::{ms, secs, Micros};

/// Declarative resilience configuration carried by
/// [`Policy`](crate::policy::Policy). The default is all-off and inert;
/// each mechanism is enabled independently (`simulate --resilience
/// breaker,hedge,degrade`).
#[derive(Clone, Debug, PartialEq)]
pub struct ResilienceSpec {
    /// Enable the per-backend circuit breaker.
    pub breaker: bool,
    /// Enable speculative duplicate cloud invocations.
    pub hedge: bool,
    /// Enable lite-variant graceful degradation.
    pub degrade: bool,
    /// Breaker sliding-window length (invocation outcomes).
    pub breaker_window: usize,
    /// Failure rate within the window that trips the breaker.
    pub breaker_threshold: f64,
    /// Minimum outcomes in the window before it may trip.
    pub breaker_min_samples: usize,
    /// How long the breaker stays open before a half-open probe.
    pub breaker_cooldown: Micros,
    /// Minimum remaining slack beyond the expected cloud duration for a
    /// dispatch to arm a hedge.
    pub hedge_slack: Micros,
    /// Deterministic delay between the primary dispatch and the
    /// speculative duplicate (a primary still in flight after this long
    /// is, by construction, in the latency tail worth hedging).
    pub hedge_delay: Micros,
    /// Edge-queue depth at/above which the controller downshifts.
    pub degrade_queue_high: usize,
    /// Edge-queue depth at/below which the controller may upshift.
    pub degrade_queue_low: usize,
    /// Minimum dwell between variant switches (flap guard on top of the
    /// two-threshold hysteresis).
    pub degrade_dwell: Micros,
}

impl Default for ResilienceSpec {
    fn default() -> Self {
        ResilienceSpec {
            breaker: false,
            hedge: false,
            degrade: false,
            breaker_window: 20,
            breaker_threshold: 0.5,
            breaker_min_samples: 8,
            breaker_cooldown: secs(5),
            hedge_slack: ms(400),
            hedge_delay: ms(700),
            degrade_queue_high: 6,
            degrade_queue_low: 2,
            degrade_dwell: ms(500),
        }
    }
}

impl ResilienceSpec {
    /// Any mechanism on? (The engine constructs state machines — and
    /// deviates from the bit-identical default path — only when true.)
    pub fn enabled(&self) -> bool {
        self.breaker || self.hedge || self.degrade
    }

    /// All three mechanisms with default knobs.
    pub fn full() -> Self {
        ResilienceSpec {
            breaker: true,
            hedge: true,
            degrade: true,
            ..ResilienceSpec::default()
        }
    }

    pub fn breaker_only() -> Self {
        ResilienceSpec { breaker: true, ..ResilienceSpec::default() }
    }

    pub fn hedge_only() -> Self {
        ResilienceSpec { hedge: true, ..ResilienceSpec::default() }
    }

    pub fn degrade_only() -> Self {
        ResilienceSpec { degrade: true, ..ResilienceSpec::default() }
    }
}

/// What the breaker says about a cloud dispatch about to happen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerGate {
    /// Normal operation — dispatch, and feed the outcome back.
    Closed,
    /// Half-open: this dispatch is the recovery probe. Its outcome
    /// (reported with `probe = true`) closes or re-opens the breaker.
    Probe,
    /// Open: do not invoke; retry no earlier than `until`.
    Open { until: Micros },
}

/// Closed/open/half-open circuit breaker over a sliding failure-rate
/// window. Purely virtual-time driven and allocation-stable: the window
/// is a fixed-capacity ring, so the disabled path aside, breaker math
/// never perturbs the RNG stream.
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    window: std::collections::VecDeque<bool>,
    win_size: usize,
    threshold: f64,
    min_samples: usize,
    cooldown: Micros,
    /// `Some(until)` while open; cleared on the half-open transition.
    open_until: Option<Micros>,
    /// Cooldown elapsed, awaiting the probe verdict.
    half_open: bool,
    probe_inflight: bool,
    /// Closed→open transitions (folded into `Metrics::breaker_trips`).
    pub trips: u64,
}

impl CircuitBreaker {
    pub fn new(spec: &ResilienceSpec) -> Self {
        CircuitBreaker {
            window: std::collections::VecDeque::with_capacity(
                spec.breaker_window,
            ),
            win_size: spec.breaker_window.max(1),
            threshold: spec.breaker_threshold,
            min_samples: spec.breaker_min_samples.max(1),
            cooldown: spec.breaker_cooldown.max(1),
            open_until: None,
            half_open: false,
            probe_inflight: false,
            trips: 0,
        }
    }

    /// Gate a dispatch at `now`. Returning [`BreakerGate::Probe`] marks
    /// the probe as in flight — the caller *must* resolve it via
    /// [`record`](Self::record) with `probe = true` (either from the
    /// invocation's completion or from an immediate throttle refusal).
    pub fn gate(&mut self, now: Micros) -> BreakerGate {
        if let Some(until) = self.open_until {
            if now < until {
                return BreakerGate::Open { until };
            }
            self.open_until = None;
            self.half_open = true;
        }
        if self.half_open {
            if self.probe_inflight {
                // One probe at a time; siblings retry shortly after.
                let wait = (self.cooldown / 4).max(1);
                return BreakerGate::Open { until: now + wait };
            }
            self.probe_inflight = true;
            return BreakerGate::Probe;
        }
        BreakerGate::Closed
    }

    /// Whether the breaker currently refuses non-probe dispatches (an
    /// input to the degrade controller: an open breaker means edge
    /// pressure is about to rise).
    pub fn is_open(&self, now: Micros) -> bool {
        match self.open_until {
            Some(until) => now < until,
            None => self.half_open,
        }
    }

    /// Feed one invocation outcome. `probe` must be true exactly for
    /// outcomes whose dispatch was gated [`BreakerGate::Probe`].
    pub fn record(&mut self, now: Micros, failure: bool, probe: bool) {
        if probe {
            self.probe_inflight = false;
            if failure {
                self.trip(now);
            } else {
                // Recovery confirmed: fully close with a clean window.
                self.half_open = false;
                self.window.clear();
            }
            return;
        }
        if self.open_until.is_some() || self.half_open {
            // A stale pre-trip invocation completing while open: the
            // verdict is already in; don't let it flap the state.
            return;
        }
        if self.window.len() == self.win_size {
            self.window.pop_front();
        }
        self.window.push_back(failure);
        if self.window.len() >= self.min_samples {
            let fails = self.window.iter().filter(|&&f| f).count();
            if fails as f64 >= self.threshold * self.window.len() as f64 {
                self.trip(now);
            }
        }
    }

    fn trip(&mut self, now: Micros) {
        self.open_until = Some(now + self.cooldown);
        self.half_open = false;
        self.probe_inflight = false;
        self.window.clear();
        self.trips += 1;
    }
}

/// Static hedge thresholds (the hedge mechanism keeps no run state of
/// its own: arming is decided per dispatch, the pairing lives with the
/// in-flight invocations in [`crate::platform`]).
#[derive(Clone, Copy, Debug)]
pub struct HedgePlan {
    pub slack: Micros,
    pub delay: Micros,
}

/// Hysteresis-guarded overload controller for graceful degradation.
///
/// Two-threshold hysteresis (`high`/`low` edge-queue depths) plus a
/// minimum dwell between switches; an open breaker forces the lite
/// variant regardless of queue depth (cloud refusals are about to pile
/// work onto the edge).
#[derive(Clone, Debug)]
pub struct DegradeController {
    high: usize,
    low: usize,
    dwell: Micros,
    lite: bool,
    last_switch: Option<Micros>,
    /// Full→lite transitions (observability; the per-task effect is
    /// counted in `Metrics::degraded_tasks`).
    pub downshifts: u64,
    pub upshifts: u64,
}

impl DegradeController {
    pub fn new(spec: &ResilienceSpec) -> Self {
        DegradeController {
            high: spec.degrade_queue_high.max(1),
            low: spec.degrade_queue_low.min(spec.degrade_queue_high),
            dwell: spec.degrade_dwell,
            lite: false,
            last_switch: None,
            downshifts: 0,
            upshifts: 0,
        }
    }

    /// Is the lite variant currently selected?
    pub fn lite(&self) -> bool {
        self.lite
    }

    fn may_switch(&self, now: Micros) -> bool {
        match self.last_switch {
            Some(at) => now.saturating_sub(at) >= self.dwell,
            None => true,
        }
    }

    /// Observe queue pressure (edge-queue depth) and breaker state at a
    /// dispatch point; switch variants when the hysteresis allows.
    pub fn observe(&mut self, now: Micros, pressure: usize,
                   breaker_open: bool) {
        if self.lite {
            if !breaker_open && pressure <= self.low
                && self.may_switch(now)
            {
                self.lite = false;
                self.upshifts += 1;
                self.last_switch = Some(now);
            }
        } else if (breaker_open || pressure >= self.high)
            && self.may_switch(now)
        {
            self.lite = true;
            self.downshifts += 1;
            self.last_switch = Some(now);
        }
    }
}

/// Per-platform resilience run state, constructed once from the policy's
/// [`ResilienceSpec`]. Every field is `None` when its mechanism is off —
/// the platform's hot paths gate on that, so disabled mechanisms cost
/// nothing and change nothing.
#[derive(Debug, Default)]
pub struct ResilienceState {
    pub breaker: Option<CircuitBreaker>,
    pub hedge: Option<HedgePlan>,
    pub degrade: Option<DegradeController>,
}

impl ResilienceState {
    pub fn from_spec(spec: &ResilienceSpec) -> Self {
        ResilienceState {
            breaker: spec.breaker.then(|| CircuitBreaker::new(spec)),
            hedge: spec.hedge.then(|| HedgePlan {
                slack: spec.hedge_slack,
                delay: spec.hedge_delay,
            }),
            degrade: spec.degrade.then(|| DegradeController::new(spec)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ResilienceSpec {
        ResilienceSpec {
            breaker_window: 4,
            breaker_threshold: 0.5,
            breaker_min_samples: 2,
            breaker_cooldown: ms(1_000),
            ..ResilienceSpec::full()
        }
    }

    #[test]
    fn default_spec_is_inert() {
        let off = ResilienceSpec::default();
        assert!(!off.enabled());
        let st = ResilienceState::from_spec(&off);
        assert!(st.breaker.is_none());
        assert!(st.hedge.is_none());
        assert!(st.degrade.is_none());
        assert!(ResilienceSpec::full().enabled());
        assert!(ResilienceSpec::breaker_only().enabled());
    }

    #[test]
    fn breaker_trips_on_failure_rate_and_reopens_on_failed_probe() {
        let mut b = CircuitBreaker::new(&spec());
        assert_eq!(b.gate(0), BreakerGate::Closed);
        // Two failures in a min-2 window at 50% threshold: trip.
        b.record(10, true, false);
        assert_eq!(b.trips, 0, "one sample is below min_samples");
        b.record(20, true, false);
        assert_eq!(b.trips, 1);
        assert!(b.is_open(20));
        assert_eq!(b.gate(30), BreakerGate::Open { until: ms(1_000) + 20 });
        // Cooldown elapsed: exactly one probe goes through.
        let at = ms(1_000) + 20;
        assert_eq!(b.gate(at), BreakerGate::Probe);
        assert!(matches!(b.gate(at), BreakerGate::Open { .. }),
                "second dispatch while the probe is in flight is refused");
        // Failed probe: back to open, counted as a fresh trip.
        b.record(at + 10, true, true);
        assert_eq!(b.trips, 2);
        assert!(b.is_open(at + 10));
    }

    #[test]
    fn successful_probe_closes_with_clean_window() {
        let mut b = CircuitBreaker::new(&spec());
        b.record(10, true, false);
        b.record(20, true, false);
        let at = ms(1_000) + 20;
        assert_eq!(b.gate(at), BreakerGate::Probe);
        b.record(at + 10, false, true);
        assert!(!b.is_open(at + 10));
        assert_eq!(b.gate(at + 20), BreakerGate::Closed);
        // The pre-trip failures were flushed: one new failure alone
        // cannot re-trip even though 1/1 ≥ 50%... min_samples guards it.
        b.record(at + 30, true, false);
        assert_eq!(b.trips, 1);
    }

    #[test]
    fn stale_completions_while_open_do_not_flap_the_state() {
        let mut b = CircuitBreaker::new(&spec());
        b.record(10, true, false);
        b.record(20, true, false);
        assert!(b.is_open(25));
        // A pre-trip invocation completes successfully mid-cooldown:
        // ignored — only the probe may close the breaker.
        b.record(30, false, false);
        assert!(b.is_open(30));
        assert_eq!(b.trips, 1);
    }

    #[test]
    fn sliding_window_evicts_oldest_outcome() {
        let mut s = spec();
        s.breaker_min_samples = 4;
        let mut b = CircuitBreaker::new(&s);
        // Two failures, then enough successes to slide them out.
        for (t, fail) in
            [(1, true), (2, true), (3, false), (4, false)]
        {
            b.record(t, fail, false);
        }
        assert_eq!(b.trips, 1, "2/4 hits the 50% threshold exactly");
        // Fresh breaker: failures age out before the window fills.
        let mut b = CircuitBreaker::new(&s);
        for (t, fail) in [(1, true), (2, false), (3, false), (4, false),
                          (5, false), (6, true)]
        {
            b.record(t, fail, false);
        }
        assert_eq!(b.trips, 0, "evicted failure no longer counts: 1/4");
    }

    #[test]
    fn degrade_hysteresis_and_dwell() {
        let mut s = spec();
        s.degrade_queue_high = 4;
        s.degrade_queue_low = 1;
        s.degrade_dwell = ms(100);
        let mut d = DegradeController::new(&s);
        assert!(!d.lite());
        d.observe(0, 4, false);
        assert!(d.lite(), "high watermark downshifts");
        // Pressure between the thresholds: hold (hysteresis).
        d.observe(ms(200), 2, false);
        assert!(d.lite());
        // At/below the low watermark but within the dwell: hold.
        d.observe(ms(200) + ms(50), 1, false);
        assert!(d.lite());
        d.observe(ms(400), 1, false);
        assert!(!d.lite(), "low watermark + dwell elapsed upshifts");
        assert_eq!((d.downshifts, d.upshifts), (1, 1));
    }

    #[test]
    fn open_breaker_forces_downshift_regardless_of_queue() {
        let mut d = DegradeController::new(&spec());
        d.observe(0, 0, true);
        assert!(d.lite(), "an open breaker alone downshifts");
        // And blocks the upshift while it stays open.
        d.observe(secs(10), 0, true);
        assert!(d.lite());
        d.observe(secs(20), 0, false);
        assert!(!d.lite());
    }
}
