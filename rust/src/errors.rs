//! Minimal string-backed error type for the offline, dependency-free build.
//!
//! The crate used to pull in `anyhow` for the CLI / runtime plumbing; the
//! default build must compile with no registry access at all, so this module
//! provides the small slice of the `anyhow` API the codebase actually uses:
//! [`Error`], [`Result`], the [`bail!`](crate::bail) / [`err!`](crate::err)
//! macros and the [`Context`] extension trait.

use std::fmt;

/// A boxed-free, message-only error. Like `anyhow::Error` it deliberately
/// does *not* implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints the Debug form on exit; keep it
        // human-readable.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result type (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!`-style constructor: `err!("bad manifest {name}")`.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::errors::Error::msg(format!($($t)*))
    };
}

/// Early-return with a formatted error: `bail!("unknown policy {other}")`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

/// Attach context to fallible values, `anyhow::Context`-style.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // std error -> Error via blanket From
        if v == 0 {
            bail!("zero is not allowed");
        }
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert_eq!(parse("0").unwrap_err().to_string(), "zero is not allowed");
    }

    #[test]
    fn context_wraps_messages() {
        let r: std::result::Result<u32, std::num::ParseIntError> =
            "x".parse();
        let e = r.context("bad int").unwrap_err();
        assert!(e.to_string().starts_with("bad int: "));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn err_macro_formats() {
        let e = err!("model {} not found", "hv");
        assert_eq!(e.to_string(), "model hv not found");
    }
}
