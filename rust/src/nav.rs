//! VIP navigation application (§7, §8.8): PD control, drone kinematics,
//! post-processing of DNN outputs, and the domain metrics of Fig. 17–18
//! (jerk, yaw error, DNF detection).
//!
//! The field validation substitute (DESIGN.md §1): a kinematic Tello model
//! follows a scripted proxy-VIP walk (straight stretches, sharp turns, a
//! stairway) using only the HV inferences the *scheduler* managed to
//! complete on time — so scheduling quality translates into trajectory
//! quality exactly as in the paper's campus flights.

use crate::metrics::percentile;
use crate::rng::Rng;
use crate::time::{to_secs, Micros};

// ---------------------------------------------------------------- control

/// Proportional–derivative controller (§7 cites a PD loop on the HV
/// bounding-box offset).
#[derive(Clone, Debug)]
pub struct PdController {
    pub kp: f64,
    pub kd: f64,
    last_err: Option<(f64, f64)>, // (error, t_secs)
}

impl PdController {
    pub fn new(kp: f64, kd: f64) -> Self {
        PdController { kp, kd, last_err: None }
    }

    /// Control output for `err` observed at time `t` (seconds).
    pub fn update(&mut self, err: f64, t: f64) -> f64 {
        let d = match self.last_err {
            Some((e0, t0)) if t > t0 => (err - e0) / (t - t0),
            _ => 0.0,
        };
        self.last_err = Some((err, t));
        self.kp * err + self.kd * d
    }

    pub fn reset(&mut self) {
        self.last_err = None;
    }
}

// ------------------------------------------------------- post-processing

/// Body-pose classes produced by the SVM stage (§7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pose {
    Upright,
    Kneel,
    Fall,
    StartStop,
    Land,
}

pub const POSES: [Pose; 5] =
    [Pose::Upright, Pose::Kneel, Pose::Fall, Pose::StartStop, Pose::Land];

/// Linear multi-class scorer over the 18×2 keypoint vector — the SVM-based
/// classifier of §7 with deterministic weights.
pub fn classify_pose(keypoints: &[f32]) -> Pose {
    assert_eq!(keypoints.len(), 36, "18 keypoints × (x, y)");
    let mut best = (f64::MIN, 0usize);
    for (c, _) in POSES.iter().enumerate() {
        let mut rng = Rng::new(0xB0D7 + c as u64 * 97);
        let mut score = 0.0f64;
        for &k in keypoints {
            score += k as f64 * (rng.f64() - 0.5);
        }
        if score > best.0 {
            best = (score, c);
        }
    }
    POSES[best.1]
}

/// DEV post-processing: linear regression over (height, width, area) of
/// the detected bounding box → distance in metres (§7).
pub fn estimate_distance(bbox: &[f32]) -> f64 {
    assert!(bbox.len() >= 4, "x, y, w, h");
    let (w, h) = (bbox[2] as f64, bbox[3] as f64);
    let area = w * h;
    // Calibrated against the paper's 3 m follow distance at h ≈ 0.55.
    (1.65 / (h + 1e-3)).clamp(0.3, 30.0) - 0.2 * area
}

/// HV post-processing: bounding-box centre offset from the frame centre,
/// normalized to [-1, 1] per axis.
pub fn bbox_offset(bbox: &[f32]) -> (f64, f64) {
    assert!(bbox.len() >= 4);
    ((bbox[0] as f64 - 0.5) * 2.0, (bbox[1] as f64 - 0.5) * 2.0)
}

// ------------------------------------------------------------ kinematics

/// Simple 4-DoF drone kinematics (x, y, z, yaw) with first-order velocity
/// response — adequate for jerk/yaw-error comparisons between schedulers.
#[derive(Clone, Debug, Default)]
pub struct DroneState {
    pub pos: [f64; 3],
    pub vel: [f64; 3],
    pub yaw: f64,
    pub yaw_rate: f64,
}

/// Scripted proxy-VIP walk: straight stretches, two sharp turns and a
/// stairway climb (the paper's route "through some sharp turns and stairs").
pub fn vip_position(t: f64) -> [f64; 3] {
    let speed = 1.2; // m/s walking pace
    if t < 30.0 {
        [speed * t, 0.0, 0.0]
    } else if t < 35.0 {
        // sharp 90° left turn over 5 s
        let f = (t - 30.0) / 5.0;
        [36.0 + 4.0 * (std::f64::consts::FRAC_PI_2 * f).sin() - 4.0 * 0.0,
         4.0 - 4.0 * (std::f64::consts::FRAC_PI_2 * f).cos(),
         0.0]
    } else if t < 65.0 {
        [40.0, 4.0 + speed * (t - 35.0), 0.0]
    } else if t < 80.0 {
        // stairway: climb 3 m over 15 s while moving
        let f = (t - 65.0) / 15.0;
        [40.0, 40.0 + 0.6 * (t - 65.0), 3.0 * f]
    } else if t < 85.0 {
        // sharp right turn at the top
        let f = (t - 80.0) / 5.0;
        [40.0 + 4.0 * (std::f64::consts::FRAC_PI_2 * f).sin(),
         49.0 + 4.0 * (1.0 - (std::f64::consts::FRAC_PI_2 * f).cos()),
         3.0]
    } else {
        [44.0 + speed * (t - 85.0), 53.0, 3.0]
    }
}

/// One tracking observation: an on-time HV completion at `at` (plus
/// whether it was fresh); produced by the scheduler run.
#[derive(Clone, Copy, Debug)]
pub struct TrackingEvent {
    pub at: Micros,
    pub success: bool,
}

/// Navigation-quality report (Fig. 18 metrics).
#[derive(Clone, Debug)]
pub struct NavReport {
    /// Jerk samples per axis (m/s³): x = front-back, y = left-right,
    /// z = up-down.
    pub jerk: [Vec<f64>; 3],
    /// Yaw error samples (degrees).
    pub yaw_err_deg: Vec<f64>,
    /// Did-not-finish: the drone lost tracking long enough to trigger the
    /// §8.8 failsafe landing.
    pub dnf: bool,
    /// Time of failsafe landing if DNF.
    pub dnf_at_s: f64,
}

impl NavReport {
    pub fn jerk_stats(&self, axis: usize) -> (f64, f64, f64) {
        let xs = &self.jerk[axis];
        (mean(xs), percentile(xs, 0.5), percentile(xs, 0.95))
    }

    pub fn yaw_stats(&self) -> (f64, f64, f64) {
        let xs = &self.yaw_err_deg;
        (mean(xs), percentile(xs, 0.5), percentile(xs, 0.95))
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Drive the drone with PD control fed by the scheduler's HV completions.
///
/// * `events` — HV task completion timeline from a platform run.
/// * `duration` — flight length.
/// * Control runs at 50 Hz; commands refresh only when a *successful*
///   tracking event arrives (stale inferences are skipped, matching the
///   platform's deadline semantics). Tracking gaps > 3 s trigger the
///   failsafe landing (DNF).
pub fn fly(events: &[TrackingEvent], duration: Micros, seed: u64)
           -> NavReport {
    let dt = 0.02; // 50 Hz physics/control
    let mut rng = Rng::new(seed);
    let mut drone = DroneState {
        pos: [-3.0, 0.0, 1.5],
        ..Default::default()
    };
    let mut pd_yaw = PdController::new(2.2, 0.5);
    let mut pd_z = PdController::new(1.4, 0.4);
    let mut pd_fwd = PdController::new(1.1, 0.35);

    let mut jerk = [Vec::new(), Vec::new(), Vec::new()];
    let mut yaw_err_deg = Vec::new();
    let mut prev_acc = [0.0f64; 3];
    let mut prev_vel = [0.0f64; 3];
    let mut cmd = [0.0f64; 3];
    let mut cmd_f = [0.0f64; 3]; // low-passed command
    let mut cmd_yaw_rate = 0.0f64;
    let mut last_fix: f64 = 0.0;
    let mut ev_idx = 0usize;
    let (mut dnf, mut dnf_at) = (false, 0.0);

    let steps = (to_secs(duration) / dt) as usize;
    for step in 0..steps {
        let t = step as f64 * dt;
        let vip = vip_position(t);

        // Consume tracking events up to t.
        let mut fresh = false;
        while ev_idx < events.len() && to_secs(events[ev_idx].at) <= t {
            if events[ev_idx].success {
                fresh = true;
                last_fix = t;
            }
            ev_idx += 1;
        }

        // Failsafe: 3 s without a successful fix → land (DNF).
        if t - last_fix > 3.0 && !dnf {
            dnf = true;
            dnf_at = t;
        }
        if dnf {
            cmd = [0.0, 0.0, -0.5]; // descend
            cmd_yaw_rate = 0.0;
        } else if fresh {
            // The HV bbox gives the offset of the VIP in the camera frame;
            // reconstruct the measured errors (with pixel noise).
            let to_vip = [vip[0] - drone.pos[0], vip[1] - drone.pos[1]];
            let bearing = to_vip[1].atan2(to_vip[0]);
            let yaw_err = wrap_angle(bearing - drone.yaw)
                + rng.normal() * 0.01;
            let dist = (to_vip[0].powi(2) + to_vip[1].powi(2)).sqrt();
            let dist_err = dist - 3.0 + rng.normal() * 0.03;
            let z_err = (vip[2] + 1.5) - drone.pos[2] + rng.normal() * 0.02;

            cmd_yaw_rate = pd_yaw.update(yaw_err, t).clamp(-1.8, 1.8);
            let fwd = pd_fwd.update(dist_err, t).clamp(-2.0, 2.0);
            let up = pd_z.update(z_err, t).clamp(-1.2, 1.2);
            cmd = [fwd * drone.yaw.cos(), fwd * drone.yaw.sin(), up];
        }

        // Jerk-limited velocity response: the flight controller low-passes
        // the commanded velocity (τ_cmd — command smoothing every autopilot
        // applies), tracks it through a first-order loop (τ), and the
        // actuators slew acceleration at most `JMAX` m/s³. Control quality
        // shows up as how much of that jerk envelope gets used: sparse or
        // stale fixes mean larger command corrections per update.
        const TAU_CMD: f64 = 0.25;
        const TAU: f64 = 0.35;
        const AMAX: f64 = 2.5; // m/s²
        const JMAX: f64 = 25.0; // m/s³ actuator slew
        let mut jerk_step = [0.0f64; 3];
        for a in 0..3 {
            cmd_f[a] += (cmd[a] - cmd_f[a]) * dt / TAU_CMD;
            let a_des =
                ((cmd_f[a] - drone.vel[a]) / TAU).clamp(-AMAX, AMAX);
            let da = (a_des - prev_acc[a]).clamp(-JMAX * dt, JMAX * dt);
            prev_acc[a] += da;
            jerk_step[a] = da / dt;
            drone.vel[a] += prev_acc[a] * dt;
            prev_vel[a] = drone.vel[a];
            drone.pos[a] += drone.vel[a] * dt;
        }
        drone.yaw_rate += (cmd_yaw_rate - drone.yaw_rate) * dt / TAU;
        drone.yaw = wrap_angle(drone.yaw + drone.yaw_rate * dt);

        if step > 0 {
            // Body-frame jerk: x = front-back, y = left-right, z = up-down.
            let (s, c) = drone.yaw.sin_cos();
            jerk[0].push(jerk_step[0] * c + jerk_step[1] * s);
            jerk[1].push(-jerk_step[0] * s + jerk_step[1] * c);
            jerk[2].push(jerk_step[2]);
        }

        if !dnf {
            let to_vip = [vip[0] - drone.pos[0], vip[1] - drone.pos[1]];
            let bearing = to_vip[1].atan2(to_vip[0]);
            yaw_err_deg
                .push(wrap_angle(bearing - drone.yaw).abs().to_degrees());
        }
    }
    NavReport { jerk, yaw_err_deg, dnf, dnf_at_s: dnf_at }
}

/// Wrap an angle to (-π, π].
pub fn wrap_angle(a: f64) -> f64 {
    let mut a = a;
    while a > std::f64::consts::PI {
        a -= 2.0 * std::f64::consts::PI;
    }
    while a <= -std::f64::consts::PI {
        a += 2.0 * std::f64::consts::PI;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::{ms, secs};

    #[test]
    fn pd_proportional_term() {
        let mut pd = PdController::new(2.0, 0.0);
        assert_eq!(pd.update(0.5, 0.0), 1.0);
        assert_eq!(pd.update(-0.5, 1.0), -1.0);
    }

    #[test]
    fn pd_derivative_term_damps() {
        let mut pd = PdController::new(0.0, 1.0);
        pd.update(1.0, 0.0);
        // Error shrinking at 0.5/s → derivative output −0.5.
        let out = pd.update(0.5, 1.0);
        assert!((out + 0.5).abs() < 1e-12);
    }

    #[test]
    fn wrap_angle_bounds() {
        use std::f64::consts::PI;
        assert!((wrap_angle(3.0 * PI) - PI).abs() < 1e-9);
        assert!((wrap_angle(-3.0 * PI) - PI).abs() < 1e-9);
        assert_eq!(wrap_angle(0.3), 0.3);
    }

    #[test]
    fn pose_classifier_is_deterministic_and_total() {
        let kp: Vec<f32> = (0..36).map(|i| i as f32 / 36.0).collect();
        let a = classify_pose(&kp);
        let b = classify_pose(&kp);
        assert_eq!(a, b);
        // Different keypoints can change the class (non-degenerate).
        let kp2: Vec<f32> = (0..36).map(|i| 1.0 - i as f32 / 36.0).collect();
        let _ = classify_pose(&kp2);
    }

    #[test]
    fn distance_estimate_monotone_in_height() {
        let near = estimate_distance(&[0.5, 0.5, 0.4, 0.8]);
        let far = estimate_distance(&[0.5, 0.5, 0.1, 0.2]);
        assert!(far > near, "far {far} vs near {near}");
        assert!(near > 0.0);
    }

    #[test]
    fn vip_path_continuous() {
        // No teleports: successive samples < 0.5 m apart at 10 Hz.
        let mut prev = vip_position(0.0);
        let mut t = 0.1;
        while t < 120.0 {
            let p = vip_position(t);
            let d = ((p[0] - prev[0]).powi(2)
                + (p[1] - prev[1]).powi(2)
                + (p[2] - prev[2]).powi(2))
            .sqrt();
            assert!(d < 0.5, "jump of {d} m at t={t}");
            prev = p;
            t += 0.1;
        }
    }

    #[test]
    fn dense_tracking_flies_smoothly() {
        // 30 Hz successful fixes for 60 s: no DNF, bounded yaw error.
        let events: Vec<TrackingEvent> = (0..1800)
            .map(|i| TrackingEvent { at: ms(i * 33 + 33), success: true })
            .collect();
        let r = fly(&events, secs(60), 7);
        assert!(!r.dnf);
        let (_, med, p95) = r.yaw_stats();
        assert!(med < 10.0, "median yaw err {med}°");
        assert!(p95 < 45.0, "p95 yaw err {p95}°");
    }

    #[test]
    fn sparse_tracking_triggers_dnf() {
        // Fixes stop after 5 s → failsafe landing around t ≈ 8 s.
        let events: Vec<TrackingEvent> = (0..150)
            .map(|i| TrackingEvent { at: ms(i * 33 + 33), success: true })
            .collect();
        let r = fly(&events, secs(60), 7);
        assert!(r.dnf);
        assert!(r.dnf_at_s > 5.0 && r.dnf_at_s < 12.0, "{}", r.dnf_at_s);
    }

    #[test]
    fn degraded_tracking_raises_yaw_error() {
        let dense: Vec<TrackingEvent> = (0..3000)
            .map(|i| TrackingEvent { at: ms(i * 33 + 33), success: true })
            .collect();
        // 1 in 15 fixes succeed (≈2 Hz) → visibly sparser control updates.
        let sparse: Vec<TrackingEvent> = dense
            .iter()
            .enumerate()
            .map(|(i, e)| TrackingEvent { at: e.at, success: i % 15 == 0 })
            .collect();
        let rd = fly(&dense, secs(90), 7);
        let rs = fly(&sparse, secs(90), 7);
        assert!(!rs.dnf);
        let (_, _, p95_d) = rd.yaw_stats();
        let (_, _, p95_s) = rs.yaw_stats();
        assert!(p95_s > p95_d, "sparse {p95_s}° vs dense {p95_d}°");
    }
}
