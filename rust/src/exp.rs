//! Paper experiments: every table and figure of the evaluation (§8) as a
//! [`Report`]-returning function — see the per-experiment index in
//! DESIGN.md §4 and the registry in [`crate::scenario`].
//!
//! Each `figNN_report` builds the same rows/series the paper reports;
//! the markdown rendering of the tables matches the pre-redesign
//! `println!` harness (headers and data rows byte-for-byte, pinned by
//! `tests/report_api.rs`), while `--format json` exposes the same numbers
//! machine-readably. Invoke via `ocularone experiment <id>` or
//! [`run_experiment`].

use crate::bail;
use crate::cloud::CloudBackend;
use crate::cluster::{Cluster, ClusterMetrics};
use crate::errors::Result;
use crate::exec::CloudExecModel;
use crate::fleet::Workload;
use crate::metrics::{percentile, Metrics};
use crate::model::{orin_field, table1, DnnKind, GemsWorkload, Resource};
use crate::nav::{self, TrackingEvent};
use crate::net::{mobility_trace, trace_stats, LognormalWan, NetworkModel};
use crate::platform::Platform;
use crate::policy::Policy;
use crate::pool::Pool;
use crate::report::{Cell, Report, Table, Value};
use crate::rng::Rng;
use crate::task::DropReason;
use crate::scenario::CloudSpec;
use crate::sim;
use crate::time::{ms, secs, Micros};

/// Number of emulated edge base stations per host (§8.1 runs 7).
pub const EDGES_PER_HOST: usize = 7;

/// Dispatch an experiment by id and print its markdown ("all" runs every
/// registry entry) — the CLI's default path. The structured path is
/// [`crate::scenario::run_scenario`].
///
/// `jobs` (`0` = auto): "all" fans the registry entries out over one
/// [`Pool`] (each experiment is an independent job; output stays in
/// registry order); a single grid-shaped id parallelizes its own cells
/// instead via [`crate::scenario::run_scenario_jobs`].
pub fn run_experiment(id: &str, seed: u64, jobs: usize) -> Result<()> {
    if id == "all" {
        let ids: Vec<&'static str> =
            crate::scenario::registry().iter().map(|e| e.id).collect();
        let pool = Pool::new(jobs);
        if pool.workers() <= 1 {
            // Sequential: stream each report as it finishes and stop at
            // the first error instead of buffering the whole registry.
            for (i, id) in ids.iter().enumerate() {
                if i > 0 {
                    println!();
                }
                let rep = crate::scenario::run_scenario(id, seed)?;
                print!("{}", rep.to_markdown());
            }
            return Ok(());
        }
        let reports = pool
            .run(ids.len(), |i| crate::scenario::run_scenario(ids[i], seed));
        for (i, rep) in reports.into_iter().enumerate() {
            if i > 0 {
                println!();
            }
            print!("{}", rep?.to_markdown());
        }
        return Ok(());
    }
    let rep = crate::scenario::run_scenario_jobs(id, seed, jobs)?;
    print!("{}", rep.to_markdown());
    Ok(())
}

// ------------------------------------------------------------------ utils

fn default_cloud() -> Box<dyn CloudBackend> {
    CloudSpec::NominalWan.build()
}

/// Run one workload × policy on an `n_edges`-station [`Cluster`] (distinct
/// per-edge seeds), as the paper does with 7 edge containers per host.
/// One event engine drives every edge; the per-edge results are
/// bit-identical to independent single-edge runs (pinned by
/// `tests/paper_shape.rs`), so the recorded figures stand.
fn run_edges(policy: &Policy, wl: &Workload, seed: u64, n_edges: usize,
             make_cloud: &dyn Fn() -> Box<dyn CloudBackend>)
             -> ClusterMetrics {
    Cluster::emulation(policy, wl, seed, n_edges, make_cloud).run()
}

/// `{:.1}%` completion-rate cell over the raw percentage value.
fn pct_cell(frac: f64) -> Cell {
    Cell::percent(100.0 * frac, 1)
}

// ------------------------------------------------------------------- T1

/// Table 1: model configs and derived per-task utilities.
pub(crate) fn t1_report(seed: u64) -> Result<Report> {
    let mut rep = Report::new(
        "t1",
        "Table 1 — workload configuration (Jetson Nano / AWS)",
        seed,
    );
    let mut t = Table::new(&[
        "DNN", "β", "δ(ms)", "t(ms)", "t̂(ms)", "κ", "κ̂", "γᴱ", "γᶜ",
    ]);
    for m in table1() {
        t.push_row(vec![
            Cell::str(m.kind.name().to_uppercase()),
            Cell::float(m.benefit, 0),
            Cell::uint(m.deadline / 1000),
            Cell::uint(m.t_edge / 1000),
            Cell::uint(m.t_cloud / 1000),
            Cell::float(m.cost_edge, 0),
            Cell::float(m.cost_cloud, 0),
            Cell::float(m.util_edge(), 0),
            Cell::float(m.util_cloud(), 0),
        ]);
    }
    rep.table(t);
    rep.text(
        "(γᶜ for MD is 60 = β−κ̂; the paper's table prints 50, \
         inconsistent with its own κ̂=15 — we keep the column \
         self-consistent.)",
    );
    Ok(rep)
}

// ------------------------------------------------------------------ Fig 1

/// Fig. 1: inferencing time distributions, edge container vs FaaS. The
/// edge numbers come from the *real* PJRT artifacts when available (scaled
/// model), the cloud numbers from the calibrated FaaS model.
pub(crate) fn fig1_report(seed: u64) -> Result<Report> {
    let mut rep = Report::new(
        "fig1",
        "Fig 1 — model inferencing time distributions (ms)",
        seed,
    );
    let mut rng = Rng::new(seed);
    let edge = crate::exec::EdgeExecModel::default();
    // Raw sampler (not a backend): fig1 draws service times directly.
    let mut cloud = CloudExecModel::new(Box::new(LognormalWan::default()));
    let mut t = Table::new(&[
        "DNN", "edge p50", "edge p95", "edge p99", "cloud p50",
        "cloud p95",
    ]);
    for m in table1() {
        let e: Vec<f64> = (0..2000)
            .map(|_| edge.sample(&m, &mut rng) as f64 / 1000.0)
            .collect();
        let c: Vec<f64> = (0..2000)
            .map(|_| cloud.sample(&m, 0, 38_000, 0, &mut rng).0 as f64
                / 1000.0)
            .collect();
        t.push_row(vec![
            Cell::str(m.kind.name().to_uppercase()),
            Cell::float(percentile(&e, 0.5), 0),
            Cell::float(percentile(&e, 0.95), 0),
            Cell::float(percentile(&e, 0.99), 0),
            Cell::float(percentile(&c, 0.5), 0),
            Cell::float(percentile(&c, 0.95), 0),
        ]);
    }
    rep.table(t);
    rep.text("(edge distributions tight, cloud long-tailed — Fig 1a/1b)");
    Ok(rep)
}

// ------------------------------------------------------------------ Fig 2

/// Fig. 2: network characteristics of the WAN and mobility models.
pub(crate) fn fig2_report(seed: u64) -> Result<Report> {
    let mut rep =
        Report::new("fig2", "Fig 2 — network characteristics", seed);
    let mut rng = Rng::new(2);
    let mut wan = LognormalWan::default();
    let lat: Vec<f64> = (0..5000)
        .map(|_| wan.latency(0, &mut rng) as f64 / 1000.0)
        .collect();
    let (l5, l50, l95) = trace_stats(&lat);
    rep.text(format!(
        "WAN ping (one-way, ms): p5 {l5:.1}  p50 {l50:.1}  p95 {l95:.1}  \
         max {:.1}",
        lat.iter().cloned().fold(0.0, f64::max)
    ));
    let bw: Vec<f64> = (0..5000)
        .map(|_| wan.bandwidth(0, &mut rng) / 1e6)
        .collect();
    let (b5, b50, b95) = trace_stats(&bw);
    rep.text(format!(
        "WAN bandwidth (MB/s): p5 {b5:.1}  p50 {b50:.1}  p95 {b95:.1}"
    ));
    rep.text("4G mobility traces (7 devices, 300 s, MB/s):");
    for d in 0..7 {
        let tr = mobility_trace(d, 300);
        let mbs: Vec<f64> = tr.iter().map(|v| v / 1e6).collect();
        let (p5, p50, p95) = trace_stats(&mbs);
        rep.text(format!(
            "  device {d}: p5 {p5:.2}  p50 {p50:.2}  p95 {p95:.2}"
        ));
    }
    Ok(rep)
}

// ------------------------------------------------------------------ Fig 8

/// Fig. 8/9/23: DEMS vs the seven baselines across the six workloads.
/// The 6 × 8 grid is enumerated flat and fanned out over the pool (48
/// independent 7-edge clusters); rows assemble in enumeration order, so
/// the report is byte-identical to the sequential run.
pub(crate) fn fig8_report(seed: u64, pool: &Pool) -> Result<Report> {
    let mut rep = Report::new(
        "fig8",
        format!(
            "Fig 8/9 — DEMS vs baselines (median edge of \
             {EDGES_PER_HOST}; utility ×10⁵)"
        ),
        seed,
    );
    let mut t = Table::new(&[
        "WL", "algo", "tasks done", "done %", "QoS util", "util edge",
        "util cloud", "min..max util",
    ]);
    let mut cells: Vec<(Workload, Policy)> = Vec::new();
    for wl in Workload::fig8_all() {
        for policy in Policy::fig8_lineup() {
            cells.push((wl.clone(), policy));
        }
    }
    let results = pool.run(cells.len(), |i| {
        let (wl, policy) = &cells[i];
        run_edges(policy, wl, seed, EDGES_PER_HOST, &default_cloud)
    });
    for ((wl, policy), cm) in cells.iter().zip(&results) {
        let m = cm.median_edge();
        let (lo, hi) = cm.minmax_utility();
        t.push_row(vec![
            Cell::str(wl.name.as_str()),
            Cell::str(policy.kind.name()),
            Cell::uint(m.completed()),
            pct_cell(m.completion_rate()),
            Cell::float(m.qos_utility() / 1e5, 2),
            Cell::float(m.qos_utility_on(Resource::Edge) / 1e5, 2),
            Cell::float(m.qos_utility_on(Resource::Cloud) / 1e5, 2),
            Cell::str(format!("{:.2}..{:.2}", lo / 1e5, hi / 1e5)),
        ]);
    }
    rep.table(t);
    Ok(rep)
}

// ----------------------------------------------------------------- Fig 10

/// Fig. 10/24: incremental benefits of DEM and DEMS over E+C.
pub(crate) fn fig10_report(seed: u64, pool: &Pool) -> Result<Report> {
    let mut rep = Report::new(
        "fig10",
        "Fig 10 — incremental benefits of migration (DEM) and stealing \
         (DEMS) over E+C",
        seed,
    );
    let mut t = Table::new(&[
        "WL", "algo", "done", "done %", "QoS util", "cloud done",
        "stolen", "stolen BP%", "edge util",
    ]);
    let mut cells: Vec<(Workload, Policy)> = Vec::new();
    for wl in Workload::fig8_all() {
        for policy in [Policy::edf_ec(), Policy::dem(), Policy::dems()] {
            cells.push((wl.clone(), policy));
        }
    }
    let results = pool.run(cells.len(), |i| {
        let (wl, policy) = &cells[i];
        run_edges(policy, wl, seed, EDGES_PER_HOST, &default_cloud)
    });
    for ((wl, policy), cm) in cells.iter().zip(&results) {
        let m = cm.median_edge();
        let stolen = m.stolen();
        let stolen_bp = m.stats(DnnKind::Bp).stolen;
        let bp_pct = if stolen > 0 {
            100.0 * stolen_bp as f64 / stolen as f64
        } else {
            0.0
        };
        t.push_row(vec![
            Cell::str(wl.name.as_str()),
            Cell::str(policy.kind.name()),
            Cell::uint(m.completed()),
            pct_cell(m.completion_rate()),
            Cell::float(m.qos_utility() / 1e5, 2),
            Cell::uint(m.completed_on(Resource::Cloud)),
            Cell::uint(stolen),
            Cell::percent(bp_pct, 0),
            Cell::percent(100.0 * m.edge_utilization(), 0),
        ]);
    }
    rep.table(t);
    Ok(rep)
}

// ----------------------------------------------------------------- Fig 11

/// Fig. 11/12/25 (and App. C Figs 21/22/26 with the 3D-P workload):
/// DEMS-A vs DEMS under latency and bandwidth variability.
pub(crate) fn fig11_report(seed: u64, wl_name: &str) -> Result<Report> {
    // The 3D-P variant is the App. C re-run (Figs 21/22/26) — give it
    // its own report id so JSON consumers can tell the two apart.
    let (wl, id) = match wl_name {
        "4D-P" => (Workload::emulation(4, false), "fig11"),
        "3D-P" => (Workload::emulation(3, false), "fig21"),
        other => bail!("fig11 supports 4D-P / 3D-P, not {other}"),
    };
    let mut rep = Report::new(
        id,
        format!("Fig 11 — adaptation to network variability ({wl_name})"),
        seed,
    );
    for (label, shaped) in [("latency (trapezium 0→400ms)", true),
                            ("bandwidth (4G mobility trace)", false)] {
        let spec = if shaped {
            CloudSpec::TrapeziumLatency
        } else {
            CloudSpec::MobilityBandwidth { device: 3 }
        };
        rep.text(format!("### {label}"));
        let mut t = Table::new(&[
            "algo", "done", "done %", "QoS util", "cloud done",
            "cloud missed",
        ]);
        for policy in [Policy::dems(), Policy::dems_a()] {
            let make: Box<dyn Fn() -> Box<dyn CloudBackend>> = {
                let spec = spec.clone();
                Box::new(move || spec.build())
            };
            let cm = run_edges(&policy, &wl, seed, EDGES_PER_HOST, &make);
            let m = cm.median_edge();
            let missed_cloud: u64 =
                m.per_model.iter().map(|(_, s)| s.missed_cloud).sum();
            t.push_row(vec![
                Cell::str(policy.kind.name()),
                Cell::uint(m.completed()),
                pct_cell(m.completion_rate()),
                Cell::float(m.qos_utility() / 1e5, 2),
                Cell::uint(m.completed_on(Resource::Cloud)),
                Cell::uint(missed_cloud),
            ]);
        }
        rep.table(t);
        // Fig 12 timeline: one DEV-task series on a representative edge.
        rep.text(
            "#### Fig 12 timeline (DEV on a representative edge; \
             10 s buckets, ms)",
        );
        for policy in [Policy::dems(), Policy::dems_a()] {
            let mut cloud = spec.build();
            cloud.cold_prob = 0.0;
            let mut platform = Platform::new(policy.clone(),
                                             wl.models.clone(), cloud,
                                             seed);
            platform.metrics.record_timeline = true;
            let m = sim::run(platform, &wl, seed);
            let mut line = format!("{:8}", policy.kind.name());
            let mut bucket = 0u64;
            let (mut n, mut obs, mut exp, mut fail) =
                (0u64, 0.0, 0.0, 0u64);
            for p in m
                .timeline
                .iter()
                .filter(|p| p.model == DnnKind::Dev)
            {
                let b = p.at / secs(10);
                if b != bucket {
                    if n > 0 {
                        line.push_str(&format!(
                            " | t={:>3}s obs={:>4.0} exp={:>4.0} miss={}",
                            bucket * 10,
                            obs / n as f64,
                            exp / n as f64,
                            fail
                        ));
                    }
                    bucket = b;
                    n = 0;
                    obs = 0.0;
                    exp = 0.0;
                    fail = 0;
                }
                n += 1;
                obs += p.observed_ms;
                exp += p.expected_ms;
                fail += u64::from(!p.success);
            }
            rep.text(line);
        }
    }
    Ok(rep)
}

// ----------------------------------------------------------------- Fig 13

/// Fig. 13/27: weak scaling — 7 edges on 1 host → 28 edges on 4 hosts.
/// The four host counts run as independent pool jobs (the 28-edge cell
/// dominates; work stealing keeps the small cells from idling a worker).
pub(crate) fn fig13_report(seed: u64, pool: &Pool) -> Result<Report> {
    let mut rep =
        Report::new("fig13", "Fig 13 — weak scaling (3D-P, DEMS)", seed);
    let mut t = Table::new(&[
        "setup", "edges", "drones", "per-edge done %",
        "per-edge QoS util", "total util",
    ]);
    let wl = Workload::emulation(3, false);
    let hosts_axis = [1usize, 2, 3, 4];
    let results = pool.run(hosts_axis.len(), |i| {
        let hosts = hosts_axis[i];
        run_edges(&Policy::dems(), &wl, seed ^ hosts as u64,
                  hosts * EDGES_PER_HOST, &default_cloud)
    });
    for (hosts, cm) in hosts_axis.iter().zip(&results) {
        let edges = hosts * EDGES_PER_HOST;
        let m = cm.median_edge();
        let total = cm.total_qos_utility();
        t.push_row(vec![
            Cell::str(format!("{hosts}HM")),
            Cell::uint(edges as u64),
            Cell::uint(edges as u64 * 3),
            pct_cell(m.completion_rate()),
            Cell::float(m.qos_utility() / 1e5, 2),
            Cell::float(total / 1e5, 2),
        ]);
    }
    rep.table(t);
    rep.text(
        "(per-edge figures ≈ constant: the FaaS and the per-host uplink \
         scale with the hosts)",
    );
    Ok(rep)
}

// ----------------------------------------------------------------- Fig 14

/// Fig. 14/15 + Table 2: GEMS vs DEMS on WL1/WL2 with α ∈ {0.9, 1.0}.
pub(crate) fn fig14_report(seed: u64) -> Result<Report> {
    let mut rep = Report::new(
        "fig14",
        "Fig 14 — GEMS vs DEMS (Table 2 workloads, ω = 20 s)",
        seed,
    );
    let mut t = Table::new(&[
        "WL", "α", "algo", "done", "done %", "cloud done",
        "GEMS resched", "QoE util", "total util",
    ]);
    let mut fig15_data: Option<(Metrics, Metrics)> = None;
    for wlk in [GemsWorkload::Wl1, GemsWorkload::Wl2] {
        for alpha in [0.9, 1.0] {
            let wl = Workload::gems(wlk, alpha);
            let mut pair = Vec::new();
            for policy in [Policy::dems(), Policy::gems(false)] {
                let mut platform = Platform::new(
                    policy.clone(),
                    wl.models.clone(),
                    default_cloud(),
                    seed,
                );
                platform.edge_exec = wl.edge_exec.clone();
                platform.metrics.record_completions = true;
                let m = sim::run(platform, &wl, seed);
                t.push_row(vec![
                    Cell::str(wl.name.as_str()),
                    Cell::fmt(Value::Float(alpha), format!("{alpha}")),
                    Cell::str(policy.kind.name()),
                    Cell::uint(m.completed()),
                    pct_cell(m.completion_rate()),
                    Cell::uint(m.completed_on(Resource::Cloud)),
                    Cell::uint(m.gems_rescheduled()),
                    Cell::float(m.qoe_utility() / 1e4, 2),
                    Cell::float(m.total_utility() / 1e4, 2),
                ]);
                pair.push(m);
            }
            if wlk == GemsWorkload::Wl1 && alpha == 0.9 {
                let gems = pair.pop().unwrap();
                let dems = pair.pop().unwrap();
                fig15_data = Some((dems, gems));
            }
        }
    }
    rep.table(t);
    // Fig 15: per-window drilldown for WL1, α = 0.9.
    if let Some((dems, gems)) = fig15_data {
        rep.text(
            "\n### Fig 15 — tasks completed per 20 s window \
             (WL1, α = 0.9)",
        );
        let mut lines = Vec::new();
        for kind in [DnnKind::Hv, DnnKind::Dev, DnnKind::Md, DnnKind::Cd]
        {
            for (name, m) in [("DEMS", &dems), ("GEMS", &gems)] {
                let mut counts = vec![0u64; 15];
                for c in m
                    .completions
                    .iter()
                    .filter(|c| c.model == kind && c.success)
                {
                    let w = (c.at / secs(20)) as usize;
                    if w < counts.len() {
                        counts[w] += 1;
                    }
                }
                lines.push(format!(
                    "{:4} {:5}: {:?}",
                    kind.name().to_uppercase(),
                    name,
                    counts
                ));
            }
        }
        rep.text(lines.join("\n"));
    }
    Ok(rep)
}

// ----------------------------------------------------------------- Fig 17

/// §8.8 field policies: EO / E+C / DEMS / GEMS(α=1).
fn field_policies() -> Vec<Policy> {
    vec![
        Policy::edge_only_field(),
        Policy::edf_ec(),
        Policy::dems(),
        Policy::gems(false),
    ]
}

fn field_run(policy: &Policy, fps: u32, seed: u64) -> Metrics {
    let wl = Workload::field(fps, orin_field());
    let mut platform = Platform::new(
        policy.clone(),
        wl.models.clone(),
        // The field cloud path is the real AWS WAN (nominal).
        default_cloud(),
        seed,
    );
    platform.edge_exec = wl.edge_exec.clone();
    platform.metrics.record_completions = true;
    sim::run(platform, &wl, seed)
}

/// Freshness window for PID control: completions older than this do not
/// produce usable drone commands (§8.8 — stale HV outputs ⇒ no commands;
/// 500 ms sits below HV's 650 ms QoS deadline, so tasks that complete at
/// the deadline edge still count for QoS but cannot steer the drone).
pub const FRESH: Micros = ms(500);

fn tracking_events(m: &Metrics) -> Vec<TrackingEvent> {
    m.completions
        .iter()
        .filter(|c| c.model == DnnKind::Hv)
        .map(|c| TrackingEvent {
            at: c.at,
            success: c.success && c.latency <= FRESH,
        })
        .collect()
}

/// Fig. 17a/17b: field validation — completion/utility per scheduler and
/// FPS, with DNF detection; plus post-processing latencies.
pub(crate) fn fig17_report(seed: u64) -> Result<Report> {
    let mut rep = Report::new(
        "fig17",
        "Fig 17a — field validation (Tello + Orin Nano sim)",
        seed,
    );
    let mut t = Table::new(&[
        "algo", "fps", "done", "done %", "edge done", "cloud done",
        "total util", "DNF",
    ]);
    for fps in [15u32, 30] {
        for policy in field_policies() {
            let m = field_run(&policy, fps, seed);
            let events = tracking_events(&m);
            let nav = nav::fly(&events, m.duration, seed ^ fps as u64);
            t.push_row(vec![
                Cell::str(policy.kind.name()),
                Cell::uint(fps as u64),
                Cell::uint(m.completed()),
                pct_cell(m.completion_rate()),
                Cell::uint(m.completed_on(Resource::Edge)),
                Cell::uint(m.completed_on(Resource::Cloud)),
                Cell::float(m.total_utility() / 1e5, 2),
                if nav.dnf {
                    Cell::str(format!("DNF@{:.0}s", nav.dnf_at_s))
                } else {
                    Cell::fmt(Value::Null, "-")
                },
            ]);
        }
    }
    rep.table(t);
    // Fig 17b: post-processing latencies on real artifact outputs when
    // available, else synthetic vectors.
    rep.text(
        "\n## Fig 17b — post-processing latencies (µs median of 1000)",
    );
    let mut rng = Rng::new(seed);
    let hv_out: Vec<f32> = (0..5).map(|_| rng.f64() as f32).collect();
    let bp_out: Vec<f32> = (0..36).map(|_| rng.f64() as f32).collect();
    let time_us = |f: &mut dyn FnMut()| -> f64 {
        let mut xs = Vec::with_capacity(1000);
        for _ in 0..1000 {
            let t0 = std::time::Instant::now();
            f();
            xs.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        percentile(&xs, 0.5)
    };
    let hv_us = time_us(&mut || {
        let _ = nav::bbox_offset(&hv_out);
    });
    let dev_us = time_us(&mut || {
        let _ = nav::estimate_distance(&hv_out);
    });
    let bp_us = time_us(&mut || {
        let _ = nav::classify_pose(&bp_out);
    });
    rep.text(format!(
        "HV {hv_us:.2} µs | DEV {dev_us:.2} µs | BP {bp_us:.2} µs \
         (paper: 4 ms / 2 ms / 10 ms in Python — Rust removes the \
         interpreter overhead; ordering preserved)"
    ));
    Ok(rep)
}

// ----------------------------------------------------------------- Fig 18

/// Fig. 18: jerk and yaw-error distributions per scheduler.
pub(crate) fn fig18_report(seed: u64) -> Result<Report> {
    let mut rep = Report::new(
        "fig18",
        "Fig 18 — drone mobility error metrics",
        seed,
    );
    let mut t = Table::new(&[
        "algo", "fps", "jerk FB p95", "jerk LR p95", "jerk UD p95",
        "yaw mean°", "yaw med°", "yaw p95°",
    ]);
    for fps in [15u32, 30] {
        for policy in field_policies() {
            let m = field_run(&policy, fps, seed);
            let events = tracking_events(&m);
            let nav = nav::fly(&events, m.duration, seed ^ fps as u64);
            if nav.dnf {
                t.push_row(vec![
                    Cell::str(policy.kind.name()),
                    Cell::uint(fps as u64),
                    Cell::str(format!("DNF@{:.0}s", nav.dnf_at_s)),
                    Cell::fmt(Value::Null, ""),
                    Cell::fmt(Value::Null, ""),
                    Cell::fmt(Value::Null, ""),
                    Cell::fmt(Value::Null, ""),
                    Cell::fmt(Value::Null, ""),
                ]);
                continue;
            }
            let fb = nav.jerk_stats(0);
            let lr = nav.jerk_stats(1);
            let ud = nav.jerk_stats(2);
            let yaw = nav.yaw_stats();
            t.push_row(vec![
                Cell::str(policy.kind.name()),
                Cell::uint(fps as u64),
                Cell::float(fb.2, 2),
                Cell::float(lr.2, 2),
                Cell::float(ud.2, 2),
                Cell::float(yaw.0, 1),
                Cell::float(yaw.1, 1),
                Cell::float(yaw.2, 1),
            ]);
        }
    }
    rep.table(t);
    Ok(rep)
}

// ---------------------------------------------------------------- helpers

/// Quick textual summary of one run (used by examples and tests). The
/// fleet-federation counters are appended only when nonzero, so
/// federation-off output is byte-identical to the pre-federation
/// harness.
pub fn summarize(m: &Metrics) -> String {
    let mut s = format!(
        "done {}/{} ({:.1}%), QoS {:.0}, QoE {:.0}, stolen {}, resched {}",
        m.completed(),
        m.generated(),
        100.0 * m.completion_rate(),
        m.qos_utility(),
        m.qoe_utility(),
        m.stolen(),
        m.gems_rescheduled()
    );
    if m.fed_steals_in > 0 || m.fed_steals_out > 0 {
        s.push_str(&format!(
            ", x-steals {}in/{}out",
            m.fed_steals_in, m.fed_steals_out
        ));
    }
    if m.handovers > 0 {
        s.push_str(&format!(", handovers {}", m.handovers));
    }
    if m.uplink_queued > 0 {
        s.push_str(&format!(
            ", uplink-queued {} ({:.1}s)",
            m.uplink_queued,
            m.uplink_wait as f64 / 1e6
        ));
    }
    s.push_str(&drop_breakdown(m));
    s
}

/// Drop-breakdown segment for [`summarize`]: per-[`DropReason`]
/// percentages of generated tasks, listing only nonzero reasons (so a
/// drop-free run appends nothing and the output stays byte-identical to
/// the pre-observability harness).
fn drop_breakdown(m: &Metrics) -> String {
    let g = m.generated();
    if g == 0 || m.dropped() == 0 {
        return String::new();
    }
    let parts: Vec<String> = DropReason::ALL
        .iter()
        .filter_map(|&r| {
            let n = m.dropped_by(r);
            (n > 0).then(|| {
                format!("{} {:.1}%",
                        crate::obs::reason_name(r),
                        100.0 * n as f64 / g as f64)
            })
        })
        .collect();
    format!(", drops[{}]", parts.join(" "))
}
