//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§8) — see the per-experiment index in DESIGN.md §4.
//!
//! Each `figNN` function prints the same rows/series the paper reports;
//! EXPERIMENTS.md records a paper-vs-measured comparison of each run.
//! Invoke via `ocularone experiment <id>` or `run_experiment`.

use crate::bail;
use crate::cluster::Cluster;
use crate::errors::Result;
use crate::exec::CloudExecModel;
use crate::fleet::Workload;
use crate::metrics::{percentile, Metrics};
use crate::model::{orin_field, table1, DnnKind, GemsWorkload, Resource};
use crate::nav::{self, TrackingEvent};
use crate::net::{mobility_trace, trace_stats, ConstantNet, LognormalWan,
                 NetworkModel, TraceBandwidth, TrapeziumLatency};
use crate::platform::Platform;
use crate::policy::Policy;
use crate::rng::Rng;
use crate::sim;
use crate::time::{ms, ms_f, secs, to_secs, Micros};

/// Number of emulated edge base stations per host (§8.1 runs 7).
pub const EDGES_PER_HOST: usize = 7;

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "t1", "fig1", "fig2", "fig8", "fig10", "fig11", "fig13", "fig14",
    "fig17", "fig18",
];

/// Dispatch an experiment by id ("all" runs everything).
pub fn run_experiment(id: &str, seed: u64) -> Result<()> {
    match id {
        "all" => {
            for e in ALL_EXPERIMENTS {
                run_experiment(e, seed)?;
                println!();
            }
            Ok(())
        }
        "t1" => t1(),
        "fig1" => fig1(seed),
        "fig2" => fig2(),
        "fig8" | "fig9" | "fig23" => fig8(seed),
        "fig10" | "fig24" => fig10(seed),
        "fig11" | "fig12" | "fig25" => fig11(seed, "4D-P"),
        "fig21" | "fig22" | "fig26" => fig11(seed, "3D-P"),
        "fig13" | "fig27" => fig13(seed),
        "fig14" | "fig15" => fig14(seed),
        "fig17" => fig17(seed),
        "fig18" => fig18(seed),
        other => bail!(
            "unknown experiment {other:?}; known: {ALL_EXPERIMENTS:?} or all"
        ),
    }
}

// ------------------------------------------------------------------ utils

fn default_cloud() -> CloudExecModel {
    CloudExecModel::new(Box::new(LognormalWan::default()))
}

/// Run one workload × policy on an `n_edges`-station [`Cluster`] (distinct
/// per-edge seeds), as the paper does with 7 edge containers per host.
/// Returns all per-edge metrics. One event engine drives every edge; the
/// per-edge results are bit-identical to the pre-cluster independent runs
/// (pinned by `tests/paper_shape.rs`), so the recorded figures stand.
fn run_edges(policy: &Policy, wl: &Workload, seed: u64, n_edges: usize,
             make_cloud: &dyn Fn() -> CloudExecModel) -> Vec<Metrics> {
    Cluster::emulation(policy, wl, seed, n_edges, make_cloud)
        .run()
        .per_edge
}

/// Median-by-utility edge (the paper reports "a median edge base station").
fn median_edge(runs: &[Metrics]) -> &Metrics {
    let mut idx: Vec<usize> = (0..runs.len()).collect();
    idx.sort_by(|&a, &b| {
        runs[a]
            .qos_utility()
            .partial_cmp(&runs[b].qos_utility())
            .unwrap()
    });
    &runs[idx[idx.len() / 2]]
}

fn minmax_utility(runs: &[Metrics]) -> (f64, f64) {
    let us: Vec<f64> = runs.iter().map(|m| m.qos_utility()).collect();
    (
        us.iter().cloned().fold(f64::INFINITY, f64::min),
        us.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    )
}

// ------------------------------------------------------------------- T1

/// Table 1: model configs and derived per-task utilities.
fn t1() -> Result<()> {
    println!("## Table 1 — workload configuration (Jetson Nano / AWS)");
    println!("| DNN | β | δ(ms) | t(ms) | t̂(ms) | κ | κ̂ | γᴱ | γᶜ |");
    println!("|-----|---|------|-------|-------|---|----|----|----|");
    for m in table1() {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} |",
            m.kind.name().to_uppercase(),
            m.benefit,
            m.deadline / 1000,
            m.t_edge / 1000,
            m.t_cloud / 1000,
            m.cost_edge,
            m.cost_cloud,
            m.util_edge(),
            m.util_cloud(),
        );
    }
    println!("(γᶜ for MD is 60 = β−κ̂; the paper's table prints 50, \
              inconsistent with its own κ̂=15 — we keep the column \
              self-consistent.)");
    Ok(())
}

// ------------------------------------------------------------------ Fig 1

/// Fig. 1: inferencing time distributions, edge container vs FaaS. The
/// edge numbers come from the *real* PJRT artifacts when available (scaled
/// model), the cloud numbers from the calibrated FaaS model.
fn fig1(seed: u64) -> Result<()> {
    println!("## Fig 1 — model inferencing time distributions (ms)");
    let mut rng = Rng::new(seed);
    let edge = crate::exec::EdgeExecModel::default();
    let mut cloud = default_cloud();
    println!("| DNN | edge p50 | edge p95 | edge p99 | cloud p50 | cloud p95 |");
    println!("|-----|---------|----------|----------|-----------|-----------|");
    for m in table1() {
        let e: Vec<f64> = (0..2000)
            .map(|_| edge.sample(&m, &mut rng) as f64 / 1000.0)
            .collect();
        let c: Vec<f64> = (0..2000)
            .map(|_| cloud.sample(&m, 0, 38_000, 0, &mut rng).0 as f64 / 1000.0)
            .collect();
        println!(
            "| {} | {:.0} | {:.0} | {:.0} | {:.0} | {:.0} |",
            m.kind.name().to_uppercase(),
            percentile(&e, 0.5),
            percentile(&e, 0.95),
            percentile(&e, 0.99),
            percentile(&c, 0.5),
            percentile(&c, 0.95),
        );
    }
    println!("(edge distributions tight, cloud long-tailed — Fig 1a/1b)");
    Ok(())
}

// ------------------------------------------------------------------ Fig 2

/// Fig. 2: network characteristics of the WAN and mobility models.
fn fig2() -> Result<()> {
    println!("## Fig 2 — network characteristics");
    let mut rng = Rng::new(2);
    let mut wan = LognormalWan::default();
    let lat: Vec<f64> = (0..5000)
        .map(|_| wan.latency(0, &mut rng) as f64 / 1000.0)
        .collect();
    let (l5, l50, l95) = trace_stats(&lat);
    println!("WAN ping (one-way, ms): p5 {l5:.1}  p50 {l50:.1}  p95 {l95:.1}  \
              max {:.1}", lat.iter().cloned().fold(0.0, f64::max));
    let bw: Vec<f64> = (0..5000)
        .map(|_| wan.bandwidth(0, &mut rng) / 1e6)
        .collect();
    let (b5, b50, b95) = trace_stats(&bw);
    println!("WAN bandwidth (MB/s): p5 {b5:.1}  p50 {b50:.1}  p95 {b95:.1}");
    println!("4G mobility traces (7 devices, 300 s, MB/s):");
    for d in 0..7 {
        let tr = mobility_trace(d, 300);
        let mbs: Vec<f64> = tr.iter().map(|v| v / 1e6).collect();
        let (p5, p50, p95) = trace_stats(&mbs);
        println!("  device {d}: p5 {p5:.2}  p50 {p50:.2}  p95 {p95:.2}");
    }
    Ok(())
}

// ------------------------------------------------------------------ Fig 8

/// Fig. 8/9/23: DEMS vs the seven baselines across the six workloads.
fn fig8(seed: u64) -> Result<()> {
    println!("## Fig 8/9 — DEMS vs baselines (median edge of {EDGES_PER_HOST}; \
              utility ×10⁵)");
    println!("| WL | algo | tasks done | done % | QoS util | util edge | \
              util cloud | min..max util |");
    println!("|----|------|-----------|--------|----------|-----------|\
              -----------|---------------|");
    for wl in Workload::fig8_all() {
        for policy in Policy::fig8_lineup() {
            let runs = run_edges(&policy, &wl, seed, EDGES_PER_HOST,
                                 &default_cloud);
            let m = median_edge(&runs);
            let (lo, hi) = minmax_utility(&runs);
            println!(
                "| {} | {} | {} | {:.1}% | {:.2} | {:.2} | {:.2} | \
                 {:.2}..{:.2} |",
                wl.name,
                policy.kind.name(),
                m.completed(),
                100.0 * m.completion_rate(),
                m.qos_utility() / 1e5,
                m.qos_utility_on(Resource::Edge) / 1e5,
                m.qos_utility_on(Resource::Cloud) / 1e5,
                lo / 1e5,
                hi / 1e5,
            );
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- Fig 10

/// Fig. 10/24: incremental benefits of DEM and DEMS over E+C.
fn fig10(seed: u64) -> Result<()> {
    println!("## Fig 10 — incremental benefits of migration (DEM) and \
              stealing (DEMS) over E+C");
    println!("| WL | algo | done | done % | QoS util | cloud done | \
              stolen | stolen BP% | edge util |");
    println!("|----|------|------|--------|----------|-----------|\
              --------|-----------|-----------|");
    for wl in Workload::fig8_all() {
        for policy in [Policy::edf_ec(), Policy::dem(), Policy::dems()] {
            let runs =
                run_edges(&policy, &wl, seed, EDGES_PER_HOST, &default_cloud);
            let m = median_edge(&runs);
            let stolen = m.stolen();
            let stolen_bp = m.stats(DnnKind::Bp).stolen;
            println!(
                "| {} | {} | {} | {:.1}% | {:.2} | {} | {} | {:.0}% | {:.0}% |",
                wl.name,
                policy.kind.name(),
                m.completed(),
                100.0 * m.completion_rate(),
                m.qos_utility() / 1e5,
                m.completed_on(Resource::Cloud),
                stolen,
                if stolen > 0 {
                    100.0 * stolen_bp as f64 / stolen as f64
                } else {
                    0.0
                },
                100.0 * m.edge_utilization(),
            );
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- Fig 11

fn latency_shaped_cloud() -> CloudExecModel {
    CloudExecModel::new(Box::new(TrapeziumLatency::paper_default(
        LognormalWan::default(),
    )))
}

fn bandwidth_shaped_cloud(device: u64) -> CloudExecModel {
    CloudExecModel::new(Box::new(TraceBandwidth {
        base: LognormalWan {
            // Mobility case: latency stays nominal, bandwidth is replayed
            // from the 4G trace.
            median_bandwidth: f64::INFINITY,
            ..LognormalWan::default()
        },
        samples: mobility_trace(device, 300),
        period: secs(1),
    }))
}

/// Fig. 11/12/25 (and App. C Figs 21/22/26 with `--workload 3D-P`):
/// DEMS-A vs DEMS under latency and bandwidth variability.
fn fig11(seed: u64, wl_name: &str) -> Result<()> {
    let wl = match wl_name {
        "4D-P" => Workload::emulation(4, false),
        "3D-P" => Workload::emulation(3, false),
        other => bail!("fig11 supports 4D-P / 3D-P, not {other}"),
    };
    println!("## Fig 11 — adaptation to network variability ({wl_name})");
    for (label, shaped) in [("latency (trapezium 0→400ms)", true),
                            ("bandwidth (4G mobility trace)", false)] {
        println!("### {label}");
        println!("| algo | done | done % | QoS util | cloud done | \
                  cloud missed |");
        println!("|------|------|--------|----------|-----------|-------------|");
        for policy in [Policy::dems(), Policy::dems_a()] {
            let make: Box<dyn Fn() -> CloudExecModel> = if shaped {
                Box::new(latency_shaped_cloud)
            } else {
                Box::new(move || bandwidth_shaped_cloud(3))
            };
            let runs = run_edges(&policy, &wl, seed, EDGES_PER_HOST, &make);
            let m = median_edge(&runs);
            let missed_cloud: u64 =
                m.per_model.iter().map(|(_, s)| s.missed_cloud).sum();
            println!(
                "| {} | {} | {:.1}% | {:.2} | {} | {} |",
                policy.kind.name(),
                m.completed(),
                100.0 * m.completion_rate(),
                m.qos_utility() / 1e5,
                m.completed_on(Resource::Cloud),
                missed_cloud,
            );
        }
        // Fig 12 timeline: one DEV-task series on a representative edge.
        println!("#### Fig 12 timeline (DEV on a representative edge; \
                  10 s buckets, ms)");
        for policy in [Policy::dems(), Policy::dems_a()] {
            let mut cloud = if shaped {
                latency_shaped_cloud()
            } else {
                bandwidth_shaped_cloud(3)
            };
            cloud.cold_prob = 0.0;
            let mut platform = Platform::new(policy.clone(),
                                             wl.models.clone(), cloud, seed);
            platform.metrics.record_timeline = true;
            let m = sim::run(platform, &wl, seed);
            print!("{:8}", policy.kind.name());
            let mut bucket = 0u64;
            let (mut n, mut obs, mut exp, mut fail) = (0u64, 0.0, 0.0, 0u64);
            for p in m
                .timeline
                .iter()
                .filter(|p| p.model == DnnKind::Dev)
            {
                let b = p.at / secs(10);
                if b != bucket {
                    if n > 0 {
                        print!(
                            " | t={:>3}s obs={:>4.0} exp={:>4.0} miss={}",
                            bucket * 10,
                            obs / n as f64,
                            exp / n as f64,
                            fail
                        );
                    }
                    bucket = b;
                    n = 0;
                    obs = 0.0;
                    exp = 0.0;
                    fail = 0;
                }
                n += 1;
                obs += p.observed_ms;
                exp += p.expected_ms;
                fail += u64::from(!p.success);
            }
            println!();
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- Fig 13

/// Fig. 13/27: weak scaling — 7 edges on 1 host → 28 edges on 4 hosts.
fn fig13(seed: u64) -> Result<()> {
    println!("## Fig 13 — weak scaling (3D-P, DEMS)");
    println!("| setup | edges | drones | per-edge done % | per-edge QoS \
              util | total util |");
    println!("|-------|-------|--------|-----------------|--------------|------------|");
    let wl = Workload::emulation(3, false);
    for hosts in [1usize, 2, 3, 4] {
        let edges = hosts * EDGES_PER_HOST;
        let runs =
            run_edges(&Policy::dems(), &wl, seed ^ hosts as u64, edges,
                      &default_cloud);
        let m = median_edge(&runs);
        let total: f64 = runs.iter().map(|r| r.qos_utility()).sum();
        println!(
            "| {}HM | {} | {} | {:.1}% | {:.2} | {:.2} |",
            hosts,
            edges,
            edges * 3,
            100.0 * m.completion_rate(),
            m.qos_utility() / 1e5,
            total / 1e5,
        );
    }
    println!("(per-edge figures ≈ constant: the FaaS and the per-host \
              uplink scale with the hosts)");
    Ok(())
}

// ----------------------------------------------------------------- Fig 14

/// Fig. 14/15 + Table 2: GEMS vs DEMS on WL1/WL2 with α ∈ {0.9, 1.0}.
fn fig14(seed: u64) -> Result<()> {
    println!("## Fig 14 — GEMS vs DEMS (Table 2 workloads, ω = 20 s)");
    println!("| WL | α | algo | done | done % | cloud done | GEMS resched | \
              QoE util | total util |");
    println!("|----|---|------|------|--------|-----------|--------------|\
              ----------|------------|");
    let mut fig15_data: Option<(Metrics, Metrics)> = None;
    for wlk in [GemsWorkload::Wl1, GemsWorkload::Wl2] {
        for alpha in [0.9, 1.0] {
            let wl = Workload::gems(wlk, alpha);
            let mut pair = Vec::new();
            for policy in [Policy::dems(), Policy::gems(false)] {
                let mut platform = Platform::new(
                    policy.clone(),
                    wl.models.clone(),
                    default_cloud(),
                    seed,
                );
                platform.edge_exec = wl.edge_exec.clone();
                platform.metrics.record_completions = true;
                let m = sim::run(platform, &wl, seed);
                println!(
                    "| {} | {} | {} | {} | {:.1}% | {} | {} | {:.2} | {:.2} |",
                    wl.name,
                    alpha,
                    policy.kind.name(),
                    m.completed(),
                    100.0 * m.completion_rate(),
                    m.completed_on(Resource::Cloud),
                    m.gems_rescheduled(),
                    m.qoe_utility() / 1e4,
                    m.total_utility() / 1e4,
                );
                pair.push(m);
            }
            if wlk == GemsWorkload::Wl1 && alpha == 0.9 {
                let gems = pair.pop().unwrap();
                let dems = pair.pop().unwrap();
                fig15_data = Some((dems, gems));
            }
        }
    }
    // Fig 15: per-window drilldown for WL1, α = 0.9.
    if let Some((dems, gems)) = fig15_data {
        println!("\n### Fig 15 — tasks completed per 20 s window \
                  (WL1, α = 0.9)");
        for kind in [DnnKind::Hv, DnnKind::Dev, DnnKind::Md, DnnKind::Cd] {
            for (name, m) in [("DEMS", &dems), ("GEMS", &gems)] {
                let mut counts = vec![0u64; 15];
                for c in m
                    .completions
                    .iter()
                    .filter(|c| c.model == kind && c.success)
                {
                    let w = (c.at / secs(20)) as usize;
                    if w < counts.len() {
                        counts[w] += 1;
                    }
                }
                println!(
                    "{:4} {:5}: {:?}",
                    kind.name().to_uppercase(),
                    name,
                    counts
                );
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- Fig 17

/// §8.8 field policies: EO / E+C / DEMS / GEMS(α=1).
fn field_policies() -> Vec<Policy> {
    vec![
        Policy::edge_only_field(),
        Policy::edf_ec(),
        Policy::dems(),
        Policy::gems(false),
    ]
}

fn field_run(policy: &Policy, fps: u32, seed: u64) -> Metrics {
    let wl = Workload::field(fps, orin_field());
    let mut platform = Platform::new(
        policy.clone(),
        wl.models.clone(),
        // The field cloud path is the real AWS WAN (nominal).
        default_cloud(),
        seed,
    );
    platform.edge_exec = wl.edge_exec.clone();
    platform.metrics.record_completions = true;
    sim::run(platform, &wl, seed)
}

/// Freshness window for PID control: completions older than this do not
/// produce usable drone commands (§8.8 — stale HV outputs ⇒ no commands;
/// 500 ms sits below HV's 650 ms QoS deadline, so tasks that complete at
/// the deadline edge still count for QoS but cannot steer the drone).
pub const FRESH: Micros = ms(500);

fn tracking_events(m: &Metrics) -> Vec<TrackingEvent> {
    m.completions
        .iter()
        .filter(|c| c.model == DnnKind::Hv)
        .map(|c| TrackingEvent {
            at: c.at,
            success: c.success && c.latency <= FRESH,
        })
        .collect()
}

/// Fig. 17a/17b: field validation — completion/utility per scheduler and
/// FPS, with DNF detection; plus post-processing latencies.
fn fig17(seed: u64) -> Result<()> {
    println!("## Fig 17a — field validation (Tello + Orin Nano sim)");
    println!("| algo | fps | done | done % | edge done | cloud done | \
              total util | DNF |");
    println!("|------|-----|------|--------|-----------|-----------|\
              -----------|-----|");
    for fps in [15u32, 30] {
        for policy in field_policies() {
            let m = field_run(&policy, fps, seed);
            let events = tracking_events(&m);
            let nav =
                nav::fly(&events, m.duration, seed ^ fps as u64);
            println!(
                "| {} | {} | {} | {:.1}% | {} | {} | {:.2} | {} |",
                policy.kind.name(),
                fps,
                m.completed(),
                100.0 * m.completion_rate(),
                m.completed_on(Resource::Edge),
                m.completed_on(Resource::Cloud),
                m.total_utility() / 1e5,
                if nav.dnf {
                    format!("DNF@{:.0}s", nav.dnf_at_s)
                } else {
                    "-".into()
                },
            );
        }
    }
    // Fig 17b: post-processing latencies on real artifact outputs when
    // available, else synthetic vectors.
    println!("\n## Fig 17b — post-processing latencies (µs median of 1000)");
    let mut rng = Rng::new(seed);
    let hv_out: Vec<f32> = (0..5).map(|_| rng.f64() as f32).collect();
    let bp_out: Vec<f32> = (0..36).map(|_| rng.f64() as f32).collect();
    let time_us = |f: &mut dyn FnMut()| -> f64 {
        let mut xs = Vec::with_capacity(1000);
        for _ in 0..1000 {
            let t0 = std::time::Instant::now();
            f();
            xs.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        percentile(&xs, 0.5)
    };
    let hv_us = time_us(&mut || {
        let _ = nav::bbox_offset(&hv_out);
    });
    let dev_us = time_us(&mut || {
        let _ = nav::estimate_distance(&hv_out);
    });
    let bp_us = time_us(&mut || {
        let _ = nav::classify_pose(&bp_out);
    });
    println!("HV {hv_us:.2} µs | DEV {dev_us:.2} µs | BP {bp_us:.2} µs \
              (paper: 4 ms / 2 ms / 10 ms in Python — Rust removes the \
              interpreter overhead; ordering preserved)");
    Ok(())
}

// ----------------------------------------------------------------- Fig 18

/// Fig. 18: jerk and yaw-error distributions per scheduler.
fn fig18(seed: u64) -> Result<()> {
    println!("## Fig 18 — drone mobility error metrics");
    println!("| algo | fps | jerk FB p95 | jerk LR p95 | jerk UD p95 | \
              yaw mean° | yaw med° | yaw p95° |");
    println!("|------|-----|------------|------------|------------|\
              ----------|----------|----------|");
    for fps in [15u32, 30] {
        for policy in field_policies() {
            let m = field_run(&policy, fps, seed);
            let events = tracking_events(&m);
            let nav = nav::fly(&events, m.duration, seed ^ fps as u64);
            if nav.dnf {
                println!(
                    "| {} | {} | DNF@{:.0}s | | | | | |",
                    policy.kind.name(),
                    fps,
                    nav.dnf_at_s
                );
                continue;
            }
            let fb = nav.jerk_stats(0);
            let lr = nav.jerk_stats(1);
            let ud = nav.jerk_stats(2);
            let yaw = nav.yaw_stats();
            println!(
                "| {} | {} | {:.2} | {:.2} | {:.2} | {:.1} | {:.1} | {:.1} |",
                policy.kind.name(),
                fps,
                fb.2,
                lr.2,
                ud.2,
                yaw.0,
                yaw.1,
                yaw.2,
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- helpers

/// Quick textual summary of one run (used by examples and tests).
pub fn summarize(m: &Metrics) -> String {
    format!(
        "done {}/{} ({:.1}%), QoS {:.0}, QoE {:.0}, stolen {}, resched {}",
        m.completed(),
        m.generated(),
        100.0 * m.completion_rate(),
        m.qos_utility(),
        m.qoe_utility(),
        m.stolen(),
        m.gems_rescheduled()
    )
}

#[allow(unused)]
fn unused_imports_guard(_: &dyn NetworkModel, _: ConstantNet) {}

#[allow(unused)]
fn _to_secs_used(x: Micros) -> f64 {
    to_secs(x)
}

#[allow(unused)]
fn _ms_f_used(x: f64) -> Micros {
    ms_f(x)
}
