//! Network models for the edge↔cloud link (§3.2, §8.5, Fig. 2).
//!
//! The paper characterizes the WAN to AWS ap-south-1 (long-tail ping, high
//! bandwidth divergence — Fig. 2a/2b) and a simulated 4G cellular network
//! under drone mobility (SUMO + NS3 — Fig. 2c). §8.5 then *shapes* this
//! link: a trapezium latency waveform (0→400 ms) and a replayed 7-device
//! mobility bandwidth trace. Each of those is a [`NetworkModel`] here.

use crate::rng::Rng;
use crate::time::{ms_f, secs, Micros};

/// Time-varying model of the edge→cloud network path.
pub trait NetworkModel: Send {
    /// One-way latency at virtual time `now` (sampled; includes jitter).
    fn latency(&mut self, now: Micros, rng: &mut Rng) -> Micros;

    /// Available bandwidth at `now`, in bytes/second.
    fn bandwidth(&mut self, now: Micros, rng: &mut Rng) -> f64;

    /// Round-trip transfer overhead for a request carrying `bytes` up and a
    /// small response down: 2·latency + bytes/bandwidth.
    fn transfer_time(&mut self, now: Micros, bytes: u64,
                     rng: &mut Rng) -> Micros {
        let lat = self.latency(now, rng);
        let bw = self.bandwidth(now, rng).max(1.0);
        2 * lat + ms_f(bytes as f64 / bw * 1_000.0)
    }
}

/// Fixed latency/bandwidth (LAN/MAN private-cloud case, §3.2).
pub struct ConstantNet {
    pub latency: Micros,
    pub bandwidth: f64,
}

impl NetworkModel for ConstantNet {
    fn latency(&mut self, _now: Micros, _rng: &mut Rng) -> Micros {
        self.latency
    }
    fn bandwidth(&mut self, _now: Micros, _rng: &mut Rng) -> f64 {
        self.bandwidth
    }
}

/// Long-tailed public-WAN model (Fig. 2a/2b): lognormal latency around a
/// median with occasional spikes, lognormal bandwidth divergence.
pub struct LognormalWan {
    pub median_latency: Micros,
    pub latency_sigma: f64,
    pub median_bandwidth: f64,
    pub bandwidth_sigma: f64,
    /// Probability of a long-tail latency spike (×4 median), matching the
    /// ping tail in Fig. 2a.
    pub spike_prob: f64,
}

impl Default for LognormalWan {
    /// Calibrated to the campus→ap-south-1 measurements: ~40 ms median
    /// one-way latency with a long tail, ~25 MB/s shared host uplink with high divergence.
    fn default() -> Self {
        LognormalWan {
            median_latency: ms_f(40.0),
            latency_sigma: 0.18,
            median_bandwidth: 25.0e6,
            bandwidth_sigma: 0.35,
            spike_prob: 0.01,
        }
    }
}

impl NetworkModel for LognormalWan {
    fn latency(&mut self, _now: Micros, rng: &mut Rng) -> Micros {
        let mut l = rng.lognormal(self.median_latency as f64,
                                  self.latency_sigma);
        if rng.chance(self.spike_prob) {
            l *= 4.0;
        }
        l as Micros
    }
    fn bandwidth(&mut self, _now: Micros, rng: &mut Rng) -> f64 {
        rng.lognormal(self.median_bandwidth, self.bandwidth_sigma)
    }
}

/// §8.5 latency shaping: a trapezium waveform θ(t) added on top of a base
/// model. Default mirrors the paper: 0 ms until 60 s, linear ramp to
/// `peak` (400 ms) during [60, 90), hold, ramp down during [210, 240).
pub struct TrapeziumLatency<N: NetworkModel> {
    pub base: N,
    pub peak: Micros,
    pub ramp_up_start: Micros,
    pub ramp_up_end: Micros,
    pub ramp_down_start: Micros,
    pub ramp_down_end: Micros,
}

impl<N: NetworkModel> TrapeziumLatency<N> {
    pub fn paper_default(base: N) -> Self {
        TrapeziumLatency {
            base,
            peak: ms_f(400.0),
            ramp_up_start: secs(60),
            ramp_up_end: secs(90),
            ramp_down_start: secs(210),
            ramp_down_end: secs(240),
        }
    }

    /// The added latency θ at time `now`.
    pub fn theta(&self, now: Micros) -> Micros {
        let p = self.peak as f64;
        if now < self.ramp_up_start || now >= self.ramp_down_end {
            0
        } else if now < self.ramp_up_end {
            let f = (now - self.ramp_up_start) as f64
                / (self.ramp_up_end - self.ramp_up_start) as f64;
            (p * f) as Micros
        } else if now < self.ramp_down_start {
            self.peak
        } else {
            let f = (self.ramp_down_end - now) as f64
                / (self.ramp_down_end - self.ramp_down_start) as f64;
            (p * f) as Micros
        }
    }
}

impl<N: NetworkModel> NetworkModel for TrapeziumLatency<N> {
    fn latency(&mut self, now: Micros, rng: &mut Rng) -> Micros {
        self.base.latency(now, rng) + self.theta(now)
    }
    fn bandwidth(&mut self, now: Micros, rng: &mut Rng) -> f64 {
        self.base.bandwidth(now, rng)
    }
}

/// Bandwidth trace replay (Fig. 2c / Fig. 11b): piecewise-constant
/// bandwidth samples at a fixed period, scaled on top of a base latency
/// model. [`mobility_trace`] synthesizes the 7-device campus trace.
pub struct TraceBandwidth<N: NetworkModel> {
    pub base: N,
    /// Bandwidth samples (bytes/s), one per `period`.
    pub samples: Vec<f64>,
    pub period: Micros,
}

impl<N: NetworkModel> TraceBandwidth<N> {
    pub fn sample_at(&self, now: Micros) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        let idx = (now / self.period) as usize % self.samples.len();
        self.samples[idx]
    }
}

impl<N: NetworkModel> NetworkModel for TraceBandwidth<N> {
    fn latency(&mut self, now: Micros, rng: &mut Rng) -> Micros {
        self.base.latency(now, rng)
    }
    fn bandwidth(&mut self, now: Micros, rng: &mut Rng) -> f64 {
        let jitter = rng.lognormal(1.0, 0.1);
        self.sample_at(now) * jitter
    }
}

/// Synthesize the Fig. 2c analogue: a 4G cellular bandwidth trace for one
/// of 7 mobile devices moving through the campus. Smooth random walk in
/// log-space between ~0.2 MB/s (cell edge / handover) and ~12 MB/s, with
/// occasional deep fades; 1 s period over `duration_s` seconds.
pub fn mobility_trace(device: u64, duration_s: u64) -> Vec<f64> {
    let mut rng = Rng::new(0x46_u64.wrapping_add(device * 7919));
    let mut log_bw: f64 = (4.0e6_f64).ln();
    let (lo, hi) = ((0.2e6_f64).ln(), (12.0e6_f64).ln());
    let mut out = Vec::with_capacity(duration_s as usize);
    for _ in 0..duration_s {
        log_bw += rng.normal() * 0.25;
        if rng.chance(0.03) {
            log_bw -= 1.2; // deep fade on handover
        }
        log_bw = log_bw.clamp(lo, hi);
        out.push(log_bw.exp());
    }
    out
}

/// Cluster-level shared backhaul (fleet federation): sibling edge
/// stations on one uplink serialize their cloud transfers through a
/// single bandwidth budget, so concurrent dispatches queue behind each
/// other instead of each enjoying the full pipe.
///
/// The per-edge [`NetworkModel`] still samples the transfer itself (its
/// latency and nominal bandwidth are unchanged); the uplink adds only the
/// *queueing delay* of contention — how long a dispatch waits for the
/// shared pipe to free up before its bytes can start flowing. That delay
/// is folded into the invocation's observed duration, which is exactly
/// what DEMS-A's §5.4 window sees and adapts t̂ to.
#[derive(Clone, Debug)]
pub struct SharedUplink {
    /// Shared serialization bandwidth, bytes/second.
    pub bandwidth: f64,
    /// When the pipe frees up (virtual time).
    busy_until: Micros,
}

impl SharedUplink {
    pub fn new(bandwidth: f64) -> Self {
        SharedUplink { bandwidth, busy_until: 0 }
    }

    /// Book a transfer of `bytes` starting no earlier than `now`; returns
    /// the queueing delay (0 when the pipe is idle).
    pub fn acquire(&mut self, now: Micros, bytes: u64) -> Micros {
        let start = self.busy_until.max(now);
        let tx = ms_f(bytes as f64 / self.bandwidth.max(1.0) * 1_000.0);
        self.busy_until = start + tx;
        start - now
    }
}

/// Pretty stats helper used by the Fig. 2 harness.
pub fn trace_stats(samples: &[f64]) -> (f64, f64, f64) {
    let mut s: Vec<f64> = samples.to_vec();
    // total_cmp: a NaN sample must not panic the whole stats pass (same
    // cleanup as metrics::percentile / the exec.rs tests).
    s.sort_by(f64::total_cmp);
    let pct = |p: f64| s[((s.len() - 1) as f64 * p) as usize];
    (pct(0.05), pct(0.50), pct(0.95))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::ms;

    #[test]
    fn constant_transfer_time() {
        let mut n = ConstantNet { latency: ms(40), bandwidth: 10.0e6 };
        let mut rng = Rng::new(1);
        // 2*40ms + 38kB / 10MB/s = 80ms + 3.8ms
        let t = n.transfer_time(0, 38_000, &mut rng);
        assert_eq!(t, ms(80) + 3_800);
    }

    #[test]
    fn trapezium_waveform_shape() {
        let t = TrapeziumLatency::paper_default(ConstantNet {
            latency: 0,
            bandwidth: 1.0e6,
        });
        assert_eq!(t.theta(secs(0)), 0);
        assert_eq!(t.theta(secs(59)), 0);
        assert_eq!(t.theta(secs(75)), ms(200)); // mid ramp-up
        assert_eq!(t.theta(secs(90)), ms(400));
        assert_eq!(t.theta(secs(150)), ms(400)); // plateau
        assert_eq!(t.theta(secs(225)), ms(200)); // mid ramp-down
        assert_eq!(t.theta(secs(240)), 0);
        assert_eq!(t.theta(secs(299)), 0);
    }

    #[test]
    fn trapezium_adds_to_base_latency() {
        let mut t = TrapeziumLatency::paper_default(ConstantNet {
            latency: ms(40),
            bandwidth: 1.0e6,
        });
        let mut rng = Rng::new(1);
        assert_eq!(t.latency(secs(150), &mut rng), ms(440));
        assert_eq!(t.latency(secs(0), &mut rng), ms(40));
    }

    #[test]
    fn lognormal_wan_latency_long_tail() {
        let mut n = LognormalWan::default();
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| n.latency(0, &mut rng) as f64)
            .collect();
        let (p5, p50, p95) = trace_stats(&xs);
        assert!((p50 - 40_000.0).abs() < 2_000.0, "median {p50}");
        assert!(p95 > p50 * 1.2);
        assert!(p5 < p50);
        // Tail spikes exist.
        assert!(xs.iter().cloned().fold(0.0, f64::max) > 100_000.0);
    }

    #[test]
    fn mobility_trace_deterministic_and_bounded() {
        let a = mobility_trace(3, 300);
        let b = mobility_trace(3, 300);
        assert_eq!(a, b);
        assert_eq!(a.len(), 300);
        for v in &a {
            assert!((0.19e6..12.1e6).contains(v), "bw {v}");
        }
        // Devices differ.
        assert_ne!(mobility_trace(1, 300), mobility_trace(2, 300));
    }

    #[test]
    fn trace_bandwidth_replay() {
        let tr = TraceBandwidth {
            base: ConstantNet { latency: ms(10), bandwidth: 0.0 },
            samples: vec![1.0e6, 2.0e6],
            period: secs(1),
        };
        assert_eq!(tr.sample_at(0), 1.0e6);
        assert_eq!(tr.sample_at(secs(1)), 2.0e6);
        assert_eq!(tr.sample_at(secs(2)), 1.0e6); // wraps
    }

    #[test]
    fn shared_uplink_serializes_concurrent_transfers() {
        // 1 MB/s pipe; 500 kB transfers occupy it 500 ms each.
        let mut up = SharedUplink::new(1.0e6);
        // Idle pipe: no wait, slot booked.
        assert_eq!(up.acquire(0, 500_000), 0);
        // Concurrent dispatch queues behind the full remaining slot.
        assert_eq!(up.acquire(0, 500_000), ms(500));
        // A later dispatch waits only for the residue.
        assert_eq!(up.acquire(ms(800), 100_000), ms(200));
        // Once the pipe drains, waits return to zero.
        assert_eq!(up.acquire(ms(5_000), 100_000), 0);
    }

    #[test]
    fn trace_stats_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let (p5, p50, p95) = trace_stats(&xs);
        assert!((p5 - 5.0).abs() <= 1.0);
        assert!((p50 - 50.0).abs() <= 1.0);
        assert!((p95 - 95.0).abs() <= 1.0);
    }
}
