//! Simulation time: microsecond ticks on a virtual clock.
//!
//! All platform logic is written against `Micros` so the same scheduler code
//! runs under the discrete-event engine (a 300 s × 4-drone experiment in
//! well under a second) and under the real-time serving path (which maps
//! `Instant` deltas onto the same axis).

/// Absolute virtual time or a duration, in microseconds.
pub type Micros = u64;

/// Signed duration in microseconds (slack can be negative).
pub type MicrosDelta = i64;

/// Milliseconds → microseconds.
#[inline]
pub const fn ms(v: u64) -> Micros {
    v * 1_000
}

/// Seconds → microseconds.
#[inline]
pub const fn secs(v: u64) -> Micros {
    v * 1_000_000
}

/// Fractional milliseconds → microseconds (rounded).
#[inline]
pub fn ms_f(v: f64) -> Micros {
    (v * 1_000.0).round().max(0.0) as Micros
}

/// Microseconds → fractional milliseconds.
#[inline]
pub fn to_ms(v: Micros) -> f64 {
    v as f64 / 1_000.0
}

/// Microseconds → fractional seconds.
#[inline]
pub fn to_secs(v: Micros) -> f64 {
    v as f64 / 1_000_000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(ms(650), 650_000);
        assert_eq!(secs(300), 300_000_000);
        assert_eq!(ms_f(0.5), 500);
        assert_eq!(ms_f(-1.0), 0); // clamped
        assert!((to_ms(ms(123)) - 123.0).abs() < 1e-9);
        assert!((to_secs(secs(7)) - 7.0).abs() < 1e-9);
    }
}
