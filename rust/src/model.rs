//! DNN model descriptors and the paper's utility math (Eqns 1–3, §4–5).
//!
//! A [`ModelProfile`] carries everything the scheduler knows about one DNN:
//! benefit β, deadline δ, expected edge/cloud durations t and t̂, normalized
//! costs κ and κ̂, and the GEMS QoE triple (β̄, α, ω). The workload tables of
//! the paper (Table 1 for DEMS, Table 2 for GEMS, the Orin field config of
//! §8.8) are provided as constructors and asserted against the paper's own
//! γᴱ/γᶜ columns in the tests.

use crate::time::{ms, Micros};

/// The six vision DNNs of the Ocularone workload (§7, §8.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DnnKind {
    /// Hazard-vest detection (YOLOv8n) — drives VIP tracking.
    Hv,
    /// Distance estimation to the VIP (YOLOv8n + linear regression).
    Dev,
    /// Face-mask detection (SSD).
    Md,
    /// Body-pose estimation (ResNet-18, 18 keypoints).
    Bp,
    /// Crowd-density estimation (YOLOv8m).
    Cd,
    /// Distance estimation to objects (Monodepth2 depth map).
    Deo,
}

impl DnnKind {
    /// Number of model kinds; [`DnnKind::index`] is dense in `0..COUNT`,
    /// so per-model arrays on hot paths size themselves with this instead
    /// of a magic `6` (asserted by `index_is_dense_in_count`).
    pub const COUNT: usize = 6;

    pub const ALL: [DnnKind; Self::COUNT] = [
        DnnKind::Hv,
        DnnKind::Dev,
        DnnKind::Md,
        DnnKind::Bp,
        DnnKind::Cd,
        DnnKind::Deo,
    ];

    /// Artifact / display name.
    pub fn name(&self) -> &'static str {
        match self {
            DnnKind::Hv => "hv",
            DnnKind::Dev => "dev",
            DnnKind::Md => "md",
            DnnKind::Bp => "bp",
            DnnKind::Cd => "cd",
            DnnKind::Deo => "deo",
        }
    }

    pub fn from_name(s: &str) -> Option<DnnKind> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Stable dense index (used for per-model arrays on hot paths).
    #[inline]
    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// Where a task ran (or would run) — selects the Eqn 1 branch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resource {
    Edge,
    Cloud,
    /// The drone's companion computer — the early-layer tier of a
    /// split-DNN pipeline (see [`crate::pipeline`]). Billed at edge κ:
    /// the companion computer is fleet-owned hardware like the edge.
    Drone,
}

/// Scheduler-facing description of one registered DNN model.
///
/// Costs follow the paper's normalization (Appendix B): the per-execution
/// cost `t·κ` / `t̂·κ̂` is folded into `cost_edge` / `cost_cloud` directly,
/// matching Table 1 where γᴱ = β − κ and γᶜ = β − κ̂.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub kind: DnnKind,
    /// QoS benefit β (normalized, unitless).
    pub benefit: f64,
    /// Deadline duration δ from segment creation.
    pub deadline: Micros,
    /// Expected (p99-benchmarked) execution duration on the edge, t.
    pub t_edge: Micros,
    /// Expected (p95-benchmarked) end-to-end duration on the cloud, t̂.
    pub t_cloud: Micros,
    /// Normalized per-execution cost on the edge, κ.
    pub cost_edge: f64,
    /// Normalized per-execution cost on the cloud FaaS, κ̂.
    pub cost_cloud: f64,
    /// QoE window benefit β̄ (Eqn 2); 0 disables QoE accrual.
    pub qoe_benefit: f64,
    /// Required completion rate α within a window (GEMS).
    pub qoe_rate: f64,
    /// Tumbling window duration ω.
    pub qoe_window: Micros,
}

impl ModelProfile {
    /// Utility of a successful edge execution: γᴱ = β − t·κ (Eqn 1).
    #[inline]
    pub fn util_edge(&self) -> f64 {
        self.benefit - self.cost_edge
    }

    /// Utility of a successful cloud execution: γᶜ = β − t̂·κ̂ (Eqn 1).
    #[inline]
    pub fn util_cloud(&self) -> f64 {
        self.benefit - self.cost_cloud
    }

    /// Utility for the given resource/outcome per Eqn 1. The drone tier
    /// bills at edge κ (fleet-owned hardware, no FaaS invoice).
    pub fn utility(&self, on: Resource, met_deadline: bool) -> f64 {
        match (on, met_deadline) {
            (Resource::Edge | Resource::Drone, true) => self.util_edge(),
            (Resource::Edge | Resource::Drone, false) => -self.cost_edge,
            (Resource::Cloud, true) => self.util_cloud(),
            (Resource::Cloud, false) => -self.cost_cloud,
        }
    }

    /// Migration score Sᵢ (Eqn 3): what we lose by moving this task from
    /// the edge to the cloud. If the task cannot profit on the cloud
    /// (`!cloud_feasible` or γᶜ ≤ 0) the whole edge utility is at stake.
    pub fn migration_score(&self, cloud_feasible: bool) -> f64 {
        if cloud_feasible && self.util_cloud() > 0.0 {
            self.util_edge() - self.util_cloud()
        } else {
            self.util_edge()
        }
    }

    /// Work-stealing rank (§5.3): utility gain per unit edge time,
    /// (γᴱ − γᶜ) / t.
    pub fn steal_rank(&self) -> f64 {
        (self.util_edge() - self.util_cloud()) / (self.t_edge as f64)
    }

    /// HPF priority (§8.2): utility per unit edge execution time.
    pub fn hpf_priority(&self) -> f64 {
        self.util_edge() / (self.t_edge as f64)
    }
}

/// Builder-style convenience used by the table constructors.
#[allow(clippy::too_many_arguments)]
fn profile(
    kind: DnnKind,
    benefit: f64,
    deadline_ms: u64,
    t_edge_ms: u64,
    t_cloud_ms: u64,
    cost_edge: f64,
    cost_cloud: f64,
) -> ModelProfile {
    ModelProfile {
        kind,
        benefit,
        deadline: ms(deadline_ms),
        t_edge: ms(t_edge_ms),
        t_cloud: ms(t_cloud_ms),
        cost_edge,
        cost_cloud,
        qoe_benefit: 0.0,
        qoe_rate: 0.0,
        qoe_window: ms(20_000),
    }
}

/// Table 1: the Jetson-Nano + AWS-Lambda workload used for the DEMS study.
pub fn table1() -> Vec<ModelProfile> {
    vec![
        profile(DnnKind::Hv, 125.0, 650, 174, 398, 1.0, 25.0),
        profile(DnnKind::Dev, 100.0, 750, 172, 429, 1.0, 26.0),
        profile(DnnKind::Md, 75.0, 850, 142, 589, 1.0, 15.0),
        profile(DnnKind::Bp, 40.0, 900, 244, 542, 2.0, 43.0),
        profile(DnnKind::Cd, 175.0, 1000, 563, 878, 4.0, 152.0),
        profile(DnnKind::Deo, 250.0, 950, 739, 832, 6.0, 210.0),
    ]
}

/// Table 1 restricted to the *Passive* app mix (HV, DEV, MD, BP).
pub fn table1_passive() -> Vec<ModelProfile> {
    table1()
        .into_iter()
        .filter(|m| {
            matches!(
                m.kind,
                DnnKind::Hv | DnnKind::Dev | DnnKind::Md | DnnKind::Bp
            )
        })
        .collect()
}

/// GEMS workload selector (Table 2): MD and CD differ between WL1 and WL2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemsWorkload {
    Wl1,
    Wl2,
}

/// Table 2: alternate edge/cloud durations + QoE benefits for the GEMS
/// study (§8.7). β is retained from Table 1; β̄/δ/t/t̂ come from Table 2;
/// κ/κ̂ are unchanged. `alpha` is the required completion rate (0.9 / 1.0).
pub fn table2(wl: GemsWorkload, alpha: f64) -> Vec<ModelProfile> {
    let mut hv = profile(DnnKind::Hv, 125.0, 400, 100, 200, 1.0, 25.0);
    let mut dev = profile(DnnKind::Dev, 100.0, 600, 300, 400, 1.0, 26.0);
    let (mut md, mut cd) = match wl {
        GemsWorkload::Wl1 => (
            profile(DnnKind::Md, 75.0, 1000, 200, 300, 1.0, 15.0),
            profile(DnnKind::Cd, 175.0, 800, 650, 750, 4.0, 152.0),
        ),
        GemsWorkload::Wl2 => (
            profile(DnnKind::Md, 75.0, 800, 200, 300, 1.0, 15.0),
            profile(DnnKind::Cd, 175.0, 1000, 750, 950, 4.0, 152.0),
        ),
    };
    hv.qoe_benefit = 360.0;
    dev.qoe_benefit = 420.0;
    md.qoe_benefit = 480.0;
    cd.qoe_benefit = 600.0;
    let mut out = vec![hv, dev, md, cd];
    for m in &mut out {
        m.qoe_rate = alpha;
        m.qoe_window = ms(20_000);
    }
    out
}

/// §8.8 field configuration: HV/DEV/BP on a Jetson Orin Nano (p99 per-frame
/// edge times 49/50/72 ms, κ = 1), cloud/deadline/β from Table 1.
pub fn orin_field() -> Vec<ModelProfile> {
    let mut hv = profile(DnnKind::Hv, 125.0, 650, 49, 398, 1.0, 25.0);
    let mut dev = profile(DnnKind::Dev, 100.0, 750, 50, 429, 1.0, 26.0);
    let mut bp = profile(DnnKind::Bp, 40.0, 900, 72, 542, 1.0, 43.0);
    for m in [&mut hv, &mut dev, &mut bp] {
        m.qoe_benefit = 100.0;
        m.qoe_rate = 1.0;
        m.qoe_window = ms(20_000);
    }
    vec![hv, dev, bp]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_gamma_columns() {
        // γᴱ and γᶜ columns of Table 1.
        let expect = [
            (DnnKind::Hv, 124.0, 100.0),
            (DnnKind::Dev, 99.0, 74.0),
            (DnnKind::Md, 74.0, 60.0),
            (DnnKind::Bp, 38.0, -3.0),
            (DnnKind::Cd, 171.0, 23.0),
            (DnnKind::Deo, 244.0, 40.0),
        ];
        for (kind, ge, gc) in expect {
            let m = table1().into_iter().find(|m| m.kind == kind).unwrap();
            assert_eq!(m.util_edge(), ge, "{kind:?} γᴱ");
            assert_eq!(m.util_cloud(), gc, "{kind:?} γᶜ");
        }
    }

    #[test]
    fn bp_is_the_only_negative_cloud_utility() {
        for m in table1() {
            assert_eq!(m.util_cloud() <= 0.0, m.kind == DnnKind::Bp);
        }
    }

    #[test]
    fn passive_mix_is_four_models() {
        let p = table1_passive();
        assert_eq!(p.len(), 4);
        assert!(p.iter().all(|m| m.kind != DnnKind::Cd
            && m.kind != DnnKind::Deo));
    }

    #[test]
    fn migration_score_branches() {
        let hv = &table1()[0];
        // Cloud-feasible + positive γᶜ: score is the edge-cloud gap.
        assert_eq!(hv.migration_score(true), 24.0);
        // Cloud-infeasible: the full edge utility is at stake.
        assert_eq!(hv.migration_score(false), 124.0);
        // BP has γᶜ < 0, so feasibility does not matter.
        let bp = table1().into_iter().find(|m| m.kind == DnnKind::Bp).unwrap();
        assert_eq!(bp.migration_score(true), 38.0);
    }

    #[test]
    fn steal_rank_prefers_bp_in_passive_mix() {
        // §8.4: BP dominates work stealing. Two mechanisms: (1) among the
        // Passive models it has the best utility-gain-per-edge-time rank,
        // and (2) its negative cloud utility gives it absolute priority in
        // the steal selection (tested in queues.rs). CD/DEO out-rank BP on
        // paper but their long edge times rarely fit the available slack.
        let models = table1_passive();
        let bp_rank = models
            .iter()
            .find(|m| m.kind == DnnKind::Bp)
            .unwrap()
            .steal_rank();
        for m in &models {
            if m.kind != DnnKind::Bp {
                assert!(
                    bp_rank >= m.steal_rank(),
                    "BP rank {} vs {:?} {}",
                    bp_rank,
                    m.kind,
                    m.steal_rank()
                );
            }
        }
    }

    #[test]
    fn table2_workloads_differ_only_in_md_cd() {
        let w1 = table2(GemsWorkload::Wl1, 0.9);
        let w2 = table2(GemsWorkload::Wl2, 0.9);
        assert_eq!(w1.len(), 4);
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.kind, b.kind);
            if matches!(a.kind, DnnKind::Hv | DnnKind::Dev) {
                assert_eq!(a.deadline, b.deadline);
                assert_eq!(a.t_edge, b.t_edge);
            }
        }
        let md1 = &w1[2];
        let md2 = &w2[2];
        assert_eq!(md1.deadline, ms(1000));
        assert_eq!(md2.deadline, ms(800));
    }

    #[test]
    fn table2_qoe_benefits() {
        let w1 = table2(GemsWorkload::Wl1, 1.0);
        let want = [360.0, 420.0, 480.0, 600.0];
        for (m, b) in w1.iter().zip(want) {
            assert_eq!(m.qoe_benefit, b);
            assert_eq!(m.qoe_rate, 1.0);
            assert_eq!(m.qoe_window, ms(20_000));
        }
    }

    #[test]
    fn orin_field_times() {
        let f = orin_field();
        assert_eq!(f.len(), 3);
        assert_eq!(f[0].t_edge, ms(49));
        assert_eq!(f[1].t_edge, ms(50));
        assert_eq!(f[2].t_edge, ms(72));
    }

    #[test]
    fn utility_eqn1_all_branches() {
        let hv = &table1()[0];
        assert_eq!(hv.utility(Resource::Edge, true), 124.0);
        assert_eq!(hv.utility(Resource::Edge, false), -1.0);
        assert_eq!(hv.utility(Resource::Cloud, true), 100.0);
        assert_eq!(hv.utility(Resource::Cloud, false), -25.0);
        // The drone tier bills at edge κ.
        assert_eq!(hv.utility(Resource::Drone, true), 124.0);
        assert_eq!(hv.utility(Resource::Drone, false), -1.0);
    }

    #[test]
    fn index_is_dense_in_count() {
        // The compile-time-adjacent contract per-model arrays rely on:
        // ALL enumerates exactly COUNT kinds and index() maps them
        // bijectively onto 0..COUNT in declaration order.
        assert_eq!(DnnKind::ALL.len(), DnnKind::COUNT);
        for (i, k) in DnnKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{k:?} index not dense");
            assert!(k.index() < DnnKind::COUNT);
        }
    }

    #[test]
    fn kind_name_round_trip() {
        for k in DnnKind::ALL {
            assert_eq!(DnnKind::from_name(k.name()), Some(k));
        }
        assert_eq!(DnnKind::from_name("nope"), None);
    }
}
