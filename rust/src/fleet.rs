//! Drone-fleet workload definitions (§8.1, §8.3, §8.8).
//!
//! The emulation study pairs 2–4 buddy drones per VIP with the *Passive*
//! (HV, DEV, MD, BP) or *Active* (all six) app mix; each drone produces one
//! 1 s ≈ 38 kB video segment per second, and every segment spawns one task
//! per registered model — 8–24 tasks/s per edge. The §8.8 field workload
//! instead generates HV per frame and DEV/BP every third frame at 15/30 FPS.

use std::sync::Arc;

use crate::exec::EdgeExecModel;
use crate::model::{table1, table1_passive, table2, DnnKind, GemsWorkload,
                   ModelProfile};
use crate::pipeline::{Stage, StageGraph};
use crate::time::{ms, ms_f, secs, Micros};

/// Per-drone segment arrival process (beyond-paper axis; the paper's
/// emulation is strictly periodic).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Arrival {
    /// One segment every `segment_period` (the paper's §8.1 setup).
    Periodic,
    /// Poisson process with mean inter-arrival `segment_period` — same
    /// average rate as [`Arrival::Periodic`], memoryless spacing.
    Poisson,
    /// Deterministic duty cycle: segments flow for `on`, pause for `off`,
    /// repeating — a stand-in for video streams that gate on motion.
    Bursty { on: Micros, off: Micros },
}

/// One drone's mid-run churn window: the (edge-local) drone produces
/// segments only while `active_from ≤ now < active_until`. A drone may
/// carry several windows (leave and rejoin); drones without any window are
/// always active.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DroneChurn {
    /// Edge-local drone index in `0..drones`.
    pub drone: u32,
    pub active_from: Micros,
    pub active_until: Micros,
}

/// A complete workload specification for one edge base station.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub models: Vec<ModelProfile>,
    pub drones: u32,
    pub duration: Micros,
    /// Segment (or frame) period per drone.
    pub segment_period: Micros,
    pub segment_bytes: u64,
    /// Per-model decimation: model *i* gets a task every k-th tick.
    pub model_every: Vec<u32>,
    /// Edge service-time regime (the hardware substitute for this study).
    pub edge_exec: EdgeExecModel,
    /// Segment arrival process (default: the paper's periodic ticks).
    pub arrival: Arrival,
    /// Mid-run drone join/leave windows (default: none — all drones
    /// stream for the whole run).
    pub churn: Vec<DroneChurn>,
    /// Split-DNN pipeline chain: when set, each drone tick emits ONE
    /// stage-0 chain task (instead of one task per model) and stage
    /// completions spawn the successors ([`crate::pipeline`]). `None`
    /// keeps the classic per-model emission bit-identically.
    pub pipeline: Option<Arc<StageGraph>>,
}

impl Workload {
    // ----------------------------------------------------- builder methods

    /// Rename the workload (scenario grids disambiguate variants, e.g.
    /// `3D-A-poi`).
    pub fn with_name(mut self, name: impl Into<String>) -> Workload {
        self.name = name.into();
        self
    }

    /// Replace the arrival process (see [`Arrival`]).
    pub fn with_arrival(mut self, arrival: Arrival) -> Workload {
        self.arrival = arrival;
        self
    }

    /// Add one churn window (see [`DroneChurn`]). May be called repeatedly
    /// to model several joins/leaves.
    pub fn with_churn(mut self, churn: DroneChurn) -> Workload {
        self.churn.push(churn);
        self
    }

    /// Override the run duration.
    pub fn with_duration(mut self, duration: Micros) -> Workload {
        self.duration = duration;
        self
    }

    /// Attach a split-DNN pipeline chain: every drone tick emits one
    /// stage-0 task of `graph` and completions chain the successors. The
    /// graph's stage kinds must be registered in `models`.
    pub fn with_pipeline(mut self, graph: StageGraph) -> Workload {
        self.pipeline = Some(Arc::new(graph));
        self
    }

    /// Whether the (edge-local) drone streams at virtual time `now` under
    /// the churn windows: unlisted drones always do; listed drones only
    /// inside one of their windows.
    pub fn drone_active(&self, drone: u32, now: Micros) -> bool {
        let mut listed = false;
        for c in &self.churn {
            if c.drone == drone {
                if now >= c.active_from && now < c.active_until {
                    return true;
                }
                listed = true;
            }
        }
        !listed
    }

    /// Whether the arrival process emits at `now` (the bursty duty-cycle
    /// gate; periodic and Poisson always emit at their tick times).
    pub fn arrival_on(&self, now: Micros) -> bool {
        match self.arrival {
            Arrival::Bursty { on, off } => {
                let cycle = on + off;
                cycle == 0 || now % cycle < on
            }
            _ => true,
        }
    }

    // ------------------------------------------------------ derived rates

    /// Expected task generation rate (tasks/second) across the fleet,
    /// assuming every drone streams for the whole run (nominal for
    /// [`Arrival::Poisson`], which matches the mean rate; churn and duty
    /// cycles reduce it).
    pub fn tasks_per_second(&self) -> f64 {
        let per_tick: f64 = self
            .model_every
            .iter()
            .map(|&e| 1.0 / e.max(1) as f64)
            .sum();
        self.drones as f64 * per_tick
            / (self.segment_period as f64 / 1_000_000.0)
    }

    /// Total tasks generated over the run. For a pipeline workload this
    /// counts the chain roots (one per segment tick); successor stages
    /// spawn dynamically on upstream success, so the realized stage-task
    /// total is between this and `len ×` it.
    pub fn total_tasks(&self) -> u64 {
        let ticks = self.duration / self.segment_period;
        if self.pipeline.is_some() {
            return ticks * self.drones as u64;
        }
        let mut n = 0u64;
        for &e in &self.model_every {
            n += ticks / e.max(1) as u64 + u64::from(ticks % e.max(1) as u64 != 0);
        }
        // Per-drone; tick 0 fires for every model.
        n * self.drones as u64
    }

    /// Total tasks generated across an `edges`-station cluster running
    /// this per-edge workload (§8.1: 7 stations per host).
    pub fn cluster_total_tasks(&self, edges: usize) -> u64 {
        self.total_tasks() * edges as u64
    }

    /// The §8.3 emulation workloads: `drones` ∈ {2,3,4}, passive/active,
    /// 300 s runs (e.g. "3D-A" = 3 drones, Active = 5 400 tasks).
    pub fn emulation(drones: u32, active: bool) -> Workload {
        let models = if active { table1() } else { table1_passive() };
        let n = models.len();
        Workload {
            name: format!("{}D-{}", drones, if active { "A" } else { "P" }),
            models,
            drones,
            duration: secs(300),
            segment_period: secs(1),
            segment_bytes: 38_000,
            model_every: vec![1; n],
            edge_exec: EdgeExecModel::default(),
            arrival: Arrival::Periodic,
            churn: Vec::new(),
            pipeline: None,
        }
    }

    /// All six Fig. 8 workloads in paper order.
    pub fn fig8_all() -> Vec<Workload> {
        let mut out = Vec::new();
        for drones in [2, 3, 4] {
            for active in [false, true] {
                out.push(Workload::emulation(drones, active));
            }
        }
        out
    }

    /// §8.7 GEMS workloads WL1/WL2 (four models, one drone, sleep-based
    /// durations from Table 2, α ∈ {0.9, 1.0}, ω = 20 s).
    pub fn gems(wl: GemsWorkload, alpha: f64) -> Workload {
        let models = table2(wl, alpha);
        let n = models.len();
        Workload {
            name: format!(
                "{}-a{alpha}",
                match wl {
                    GemsWorkload::Wl1 => "WL1",
                    GemsWorkload::Wl2 => "WL2",
                }
            ),
            models,
            drones: 1,
            duration: secs(300),
            segment_period: ms_f(250.0),
            segment_bytes: 38_000,
            model_every: vec![1; n],
            // §8.7 replaces DNN execution with sleep functions.
            edge_exec: EdgeExecModel::sleep_semantics(),
            arrival: Arrival::Periodic,
            churn: Vec::new(),
            pipeline: None,
        }
    }

    /// §8.8 field workload: HV per frame, DEV and BP every third frame, at
    /// the given FPS, on the Orin-Nano profile; ~3.5 minute flights.
    pub fn field(fps: u32, models: Vec<ModelProfile>) -> Workload {
        let n = models.len();
        let mut every = vec![3; n];
        if n > 0 {
            every[0] = 1; // HV runs on every frame
        }
        Workload {
            name: format!("field-{fps}fps"),
            models,
            drones: 1,
            duration: secs(210),
            segment_period: ms_f(1_000.0 / fps as f64),
            segment_bytes: 30_000,
            model_every: every,
            // The Orin Nano's per-frame latencies are tight (§8.8 p99s of
            // 49/50/72 ms): typical draws sit close to the p99, so even
            // 15 FPS edge-only is overloaded, as the paper observes.
            edge_exec: EdgeExecModel { sigma: 0.14, overhead: (0, 0) },
            arrival: Arrival::Periodic,
            churn: Vec::new(),
            pipeline: None,
        }
    }

    /// The split-DNN VIP chain: detect → track → describe, one chain per
    /// drone per second with a 2 s end-to-end deadline.
    ///
    /// The three stages are *layer partitions* of one perception
    /// pipeline, so the profiles are chain-specific rather than Table 1
    /// rows: only the final stage carries the chain's β (intermediate
    /// outputs are worthless alone), the early stages are light enough
    /// for a companion computer, and the describe head is cloud-friendly
    /// (t̂ < t, as for Deo in Table 1). The numbers make the cut matter:
    /// pinning everything cloud-side blows the tight stage-0 deadline,
    /// keeping everything edge-side overloads the station at 4 chains/s,
    /// and the adaptive policy's drone prefix + stage-aware κ̂ ranking
    /// threads the needle (pinned by the `split-pipeline` scenario test).
    pub fn vip_pipeline() -> Workload {
        let stage_profile = |kind, benefit, dl_ms, te_ms, tc_ms, ke, kc| {
            ModelProfile {
                kind,
                benefit,
                deadline: ms(dl_ms),
                t_edge: ms(te_ms),
                t_cloud: ms(tc_ms),
                cost_edge: ke,
                cost_cloud: kc,
                qoe_benefit: 0.0,
                qoe_rate: 0.0,
                qoe_window: ms(20_000),
            }
        };
        let models = vec![
            // Detect backbone: cheap on fleet hardware, hopeless on the
            // cloud within its 320 ms stage budget (t̂ ≈ 600 ms).
            stage_profile(DnnKind::Hv, 0.0, 320, 120, 600, 5.0, 25.0),
            // Track: same shape, slightly heavier.
            stage_profile(DnnKind::Md, 0.0, 640, 180, 700, 5.0, 15.0),
            // Describe head: the chain's whole β, cloud-friendly.
            stage_profile(DnnKind::Deo, 250.0, 2_000, 700, 450, 40.0, 60.0),
        ];
        let graph = StageGraph::chain(
            "vip-chain",
            vec![
                Stage {
                    kind: DnnKind::Hv,
                    deadline_slack: 0.16,
                    output_bytes: 24_000,
                    drone_capable: true,
                },
                Stage {
                    kind: DnnKind::Md,
                    deadline_slack: 0.16,
                    output_bytes: 16_000,
                    drone_capable: true,
                },
                Stage {
                    kind: DnnKind::Deo,
                    deadline_slack: 0.68,
                    output_bytes: 0,
                    drone_capable: false,
                },
            ],
            ms(2_000),
        );
        let n = models.len();
        Workload {
            name: "vip-pipe".into(),
            models,
            drones: 4,
            duration: secs(60),
            segment_period: secs(1),
            segment_bytes: 38_000,
            model_every: vec![1; n],
            edge_exec: EdgeExecModel::default(),
            arrival: Arrival::Periodic,
            churn: Vec::new(),
            pipeline: None,
        }
        .with_pipeline(graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::orin_field;

    #[test]
    fn emulation_task_counts_match_paper() {
        // §8.3: 2D-P → 2 400 tasks, 3D-A → 5 400, 4D-A → 7 200 per station.
        assert_eq!(Workload::emulation(2, false).total_tasks(), 2_400);
        assert_eq!(Workload::emulation(2, true).total_tasks(), 3_600);
        assert_eq!(Workload::emulation(3, false).total_tasks(), 3_600);
        assert_eq!(Workload::emulation(3, true).total_tasks(), 5_400);
        assert_eq!(Workload::emulation(4, false).total_tasks(), 4_800);
        assert_eq!(Workload::emulation(4, true).total_tasks(), 7_200);
    }

    #[test]
    fn cluster_totals_scale_with_edges() {
        // §8.1: 7 stations × 3D-P = 7 × 3 600 tasks per host.
        let wl = Workload::emulation(3, false);
        assert_eq!(wl.cluster_total_tasks(1), wl.total_tasks());
        assert_eq!(wl.cluster_total_tasks(7), 7 * 3_600);
    }

    #[test]
    fn task_rates_in_paper_range() {
        // "8–24 tasks/second per edge" (§8.1).
        let lo = Workload::emulation(2, false).tasks_per_second();
        let hi = Workload::emulation(4, true).tasks_per_second();
        assert_eq!(lo, 8.0);
        assert_eq!(hi, 24.0);
    }

    #[test]
    fn fig8_has_six_workloads() {
        let names: Vec<String> =
            Workload::fig8_all().iter().map(|w| w.name.clone()).collect();
        assert_eq!(names, ["2D-P", "2D-A", "3D-P", "3D-A", "4D-P", "4D-A"]);
    }

    #[test]
    fn field_workload_rates() {
        let w = Workload::field(30, orin_field());
        // HV at 30 FPS + DEV and BP at 10 FPS = 50 tasks/s.
        assert!((w.tasks_per_second() - 50.0).abs() < 0.5);
        let w15 = Workload::field(15, orin_field());
        assert!((w15.tasks_per_second() - 25.0).abs() < 0.5);
    }

    #[test]
    fn gems_workload_names() {
        assert_eq!(Workload::gems(GemsWorkload::Wl1, 0.9).name, "WL1-a0.9");
        assert_eq!(Workload::gems(GemsWorkload::Wl2, 1.0).name, "WL2-a1");
    }

    #[test]
    fn presets_default_to_periodic_no_churn() {
        for wl in [
            Workload::emulation(3, true),
            Workload::gems(GemsWorkload::Wl1, 0.9),
            Workload::field(30, orin_field()),
        ] {
            assert_eq!(wl.arrival, Arrival::Periodic);
            assert!(wl.churn.is_empty());
            assert!(wl.drone_active(0, 0));
            assert!(wl.arrival_on(secs(123)));
        }
    }

    #[test]
    fn churn_windows_gate_drones() {
        let wl = Workload::emulation(4, false)
            .with_churn(DroneChurn {
                drone: 2,
                active_from: 0,
                active_until: secs(150),
            })
            .with_churn(DroneChurn {
                drone: 3,
                active_from: secs(120),
                active_until: secs(300),
            })
            .with_churn(DroneChurn {
                drone: 2,
                active_from: secs(250),
                active_until: secs(300),
            });
        // Unlisted drones are always active.
        assert!(wl.drone_active(0, 0));
        assert!(wl.drone_active(1, secs(299)));
        // Drone 2 leaves at 150 s and rejoins at 250 s.
        assert!(wl.drone_active(2, secs(149)));
        assert!(!wl.drone_active(2, secs(150)));
        assert!(!wl.drone_active(2, secs(200)));
        assert!(wl.drone_active(2, secs(250)));
        // Drone 3 joins at 120 s.
        assert!(!wl.drone_active(3, 0));
        assert!(wl.drone_active(3, secs(120)));
    }

    #[test]
    fn bursty_duty_cycle_gates_arrivals() {
        let wl = Workload::emulation(2, false).with_arrival(
            Arrival::Bursty { on: secs(10), off: secs(10) },
        );
        assert!(wl.arrival_on(0));
        assert!(wl.arrival_on(secs(10) - 1));
        assert!(!wl.arrival_on(secs(10)));
        assert!(!wl.arrival_on(secs(20) - 1));
        assert!(wl.arrival_on(secs(20)));
        // Degenerate zero cycle never blocks.
        let z = Workload::emulation(2, false)
            .with_arrival(Arrival::Bursty { on: 0, off: 0 });
        assert!(z.arrival_on(secs(5)));
    }

    #[test]
    fn vip_pipeline_chain_is_well_formed() {
        let wl = Workload::vip_pipeline();
        let g = wl.pipeline.as_ref().expect("pipeline attached");
        assert_eq!(g.len(), 3);
        // Stage deadlines partition the 2 s end-to-end budget.
        assert_eq!(g.stage_deadline(0), ms(320));
        assert_eq!(g.stage_deadline(1), ms(640));
        assert_eq!(g.stage_deadline(2), ms(2_000));
        // Every stage kind is registered in the workload's models.
        for s in &g.stages {
            assert!(wl.models.iter().any(|m| m.kind == s.kind),
                    "{:?} unregistered", s.kind);
        }
        // Only the final stage carries the chain's benefit, and its
        // remaining-chain cloud utility is positive from stage 0 on —
        // what lets the adaptive cut send the describe head out.
        assert_eq!(wl.models[0].benefit, 0.0);
        assert_eq!(wl.models[1].benefit, 0.0);
        assert!(wl.models[2].benefit > 0.0);
        let pr = crate::pipeline::PipelineRef {
            graph: g.clone(),
            stage: 0,
            drone_prefix: 2,
        };
        let chain_util = crate::pipeline::chain_util_cloud(
            Some(&pr), &wl.models[0], &wl.models);
        assert_eq!(chain_util, 250.0 - 25.0 - 15.0 - 60.0);
        // The classic presets stay pipeline-free.
        assert!(Workload::emulation(3, true).pipeline.is_none());
    }

    #[test]
    fn builder_methods_compose() {
        let wl = Workload::emulation(3, true)
            .with_name("3D-A-poi")
            .with_arrival(Arrival::Poisson)
            .with_duration(secs(60));
        assert_eq!(wl.name, "3D-A-poi");
        assert_eq!(wl.arrival, Arrival::Poisson);
        assert_eq!(wl.duration, secs(60));
        // The nominal rate is unchanged: Poisson matches the mean.
        assert_eq!(wl.tasks_per_second(), 18.0);
    }
}
