//! Structured experiment results: tables, text blocks and renderers.
//!
//! Every experiment in the registry (`crate::scenario`) *returns* a
//! [`Report`] instead of printing; the report renders to the same markdown
//! the pre-redesign `println!` harness emitted (pinned by
//! `tests/report_api.rs`) and, dependency-free, to machine-readable JSON
//! (`ocularone experiment all --format json --out reports/`).
//!
//! A table cell carries **both** a typed [`Value`] (what JSON consumers
//! read) and a display string (what the markdown table shows), so a column
//! like `done %` can render as `83.1%` while serializing as `83.1`.

use crate::bail;
use crate::errors::Result;

// ------------------------------------------------------------------ values

/// Machine-readable cell payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
}

/// One table cell: a typed value plus its human rendering.
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    pub value: Value,
    pub text: String,
}

impl Cell {
    pub fn str(s: impl Into<String>) -> Cell {
        let text = s.into();
        Cell { value: Value::Str(text.clone()), text }
    }

    pub fn int(v: i64) -> Cell {
        Cell { value: Value::Int(v), text: v.to_string() }
    }

    pub fn uint(v: u64) -> Cell {
        Cell { value: Value::Int(v as i64), text: v.to_string() }
    }

    /// Float rendered with a fixed number of decimals.
    pub fn float(v: f64, decimals: usize) -> Cell {
        Cell { value: Value::Float(v), text: format!("{v:.decimals$}") }
    }

    /// Percentage cell: `pct` rendered as `{pct:.d}%` over
    /// `Value::Float(pct)` (the `%` lives only in the display text).
    pub fn percent(pct: f64, decimals: usize) -> Cell {
        Cell {
            value: Value::Float(pct),
            text: format!("{pct:.decimals$}%"),
        }
    }

    /// Dollar amount: `$0.0123` display over `Value::Float` (cloud cost
    /// columns; four decimals resolve sub-cent FaaS fees).
    pub fn dollars(v: f64) -> Cell {
        Cell { value: Value::Float(v), text: format!("${v:.4}") }
    }

    /// Seconds cell over a microsecond total (federation uplink delays,
    /// transfer budgets): renders `12.3`, serializes `Value::Float` in
    /// seconds.
    pub fn seconds(us: u64, decimals: usize) -> Cell {
        Cell::float(us as f64 / 1e6, decimals)
    }

    /// Custom display text over an explicit machine value (e.g. `83.1%`
    /// over `Float(83.1)`, or `DNF@112s` over a string).
    pub fn fmt(value: Value, text: impl Into<String>) -> Cell {
        Cell { value, text: text.into() }
    }
}

// ------------------------------------------------------------------ tables

/// A column-labelled table of [`Cell`] rows.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Cell>>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Table {
        Table {
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity disagrees with the header (an
    /// experiment-authoring bug, not a runtime condition).
    pub fn push_row(&mut self, row: Vec<Cell>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "table row arity mismatch"
        );
        self.rows.push(row);
    }
}

/// One block of a report, in document order.
#[derive(Clone, Debug, PartialEq)]
pub enum Section {
    /// A markdown table (machine-readable rows).
    Table(Table),
    /// Free text: notes, sub-headings (`### …`), preformatted series.
    Text(String),
}

// ------------------------------------------------------------------ report

/// Structured result of one experiment/scenario run.
#[derive(Clone, Debug, PartialEq)]
pub struct Report {
    /// Registry id (`fig8`, `churn`, …).
    pub id: String,
    /// Human title, rendered as the `## …` heading.
    pub title: String,
    /// Base seed the run used (recorded for reproducibility).
    pub seed: u64,
    pub sections: Vec<Section>,
}

impl Report {
    pub fn new(id: impl Into<String>, title: impl Into<String>,
               seed: u64) -> Report {
        Report {
            id: id.into(),
            title: title.into(),
            seed,
            sections: Vec::new(),
        }
    }

    pub fn table(&mut self, t: Table) {
        self.sections.push(Section::Table(t));
    }

    pub fn text(&mut self, s: impl Into<String>) {
        self.sections.push(Section::Text(s.into()));
    }

    /// All tables of the report, in order.
    pub fn tables(&self) -> Vec<&Table> {
        self.sections
            .iter()
            .filter_map(|s| match s {
                Section::Table(t) => Some(t),
                Section::Text(_) => None,
            })
            .collect()
    }

    // ------------------------------------------------------------ markdown

    /// Render to markdown: `## title`, then each section (tables as pipe
    /// tables, text verbatim). Data rows and headers match the pre-redesign
    /// `println!` harness byte-for-byte; separator rows are derived from
    /// the header widths.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("## ");
        out.push_str(&self.title);
        out.push('\n');
        for s in &self.sections {
            match s {
                Section::Table(t) => render_table(t, &mut out),
                Section::Text(txt) => {
                    out.push_str(txt);
                    out.push('\n');
                }
            }
        }
        out
    }

    // ---------------------------------------------------------------- json

    /// Render to a compact JSON object (see [`JsonValue`] for the dialect).
    pub fn to_json(&self) -> String {
        self.to_json_value().dump()
    }

    /// The report as a JSON tree (what [`Report::to_json`] serializes).
    pub fn to_json_value(&self) -> JsonValue {
        let sections: Vec<JsonValue> = self
            .sections
            .iter()
            .map(|s| match s {
                Section::Table(t) => JsonValue::Obj(vec![
                    ("type".into(), JsonValue::Str("table".into())),
                    (
                        "columns".into(),
                        JsonValue::Arr(
                            t.columns
                                .iter()
                                .map(|c| JsonValue::Str(c.clone()))
                                .collect(),
                        ),
                    ),
                    (
                        "rows".into(),
                        JsonValue::Arr(
                            t.rows
                                .iter()
                                .map(|r| {
                                    JsonValue::Arr(
                                        r.iter()
                                            .map(|c| value_json(&c.value))
                                            .collect(),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                ]),
                Section::Text(txt) => JsonValue::Obj(vec![
                    ("type".into(), JsonValue::Str("text".into())),
                    ("text".into(), JsonValue::Str(txt.clone())),
                ]),
            })
            .collect();
        // Seeds are recorded for reproducibility: u64 values beyond f64's
        // 2⁵³ integer range would silently round through Num, so those
        // serialize as a decimal string instead.
        let seed_json = if self.seed <= (1u64 << 53) {
            JsonValue::Num(self.seed as f64)
        } else {
            JsonValue::Str(self.seed.to_string())
        };
        JsonValue::Obj(vec![
            ("id".into(), JsonValue::Str(self.id.clone())),
            ("title".into(), JsonValue::Str(self.title.clone())),
            ("seed".into(), seed_json),
            ("sections".into(), JsonValue::Arr(sections)),
        ])
    }
}

fn value_json(v: &Value) -> JsonValue {
    match v {
        Value::Null => JsonValue::Null,
        Value::Bool(b) => JsonValue::Bool(*b),
        Value::Int(i) => JsonValue::Num(*i as f64),
        Value::Float(f) => {
            if f.is_finite() {
                JsonValue::Num(*f)
            } else {
                JsonValue::Null
            }
        }
        Value::Str(s) => JsonValue::Str(s.clone()),
    }
}

fn render_table(t: &Table, out: &mut String) {
    out.push('|');
    for c in &t.columns {
        out.push(' ');
        out.push_str(c);
        out.push_str(" |");
    }
    out.push('\n');
    out.push('|');
    for c in &t.columns {
        out.push_str(&"-".repeat(c.chars().count() + 2));
        out.push('|');
    }
    out.push('\n');
    for row in &t.rows {
        out.push('|');
        for cell in row {
            if cell.text.is_empty() {
                // `| |`, as the pre-redesign harness printed empty cells
                // (e.g. the fig18 DNF rows) — not `|  |`.
                out.push_str(" |");
            } else {
                out.push(' ');
                out.push_str(&cell.text);
                out.push_str(" |");
            }
        }
        out.push('\n');
    }
}

// -------------------------------------------------------------------- json

/// Minimal JSON tree, the dialect of [`Report::to_json`]: numbers are f64
/// (i64 cells fit losslessly for every counter this repo produces),
/// objects preserve key order, non-finite floats serialize as `null`.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Compact serialization (no whitespace outside strings).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            JsonValue::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if *n == n.trunc()
                    && n.abs() < 9_007_199_254_740_992.0
                {
                    // Integral values print without the trailing ".0" so
                    // counters read as JSON integers.
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            JsonValue::Str(s) => write_json_string(s, out),
            JsonValue::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (the subset [`JsonValue::dump`] emits plus
/// insignificant whitespace). Used by the round-trip tests and available
/// to downstream tooling; not a general-purpose validator.
pub fn parse_json(s: &str) -> Result<JsonValue> {
    let bytes = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing bytes after JSON value at offset {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len()
        && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r')
    {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<()> {
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        bail!(
            "expected {:?} at offset {} in JSON",
            ch as char,
            *pos
        )
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<JsonValue> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of JSON input");
    }
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", JsonValue::Null),
        b't' => parse_lit(b, pos, "true", JsonValue::Bool(true)),
        b'f' => parse_lit(b, pos, "false", JsonValue::Bool(false)),
        b'"' => Ok(JsonValue::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(JsonValue::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                if *pos < b.len() && b[*pos] == b',' {
                    *pos += 1;
                } else {
                    expect(b, pos, b']')?;
                    return Ok(JsonValue::Arr(xs));
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut kvs = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(JsonValue::Obj(kvs));
            }
            loop {
                skip_ws(b, pos);
                let k = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                let v = parse_value(b, pos)?;
                kvs.push((k, v));
                skip_ws(b, pos);
                if *pos < b.len() && b[*pos] == b',' {
                    *pos += 1;
                } else {
                    expect(b, pos, b'}')?;
                    return Ok(JsonValue::Obj(kvs));
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str,
             v: JsonValue) -> Result<JsonValue> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid JSON literal at offset {}", *pos)
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            bail!("unterminated JSON string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("unterminated JSON escape");
                }
                let c = b[*pos];
                *pos += 1;
                match c {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex =
                            std::str::from_utf8(&b[*pos..*pos + 4])
                                .map_err(|_| {
                                    crate::errors::Error::msg(
                                        "non-utf8 \\u escape",
                                    )
                                })?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| {
                                crate::errors::Error::msg(
                                    "invalid \\u escape",
                                )
                            })?;
                        *pos += 4;
                        match char::from_u32(code) {
                            Some(ch) => out.push(ch),
                            // Surrogates (never emitted by dump()).
                            None => bail!(
                                "unsupported \\u{hex} escape"
                            ),
                        }
                    }
                    other => bail!(
                        "unknown JSON escape \\{}",
                        other as char
                    ),
                }
            }
            _ => {
                // Consume one UTF-8 scalar starting here.
                let rest = std::str::from_utf8(&b[*pos..]).map_err(
                    |_| crate::errors::Error::msg("non-utf8 JSON"),
                )?;
                let ch = rest.chars().next().expect("non-empty");
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<JsonValue> {
    let start = *pos;
    if *pos < b.len() && b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos],
                    b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let txt = std::str::from_utf8(&b[start..*pos])
        .map_err(|_| crate::errors::Error::msg("non-utf8 number"))?;
    match txt.parse::<f64>() {
        Ok(n) => Ok(JsonValue::Num(n)),
        Err(_) => bail!("invalid JSON number {txt:?} at offset {start}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> Report {
        let mut r = Report::new("demo", "Demo — sanity", 42);
        let mut t = Table::new(&["WL", "done %", "QoS util"]);
        t.push_row(vec![
            Cell::str("3D-A"),
            Cell::percent(83.1, 1),
            Cell::float(12.34567, 2),
        ]);
        t.push_row(vec![
            Cell::str("4D-P"),
            Cell::percent(71.0, 1),
            Cell::float(-3.5, 2),
        ]);
        r.table(t);
        r.text("(a note with \"quotes\" and a \\ backslash)");
        r
    }

    #[test]
    fn markdown_shape() {
        let md = sample_report().to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines[0], "## Demo — sanity");
        assert_eq!(lines[1], "| WL | done % | QoS util |");
        assert_eq!(lines[2], "|----|--------|----------|");
        assert_eq!(lines[3], "| 3D-A | 83.1% | 12.35 |");
        assert_eq!(lines[4], "| 4D-P | 71.0% | -3.50 |");
        assert_eq!(
            lines[5],
            "(a note with \"quotes\" and a \\ backslash)"
        );
    }

    #[test]
    fn json_round_trips() {
        let r = sample_report();
        let json = r.to_json();
        let parsed = parse_json(&json).expect("valid JSON");
        assert_eq!(parsed.dump(), json, "parse∘dump is the identity");
        // And the tree carries the machine values, not the display text.
        match &parsed {
            JsonValue::Obj(kvs) => {
                assert_eq!(kvs[0].0, "id");
                assert_eq!(kvs[0].1, JsonValue::Str("demo".into()));
                assert_eq!(kvs[2].1, JsonValue::Num(42.0));
            }
            other => panic!("expected object, got {other:?}"),
        }
    }

    #[test]
    fn json_escapes_and_unicode() {
        let v = JsonValue::Str("×10⁵ \"q\" \\ \n\t\u{1}".into());
        let s = v.dump();
        let back = parse_json(&s).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn json_numbers() {
        for v in
            [0.0, 1.0, -1.0, 123456.0, 0.5, -2.25, 83.1, 1e-3, 7200.0]
        {
            let s = JsonValue::Num(v).dump();
            let back = parse_json(&s).unwrap();
            match back {
                JsonValue::Num(n) => {
                    assert_eq!(n, v, "round-trip of {v}")
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(JsonValue::Num(f64::NAN).dump(), "null");
        assert_eq!(JsonValue::Num(7200.0).dump(), "7200");
    }

    #[test]
    fn empty_cells_render_like_the_old_harness() {
        let mut t = Table::new(&["a", "b", "c"]);
        t.push_row(vec![
            Cell::str("DNF@112s"),
            Cell::fmt(Value::Null, ""),
            Cell::fmt(Value::Null, ""),
        ]);
        let mut r = Report::new("d", "D", 0);
        r.table(t);
        let md = r.to_markdown();
        assert!(md.contains("| DNF@112s | | |"), "{md}");
    }

    #[test]
    fn huge_seeds_survive_serialization() {
        let seed = u64::MAX - 1;
        let r = Report::new("s", "S", seed);
        let json = r.to_json();
        assert!(json.contains(&format!("\"seed\":\"{seed}\"")), "{json}");
        let back = parse_json(&json).unwrap();
        assert_eq!(back.dump(), json);
        // Ordinary seeds stay plain JSON numbers.
        let small = Report::new("s", "S", 42).to_json();
        assert!(small.contains("\"seed\":42"), "{small}");
    }

    #[test]
    fn seconds_cell_converts_micros() {
        let c = Cell::seconds(12_345_678, 1);
        assert_eq!(c.value, Value::Float(12.345678));
        assert_eq!(c.text, "12.3");
        assert_eq!(Cell::seconds(0, 1).text, "0.0");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("12 34").is_err());
        assert!(parse_json("nul").is_err());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_is_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec![Cell::int(1)]);
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        let mut t = Table::new(&["x"]);
        t.push_row(vec![Cell::fmt(Value::Float(f64::NAN), "NaN")]);
        let mut r = Report::new("n", "n", 0);
        r.table(t);
        let json = r.to_json();
        assert!(json.contains("null"));
        assert!(parse_json(&json).is_ok());
    }
}
