//! The Ocularone scheduling platform (Fig. 4): one edge base station with
//! its task queues, the edge executor, the cloud FaaS path, and the DEMS /
//! DEMS-A / GEMS decision logic plus all baselines of §8.2.
//!
//! The platform is a deterministic state machine over virtual time: the
//! discrete-event engine ([`crate::sim`]) or the real-time serving loop
//! ([`crate::serve`]) feeds it events; it mutates queues and pushes future
//! events. All heuristics of §5–§6 live here:
//!
//! * admission + EDF feasibility check (§5.1),
//! * migration scoring, Eqn 3 (§5.2),
//! * deferred cloud triggers + work stealing (§5.3),
//! * sliding-window adaptation with cooling reset (§5.4),
//! * the GEMS window monitor, Algorithm 1 (§6).

use std::collections::{HashMap, VecDeque};

use crate::adapt::ModelAdapt;
use crate::exec::{CloudExecModel, EdgeExecModel};
use crate::metrics::{Metrics, TimelinePoint};
use crate::model::{DnnKind, ModelProfile, Resource};
use crate::policy::{Policy, PolicyKind};
use crate::qoe::WindowMonitor;
use crate::queues::{CloudEntry, CloudQueue, EdgeEntry, EdgeQueue};
use crate::rng::Rng;
use crate::sim::{Event, EventQueue};
use crate::task::{DropReason, Fate, Task, TaskId, TaskOutcome};
use crate::time::Micros;

/// The edge executor's currently running task.
#[derive(Debug)]
struct RunningEdge {
    entry: EdgeEntry,
    /// Expected completion (used for feasibility of later arrivals).
    expected_end: Micros,
    /// Actual completion (when `EdgeDone` fires).
    actual_end: Micros,
    stolen: bool,
}

/// One in-flight FaaS invocation.
struct CloudRunning {
    entry: CloudEntry,
    end: Micros,
    duration: Micros,
    timed_out: bool,
}

/// A single edge base station with its cloud path.
pub struct Platform {
    pub policy: Policy,
    pub models: Vec<ModelProfile>,
    pub metrics: Metrics,
    edge_q: EdgeQueue,
    cloud_q: CloudQueue,
    /// Triggered cloud entries waiting for a free executor thread.
    cloud_ready: VecDeque<CloudEntry>,
    running_edge: Option<RunningEdge>,
    cloud_running: HashMap<u64, CloudRunning>,
    cloud_inflight: usize,
    /// Cloud executor thread-pool size (§3.3).
    pub cloud_pool: usize,
    pub edge_exec: EdgeExecModel,
    cloud_exec: CloudExecModel,
    adapt: Vec<ModelAdapt>,
    qoe: Vec<WindowMonitor>,
    rng: Rng,
    next_task_id: TaskId,
    next_cloud_key: u64,
    /// Smallest expected edge duration across models (steal gate, §5.3).
    min_t_edge: Micros,
}

impl Platform {
    pub fn new(policy: Policy, models: Vec<ModelProfile>,
               cloud_exec: CloudExecModel, seed: u64) -> Self {
        let kinds: Vec<DnnKind> = models.iter().map(|m| m.kind).collect();
        let adapt = models
            .iter()
            .map(|m| ModelAdapt::new(m.t_cloud, policy.adapt_window))
            .collect();
        let qoe = models
            .iter()
            .map(|m| WindowMonitor::new(m.qoe_rate, m.qoe_window,
                                        m.qoe_benefit))
            .collect();
        let min_t_edge =
            models.iter().map(|m| m.t_edge).min().unwrap_or(0);
        Platform {
            edge_q: EdgeQueue::new(policy.edge_order),
            policy,
            metrics: Metrics::new(&kinds),
            models,
            cloud_q: CloudQueue::new(),
            cloud_ready: VecDeque::new(),
            running_edge: None,
            cloud_running: HashMap::new(),
            cloud_inflight: 0,
            cloud_pool: 16,
            edge_exec: EdgeExecModel::default(),
            cloud_exec,
            adapt,
            qoe,
            rng: Rng::new(seed),
            next_task_id: 0,
            next_cloud_key: 0,
            min_t_edge,
        }
    }

    // ------------------------------------------------------------ helpers

    fn idx(&self, kind: DnnKind) -> usize {
        self.models
            .iter()
            .position(|m| m.kind == kind)
            .expect("model registered")
    }

    fn profile(&self, kind: DnnKind) -> &ModelProfile {
        &self.models[self.idx(kind)]
    }

    /// Expected cloud duration for a model (adapted when DEMS-A is on).
    fn expected_cloud(&self, kind: DnnKind) -> Micros {
        if self.policy.adaptive {
            self.adapt[self.idx(kind)].expected()
        } else {
            self.profile(kind).t_cloud
        }
    }

    /// When the edge executor is expected to free up.
    fn edge_busy_until(&self, now: Micros) -> Micros {
        match &self.running_edge {
            Some(r) => r.expected_end.max(now),
            None => now,
        }
    }

    pub fn fresh_task_id(&mut self) -> TaskId {
        self.next_task_id += 1;
        self.next_task_id
    }

    /// Register the initial QoE window-close events (call once at t=0).
    pub fn schedule_windows(&mut self, q: &mut EventQueue) {
        for (i, mon) in self.qoe.iter().enumerate() {
            if mon.enabled() {
                q.push(mon.window_end, Event::WindowClose { model_idx: i });
            }
        }
    }

    // --------------------------------------------------------- submission

    /// Entry point: the task-scheduler thread of Fig. 4.
    pub fn submit_task(&mut self, now: Micros, task: Task,
                       q: &mut EventQueue) {
        self.metrics.stats_mut(task.model).generated += 1;
        match self.policy.kind {
            PolicyKind::CloudOnly => {
                self.offer_cloud(now, task, false, q);
            }
            PolicyKind::EdgeEdf | PolicyKind::EdgeHpf => {
                let p = self.profile(task.model);
                let (dl, te, hp) = (
                    task.absolute_deadline(p.deadline),
                    p.t_edge,
                    p.hpf_priority(),
                );
                self.edge_q.insert(task, dl, te, hp);
                self.try_start_edge(now, q);
            }
            PolicyKind::EdfEC | PolicyKind::SjfEC => {
                self.admit_ec(now, task, q);
            }
            PolicyKind::Dem
            | PolicyKind::Dems
            | PolicyKind::DemsA
            | PolicyKind::Gems => {
                self.admit_dem(now, task, q);
            }
            PolicyKind::Sota1 => self.admit_sota1(now, task, q),
            PolicyKind::Sota2 => self.admit_sota2(now, task, q),
        }
    }

    /// E+C admission (§5.1): edge if self-feasible, else offer to cloud.
    fn admit_ec(&mut self, now: Micros, task: Task, q: &mut EventQueue) {
        let p = self.profile(task.model);
        let (dl, te, hp) =
            (task.absolute_deadline(p.deadline), p.t_edge, p.hpf_priority());
        let busy = self.edge_busy_until(now);
        if self.edge_q.feasible(dl, te, hp, busy) {
            self.edge_q.insert(task, dl, te, hp);
            self.try_start_edge(now, q);
        } else {
            self.offer_cloud(now, task, false, q);
        }
    }

    /// DEM/DEMS admission with migration scoring (§5.2, Fig. 5).
    fn admit_dem(&mut self, now: Micros, task: Task, q: &mut EventQueue) {
        let p = self.profile(task.model).clone();
        let dl = task.absolute_deadline(p.deadline);
        let busy = self.edge_busy_until(now);
        let probe =
            self.edge_q.probe_insert(dl, p.t_edge, p.hpf_priority(), busy);
        if probe.completion > dl {
            // Scenario "own deadline missed": redirect to cloud.
            self.offer_cloud(now, task, false, q);
            return;
        }
        if !probe.victims.is_empty() && self.policy.migration {
            // Eqn 3 scores for the victims and the incoming task.
            let t_hat_in = self.expected_cloud(task.model);
            let s_in = p.migration_score(now + t_hat_in <= dl);
            let mut s_victims = 0.0;
            for &vi in &probe.victims {
                let e = &self.edge_q.get(vi).unwrap().task;
                let vp = self.profile(e.model);
                let t_hat = self.expected_cloud(e.model);
                let feasible = now + t_hat
                    <= e.absolute_deadline(vp.deadline);
                s_victims += vp.migration_score(feasible);
            }
            if s_victims < s_in {
                // Migrate the victims (rear-first so indices stay valid),
                // then insert the incoming task (Fig. 5, scenario 2).
                for &vi in probe.victims.iter().rev() {
                    let victim = self.edge_q.remove_at(vi);
                    self.offer_cloud(now, victim.task, false, q);
                }
                self.edge_q.insert(task, dl, p.t_edge, p.hpf_priority());
            } else {
                // Retain existing tasks; incoming goes to the cloud
                // (Fig. 5, scenario 3).
                self.offer_cloud(now, task, false, q);
            }
        } else {
            self.edge_q.insert(task, dl, p.t_edge, p.hpf_priority());
        }
        self.try_start_edge(now, q);
    }

    /// SOTA 1 (Kalmia + D3): urgent tasks never wait for a stretched
    /// deadline; non-urgent tasks get a one-shot 10% deadline extension
    /// before being offloaded.
    fn admit_sota1(&mut self, now: Micros, task: Task, q: &mut EventQueue) {
        let p = self.profile(task.model).clone();
        let dl = task.absolute_deadline(p.deadline);
        let busy = self.edge_busy_until(now);
        if self.edge_q.feasible(dl, p.t_edge, p.hpf_priority(), busy) {
            self.edge_q.insert(task, dl, p.t_edge, p.hpf_priority());
            self.try_start_edge(now, q);
            return;
        }
        let urgent = p.deadline < self.policy.sota1_urgent_below;
        if !urgent {
            let stretched = dl
                + (p.deadline as f64 * self.policy.sota1_extension) as Micros;
            if self
                .edge_q
                .feasible(stretched, p.t_edge, p.hpf_priority(), busy)
            {
                self.edge_q.insert(task, stretched, p.t_edge,
                                   p.hpf_priority());
                self.try_start_edge(now, q);
                return;
            }
        }
        self.offer_cloud(now, task, false, q);
    }

    /// SOTA 2 (Dedas-style): exec-time priority; reject to cloud when more
    /// than one queued task would miss its deadline, otherwise keep the
    /// schedule with the lower average completion time.
    fn admit_sota2(&mut self, now: Micros, task: Task, q: &mut EventQueue) {
        let p = self.profile(task.model).clone();
        let dl = task.absolute_deadline(p.deadline);
        let busy = self.edge_busy_until(now);
        let probe =
            self.edge_q.probe_insert(dl, p.t_edge, p.hpf_priority(), busy);
        let accept = if probe.completion > dl || probe.victims.len() > 1 {
            false
        } else if probe.victims.is_empty() {
            true
        } else {
            // One victim: compare ACT of the two candidate schedules.
            let act_without = self.edge_act(busy, None);
            let act_with = self.edge_act(busy, Some((probe.pos, p.t_edge)));
            act_with <= act_without + p.t_edge as f64
        };
        if accept {
            self.edge_q.insert(task, dl, p.t_edge, p.hpf_priority());
            self.try_start_edge(now, q);
        } else {
            self.offer_cloud(now, task, false, q);
        }
    }

    /// Mean expected completion time of the edge queue, optionally with a
    /// hypothetical insertion `(pos, t_edge)`.
    fn edge_act(&self, busy: Micros, insert: Option<(usize, Micros)>) -> f64 {
        let mut t = busy;
        let mut sum = 0.0;
        let mut n = 0u64;
        let mut entries: Vec<Micros> =
            self.edge_q.iter().map(|e| e.t_edge).collect();
        if let Some((pos, te)) = insert {
            entries.insert(pos.min(entries.len()), te);
        }
        for te in entries {
            t += te;
            sum += t as f64;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    // ------------------------------------------------------------- cloud

    /// Offer a task to the cloud scheduler (§5.1/§5.3). Returns true if it
    /// was queued; otherwise its drop has been finalized.
    fn offer_cloud(&mut self, now: Micros, task: Task, gems: bool,
                   q: &mut EventQueue) -> bool {
        if !self.policy.use_cloud {
            self.drop_task(now, task, DropReason::Infeasible, q);
            return false;
        }
        let p = self.profile(task.model).clone();
        let i = self.idx(task.model);
        let dl = task.absolute_deadline(p.deadline);
        let t_hat = self.expected_cloud(task.model);
        if now + t_hat > dl {
            if self.policy.adaptive {
                self.adapt[i].on_skip(now, self.policy.cooling_period);
            }
            self.drop_task(now, task, DropReason::Infeasible, q);
            return false;
        }
        let negative = p.util_cloud() <= 0.0;
        if negative && !self.policy.cloud_accepts_negative {
            if self.policy.defer_cloud && self.policy.stealing {
                // §5.3: keep as a steal candidate until the latest time it
                // could still start on the edge.
                let trigger = dl.saturating_sub(p.t_edge).max(now);
                self.cloud_q.insert(CloudEntry {
                    task,
                    abs_deadline: dl,
                    t_cloud: t_hat,
                    t_edge: p.t_edge,
                    trigger,
                    negative_utility: true,
                    gems_rescheduled: gems,
                });
                q.push(trigger, Event::CloudTrigger);
                return true;
            }
            self.drop_task(now, task, DropReason::NegativeCloudUtility, q);
            return false;
        }
        // Positive-utility path: deferred trigger under DEMS, immediate
        // dispatch otherwise (and always immediate for GEMS reschedules).
        // The deferral headroom is 1.5·t̂ + margin: t̂ is a p95, so leaving
        // only t̂ of runway turns every above-p95 draw (and any transfer
        // contention from synchronized triggers) into a miss billed at κ̂.
        // In practice this defers only long-deadline/short-t̂ tasks — the
        // same population §5.3 observes being stolen.
        let trigger = if self.policy.defer_cloud && !gems {
            dl.saturating_sub(t_hat + t_hat / 2 + self.policy.safety_margin)
                .max(now)
        } else {
            now
        };
        self.cloud_q.insert(CloudEntry {
            task,
            abs_deadline: dl,
            t_cloud: t_hat,
            t_edge: p.t_edge,
            trigger,
            negative_utility: negative,
            gems_rescheduled: gems,
        });
        q.push(trigger, Event::CloudTrigger);
        true
    }

    /// Trigger-time arrival: dispatch due entries to the FaaS pool (§5.3).
    pub fn on_cloud_trigger(&mut self, now: Micros, q: &mut EventQueue) {
        while let Some(e) = self.cloud_q.pop_due(now) {
            if e.negative_utility && !self.policy.cloud_accepts_negative {
                // Un-stolen steal candidate: drop just-in-time.
                self.finalize_drop_entry(now, e, DropReason::TriggerExpired,
                                         q);
                continue;
            }
            let t_hat = self.expected_cloud(e.task.model);
            if now + t_hat > e.abs_deadline {
                if self.policy.adaptive {
                    let i = self.idx(e.task.model);
                    self.adapt[i].on_skip(now, self.policy.cooling_period);
                }
                self.finalize_drop_entry(now, e, DropReason::JitExpired, q);
                continue;
            }
            if self.cloud_inflight < self.cloud_pool {
                self.dispatch_cloud(now, e, q);
            } else {
                self.cloud_ready.push_back(e);
            }
        }
    }

    fn dispatch_cloud(&mut self, now: Micros, e: CloudEntry,
                      q: &mut EventQueue) {
        let p = self.profile(e.task.model).clone();
        let (dur, timed_out) = self.cloud_exec.sample(
            &p,
            now,
            e.task.segment.bytes,
            self.cloud_inflight,
            &mut self.rng,
        );
        self.next_cloud_key += 1;
        let key = self.next_cloud_key;
        self.cloud_running.insert(
            key,
            CloudRunning { entry: e, end: now + dur, duration: dur,
                           timed_out },
        );
        self.cloud_inflight += 1;
        q.push(now + dur, Event::CloudDone { key });
    }

    pub fn on_cloud_done(&mut self, now: Micros, key: u64,
                         q: &mut EventQueue) {
        let run = match self.cloud_running.remove(&key) {
            Some(r) => r,
            None => return,
        };
        self.cloud_inflight -= 1;
        let p = self.profile(run.entry.task.model).clone();
        let success = !run.timed_out && run.end <= run.entry.abs_deadline;
        if self.policy.adaptive {
            let i = self.idx(run.entry.task.model);
            self.adapt[i].observe(run.duration, self.policy.adapt_epsilon);
        }
        if run.timed_out {
            // Abandoned request: no usable output, not billed as a miss.
            let outcome = TaskOutcome {
                task_id: run.entry.task.id,
                model: run.entry.task.model,
                drone: run.entry.task.segment.drone,
                fate: Fate::Dropped(DropReason::Timeout),
                at: now,
                created_at: run.entry.task.segment.created_at,
                exec_duration: run.duration,
                utility: 0.0,
                gems_rescheduled: run.entry.gems_rescheduled,
                stolen: false,
            };
            self.finalize(now, outcome, q);
            self.pull_cloud_ready(now, q);
            return;
        }
        if self.metrics.record_timeline {
            self.metrics.timeline.push(TimelinePoint {
                at: now,
                model: run.entry.task.model,
                observed_ms: run.duration as f64 / 1_000.0,
                expected_ms: self.expected_cloud(run.entry.task.model) as f64
                    / 1_000.0,
                success,
            });
        }
        let fate = if success {
            Fate::Completed(Resource::Cloud)
        } else {
            Fate::Missed(Resource::Cloud)
        };
        let outcome = TaskOutcome {
            task_id: run.entry.task.id,
            model: run.entry.task.model,
            drone: run.entry.task.segment.drone,
            fate,
            at: now,
            created_at: run.entry.task.segment.created_at,
            exec_duration: run.duration,
            utility: p.utility(Resource::Cloud, success),
            gems_rescheduled: run.entry.gems_rescheduled,
            stolen: false,
        };
        self.finalize(now, outcome, q);
        self.pull_cloud_ready(now, q);
    }

    /// A pool slot freed: pull the next ready entry (re-JIT-checked).
    fn pull_cloud_ready(&mut self, now: Micros, q: &mut EventQueue) {
        while let Some(e) = self.cloud_ready.pop_front() {
            let t_hat = self.expected_cloud(e.task.model);
            if now + t_hat > e.abs_deadline {
                self.finalize_drop_entry(now, e, DropReason::JitExpired, q);
                continue;
            }
            self.dispatch_cloud(now, e, q);
            break;
        }
    }

    // -------------------------------------------------------------- edge

    /// The edge executor's pick-next loop, with the §5.3 steal hook.
    pub fn try_start_edge(&mut self, now: Micros, q: &mut EventQueue) {
        if self.running_edge.is_some() || !self.policy.use_edge {
            return;
        }
        loop {
            if self.policy.stealing {
                let slack = self.edge_min_slack(now);
                if slack > self.min_t_edge as i64 {
                    let models = &self.models;
                    let steal = self.cloud_q.best_steal(now, slack, |e| {
                        models
                            .iter()
                            .find(|m| m.kind == e.task.model)
                            .map(|m| m.steal_rank())
                            .unwrap_or(f64::MIN)
                    });
                    if let Some(idx) = steal {
                        let ce = self.cloud_q.remove_at(idx);
                        let entry = EdgeEntry {
                            abs_deadline: ce.abs_deadline,
                            t_edge: ce.t_edge,
                            key: 0,
                            seq: 0,
                            gems_rescheduled: ce.gems_rescheduled,
                            task: ce.task,
                        };
                        self.start_edge(now, entry, true, q);
                        return;
                    }
                }
            }
            let head = match self.edge_q.pop() {
                Some(h) => h,
                None => return,
            };
            // JIT check (§3.3): expected completion must meet the deadline.
            // Edge-only baselines execute regardless (Policy::edge_jit_drop).
            if self.policy.edge_jit_drop
                && now + head.t_edge > head.abs_deadline
            {
                self.finalize_drop_edge(now, head, DropReason::JitExpired, q);
                continue;
            }
            self.start_edge(now, head, false, q);
            return;
        }
    }

    /// Minimum slack across the queued edge tasks (i64::MAX when empty):
    /// how much extra work the executor can take on *now* without pushing
    /// any queued task past its deadline.
    fn edge_min_slack(&self, now: Micros) -> i64 {
        let mut t = now;
        let mut min = i64::MAX;
        for e in self.edge_q.iter() {
            t += e.t_edge;
            min = min.min(e.abs_deadline as i64 - t as i64);
        }
        min
    }

    fn start_edge(&mut self, now: Micros, entry: EdgeEntry, stolen: bool,
                  q: &mut EventQueue) {
        let p = self.profile(entry.task.model).clone();
        let actual = self.edge_exec.sample(&p, &mut self.rng);
        self.metrics.edge_busy += actual;
        let expected_end = now + entry.t_edge;
        let actual_end = now + actual;
        self.running_edge =
            Some(RunningEdge { entry, expected_end, actual_end, stolen });
        q.push(actual_end, Event::EdgeDone);
    }

    pub fn on_edge_done(&mut self, now: Micros, q: &mut EventQueue) {
        let run = match self.running_edge.take() {
            Some(r) => r,
            None => return,
        };
        let p = self.profile(run.entry.task.model).clone();
        let success = run.actual_end <= run.entry.abs_deadline;
        let fate = if success {
            Fate::Completed(Resource::Edge)
        } else {
            Fate::Missed(Resource::Edge)
        };
        let outcome = TaskOutcome {
            task_id: run.entry.task.id,
            model: run.entry.task.model,
            drone: run.entry.task.segment.drone,
            fate,
            at: now,
            created_at: run.entry.task.segment.created_at,
            exec_duration: run.actual_end
                - (run.expected_end - run.entry.t_edge),
            utility: p.utility(Resource::Edge, success),
            gems_rescheduled: run.entry.gems_rescheduled,
            stolen: run.stolen,
        };
        self.finalize(now, outcome, q);
        self.try_start_edge(now, q);
    }

    // --------------------------------------------------------------- QoE

    /// Tumbling window boundary (Alg. 1 lines 16–21).
    pub fn on_window_close(&mut self, _now: Micros, model_idx: usize,
                           q: &mut EventQueue) {
        let kind = self.models[model_idx].kind;
        let mon = &mut self.qoe[model_idx];
        let met = mon.close_window();
        let s = self.metrics.stats_mut(kind);
        s.windows_total += 1;
        if met {
            s.windows_met += 1;
            s.qoe_utility += self.qoe[model_idx].qoe_benefit;
        }
        q.push(self.qoe[model_idx].window_end,
               Event::WindowClose { model_idx });
    }

    /// Algorithm 1, per-completion trigger: update α̂ and, when falling
    /// behind, greedily reschedule this model's pending edge tasks to the
    /// cloud (lines 8–14).
    fn gems_hook(&mut self, now: Micros, kind: DnnKind, success: bool,
                 q: &mut EventQueue) {
        let i = self.idx(kind);
        if !self.qoe[i].enabled() {
            return;
        }
        self.qoe[i].record(success);
        if !(self.policy.gems && self.qoe[i].falling_behind()) {
            return;
        }
        let p = self.profile(kind).clone();
        if p.util_cloud() <= 0.0 {
            return; // GEMS only helps via positive-utility cloud runs (§6)
        }
        let t_hat = self.expected_cloud(kind);
        let pending = self.edge_q.tasks_of_model(kind);
        for (_, tid) in pending {
            // Re-find by id: earlier removals shift indices.
            let Some(entry) = self.peek_entry(tid) else { continue };
            if now + t_hat <= entry.abs_deadline {
                let e = self.edge_q.remove_task(tid).unwrap();
                self.cloud_q.insert(CloudEntry {
                    task: e.task,
                    abs_deadline: e.abs_deadline,
                    t_cloud: t_hat,
                    t_edge: e.t_edge,
                    trigger: now,
                    negative_utility: false,
                    gems_rescheduled: true,
                });
                q.push(now, Event::CloudTrigger);
            }
        }
    }

    fn peek_entry(&self, tid: TaskId) -> Option<&EdgeEntry> {
        self.edge_q.iter().find(|e| e.task.id == tid)
    }

    // ------------------------------------------------------- finalization

    fn finalize(&mut self, now: Micros, outcome: TaskOutcome,
                q: &mut EventQueue) {
        let kind = outcome.model;
        let success = outcome.success();
        self.metrics.record(&outcome);
        self.gems_hook(now, kind, success, q);
    }

    fn drop_task(&mut self, now: Micros, task: Task, reason: DropReason,
                 q: &mut EventQueue) {
        let outcome = TaskOutcome {
            task_id: task.id,
            model: task.model,
            drone: task.segment.drone,
            fate: Fate::Dropped(reason),
            at: now,
            created_at: task.segment.created_at,
            exec_duration: 0,
            utility: 0.0,
            gems_rescheduled: false,
            stolen: false,
        };
        self.finalize(now, outcome, q);
    }

    fn finalize_drop_entry(&mut self, now: Micros, e: CloudEntry,
                           reason: DropReason, q: &mut EventQueue) {
        self.drop_task(now, e.task, reason, q);
    }

    fn finalize_drop_edge(&mut self, now: Micros, e: EdgeEntry,
                          reason: DropReason, q: &mut EventQueue) {
        self.drop_task(now, e.task, reason, q);
    }

    // ------------------------------------------------------ observability

    pub fn edge_queue_len(&self) -> usize {
        self.edge_q.len()
    }

    pub fn cloud_queue_len(&self) -> usize {
        self.cloud_q.len()
    }

    pub fn cloud_inflight(&self) -> usize {
        self.cloud_inflight
    }

    pub fn expected_cloud_ms(&self, kind: DnnKind) -> f64 {
        self.expected_cloud(kind) as f64 / 1_000.0
    }

    /// Drain bookkeeping at end of run (drops queued tasks as infeasible so
    /// task accounting closes; the paper's runs likewise count unfinished
    /// tasks as not completed).
    pub fn drain(&mut self, now: Micros, q: &mut EventQueue) {
        if let Some(run) = self.running_edge.take() {
            self.finalize_drop_edge(now, run.entry, DropReason::JitExpired,
                                    q);
        }
        let keys: Vec<u64> = self.cloud_running.keys().copied().collect();
        for k in keys {
            if let Some(run) = self.cloud_running.remove(&k) {
                self.drop_task(now, run.entry.task, DropReason::Timeout, q);
            }
        }
        while let Some(e) = self.edge_q.pop() {
            self.finalize_drop_edge(now, e, DropReason::JitExpired, q);
        }
        while let Some(idx) = (!self.cloud_q.is_empty()).then_some(0) {
            let e = self.cloud_q.remove_at(idx);
            self.finalize_drop_entry(now, e, DropReason::TriggerExpired, q);
        }
        while let Some(e) = self.cloud_ready.pop_front() {
            self.finalize_drop_entry(now, e, DropReason::JitExpired, q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::EdgeExecModel;
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::task::VideoSegment;
    use crate::time::ms;

    fn mkplatform(policy: Policy) -> Platform {
        let mut cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        // Deterministic cloud for scenario tests: no cold starts.
        cloud.cold_start = 0;
        cloud.cold_prob = 0.0;
        let mut p = Platform::new(policy, table1(), cloud, 7);
        // Deterministic edge service times for scenario tests.
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        p
    }

    fn mktask(p: &mut Platform, kind: DnnKind, created: Micros) -> Task {
        let id = p.fresh_task_id();
        Task {
            id,
            model: kind,
            segment: VideoSegment {
                id,
                drone: 0,
                created_at: created,
                bytes: 38_000,
            },
        }
    }

    /// Drain all events up to (and including) time `until`.
    fn settle(p: &mut Platform, q: &mut EventQueue, until: Micros) {
        while let Some((t, ev)) = q.pop() {
            if t > until {
                // Push back and stop (EventQueue has no peek).
                q.push(t, ev);
                break;
            }
            match ev {
                Event::EdgeDone => p.on_edge_done(t, q),
                Event::CloudTrigger => p.on_cloud_trigger(t, q),
                Event::CloudDone { key } => p.on_cloud_done(t, key, q),
                Event::WindowClose { model_idx } => {
                    p.on_window_close(t, model_idx, q)
                }
                Event::Segment { .. } => {}
            }
        }
    }

    #[test]
    fn single_task_completes_on_edge() {
        let mut p = mkplatform(Policy::dems());
        let mut q = EventQueue::new();
        let t = mktask(&mut p, DnnKind::Hv, 0);
        p.submit_task(0, t, &mut q);
        settle(&mut p, &mut q, ms(1_000));
        assert_eq!(p.metrics.completed(), 1);
        assert_eq!(p.metrics.completed_on(Resource::Edge), 1);
        assert_eq!(p.metrics.qos_utility(), 124.0);
    }

    #[test]
    fn infeasible_edge_task_offloads_and_completes_on_cloud() {
        let mut p = mkplatform(Policy::edf_ec());
        let mut q = EventQueue::new();
        // Saturate the edge with DEO (739 ms each), then submit HV whose
        // 650 ms deadline cannot be met behind them.
        for _ in 0..2 {
            let t = mktask(&mut p, DnnKind::Deo, 0);
            p.submit_task(0, t, &mut q);
        }
        let hv = mktask(&mut p, DnnKind::Hv, 0);
        p.submit_task(0, hv, &mut q);
        settle(&mut p, &mut q, ms(3_000));
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.completed_cloud, 1, "HV should offload: {s:?}");
    }

    #[test]
    fn fig5_scenario2_migrates_lower_score_victim() {
        // DEO occupies the queue rear; an incoming HV (earlier deadline)
        // starves it. DEO is cloud-feasible (score γᴱ−γᶜ = 204) vs HV
        // incoming score 24 → HV itself is redirected (scenario 3 shape).
        // Conversely a BP victim (score 38) loses to an incoming DEO
        // (score 204) and gets migrated (scenario 2 shape).
        let mut p = mkplatform(Policy::dems());
        let mut q = EventQueue::new();
        // Edge busy: one BP at the head (deadline 900, t 244), queue holds
        // another BP.
        let b1 = mktask(&mut p, DnnKind::Bp, 0);
        p.submit_task(0, b1, &mut q); // starts executing
        let b2 = mktask(&mut p, DnnKind::Bp, 0);
        p.submit_task(0, b2, &mut q); // queued
        // Incoming DEO with deadline 950 and t 739: probing places it
        // after BP (deadline 950 > 900) — no victims... instead craft the
        // starvation with CD (deadline 1000, t 563):
        let cd = mktask(&mut p, DnnKind::Cd, 0);
        p.submit_task(0, cd, &mut q); // rear: completes 244+244+563 = 1051 > 1000? → offloaded itself
        // Now a DEO arriving with an earlier deadline (950) would insert
        // before CD; validate by metrics after settling instead of queue
        // internals: everything must be accounted for.
        let deo = mktask(&mut p, DnnKind::Deo, 0);
        p.submit_task(0, deo, &mut q);
        settle(&mut p, &mut q, ms(5_000));
        let m = &p.metrics;
        let total: u64 = m.per_model.iter().map(|(_, s)| s.generated).sum();
        let closed: u64 = m
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(total, closed, "accounting closes under migration");
        // At least one task must have been pushed to the cloud path.
        assert!(
            m.completed_on(Resource::Cloud) > 0
                || m.per_model.iter().any(|(_, s)| s.dropped() > 0)
        );
    }

    #[test]
    fn fig6_negative_utility_bp_is_stolen_by_idle_edge() {
        let mut p = mkplatform(Policy::dems());
        let mut q = EventQueue::new();
        // Saturate the edge so BP is rejected there (its own deadline
        // cannot be met), sending it to the cloud queue as a negative-
        // utility steal candidate.
        for _ in 0..3 {
            let t = mktask(&mut p, DnnKind::Deo, 0);
            p.submit_task(0, t, &mut q);
        }
        let bp = mktask(&mut p, DnnKind::Bp, 0);
        p.submit_task(0, bp, &mut q);
        assert!(p.cloud_queue_len() > 0, "BP parked in the cloud queue");
        settle(&mut p, &mut q, ms(10_000));
        let s = p.metrics.stats(DnnKind::Bp);
        // Either stolen back to the edge (preferred) or trigger-expired;
        // DEMS must never execute it on the cloud.
        assert_eq!(s.completed_cloud, 0);
        assert_eq!(s.missed_cloud, 0);
    }

    #[test]
    fn cloud_pool_limits_inflight() {
        let mut p = mkplatform(Policy::cloud_only());
        p.cloud_pool = 2;
        let mut q = EventQueue::new();
        for _ in 0..8 {
            let t = mktask(&mut p, DnnKind::Hv, 0);
            p.submit_task(0, t, &mut q);
        }
        // Fire the triggers (CLD dispatches immediately → trigger at 0).
        p.on_cloud_trigger(0, &mut q);
        assert!(p.cloud_inflight() <= 2);
        settle(&mut p, &mut q, ms(20_000));
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.generated, s.executed() + s.dropped());
    }

    #[test]
    fn gems_reschedules_pending_edge_tasks_on_slip() {
        use crate::model::{table2, GemsWorkload};
        let cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        let mut p =
            Platform::new(Policy::gems(false), table2(GemsWorkload::Wl1, 0.9),
                          cloud, 7);
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        let mut q = EventQueue::new();
        // Queue several DEV tasks, then force a completion-rate slip by
        // dropping one (finalize path) — the monitor should move pending
        // DEV tasks to the cloud queue.
        for _ in 0..3 {
            let t = mktask(&mut p, DnnKind::Dev, 0);
            p.submit_task(0, t, &mut q);
        }
        let before_cloud = p.cloud_queue_len();
        // A missed DEV (deadline in the past ⇒ JIT drop at the executor)
        let stale = mktask(&mut p, DnnKind::Dev, 0);
        // Manufacture a failure via the public API: submit with an
        // already-hopeless deadline by advancing `now` far beyond it.
        p.submit_task(ms(10_000), stale, &mut q);
        assert!(
            p.cloud_queue_len() > before_cloud
                || p.metrics.gems_rescheduled() > 0
                || p.metrics.stats(DnnKind::Dev).dropped() > 0,
            "GEMS should react to the slip"
        );
    }

    #[test]
    fn sota1_extends_non_urgent_deadlines() {
        let mut p = mkplatform(Policy::sota1());
        let mut q = EventQueue::new();
        // CD (δ=1000 ≥ 750 ⇒ non-urgent) behind enough work that plain
        // feasibility fails but a 10% stretch passes.
        let a = mktask(&mut p, DnnKind::Cd, 0);
        p.submit_task(0, a, &mut q);
        let b = mktask(&mut p, DnnKind::Md, 0);
        p.submit_task(0, b, &mut q);
        settle(&mut p, &mut q, ms(5_000));
        let m = &p.metrics;
        let total: u64 = m.per_model.iter().map(|(_, s)| s.generated).sum();
        let closed: u64 = m
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(total, closed);
    }

    #[test]
    fn edge_only_has_no_cloud_activity() {
        let mut p = mkplatform(Policy::edge_edf());
        let mut q = EventQueue::new();
        for kind in DnnKind::ALL {
            let t = mktask(&mut p, kind, 0);
            p.submit_task(0, t, &mut q);
        }
        settle(&mut p, &mut q, ms(20_000));
        assert_eq!(p.metrics.completed_on(Resource::Cloud), 0);
        assert_eq!(p.cloud_queue_len(), 0);
    }

    #[test]
    fn expected_cloud_uses_adaptation_only_when_enabled() {
        let mut p = mkplatform(Policy::dems());
        assert_eq!(p.expected_cloud_ms(DnnKind::Hv), 398.0);
        let mut pa = mkplatform(Policy::dems_a());
        assert_eq!(pa.expected_cloud_ms(DnnKind::Hv), 398.0);
        let _ = &mut pa;
    }
}
