//! The Ocularone platform substrate (Fig. 4): one edge base station with
//! its task queues, the edge executor, the cloud FaaS path and the metrics
//! plumbing — *mechanism only*.
//!
//! Every scheduling decision (admission, migration scoring, work stealing,
//! adaptation, the GEMS window monitor) lives behind the
//! [`Scheduler`](crate::sched::Scheduler) trait in [`crate::sched`]; a
//! [`Platform`] pairs one scheduler with one [`Core`]. The platform is a
//! deterministic state machine over virtual time: the discrete-event engine
//! ([`crate::cluster`] / [`crate::sim`]) or the real-time serving loop
//! (`serve`, behind the `pjrt` feature) feeds it events; it mutates queues
//! and pushes future events.
//!
//! Split of responsibilities:
//!
//! * [`Core`] — queues, executors, the cloud pool, RNG, metrics, QoE window
//!   accounting, task-id allocation. No `PolicyKind` branching.
//! * [`Platform`] — event handlers (`submit_task`, `on_edge_done`, …) that
//!   interleave core mechanics with scheduler hook calls at exactly the
//!   decision points of §5–§6.
//!
//! `Platform` derefs to `Core`, so observability fields (`metrics`,
//! `edge_exec`, `cloud_pool`, …) read like the pre-split monolith.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::cloud::{Attempt, CloudBackend, CloudStats};
use crate::exec::{lite_variant, DroneExecModel, EdgeExecModel};
use crate::metrics::{Metrics, TimelinePoint};
use crate::model::{DnnKind, ModelProfile, Resource};
use crate::net::{ConstantNet, NetworkModel, SharedUplink};
use crate::obs::{TraceHandle, TraceKind};
use crate::pipeline::{PipelineRef, StageGraph};
use crate::policy::{PipelineCut, Policy};
use crate::qoe::WindowMonitor;
use crate::queues::{CloudEntry, CloudQueue, EdgeEntry, EdgeQueue};
use crate::resilience::{BreakerGate, ResilienceState};
use crate::rng::Rng;
use crate::sched::{CloudReport, SchedCtx, Scheduler};
use crate::sim::{Event, EventQueue};
use crate::task::{DropReason, Fate, Task, TaskId, TaskOutcome};
use crate::time::{ms, Micros};

/// The edge executor's currently running task.
#[derive(Debug)]
pub(crate) struct RunningEdge {
    pub(crate) entry: EdgeEntry,
    /// Expected completion (used for feasibility of later arrivals).
    pub(crate) expected_end: Micros,
    /// Actual completion (when `EdgeDone` fires).
    pub(crate) actual_end: Micros,
    pub(crate) stolen: bool,
    /// Running on the lite model variant (graceful degradation): the
    /// sampled duration was scaled down and the success utility will be
    /// discounted at finalize ([`crate::exec::lite_variant`]).
    pub(crate) degraded: bool,
}

/// One in-flight FaaS invocation.
pub(crate) struct CloudRunning {
    pub(crate) entry: CloudEntry,
    pub(crate) end: Micros,
    pub(crate) duration: Micros,
    pub(crate) timed_out: bool,
    /// Backend routing token (see [`CloudBackend::complete`]).
    pub(crate) token: u32,
    /// This invocation is the circuit breaker's half-open recovery
    /// probe; its outcome is reported with `probe = true`.
    pub(crate) probe: bool,
    /// This invocation is the speculative duplicate of a hedged pair.
    /// Exactly one leg of a pair has `is_hedge == false` at any time —
    /// that leg owns the task's ledger (crash/drain finalize only it).
    pub(crate) is_hedge: bool,
    /// Key of the partner leg of a hedged pair while both are in
    /// flight; cleared on promotion, moot once the loser is cancelled.
    pub(crate) hedge_pair: Option<u64>,
}

/// Mechanism-only substrate of one edge base station: queues, executors,
/// the cloud thread pool, metrics and QoE window accounting. Scheduler
/// implementations manipulate it through [`SchedCtx`].
pub struct Core {
    /// Declarative scheduler configuration. The core only reads the
    /// mechanism-ish switches (`use_edge`, `use_cloud`, `edge_jit_drop`,
    /// `cloud_accepts_negative`); everything decision-shaped is interpreted
    /// by the [`Scheduler`] implementations.
    pub policy: Policy,
    pub models: Vec<ModelProfile>,
    pub metrics: Metrics,
    pub(crate) edge_q: EdgeQueue,
    pub(crate) cloud_q: CloudQueue,
    /// Triggered cloud entries waiting for a free executor thread.
    pub(crate) cloud_ready: VecDeque<CloudEntry>,
    pub(crate) running_edge: Option<RunningEdge>,
    pub(crate) cloud_running: HashMap<u64, CloudRunning>,
    pub(crate) cloud_inflight: usize,
    /// Cloud executor thread-pool size (§3.3).
    pub cloud_pool: usize,
    pub edge_exec: EdgeExecModel,
    /// Companion-computer execution model for pipeline prefix stages
    /// ([`crate::pipeline`]); idle unless a workload carries a
    /// [`StageGraph`] whose planned drone prefix is non-zero.
    pub drone_exec: DroneExecModel,
    /// Wireless drone→edge link, charged when a pipeline stage handoff
    /// leaves the drone tier (intermediate tensors are small, the link
    /// is slow — the trade-off the partition point navigates).
    pub(crate) drone_net: Box<dyn NetworkModel>,
    /// Pluggable cloud tier (see [`crate::cloud`]): the default
    /// [`SimpleBackend`](crate::cloud::SimpleBackend) reproduces the
    /// legacy sampler bit-identically; FaaS/multi-region backends add
    /// container lifecycle, concurrency ceilings and billing.
    pub(crate) cloud: Box<dyn CloudBackend>,
    /// Shared backhaul serializing this edge's cloud transfers with its
    /// siblings' (fleet federation); `None` — the default — models
    /// independent uplinks and changes nothing.
    pub(crate) uplink: Option<Arc<Mutex<SharedUplink>>>,
    /// Per-model QoE window monitors (Alg. 1 counters; always recorded so
    /// any scheduler can consult them).
    pub(crate) qoe: Vec<WindowMonitor>,
    pub(crate) rng: Rng,
    /// Resilience state machines (see [`crate::resilience`]), built once
    /// from the policy's `ResilienceSpec`. Every member is `None` under
    /// the all-off default, so the hot paths below gate on that and the
    /// plain engine stays bit-identical.
    pub(crate) resilience: ResilienceState,
    /// Fault injection (see [`crate::fault`]): the edge is dark — any
    /// work submitted while set is immediately lost with
    /// [`DropReason::NodeFailure`]. Always false without a `FaultSpec`
    /// (bit-identity with the fault-free engine).
    pub(crate) crashed: bool,
    /// Task-lifecycle trace sink (see [`crate::obs`]). `None` — the
    /// default — constructs nothing on any hot path; the traced engine
    /// is pinned bit-identical to the untraced one.
    pub(crate) trace: Option<TraceHandle>,
    next_task_id: TaskId,
    next_cloud_key: u64,
    /// Smallest expected edge duration across models (steal gate, §5.3).
    pub(crate) min_t_edge: Micros,
    /// Finalized (model, success) pairs not yet reported to the scheduler;
    /// drained via [`Scheduler::drain_done`] right after each finalize so
    /// hook ordering matches the pre-split monolith.
    pub(crate) pending_done: VecDeque<(DnnKind, bool)>,
}

impl Core {
    pub fn new(policy: Policy, models: Vec<ModelProfile>,
               cloud: impl Into<Box<dyn CloudBackend>>, seed: u64) -> Self {
        let kinds: Vec<DnnKind> = models.iter().map(|m| m.kind).collect();
        let qoe = models
            .iter()
            .map(|m| WindowMonitor::new(m.qoe_rate, m.qoe_window,
                                        m.qoe_benefit))
            .collect();
        let min_t_edge =
            models.iter().map(|m| m.t_edge).min().unwrap_or(0);
        Core {
            edge_q: EdgeQueue::new(policy.edge_order),
            resilience: ResilienceState::from_spec(&policy.resilience),
            policy,
            metrics: Metrics::new(&kinds),
            models,
            cloud_q: CloudQueue::new(),
            cloud_ready: VecDeque::new(),
            running_edge: None,
            cloud_running: HashMap::new(),
            cloud_inflight: 0,
            cloud_pool: 16,
            edge_exec: EdgeExecModel::default(),
            drone_exec: DroneExecModel::default(),
            drone_net: Box::new(ConstantNet {
                latency: ms(10),
                bandwidth: 2.0e6,
            }),
            cloud: cloud.into(),
            uplink: None,
            qoe,
            rng: Rng::new(seed),
            crashed: false,
            trace: None,
            next_task_id: 0,
            next_cloud_key: 0,
            min_t_edge,
            pending_done: VecDeque::new(),
        }
    }

    // ------------------------------------------------------------ helpers

    /// Install a task-lifecycle trace sink (see [`crate::obs`]).
    pub fn set_trace(&mut self, handle: TraceHandle) {
        self.trace = Some(handle);
    }

    /// Emit a trace event when a sink is installed. The untraced default
    /// is a single branch on `None` — no event is even constructed.
    #[inline]
    pub(crate) fn emit_trace(&self, at: Micros, kind: TraceKind) {
        if let Some(t) = &self.trace {
            t.emit(at, kind);
        }
    }

    pub(crate) fn idx(&self, kind: DnnKind) -> usize {
        self.models
            .iter()
            .position(|m| m.kind == kind)
            .expect("model registered")
    }

    pub fn profile(&self, kind: DnnKind) -> &ModelProfile {
        &self.models[self.idx(kind)]
    }

    /// When the edge executor is expected to free up.
    pub fn edge_busy_until(&self, now: Micros) -> Micros {
        match &self.running_edge {
            Some(r) => r.expected_end.max(now),
            None => now,
        }
    }

    pub fn fresh_task_id(&mut self) -> TaskId {
        self.next_task_id += 1;
        self.next_task_id
    }

    /// Register the initial QoE window-close events (call once at t=0).
    pub fn schedule_windows(&mut self, q: &mut EventQueue) {
        for (i, mon) in self.qoe.iter().enumerate() {
            if mon.enabled() {
                q.push(mon.window_end, Event::WindowClose { model_idx: i });
            }
        }
    }

    /// Minimum slack across the queued edge tasks (i64::MAX when empty):
    /// how much extra work the executor can take on *now* without pushing
    /// any queued task past its deadline.
    pub fn edge_min_slack(&self, now: Micros) -> i64 {
        let mut t = now;
        let mut min = i64::MAX;
        for e in self.edge_q.iter() {
            t += e.t_edge;
            min = min.min(e.abs_deadline as i64 - t as i64);
        }
        min
    }

    /// Mean expected completion time of the edge queue, optionally with a
    /// hypothetical insertion `(pos, t_edge)` — the SOTA 2 ACT comparison.
    pub(crate) fn edge_act(&self, busy: Micros,
                           insert: Option<(usize, Micros)>) -> f64 {
        let mut t = busy;
        let mut sum = 0.0;
        let mut n = 0u64;
        let mut entries: Vec<Micros> =
            self.edge_q.iter().map(|e| e.t_edge).collect();
        if let Some((pos, te)) = insert {
            entries.insert(pos.min(entries.len()), te);
        }
        for te in entries {
            t += te;
            sum += t as f64;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    // -------------------------------------------------------------- cloud

    /// Queue a cloud entry and register its trigger event (mechanism half
    /// of a cloud offload; the *decision* — deferral window, negative
    /// utility handling — is made by the scheduler before calling this).
    pub(crate) fn push_cloud(&mut self, now: Micros, entry: CloudEntry,
                             q: &mut EventQueue) {
        self.emit_trace(now, TraceKind::Enqueue {
            task: entry.task.id,
            queue: Resource::Cloud,
        });
        let trigger = entry.trigger;
        self.cloud_q.insert(entry);
        q.push(trigger, Event::CloudTrigger);
    }

    /// Queue a task for the edge executor under this edge's priority
    /// order (the single funnel every admission path routes through, so
    /// the enqueue trace hook sees each of them).
    pub(crate) fn enqueue_edge(&mut self, now: Micros, task: Task,
                               abs_deadline: Micros, t_edge: Micros,
                               hpf_priority: f64) {
        self.emit_trace(now, TraceKind::Enqueue {
            task: task.id,
            queue: Resource::Edge,
        });
        self.edge_q.insert(task, abs_deadline, t_edge, hpf_priority);
    }

    /// Hand an entry to the cloud backend. `None` when the invocation is
    /// in flight (a `CloudDone` event is scheduled); `Some((entry,
    /// retry_after))` when the backend throttled it — the caller decides
    /// retry-or-drop (see [`Platform::on_cloud_throttled`]).
    pub(crate) fn dispatch_cloud(&mut self, now: Micros, e: CloudEntry,
                                 q: &mut EventQueue)
                                 -> Option<(CloudEntry, Micros)> {
        // Resilience: an open circuit breaker short-circuits the dispatch
        // *before* the backend is touched. The refusal is throttle-shaped
        // (`Some((entry, retry_after))`), so the caller's existing
        // throttle machinery — §5.4 report, t̂ inflation, retry-or-drop —
        // re-plans the task to edge/federation immediately. The breaker
        // is fed only by real backend outcomes, never by its own
        // refusals, so it cannot self-reinforce.
        let mut probe = false;
        if let Some(br) = &mut self.resilience.breaker {
            match br.gate(now) {
                BreakerGate::Open { until } => {
                    self.metrics.breaker_shorted += 1;
                    return Some((e, until.saturating_sub(now).max(1)));
                }
                BreakerGate::Probe => {
                    probe = true;
                    self.metrics.breaker_probes += 1;
                }
                BreakerGate::Closed => {}
            }
        }
        if probe {
            self.emit_trace(now, TraceKind::BreakerProbe);
        }
        // Split field borrows (backend / profile table / RNG are
        // disjoint) instead of cloning the profile per dispatch.
        let i = self.idx(e.task.model);
        let inv = match self.cloud.invoke(
            &self.models[i],
            now,
            e.task.payload_bytes(),
            self.cloud_inflight,
            &mut self.rng,
        ) {
            Attempt::Run(inv) => inv,
            Attempt::Throttle { retry_after } => {
                // A refusal at the account/region layer (concurrency
                // ceiling, PR 7 outage) is a breaker failure signal —
                // and the verdict of a half-open probe.
                let mut tripped = false;
                if let Some(br) = &mut self.resilience.breaker {
                    let before = br.trips;
                    br.record(now, true, probe);
                    tripped = br.trips > before;
                }
                if tripped {
                    self.emit_trace(now, TraceKind::BreakerTrip);
                }
                return Some((e, retry_after));
            }
        };
        // Shared-uplink contention (fleet federation): the dispatch
        // queues for the sibling-shared pipe before its bytes can flow;
        // the wait inflates the observed duration, which is what the
        // §5.4 adaptation window then reacts to.
        let mut duration = inv.duration;
        if let Some(up) = &self.uplink {
            let wait = up
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .acquire(now, e.task.payload_bytes());
            if wait > 0 {
                self.metrics.uplink_wait += wait;
                self.metrics.uplink_queued += 1;
                if let Some(tl) = &mut self.metrics.windowed {
                    tl.observe_uplink_wait(now, wait);
                }
                duration += wait;
            }
        }
        self.emit_trace(now, TraceKind::Dispatch {
            task: e.task.id,
            on: Resource::Cloud,
        });
        self.next_cloud_key += 1;
        let key = self.next_cloud_key;
        // Hedging: a task with enough remaining slack beyond the nominal
        // cloud duration arms a speculative-duplicate timer. If the
        // primary is still in flight when it fires (i.e. it landed in the
        // latency tail), `on_hedge_fire` launches the duplicate. An
        // invocation that will finish before the timer is never armed
        // (the fire would be a guaranteed no-op); probes are never
        // hedged.
        let hedge_at = match &self.resilience.hedge {
            Some(h)
                if !probe
                    && duration > h.delay
                    && e.abs_deadline
                        >= now + self.models[i].t_cloud + h.slack =>
            {
                Some(now + h.delay)
            }
            _ => None,
        };
        self.cloud_running.insert(
            key,
            CloudRunning {
                entry: e,
                end: now + duration,
                duration,
                timed_out: inv.timed_out,
                token: inv.token,
                probe,
                is_hedge: false,
                hedge_pair: None,
            },
        );
        self.cloud_inflight += 1;
        q.push(now + duration, Event::CloudDone { key });
        if let Some(at) = hedge_at {
            q.push(at, Event::HedgeFire { key });
        }
        None
    }

    // --------------------------------------------------------------- edge

    pub(crate) fn start_edge(&mut self, now: Micros, entry: EdgeEntry,
                             stolen: bool, q: &mut EventQueue) {
        self.emit_trace(now, TraceKind::Dispatch {
            task: entry.task.id,
            on: Resource::Edge,
        });
        let i = self.idx(entry.task.model);
        let mut actual =
            self.edge_exec.sample(&self.models[i], &mut self.rng);
        // Graceful degradation: the lite variant trades accuracy (a
        // utility discount at finalize) for latency. The full-variant
        // sample is scaled after the draw — same RNG consumption, so
        // degrade-off runs stay bit-identical.
        let degraded = self
            .resilience
            .degrade
            .as_ref()
            .is_some_and(|dc| dc.lite());
        if degraded {
            let f = lite_variant(entry.task.model).time_factor;
            actual = ((actual as f64) * f).round() as Micros;
        }
        self.metrics.edge_busy += actual;
        let expected_end = now + entry.t_edge;
        let actual_end = now + actual;
        self.running_edge = Some(RunningEdge {
            entry,
            expected_end,
            actual_end,
            stolen,
            degraded,
        });
        q.push(actual_end, Event::EdgeDone);
    }

    /// Graceful degradation: feed the overload controller its inputs —
    /// edge-queue depth and whether the cloud escape valve is
    /// breaker-blocked — at an executor decision point. No-op without a
    /// [`DegradeController`](crate::resilience::DegradeController).
    pub(crate) fn update_degrade(&mut self, now: Micros) {
        if self.resilience.degrade.is_none() {
            return;
        }
        let breaker_open = self
            .resilience
            .breaker
            .as_ref()
            .is_some_and(|b| b.is_open(now));
        let pressure = self.edge_q.len();
        if let Some(dc) = &mut self.resilience.degrade {
            dc.observe(now, pressure, breaker_open);
        }
    }

    // ------------------------------------------------------- finalization

    /// Record a finalized outcome: metrics, the QoE window counters
    /// (Alg. 1 lines 3–7 — always tracked when a model's monitor is
    /// enabled) and the pending-done queue the scheduler hook drains.
    ///
    /// QoE credit is per *chain*, not per stage: a successful
    /// intermediate pipeline stage records nothing (the operator's
    /// frequency window counts end-to-end results), while any stage
    /// failure kills the chain and records a miss against the chain's
    /// *final* model — the one whose window the verdict belongs to.
    /// Plain tasks and final stages keep the pre-pipeline accounting.
    pub(crate) fn finalize(&mut self, outcome: TaskOutcome,
                           pipeline: Option<&PipelineRef>) {
        self.emit_trace(outcome.at, TraceKind::Finalize {
            task: outcome.task_id,
            fate: outcome.fate,
            utility: outcome.utility,
        });
        let kind = outcome.model;
        let success = outcome.success();
        self.metrics.record(&outcome);
        match pipeline {
            Some(pr) if !pr.is_final() => {
                if !success {
                    let f = self.idx(pr.graph.final_kind());
                    if self.qoe[f].enabled() {
                        self.qoe[f].record(false);
                    }
                }
            }
            _ => {
                let i = self.idx(kind);
                if self.qoe[i].enabled() {
                    self.qoe[i].record(success);
                }
            }
        }
        self.pending_done.push_back((kind, success));
    }

    /// Finalize a drop without execution.
    pub fn drop_task(&mut self, now: Micros, task: Task,
                     reason: DropReason) {
        if reason == DropReason::NodeFailure {
            self.emit_trace(now, TraceKind::FaultLoss { task: task.id });
        }
        let outcome = TaskOutcome {
            task_id: task.id,
            model: task.model,
            drone: task.segment.drone,
            fate: Fate::Dropped(reason),
            at: now,
            created_at: task.segment.created_at,
            exec_duration: 0,
            utility: 0.0,
            gems_rescheduled: false,
            stolen: false,
        };
        self.finalize(outcome, task.pipeline.as_ref());
    }

    /// Stage-gated QoS utility: a successful intermediate pipeline stage
    /// earns nothing (the chain's β is credited once, by its final
    /// stage), while any executed stage that fails is billed the
    /// resource cost it burned. Plain tasks are exactly Eqn 1.
    pub(crate) fn stage_utility(&self, task: &Task, on: Resource,
                                success: bool) -> f64 {
        match &task.pipeline {
            Some(pr) if !pr.is_final() && success => 0.0,
            _ => self.profile(task.model).utility(on, success),
        }
    }

    /// How many leading stages of `graph` the drone's companion computer
    /// takes. A fixed cut pins the count outright; the adaptive planner
    /// keeps extending the prefix while the stage is drone-capable and
    /// the cumulative expected on-drone time still meets each stage's
    /// deadline budget.
    pub fn plan_drone_prefix(&self, graph: &StageGraph) -> usize {
        let limit = match self.policy.pipeline {
            PipelineCut::Fixed { drone, .. } => drone.min(graph.len()),
            PipelineCut::Adaptive => graph.len(),
        };
        let adaptive =
            matches!(self.policy.pipeline, PipelineCut::Adaptive);
        let mut cum: Micros = 0;
        let mut prefix = 0;
        while prefix < limit && graph.stages[prefix].drone_capable {
            cum += self.drone_exec.expected(
                self.profile(graph.stages[prefix].kind));
            if adaptive && cum > graph.stage_deadline(prefix) {
                break;
            }
            prefix += 1;
        }
        prefix
    }

    /// Run a pipeline prefix stage on the drone's companion computer.
    /// The drone tier is per-drone hardware, so there is no shared
    /// queue: the stage starts immediately and its `DroneDone` fires
    /// after a sampled companion-computer duration.
    pub(crate) fn start_drone(&mut self, now: Micros, task: Task,
                              q: &mut EventQueue) {
        self.emit_trace(now, TraceKind::Dispatch {
            task: task.id,
            on: Resource::Drone,
        });
        let i = self.idx(task.model);
        let actual = self.drone_exec.sample(&self.models[i], &mut self.rng);
        let slot = q.stash_task(task);
        q.push(now + actual,
               Event::DroneDone { task: slot, started: now });
    }

    /// A non-final pipeline stage completed: mint the successor stage as
    /// a fresh task and schedule its arrival at this edge's scheduler.
    /// Leaving the drone tier charges the wireless drone→edge link for
    /// the intermediate tensor; edge→cloud handoffs pay their transfer
    /// inside the cloud invocation itself (via [`Task::payload_bytes`]).
    pub(crate) fn spawn_successor(&mut self, now: Micros, done: &Task,
                                  from: Resource, q: &mut EventQueue) {
        let Some(pr) = &done.pipeline else { return };
        if pr.is_final() {
            return;
        }
        let next = pr.stage + 1;
        let next_ref = PipelineRef {
            graph: pr.graph.clone(),
            stage: next,
            drone_prefix: pr.drone_prefix,
        };
        let at = if from == Resource::Drone && next >= pr.drone_prefix {
            let bytes = pr.graph.stages[pr.stage].output_bytes;
            now + self.drone_net.transfer_time(now, bytes, &mut self.rng)
        } else {
            now
        };
        let id = self.fresh_task_id();
        let task = Task {
            id,
            model: next_ref.graph.stages[next].kind,
            segment: done.segment.clone(),
            pipeline: Some(next_ref),
        };
        let slot = q.stash_task(task);
        q.push(at, Event::StageArrive { task: slot });
    }

    /// Next finalized (model, success) pair awaiting the scheduler's
    /// `on_task_done` hook (see [`Scheduler::drain_done`]).
    pub(crate) fn pop_done(&mut self) -> Option<(DnnKind, bool)> {
        self.pending_done.pop_front()
    }

    // ---------------------------------------------------------------- QoE

    /// Tumbling window boundary (Alg. 1 lines 16–21).
    pub(crate) fn window_close(&mut self, model_idx: usize,
                               q: &mut EventQueue) {
        let kind = self.models[model_idx].kind;
        let mon = &mut self.qoe[model_idx];
        let met = mon.close_window();
        let s = self.metrics.stats_mut(kind);
        s.windows_total += 1;
        if met {
            s.windows_met += 1;
            s.qoe_utility += self.qoe[model_idx].qoe_benefit;
        }
        q.push(self.qoe[model_idx].window_end,
               Event::WindowClose { model_idx });
    }

    // ------------------------------------------------------ observability

    pub fn edge_queue_len(&self) -> usize {
        self.edge_q.len()
    }

    pub fn cloud_queue_len(&self) -> usize {
        self.cloud_q.len()
    }

    pub fn cloud_inflight(&self) -> usize {
        self.cloud_inflight
    }

    /// Cumulative accounting of the cloud backend (cost, cold starts,
    /// throttles). Also merged into [`Metrics::cloud`] at end of run.
    pub fn cloud_stats(&self) -> CloudStats {
        self.cloud.stats()
    }

    /// Tag of the configured cloud backend ("simple", "faas", …).
    pub fn cloud_backend_name(&self) -> &'static str {
        self.cloud.name()
    }
}

/// Where [`Platform::submit_task`] sends a task before (or instead of)
/// scheduler admission.
enum Route {
    /// Pipeline prefix stage: the drone's companion computer.
    Drone,
    /// Fixed-cut pipeline stage at/past the cloud cut: pinned cloud entry.
    FixedCloud,
    /// Fixed-cut pipeline stage between drone prefix and cloud cut:
    /// straight to the edge queue.
    FixedEdge,
    /// Everything else: normal scheduler admission.
    Admit,
}

/// One edge base station = mechanism [`Core`] + pluggable [`Scheduler`].
///
/// `S` defaults to `Box<dyn Scheduler>` (what [`Policy::build`] returns);
/// benches compare that against a statically dispatched scheduler by
/// instantiating `Platform<FlagBranchScheduler>` via [`with_scheduler`].
///
/// [`with_scheduler`]: Platform::with_scheduler
pub struct Platform<S: Scheduler = Box<dyn Scheduler>> {
    pub(crate) core: Core,
    sched: S,
}

impl<S: Scheduler> std::ops::Deref for Platform<S> {
    type Target = Core;

    fn deref(&self) -> &Core {
        &self.core
    }
}

impl<S: Scheduler> std::ops::DerefMut for Platform<S> {
    fn deref_mut(&mut self) -> &mut Core {
        &mut self.core
    }
}

impl Platform<Box<dyn Scheduler>> {
    /// Build a platform whose scheduler is resolved from the policy via
    /// [`Policy::build`] (dynamic dispatch). `cloud` accepts a raw
    /// [`CloudExecModel`](crate::exec::CloudExecModel) (wrapped into the
    /// default [`SimpleBackend`](crate::cloud::SimpleBackend)) or any
    /// boxed [`CloudBackend`].
    pub fn new(policy: Policy, models: Vec<ModelProfile>,
               cloud: impl Into<Box<dyn CloudBackend>>, seed: u64) -> Self {
        let sched = policy.build();
        Self::with_scheduler(sched, policy, models, cloud, seed)
    }
}

impl<S: Scheduler> Platform<S> {
    /// Pair an explicit scheduler instance with a fresh core. The policy is
    /// still required: it carries the declarative configuration both the
    /// core mechanisms and the scheduler interpret.
    pub fn with_scheduler(mut sched: S, policy: Policy,
                          models: Vec<ModelProfile>,
                          cloud: impl Into<Box<dyn CloudBackend>>,
                          seed: u64) -> Self {
        let core = Core::new(policy, models, cloud, seed);
        sched.bind(&core);
        Platform { core, sched }
    }

    /// Consume the platform, returning its metrics (end of a run) with
    /// the cloud backend's accounting folded in.
    pub fn into_metrics(self) -> Metrics {
        let mut m = self.core.metrics;
        m.cloud = self.core.cloud.stats();
        if let Some(br) = &self.core.resilience.breaker {
            m.breaker_trips = br.trips;
        }
        m
    }

    /// The scheduler driving this platform.
    pub fn scheduler(&self) -> &S {
        &self.sched
    }

    /// Expected cloud duration for a model in ms (adapted under DEMS-A).
    pub fn expected_cloud_ms(&self, kind: DnnKind) -> f64 {
        self.sched.expected_cloud(&self.core, kind) as f64 / 1_000.0
    }

    /// Deliver buffered task-done reports to the scheduler (GEMS hook).
    fn drain_done(&mut self, now: Micros, q: &mut EventQueue) {
        let mut ctx = SchedCtx { now, core: &mut self.core, q: &mut *q };
        self.sched.drain_done(&mut ctx);
    }

    // --------------------------------------------------------- submission

    /// Entry point: the task-scheduler thread of Fig. 4. Admission is fully
    /// delegated to the scheduler; the platform only does the generation
    /// accounting and kicks the edge executor afterwards.
    ///
    /// Pipeline stages are *routed* first: drone-prefix stages run on the
    /// companion computer, and under a fixed [`PipelineCut`] the stage's
    /// tier is the experiment's control variable — it bypasses scheduler
    /// admission entirely. Plain tasks (and adaptive pipeline stages past
    /// the drone prefix) take the unchanged admission path, which keeps
    /// single-stage runs bit-identical to the pre-pipeline engine.
    pub fn submit_task(&mut self, now: Micros, task: Task,
                       q: &mut EventQueue) {
        self.core.metrics.stats_mut(task.model).generated += 1;
        self.core.emit_trace(now, TraceKind::Generate {
            task: task.id,
            model: task.model,
            drone: task.segment.drone,
        });
        if self.core.metrics.windowed.is_some() {
            let depth =
                self.core.edge_q.len() + self.core.cloud_q.len();
            if let Some(tl) = &mut self.core.metrics.windowed {
                tl.observe_generated(now, depth);
            }
        }
        if self.core.crashed {
            // The station is dark (fault injection): the task is still
            // *generated* — the drone streamed it — but nothing can
            // serve it, so the ledger closes immediately.
            self.core.drop_task(now, task, DropReason::NodeFailure);
            self.drain_done(now, q);
            return;
        }
        match self.route(&task) {
            Route::Drone => {
                self.core.start_drone(now, task, q);
                return;
            }
            Route::FixedCloud => self.enqueue_fixed_cloud(now, task, q),
            Route::FixedEdge => self.enqueue_fixed_edge(now, task),
            Route::Admit => {
                self.core.emit_trace(now,
                                     TraceKind::Admit { task: task.id });
                let mut ctx =
                    SchedCtx { now, core: &mut self.core, q: &mut *q };
                self.sched.admit(&mut ctx, task);
            }
        }
        self.drain_done(now, q);
        self.try_start_edge(now, q);
    }

    /// Tier routing ahead of scheduler admission (pipeline stages only;
    /// plain tasks always take [`Route::Admit`]).
    fn route(&self, task: &Task) -> Route {
        let Some(pr) = &task.pipeline else { return Route::Admit };
        if pr.stage < pr.drone_prefix {
            return Route::Drone;
        }
        if let PipelineCut::Fixed { cloud_start, .. } =
            self.core.policy.pipeline
        {
            if pr.stage >= cloud_start {
                return Route::FixedCloud;
            }
            return Route::FixedEdge;
        }
        Route::Admit
    }

    /// Fixed-cut stage at/past the cloud cut: a *pinned* cloud entry
    /// (never a steal candidate — the cut is the control variable),
    /// triggered immediately. The trigger-time JIT check still applies,
    /// which is exactly how an infeasible fixed cut shows up as QoS loss.
    fn enqueue_fixed_cloud(&mut self, now: Micros, task: Task,
                           q: &mut EventQueue) {
        let (dl, te) = {
            let p = self.core.profile(task.model);
            (task.absolute_deadline(p.deadline), p.t_edge)
        };
        let t_hat = self.sched.expected_cloud(&self.core, task.model);
        self.core.push_cloud(
            now,
            CloudEntry {
                task,
                abs_deadline: dl,
                t_cloud: t_hat,
                t_edge: te,
                trigger: now,
                negative_utility: false,
                gems_rescheduled: false,
                pinned: true,
            },
            q,
        );
    }

    /// Fixed-cut stage on the edge side of the cloud cut: straight into
    /// the edge queue under this edge's priority order, bypassing
    /// admission. The executor's JIT check still guards staleness.
    fn enqueue_fixed_edge(&mut self, now: Micros, task: Task) {
        let (dl, te, hp) = {
            let p = self.core.profile(task.model);
            (task.absolute_deadline(p.deadline), p.t_edge,
             p.hpf_priority())
        };
        self.core.enqueue_edge(now, task, dl, te, hp);
    }

    /// The drone's companion computer finished a pipeline prefix stage:
    /// verdict it against the stage deadline and, on success, hand off
    /// to the successor stage (paying the wireless link if the successor
    /// leaves the drone tier).
    pub fn on_drone_done(&mut self, now: Micros, task: Task,
                         started: Micros, q: &mut EventQueue) {
        let dl = {
            let p = self.core.profile(task.model);
            task.absolute_deadline(p.deadline)
        };
        let success = now <= dl;
        let utility =
            self.core.stage_utility(&task, Resource::Drone, success);
        let fate = if success {
            Fate::Completed(Resource::Drone)
        } else {
            Fate::Missed(Resource::Drone)
        };
        let outcome = TaskOutcome {
            task_id: task.id,
            model: task.model,
            drone: task.segment.drone,
            fate,
            at: now,
            created_at: task.segment.created_at,
            exec_duration: now - started,
            utility,
            gems_rescheduled: false,
            stolen: false,
        };
        self.core.finalize(outcome, task.pipeline.as_ref());
        self.drain_done(now, q);
        if success {
            self.core.spawn_successor(now, &task, Resource::Drone, q);
        }
    }

    // --------------------------------------------------------------- edge

    /// The edge executor's pick-next loop, with the §5.3 steal hook.
    pub fn try_start_edge(&mut self, now: Micros, q: &mut EventQueue) {
        if self.core.running_edge.is_some() || !self.core.policy.use_edge {
            return;
        }
        // Degrade controller: observe pressure where the pick is made, so
        // the variant choice below reflects the queue it has to clear.
        self.core.update_degrade(now);
        loop {
            let steal = {
                let mut ctx = SchedCtx { now, core: &mut self.core, q: &mut *q };
                self.sched.on_edge_idle(&mut ctx)
            };
            if let Some(idx) = steal {
                let entry =
                    self.core.cloud_q.remove_at(idx).into_edge_entry();
                self.core.start_edge(now, entry, true, q);
                return;
            }
            let head = match self.core.edge_q.pop() {
                Some(h) => h,
                None => return,
            };
            // JIT check (§3.3): expected completion must meet the deadline.
            // Edge-only baselines execute regardless (Policy::edge_jit_drop).
            if self.core.policy.edge_jit_drop
                && now + head.t_edge > head.abs_deadline
            {
                self.core.drop_task(now, head.task, DropReason::JitExpired);
                self.drain_done(now, q);
                continue;
            }
            self.core.start_edge(now, head, false, q);
            return;
        }
    }

    pub fn on_edge_done(&mut self, now: Micros, q: &mut EventQueue) {
        let run = match self.core.running_edge.take() {
            Some(r) => r,
            None => return,
        };
        let success = run.actual_end <= run.entry.abs_deadline;
        let mut utility = self.core.stage_utility(&run.entry.task,
                                                  Resource::Edge, success);
        if run.degraded {
            // Lite-variant accounting: the accuracy trade shows up as a
            // utility discount on success (a degraded miss already earns
            // the miss penalty; don't deepen it).
            self.core.metrics.degraded_tasks += 1;
            if success && utility > 0.0 {
                let d = lite_variant(run.entry.task.model).utility_discount;
                let discounted = utility * d;
                self.core.metrics.degraded_utility_lost +=
                    utility - discounted;
                utility = discounted;
            }
        }
        let fate = if success {
            Fate::Completed(Resource::Edge)
        } else {
            Fate::Missed(Resource::Edge)
        };
        let outcome = TaskOutcome {
            task_id: run.entry.task.id,
            model: run.entry.task.model,
            drone: run.entry.task.segment.drone,
            fate,
            at: now,
            created_at: run.entry.task.segment.created_at,
            exec_duration: run.actual_end
                - (run.expected_end - run.entry.t_edge),
            utility,
            gems_rescheduled: run.entry.gems_rescheduled,
            stolen: run.stolen,
        };
        self.core.finalize(outcome, run.entry.task.pipeline.as_ref());
        self.drain_done(now, q);
        if success {
            self.core.spawn_successor(now, &run.entry.task,
                                      Resource::Edge, q);
        }
        self.try_start_edge(now, q);
    }

    // -------------------------------------------------------------- cloud

    /// Trigger-time arrival: dispatch due entries to the FaaS pool (§5.3).
    pub fn on_cloud_trigger(&mut self, now: Micros, q: &mut EventQueue) {
        while let Some(e) = self.core.cloud_q.pop_due(now) {
            if e.negative_utility && !self.core.policy.cloud_accepts_negative
            {
                // Un-stolen steal candidate: drop just-in-time.
                self.core.drop_task(now, e.task, DropReason::TriggerExpired);
                self.drain_done(now, q);
                continue;
            }
            let t_hat =
                self.sched.expected_cloud(&self.core, e.task.model);
            if now + t_hat > e.abs_deadline {
                self.sched.on_cloud_skip(&self.core, now, e.task.model);
                self.core.drop_task(now, e.task, DropReason::JitExpired);
                self.drain_done(now, q);
                continue;
            }
            if self.core.cloud_inflight < self.core.cloud_pool {
                if let Some((e, retry)) = self.core.dispatch_cloud(now, e, q)
                {
                    self.on_cloud_throttled(now, e, retry, q);
                }
            } else {
                self.core.cloud_ready.push_back(e);
            }
        }
    }

    /// The backend throttled a dispatch (per-account concurrency
    /// ceiling). The attempt is reported through `on_cloud_report` as an
    /// unsuccessful observation whose effective duration is the backoff
    /// plus the current expectation — so DEMS-A's sliding window sees
    /// throttling as cloud slowdown and adapts — then retried at
    /// `now + retry_after` when the deadline still allows, else dropped.
    fn on_cloud_throttled(&mut self, now: Micros, mut e: CloudEntry,
                          retry_after: Micros, q: &mut EventQueue) {
        let t_hat = self.sched.expected_cloud(&self.core, e.task.model);
        let report = CloudReport {
            kind: e.task.model,
            duration: retry_after + t_hat,
            timed_out: false,
            success: false,
            throttled: true,
        };
        {
            let mut ctx = SchedCtx { now, core: &mut self.core, q: &mut *q };
            self.sched.on_cloud_report(&mut ctx, &report);
        }
        self.core.metrics.stats_mut(e.task.model).throttled += 1;
        let retry_at = now + retry_after.max(1);
        // Re-check feasibility with the (possibly re-adapted) t̂.
        let t_hat = self.sched.expected_cloud(&self.core, e.task.model);
        if retry_at + t_hat <= e.abs_deadline {
            e.trigger = retry_at;
            self.core.push_cloud(now, e, q);
        } else {
            self.sched.on_cloud_skip(&self.core, now, e.task.model);
            self.core.drop_task(now, e.task, DropReason::Throttled);
            self.drain_done(now, q);
        }
    }

    pub fn on_cloud_done(&mut self, now: Micros, key: u64,
                         q: &mut EventQueue) {
        let run = match self.core.cloud_running.remove(&key) {
            Some(r) => r,
            None => return,
        };
        self.core.cloud_inflight -= 1;
        // Release the backend's concurrency slot / warm container.
        self.core.cloud.complete(run.entry.task.model, run.token, now);
        // Breaker feed: a timeout is the backend-health failure signal (a
        // deadline miss is a scheduling verdict, not backend health).
        // Probe outcomes close or re-open a half-open breaker.
        let mut tripped = false;
        if let Some(br) = &mut self.core.resilience.breaker {
            let before = br.trips;
            br.record(now, run.timed_out, run.probe);
            tripped = br.trips > before;
        }
        if tripped {
            self.core.emit_trace(now, TraceKind::BreakerTrip);
        }
        // Hedged-pair resolution (links are only ever set by
        // `on_hedge_fire`, so this whole block is inert when hedging is
        // off). First usable completion wins; exactly one leg of a pair
        // ever finalizes the task.
        let partner_alive = run
            .hedge_pair
            .filter(|pk| self.core.cloud_running.contains_key(pk));
        if let Some(pk) = partner_alive {
            if run.timed_out {
                // This leg is useless but its partner is still racing:
                // abandon it silently (backend slot released above, no
                // finalization) and promote the partner to sole owner of
                // the task's ledger.
                let mut promoted = false;
                if let Some(p) = self.core.cloud_running.get_mut(&pk) {
                    p.hedge_pair = None;
                    if p.is_hedge {
                        p.is_hedge = false;
                        self.core.metrics.hedge_wins += 1;
                        promoted = true;
                    }
                }
                if promoted {
                    self.core.emit_trace(now, TraceKind::HedgeWin {
                        task: run.entry.task.id,
                    });
                }
                self.pull_cloud_ready(now, q);
                return;
            }
            // Usable result: cancel the in-flight loser. FaaS semantics —
            // the backend bills the cancelled invocation in full; only
            // the slot/container bookkeeping is released.
            if let Some(loser) = self.core.cloud_running.remove(&pk) {
                self.core.cloud_inflight -= 1;
                self.core.cloud.cancel(loser.entry.task.model, loser.token,
                                       now);
                self.core.metrics.hedge_cancels += 1;
                self.core.emit_trace(now, TraceKind::HedgeCancel {
                    task: run.entry.task.id,
                });
            }
            if run.is_hedge {
                self.core.metrics.hedge_wins += 1;
                self.core.emit_trace(now, TraceKind::HedgeWin {
                    task: run.entry.task.id,
                });
            }
        }
        let success = !run.timed_out && run.end <= run.entry.abs_deadline;
        // §5.4 observation hook fires before verdicting so adapted
        // expectations (and the timeline's expected_ms) include this sample.
        let report = CloudReport {
            kind: run.entry.task.model,
            duration: run.duration,
            timed_out: run.timed_out,
            success,
            throttled: false,
        };
        {
            let mut ctx = SchedCtx { now, core: &mut self.core, q: &mut *q };
            self.sched.on_cloud_report(&mut ctx, &report);
        }
        if run.timed_out {
            // Abandoned request: no usable output, not billed as a miss.
            let outcome = TaskOutcome {
                task_id: run.entry.task.id,
                model: run.entry.task.model,
                drone: run.entry.task.segment.drone,
                fate: Fate::Dropped(DropReason::Timeout),
                at: now,
                created_at: run.entry.task.segment.created_at,
                exec_duration: run.duration,
                utility: 0.0,
                gems_rescheduled: run.entry.gems_rescheduled,
                stolen: false,
            };
            self.core.finalize(outcome, run.entry.task.pipeline.as_ref());
            self.drain_done(now, q);
            self.pull_cloud_ready(now, q);
            return;
        }
        if self.core.metrics.record_timeline {
            let expected_ms = self
                .sched
                .expected_cloud(&self.core, run.entry.task.model)
                as f64
                / 1_000.0;
            self.core.metrics.timeline.push(TimelinePoint {
                at: now,
                model: run.entry.task.model,
                observed_ms: run.duration as f64 / 1_000.0,
                expected_ms,
                success,
            });
        }
        let fate = if success {
            Fate::Completed(Resource::Cloud)
        } else {
            Fate::Missed(Resource::Cloud)
        };
        let utility = self.core.stage_utility(&run.entry.task,
                                              Resource::Cloud, success);
        let outcome = TaskOutcome {
            task_id: run.entry.task.id,
            model: run.entry.task.model,
            drone: run.entry.task.segment.drone,
            fate,
            at: now,
            created_at: run.entry.task.segment.created_at,
            exec_duration: run.duration,
            utility,
            gems_rescheduled: run.entry.gems_rescheduled,
            stolen: false,
        };
        self.core.finalize(outcome, run.entry.task.pipeline.as_ref());
        self.drain_done(now, q);
        if success {
            self.core.spawn_successor(now, &run.entry.task,
                                      Resource::Cloud, q);
        }
        self.pull_cloud_ready(now, q);
    }

    /// The hedge timer for in-flight invocation `key` elapsed: if the
    /// primary is still running — which, with the timer set past the
    /// median duration, means it landed in the latency tail — launch a
    /// speculative duplicate on the backend and link the pair. The
    /// duplicate is strictly opportunistic: no free pool slot, an open
    /// breaker or a backend throttle simply forfeits the hedge (the
    /// primary is unaffected).
    pub fn on_hedge_fire(&mut self, now: Micros, key: u64,
                         q: &mut EventQueue) {
        if self.core.resilience.hedge.is_none() {
            return;
        }
        if self.core.cloud_inflight >= self.core.cloud_pool {
            return;
        }
        if self
            .core
            .resilience
            .breaker
            .as_ref()
            .is_some_and(|b| b.is_open(now))
        {
            return;
        }
        let (task, abs_deadline, t_cloud, t_edge, gems, pinned,
             primary_start) = {
            let Some(run) = self.core.cloud_running.get(&key) else {
                return; // primary already done — nothing left to hedge
            };
            if run.is_hedge || run.hedge_pair.is_some() || run.probe {
                return;
            }
            (
                run.entry.task.clone(),
                run.entry.abs_deadline,
                run.entry.t_cloud,
                run.entry.t_edge,
                run.entry.gems_rescheduled,
                run.entry.pinned,
                run.end - run.duration,
            )
        };
        // The duplicate draws its own invocation (cold-start, jitter and
        // billing are per-invocation). A throttle here is NOT fed to the
        // breaker or the scheduler — hedges are extra load, and their
        // refusal must not poison the primary path's health signals.
        let i = self.core.idx(task.model);
        let inv = match self.core.cloud.invoke(
            &self.core.models[i],
            now,
            task.payload_bytes(),
            self.core.cloud_inflight,
            &mut self.core.rng,
        ) {
            Attempt::Run(inv) => inv,
            Attempt::Throttle { .. } => return,
        };
        let mut duration = inv.duration;
        if let Some(up) = &self.core.uplink {
            let wait = up
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .acquire(now, task.payload_bytes());
            if wait > 0 {
                self.core.metrics.uplink_wait += wait;
                self.core.metrics.uplink_queued += 1;
                if let Some(tl) = &mut self.core.metrics.windowed {
                    tl.observe_uplink_wait(now, wait);
                }
                duration += wait;
            }
        }
        let hedged_task = task.id;
        self.core.next_cloud_key += 1;
        let dup_key = self.core.next_cloud_key;
        // The duplicate's ledger duration spans from the *primary's*
        // launch, so exec-duration percentiles report task-level cloud
        // latency — min(primary, delay + duplicate), the quantity
        // hedging squeezes.
        let offset = now - primary_start;
        self.core.cloud_running.insert(
            dup_key,
            CloudRunning {
                entry: CloudEntry {
                    task,
                    abs_deadline,
                    t_cloud,
                    t_edge,
                    trigger: now,
                    negative_utility: false,
                    gems_rescheduled: gems,
                    pinned,
                },
                end: now + duration,
                duration: offset + duration,
                timed_out: inv.timed_out,
                token: inv.token,
                probe: false,
                is_hedge: true,
                hedge_pair: Some(key),
            },
        );
        self.core.cloud_inflight += 1;
        if let Some(primary) = self.core.cloud_running.get_mut(&key) {
            primary.hedge_pair = Some(dup_key);
        }
        self.core.metrics.hedge_launches += 1;
        self.core
            .emit_trace(now, TraceKind::HedgeFire { task: hedged_task });
        q.push(now + duration, Event::CloudDone { key: dup_key });
    }

    /// A pool slot freed: pull the next ready entry (re-JIT-checked).
    fn pull_cloud_ready(&mut self, now: Micros, q: &mut EventQueue) {
        while let Some(e) = self.core.cloud_ready.pop_front() {
            let t_hat =
                self.sched.expected_cloud(&self.core, e.task.model);
            if now + t_hat > e.abs_deadline {
                self.core.drop_task(now, e.task, DropReason::JitExpired);
                self.drain_done(now, q);
                continue;
            }
            if let Some((e, retry)) = self.core.dispatch_cloud(now, e, q) {
                // Account ceiling hit: any further ready entry would
                // throttle too; this one retries via its trigger event.
                self.on_cloud_throttled(now, e, retry, q);
            }
            break;
        }
    }

    // --------------------------------------------------------------- QoE

    /// Tumbling window boundary (Alg. 1 lines 16–21), then the scheduler's
    /// window hook.
    pub fn on_window_close(&mut self, now: Micros, model_idx: usize,
                           q: &mut EventQueue) {
        self.core.window_close(model_idx, q);
        let mut ctx = SchedCtx { now, core: &mut self.core, q: &mut *q };
        self.sched.on_window_close(&mut ctx, model_idx);
    }

    // --------------------------------------------------------- federation

    /// Fleet federation: a task stolen from a sibling edge arrives after
    /// its LAN transfer. It is JIT-checked against *this* edge's profile
    /// (hetero stations run their own t table); accepted tasks join the
    /// edge queue under this edge's priority order and start immediately
    /// when the executor is idle. Generation stays accounted at the
    /// origin edge — only the execution outcome lands here, so
    /// conservation holds cluster-wide (not per edge), which is exactly
    /// what the invariant harness asserts.
    pub fn accept_federated(&mut self, now: Micros, task: Task,
                            q: &mut EventQueue) {
        self.core.metrics.fed_steals_in += 1;
        self.core
            .emit_trace(now, TraceKind::FedArrive { task: task.id });
        let (dl, te, hp) = {
            let p = self.core.profile(task.model);
            (task.absolute_deadline(p.deadline), p.t_edge,
             p.hpf_priority())
        };
        if now + te > dl {
            // The transfer ate the remaining headroom (the steal-time
            // feasibility screen makes this rare).
            self.core.drop_task(now, task, DropReason::JitExpired);
            self.drain_done(now, q);
            return;
        }
        self.core.enqueue_edge(now, task, dl, te, hp);
        self.try_start_edge(now, q);
    }

    /// Fleet federation: hand the cloud-queue entry at `idx` to a sibling
    /// edge (the federation coordinator picked it via the κ/κ̂ steal
    /// rank). The stale trigger event it leaves behind is harmless — the
    /// trigger handler pops by due time, exactly as local §5.3 steals
    /// always have.
    pub(crate) fn take_fed_offer(&mut self, now: Micros, idx: usize)
                                 -> crate::queues::CloudEntry {
        self.core.metrics.fed_steals_out += 1;
        let entry = self.core.cloud_q.remove_at(idx);
        self.core.emit_trace(now, TraceKind::StealDepart {
            task: entry.task.id,
        });
        entry
    }

    /// Fleet federation: a stolen task was still in LAN transfer when the
    /// run drained — close its accounting at the destination edge.
    pub fn drop_in_transit(&mut self, now: Micros, task: Task,
                           q: &mut EventQueue) {
        self.core.drop_task(now, task, DropReason::JitExpired);
        self.drain_done(now, q);
    }

    // -------------------------------------------------------------- fault

    /// Fault injection: this edge dies at `now`. Every holder of work
    /// decides its fate (the conservation contract — nothing is silently
    /// lost):
    ///
    /// * the edge executor's running task and in-flight cloud
    ///   invocations are lost outright (`DropReason::NodeFailure`; the
    ///   backend still gets its `complete` so warm pools / concurrency
    ///   slots don't leak, and the stale `EdgeDone`/`CloudDone` events
    ///   become no-ops);
    /// * queued work (edge queue, un-pinned cloud-queue entries, the
    ///   triggered ready line) is *returned* for relocation when
    ///   `relocate` is set ([`Recovery::Requeue`]
    ///   semantics — the cluster pushes survivors through the federation
    ///   steal path), otherwise lost;
    /// * pinned fixed-cloud pipeline stages are always lost — the cut
    ///   bound them to this station's cloud path.
    ///
    /// Until [`recover`](Self::recover), `submit_task` closes any new
    /// arrival as a `NodeFailure` drop.
    ///
    /// [`Recovery::Requeue`]: crate::fault::Recovery::Requeue
    pub fn crash(&mut self, now: Micros, relocate: bool,
                 q: &mut EventQueue) -> Vec<(Task, Micros, Micros)> {
        self.core.crashed = true;
        self.core.metrics.crashes += 1;
        self.core.emit_trace(now, TraceKind::Crash);
        if let Some(run) = self.core.running_edge.take() {
            self.core.drop_task(now, run.entry.task,
                                DropReason::NodeFailure);
            self.drain_done(now, q);
        }
        let mut keys: Vec<u64> =
            self.core.cloud_running.keys().copied().collect();
        keys.sort_unstable(); // HashMap order must not leak into the run
        for k in keys {
            if let Some(run) = self.core.cloud_running.remove(&k) {
                self.core.cloud.complete(run.entry.task.model, run.token,
                                         now);
                if run.is_hedge {
                    // The primary leg of the hedged pair (also swept
                    // here) owns the task's ledger: closing both would
                    // double-finalize.
                    continue;
                }
                self.core.drop_task(now, run.entry.task,
                                    DropReason::NodeFailure);
                self.drain_done(now, q);
            }
        }
        self.core.cloud_inflight = 0;
        let mut out = Vec::new();
        while let Some(e) = self.core.edge_q.pop() {
            if relocate {
                out.push((e.task, e.abs_deadline, e.t_edge));
            } else {
                self.core.drop_task(now, e.task, DropReason::NodeFailure);
                self.drain_done(now, q);
            }
        }
        while !self.core.cloud_q.is_empty() {
            let e = self.core.cloud_q.remove_at(0);
            if relocate && !e.pinned {
                out.push((e.task, e.abs_deadline, e.t_edge));
            } else {
                self.core.drop_task(now, e.task, DropReason::NodeFailure);
                self.drain_done(now, q);
            }
        }
        while let Some(e) = self.core.cloud_ready.pop_front() {
            if relocate && !e.pinned {
                out.push((e.task, e.abs_deadline, e.t_edge));
            } else {
                self.core.drop_task(now, e.task, DropReason::NodeFailure);
                self.drain_done(now, q);
            }
        }
        out
    }

    /// Fault injection: the station reboots — queues are already empty
    /// (swept at crash), so it simply starts accepting work again.
    pub fn recover(&mut self, now: Micros) {
        self.core.crashed = false;
        self.core.metrics.recoveries += 1;
        self.core.emit_trace(now, TraceKind::Recover);
    }

    /// Fault injection: a task was bound for this edge (a federated
    /// steal or crash relocation in LAN transit, a pipeline stage
    /// handoff) when the station died — close its ledger here, exactly
    /// once, as a node failure.
    pub fn drop_failed(&mut self, now: Micros, task: Task,
                       q: &mut EventQueue) {
        self.core.drop_task(now, task, DropReason::NodeFailure);
        self.drain_done(now, q);
    }

    // --------------------------------------------------------------- end

    /// Drain bookkeeping at end of run (drops queued tasks as infeasible so
    /// task accounting closes; the paper's runs likewise count unfinished
    /// tasks as not completed).
    pub fn drain(&mut self, now: Micros, q: &mut EventQueue) {
        if let Some(run) = self.core.running_edge.take() {
            self.core.drop_task(now, run.entry.task, DropReason::JitExpired);
            self.drain_done(now, q);
        }
        let mut keys: Vec<u64> =
            self.core.cloud_running.keys().copied().collect();
        keys.sort_unstable(); // HashMap order must not leak into the run
        for k in keys {
            if let Some(run) = self.core.cloud_running.remove(&k) {
                self.core.cloud.complete(run.entry.task.model, run.token,
                                         now);
                if run.is_hedge {
                    // Hedge leg of a pair: its primary (also swept here)
                    // closes the task's ledger exactly once.
                    continue;
                }
                self.core.drop_task(now, run.entry.task, DropReason::Timeout);
                self.drain_done(now, q);
            }
        }
        while let Some(e) = self.core.edge_q.pop() {
            self.core.drop_task(now, e.task, DropReason::JitExpired);
            self.drain_done(now, q);
        }
        while !self.core.cloud_q.is_empty() {
            let e = self.core.cloud_q.remove_at(0);
            self.core.drop_task(now, e.task, DropReason::TriggerExpired);
            self.drain_done(now, q);
        }
        while let Some(e) = self.core.cloud_ready.pop_front() {
            self.core.drop_task(now, e.task, DropReason::JitExpired);
            self.drain_done(now, q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{CloudExecModel, EdgeExecModel};
    use crate::model::table1;
    use crate::net::ConstantNet;
    use crate::task::VideoSegment;
    use crate::time::ms;

    fn mkplatform(policy: Policy) -> Platform {
        let mut cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        // Deterministic cloud for scenario tests: no cold starts.
        cloud.cold_start = 0;
        cloud.cold_prob = 0.0;
        let mut p = Platform::new(policy, table1(), cloud, 7);
        // Deterministic edge service times for scenario tests.
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        p
    }

    fn mktask(p: &mut Platform, kind: DnnKind, created: Micros) -> Task {
        let id = p.fresh_task_id();
        Task {
            id,
            model: kind,
            segment: VideoSegment {
                id,
                drone: 0,
                created_at: created,
                bytes: 38_000,
            },
            pipeline: None,
        }
    }

    /// Drain all events up to (and including) time `until`.
    fn settle(p: &mut Platform, q: &mut EventQueue, until: Micros) {
        while let Some((t, ev)) = q.pop() {
            if t > until {
                // Push back and stop (EventQueue has no peek).
                q.push(t, ev);
                break;
            }
            match ev {
                Event::EdgeDone => p.on_edge_done(t, q),
                Event::CloudTrigger => p.on_cloud_trigger(t, q),
                Event::CloudDone { key } => p.on_cloud_done(t, key, q),
                Event::WindowClose { model_idx } => {
                    p.on_window_close(t, model_idx, q)
                }
                Event::StageArrive { task } => {
                    let task = q.take_task(task);
                    p.submit_task(t, task, q)
                }
                Event::DroneDone { task, started } => {
                    let task = q.take_task(task);
                    p.on_drone_done(t, task, started, q)
                }
                Event::HedgeFire { key } => p.on_hedge_fire(t, key, q),
                // Segment / federation events: cluster-driver concerns.
                _ => {}
            }
        }
    }

    #[test]
    fn single_task_completes_on_edge() {
        let mut p = mkplatform(Policy::dems());
        let mut q = EventQueue::new();
        let t = mktask(&mut p, DnnKind::Hv, 0);
        p.submit_task(0, t, &mut q);
        settle(&mut p, &mut q, ms(1_000));
        assert_eq!(p.metrics.completed(), 1);
        assert_eq!(p.metrics.completed_on(Resource::Edge), 1);
        assert_eq!(p.metrics.qos_utility(), 124.0);
    }

    #[test]
    fn infeasible_edge_task_offloads_and_completes_on_cloud() {
        let mut p = mkplatform(Policy::edf_ec());
        let mut q = EventQueue::new();
        // Saturate the edge with DEO (739 ms each), then submit HV whose
        // 650 ms deadline cannot be met behind them.
        for _ in 0..2 {
            let t = mktask(&mut p, DnnKind::Deo, 0);
            p.submit_task(0, t, &mut q);
        }
        let hv = mktask(&mut p, DnnKind::Hv, 0);
        p.submit_task(0, hv, &mut q);
        settle(&mut p, &mut q, ms(3_000));
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.completed_cloud, 1, "HV should offload: {s:?}");
    }

    #[test]
    fn fig5_scenario2_migrates_lower_score_victim() {
        // DEO occupies the queue rear; an incoming HV (earlier deadline)
        // starves it. DEO is cloud-feasible (score γᴱ−γᶜ = 204) vs HV
        // incoming score 24 → HV itself is redirected (scenario 3 shape).
        // Conversely a BP victim (score 38) loses to an incoming DEO
        // (score 204) and gets migrated (scenario 2 shape).
        let mut p = mkplatform(Policy::dems());
        let mut q = EventQueue::new();
        // Edge busy: one BP at the head (deadline 900, t 244), queue holds
        // another BP.
        let b1 = mktask(&mut p, DnnKind::Bp, 0);
        p.submit_task(0, b1, &mut q); // starts executing
        let b2 = mktask(&mut p, DnnKind::Bp, 0);
        p.submit_task(0, b2, &mut q); // queued
        // Incoming DEO with deadline 950 and t 739: probing places it
        // after BP (deadline 950 > 900) — no victims... instead craft the
        // starvation with CD (deadline 1000, t 563):
        let cd = mktask(&mut p, DnnKind::Cd, 0);
        p.submit_task(0, cd, &mut q); // rear: completes 244+244+563 = 1051 > 1000? → offloaded itself
        // Now a DEO arriving with an earlier deadline (950) would insert
        // before CD; validate by metrics after settling instead of queue
        // internals: everything must be accounted for.
        let deo = mktask(&mut p, DnnKind::Deo, 0);
        p.submit_task(0, deo, &mut q);
        settle(&mut p, &mut q, ms(5_000));
        let m = &p.metrics;
        let total: u64 = m.per_model.iter().map(|(_, s)| s.generated).sum();
        let closed: u64 = m
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(total, closed, "accounting closes under migration");
        // At least one task must have been pushed to the cloud path.
        assert!(
            m.completed_on(Resource::Cloud) > 0
                || m.per_model.iter().any(|(_, s)| s.dropped() > 0)
        );
    }

    #[test]
    fn fig6_negative_utility_bp_is_stolen_by_idle_edge() {
        let mut p = mkplatform(Policy::dems());
        let mut q = EventQueue::new();
        // Saturate the edge so BP is rejected there (its own deadline
        // cannot be met), sending it to the cloud queue as a negative-
        // utility steal candidate.
        for _ in 0..3 {
            let t = mktask(&mut p, DnnKind::Deo, 0);
            p.submit_task(0, t, &mut q);
        }
        let bp = mktask(&mut p, DnnKind::Bp, 0);
        p.submit_task(0, bp, &mut q);
        assert!(p.cloud_queue_len() > 0, "BP parked in the cloud queue");
        settle(&mut p, &mut q, ms(10_000));
        let s = p.metrics.stats(DnnKind::Bp);
        // Either stolen back to the edge (preferred) or trigger-expired;
        // DEMS must never execute it on the cloud.
        assert_eq!(s.completed_cloud, 0);
        assert_eq!(s.missed_cloud, 0);
    }

    #[test]
    fn cloud_pool_limits_inflight() {
        let mut p = mkplatform(Policy::cloud_only());
        p.cloud_pool = 2;
        let mut q = EventQueue::new();
        for _ in 0..8 {
            let t = mktask(&mut p, DnnKind::Hv, 0);
            p.submit_task(0, t, &mut q);
        }
        // Fire the triggers (CLD dispatches immediately → trigger at 0).
        p.on_cloud_trigger(0, &mut q);
        assert!(p.cloud_inflight() <= 2);
        settle(&mut p, &mut q, ms(20_000));
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.generated, s.executed() + s.dropped());
    }

    #[test]
    fn gems_reschedules_pending_edge_tasks_on_slip() {
        use crate::model::{table2, GemsWorkload};
        let cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        let mut p =
            Platform::new(Policy::gems(false), table2(GemsWorkload::Wl1, 0.9),
                          cloud, 7);
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        let mut q = EventQueue::new();
        // Queue several DEV tasks, then force a completion-rate slip by
        // dropping one (finalize path) — the monitor should move pending
        // DEV tasks to the cloud queue.
        for _ in 0..3 {
            let t = mktask(&mut p, DnnKind::Dev, 0);
            p.submit_task(0, t, &mut q);
        }
        let before_cloud = p.cloud_queue_len();
        // A missed DEV (deadline in the past ⇒ JIT drop at the executor)
        let stale = mktask(&mut p, DnnKind::Dev, 0);
        // Manufacture a failure via the public API: submit with an
        // already-hopeless deadline by advancing `now` far beyond it.
        p.submit_task(ms(10_000), stale, &mut q);
        assert!(
            p.cloud_queue_len() > before_cloud
                || p.metrics.gems_rescheduled() > 0
                || p.metrics.stats(DnnKind::Dev).dropped() > 0,
            "GEMS should react to the slip"
        );
    }

    #[test]
    fn sota1_extends_non_urgent_deadlines() {
        let mut p = mkplatform(Policy::sota1());
        let mut q = EventQueue::new();
        // CD (δ=1000 ≥ 750 ⇒ non-urgent) behind enough work that plain
        // feasibility fails but a 10% stretch passes.
        let a = mktask(&mut p, DnnKind::Cd, 0);
        p.submit_task(0, a, &mut q);
        let b = mktask(&mut p, DnnKind::Md, 0);
        p.submit_task(0, b, &mut q);
        settle(&mut p, &mut q, ms(5_000));
        let m = &p.metrics;
        let total: u64 = m.per_model.iter().map(|(_, s)| s.generated).sum();
        let closed: u64 = m
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(total, closed);
    }

    #[test]
    fn edge_only_has_no_cloud_activity() {
        let mut p = mkplatform(Policy::edge_edf());
        let mut q = EventQueue::new();
        for kind in DnnKind::ALL {
            let t = mktask(&mut p, kind, 0);
            p.submit_task(0, t, &mut q);
        }
        settle(&mut p, &mut q, ms(20_000));
        assert_eq!(p.metrics.completed_on(Resource::Cloud), 0);
        assert_eq!(p.cloud_queue_len(), 0);
    }

    /// Deterministic FaaS backend: sigma-0 compute, no cold jitter, tiny
    /// concurrency ceiling.
    fn faas_platform(policy: Policy, concurrency: usize) -> Platform {
        use crate::cloud::{FaasBackend, FaasConfig};
        let be = FaasBackend::new(
            FaasConfig {
                concurrency,
                sigma: 0.0,
                cold_start: 0,
                ..FaasConfig::default()
            },
            Box::new(ConstantNet { latency: ms(40), bandwidth: 25.0e6 }),
        );
        let mut p = Platform::new(policy, table1(),
                                  Box::new(be) as Box<dyn CloudBackend>, 7);
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        p
    }

    #[test]
    fn faas_throttle_retries_then_drops_and_counts() {
        // CLD with a 1-slot account: the first HV runs, later dispatches
        // are throttled, retried on the 200 ms backoff while the deadline
        // allows, and finally dropped as Throttled.
        let mut p = faas_platform(Policy::cloud_only(), 1);
        let mut q = EventQueue::new();
        for _ in 0..4 {
            let t = mktask(&mut p, DnnKind::Hv, 0);
            p.submit_task(0, t, &mut q);
        }
        settle(&mut p, &mut q, ms(20_000));
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.generated, 4);
        assert!(p.metrics.throttled() >= 2,
                "throttles observed: {}", p.metrics.throttled());
        assert!(s.dropped_throttled >= 1,
                "deadline-exhausted retries drop: {s:?}");
        assert_eq!(s.generated, s.executed() + s.dropped(),
                   "accounting closes under throttling");
        let cs = p.cloud_stats();
        assert!(cs.throttles >= 2);
        assert!(cs.dollars > 0.0, "admitted invocations bill");
        assert_eq!(p.cloud_backend_name(), "faas");
    }

    #[test]
    fn faas_throttle_reports_raise_dems_a_expectations() {
        // DEMS-A folds throttle reports (backoff + expectation) into its
        // §5.4 window: after one throttled DEO dispatch the expected
        // cloud duration rises above the static 832 ms, so later DEOs
        // are refused for the cloud instead of burning retries.
        let mut p = faas_platform(Policy::dems_a(), 1);
        let mut q = EventQueue::new();
        for _ in 0..4 {
            let t = mktask(&mut p, DnnKind::Deo, 0);
            p.submit_task(0, t, &mut q);
        }
        settle(&mut p, &mut q, ms(20_000));
        assert!(p.metrics.throttled() >= 1, "a dispatch was throttled");
        assert!(
            p.expected_cloud_ms(DnnKind::Deo) > 832.0,
            "throttle must inflate the adapted expectation: {}",
            p.expected_cloud_ms(DnnKind::Deo)
        );
        let total: u64 =
            p.metrics.per_model.iter().map(|(_, s)| s.generated).sum();
        let closed: u64 = p
            .metrics
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(total, closed);
    }

    #[test]
    fn simple_backend_metrics_cloud_accounting_is_zero_cost() {
        let mut p = mkplatform(Policy::cloud_only());
        let mut q = EventQueue::new();
        let t = mktask(&mut p, DnnKind::Hv, 0);
        p.submit_task(0, t, &mut q);
        settle(&mut p, &mut q, ms(5_000));
        assert_eq!(p.cloud_backend_name(), "simple");
        let m = p.into_metrics();
        assert_eq!(m.cloud.invocations, 1);
        assert_eq!(m.cloud.dollars, 0.0);
        assert_eq!(m.cloud.throttles, 0);
        assert_eq!(m.throttled(), 0);
    }

    #[test]
    fn expected_cloud_uses_adaptation_only_when_enabled() {
        let p = mkplatform(Policy::dems());
        assert_eq!(p.expected_cloud_ms(DnnKind::Hv), 398.0);
        let pa = mkplatform(Policy::dems_a());
        assert_eq!(pa.expected_cloud_ms(DnnKind::Hv), 398.0);
    }

    #[test]
    fn scheduler_families_resolve_from_policy() {
        for (policy, family) in [
            (Policy::edge_edf(), "edge-only"),
            (Policy::edge_hpf(), "edge-only"),
            (Policy::cloud_only(), "cloud-only"),
            (Policy::edf_ec(), "e+c"),
            (Policy::sjf_ec(), "e+c"),
            (Policy::dem(), "dems"),
            (Policy::dems(), "dems"),
            (Policy::dems_a(), "dems"),
            (Policy::gems(false), "gems"),
            (Policy::sota1(), "sota1"),
            (Policy::sota2(), "sota2"),
        ] {
            let p = mkplatform(policy.clone());
            assert_eq!(
                p.scheduler().family(),
                family,
                "family for {}",
                policy.kind.name()
            );
        }
    }

    // ----------------------------------------------- resilience mechanics

    use crate::resilience::ResilienceSpec;
    use crate::time::secs;

    #[test]
    fn breaker_trips_on_timeouts_and_short_circuits_dispatch() {
        // Every invocation times out → the breaker trips after
        // min_samples failures; later dispatches are refused before the
        // backend is touched and re-plan through the throttle path.
        let mut cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        cloud.cold_start = 0;
        cloud.cold_prob = 0.0;
        cloud.timeout = ms(1);
        let spec = ResilienceSpec {
            breaker_window: 4,
            breaker_min_samples: 2,
            breaker_cooldown: secs(600),
            ..ResilienceSpec::breaker_only()
        };
        let mut p = Platform::new(
            Policy::cloud_only().with_resilience(spec),
            table1(),
            cloud,
            7,
        );
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        let mut q = EventQueue::new();
        for i in 0..8u64 {
            settle(&mut p, &mut q, i * ms(500));
            let t = mktask(&mut p, DnnKind::Hv, i * ms(500));
            p.submit_task(i * ms(500), t, &mut q);
        }
        settle(&mut p, &mut q, secs(120));
        let br = p.core.resilience.breaker.as_ref().unwrap();
        assert!(br.trips >= 1, "timeouts must trip the breaker");
        assert!(p.metrics.breaker_shorted >= 1,
                "post-trip dispatches short-circuit: {}",
                p.metrics.breaker_shorted);
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.generated, 8);
        assert_eq!(s.generated, s.executed() + s.dropped(),
                   "accounting closes under breaking: {s:?}");
        assert!(s.dropped_throttled >= 1,
                "short-circuited CLD tasks exhaust their deadline: {s:?}");
        let m = p.into_metrics();
        assert!(m.breaker_trips >= 1, "trips fold into metrics");
    }

    #[test]
    fn hedged_requests_conserve_and_first_usable_completion_wins() {
        let spec = ResilienceSpec {
            hedge_slack: 0,
            hedge_delay: ms(1),
            ..ResilienceSpec::hedge_only()
        };
        let mut p = mkplatform(Policy::cloud_only().with_resilience(spec));
        p.metrics.record_completions = true;
        let mut q = EventQueue::new();
        for i in 0..6u64 {
            settle(&mut p, &mut q, i * ms(100));
            let t = mktask(&mut p, DnnKind::Hv, i * ms(100));
            p.submit_task(i * ms(100), t, &mut q);
        }
        settle(&mut p, &mut q, secs(120));
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.generated, 6);
        assert_eq!(s.generated, s.executed() + s.dropped(),
                   "exactly one finalization per hedged task: {s:?}");
        assert_eq!(p.metrics.completions.len(), 6,
                   "one completion record per task, duplicates invisible");
        assert!(p.metrics.hedge_launches >= 1,
                "1 ms delay must arm and fire hedges");
        assert_eq!(p.metrics.hedge_cancels, p.metrics.hedge_launches,
                   "every race has exactly one cancelled loser");
        assert_eq!(p.cloud_inflight(), 0, "no leaked pool slots");
    }

    #[test]
    fn crash_with_inflight_hedged_pairs_finalizes_each_task_once() {
        let spec = ResilienceSpec {
            hedge_slack: 0,
            hedge_delay: ms(1),
            ..ResilienceSpec::hedge_only()
        };
        let mut p = mkplatform(Policy::cloud_only().with_resilience(spec));
        p.metrics.record_completions = true;
        let mut q = EventQueue::new();
        for _ in 0..4 {
            let t = mktask(&mut p, DnnKind::Hv, 0);
            p.submit_task(0, t, &mut q);
        }
        settle(&mut p, &mut q, ms(5)); // triggers + hedge timers fire
        assert!(p.metrics.hedge_launches >= 1, "pairs are in flight");
        let relocated = p.crash(ms(10), false, &mut q);
        assert!(relocated.is_empty());
        settle(&mut p, &mut q, secs(120)); // stale CloudDones no-op
        let s = p.metrics.stats(DnnKind::Hv);
        assert_eq!(s.generated, 4);
        assert_eq!(s.dropped_node_failure, 4,
                   "each hedged pair closes as ONE node-failure drop");
        assert_eq!(p.metrics.completions.len(), 4);
        assert_eq!(p.cloud_inflight(), 0);
    }

    #[test]
    fn degradation_discounts_lite_completions_under_pressure() {
        let spec = ResilienceSpec {
            degrade_queue_high: 2,
            degrade_queue_low: 0,
            degrade_dwell: 0,
            ..ResilienceSpec::degrade_only()
        };
        // Edge-only EDF: all four HVs run on the edge, so the queue-depth
        // trajectory (3 queued behind the first) is fully deterministic.
        let mut p =
            mkplatform(Policy::edge_edf().with_resilience(spec.clone()));
        let mut q = EventQueue::new();
        for _ in 0..4 {
            let t = mktask(&mut p, DnnKind::Hv, 0);
            p.submit_task(0, t, &mut q);
        }
        settle(&mut p, &mut q, secs(30));
        assert_eq!(p.metrics.degraded_tasks, 3,
                   "the three queued-behind tasks run lite");
        assert!(p.metrics.degraded_utility_lost > 0.0,
                "successful lite completions forfeit the discount");
        let m = &p.metrics;
        let total: u64 = m.per_model.iter().map(|(_, s)| s.generated).sum();
        let closed: u64 = m
            .per_model
            .iter()
            .map(|(_, s)| s.executed() + s.dropped())
            .sum();
        assert_eq!(total, closed);
        // An unloaded executor stays on the full variant.
        let mut p2 = mkplatform(Policy::edge_edf().with_resilience(spec));
        let mut q2 = EventQueue::new();
        let t = mktask(&mut p2, DnnKind::Hv, 0);
        p2.submit_task(0, t, &mut q2);
        settle(&mut p2, &mut q2, secs(30));
        assert_eq!(p2.metrics.degraded_tasks, 0);
        assert_eq!(p2.metrics.qos_utility(), 124.0,
                   "idle-queue task earns the undiscounted utility");
    }

    #[test]
    fn disabled_mechanisms_build_no_state_regardless_of_knobs() {
        // Gating is on the three bools, not on knob values: a spec with
        // exotic knobs but every mechanism off constructs nothing.
        let spec = ResilienceSpec {
            breaker_window: 1,
            breaker_min_samples: 1,
            hedge_delay: 1,
            degrade_queue_high: 1,
            ..ResilienceSpec::default()
        };
        let p = mkplatform(Policy::dems_a().with_resilience(spec));
        assert!(p.core.resilience.breaker.is_none());
        assert!(p.core.resilience.hedge.is_none());
        assert!(p.core.resilience.degrade.is_none());
    }

    // ------------------------------------------------ pipeline mechanics

    use crate::pipeline::Stage;

    /// Deterministic DEMS platform with QoE monitors enabled on HV and
    /// DEO (so the chain-gating of `finalize` is observable).
    fn pipe_platform(cut: PipelineCut) -> Platform {
        let mut models = table1();
        for m in &mut models {
            if matches!(m.kind, DnnKind::Hv | DnnKind::Deo) {
                m.qoe_rate = 0.9;
                m.qoe_window = ms(20_000);
                m.qoe_benefit = 50.0;
            }
        }
        let mut cloud = CloudExecModel::new(Box::new(ConstantNet {
            latency: ms(40),
            bandwidth: 25.0e6,
        }));
        cloud.cold_start = 0;
        cloud.cold_prob = 0.0;
        let mut p = Platform::new(Policy::dems().with_pipeline_cut(cut),
                                  models, cloud, 7);
        p.edge_exec = EdgeExecModel { sigma: 0.0, overhead: (0, 0) };
        p.drone_exec = DroneExecModel { slowdown: 2.0, sigma: 0.0 };
        p
    }

    /// HV → DEO chain; `s0_slack` is stage 0's share of the e2e budget.
    fn chain2(e2e: Micros, s0_slack: f64) -> Arc<StageGraph> {
        Arc::new(StageGraph::chain(
            "t",
            vec![
                Stage {
                    kind: DnnKind::Hv,
                    deadline_slack: s0_slack,
                    output_bytes: 24_000,
                    drone_capable: true,
                },
                Stage {
                    kind: DnnKind::Deo,
                    deadline_slack: 1.0 - s0_slack,
                    output_bytes: 0,
                    drone_capable: false,
                },
            ],
            e2e,
        ))
    }

    fn mkchain(p: &mut Platform, g: &Arc<StageGraph>, drone_prefix: usize,
               created: Micros) -> Task {
        let id = p.fresh_task_id();
        Task {
            id,
            model: g.stages[0].kind,
            segment: VideoSegment {
                id,
                drone: 0,
                created_at: created,
                bytes: 38_000,
            },
            pipeline: Some(crate::pipeline::PipelineRef {
                graph: g.clone(),
                stage: 0,
                drone_prefix,
            }),
        }
    }

    #[test]
    fn pipeline_qoe_credits_on_chain_completion_not_per_stage() {
        let mut p = pipe_platform(PipelineCut::Adaptive);
        let mut q = EventQueue::new();
        let g = chain2(ms(4_000), 0.5);
        let t = mkchain(&mut p, &g, 0, 0);
        p.submit_task(0, t, &mut q);
        settle(&mut p, &mut q, ms(10_000));
        assert_eq!(p.metrics.completed(), 2, "both stages complete");
        // The HV stage succeeded but is intermediate: no QoE sample even
        // though its monitor is enabled.
        let hv = p.core.idx(DnnKind::Hv);
        assert_eq!(p.core.qoe[hv].total, 0);
        // Exactly one sample — the chain verdict — in DEO's window.
        let deo = p.core.idx(DnnKind::Deo);
        assert_eq!((p.core.qoe[deo].total, p.core.qoe[deo].succeeded),
                   (1, 1));
        // Stage-gated Eqn 1: only the final stage's γ counts.
        assert_eq!(p.metrics.qos_utility(), 244.0);
    }

    #[test]
    fn chain_kill_records_one_miss_in_final_models_window() {
        let mut p = pipe_platform(PipelineCut::Adaptive);
        let mut q = EventQueue::new();
        // Stage 0 gets 1% of the budget — hopeless on every tier — so
        // the chain dies at admission and DEO never runs.
        let g = chain2(ms(1_000), 0.01);
        let t = mkchain(&mut p, &g, 0, 0);
        p.submit_task(0, t, &mut q);
        settle(&mut p, &mut q, ms(10_000));
        assert_eq!(p.metrics.stats(DnnKind::Hv).dropped(), 1);
        assert_eq!(p.metrics.stats(DnnKind::Deo).generated, 0,
                   "a dead chain spawns no successor");
        let hv = p.core.idx(DnnKind::Hv);
        let deo = p.core.idx(DnnKind::Deo);
        assert_eq!(p.core.qoe[hv].total, 0);
        assert_eq!((p.core.qoe[deo].total, p.core.qoe[deo].succeeded),
                   (1, 0));
    }

    #[test]
    fn adaptive_drone_prefix_runs_early_stage_on_the_drone() {
        let mut p = pipe_platform(PipelineCut::Adaptive);
        let mut q = EventQueue::new();
        let g = chain2(ms(4_000), 0.5);
        let prefix = p.plan_drone_prefix(&g);
        assert_eq!(prefix, 1, "HV is drone-capable, DEO is not");
        let t = mkchain(&mut p, &g, prefix, 0);
        p.submit_task(0, t, &mut q);
        settle(&mut p, &mut q, ms(10_000));
        assert_eq!(p.metrics.completed_on(Resource::Drone), 1);
        assert_eq!(p.metrics.stats(DnnKind::Deo).completed(), 1);
        assert_eq!(p.metrics.qos_utility(), 244.0);
    }

    #[test]
    fn fixed_cloud_cut_routes_stages_to_pinned_cloud_entries() {
        let mut p = pipe_platform(PipelineCut::Fixed {
            drone: 0,
            cloud_start: 0,
        });
        let mut q = EventQueue::new();
        let g = chain2(ms(8_000), 0.5);
        let t = mkchain(&mut p, &g, 0, 0);
        p.submit_task(0, t, &mut q);
        // The stage sits in the cloud queue, pinned against stealing
        // (the idle edge executor must NOT claim it).
        assert_eq!(p.cloud_queue_len(), 1);
        settle(&mut p, &mut q, ms(20_000));
        assert_eq!(p.metrics.completed_on(Resource::Cloud), 2);
        assert_eq!(p.metrics.completed_on(Resource::Edge), 0);
        // Stage-gated Eqn 1: only the final stage's cloud γ counts.
        assert_eq!(p.metrics.qos_utility(), 40.0);
    }

    #[test]
    fn single_stage_pipeline_matches_plain_submission() {
        // A 1-stage graph must take the exact legacy admission path:
        // same outcome, same utility, same RNG consumption as a plain
        // task (the bit-identity pin at platform granularity).
        let run = |pipelined: bool| {
            let mut p = mkplatform(Policy::dems());
            let mut q = EventQueue::new();
            for i in 0..4u64 {
                let task = if pipelined {
                    let g = Arc::new(StageGraph::chain(
                        "one",
                        vec![Stage {
                            kind: DnnKind::Hv,
                            deadline_slack: 1.0,
                            output_bytes: 0,
                            drone_capable: false,
                        }],
                        p.profile(DnnKind::Hv).deadline,
                    ));
                    mkchain(&mut p, &g, 0, i * 1_000)
                } else {
                    mktask(&mut p, DnnKind::Hv, i * 1_000)
                };
                p.submit_task(i * 1_000, task, &mut q);
            }
            settle(&mut p, &mut q, ms(30_000));
            (p.metrics.completed(), p.metrics.qos_utility())
        };
        assert_eq!(run(false), run(true));
    }
}
