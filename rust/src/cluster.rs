//! Multi-edge cluster orchestration (§8.1): N platforms, a drone→edge
//! router and ONE discrete-event engine.
//!
//! The paper's emulation runs 7 edge base stations per host with 2–4 buddy
//! drones each. Pre-refactor the harness faked this by looping independent
//! single-edge simulations; a [`Cluster`] instead drives every platform
//! from a single [`EventQueue`] whose entries carry an edge scope
//! ([`EventQueue::set_scope`]), so cross-edge mechanisms added later
//! (fleet-level work stealing, shared-uplink contention, drone handover)
//! have a place to live.
//!
//! Determinism contract: per-edge event order equals the order the same
//! platform would see in an isolated run — events of different edges are
//! independent and the queue tie-breaks equal timestamps by push order,
//! which is preserved per edge. `tests/paper_shape.rs` pins this with a
//! bit-identical cluster-vs-solo comparison, which is also why the ported
//! `exp::run_edges` reproduces the paper figures unchanged.

use std::cell::RefCell;
use std::sync::{Arc, Mutex};

use crate::cloud::{CloudBackend, CloudStats};
use crate::fault::{DegradedLan, FaultAction, FaultDriver, FaultSpec,
                   FlapLink, Recovery};
use crate::fleet::{Arrival, Workload};
use crate::metrics::{self, Metrics};
use crate::obs::{SharedSink, TraceHandle, TraceKind};
use crate::net::{ConstantNet, NetworkModel, SharedUplink};
use crate::pipeline::PipelineRef;
use crate::platform::Platform;
use crate::policy::Policy;
use crate::rng::Rng;
use crate::sched::Scheduler;
use crate::sim::{Event, EventQueue, SETTLE};
use crate::task::{Task, VideoSegment};
use crate::time::{ms, Micros};

/// XOR-multiplier used to derive per-edge seeds in emulation runs (the
/// same derivation the pre-cluster harness used, kept for reproducibility
/// of the recorded figures).
pub const EDGE_SEED_PHI: u64 = 0x9E37_79B9;

/// XOR applied to an edge's platform seed to derive its arrival-stream RNG.
pub const ARRIVAL_SEED_XOR: u64 = 0x5EED_F1EE7;

thread_local! {
    /// Per-thread reusable event-queue allocation for [`Cluster::run`]:
    /// cleared before every run, so reuse is invisible to results.
    static SHARED_QUEUE: RefCell<EventQueue> =
        RefCell::new(EventQueue::new());
}

/// Maps fleet drones onto edge base stations: drone `g` reports to edge
/// `g / drones_per_edge` (the §8.1 setup assigns each VIP's buddy drones
/// to their personal edge).
///
/// Since the fleet-federation layer the router is **dynamic**: a
/// mobility/churn window can [`re_home`](Router::re_home) a drone to the
/// nearest edge mid-run, after which its segment stream emits at the new
/// edge while tasks already admitted at the old edge run there to
/// completion (no double-count — generation and outcome both move with
/// the stream, never split).
#[derive(Clone, Debug, Default)]
pub struct Router {
    pub drones_per_edge: u32,
    /// Mid-run re-homes (fleet handover): `(global drone, current edge)`.
    /// Empty for the paper's static mapping.
    overrides: Vec<(u32, u32)>,
}

impl Router {
    /// The static §8.1 mapping: `drones_per_edge` buddies per station.
    pub fn uniform(drones_per_edge: u32) -> Self {
        Router { drones_per_edge, overrides: Vec::new() }
    }

    /// Edge index serving a (global) drone id.
    pub fn edge_of(&self, drone: u32) -> usize {
        if let Some(&(_, e)) =
            self.overrides.iter().find(|(d, _)| *d == drone)
        {
            return e as usize;
        }
        (drone / self.drones_per_edge.max(1)) as usize
    }

    /// Global drone id of edge-local drone `local` on edge `edge`.
    pub fn global_id(&self, edge: usize, local: u32) -> u32 {
        edge as u32 * self.drones_per_edge + local
    }

    /// Dynamic re-home of one drone (fleet handover): subsequent lookups
    /// report `edge`. Idempotent per drone — a second handover replaces
    /// the first.
    pub fn re_home(&mut self, drone: u32, edge: usize) {
        if let Some(o) =
            self.overrides.iter_mut().find(|(d, _)| *d == drone)
        {
            o.1 = edge as u32;
        } else {
            self.overrides.push((drone, edge as u32));
        }
    }

    /// Current home of `drone` given its static origin edge `origin`
    /// (prefix-sum correct for hetero clusters, where the flat
    /// `drones_per_edge` division is undefined).
    pub fn homed_edge(&self, drone: u32, origin: usize) -> usize {
        if let Some(&(_, e)) =
            self.overrides.iter().find(|(d, _)| *d == drone)
        {
            return e as usize;
        }
        origin
    }

    /// Has any drone been re-homed?
    pub fn is_dynamic(&self) -> bool {
        !self.overrides.is_empty()
    }

    /// The current override for `drone`, if any (fault recovery snapshots
    /// this before re-homing a crashed edge's drones, so it can restore
    /// the pre-crash mapping verbatim).
    pub fn override_of(&self, drone: u32) -> Option<u32> {
        self.overrides
            .iter()
            .find(|(d, _)| *d == drone)
            .map(|&(_, e)| e)
    }

    /// Remove `drone`'s override, restoring its static/origin mapping
    /// (fault recovery for a drone that had no override pre-crash).
    pub fn clear_override(&mut self, drone: u32) {
        self.overrides.retain(|(d, _)| *d != drone);
    }
}

/// Aggregated results of one cluster run.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterMetrics {
    pub per_edge: Vec<Metrics>,
}

impl ClusterMetrics {
    pub fn edges(&self) -> usize {
        self.per_edge.len()
    }

    pub fn generated(&self) -> u64 {
        self.per_edge.iter().map(|m| m.generated()).sum()
    }

    pub fn completed(&self) -> u64 {
        self.per_edge.iter().map(|m| m.completed()).sum()
    }

    pub fn completion_rate(&self) -> f64 {
        let g = self.generated();
        if g == 0 {
            0.0
        } else {
            self.completed() as f64 / g as f64
        }
    }

    pub fn total_qos_utility(&self) -> f64 {
        self.per_edge.iter().map(|m| m.qos_utility()).sum()
    }

    pub fn total_utility(&self) -> f64 {
        self.per_edge.iter().map(|m| m.total_utility()).sum()
    }

    /// Median-by-QoS-utility edge (the paper reports "a median edge base
    /// station"). Panics on an empty cluster.
    pub fn median_edge(&self) -> &Metrics {
        metrics::median_by_qos_utility(&self.per_edge)
            .expect("cluster has at least one edge")
    }

    /// (min, max) QoS utility across the edges.
    pub fn minmax_utility(&self) -> (f64, f64) {
        metrics::minmax_qos_utility(&self.per_edge)
    }

    /// Cloud backend accounting summed across the edges (dollars,
    /// GB-seconds, cold starts, backend-side throttles).
    pub fn cloud_stats(&self) -> CloudStats {
        let mut s = CloudStats::default();
        for m in &self.per_edge {
            s.merge(&m.cloud);
        }
        s
    }

    /// Platform-observed throttled dispatch attempts across the edges.
    pub fn throttled(&self) -> u64 {
        self.per_edge.iter().map(Metrics::throttled).sum()
    }

    // ----------------------------------------------- federation columns

    /// Cross-edge steal arrivals executed-side (fleet federation).
    pub fn fed_steals(&self) -> u64 {
        self.per_edge.iter().map(|m| m.fed_steals_in).sum()
    }

    /// Deferred entries offered away to sibling edges. Every arrival
    /// has an offer, so `fed_offers() >= fed_steals()`; the difference
    /// is transfers still in flight at drain (dropped at the
    /// destination without counting as arrivals).
    pub fn fed_offers(&self) -> u64 {
        self.per_edge.iter().map(|m| m.fed_steals_out).sum()
    }

    /// Drone re-homes performed mid-run (fleet handover).
    pub fn handovers(&self) -> u64 {
        self.per_edge.iter().map(|m| m.handovers).sum()
    }

    /// Total shared-uplink queueing delay across the edges (µs).
    pub fn uplink_wait(&self) -> Micros {
        self.per_edge.iter().map(|m| m.uplink_wait).sum()
    }

    /// Cloud dispatches that queued on the shared uplink.
    pub fn uplink_queued(&self) -> u64 {
        self.per_edge.iter().map(|m| m.uplink_queued).sum()
    }

    // ---------------------------------------------------- fault columns

    /// Edge-crash events applied (fault injection).
    pub fn crashes(&self) -> u64 {
        self.per_edge.iter().map(|m| m.crashes).sum()
    }

    /// Edge recoveries applied (fault injection).
    pub fn recoveries(&self) -> u64 {
        self.per_edge.iter().map(|m| m.recoveries).sum()
    }

    /// Queued entries a crashed edge relocated to live siblings through
    /// the federation steal path ([`Recovery::Requeue`]).
    pub fn fault_relocated(&self) -> u64 {
        self.per_edge.iter().map(|m| m.fault_relocated).sum()
    }

    /// Tasks lost to node failure (in-flight work on a crashed edge,
    /// infeasible relocations, arrivals during downtime).
    pub fn node_failures(&self) -> u64 {
        self.per_edge.iter().map(|m| m.node_failures()).sum()
    }

    /// Total edge downtime across the cluster (µs; never-recovered
    /// edges are charged to the run horizon).
    pub fn downtime(&self) -> Micros {
        self.per_edge.iter().map(|m| m.downtime).sum()
    }

    // ----------------------------------------------- resilience columns

    /// Circuit-breaker open transitions across the edges.
    pub fn breaker_trips(&self) -> u64 {
        self.per_edge.iter().map(|m| m.breaker_trips).sum()
    }

    /// Cloud dispatches short-circuited by an open breaker.
    pub fn breaker_shorted(&self) -> u64 {
        self.per_edge.iter().map(|m| m.breaker_shorted).sum()
    }

    /// Half-open probe invocations let through by breakers.
    pub fn breaker_probes(&self) -> u64 {
        self.per_edge.iter().map(|m| m.breaker_probes).sum()
    }

    /// Speculative hedge duplicates launched.
    pub fn hedge_launches(&self) -> u64 {
        self.per_edge.iter().map(|m| m.hedge_launches).sum()
    }

    /// Hedged pairs whose duplicate delivered the usable result.
    pub fn hedge_wins(&self) -> u64 {
        self.per_edge.iter().map(|m| m.hedge_wins).sum()
    }

    /// Losing hedge legs cancelled client-side (billed in full).
    pub fn hedge_cancels(&self) -> u64 {
        self.per_edge.iter().map(|m| m.hedge_cancels).sum()
    }

    /// Edge executions run as lite (degraded) variants.
    pub fn degraded_tasks(&self) -> u64 {
        self.per_edge.iter().map(|m| m.degraded_tasks).sum()
    }

    /// Utility forfeited to lite-variant discounts.
    pub fn degraded_utility_lost(&self) -> f64 {
        self.per_edge.iter().map(|m| m.degraded_utility_lost).sum()
    }

    /// p-th percentile of cloud-leg latency (ms) across every edge and
    /// model: completed/missed cloud tasks plus client timeouts — the
    /// tail the hedging mechanism attacks. NaN when no cloud task ran.
    ///
    /// Served from the O(1)-memory [`LogHistogram`]s (≤ 0.5% relative
    /// bucket error); enable [`Metrics::record_exact_samples`] and use
    /// [`metrics::percentile`] over `cloud_exec_ms` for exact values.
    ///
    /// [`LogHistogram`]: crate::obs::LogHistogram
    /// [`Metrics::record_exact_samples`]: crate::metrics::Metrics::record_exact_samples
    pub fn cloud_latency_percentile(&self, p: f64) -> f64 {
        let mut hist = crate::obs::LogHistogram::default();
        for m in &self.per_edge {
            for (_, s) in m.per_model.iter() {
                hist.merge(&s.cloud_exec_hist);
            }
        }
        hist.percentile(p)
    }

    /// Total simulation events processed across the cluster's engines
    /// (engine-throughput profiling; see `docs/OBSERVABILITY.md`).
    pub fn events_processed(&self) -> u64 {
        self.per_edge.iter().map(|m| m.events_processed).sum()
    }

    /// Tasks dropped for `reason` across the edges (drop-breakdown
    /// column group).
    pub fn dropped_by(&self, reason: crate::task::DropReason) -> u64 {
        self.per_edge.iter().map(|m| m.dropped_by(reason)).sum()
    }

    /// Total dropped tasks across the edges.
    pub fn dropped(&self) -> u64 {
        self.per_edge.iter().map(|m| m.dropped()).sum()
    }
}

// -------------------------------------------------------------- federation

/// One scheduled drone re-home (fleet handover): at virtual time `at`,
/// global drone `drone`'s stream moves to `to_edge`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Handover {
    pub at: Micros,
    pub drone: u32,
    pub to_edge: usize,
}

/// Fleet-federation configuration for one cluster run — the cross-edge
/// layer the scope-tagged event queue always reserved a slot for:
///
/// 1. **Work stealing across edges**: when an edge goes fully idle, the
///    coordinator offers it the best deadline-viable entry from a
///    sibling's deferred cloud queue (the §5.3 population), charging the
///    edge↔edge LAN transfer through a [`NetworkModel`] and ranking
///    candidates with the schedulers' κ/κ̂ machinery.
/// 2. **Drone handover**: scheduled [`Handover`]s re-home a stream via
///    the dynamic [`Router`]; in-flight tasks finish at the old edge.
/// 3. **Shared-uplink contention**: sibling edges serialize their cloud
///    transfers through one [`SharedUplink`] budget, so concurrent
///    dispatches inflate each other's observed durations (and DEMS-A's
///    t̂ adapts through the ordinary `on_cloud_report` path).
///
/// The default config turns everything off; a cluster federated with it
/// is **bit-identical** to an unfederated one (pinned by
/// `tests/sweep_parity.rs`).
pub struct Federation {
    /// Cross-edge §5.3 work stealing between sibling edges.
    pub steal: bool,
    /// Edge↔edge LAN charging steal transfers (default: 2 ms constant
    /// latency at 125 MB/s — a switched MAN between base stations).
    pub lan: Box<dyn NetworkModel>,
    /// Scheduled drone re-homes, applied at their `at` instants.
    pub handovers: Vec<Handover>,
    /// Shared backhaul bandwidth (bytes/s) serializing the sibling
    /// edges' cloud transfers; `None` = independent uplinks.
    pub uplink_bytes_per_sec: Option<f64>,
    /// RNG for stochastic LAN models — its own stream, so federation
    /// never perturbs the platforms' paper-calibrated draw sequences.
    rng: Rng,
}

impl Default for Federation {
    fn default() -> Self {
        Federation {
            steal: false,
            lan: Box::new(ConstantNet {
                latency: ms(2),
                bandwidth: 125.0e6,
            }),
            handovers: Vec::new(),
            uplink_bytes_per_sec: None,
            rng: Rng::new(0xFED_F1EE7),
        }
    }
}

impl Federation {
    /// Cross-edge stealing on, everything else default.
    pub fn stealing() -> Self {
        Federation { steal: true, ..Federation::default() }
    }

    /// Add one scheduled drone re-home.
    pub fn with_handover(mut self, h: Handover) -> Self {
        self.handovers.push(h);
        self
    }

    /// Serialize the edges' cloud transfers through one shared uplink.
    pub fn with_uplink(mut self, bytes_per_sec: f64) -> Self {
        self.uplink_bytes_per_sec = Some(bytes_per_sec);
        self
    }

    /// Replace the edge↔edge LAN model for steal transfers.
    pub fn with_lan(mut self, lan: Box<dyn NetworkModel>) -> Self {
        self.lan = lan;
        self
    }

    /// Is any federation mechanism active?
    pub fn enabled(&self) -> bool {
        self.steal
            || !self.handovers.is_empty()
            || self.uplink_bytes_per_sec.is_some()
    }
}

/// N edge platforms + drone router + per-edge arrival streams, driven by
/// one event engine.
///
/// Every edge carries its *own* [`Workload`]: the uniform §8.1 emulation
/// clones one spec per station ([`Cluster::from_parts`]), while
/// heterogeneous studies mix fleet sizes, app mixes, durations and arrival
/// processes per edge ([`Cluster::from_parts_hetero`] — the
/// `hetero-edges` scenario).
pub struct Cluster<S: Scheduler = Box<dyn Scheduler>> {
    edges: Vec<Platform<S>>,
    /// Per-edge workload specification.
    workloads: Vec<Workload>,
    router: Router,
    /// First global drone id of each edge (prefix sums of per-edge fleet
    /// sizes; equals `Router::global_id(e, 0)` for uniform clusters).
    drone_base: Vec<u32>,
    /// Per-edge arrival-stream RNG (segment fan-out order §3.3, Poisson
    /// inter-arrival draws).
    arrivals: Vec<Rng>,
    /// Per-edge segment-id counters.
    segment_ids: Vec<u64>,
    /// Fleet-federation layer; `None` (the default) runs the edges fully
    /// isolated, bit-identical to the pre-federation engine.
    federation: Option<Federation>,
    /// Fault-injection schedule; the default (empty) spec is inert and
    /// keeps the run bit-identical to the pre-fault engine.
    faults: FaultSpec,
}

impl Cluster<Box<dyn Scheduler>> {
    /// Canonical §8.1 per-edge platform for station `e`: platform seed
    /// `base_seed ^ ((e+1)·EDGE_SEED_PHI)`, the workload's edge-exec
    /// regime, and the paired arrival-stream seed (`^ ARRIVAL_SEED_XOR`).
    /// Shared by [`Cluster::emulation`] and the hetero scenario builder so
    /// the derivation can never drift between them.
    pub fn edge_parts(policy: &Policy, wl: &Workload, base_seed: u64,
                      e: usize, cloud: impl Into<Box<dyn CloudBackend>>)
                      -> (Platform, u64) {
        let s = base_seed ^ ((e as u64 + 1) * EDGE_SEED_PHI);
        let mut p =
            Platform::new(policy.clone(), wl.models.clone(), cloud, s);
        p.edge_exec = wl.edge_exec.clone();
        (p, s ^ ARRIVAL_SEED_XOR)
    }

    /// §8.1 emulation cluster: `n_edges` stations running the same policy
    /// and per-edge workload, with the canonical per-edge seed derivation
    /// `seed ^ ((e+1)·EDGE_SEED_PHI)`.
    pub fn emulation(policy: &Policy, wl: &Workload, seed: u64,
                     n_edges: usize,
                     make_cloud: &dyn Fn() -> Box<dyn CloudBackend>)
                     -> Self {
        let mut platforms = Vec::with_capacity(n_edges);
        let mut arrival_seeds = Vec::with_capacity(n_edges);
        for e in 0..n_edges {
            let (p, aseed) =
                Self::edge_parts(policy, wl, seed, e, make_cloud());
            platforms.push(p);
            arrival_seeds.push(aseed);
        }
        Cluster::from_parts(platforms, wl.clone(), arrival_seeds)
    }

    /// Single-edge cluster seeded directly with `seed` (the `simulate`
    /// path; bit-identical to the pre-cluster single-edge engine).
    pub fn single(policy: &Policy, wl: &Workload, seed: u64,
                  cloud: impl Into<Box<dyn CloudBackend>>) -> Self {
        let mut p =
            Platform::new(policy.clone(), wl.models.clone(), cloud, seed);
        p.edge_exec = wl.edge_exec.clone();
        Cluster::from_parts(vec![p], wl.clone(),
                            vec![seed ^ ARRIVAL_SEED_XOR])
    }
}

impl<S: Scheduler> Cluster<S> {
    /// Assemble a uniform cluster from pre-built platforms: every edge
    /// runs the same `workload`. `arrival_seeds[e]` seeds edge `e`'s
    /// segment fan-out RNG.
    pub fn from_parts(edges: Vec<Platform<S>>, workload: Workload,
                      arrival_seeds: Vec<u64>) -> Self {
        let n = edges.len();
        Self::from_parts_hetero(edges, vec![workload; n], arrival_seeds)
    }

    /// Assemble a heterogeneous cluster: `workloads[e]` drives edge `e`
    /// (its own fleet size, app mix, duration, arrival process and churn
    /// windows). For uniform inputs this is bit-identical to
    /// [`Cluster::from_parts`].
    pub fn from_parts_hetero(edges: Vec<Platform<S>>,
                             workloads: Vec<Workload>,
                             arrival_seeds: Vec<u64>) -> Self {
        assert_eq!(edges.len(), arrival_seeds.len(),
                   "one arrival seed per edge");
        assert_eq!(edges.len(), workloads.len(), "one workload per edge");
        let n = edges.len();
        let router =
            Router::uniform(workloads.first().map_or(0, |w| w.drones));
        let mut drone_base = Vec::with_capacity(n);
        let mut base = 0u32;
        for w in &workloads {
            drone_base.push(base);
            base += w.drones;
        }
        Cluster {
            edges,
            workloads,
            router,
            drone_base,
            arrivals: arrival_seeds.into_iter().map(Rng::new).collect(),
            segment_ids: vec![0; n],
            federation: None,
            faults: FaultSpec::default(),
        }
    }

    /// Attach a fleet-federation layer (cross-edge work stealing, drone
    /// handover, shared-uplink contention). With the default all-off
    /// [`Federation`] the run stays bit-identical to an unfederated
    /// cluster.
    pub fn federated(mut self, fed: Federation) -> Self {
        for h in &fed.handovers {
            assert!(h.to_edge < self.edges.len(),
                    "handover target edge {} out of range", h.to_edge);
        }
        self.federation = Some(fed);
        self
    }

    /// Attach a fault-injection schedule (edge crashes, region outages,
    /// link flaps — see [`crate::fault`]). The default empty
    /// [`FaultSpec`] is inert: the run stays bit-identical to a cluster
    /// without one.
    pub fn with_faults(mut self, spec: FaultSpec) -> Self {
        if let Some(max) = spec.max_edge() {
            assert!(max < self.edges.len(),
                    "fault crash edge {} out of range", max);
        }
        self.faults = spec;
        self
    }

    /// Attach a task-lifecycle trace sink: every edge gets a
    /// [`TraceHandle`] badged with its station index, so one sink
    /// receives the whole cluster's event stream (see
    /// `docs/OBSERVABILITY.md`). Without this call no handle exists and
    /// the engine's hot paths skip tracing entirely — runs are
    /// bit-identical to the untraced engine (pinned in
    /// `tests/observability.rs`).
    pub fn with_trace(mut self, sink: SharedSink) -> Self {
        for (e, edge) in self.edges.iter_mut().enumerate() {
            edge.core.set_trace(TraceHandle::new(e as u32, sink.clone()));
        }
        self
    }

    /// Enable windowed time-series metrics on every edge: each station
    /// folds its outcomes into an O(1)-memory [`Timeline`] with the
    /// given window width (virtual µs).
    ///
    /// [`Timeline`]: crate::obs::Timeline
    pub fn with_timeline(mut self, window: crate::time::Micros) -> Self {
        for edge in self.edges.iter_mut() {
            edge.core.metrics.windowed =
                Some(crate::obs::Timeline::new(window));
        }
        self
    }

    /// Uniform drone→edge router. Only defined when every edge serves the
    /// same fleet size — on a mixed-fleet cluster the flat
    /// `drones_per_edge` mapping would mis-route drones, so this panics;
    /// use [`Cluster::first_drone`] (the prefix-sum base the event loop
    /// itself uses) instead.
    pub fn router(&self) -> Router {
        assert!(
            self.workloads
                .iter()
                .all(|w| w.drones == self.router.drones_per_edge),
            "router() is undefined for mixed-fleet clusters; \
             use first_drone(edge)"
        );
        self.router.clone()
    }

    /// First global drone id served by edge `e` (prefix sums of the
    /// per-edge fleet sizes; correct for hetero clusters too).
    pub fn first_drone(&self, e: usize) -> u32 {
        self.drone_base[e]
    }

    /// The workload driving edge `e`.
    pub fn workload(&self, e: usize) -> &Workload {
        &self.workloads[e]
    }

    /// Run the whole cluster to completion; returns per-edge metrics.
    ///
    /// Reuses a per-thread [`EventQueue`] allocation: a sweep runs
    /// thousands of clusters per worker thread and the event heap is the
    /// biggest buffer each run would otherwise re-grow from cold (see
    /// docs/PERF.md).
    pub fn run(self) -> ClusterMetrics {
        SHARED_QUEUE.with(|q| match q.try_borrow_mut() {
            Ok(mut q) => self.run_with(&mut q),
            // Re-entrant cluster run on this thread (no engine path does
            // this, but staying correct is one allocation).
            Err(_) => self.run_with(&mut EventQueue::new()),
        })
    }

    /// [`Cluster::run`] against an explicit event-queue allocation. The
    /// queue is cleared first (seq/scope included), so results are
    /// bit-identical to a fresh queue no matter what ran on it before.
    pub fn run_with(self, q: &mut EventQueue) -> ClusterMetrics {
        q.clear();
        let Cluster {
            mut edges,
            workloads,
            mut router,
            drone_base,
            mut arrivals,
            mut segment_ids,
            federation,
            faults,
        } = self;
        let n = edges.len();
        let mut fed = federation;

        // Fault injection: compile the schedule FIRST, so at equal
        // timestamps a fault wins the tie by push order — a crash at
        // exactly a handover/tick instant strictly precedes it. The
        // driver only exists when the spec injects something, keeping
        // faults-off runs bit-identical to the pre-fault engine.
        let faults_on = faults.enabled();
        let mut driver = if faults_on {
            faults.compile(q);
            Some(FaultDriver::new(n, faults.recovery))
        } else {
            None
        };
        if let Some(d) = &driver {
            // A LAN flap needs a hook into the federation's steal/
            // relocation network model: wrap it once, here, so the
            // in-run toggle is just the shared cell.
            if faults.flaps.iter().any(|f| f.link == FlapLink::Lan) {
                if let Some(f) = fed.as_mut() {
                    let inner = std::mem::replace(
                        &mut f.lan,
                        Box::new(ConstantNet { latency: 0,
                                               bandwidth: f64::INFINITY }),
                    );
                    f.lan = Box::new(DegradedLan {
                        inner,
                        degraded: d.lan_degraded.clone(),
                    });
                }
            }
        }

        // Shared-uplink contention: hand every edge the same budget so
        // their cloud dispatches serialize against each other. The
        // handle is kept so an uplink flap can degrade it mid-run.
        let mut shared_up: Option<Arc<Mutex<SharedUplink>>> = None;
        if let Some(f) = &fed {
            if let Some(bw) = f.uplink_bytes_per_sec {
                let up = Arc::new(Mutex::new(SharedUplink::new(bw)));
                for edge in edges.iter_mut() {
                    edge.core.uplink = Some(up.clone());
                }
                shared_up = Some(up);
            }
            // Handovers are pushed *before* the segment seeds, so a
            // re-home at exactly a tick instant wins the tie and that
            // tick already emits at the new edge (push-order tie-break,
            // pinned in sim.rs).
            for h in &f.handovers {
                q.set_scope(h.to_edge as u32);
                q.push(h.at, Event::Handover {
                    drone: h.drone,
                    to_edge: h.to_edge as u32,
                });
            }
        }

        // Seed every edge's drone streams (staggered phases so segment
        // arrivals don't collide on identical microsecond ticks — real
        // streams are never phase-locked) and QoE windows.
        for (e, edge) in edges.iter_mut().enumerate() {
            let wl = &workloads[e];
            q.set_scope(e as u32);
            for d in 0..wl.drones {
                let phase =
                    (d as Micros * 37_003) % wl.segment_period;
                q.push(phase, Event::Segment {
                    drone: drone_base[e] + d,
                    tick: 0,
                });
            }
            edge.schedule_windows(&mut q);
        }

        let horizon =
            workloads.iter().map(|w| w.duration).max().unwrap_or(0)
                + SETTLE;
        let pipelined = workloads.iter().any(|w| w.pipeline.is_some());
        while let Some((now, scope, ev)) = q.pop_scoped() {
            if now > horizon {
                if fed.is_none() && !pipelined && !faults_on {
                    break;
                }
                // Federated runs keep popping: a steal still in LAN
                // transfer must close its accounting at the destination
                // edge or the cluster-wide conservation invariant leaks.
                // Pipeline runs likewise: a stage still running on a
                // drone was counted generated and must close, while a
                // successor still in handoff was never submitted and is
                // simply discarded.
                match ev {
                    Event::FedArrive { task }
                    | Event::DroneDone { task, .. } => {
                        let e = scope as usize;
                        q.set_scope(scope);
                        let task = q.take_task(task);
                        edges[e].drop_in_transit(horizon, task, &mut *q);
                    }
                    // A successor still in handoff was never submitted
                    // (and never charged `generated`): just free its
                    // arena slot.
                    Event::StageArrive { task } => {
                        let _ = q.take_task(task);
                    }
                    _ => {}
                }
                continue;
            }
            let e = scope as usize;
            q.set_scope(scope);
            // Engine-throughput profiling: one tick per event actually
            // processed within the horizon, attributed to the scope edge.
            edges[e].metrics.events_processed += 1;
            // Which edge this event mutated (differs from the scope only
            // when a handed-over drone's segment emits at its new home).
            let mut touched = e;
            match ev {
                Event::Segment { drone, tick } => {
                    let wl = &workloads[e];
                    if now < wl.duration {
                        // Churn windows and bursty duty cycles suppress
                        // the emission but keep the tick chain alive (a
                        // rejoining drone resumes on its own phase).
                        let local = drone - drone_base[e];
                        if wl.drone_active(local, now)
                            && wl.arrival_on(now)
                        {
                            segment_ids[e] += 1;
                            let sid = segment_ids[e];
                            // Fleet handover: a re-homed drone emits at
                            // its current edge; the tick chain, churn
                            // windows and arrival RNG stay with the
                            // origin stream.
                            let home = router.homed_edge(drone, e);
                            if home != e {
                                q.set_scope(home as u32);
                                touched = home;
                            }
                            emit_segment(&mut edges[home], wl, now,
                                         drone, tick, sid,
                                         &mut arrivals[e], &mut q);
                            if home != e {
                                q.set_scope(scope);
                            }
                        }
                        // Periodic ticks draw nothing from the RNG, so
                        // the paper's workloads stay bit-identical to the
                        // pre-arrival-process engine.
                        let next = match wl.arrival {
                            Arrival::Periodic
                            | Arrival::Bursty { .. } => {
                                now + wl.segment_period
                            }
                            Arrival::Poisson => {
                                let gap = arrivals[e].exponential(
                                    wl.segment_period as f64,
                                );
                                now + (gap as Micros).max(1)
                            }
                        };
                        q.push(next,
                               Event::Segment { drone, tick: tick + 1 });
                    }
                }
                Event::EdgeDone => edges[e].on_edge_done(now, &mut q),
                Event::CloudTrigger => {
                    edges[e].on_cloud_trigger(now, &mut q)
                }
                Event::CloudDone { key } => {
                    edges[e].on_cloud_done(now, key, &mut q)
                }
                Event::HedgeFire { key } => {
                    edges[e].on_hedge_fire(now, key, &mut q)
                }
                Event::WindowClose { model_idx } => {
                    if now <= workloads[e].duration {
                        edges[e].on_window_close(now, model_idx, &mut q);
                    }
                }
                Event::FedArrive { task } => {
                    // A transfer landing on an edge that crashed while
                    // it was on the LAN dies here — closed exactly once
                    // (it was charged `generated` at its origin).
                    let task = q.take_task(task);
                    if driver.as_ref().map_or(false, |d| d.is_down(e)) {
                        edges[e].drop_failed(now, task, &mut q);
                    } else {
                        edges[e].accept_federated(now, task, &mut q);
                    }
                }
                Event::Handover { drone, to_edge } => {
                    let mut dst = Some(to_edge as usize);
                    if let Some(d) = driver.as_mut() {
                        // The planned handover supersedes any crash
                        // re-home: recovery must not undo it.
                        d.forget_rehome(drone);
                        if d.is_down(to_edge as usize) {
                            dst = d.live_edge(to_edge as usize);
                        }
                    }
                    if let Some(dst) = dst {
                        router.re_home(drone, dst);
                        edges[e].metrics.handovers += 1;
                        edges[e].core
                                .emit_trace(now,
                                            TraceKind::Handover { drone });
                    }
                }
                Event::StageArrive { task } => {
                    let task = q.take_task(task);
                    edges[e].submit_task(now, task, &mut q)
                }
                Event::DroneDone { task, started } => {
                    // The drone survives, but the station that would
                    // collect its result is dark.
                    let task = q.take_task(task);
                    if driver.as_ref().map_or(false, |d| d.is_down(e)) {
                        edges[e].drop_failed(now, task, &mut q);
                    } else {
                        edges[e].on_drone_done(now, task, started,
                                               &mut q)
                    }
                }
                Event::Fault(action) => {
                    apply_fault(now, action,
                                driver.as_mut()
                                      .expect("fault event without driver"),
                                fed.as_mut(), &shared_up, &mut router,
                                &workloads, &drone_base, &mut edges,
                                &mut q);
                }
            }
            // Fleet work stealing: when the event left the touched edge
            // fully idle, pull the best deadline-viable deferred entry
            // from a sibling's cloud queue (§5.3 across edges).
            if n > 1 {
                if let Some(f) = fed.as_mut() {
                    if f.steal {
                        try_fed_steal(now, touched, f, &mut edges,
                                      &mut *q);
                    }
                }
            }
        }

        // Edges still dark at the horizon never saw their Recover
        // event: charge the open downtime window to the run end.
        if let Some(d) = &driver {
            for (e, edge) in edges.iter_mut().enumerate() {
                edge.metrics.downtime += d.residual_downtime(e, horizon);
            }
        }

        let mut per_edge = Vec::with_capacity(n);
        for (e, mut p) in edges.into_iter().enumerate() {
            q.set_scope(e as u32);
            p.drain(horizon, &mut q);
            let mut m = p.into_metrics();
            m.duration = workloads[e].duration;
            per_edge.push(m);
        }
        ClusterMetrics { per_edge }
    }
}

/// Cross-edge steal attempt for an idle `thief` edge: scan the siblings'
/// deferred cloud queues for the best candidate by (negative-utility
/// first, then κ/κ̂ steal rank — the same order as
/// [`CloudQueue::best_steal`](crate::queues::CloudQueue)), feasibility-
/// screened against the thief's own profile *including* the LAN transfer.
/// The winner is removed from its origin queue and arrives at the thief
/// as a [`Event::FedArrive`] after the transfer.
fn try_fed_steal<S: Scheduler>(now: Micros, thief: usize,
                               fed: &mut Federation,
                               edges: &mut [Platform<S>],
                               q: &mut EventQueue) {
    {
        // Only a fully idle thief steals across edges: its executor is
        // free and its own queues gave it nothing to run (the local
        // §5.3 hook already had its chance inside try_start_edge). The
        // thief must itself run a stealing policy — in a mixed-policy
        // cluster a non-stealing baseline neither offers nor steals, so
        // federation extends §5.3 symmetrically.
        let t = &edges[thief];
        // A crashed thief has (vacuously) empty queues — gate it
        // explicitly so a dark station never pulls work.
        if t.core.crashed
            || !t.policy.use_edge
            || !t.scheduler().federates(&t.core)
            || t.core.running_edge.is_some()
            || !t.core.edge_q.is_empty()
        {
            return;
        }
    }
    // (origin edge, cloud-queue index, negative-utility, rank, transfer)
    let mut best: Option<(usize, usize, bool, f64, Micros)> = None;
    for (s, origin) in edges.iter().enumerate() {
        if s == thief {
            continue;
        }
        // The origin's scheduler gates federation (§5.3 extended): a
        // policy that never steals locally is never stolen from either.
        // A crashed origin's queues were swept at the crash, but skip
        // it outright for clarity.
        if origin.core.crashed
            || !origin.scheduler().federates(&origin.core)
        {
            continue;
        }
        for (idx, en) in origin.core.cloud_q.iter().enumerate() {
            // Fixed-cut pipeline stages are pinned to their tier — the
            // cut is the experiment's control variable, so the fleet
            // never steals them either.
            if en.pinned {
                continue;
            }
            let kind = en.task.model;
            // The thief must serve the model (hetero mixes differ) and
            // its own profile prices the feasibility and the rank.
            let tp = match edges[thief]
                .models
                .iter()
                .find(|m| m.kind == kind)
            {
                Some(p) => p,
                None => continue,
            };
            let transfer = fed.lan.transfer_time(
                now,
                en.task.payload_bytes(),
                &mut fed.rng,
            );
            if now + transfer + tp.t_edge > en.abs_deadline {
                continue;
            }
            let cand =
                (s, idx, en.negative_utility, tp.steal_rank(), transfer);
            best = Some(match best {
                None => cand,
                Some(b) => {
                    let better = (cand.2 && !b.2)
                        || (cand.2 == b.2 && cand.3 > b.3);
                    if better {
                        cand
                    } else {
                        b
                    }
                }
            });
        }
    }
    if let Some((s, idx, _, _, transfer)) = best {
        let entry = edges[s].take_fed_offer(now, idx);
        q.set_scope(thief as u32);
        let slot = q.stash_task(entry.task);
        q.push(now + transfer, Event::FedArrive { task: slot });
    }
}

/// Apply one compiled [`FaultAction`] to the running cluster. Crash and
/// recover mutate one platform + the router; outages fan out to every
/// edge's cloud backend; flaps toggle the shared link models in place.
#[allow(clippy::too_many_arguments)]
fn apply_fault<S: Scheduler>(now: Micros, action: FaultAction,
                             d: &mut FaultDriver,
                             mut fed: Option<&mut Federation>,
                             shared_up: &Option<Arc<Mutex<SharedUplink>>>,
                             router: &mut Router, workloads: &[Workload],
                             drone_base: &[u32],
                             edges: &mut [Platform<S>],
                             q: &mut EventQueue) {
    match action {
        FaultAction::Crash { edge } => {
            // A double crash in a random spec is a no-op, not a second
            // sweep.
            if !d.mark_down(edge, now) {
                return;
            }
            // Re-home the dead station's buddy drones to the lowest-
            // index live sibling (deterministic), remembering their
            // pre-crash mapping for recovery. With every edge dark the
            // fleet has nowhere to stream and arrivals die at submit.
            if let Some(fallback) = d.live_edge(edge) {
                for (o, wl) in workloads.iter().enumerate() {
                    for ld in 0..wl.drones {
                        let g = drone_base[o] + ld;
                        if router.homed_edge(g, o) == edge {
                            d.save_rehome(edge, g,
                                          router.override_of(g));
                            router.re_home(g, fallback);
                        }
                    }
                }
            }
            // Sweep the platform: in-flight work is always lost;
            // queued, un-pinned entries come back as relocation
            // candidates under Recovery::Requeue — federated runs
            // with a live sibling only, since without a federation
            // there is no LAN to carry them.
            let relocate = d.recovery == Recovery::Requeue
                && fed.is_some()
                && d.live_edge(edge).is_some();
            q.set_scope(edge as u32);
            let orphans = edges[edge].crash(now, relocate, q);
            if orphans.is_empty() {
                return;
            }
            let f = fed.as_mut().expect("relocation implies federation");
            let target = d
                .live_edge(edge)
                .expect("relocation implies a live sibling");
            for (task, abs_deadline, _) in orphans {
                // Same screen as try_fed_steal: the target must serve
                // the model and make the deadline after the LAN hop.
                let tp = edges[target]
                    .models
                    .iter()
                    .find(|m| m.kind == task.model);
                let transfer = f.lan.transfer_time(
                    now, task.payload_bytes(), &mut f.rng);
                let feasible = tp.map_or(false, |p| {
                    now + transfer + p.t_edge <= abs_deadline
                });
                if feasible {
                    edges[edge].metrics.fault_relocated += 1;
                    // The relocation is an offer through the steal
                    // path, so the offers ≥ arrivals ledger still
                    // closes.
                    edges[edge].metrics.fed_steals_out += 1;
                    q.set_scope(target as u32);
                    let slot = q.stash_task(task);
                    q.push(now + transfer,
                           Event::FedArrive { task: slot });
                    q.set_scope(edge as u32);
                } else {
                    edges[edge].drop_failed(now, task, q);
                }
            }
        }
        FaultAction::Recover { edge } => {
            let Some(dt) = d.mark_up(edge, now) else { return };
            edges[edge].metrics.downtime += dt;
            edges[edge].recover(now);
            // Hand the re-homed streams back: restore each drone's
            // pre-crash mapping (drones a planned handover retargeted
            // mid-downtime were already forgotten).
            for (g, prev) in d.take_rehomed(edge) {
                match prev {
                    Some(p) => router.re_home(g, p as usize),
                    None => router.clear_override(g),
                }
            }
        }
        FaultAction::OutageStart { region, until } => {
            for edge in edges.iter_mut() {
                edge.core.cloud.fault_outage(region, until);
            }
        }
        FaultAction::OutageEnd { region } => {
            for edge in edges.iter_mut() {
                edge.core.cloud.fault_outage(region, 0);
            }
        }
        FaultAction::FlapStart { link, degraded_bps } => match link {
            FlapLink::Uplink => {
                if let Some(up) = shared_up {
                    let mut u = up.lock().expect("shared uplink");
                    if d.uplink_nominal.is_none() {
                        d.uplink_nominal = Some(u.bandwidth);
                    }
                    u.bandwidth = degraded_bps;
                }
            }
            FlapLink::Lan => {
                *d.lan_degraded.lock().expect("lan flap cell") =
                    Some(degraded_bps);
            }
        },
        FaultAction::FlapEnd { link } => match link {
            FlapLink::Uplink => {
                if let Some(up) = shared_up {
                    if let Some(nom) = d.uplink_nominal.take() {
                        up.lock().expect("shared uplink").bandwidth =
                            nom;
                    }
                }
            }
            FlapLink::Lan => {
                *d.lan_degraded.lock().expect("lan flap cell") = None;
            }
        },
    }
}

/// Create the per-model tasks for one segment tick, in randomized order
/// (§3.3), and submit them to the platform's task scheduler.
#[allow(clippy::too_many_arguments)]
fn emit_segment<S: Scheduler>(platform: &mut Platform<S>, wl: &Workload,
                              now: Micros, drone: u32, tick: u64,
                              segment_id: u64, rng: &mut Rng,
                              q: &mut EventQueue) {
    let segment = VideoSegment {
        id: segment_id,
        drone,
        created_at: now,
        bytes: wl.segment_bytes,
    };
    // Pipeline workload: each tick emits ONE stage-0 chain task — the
    // chain's stages cover the app mix, and successors are minted by
    // the platform as stages complete. The branch draws nothing from
    // the arrival RNG (a 1-model plain workload's shuffle draws nothing
    // either), which keeps single-stage graphs bit-identical to the
    // plain path below.
    if let Some(graph) = &wl.pipeline {
        let drone_prefix = platform.plan_drone_prefix(graph);
        let id = platform.fresh_task_id();
        let task = Task {
            id,
            model: graph.stages[0].kind,
            segment,
            pipeline: Some(PipelineRef {
                graph: graph.clone(),
                stage: 0,
                drone_prefix,
            }),
        };
        platform.submit_task(now, task, q);
        return;
    }
    let mut due: Vec<usize> = (0..platform.models.len())
        .filter(|&i| {
            // Cadence follows the *origin* workload per model kind: on
            // the drone's home edge `platform.models == wl.models` and
            // this is the plain positional lookup; after a handover to
            // a hetero sibling, the decimation still tracks the model,
            // not whatever sits at the same index there (models the
            // origin never listed default to every tick).
            let kind = platform.models[i].kind;
            let every = wl
                .models
                .iter()
                .position(|m| m.kind == kind)
                .and_then(|j| wl.model_every.get(j))
                .copied()
                .unwrap_or(1);
            tick % every as u64 == 0
        })
        .collect();
    rng.shuffle(&mut due);
    for i in due {
        let model = platform.models[i].kind;
        let id = platform.fresh_task_id();
        let task =
            Task { id, model, segment: segment.clone(), pipeline: None };
        platform.submit_task(now, task, q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::CloudExecModel;
    use crate::net::LognormalWan;

    fn wan() -> Box<dyn CloudBackend> {
        CloudExecModel::new(Box::new(LognormalWan::default())).into()
    }

    #[test]
    fn router_partitions_drones() {
        let r = Router::uniform(3);
        assert_eq!(r.edge_of(0), 0);
        assert_eq!(r.edge_of(2), 0);
        assert_eq!(r.edge_of(3), 1);
        assert_eq!(r.global_id(2, 1), 7);
        assert_eq!(r.edge_of(r.global_id(5, 2)), 5);
        assert!(!r.is_dynamic());
    }

    #[test]
    fn router_re_home_overrides_static_mapping() {
        let mut r = Router::uniform(3);
        assert_eq!(r.edge_of(4), 1);
        r.re_home(4, 2);
        assert!(r.is_dynamic());
        assert_eq!(r.edge_of(4), 2);
        assert_eq!(r.homed_edge(4, 1), 2);
        // Untouched drones keep the static mapping (and the hetero
        // prefix-sum fallback).
        assert_eq!(r.edge_of(3), 1);
        assert_eq!(r.homed_edge(3, 1), 1);
        // A second handover replaces the first.
        r.re_home(4, 0);
        assert_eq!(r.edge_of(4), 0);
    }

    #[test]
    fn cluster_accounts_for_all_edges() {
        let wl = Workload::emulation(2, false);
        let policy = Policy::dems();
        let cm = Cluster::emulation(&policy, &wl, 9, 3, &wan).run();
        assert_eq!(cm.edges(), 3);
        assert_eq!(cm.generated(), 3 * wl.total_tasks());
        for m in &cm.per_edge {
            let closed: u64 = m
                .per_model
                .iter()
                .map(|(_, s)| s.executed() + s.dropped())
                .sum();
            assert_eq!(m.generated(), closed, "per-edge accounting closes");
        }
        assert!(cm.completion_rate() > 0.5);
    }

    #[test]
    fn median_and_minmax_are_consistent() {
        let wl = Workload::emulation(2, false);
        let cm = Cluster::emulation(&Policy::dems(), &wl, 11, 5, &wan).run();
        let (lo, hi) = cm.minmax_utility();
        let med = cm.median_edge().qos_utility();
        assert!(lo <= med && med <= hi);
        assert!(cm.total_qos_utility() >= hi);
    }

    #[test]
    fn cluster_is_deterministic() {
        let wl = Workload::emulation(2, true);
        let a = Cluster::emulation(&Policy::dems(), &wl, 4, 2, &wan).run();
        let b = Cluster::emulation(&Policy::dems(), &wl, 4, 2, &wan).run();
        assert_eq!(a, b);
    }

    #[test]
    fn churn_suppresses_inactive_drone_tasks() {
        use crate::fleet::DroneChurn;
        use crate::time::secs;
        let full = Workload::emulation(2, false).with_duration(secs(60));
        // Drone 1 leaves halfway through.
        let churned = full.clone().with_churn(DroneChurn {
            drone: 1,
            active_from: 0,
            active_until: secs(30),
        });
        let a = Cluster::emulation(&Policy::dems(), &full, 5, 1, &wan)
            .run();
        let b = Cluster::emulation(&Policy::dems(), &churned, 5, 1, &wan)
            .run();
        assert!(b.generated() < a.generated(),
                "churn must shed load: {} vs {}",
                b.generated(), a.generated());
        // Roughly one quarter of the stream is gone (one of two drones,
        // half the run).
        let ratio = b.generated() as f64 / a.generated() as f64;
        assert!((0.70..0.80).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn bursty_duty_cycle_halves_load() {
        use crate::fleet::Arrival;
        use crate::time::secs;
        let base = Workload::emulation(2, false).with_duration(secs(60));
        let bursty = base.clone().with_arrival(Arrival::Bursty {
            on: secs(5),
            off: secs(5),
        });
        let a = Cluster::emulation(&Policy::dems(), &base, 6, 1, &wan)
            .run();
        let b = Cluster::emulation(&Policy::dems(), &bursty, 6, 1, &wan)
            .run();
        let ratio = b.generated() as f64 / a.generated() as f64;
        assert!((0.40..0.60).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn poisson_arrivals_match_mean_rate_and_are_deterministic() {
        use crate::fleet::Arrival;
        use crate::time::secs;
        let base = Workload::emulation(3, false).with_duration(secs(120));
        let poisson =
            base.clone().with_arrival(Arrival::Poisson);
        let p1 = Cluster::emulation(&Policy::dems(), &poisson, 8, 1, &wan)
            .run();
        let p2 = Cluster::emulation(&Policy::dems(), &poisson, 8, 1, &wan)
            .run();
        assert_eq!(p1, p2, "Poisson streams must be seed-deterministic");
        // Same mean rate as periodic: 3 drones × 4 models ⇒ 1 440 nominal
        // tasks over 120 s; Poisson fluctuates around it.
        let nominal = base.total_tasks() as f64;
        let got = p1.generated() as f64;
        assert!((got / nominal - 1.0).abs() < 0.2,
                "poisson {got} vs nominal {nominal}");
    }

    #[test]
    fn hetero_cluster_mixes_fleet_sizes() {
        use crate::platform::Platform;
        let policy = Policy::dems();
        let wls = vec![
            Workload::emulation(2, false),
            Workload::emulation(4, true),
            Workload::emulation(3, false),
        ];
        let mut platforms = Vec::new();
        let mut seeds = Vec::new();
        for (e, wl) in wls.iter().enumerate() {
            let s = 9 ^ ((e as u64 + 1) * EDGE_SEED_PHI);
            let mut p = Platform::new(policy.clone(), wl.models.clone(),
                                      wan(), s);
            p.edge_exec = wl.edge_exec.clone();
            platforms.push(p);
            seeds.push(s ^ ARRIVAL_SEED_XOR);
        }
        let cm =
            Cluster::from_parts_hetero(platforms, wls.clone(), seeds)
                .run();
        assert_eq!(cm.edges(), 3);
        // Every edge generated exactly its own workload's task count.
        for (e, wl) in wls.iter().enumerate() {
            assert_eq!(cm.per_edge[e].generated(), wl.total_tasks(),
                       "edge {e}");
        }
        // And each edge's accounting closes independently.
        for m in &cm.per_edge {
            let closed: u64 = m
                .per_model
                .iter()
                .map(|(_, s)| s.executed() + s.dropped())
                .sum();
            assert_eq!(m.generated(), closed);
        }
    }

    #[test]
    fn hetero_drone_bases_and_router_guard() {
        use crate::platform::Platform;
        let wls = vec![
            Workload::emulation(2, false),
            Workload::emulation(4, false),
            Workload::emulation(3, false),
        ];
        let platforms: Vec<Platform> = wls
            .iter()
            .map(|wl| {
                let mut p = Platform::new(Policy::dems(),
                                          wl.models.clone(), wan(), 1);
                p.edge_exec = wl.edge_exec.clone();
                p
            })
            .collect();
        let c = Cluster::from_parts_hetero(platforms, wls,
                                           vec![1, 2, 3]);
        assert_eq!(c.first_drone(0), 0);
        assert_eq!(c.first_drone(1), 2);
        assert_eq!(c.first_drone(2), 6);
        // The flat router is undefined on mixed fleets.
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| c.router()),
        );
        assert!(r.is_err(), "router() must reject mixed fleets");
    }

    fn closed_tasks(cm: &ClusterMetrics) -> u64 {
        cm.per_edge
            .iter()
            .flat_map(|m| m.per_model.iter())
            .map(|(_, s)| s.executed() + s.dropped())
            .sum()
    }

    #[test]
    fn federated_default_is_bit_identical() {
        let wl = Workload::emulation(3, true);
        let a =
            Cluster::emulation(&Policy::dems_a(), &wl, 7, 3, &wan).run();
        let b = Cluster::emulation(&Policy::dems_a(), &wl, 7, 3, &wan)
            .federated(Federation::default())
            .run();
        assert_eq!(a, b, "all-off federation must change nothing");
    }

    #[test]
    fn handover_rehomes_stream_at_exact_window_edge() {
        use crate::time::secs;
        let wl = Workload::emulation(2, false);
        let fed = Federation::default().with_handover(Handover {
            at: secs(150),
            drone: 0,
            to_edge: 1,
        });
        let cm = Cluster::emulation(&Policy::dems(), &wl, 9, 2, &wan)
            .federated(fed)
            .run();
        // The handover is pushed at setup, so it wins the equal-
        // timestamp tie: drone 0's tick at exactly t = 150 s already
        // emits at edge 1 (150 ticks stay, 150 move; 4 models per tick).
        assert_eq!(cm.per_edge[0].generated(), (150 + 300) * 4);
        assert_eq!(cm.per_edge[1].generated(), (600 + 150) * 4);
        assert_eq!(cm.per_edge[1].handovers, 1);
        assert_eq!(cm.per_edge[0].handovers, 0);
        // No double-count: tasks admitted at the old edge before the
        // handover finish there, so each edge's accounting closes on
        // its own generation count.
        for m in &cm.per_edge {
            let closed: u64 = m
                .per_model
                .iter()
                .map(|(_, s)| s.executed() + s.dropped())
                .sum();
            assert_eq!(m.generated(), closed, "per-edge closure");
        }
        assert_eq!(cm.generated(), 2 * wl.total_tasks());
    }

    #[test]
    fn fed_steal_relieves_overloaded_sibling_and_conserves() {
        use crate::fleet::Arrival;
        use crate::time::secs;
        let policy = Policy::dems_a();
        let heavy = Workload::emulation(4, true);
        let light = Workload::emulation(2, false)
            .with_arrival(Arrival::Bursty { on: secs(2), off: secs(8) });
        let build = || {
            let wls = vec![heavy.clone(), light.clone()];
            let mut platforms = Vec::new();
            let mut seeds = Vec::new();
            for (e, w) in wls.iter().enumerate() {
                let (p, s) =
                    Cluster::edge_parts(&policy, w, 33, e, wan());
                platforms.push(p);
                seeds.push(s);
            }
            Cluster::from_parts_hetero(platforms, wls, seeds)
        };
        let iso = build().run();
        let fed = build().federated(Federation::stealing()).run();
        assert!(fed.fed_steals() > 0, "cross-edge steals occurred");
        assert!(fed.fed_offers() >= fed.fed_steals(),
                "every arrival has an offer");
        // Conservation closes cluster-wide: stolen tasks are generated
        // at the origin edge and finalized at the thief.
        assert_eq!(fed.generated(), closed_tasks(&fed));
        assert_eq!(fed.generated(), iso.generated(),
                   "stealing never changes what is generated");
    }

    #[test]
    fn empty_fault_spec_is_bit_identical() {
        let wl = Workload::emulation(3, true);
        let a =
            Cluster::emulation(&Policy::dems_a(), &wl, 7, 2, &wan).run();
        let b = Cluster::emulation(&Policy::dems_a(), &wl, 7, 2, &wan)
            .with_faults(FaultSpec::default())
            .run();
        assert_eq!(a, b, "the empty fault spec must change nothing");
    }

    #[test]
    fn crash_at_exact_handover_boundary_wins_the_tie() {
        use crate::time::secs;
        let wl = Workload::emulation(2, false);
        // The handover targets edge 1 at the very instant edge 1 dies.
        // Faults compile before handovers, so the crash wins the tie:
        // the handover falls back to the live edge 0 and drone 0 never
        // actually moves.
        let fed = Federation::default().with_handover(Handover {
            at: secs(150),
            drone: 0,
            to_edge: 1,
        });
        let spec = FaultSpec::default().crash(1, secs(150), None);
        let cm = Cluster::emulation(&Policy::dems(), &wl, 9, 2, &wan)
            .federated(fed)
            .with_faults(spec)
            .run();
        assert_eq!(cm.crashes(), 1);
        assert_eq!(cm.recoveries(), 0);
        // Edge 1's two drones re-home at the crash: their first 150
        // ticks stayed, the rest emit at edge 0 — and drone 0's full
        // stream stays at edge 0 (4 models per tick).
        assert_eq!(cm.per_edge[1].generated(), (150 + 150) * 4);
        assert_eq!(cm.per_edge[0].generated(),
                   (300 + 300 + 150 + 150) * 4);
        assert_eq!(cm.generated(), 2 * wl.total_tasks());
        // The handover still happened — onto the fallback edge.
        assert_eq!(cm.handovers(), 1);
        // A never-recovered edge is charged downtime to the horizon.
        assert_eq!(cm.per_edge[1].downtime, secs(150) + SETTLE);
        assert_eq!(cm.generated(), closed_tasks(&cm),
                   "conservation closes under the crash");
    }

    #[test]
    fn crash_mid_transit_relocation_closes_ledger_once() {
        use crate::time::secs;
        let wl = Workload::emulation(4, true);
        // Edge 0 dies and relocates its queued work to edge 1 over the
        // ~2 ms LAN; edge 1 dies 1 ms later, while those transfers are
        // still in flight. Every relocated task must close exactly once
        // (NodeFailure at the dead target), never twice.
        let spec = FaultSpec::default()
            .crash(0, secs(150), None)
            .crash(1, secs(150) + ms(1), None)
            .with_recovery(Recovery::Requeue);
        let (mut relocated, mut failures) = (0, 0);
        for seed in 0..5u64 {
            let cm = Cluster::emulation(&Policy::dems_a(), &wl, 33 + seed,
                                        2, &wan)
                .federated(Federation::stealing())
                .with_faults(spec.clone())
                .run();
            assert_eq!(cm.crashes(), 2);
            assert_eq!(cm.generated(), closed_tasks(&cm),
                       "seed {seed}: every task closes exactly once");
            assert!(cm.fed_offers() >= cm.fed_steals(),
                    "seed {seed}: offers cover arrivals");
            relocated += cm.fault_relocated();
            failures += cm.node_failures();
        }
        assert!(relocated > 0,
                "a heavy cluster relocates queued work at the crash");
        assert!(failures > 0, "in-flight work dies with the node");
    }

    #[test]
    fn recovery_readmits_rehomed_stream() {
        use crate::time::secs;
        let wl = Workload::emulation(2, false);
        let spec = FaultSpec::default()
            .crash(1, secs(100), Some(secs(200)));
        let cm = Cluster::emulation(&Policy::dems(), &wl, 9, 2, &wan)
            .with_faults(spec)
            .run();
        assert_eq!(cm.per_edge[1].crashes, 1);
        assert_eq!(cm.per_edge[1].recoveries, 1);
        assert_eq!(cm.per_edge[1].downtime, secs(100));
        // Edge 1's two drones spend ticks [100, 200) at edge 0 and
        // return at recovery: 200 of each drone's 300 ticks stay home.
        assert_eq!(cm.per_edge[1].generated(), 2 * 200 * 4);
        assert_eq!(cm.per_edge[0].generated(), (2 * 300 + 2 * 100) * 4);
        assert_eq!(cm.generated(), 2 * wl.total_tasks());
        // Unfederated Lose semantics: each edge closes its own ledger.
        for m in &cm.per_edge {
            let closed: u64 = m
                .per_model
                .iter()
                .map(|(_, s)| s.executed() + s.dropped())
                .sum();
            assert_eq!(m.generated(), closed, "per-edge closure");
        }
    }

    #[test]
    fn shared_uplink_contention_queues_and_inflates() {
        let wl = Workload::emulation(4, true);
        let free =
            Cluster::emulation(&Policy::dems(), &wl, 3, 2, &wan).run();
        let tight = Cluster::emulation(&Policy::dems(), &wl, 3, 2, &wan)
            .federated(Federation::default().with_uplink(2.0e6))
            .run();
        assert_eq!(free.uplink_wait(), 0);
        assert_eq!(free.uplink_queued(), 0);
        assert!(tight.uplink_queued() > 0,
                "concurrent dispatches must queue on a 2 MB/s backhaul");
        assert!(tight.uplink_wait() > 0);
        assert_eq!(tight.generated(), closed_tasks(&tight));
    }

    #[test]
    fn pipeline_workload_runs_chains_and_conserves() {
        let wl = Workload::vip_pipeline();
        let cm =
            Cluster::emulation(&Policy::dems(), &wl, 11, 2, &wan).run();
        assert!(cm.generated() > 0);
        assert_eq!(cm.generated(), closed_tasks(&cm),
                   "per-stage accounting closes");
        // Chains make progress end-to-end: final stages complete.
        let finals: u64 = cm
            .per_edge
            .iter()
            .map(|m| m.stats(crate::model::DnnKind::Deo).completed())
            .sum();
        assert!(finals > 0, "chains complete end-to-end");
        // All-off federation stays bit-identical under pipelines too.
        let fed = Cluster::emulation(&Policy::dems(), &wl, 11, 2, &wan)
            .federated(Federation::default())
            .run();
        assert_eq!(cm, fed);
    }

    #[test]
    fn hetero_uniform_matches_from_parts() {
        use crate::platform::Platform;
        let wl = Workload::emulation(2, true);
        let policy = Policy::dems();
        let build = |n: usize| -> Vec<Platform> {
            (0..n)
                .map(|e| {
                    let s = 3 ^ ((e as u64 + 1) * EDGE_SEED_PHI);
                    let mut p = Platform::new(policy.clone(),
                                              wl.models.clone(), wan(), s);
                    p.edge_exec = wl.edge_exec.clone();
                    p
                })
                .collect()
        };
        let seeds: Vec<u64> = (0..2u64)
            .map(|e| (3 ^ ((e + 1) * EDGE_SEED_PHI)) ^ ARRIVAL_SEED_XOR)
            .collect();
        let a = Cluster::from_parts(build(2), wl.clone(), seeds.clone())
            .run();
        let b = Cluster::from_parts_hetero(
            build(2),
            vec![wl.clone(), wl.clone()],
            seeds,
        )
        .run();
        assert_eq!(a, b, "uniform hetero must be bit-identical");
    }
}
