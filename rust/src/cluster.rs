//! Multi-edge cluster orchestration (§8.1): N platforms, a drone→edge
//! router and ONE discrete-event engine.
//!
//! The paper's emulation runs 7 edge base stations per host with 2–4 buddy
//! drones each. Pre-refactor the harness faked this by looping independent
//! single-edge simulations; a [`Cluster`] instead drives every platform
//! from a single [`EventQueue`] whose entries carry an edge scope
//! ([`EventQueue::set_scope`]), so cross-edge mechanisms added later
//! (fleet-level work stealing, shared-uplink contention, drone handover)
//! have a place to live.
//!
//! Determinism contract: per-edge event order equals the order the same
//! platform would see in an isolated run — events of different edges are
//! independent and the queue tie-breaks equal timestamps by push order,
//! which is preserved per edge. `tests/paper_shape.rs` pins this with a
//! bit-identical cluster-vs-solo comparison, which is also why the ported
//! `exp::run_edges` reproduces the paper figures unchanged.

use crate::exec::CloudExecModel;
use crate::fleet::Workload;
use crate::metrics::Metrics;
use crate::platform::Platform;
use crate::policy::Policy;
use crate::rng::Rng;
use crate::sched::Scheduler;
use crate::sim::{Event, EventQueue, SETTLE};
use crate::task::{Task, VideoSegment};
use crate::time::Micros;

/// XOR-multiplier used to derive per-edge seeds in emulation runs (the
/// same derivation the pre-cluster harness used, kept for reproducibility
/// of the recorded figures).
pub const EDGE_SEED_PHI: u64 = 0x9E37_79B9;

/// XOR applied to an edge's platform seed to derive its arrival-stream RNG.
pub const ARRIVAL_SEED_XOR: u64 = 0x5EED_F1EE7;

/// Maps fleet drones onto edge base stations: drone `g` reports to edge
/// `g / drones_per_edge` (the §8.1 setup assigns each VIP's buddy drones
/// to their personal edge).
#[derive(Clone, Copy, Debug)]
pub struct Router {
    pub drones_per_edge: u32,
}

impl Router {
    /// Edge index serving a (global) drone id.
    pub fn edge_of(&self, drone: u32) -> usize {
        (drone / self.drones_per_edge.max(1)) as usize
    }

    /// Global drone id of edge-local drone `local` on edge `edge`.
    pub fn global_id(&self, edge: usize, local: u32) -> u32 {
        edge as u32 * self.drones_per_edge + local
    }
}

/// Aggregated results of one cluster run.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterMetrics {
    pub per_edge: Vec<Metrics>,
}

impl ClusterMetrics {
    pub fn edges(&self) -> usize {
        self.per_edge.len()
    }

    pub fn generated(&self) -> u64 {
        self.per_edge.iter().map(|m| m.generated()).sum()
    }

    pub fn completed(&self) -> u64 {
        self.per_edge.iter().map(|m| m.completed()).sum()
    }

    pub fn completion_rate(&self) -> f64 {
        let g = self.generated();
        if g == 0 {
            0.0
        } else {
            self.completed() as f64 / g as f64
        }
    }

    pub fn total_qos_utility(&self) -> f64 {
        self.per_edge.iter().map(|m| m.qos_utility()).sum()
    }

    pub fn total_utility(&self) -> f64 {
        self.per_edge.iter().map(|m| m.total_utility()).sum()
    }

    /// Median-by-QoS-utility edge (the paper reports "a median edge base
    /// station").
    pub fn median_edge(&self) -> &Metrics {
        let mut idx: Vec<usize> = (0..self.per_edge.len()).collect();
        idx.sort_by(|&a, &b| {
            self.per_edge[a]
                .qos_utility()
                .partial_cmp(&self.per_edge[b].qos_utility())
                .unwrap()
        });
        &self.per_edge[idx[idx.len() / 2]]
    }

    /// (min, max) QoS utility across the edges.
    pub fn minmax_utility(&self) -> (f64, f64) {
        let us: Vec<f64> =
            self.per_edge.iter().map(|m| m.qos_utility()).collect();
        (
            us.iter().cloned().fold(f64::INFINITY, f64::min),
            us.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        )
    }
}

/// N edge platforms + drone router + per-edge arrival streams, driven by
/// one event engine.
pub struct Cluster<S: Scheduler = Box<dyn Scheduler>> {
    edges: Vec<Platform<S>>,
    workload: Workload,
    router: Router,
    /// Per-edge arrival-stream RNG (segment fan-out order, §3.3).
    arrivals: Vec<Rng>,
    /// Per-edge segment-id counters.
    segment_ids: Vec<u64>,
}

impl Cluster<Box<dyn Scheduler>> {
    /// §8.1 emulation cluster: `n_edges` stations running the same policy
    /// and per-edge workload, with the canonical per-edge seed derivation
    /// `seed ^ ((e+1)·EDGE_SEED_PHI)`.
    pub fn emulation(policy: &Policy, wl: &Workload, seed: u64,
                     n_edges: usize,
                     make_cloud: &dyn Fn() -> CloudExecModel) -> Self {
        let mut platforms = Vec::with_capacity(n_edges);
        let mut arrival_seeds = Vec::with_capacity(n_edges);
        for e in 0..n_edges {
            let s = seed ^ ((e as u64 + 1) * EDGE_SEED_PHI);
            let mut p = Platform::new(policy.clone(), wl.models.clone(),
                                      make_cloud(), s);
            p.edge_exec = wl.edge_exec.clone();
            platforms.push(p);
            arrival_seeds.push(s ^ ARRIVAL_SEED_XOR);
        }
        Cluster::from_parts(platforms, wl.clone(), arrival_seeds)
    }

    /// Single-edge cluster seeded directly with `seed` (the `simulate`
    /// path; bit-identical to the pre-cluster single-edge engine).
    pub fn single(policy: &Policy, wl: &Workload, seed: u64,
                  cloud: CloudExecModel) -> Self {
        let mut p =
            Platform::new(policy.clone(), wl.models.clone(), cloud, seed);
        p.edge_exec = wl.edge_exec.clone();
        Cluster::from_parts(vec![p], wl.clone(),
                            vec![seed ^ ARRIVAL_SEED_XOR])
    }
}

impl<S: Scheduler> Cluster<S> {
    /// Assemble a cluster from pre-built platforms. `arrival_seeds[e]`
    /// seeds edge `e`'s segment fan-out RNG.
    pub fn from_parts(edges: Vec<Platform<S>>, workload: Workload,
                      arrival_seeds: Vec<u64>) -> Self {
        assert_eq!(edges.len(), arrival_seeds.len(),
                   "one arrival seed per edge");
        let n = edges.len();
        let router = Router { drones_per_edge: workload.drones };
        Cluster {
            edges,
            workload,
            router,
            arrivals: arrival_seeds.into_iter().map(Rng::new).collect(),
            segment_ids: vec![0; n],
        }
    }

    pub fn router(&self) -> Router {
        self.router
    }

    /// Run the whole cluster to completion; returns per-edge metrics.
    pub fn run(mut self) -> ClusterMetrics {
        let wl = self.workload.clone();
        let n = self.edges.len();
        let mut q = EventQueue::new();

        // Seed every edge's drone streams (staggered phases so segment
        // arrivals don't collide on identical microsecond ticks — real
        // streams are never phase-locked) and QoE windows.
        let router = self.router;
        for (e, edge) in self.edges.iter_mut().enumerate() {
            q.set_scope(e as u32);
            for d in 0..wl.drones {
                let phase =
                    (d as Micros * 37_003) % wl.segment_period;
                q.push(phase, Event::Segment {
                    drone: router.global_id(e, d),
                    tick: 0,
                });
            }
            edge.schedule_windows(&mut q);
        }

        let horizon = wl.duration + SETTLE;
        while let Some((now, scope, ev)) = q.pop_scoped() {
            if now > horizon {
                break;
            }
            let e = scope as usize;
            q.set_scope(scope);
            match ev {
                Event::Segment { drone, tick } => {
                    if now < wl.duration {
                        self.segment_ids[e] += 1;
                        let sid = self.segment_ids[e];
                        emit_segment(&mut self.edges[e], &wl, now, drone,
                                     tick, sid, &mut self.arrivals[e],
                                     &mut q);
                        q.push(now + wl.segment_period,
                               Event::Segment { drone, tick: tick + 1 });
                    }
                }
                Event::EdgeDone => self.edges[e].on_edge_done(now, &mut q),
                Event::CloudTrigger => {
                    self.edges[e].on_cloud_trigger(now, &mut q)
                }
                Event::CloudDone { key } => {
                    self.edges[e].on_cloud_done(now, key, &mut q)
                }
                Event::WindowClose { model_idx } => {
                    if now <= wl.duration {
                        self.edges[e].on_window_close(now, model_idx,
                                                      &mut q);
                    }
                }
            }
        }

        let mut per_edge = Vec::with_capacity(n);
        for (e, mut p) in self.edges.into_iter().enumerate() {
            q.set_scope(e as u32);
            p.drain(horizon, &mut q);
            let mut m = p.into_metrics();
            m.duration = wl.duration;
            per_edge.push(m);
        }
        ClusterMetrics { per_edge }
    }
}

/// Create the per-model tasks for one segment tick, in randomized order
/// (§3.3), and submit them to the platform's task scheduler.
#[allow(clippy::too_many_arguments)]
fn emit_segment<S: Scheduler>(platform: &mut Platform<S>, wl: &Workload,
                              now: Micros, drone: u32, tick: u64,
                              segment_id: u64, rng: &mut Rng,
                              q: &mut EventQueue) {
    let segment = VideoSegment {
        id: segment_id,
        drone,
        created_at: now,
        bytes: wl.segment_bytes,
    };
    let mut due: Vec<usize> = (0..platform.models.len())
        .filter(|&i| {
            let every = wl.model_every.get(i).copied().unwrap_or(1);
            tick % every as u64 == 0
        })
        .collect();
    rng.shuffle(&mut due);
    for i in due {
        let model = platform.models[i].kind;
        let id = platform.fresh_task_id();
        let task = Task { id, model, segment: segment.clone() };
        platform.submit_task(now, task, q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LognormalWan;

    fn wan() -> CloudExecModel {
        CloudExecModel::new(Box::new(LognormalWan::default()))
    }

    #[test]
    fn router_partitions_drones() {
        let r = Router { drones_per_edge: 3 };
        assert_eq!(r.edge_of(0), 0);
        assert_eq!(r.edge_of(2), 0);
        assert_eq!(r.edge_of(3), 1);
        assert_eq!(r.global_id(2, 1), 7);
        assert_eq!(r.edge_of(r.global_id(5, 2)), 5);
    }

    #[test]
    fn cluster_accounts_for_all_edges() {
        let wl = Workload::emulation(2, false);
        let policy = Policy::dems();
        let cm = Cluster::emulation(&policy, &wl, 9, 3, &wan).run();
        assert_eq!(cm.edges(), 3);
        assert_eq!(cm.generated(), 3 * wl.total_tasks());
        for m in &cm.per_edge {
            let closed: u64 = m
                .per_model
                .iter()
                .map(|(_, s)| s.executed() + s.dropped())
                .sum();
            assert_eq!(m.generated(), closed, "per-edge accounting closes");
        }
        assert!(cm.completion_rate() > 0.5);
    }

    #[test]
    fn median_and_minmax_are_consistent() {
        let wl = Workload::emulation(2, false);
        let cm = Cluster::emulation(&Policy::dems(), &wl, 11, 5, &wan).run();
        let (lo, hi) = cm.minmax_utility();
        let med = cm.median_edge().qos_utility();
        assert!(lo <= med && med <= hi);
        assert!(cm.total_qos_utility() >= hi);
    }

    #[test]
    fn cluster_is_deterministic() {
        let wl = Workload::emulation(2, true);
        let a = Cluster::emulation(&Policy::dems(), &wl, 4, 2, &wan).run();
        let b = Cluster::emulation(&Policy::dems(), &wl, 4, 2, &wan).run();
        assert_eq!(a, b);
    }
}
