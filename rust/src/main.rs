//! `ocularone` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (hand-rolled parser; no clap in the offline build):
//!
//! * `experiment <id>` — regenerate a paper table/figure (DESIGN.md §4).
//! * `simulate` — one workload × policy simulation; `--edges N` runs the
//!   §8.1 multi-edge emulation through the `Cluster` engine.
//! * `serve` — real-time serving on the compiled PJRT artifacts, through
//!   any scheduler (`--policy`); requires the `pjrt` feature.
//! * `bench-models` — calibrate per-model PJRT latencies (`pjrt` feature).
//! * `navigate` — run the VIP navigation simulation with one scheduler.

use ocularone::bail;
use ocularone::errors::Result;
use ocularone::exp::summarize;
use ocularone::fleet::Workload;
use ocularone::model::orin_field;
use ocularone::nav;
use ocularone::policy::Policy;
use ocularone::scenario;

const USAGE: &str = "\
ocularone — adaptive edge+cloud scheduling for UAV DNN inferencing

USAGE:
  ocularone experiment <id|all|list> [--seed N] [--format md|json]
                       [--out DIR]          paper figs (t1, fig1..fig18)
                                           plus beyond-paper scenarios
                                           (poisson, churn, hetero-edges);
                                           `list` prints the registry,
                                           --out writes one file per id
  ocularone simulate [--workload 3D-A] [--policy dems] [--edges N]
                     [--seed N]            N>1 emulates N edge stations
                                           through one Cluster engine (§8.1)
  ocularone serve [--policy ec] [--rate R] [--drones D] [--secs S]
                  [--artifacts DIR]        (requires the pjrt feature)
  ocularone bench-models [--artifacts DIR] (requires the pjrt feature)
  ocularone navigate [--policy gems] [--fps 30] [--seed N]
";

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn parse_policy(name: &str) -> Result<Policy> {
    Ok(match name.to_lowercase().as_str() {
        "edf" => Policy::edge_edf(),
        "hpf" => Policy::edge_hpf(),
        "cld" | "cloud" => Policy::cloud_only(),
        "edf-ec" | "ec" | "e+c" => Policy::edf_ec(),
        "sjf-ec" | "sjf" => Policy::sjf_ec(),
        "dem" => Policy::dem(),
        "dems" => Policy::dems(),
        "dems-a" | "demsa" => Policy::dems_a(),
        "gems" => Policy::gems(false),
        "gems-a" => Policy::gems(true),
        "sota1" => Policy::sota1(),
        "sota2" => Policy::sota2(),
        other => bail!("unknown policy {other}"),
    })
}

fn parse_workload(name: &str) -> Result<Workload> {
    let up = name.to_uppercase();
    let (d, a) = match up.as_str() {
        "2D-P" => (2, false),
        "2D-A" => (2, true),
        "3D-P" => (3, false),
        "3D-A" => (3, true),
        "4D-P" => (4, false),
        "4D-A" => (4, true),
        other => bail!("unknown workload {other} (2D/3D/4D × P/A)"),
    };
    Ok(Workload::emulation(d, a))
}

/// Output format of `experiment` reports.
enum ReportFormat {
    Markdown,
    Json,
}

fn parse_format(name: &str) -> Result<ReportFormat> {
    Ok(match name.to_lowercase().as_str() {
        "md" | "markdown" => ReportFormat::Markdown,
        "json" => ReportFormat::Json,
        other => bail!("unknown format {other} (md|json)"),
    })
}

fn cmd_experiment(args: &[String], seed: u64) -> Result<()> {
    let id = match args.get(1).map(|s| s.as_str()) {
        None => "all",
        Some(s) if s.starts_with("--") => bail!(
            "experiment id must come before flags (got {s}); usage: \
             ocularone experiment <id|all|list> [--seed N] \
             [--format md|json] [--out DIR]"
        ),
        Some(s) => s,
    };
    let format = parse_format(
        &flag(args, "--format").unwrap_or_else(|| "md".into()),
    )?;
    let out = flag(args, "--out");
    if id == "list" {
        for e in scenario::registry() {
            println!(
                "{:14} {} {}",
                e.id,
                if e.paper { "[paper] " } else { "[beyond]" },
                e.about
            );
        }
        return Ok(());
    }
    if out.is_none() && matches!(format, ReportFormat::Markdown) {
        // Markdown to stdout is the library's canonical print path.
        return ocularone::exp::run_experiment(id, seed);
    }
    let ids: Vec<String> = if id == "all" {
        scenario::registry().iter().map(|e| e.id.to_string()).collect()
    } else {
        vec![id.to_string()]
    };
    if let Some(dir) = out {
        let dir = std::path::Path::new(&dir);
        std::fs::create_dir_all(dir)?;
        for id in &ids {
            let rep = scenario::run_scenario(id, seed)?;
            let (ext, body) = match format {
                ReportFormat::Markdown => ("md", rep.to_markdown()),
                ReportFormat::Json => ("json", rep.to_json()),
            };
            std::fs::write(dir.join(format!("{id}.{ext}")), body)?;
        }
        println!("wrote {} report(s) to {}", ids.len(), dir.display());
        return Ok(());
    }
    // JSON to stdout: one object per line (NDJSON when streaming "all").
    for id in &ids {
        let rep = scenario::run_scenario(id, seed)?;
        println!("{}", rep.to_json());
    }
    Ok(())
}

fn cmd_simulate(args: &[String], seed: u64) -> Result<()> {
    let wl = parse_workload(
        &flag(args, "--workload").unwrap_or_else(|| "3D-A".into()),
    )?;
    let policy = parse_policy(
        &flag(args, "--policy").unwrap_or_else(|| "dems".into()),
    )?;
    let edges: usize = flag(args, "--edges")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    if edges == 0 {
        bail!("--edges must be at least 1");
    }
    let name = policy.kind.name().to_string();
    if edges == 1 {
        let m = ocularone::simulate(policy, &wl, seed);
        println!("{} on {}: {}", name, wl.name, summarize(&m));
        return Ok(());
    }
    let cm = ocularone::simulate_cluster(policy, &wl, seed, edges);
    println!(
        "{} on {} x {} edges ({} drones, {} tasks):",
        name,
        wl.name,
        edges,
        edges as u32 * wl.drones,
        wl.cluster_total_tasks(edges),
    );
    for (e, m) in cm.per_edge.iter().enumerate() {
        println!("  edge {e}: {}", summarize(m));
    }
    let (lo, hi) = cm.minmax_utility();
    println!(
        "  cluster: done {}/{} ({:.1}%), median-edge QoS {:.0}, \
         QoS {:.0}..{:.0}, total util {:.0}",
        cm.completed(),
        cm.generated(),
        100.0 * cm.completion_rate(),
        cm.median_edge().qos_utility(),
        lo,
        hi,
        cm.total_utility(),
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String], seed: u64) -> Result<()> {
    use ocularone::runtime::Runtime;
    use ocularone::serve::{self, ServeConfig};
    use std::time::Duration;

    let dir = flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let cfg = ServeConfig {
        policy: parse_policy(
            &flag(args, "--policy").unwrap_or_else(|| "ec".into()),
        )?,
        rate: flag(args, "--rate")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(2.0),
        drones: flag(args, "--drones")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(2),
        duration: Duration::from_secs(
            flag(args, "--secs")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(10),
        ),
        seed,
        ..Default::default()
    };
    let probe = Runtime::load(&dir)?;
    println!(
        "loaded {} models on {} (policy {})",
        probe.kinds().len(),
        probe.platform_name(),
        cfg.policy.kind.name(),
    );
    drop(probe);
    let report = serve::serve(std::path::Path::new(&dir), &cfg)?;
    println!(
        "served {:.1} inferences/s over {:.1}s; completion {:.1}%",
        report.throughput(),
        report.wall_secs,
        100.0 * report.completion_rate()
    );
    for (kind, s) in &report.per_model {
        println!(
            "  {:4} done={} missed={} dropped={} cloud={} \
             p50={:.2}ms p95={:.2}ms",
            kind.name(),
            s.completed,
            s.missed,
            s.dropped,
            s.on_cloud,
            ocularone::metrics::percentile(&s.latency_ms, 0.5),
            ocularone::metrics::percentile(&s.latency_ms, 0.95),
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &[String], _seed: u64) -> Result<()> {
    bail!(
        "`serve` needs the PJRT runtime; rebuild with `--features pjrt` \
         (see docs/ARCHITECTURE.md)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_bench_models(args: &[String]) -> Result<()> {
    use ocularone::runtime::Runtime;
    use ocularone::serve;

    let dir = flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::load(&dir)?;
    println!("PJRT platform: {}", rt.platform_name());
    for (kind, p95) in serve::calibrate(&rt, 50)? {
        println!("  {:4}: p95 {:.3} ms", kind.name(), p95);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_bench_models(_args: &[String]) -> Result<()> {
    bail!(
        "`bench-models` needs the PJRT runtime; rebuild with \
         `--features pjrt` (see docs/ARCHITECTURE.md)"
    )
}

fn cmd_navigate(args: &[String], seed: u64) -> Result<()> {
    let policy = parse_policy(
        &flag(args, "--policy").unwrap_or_else(|| "gems".into()),
    )?;
    let fps: u32 = flag(args, "--fps")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);
    let wl = Workload::field(fps, orin_field());
    let name = policy.kind.name().to_string();
    let mut platform = ocularone::platform::Platform::new(
        policy,
        wl.models.clone(),
        ocularone::exec::CloudExecModel::new(Box::new(
            ocularone::net::LognormalWan::default(),
        )),
        seed,
    );
    platform.edge_exec = wl.edge_exec.clone();
    platform.metrics.record_completions = true;
    let m = ocularone::sim::run(platform, &wl, seed);
    let events: Vec<nav::TrackingEvent> = m
        .completions
        .iter()
        .filter(|c| c.model == ocularone::model::DnnKind::Hv)
        .map(|c| nav::TrackingEvent {
            at: c.at,
            success: c.success && c.latency <= ocularone::exp::FRESH,
        })
        .collect();
    let r = nav::fly(&events, m.duration, seed);
    println!("{name} @ {fps} FPS: {}", summarize(&m));
    if r.dnf {
        println!("  DNF (failsafe landing at {:.0}s)", r.dnf_at_s);
    } else {
        let (ym, ymed, y95) = r.yaw_stats();
        println!("  yaw err: mean {ym:.1}° median {ymed:.1}° p95 {y95:.1}°");
        for (ax, label) in
            ["front-back", "left-right", "up-down"].iter().enumerate()
        {
            let (_, med, p95) = r.jerk_stats(ax);
            println!("  jerk {label}: median {med:.2} p95 {p95:.2} m/s³");
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = flag(&args, "--seed")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(42);
    match args.first().map(|s| s.as_str()) {
        Some("experiment") => cmd_experiment(&args, seed),
        Some("simulate") => cmd_simulate(&args, seed),
        Some("serve") => cmd_serve(&args, seed),
        Some("bench-models") => cmd_bench_models(&args),
        Some("navigate") => cmd_navigate(&args, seed),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
