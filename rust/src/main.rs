//! `ocularone` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (hand-rolled parser; no clap in the offline build):
//!
//! * `experiment <id>` — regenerate a paper table/figure (DESIGN.md §4).
//! * `serve` — real-time serving on the compiled PJRT artifacts.
//! * `bench-models` — calibrate per-model PJRT latencies.
//! * `navigate` — run the VIP navigation simulation with one scheduler.
//! * `simulate` — one workload × policy simulation with a summary.

use std::time::Duration;

use anyhow::{bail, Result};

use ocularone::exp::{self, summarize};
use ocularone::fleet::Workload;
use ocularone::model::orin_field;
use ocularone::nav;
use ocularone::policy::Policy;
use ocularone::runtime::Runtime;
use ocularone::serve::{self, ServeConfig};
use ocularone::simulate;

const USAGE: &str = "\
ocularone — adaptive edge+cloud scheduling for UAV DNN inferencing

USAGE:
  ocularone experiment <id> [--seed N]     t1|fig1|fig2|fig8|fig10|fig11|
                                           fig13|fig14|fig17|fig18|all
  ocularone simulate [--workload 3D-A] [--policy dems] [--seed N]
  ocularone serve [--rate R] [--drones D] [--secs S] [--artifacts DIR]
  ocularone bench-models [--artifacts DIR]
  ocularone navigate [--policy gems] [--fps 30] [--seed N]
";

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn parse_policy(name: &str) -> Result<Policy> {
    Ok(match name.to_lowercase().as_str() {
        "edf" => Policy::edge_edf(),
        "hpf" => Policy::edge_hpf(),
        "cld" | "cloud" => Policy::cloud_only(),
        "edf-ec" | "ec" | "e+c" => Policy::edf_ec(),
        "sjf-ec" | "sjf" => Policy::sjf_ec(),
        "dem" => Policy::dem(),
        "dems" => Policy::dems(),
        "dems-a" | "demsa" => Policy::dems_a(),
        "gems" => Policy::gems(false),
        "gems-a" => Policy::gems(true),
        "sota1" => Policy::sota1(),
        "sota2" => Policy::sota2(),
        other => bail!("unknown policy {other}"),
    })
}

fn parse_workload(name: &str) -> Result<Workload> {
    let up = name.to_uppercase();
    let (d, a) = match up.as_str() {
        "2D-P" => (2, false),
        "2D-A" => (2, true),
        "3D-P" => (3, false),
        "3D-A" => (3, true),
        "4D-P" => (4, false),
        "4D-A" => (4, true),
        other => bail!("unknown workload {other} (2D/3D/4D × P/A)"),
    };
    Ok(Workload::emulation(d, a))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = flag(&args, "--seed")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(42);
    match args.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let id = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            exp::run_experiment(id, seed)
        }
        Some("simulate") => {
            let wl = parse_workload(
                &flag(&args, "--workload").unwrap_or_else(|| "3D-A".into()),
            )?;
            let policy = parse_policy(
                &flag(&args, "--policy").unwrap_or_else(|| "dems".into()),
            )?;
            let name = policy.kind.name().to_string();
            let m = simulate(policy, &wl, seed);
            println!("{} on {}: {}", name, wl.name, summarize(&m));
            Ok(())
        }
        Some("serve") => {
            let dir = flag(&args, "--artifacts")
                .unwrap_or_else(|| "artifacts".into());
            let cfg = ServeConfig {
                rate: flag(&args, "--rate")
                    .map(|s| s.parse())
                    .transpose()?
                    .unwrap_or(2.0),
                drones: flag(&args, "--drones")
                    .map(|s| s.parse())
                    .transpose()?
                    .unwrap_or(2),
                duration: Duration::from_secs(
                    flag(&args, "--secs")
                        .map(|s| s.parse())
                        .transpose()?
                        .unwrap_or(10),
                ),
                seed,
                ..Default::default()
            };
            let probe = Runtime::load(&dir)?;
            println!("loaded {} models on {}", probe.kinds().len(),
                     probe.platform_name());
            drop(probe);
            let report = serve::serve(std::path::Path::new(&dir), &cfg)?;
            println!(
                "served {:.1} inferences/s over {:.1}s; completion {:.1}%",
                report.throughput(),
                report.wall_secs,
                100.0 * report.completion_rate()
            );
            for (kind, s) in &report.per_model {
                println!(
                    "  {:4} done={} missed={} dropped={} cloud={} \
                     p50={:.2}ms p95={:.2}ms",
                    kind.name(),
                    s.completed,
                    s.missed,
                    s.dropped,
                    s.on_cloud,
                    ocularone::metrics::percentile(&s.latency_ms, 0.5),
                    ocularone::metrics::percentile(&s.latency_ms, 0.95),
                );
            }
            Ok(())
        }
        Some("bench-models") => {
            let dir = flag(&args, "--artifacts")
                .unwrap_or_else(|| "artifacts".into());
            let rt = Runtime::load(&dir)?;
            println!("PJRT platform: {}", rt.platform_name());
            for (kind, p95) in serve::calibrate(&rt, 50)? {
                println!("  {:4}: p95 {:.3} ms", kind.name(), p95);
            }
            Ok(())
        }
        Some("navigate") => {
            let policy = parse_policy(
                &flag(&args, "--policy").unwrap_or_else(|| "gems".into()),
            )?;
            let fps: u32 = flag(&args, "--fps")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(30);
            let wl = Workload::field(fps, orin_field());
            let name = policy.kind.name().to_string();
            let mut platform = ocularone::platform::Platform::new(
                policy,
                wl.models.clone(),
                ocularone::exec::CloudExecModel::new(Box::new(
                    ocularone::net::LognormalWan::default(),
                )),
                seed,
            );
            platform.edge_exec = wl.edge_exec.clone();
            platform.metrics.record_completions = true;
            let m = ocularone::sim::run(platform, &wl, seed);
            let events: Vec<nav::TrackingEvent> = m
                .completions
                .iter()
                .filter(|c| c.model == ocularone::model::DnnKind::Hv)
                .map(|c| nav::TrackingEvent {
                    at: c.at,
                    success: c.success
                        && c.latency <= ocularone::exp::FRESH,
                })
                .collect();
            let r = nav::fly(&events, m.duration, seed);
            println!("{name} @ {fps} FPS: {}", summarize(&m));
            if r.dnf {
                println!("  DNF (failsafe landing at {:.0}s)", r.dnf_at_s);
            } else {
                let (ym, ymed, y95) = r.yaw_stats();
                println!(
                    "  yaw err: mean {ym:.1}° median {ymed:.1}° p95 {y95:.1}°"
                );
                for (ax, label) in
                    ["front-back", "left-right", "up-down"].iter().enumerate()
                {
                    let (_, med, p95) = r.jerk_stats(ax);
                    println!(
                        "  jerk {label}: median {med:.2} p95 {p95:.2} m/s³"
                    );
                }
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
