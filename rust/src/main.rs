//! `ocularone` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (hand-rolled parser; no clap in the offline build):
//!
//! * `experiment <id>` — regenerate a paper table/figure (DESIGN.md §4).
//! * `simulate` — one workload × policy simulation; `--edges N` runs the
//!   §8.1 multi-edge emulation through the `Cluster` engine.
//! * `serve` — real-time serving on the compiled PJRT artifacts, through
//!   any scheduler (`--policy`); requires the `pjrt` feature.
//! * `bench-models` — calibrate per-model PJRT latencies (`pjrt` feature).
//! * `navigate` — run the VIP navigation simulation with one scheduler.

use ocularone::bail;
use ocularone::errors::Result;
use ocularone::exp::summarize;
use ocularone::fault::{FaultSpec, FlapLink, Recovery};
use ocularone::fleet::Workload;
use ocularone::model::orin_field;
use ocularone::nav;
use ocularone::obs::{ChromeSink, JsonlSink, SharedSink};
use ocularone::policy::Policy;
use ocularone::scenario;

const USAGE: &str = "\
ocularone — adaptive edge+cloud scheduling for UAV DNN inferencing

USAGE:
  ocularone experiment <id|all|list> [--seed N] [--format md|json]
                       [--out DIR] [--jobs N]
                                           paper figs (t1, fig1..fig18)
                                           plus beyond-paper scenarios
                                           (poisson, churn, hetero-edges);
                                           `list` prints the registry,
                                           --out writes one file per id,
                                           --jobs N sweeps on N workers
                                           (0 = all cores; reports are
                                           byte-identical to --jobs 1)
  ocularone simulate [--workload 3D-A] [--pipeline] [--policy dems]
                     [--edges N] [--seed N] [--seeds K] [--jobs N]
                     [--cloud wan|trapezium|mobility|faas|multi-region]
                     [--keep-alive SECS] [--concurrency N]
                     [--retry-after MS]
                     [--federation] [--uplink-mbps F]
                     [--handover DRONE:EDGE@SECS[,..]]
                     [--fault SPEC[,..]] [--recovery lose|requeue]
                     [--resilience breaker|hedge|degrade|all[,..]]
                     [--trace FILE] [--trace-format jsonl|chrome]
                                           N>1 emulates N edge stations
                                           through one Cluster engine (§8.1);
                                           --pipeline swaps the workload
                                           for the VIP split-DNN chain
                                           (Hv -> Md -> Deo stage graph,
                                           partitioned across drone, edge
                                           and cloud by the scheduler);
                                           --seeds K sweeps K derived seeds
                                           (in parallel with --jobs);
                                           --cloud picks the cloud backend
                                           (faas/multi-region add container
                                           keep-alive, a per-edge-account
                                           concurrency ceiling and $ cost);
                                           --federation turns on cross-edge
                                           work stealing, --uplink-mbps
                                           shares one backhaul across the
                                           stations, --handover re-homes a
                                           drone mid-run (all need
                                           --edges >= 2);
                                           --fault injects chaos:
                                           crash:EDGE@FROM[-UNTIL] kills a
                                           station (optionally rebooting),
                                           outage:REGION@FROM-UNTIL darkens
                                           a multi-region FaaS region,
                                           flap:uplink|lan@FROM-UNTIL:MBPS
                                           degrades a link for the window
                                           (times in seconds); --recovery
                                           requeue relocates a crashed
                                           station's queue over the
                                           federation LAN instead of
                                           losing it; --retry-after sets
                                           the FaaS throttle backoff hint
                                           (milliseconds, --cloud faas
                                           only); --resilience arms any
                                           subset of the resilience layer:
                                           breaker (per-backend circuit
                                           breaker), hedge (speculative
                                           cloud duplicates), degrade
                                           (lite model variants under
                                           overload), all (everything);
                                           --trace streams every task-
                                           lifecycle event to FILE as
                                           JSON-lines (default) or Chrome
                                           trace-event JSON — load the
                                           latter in Perfetto /
                                           chrome://tracing (see
                                           docs/OBSERVABILITY.md)
  ocularone serve [--policy ec] [--rate R] [--drones D] [--secs S]
                  [--artifacts DIR]        (requires the pjrt feature)
  ocularone bench-models [--artifacts DIR] (requires the pjrt feature)
  ocularone navigate [--policy gems] [--fps 30] [--seed N]
";

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Boolean flag presence (no value argument).
fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn parse_policy(name: &str) -> Result<Policy> {
    Ok(match name.to_lowercase().as_str() {
        "edf" => Policy::edge_edf(),
        "hpf" => Policy::edge_hpf(),
        "cld" | "cloud" => Policy::cloud_only(),
        "edf-ec" | "ec" | "e+c" => Policy::edf_ec(),
        "sjf-ec" | "sjf" => Policy::sjf_ec(),
        "dem" => Policy::dem(),
        "dems" => Policy::dems(),
        "dems-a" | "demsa" => Policy::dems_a(),
        "gems" => Policy::gems(false),
        "gems-a" => Policy::gems(true),
        "sota1" => Policy::sota1(),
        "sota2" => Policy::sota2(),
        other => bail!("unknown policy {other}"),
    })
}

fn parse_workload(name: &str) -> Result<Workload> {
    let up = name.to_uppercase();
    let (d, a) = match up.as_str() {
        "2D-P" => (2, false),
        "2D-A" => (2, true),
        "3D-P" => (3, false),
        "3D-A" => (3, true),
        "4D-P" => (4, false),
        "4D-A" => (4, true),
        other => bail!("unknown workload {other} (2D/3D/4D × P/A)"),
    };
    Ok(Workload::emulation(d, a))
}

/// Output format of `experiment` reports.
enum ReportFormat {
    Markdown,
    Json,
}

fn parse_format(name: &str) -> Result<ReportFormat> {
    Ok(match name.to_lowercase().as_str() {
        "md" | "markdown" => ReportFormat::Markdown,
        "json" => ReportFormat::Json,
        other => bail!("unknown format {other} (md|json)"),
    })
}

fn parse_jobs(args: &[String]) -> Result<usize> {
    Ok(flag(args, "--jobs")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1))
}

/// Cloud backend spec for `simulate` (see `scenario::CloudSpec`):
/// `--cloud faas|multi-region` takes `--keep-alive` (seconds) and
/// `--concurrency` (the in-flight ceiling of each edge station's own
/// FaaS account — one account per edge); `--cloud faas` additionally
/// takes `--retry-after` (the throttle backoff hint, milliseconds).
/// Passing any of the three with a backend it does not apply to is an
/// error, not a silent no-op.
fn parse_cloud(args: &[String]) -> Result<scenario::CloudSpec> {
    use ocularone::time::{ms, ms_f, secs};
    let name = flag(args, "--cloud").unwrap_or_else(|| "wan".into());
    let keep_alive_flag = flag(args, "--keep-alive")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .map(secs);
    let concurrency_flag: Option<usize> = flag(args, "--concurrency")
        .map(|s| s.parse())
        .transpose()?;
    let retry_after_flag = flag(args, "--retry-after")
        .map(|s| s.parse::<f64>())
        .transpose()?
        .map(ms_f);
    let keep_alive = keep_alive_flag.unwrap_or(secs(300));
    let concurrency = concurrency_flag.unwrap_or(1000);
    let spec = match name.to_lowercase().as_str() {
        "wan" | "simple" => scenario::CloudSpec::NominalWan,
        "trapezium" => scenario::CloudSpec::TrapeziumLatency,
        "mobility" => scenario::CloudSpec::MobilityBandwidth { device: 3 },
        "faas" => match retry_after_flag {
            Some(retry_after) => scenario::CloudSpec::Faas {
                keep_alive,
                concurrency,
                retry_after,
            },
            None => scenario::CloudSpec::faas(keep_alive, concurrency),
        },
        "multi-region" | "multiregion" => scenario::CloudSpec::MultiRegion {
            keep_alive,
            concurrency,
            extra_latency: ms(40),
        },
        other => bail!(
            "unknown cloud backend {other} \
             (wan|trapezium|mobility|faas|multi-region)"
        ),
    };
    if !cloud_has_accounting(&spec)
        && (keep_alive_flag.is_some() || concurrency_flag.is_some())
    {
        bail!(
            "--keep-alive/--concurrency only apply to \
             --cloud faas|multi-region (got --cloud {name})"
        );
    }
    if retry_after_flag.is_some()
        && !matches!(spec, scenario::CloudSpec::Faas { .. })
    {
        // Multi-region keeps its regions' default backoff; only the
        // single-account FaaS backend exposes the knob.
        bail!("--retry-after only applies to --cloud faas (got --cloud {name})");
    }
    Ok(spec)
}

/// Resilience arming for `simulate`: `--resilience` takes a comma list
/// of `breaker`, `hedge`, `degrade` (or `all`) and turns the named
/// mechanisms on with their default knobs (see
/// `ocularone::resilience::ResilienceSpec`). Absent, the policy runs
/// with resilience off — bit-identical to the pre-resilience engine.
fn parse_resilience(args: &[String])
                    -> Result<Option<ocularone::resilience::ResilienceSpec>> {
    use ocularone::resilience::ResilienceSpec;
    let Some(list) = flag(args, "--resilience") else {
        return Ok(None);
    };
    let mut spec = ResilienceSpec::default();
    for part in list.split(',') {
        match part.trim().to_lowercase().as_str() {
            "breaker" => spec.breaker = true,
            "hedge" => spec.hedge = true,
            "degrade" => spec.degrade = true,
            "all" => spec = ResilienceSpec::full(),
            other => bail!(
                "unknown resilience mechanism {other:?} \
                 (breaker|hedge|degrade|all)"
            ),
        }
    }
    Ok(Some(spec))
}

/// Fleet-federation spec for `simulate`: `--federation` turns on
/// cross-edge work stealing, `--uplink-mbps F` shares one F-MB/s
/// backhaul across the stations, `--handover D:E@S` re-homes global
/// drone D to edge E at S seconds (comma-separate several). All three
/// are cross-edge mechanisms, so they demand `--edges >= 2` instead of
/// being silently ignored.
fn parse_federation(args: &[String], edges: usize)
                    -> Result<Option<scenario::FederationSpec>> {
    let steal = has_flag(args, "--federation");
    let uplink_mbps: Option<f64> = flag(args, "--uplink-mbps")
        .map(|s| s.parse())
        .transpose()?;
    let mut handovers = Vec::new();
    if let Some(list) = flag(args, "--handover") {
        for part in list.split(',') {
            let (de, at) = match part.split_once('@') {
                Some(x) => x,
                None => bail!(
                    "--handover expects DRONE:EDGE@SECS, got {part:?}"
                ),
            };
            let (d, e) = match de.split_once(':') {
                Some(x) => x,
                None => bail!(
                    "--handover expects DRONE:EDGE@SECS, got {part:?}"
                ),
            };
            handovers.push(ocularone::cluster::Handover {
                drone: d.parse()?,
                to_edge: e.parse()?,
                at: ocularone::time::secs(at.parse()?),
            });
        }
    }
    let spec = scenario::FederationSpec {
        steal,
        handovers,
        uplink_bytes_per_sec: uplink_mbps.map(|m| m * 1.0e6),
    };
    if !spec.enabled() {
        return Ok(None);
    }
    if edges < 2 {
        bail!(
            "--federation/--uplink-mbps/--handover need --edges >= 2 \
             (cross-edge mechanisms on one station are no-ops)"
        );
    }
    for h in &spec.handovers {
        if h.to_edge >= edges {
            bail!("--handover target edge {} out of range ({edges} edges)",
                  h.to_edge);
        }
    }
    Ok(Some(spec))
}

/// `"FROM-UNTIL"` (seconds) → a closed fault window.
fn parse_window(s: &str) -> Result<(u64, u64)> {
    match s.split_once('-') {
        Some((a, b)) => Ok((a.parse()?, b.parse()?)),
        None => bail!("expected a FROM-UNTIL seconds window, got {s:?}"),
    }
}

/// Fault-injection spec for `simulate` (see `ocularone::fault`):
/// `--fault` takes a comma list of `crash:EDGE@FROM[-UNTIL]` (station
/// crash, optionally rebooting at UNTIL), `outage:REGION@FROM-UNTIL`
/// (FaaS region dark; needs `--cloud multi-region`) and
/// `flap:uplink|lan@FROM-UNTIL:MBPS` (link degraded to MBPS for the
/// window), all times in seconds. `--recovery lose|requeue` picks what
/// happens to a crashed station's queue; `requeue` relocates over the
/// federation LAN, so it — like the LAN/uplink flaps — demands the
/// matching federation flags instead of being silently ignored.
fn parse_faults(args: &[String], edges: usize,
                cloud: &scenario::CloudSpec,
                fed: Option<&scenario::FederationSpec>)
                -> Result<Option<FaultSpec>> {
    use ocularone::time::secs;
    let recovery_flag = flag(args, "--recovery");
    let mut spec = FaultSpec::default();
    if let Some(list) = flag(args, "--fault") {
        for part in list.split(',') {
            let (kind, rest) = match part.split_once(':') {
                Some(x) => x,
                None => bail!(
                    "--fault expects KIND:SPEC (crash|outage|flap), \
                     got {part:?}"
                ),
            };
            match kind {
                "crash" => {
                    let (edge, window) = match rest.split_once('@') {
                        Some(x) => x,
                        None => bail!(
                            "--fault crash expects crash:EDGE@FROM[-UNTIL], \
                             got {part:?}"
                        ),
                    };
                    let (at, until) = match window.split_once('-') {
                        Some((a, b)) => {
                            (a.parse()?, Some(secs(b.parse()?)))
                        }
                        None => (window.parse()?, None),
                    };
                    spec = spec.crash(edge.parse()?, secs(at), until);
                }
                "outage" => {
                    let (region, window) = match rest.split_once('@') {
                        Some(x) => x,
                        None => bail!(
                            "--fault outage expects \
                             outage:REGION@FROM-UNTIL, got {part:?}"
                        ),
                    };
                    let (from, until) = parse_window(window)?;
                    spec = spec.outage(region.parse()?, secs(from),
                                       secs(until));
                }
                "flap" => {
                    let (link, rem) = match rest.split_once('@') {
                        Some(x) => x,
                        None => bail!(
                            "--fault flap expects \
                             flap:uplink|lan@FROM-UNTIL:MBPS, got {part:?}"
                        ),
                    };
                    let link = match link {
                        "uplink" => FlapLink::Uplink,
                        "lan" => FlapLink::Lan,
                        other => bail!(
                            "unknown flap link {other:?} (uplink|lan)"
                        ),
                    };
                    let (window, mbps) = match rem.rsplit_once(':') {
                        Some(x) => x,
                        None => bail!(
                            "--fault flap expects \
                             flap:uplink|lan@FROM-UNTIL:MBPS, got {part:?}"
                        ),
                    };
                    let (from, until) = parse_window(window)?;
                    spec = spec.flap(link, secs(from), secs(until),
                                     mbps.parse::<f64>()? * 1.0e6);
                }
                other => bail!(
                    "unknown --fault kind {other:?} (crash|outage|flap)"
                ),
            }
        }
    }
    if !spec.enabled() {
        if recovery_flag.is_some() {
            bail!("--recovery needs --fault crash:...");
        }
        return Ok(None);
    }
    if let Some(r) = recovery_flag {
        spec = spec.with_recovery(match r.to_lowercase().as_str() {
            "lose" => Recovery::Lose,
            "requeue" => Recovery::Requeue,
            other => bail!("unknown recovery {other} (lose|requeue)"),
        });
    }
    if let Some(max) = spec.max_edge() {
        if max >= edges {
            bail!("--fault crash edge {max} out of range ({edges} edge(s))");
        }
    }
    if !spec.outages.is_empty()
        && !matches!(cloud, scenario::CloudSpec::MultiRegion { .. })
    {
        bail!("--fault outage:... needs --cloud multi-region");
    }
    if spec.recovery == Recovery::Requeue && fed.is_none() {
        bail!(
            "--recovery requeue relocates over the federation LAN; \
             add --federation"
        );
    }
    if spec.flaps.iter().any(|f| f.link == FlapLink::Lan) && fed.is_none() {
        bail!(
            "--fault flap:lan degrades the federation LAN; \
             add --federation"
        );
    }
    if spec.flaps.iter().any(|f| f.link == FlapLink::Uplink)
        && fed.map_or(true, |f| f.uplink_bytes_per_sec.is_none())
    {
        bail!(
            "--fault flap:uplink degrades the shared backhaul; \
             add --uplink-mbps F"
        );
    }
    Ok(Some(spec))
}

/// One-line fault summary for a cluster run.
fn fault_summary(cm: &ocularone::cluster::ClusterMetrics) -> String {
    format!(
        "faults: {} crashes ({} recovered, {:.1}s downtime), \
         {} relocated, {} node-failed",
        cm.crashes(),
        cm.recoveries(),
        cm.downtime() as f64 / 1e6,
        cm.fault_relocated(),
        cm.node_failures(),
    )
}

/// One-line federation summary for a cluster run.
fn federation_summary(cm: &ocularone::cluster::ClusterMetrics) -> String {
    format!(
        "federation: {} x-edge steals ({} offered), {} handovers, \
         uplink queued {} ({:.1}s delay)",
        cm.fed_steals(),
        cm.fed_offers(),
        cm.handovers(),
        cm.uplink_queued(),
        cm.uplink_wait() as f64 / 1e6,
    )
}

/// True when the spec carries FaaS accounting worth printing.
fn cloud_has_accounting(spec: &scenario::CloudSpec) -> bool {
    matches!(
        spec,
        scenario::CloudSpec::Faas { .. }
            | scenario::CloudSpec::MultiRegion { .. }
    )
}

/// One-line cloud accounting summary for a cluster run.
fn cloud_summary(cm: &ocularone::cluster::ClusterMetrics) -> String {
    let s = cm.cloud_stats();
    format!(
        "cloud: ${:.4} ({} invocations, {} cold {:.1}%, {} throttled, \
         {:.1} GB-s)",
        s.dollars,
        s.invocations,
        s.cold_starts,
        100.0 * s.cold_start_rate(),
        cm.throttled(),
        s.gb_seconds,
    )
}

/// One-line resilience summary for a cluster run.
fn resilience_summary(cm: &ocularone::cluster::ClusterMetrics) -> String {
    format!(
        "resilience: breaker {} trips ({} shorted, {} probes), \
         hedge {} launched ({} won, {} cancelled), {} degraded \
         (-{:.0} util)",
        cm.breaker_trips(),
        cm.breaker_shorted(),
        cm.breaker_probes(),
        cm.hedge_launches(),
        cm.hedge_wins(),
        cm.hedge_cancels(),
        cm.degraded_tasks(),
        cm.degraded_utility_lost(),
    )
}

fn cmd_experiment(args: &[String], seed: u64) -> Result<()> {
    let id = match args.get(1).map(|s| s.as_str()) {
        None => "all",
        Some(s) if s.starts_with("--") => bail!(
            "experiment id must come before flags (got {s}); usage: \
             ocularone experiment <id|all|list> [--seed N] \
             [--format md|json] [--out DIR] [--jobs N]"
        ),
        Some(s) => s,
    };
    let format = parse_format(
        &flag(args, "--format").unwrap_or_else(|| "md".into()),
    )?;
    let out = flag(args, "--out");
    let jobs = parse_jobs(args)?;
    if id == "list" {
        for e in scenario::registry() {
            println!(
                "{:14} {} {}",
                e.id,
                if e.paper { "[paper] " } else { "[beyond]" },
                e.about
            );
        }
        return Ok(());
    }
    if out.is_none() && matches!(format, ReportFormat::Markdown) {
        // Markdown to stdout is the library's canonical print path.
        return ocularone::exp::run_experiment(id, seed, jobs);
    }
    let ids: Vec<String> = if id == "all" {
        scenario::registry().iter().map(|e| e.id.to_string()).collect()
    } else {
        vec![id.to_string()]
    };
    let dir = match &out {
        Some(d) => {
            let p = std::path::PathBuf::from(d);
            std::fs::create_dir_all(&p)?;
            Some(p)
        }
        None => None,
    };
    // Emit one finished report: a file under --out, else one JSON object
    // per line on stdout (NDJSON when streaming "all").
    let emit = |id: &str, rep: &ocularone::report::Report| -> Result<()> {
        match &dir {
            Some(dir) => {
                let (ext, body) = match format {
                    ReportFormat::Markdown => ("md", rep.to_markdown()),
                    ReportFormat::Json => ("json", rep.to_json()),
                };
                std::fs::write(dir.join(format!("{id}.{ext}")), body)?;
            }
            None => println!("{}", rep.to_json()),
        }
        Ok(())
    };
    let pool = ocularone::pool::Pool::new(jobs);
    if ids.len() > 1 && pool.workers() > 1 {
        // "all" parallelizes across experiments (one pool job each);
        // output stays in registry order, independent of the schedule.
        let reports =
            pool.run(ids.len(), |i| scenario::run_scenario(&ids[i], seed));
        for (id, rep) in ids.iter().zip(reports) {
            emit(id, &rep?)?;
        }
    } else {
        // Sequential (or single id): stream each report as it finishes
        // and stop at the first error. A single id spends the jobs
        // budget on its own grid cells instead.
        for id in &ids {
            let rep = scenario::run_scenario_jobs(id, seed, jobs)?;
            emit(id, &rep)?;
        }
    }
    if let Some(dir) = &dir {
        println!("wrote {} report(s) to {}", ids.len(), dir.display());
    }
    Ok(())
}

/// Task-lifecycle tracing for `simulate`: `--trace FILE` streams every
/// engine event to FILE through a shared [`TraceSink`];
/// `--trace-format` picks the writer — `jsonl` (default, one JSON
/// object per line) or `chrome` (Chrome trace-event array, loadable in
/// Perfetto / `chrome://tracing`).
///
/// [`TraceSink`]: ocularone::obs::TraceSink
fn parse_trace(args: &[String]) -> Result<Option<SharedSink>> {
    use std::sync::{Arc, Mutex};
    let Some(path) = flag(args, "--trace") else {
        if flag(args, "--trace-format").is_some() {
            bail!("--trace-format requires --trace FILE");
        }
        return Ok(None);
    };
    let format =
        flag(args, "--trace-format").unwrap_or_else(|| "jsonl".into());
    let w = std::io::BufWriter::new(std::fs::File::create(&path)?);
    let sink: SharedSink = match format.as_str() {
        "jsonl" => Arc::new(Mutex::new(JsonlSink::new(w))),
        "chrome" => Arc::new(Mutex::new(ChromeSink::new(w))),
        other => {
            bail!("unknown trace format '{other}' (expected jsonl|chrome)")
        }
    };
    Ok(Some(sink))
}

/// Flush and close a `--trace` sink after the run (writes the Chrome
/// array terminator; a poisoned lock means a writer panicked mid-run).
fn finish_trace(sink: &Option<SharedSink>) {
    if let Some(s) = sink {
        s.lock().expect("trace sink poisoned").finish();
    }
}

fn cmd_simulate(args: &[String], seed: u64) -> Result<()> {
    let wl = if has_flag(args, "--pipeline") {
        if flag(args, "--workload").is_some() {
            bail!("--pipeline replaces the workload; drop --workload");
        }
        Workload::vip_pipeline()
    } else {
        parse_workload(
            &flag(args, "--workload").unwrap_or_else(|| "3D-A".into()),
        )?
    };
    let mut policy = parse_policy(
        &flag(args, "--policy").unwrap_or_else(|| "dems".into()),
    )?;
    let resilient = match parse_resilience(args)? {
        Some(spec) => {
            policy = policy.with_resilience(spec);
            true
        }
        None => false,
    };
    let edges: usize = flag(args, "--edges")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    if edges == 0 {
        bail!("--edges must be at least 1");
    }
    let sweeps: u64 = flag(args, "--seeds")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    let jobs = parse_jobs(args)?;
    let cloud = parse_cloud(args)?;
    let fed = parse_federation(args, edges)?;
    let faults = parse_faults(args, edges, &cloud, fed.as_ref())?;
    let trace = parse_trace(args)?;
    let name = policy.kind.name().to_string();
    if sweeps > 1 {
        if trace.is_some() {
            bail!("--trace records one run; drop --seeds");
        }
        return simulate_sweep(&name, policy, &wl, seed, edges, sweeps,
                              jobs, &cloud, fed.as_ref(),
                              faults.as_ref());
    }
    if edges == 1 {
        let cm = scenario::run_cluster_observed(&policy, &wl, seed, 1,
                                                &cloud, None,
                                                faults.as_ref(),
                                                trace.clone(), None);
        finish_trace(&trace);
        println!("{} on {}: {}", name, wl.name,
                 summarize(&cm.per_edge[0]));
        if cloud_has_accounting(&cloud) {
            println!("  {}", cloud_summary(&cm));
        }
        if faults.is_some() {
            println!("  {}", fault_summary(&cm));
        }
        if resilient {
            println!("  {}", resilience_summary(&cm));
        }
        return Ok(());
    }
    let cm = scenario::run_cluster_observed(&policy, &wl, seed, edges,
                                            &cloud, fed.as_ref(),
                                            faults.as_ref(),
                                            trace.clone(), None);
    finish_trace(&trace);
    println!(
        "{} on {} x {} edges ({} drones, {} tasks):",
        name,
        wl.name,
        edges,
        edges as u32 * wl.drones,
        wl.cluster_total_tasks(edges),
    );
    for (e, m) in cm.per_edge.iter().enumerate() {
        println!("  edge {e}: {}", summarize(m));
    }
    let (lo, hi) = cm.minmax_utility();
    println!(
        "  cluster: done {}/{} ({:.1}%), median-edge QoS {:.0}, \
         QoS {:.0}..{:.0}, total util {:.0}",
        cm.completed(),
        cm.generated(),
        100.0 * cm.completion_rate(),
        cm.median_edge().qos_utility(),
        lo,
        hi,
        cm.total_utility(),
    );
    if cloud_has_accounting(&cloud) {
        println!("  {}", cloud_summary(&cm));
    }
    if fed.is_some() {
        println!("  {}", federation_summary(&cm));
    }
    if faults.is_some() {
        println!("  {}", fault_summary(&cm));
    }
    if resilient {
        println!("  {}", resilience_summary(&cm));
    }
    Ok(())
}

/// `simulate --seeds K`: run the same workload × policy × edges cell over
/// K derived seeds (`seed + i·SEED_STRIDE`, the scenario sweep
/// derivation), in parallel on `--jobs` workers, and summarize the
/// spread. Per-seed results are independent pool jobs, so the printed
/// order and every number are identical for any `--jobs` value.
#[allow(clippy::too_many_arguments)]
fn simulate_sweep(name: &str, policy: Policy, wl: &Workload, seed: u64,
                  edges: usize, sweeps: u64, jobs: usize,
                  cloud: &scenario::CloudSpec,
                  fed: Option<&scenario::FederationSpec>,
                  faults: Option<&FaultSpec>) -> Result<()> {
    use ocularone::metrics::percentile;

    let runs = ocularone::pool::Pool::new(jobs).run(
        sweeps as usize,
        |i| {
            let s = seed
                .wrapping_add((i as u64).wrapping_mul(scenario::SEED_STRIDE));
            scenario::run_cluster_faulted(&policy, wl, s, edges, cloud,
                                          fed, faults)
        },
    );
    println!(
        "{} on {} x {} edge(s), {} seeds:",
        name, wl.name, edges, sweeps
    );
    for (i, cm) in runs.iter().enumerate() {
        println!(
            "  seed#{i}: done {}/{} ({:.1}%), median-edge QoS {:.0}, \
             total util {:.0}",
            cm.completed(),
            cm.generated(),
            100.0 * cm.completion_rate(),
            cm.median_edge().qos_utility(),
            cm.total_utility(),
        );
    }
    let rates: Vec<f64> =
        runs.iter().map(|cm| 100.0 * cm.completion_rate()).collect();
    let qos: Vec<f64> =
        runs.iter().map(|cm| cm.median_edge().qos_utility()).collect();
    println!(
        "  sweep: done% p0/p50/p100 {:.1}/{:.1}/{:.1}, \
         median-edge QoS p0/p50/p100 {:.0}/{:.0}/{:.0}",
        percentile(&rates, 0.0),
        percentile(&rates, 0.5),
        percentile(&rates, 1.0),
        percentile(&qos, 0.0),
        percentile(&qos, 0.5),
        percentile(&qos, 1.0),
    );
    if cloud_has_accounting(cloud) {
        let dollars: f64 =
            runs.iter().map(|cm| cm.cloud_stats().dollars).sum();
        let throttled: u64 = runs.iter().map(|cm| cm.throttled()).sum();
        println!(
            "  cloud: ${dollars:.4} total across seeds, \
             {throttled} throttled"
        );
    }
    if fed.is_some() {
        let steals: u64 = runs.iter().map(|cm| cm.fed_steals()).sum();
        let handovers: u64 = runs.iter().map(|cm| cm.handovers()).sum();
        let queued: u64 = runs.iter().map(|cm| cm.uplink_queued()).sum();
        println!(
            "  federation: {steals} x-edge steals, {handovers} \
             handovers, {queued} uplink-queued across seeds"
        );
    }
    if faults.is_some() {
        let crashes: u64 = runs.iter().map(|cm| cm.crashes()).sum();
        let relocated: u64 =
            runs.iter().map(|cm| cm.fault_relocated()).sum();
        let failed: u64 = runs.iter().map(|cm| cm.node_failures()).sum();
        println!(
            "  faults: {crashes} crashes, {relocated} relocated, \
             {failed} node-failed across seeds"
        );
    }
    if policy.resilience.enabled() {
        let trips: u64 = runs.iter().map(|cm| cm.breaker_trips()).sum();
        let hedges: u64 = runs.iter().map(|cm| cm.hedge_launches()).sum();
        let degraded: u64 =
            runs.iter().map(|cm| cm.degraded_tasks()).sum();
        println!(
            "  resilience: {trips} breaker trips, {hedges} hedges, \
             {degraded} degraded across seeds"
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String], seed: u64) -> Result<()> {
    use ocularone::runtime::Runtime;
    use ocularone::serve::{self, ServeConfig};
    use std::time::Duration;

    let dir = flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let cfg = ServeConfig {
        policy: parse_policy(
            &flag(args, "--policy").unwrap_or_else(|| "ec".into()),
        )?,
        rate: flag(args, "--rate")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(2.0),
        drones: flag(args, "--drones")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(2),
        duration: Duration::from_secs(
            flag(args, "--secs")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(10),
        ),
        seed,
        ..Default::default()
    };
    let probe = Runtime::load(&dir)?;
    println!(
        "loaded {} models on {} (policy {})",
        probe.kinds().len(),
        probe.platform_name(),
        cfg.policy.kind.name(),
    );
    drop(probe);
    let report = serve::serve(std::path::Path::new(&dir), &cfg)?;
    println!(
        "served {:.1} inferences/s over {:.1}s; completion {:.1}%",
        report.throughput(),
        report.wall_secs,
        100.0 * report.completion_rate()
    );
    for (kind, s) in &report.per_model {
        println!(
            "  {:4} done={} missed={} dropped={} cloud={} \
             p50={:.2}ms p95={:.2}ms",
            kind.name(),
            s.completed,
            s.missed,
            s.dropped,
            s.on_cloud,
            ocularone::metrics::percentile(&s.latency_ms, 0.5),
            ocularone::metrics::percentile(&s.latency_ms, 0.95),
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &[String], _seed: u64) -> Result<()> {
    bail!(
        "`serve` needs the PJRT runtime; rebuild with `--features pjrt` \
         (see docs/ARCHITECTURE.md)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_bench_models(args: &[String]) -> Result<()> {
    use ocularone::runtime::Runtime;
    use ocularone::serve;

    let dir = flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::load(&dir)?;
    println!("PJRT platform: {}", rt.platform_name());
    for (kind, p95) in serve::calibrate(&rt, 50)? {
        println!("  {:4}: p95 {:.3} ms", kind.name(), p95);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_bench_models(_args: &[String]) -> Result<()> {
    bail!(
        "`bench-models` needs the PJRT runtime; rebuild with \
         `--features pjrt` (see docs/ARCHITECTURE.md)"
    )
}

fn cmd_navigate(args: &[String], seed: u64) -> Result<()> {
    let policy = parse_policy(
        &flag(args, "--policy").unwrap_or_else(|| "gems".into()),
    )?;
    let fps: u32 = flag(args, "--fps")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);
    let wl = Workload::field(fps, orin_field());
    let name = policy.kind.name().to_string();
    let mut platform = ocularone::platform::Platform::new(
        policy,
        wl.models.clone(),
        ocularone::exec::CloudExecModel::new(Box::new(
            ocularone::net::LognormalWan::default(),
        )),
        seed,
    );
    platform.edge_exec = wl.edge_exec.clone();
    platform.metrics.record_completions = true;
    let m = ocularone::sim::run(platform, &wl, seed);
    let events: Vec<nav::TrackingEvent> = m
        .completions
        .iter()
        .filter(|c| c.model == ocularone::model::DnnKind::Hv)
        .map(|c| nav::TrackingEvent {
            at: c.at,
            success: c.success && c.latency <= ocularone::exp::FRESH,
        })
        .collect();
    let r = nav::fly(&events, m.duration, seed);
    println!("{name} @ {fps} FPS: {}", summarize(&m));
    if r.dnf {
        println!("  DNF (failsafe landing at {:.0}s)", r.dnf_at_s);
    } else {
        let (ym, ymed, y95) = r.yaw_stats();
        println!("  yaw err: mean {ym:.1}° median {ymed:.1}° p95 {y95:.1}°");
        for (ax, label) in
            ["front-back", "left-right", "up-down"].iter().enumerate()
        {
            let (_, med, p95) = r.jerk_stats(ax);
            println!("  jerk {label}: median {med:.2} p95 {p95:.2} m/s³");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use ocularone::time::{ms_f, secs};

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    /// Every generated task reached exactly one terminal state.
    fn assert_conserved(cm: &ocularone::cluster::ClusterMetrics) {
        let closed: u64 = cm
            .per_edge
            .iter()
            .map(|m| m.executed() + m.dropped())
            .sum();
        assert_eq!(cm.generated(), closed,
                   "every task must be accounted for exactly once");
    }

    // ---- `--fault` grammar corner cases ---------------------------------

    #[test]
    fn overlapping_crash_windows_on_one_station_both_parse() {
        let args = argv(&[
            "simulate", "--edges", "2", "--fault",
            "crash:0@50-100,crash:0@80-120",
        ]);
        let cloud = parse_cloud(&args).unwrap();
        let spec = parse_faults(&args, 2, &cloud, None).unwrap().unwrap();
        assert_eq!(spec.crashes.len(), 2,
                   "overlapping windows are both kept, not merged");
        assert!(spec.crashes.iter().all(|c| c.edge == 0));
        assert_eq!(spec.crashes[0].at, secs(50));
        assert_eq!(spec.crashes[0].recover_at, Some(secs(100)));
        assert_eq!(spec.crashes[1].at, secs(80));
        assert_eq!(spec.crashes[1].recover_at, Some(secs(120)));
        // The overlapping pair still drives a deterministic run: the
        // second crash lands on an already-dead station and the engine
        // must neither double-kill nor double-reboot it.
        let wl = Workload::emulation(2, false).with_duration(secs(150));
        let cm = scenario::run_cluster_faulted(
            &Policy::dems_a(), &wl, 7, 2,
            &scenario::CloudSpec::NominalWan, None, Some(&spec),
        );
        assert_conserved(&cm);
    }

    #[test]
    fn crash_without_reboot_then_handover_to_the_dead_edge() {
        // Station 1 dies at 50 s and never reboots; a handover scheduled
        // at 100 s re-homes drone 0 onto that dead station. The grammar
        // accepts the composition and the cluster falls back instead of
        // wedging.
        let args = argv(&[
            "simulate", "--edges", "2", "--federation",
            "--handover", "0:1@100",
            "--fault", "crash:1@50",
        ]);
        let cloud = parse_cloud(&args).unwrap();
        let fed = parse_federation(&args, 2).unwrap().unwrap();
        let spec =
            parse_faults(&args, 2, &cloud, Some(&fed)).unwrap().unwrap();
        assert_eq!(spec.crashes.len(), 1);
        assert_eq!(spec.crashes[0].edge, 1);
        assert_eq!(spec.crashes[0].at, secs(50));
        assert_eq!(spec.crashes[0].recover_at, None, "no reboot scheduled");
        assert_eq!(fed.handovers.len(), 1);
        assert_eq!(fed.handovers[0].to_edge, 1,
                   "handover targets the station that will be dead");
        let wl = Workload::emulation(2, false).with_duration(secs(150));
        let cm = scenario::run_cluster_faulted(
            &Policy::dems_a(), &wl, 7, 2, &cloud, Some(&fed), Some(&spec),
        );
        assert_conserved(&cm);
        assert_eq!(cm.crashes(), 1);
        assert_eq!(cm.recoveries(), 0, "the station never reboots");
    }

    #[test]
    fn fault_grammar_rejections() {
        let cloud = scenario::CloudSpec::NominalWan;
        // Crash edge out of range.
        let args = argv(&["simulate", "--fault", "crash:3@50"]);
        assert!(parse_faults(&args, 2, &cloud, None).is_err());
        // Outage without a multi-region backend.
        let args = argv(&["simulate", "--fault", "outage:0@50-100"]);
        assert!(parse_faults(&args, 2, &cloud, None).is_err());
        // Requeue recovery without federation.
        let args = argv(&[
            "simulate", "--fault", "crash:0@50", "--recovery", "requeue",
        ]);
        assert!(parse_faults(&args, 2, &cloud, None).is_err());
        // Recovery flag with no fault at all.
        let args = argv(&["simulate", "--recovery", "lose"]);
        assert!(parse_faults(&args, 2, &cloud, None).is_err());
    }

    // ---- `--retry-after` gating -----------------------------------------

    #[test]
    fn retry_after_reaches_the_faas_spec_and_defaults_pin() {
        let args = argv(&[
            "simulate", "--cloud", "faas", "--retry-after", "350",
        ]);
        match parse_cloud(&args).unwrap() {
            scenario::CloudSpec::Faas { retry_after, .. } => {
                assert_eq!(retry_after, ms_f(350.0));
            }
            other => panic!("expected Faas, got {other:?}"),
        }
        // Default stays the backend's pinned 200 ms backoff.
        let args = argv(&["simulate", "--cloud", "faas"]);
        match parse_cloud(&args).unwrap() {
            scenario::CloudSpec::Faas { retry_after, .. } => {
                assert_eq!(retry_after, ms_f(200.0));
            }
            other => panic!("expected Faas, got {other:?}"),
        }
    }

    #[test]
    fn retry_after_rejected_off_the_faas_backend() {
        for cloud in ["wan", "multi-region"] {
            let args = argv(&[
                "simulate", "--cloud", cloud, "--retry-after", "350",
            ]);
            assert!(parse_cloud(&args).is_err(),
                    "--retry-after must be rejected for --cloud {cloud}");
        }
    }

    // ---- `--resilience` parsing -----------------------------------------

    #[test]
    fn resilience_list_arms_the_named_mechanisms() {
        let spec = parse_resilience(
            &argv(&["simulate", "--resilience", "breaker,degrade"]),
        ).unwrap().unwrap();
        assert!(spec.breaker && spec.degrade && !spec.hedge);
        let spec = parse_resilience(
            &argv(&["simulate", "--resilience", "all"]),
        ).unwrap().unwrap();
        assert!(spec.breaker && spec.hedge && spec.degrade);
        assert!(parse_resilience(&argv(&["simulate"])).unwrap().is_none());
        assert!(parse_resilience(
            &argv(&["simulate", "--resilience", "breaker,nope"]),
        ).is_err());
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = flag(&args, "--seed")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(42);
    match args.first().map(|s| s.as_str()) {
        Some("experiment") => cmd_experiment(&args, seed),
        Some("simulate") => cmd_simulate(&args, seed),
        Some("serve") => cmd_serve(&args, seed),
        Some("bench-models") => cmd_bench_models(&args),
        Some("navigate") => cmd_navigate(&args, seed),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
