//! Scheduling policies: DEMS and its ablations, plus the seven baselines
//! of §8.2. A [`Policy`] is a declarative description — which heuristic
//! family runs and with which knobs — that resolves into an executable
//! [`Scheduler`](crate::sched::Scheduler) via [`Policy::build`]. The
//! platform substrate ([`crate::platform`]) reads only the mechanism-ish
//! switches (`use_edge`, `use_cloud`, `edge_jit_drop`,
//! `cloud_accepts_negative`); everything else is interpreted by the
//! scheduler implementations in [`crate::sched`].

use crate::queues::EdgeOrder;
use crate::resilience::ResilienceSpec;
use crate::sched::{CloudOnly, Dems, EcBaseline, EdgeOnly, Gems, Scheduler,
                   Sota1, Sota2};
use crate::time::{ms, secs, Micros};

/// Which named algorithm this policy encodes (for reports).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Edge-only, earliest-deadline-first.
    EdgeEdf,
    /// Edge-only, highest-utility-per-time-first.
    EdgeHpf,
    /// Cloud-only FaaS scheduling.
    CloudOnly,
    /// EDF on edge + FIFO cloud offload (the E+C baseline, §5.1).
    EdfEC,
    /// SJF on edge + FIFO cloud offload (sends even negative-utility tasks).
    SjfEC,
    /// E+C + migration scoring (§5.2).
    Dem,
    /// DEM + work stealing with deferred cloud triggers (§5.3).
    Dems,
    /// DEMS + adaptation to network variability (§5.4).
    DemsA,
    /// DEMS(-A) + the QoE window monitor of Algorithm 1 (§6).
    Gems,
    /// Kalmia + D3 hybrid (urgent/non-urgent split, deadline extension).
    Sota1,
    /// Dedas-style insertion by exec time with ACT comparison.
    Sota2,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::EdgeEdf => "EDF",
            PolicyKind::EdgeHpf => "HPF",
            PolicyKind::CloudOnly => "CLD",
            PolicyKind::EdfEC => "EDF (E+C)",
            PolicyKind::SjfEC => "SJF (E+C)",
            PolicyKind::Dem => "DEM",
            PolicyKind::Dems => "DEMS",
            PolicyKind::DemsA => "DEMS-A",
            PolicyKind::Gems => "GEMS",
            PolicyKind::Sota1 => "SOTA 1",
            PolicyKind::Sota2 => "SOTA 2",
        }
    }
}

/// How split-DNN pipeline chains are partitioned across drone, edge and
/// cloud (see [`crate::pipeline`]). Non-pipeline workloads ignore this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineCut {
    /// The scheduler decides per chain/stage: the drone prefix is planned
    /// from per-stage deadline budgets at admission, and edge-vs-cloud
    /// falls out of the family's own admission path with the stage-aware
    /// κ̂ ranking ([`crate::pipeline::chain_util_cloud`]).
    Adaptive,
    /// A fixed partition (the baselines and the partition sweep): stages
    /// `0..drone` run on the drone's companion computer, stages
    /// `cloud_start..` are pinned to the cloud, the rest go straight to
    /// the edge queue. `drone <= cloud_start` is assumed.
    Fixed { drone: usize, cloud_start: usize },
}

/// Declarative scheduler configuration.
#[derive(Clone, Debug)]
pub struct Policy {
    pub kind: PolicyKind,
    pub edge_order: EdgeOrder,
    pub use_edge: bool,
    pub use_cloud: bool,
    /// DEM migration scoring on insert (Eqn 3).
    pub migration: bool,
    /// Work stealing from the cloud queue (§5.3).
    pub stealing: bool,
    /// Defer cloud dispatch to trigger times (§5.3); otherwise FIFO-now.
    pub defer_cloud: bool,
    /// Sliding-window adaptation of expected cloud times (§5.4).
    pub adaptive: bool,
    /// GEMS QoE window monitor (Alg. 1).
    pub gems: bool,
    /// Cloud accepts tasks with γᶜ ≤ 0 for execution (SJF E+C / SOTA do).
    pub cloud_accepts_negative: bool,
    /// Edge executor drops JIT-expired tasks before execution. The hybrid
    /// schedulers do (§3.3); the edge-only baselines have nowhere to shed
    /// load and execute in priority order regardless — the §8.8 mechanism
    /// behind EO's collapse at 30 FPS ("HV tasks expire due to queuing
    /// delays... the drone is unable to fly beyond a few seconds").
    pub edge_jit_drop: bool,
    /// Safety margin subtracted when computing cloud trigger times.
    pub safety_margin: Micros,
    /// §5.4 parameters: sliding window size w, threshold ε, cooling t_cp.
    pub adapt_window: usize,
    pub adapt_epsilon: Micros,
    pub cooling_period: Micros,
    /// SOTA 1: urgency threshold on δ and the per-retry deadline stretch.
    pub sota1_urgent_below: Micros,
    pub sota1_extension: f64,
    /// Split-DNN pipeline partitioning (ignored without pipeline
    /// workloads): adaptive per-chain cuts or a fixed partition.
    pub pipeline: PipelineCut,
    /// Resilience mechanisms (circuit breaker / hedged requests /
    /// graceful degradation — see [`crate::resilience`]). All-off by
    /// default: the engine then builds no state machines and stays
    /// bit-identical to the plain paths.
    pub resilience: ResilienceSpec,
}

impl Policy {
    fn base(kind: PolicyKind) -> Policy {
        Policy {
            kind,
            edge_order: EdgeOrder::Edf,
            use_edge: true,
            use_cloud: true,
            migration: false,
            stealing: false,
            defer_cloud: false,
            adaptive: false,
            gems: false,
            cloud_accepts_negative: false,
            edge_jit_drop: true,
            safety_margin: ms(100),
            adapt_window: 10,
            adapt_epsilon: ms(10),
            cooling_period: secs(10),
            sota1_urgent_below: ms(750),
            sota1_extension: 0.10,
            pipeline: PipelineCut::Adaptive,
            resilience: ResilienceSpec::default(),
        }
    }

    /// Pin the split-DNN partition point (see [`PipelineCut`]); used by
    /// the fixed-cut baselines and the `partition-sweep` scenario.
    pub fn with_pipeline_cut(self, cut: PipelineCut) -> Policy {
        Policy { pipeline: cut, ..self }
    }

    /// Opt this policy into the resilience layer (breaker / hedge /
    /// degrade per the spec's flags — see
    /// [`ResilienceSpec`](crate::resilience::ResilienceSpec)). Orthogonal
    /// to the heuristic family: any scheduler can run resilient.
    pub fn with_resilience(self, spec: ResilienceSpec) -> Policy {
        Policy { resilience: spec, ..self }
    }

    pub fn edge_edf() -> Policy {
        Policy { use_cloud: false, ..Self::base(PolicyKind::EdgeEdf) }
    }

    pub fn edge_hpf() -> Policy {
        Policy {
            use_cloud: false,
            edge_order: EdgeOrder::Hpf,
            ..Self::base(PolicyKind::EdgeHpf)
        }
    }

    /// §8.8's Edge-Only configuration: the field platform executes frames
    /// in priority order without JIT shedding (there is no cloud to shed
    /// to and the app consumes every output) — the configuration whose
    /// 30 FPS overload collapse the paper reports as DNF.
    pub fn edge_only_field() -> Policy {
        Policy { edge_jit_drop: false, ..Self::edge_edf() }
    }

    pub fn cloud_only() -> Policy {
        Policy { use_edge: false, ..Self::base(PolicyKind::CloudOnly) }
    }

    pub fn edf_ec() -> Policy {
        Self::base(PolicyKind::EdfEC)
    }

    pub fn sjf_ec() -> Policy {
        Policy {
            edge_order: EdgeOrder::Sjf,
            cloud_accepts_negative: true,
            ..Self::base(PolicyKind::SjfEC)
        }
    }

    pub fn dem() -> Policy {
        Policy { migration: true, ..Self::base(PolicyKind::Dem) }
    }

    pub fn dems() -> Policy {
        Policy {
            migration: true,
            stealing: true,
            defer_cloud: true,
            ..Self::base(PolicyKind::Dems)
        }
    }

    pub fn dems_a() -> Policy {
        Policy { adaptive: true, kind: PolicyKind::DemsA, ..Self::dems() }
    }

    /// GEMS builds on DEMS (§6); pass `adaptive=true` for the GEMS-A used
    /// in the variability studies.
    pub fn gems(adaptive: bool) -> Policy {
        Policy { gems: true, adaptive, kind: PolicyKind::Gems, ..Self::dems() }
    }

    pub fn sota1() -> Policy {
        Policy {
            cloud_accepts_negative: true,
            ..Self::base(PolicyKind::Sota1)
        }
    }

    pub fn sota2() -> Policy {
        Policy {
            edge_order: EdgeOrder::Sjf,
            cloud_accepts_negative: true,
            ..Self::base(PolicyKind::Sota2)
        }
    }

    /// Resolve this declarative policy into an executable scheduler.
    ///
    /// Every one of the eleven [`PolicyKind`]s maps onto one of the five
    /// heuristic families in [`crate::sched`]; the family then interprets
    /// the policy's flags (queue order, migration, stealing, deferral,
    /// adaptation, GEMS) at its decision hooks.
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self.kind {
            PolicyKind::EdgeEdf | PolicyKind::EdgeHpf => Box::new(EdgeOnly),
            PolicyKind::CloudOnly => Box::new(CloudOnly),
            PolicyKind::EdfEC | PolicyKind::SjfEC => Box::new(EcBaseline),
            PolicyKind::Dem | PolicyKind::Dems | PolicyKind::DemsA => {
                Box::new(Dems::new())
            }
            PolicyKind::Gems => Box::new(Gems::new()),
            PolicyKind::Sota1 => Box::new(Sota1),
            PolicyKind::Sota2 => Box::new(Sota2),
        }
    }

    /// The eight QoS-study schedulers of Fig. 8/9 in paper order.
    pub fn fig8_lineup() -> Vec<Policy> {
        vec![
            Self::edge_hpf(),
            Self::edge_edf(),
            Self::cloud_only(),
            Self::edf_ec(),
            Self::sjf_ec(),
            Self::sota1(),
            Self::sota2(),
            Self::dems(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dems_stack_is_incremental() {
        let ec = Policy::edf_ec();
        assert!(!ec.migration && !ec.stealing && !ec.adaptive);
        let dem = Policy::dem();
        assert!(dem.migration && !dem.stealing);
        let dems = Policy::dems();
        assert!(dems.migration && dems.stealing && dems.defer_cloud);
        assert!(!dems.adaptive);
        let dems_a = Policy::dems_a();
        assert!(dems_a.adaptive);
        let gems = Policy::gems(false);
        assert!(gems.gems && gems.migration && gems.stealing);
    }

    #[test]
    fn edge_only_policies_disable_cloud() {
        assert!(!Policy::edge_edf().use_cloud);
        assert!(!Policy::edge_hpf().use_cloud);
        assert!(!Policy::cloud_only().use_edge);
    }

    #[test]
    fn sjf_ec_sends_negative_tasks() {
        assert!(Policy::sjf_ec().cloud_accepts_negative);
        assert!(!Policy::edf_ec().cloud_accepts_negative);
    }

    #[test]
    fn fig8_lineup_has_eight_schedulers() {
        let names: Vec<&str> =
            Policy::fig8_lineup().iter().map(|p| p.kind.name()).collect();
        assert_eq!(
            names,
            ["HPF", "EDF", "CLD", "EDF (E+C)", "SJF (E+C)", "SOTA 1",
             "SOTA 2", "DEMS"]
        );
    }

    #[test]
    fn every_kind_builds_a_scheduler() {
        let all = [
            Policy::edge_edf(),
            Policy::edge_hpf(),
            Policy::cloud_only(),
            Policy::edf_ec(),
            Policy::sjf_ec(),
            Policy::dem(),
            Policy::dems(),
            Policy::dems_a(),
            Policy::gems(false),
            Policy::gems(true),
            Policy::sota1(),
            Policy::sota2(),
        ];
        for p in all {
            let s = p.build();
            assert!(!s.family().is_empty(), "{:?}", p.kind);
        }
    }

    #[test]
    fn pipeline_cut_defaults_to_adaptive() {
        assert_eq!(Policy::dems().pipeline, PipelineCut::Adaptive);
        let fixed = Policy::dems().with_pipeline_cut(PipelineCut::Fixed {
            drone: 1,
            cloud_start: 2,
        });
        assert_eq!(fixed.pipeline,
                   PipelineCut::Fixed { drone: 1, cloud_start: 2 });
        // The cut is orthogonal to the heuristic flags.
        assert!(fixed.migration && fixed.stealing);
    }

    #[test]
    fn paper_adaptation_parameters() {
        let p = Policy::dems_a();
        assert_eq!(p.adapt_window, 10);
        assert_eq!(p.adapt_epsilon, ms(10));
        assert_eq!(p.cooling_period, secs(10));
    }

    #[test]
    fn resilience_defaults_off_and_opts_in_per_policy() {
        // Every constructor ships the inert spec (the bit-identity
        // contract: no breaker, no hedges, no degradation).
        for p in Policy::fig8_lineup() {
            assert!(!p.resilience.enabled(), "{:?}", p.kind);
        }
        assert!(!Policy::dems_a().resilience.enabled());
        let r = Policy::dems_a().with_resilience(ResilienceSpec::full());
        assert!(r.resilience.breaker && r.resilience.hedge
                && r.resilience.degrade);
        // Orthogonal to the heuristic flags.
        assert!(r.migration && r.stealing && r.adaptive);
        let h = Policy::cloud_only()
            .with_resilience(ResilienceSpec::hedge_only());
        assert!(h.resilience.hedge && !h.resilience.breaker);
    }
}
