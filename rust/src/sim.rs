//! Discrete-event primitives and the single-edge simulation entry point.
//!
//! The event engine itself lives in [`crate::cluster`]: a [`Cluster`] of N
//! [`Platform`](crate::platform::Platform)s is driven by one [`EventQueue`]
//! whose entries carry an *edge scope* tag, so a 7-edge §8.1 emulation and a
//! single-edge study run through the same deterministic loop. [`run`] here
//! is the convenience wrapper for the 1-edge case every unit study uses.
//!
//! ## The time-wheel queue
//!
//! [`EventQueue`] is a bucketed calendar queue keyed on the millisecond
//! quantum of the virtual clock ([`QUANTUM_US`]): a ring of
//! [`WHEEL_SLOTS`] buckets covers a ~1 s horizon, an overflow list parks
//! far-future events (QoE window closes, fault schedules), and pops drain
//! one *activated* bucket at a time — the same-tick batch — so the common
//! push/pop pair is O(1) instead of the old `BinaryHeap`'s O(log n)
//! sift. Events inside a bucket are only ordered when the bucket
//! activates (one `sort_unstable` by the unique `(time, push-seq)` key),
//! which keeps the queue's observable stream *bit-identical* to the heap
//! it replaced: `tests/queue_differential.rs` drives both implementations
//! (the heap survives as [`HeapQueue`]) through randomized op sequences
//! and asserts identical `(at, scope, event)` streams.
//!
//! Tasks never ride inside events any more: the queue owns a per-run
//! [`Arena`] of [`Task`]s and the task-carrying variants carry a 4-byte
//! [`TaskSlot`] handle ([`EventQueue::stash_task`] /
//! [`EventQueue::take_task`]), shrinking the moved `Event` payload and
//! cutting per-event task clone/move traffic through the engine.
//!
//! A 300 s × 4-drone × 6-model experiment (7 200 tasks) runs in a few
//! milliseconds, which is what makes the full Fig. 8–18 reproduction sweep
//! tractable. The same platform state machine is also driven by the
//! real-time serving loop in `serve` (behind the `pjrt` feature).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::arena::Arena;
use crate::cluster::{Cluster, ARRIVAL_SEED_XOR};
use crate::fleet::Workload;
use crate::metrics::Metrics;
use crate::platform::Platform;
use crate::sched::Scheduler;
use crate::task::Task;
use crate::time::{secs, Micros};

/// Handle to a [`Task`] parked in the event queue's per-run arena
/// ([`EventQueue::stash_task`]). Single-owner: exactly one pending event
/// refers to a slot, and its handler takes the task back out
/// ([`EventQueue::take_task`]); the conservation invariants pin that
/// every stashed task is taken exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskSlot(u32);

/// Platform events, ordered by virtual time. `Copy` since the arena
/// refactor: task payloads live in the queue's [`Arena`] and events carry
/// only [`TaskSlot`] handles.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A video segment tick for one drone (self-rescheduling).
    Segment { drone: u32, tick: u64 },
    /// The edge executor finished its current task.
    EdgeDone,
    /// A cloud-queue trigger time arrived.
    CloudTrigger,
    /// An in-flight FaaS invocation completed.
    CloudDone { key: u64 },
    /// A model's tumbling QoE window closed.
    WindowClose { model_idx: usize },
    /// A cross-edge stolen task arrives at its destination edge after
    /// its LAN transfer (fleet federation; scope = destination edge).
    FedArrive { task: TaskSlot },
    /// A drone re-homes to another edge (fleet handover; scope = the
    /// destination edge, which records the handover).
    Handover { drone: u32, to_edge: u32 },
    /// A pipeline successor stage arrives at its home edge for admission
    /// — pushed at the predecessor's completion time plus the wireless
    /// transfer when the handoff leaves the drone tier
    /// ([`crate::pipeline`]).
    StageArrive { task: TaskSlot },
    /// The drone's companion computer finished a pipeline prefix stage
    /// (`started` = when it began, for the exec-duration accounting).
    DroneDone { task: TaskSlot, started: Micros },
    /// A scheduled fault fires (edge crash/recovery, region outage, link
    /// flap — see [`crate::fault`]). Compiled from a
    /// [`FaultSpec`](crate::fault::FaultSpec) at cluster setup, so at
    /// equal timestamps a fault precedes handovers and every in-run event
    /// (push order breaks ties; faults are pushed first).
    Fault(crate::fault::FaultAction),
    /// The hedge delay of in-flight cloud invocation `key` elapsed: if
    /// the primary is still running, launch the speculative duplicate
    /// (see [`crate::resilience`]). Pushed only when the policy's
    /// `ResilienceSpec` enables hedging; a no-op when the primary
    /// already completed.
    HedgeFire { key: u64 },
}

/// One queued event: timestamp, FIFO tie-break sequence, edge scope.
#[derive(Clone, Copy, Debug)]
struct Item {
    at: Micros,
    seq: u64,
    /// Edge scope: which platform of a cluster this event belongs to.
    scope: u32,
    event: Event,
}

impl PartialEq for Item {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Item {}
impl PartialOrd for Item {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Item {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Wheel quantum: one bucket per virtual millisecond. Executor/transfer
/// durations are tens of ms, so consecutive events land a few buckets
/// apart and the cursor scan stays short.
pub const QUANTUM_US: Micros = 1_000;

/// Wheel size (power of two): ~1.02 s of horizon. Beyond it events go to
/// the overflow list and are promoted when the wheel next runs dry.
pub const WHEEL_SLOTS: usize = 1024;

/// Time-ordered event queue (bucketed time wheel, FIFO among equal
/// timestamps).
///
/// Every pushed event is stamped with the queue's *current scope* (an edge
/// index, set by the cluster driver before dispatching into a platform), so
/// one queue can interleave N independent platforms deterministically. The
/// scope is ignored in single-edge runs; relative ordering is always
/// `(time, push order)`, never scope.
///
/// Layout — three tiers by distance from "now":
///
/// * `active`: the currently activated bucket, sorted ascending by
///   `(at, seq)`; pops come off its front. Same-quantum (and rare
///   past-time) pushes sorted-insert here, preserving exact heap order.
/// * `buckets[q % WHEEL_SLOTS]`: unsorted spill lists for quanta within
///   the rotation window `[wheel_base, wheel_base + WHEEL_SLOTS)`. A
///   bucket is sorted once, when the cursor reaches it.
/// * `overflow`: everything at or beyond the window end. When the wheel
///   runs dry the window re-bases onto the earliest overflow quantum and
///   in-window items are promoted into buckets — one O(overflow) sweep
///   per re-base, amortized across the (sparse, far-future) events that
///   use it.
///
/// Cross-edge tie-break (audited for the fleet-federation layer): when a
/// federated event — a steal arrival, a handover — lands on the same
/// microsecond as a sibling edge's local event (a cloud trigger, an
/// `EdgeDone`), the winner is strictly whichever was *pushed first*; the
/// scope stamp never reorders. Handovers are pushed at cluster setup, so
/// a handover at `t` always precedes segment ticks at `t` (their pushes
/// chain from `t − period`); steal arrivals are pushed at steal time, so
/// they rank after any same-instant event that was already pending. This
/// order is pinned by `cross_edge_equal_timestamp_ties_break_by_push_order`
/// below and by the heap-vs-wheel differential harness — federation stays
/// deterministic because every tie is resolved by push order alone.
pub struct EventQueue {
    active: VecDeque<Item>,
    /// Quantum of the last activated bucket: pushes at `q <= active_q`
    /// sorted-insert into `active` (the same-tick batch); later quanta go
    /// to the wheel. Invariant: `cursor == active_q + 1` after any
    /// activation, so no push can land behind the cursor.
    active_q: u64,
    buckets: Vec<Vec<Item>>,
    /// Total items across all buckets (cheap dry-wheel check).
    in_buckets: usize,
    /// Quantum at the rotation window's start; the window covers
    /// `[wheel_base, wheel_base + WHEEL_SLOTS)`.
    wheel_base: u64,
    /// Next quantum the dry-active scan will probe.
    cursor: u64,
    overflow: Vec<Item>,
    seq: u64,
    scope: u32,
    tasks: Arena<Task>,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            active: VecDeque::new(),
            active_q: 0,
            buckets: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            in_buckets: 0,
            wheel_base: 0,
            cursor: 0,
            overflow: Vec::new(),
            seq: 0,
            scope: 0,
            tasks: Arena::new(),
        }
    }

    /// Set the edge scope stamped onto subsequently pushed events.
    pub fn set_scope(&mut self, scope: u32) {
        self.scope = scope;
    }

    /// Reset to the empty state (scope, FIFO tie-break counter, wheel
    /// position and task arena included) while keeping every backing
    /// allocation, so one queue can be reused across cluster runs with
    /// bit-identical results and a stable allocation footprint
    /// ([`crate::cluster::Cluster::run_with`]; pinned by
    /// `queue_reuse_keeps_allocation_footprint`).
    pub fn clear(&mut self) {
        self.active.clear();
        for b in self.buckets.iter_mut() {
            b.clear();
        }
        self.in_buckets = 0;
        self.overflow.clear();
        self.tasks.clear();
        self.seq = 0;
        self.scope = 0;
        self.active_q = 0;
        self.wheel_base = 0;
        self.cursor = 0;
    }

    /// Park a task in the per-run arena; the handle rides in the event.
    pub fn stash_task(&mut self, task: Task) -> TaskSlot {
        TaskSlot(self.tasks.insert(task))
    }

    /// Take a stashed task back out, freeing its slot.
    pub fn take_task(&mut self, slot: TaskSlot) -> Task {
        self.tasks.remove(slot.0)
    }

    /// Tasks currently parked in the arena (should be zero once a run
    /// fully drains — every stash has exactly one take).
    pub fn tasks_in_flight(&self) -> usize {
        self.tasks.len()
    }

    pub fn push(&mut self, at: Micros, event: Event) {
        self.seq += 1;
        let it = Item { at, seq: self.seq, scope: self.scope, event };
        let q = at / QUANTUM_US;
        if q <= self.active_q {
            // Same-tick (or past-time) push: keep `active` sorted by the
            // unique (at, seq) key. `seq` is fresh-maximal, so among
            // equal timestamps this lands after its peers — push-order
            // FIFO, exactly the heap's order.
            let pos = self
                .active
                .partition_point(|x| (x.at, x.seq) < (it.at, it.seq));
            self.active.insert(pos, it);
        } else if q < self.wheel_base + WHEEL_SLOTS as u64 {
            self.buckets[(q % WHEEL_SLOTS as u64) as usize].push(it);
            self.in_buckets += 1;
        } else {
            self.overflow.push(it);
        }
    }

    pub fn pop(&mut self) -> Option<(Micros, Event)> {
        self.pop_item().map(|i| (i.at, i.event))
    }

    /// Pop with the edge scope the event was pushed under.
    pub fn pop_scoped(&mut self) -> Option<(Micros, u32, Event)> {
        self.pop_item().map(|i| (i.at, i.scope, i.event))
    }

    fn pop_item(&mut self) -> Option<Item> {
        if let Some(it) = self.active.pop_front() {
            return Some(it);
        }
        self.advance()
    }

    /// The active batch ran dry: scan the wheel to the next non-empty
    /// bucket (re-basing onto the overflow list when the whole wheel is
    /// dry), activate it — one `sort_unstable` by the unique
    /// `(at, seq)` key — and pop its head.
    fn advance(&mut self) -> Option<Item> {
        loop {
            if self.in_buckets == 0 {
                if self.overflow.is_empty() {
                    return None;
                }
                self.rebase_onto_overflow();
            }
            let horizon = self.wheel_base + WHEEL_SLOTS as u64;
            while self.cursor < horizon {
                let slot = (self.cursor % WHEEL_SLOTS as u64) as usize;
                if self.buckets[slot].is_empty() {
                    self.cursor += 1;
                    continue;
                }
                let bucket = &mut self.buckets[slot];
                bucket.sort_unstable_by_key(|i| (i.at, i.seq));
                self.in_buckets -= bucket.len();
                // `drain` keeps the bucket's capacity — the reuse
                // contract for steady-state zero allocation.
                self.active.extend(bucket.drain(..));
                self.active_q = self.cursor;
                self.cursor += 1;
                return self.active.pop_front();
            }
            // Window exhausted: every bucketed quantum lies in the
            // window, so a dry scan implies a dry wheel — loop to
            // re-base onto the overflow list (or finish).
            debug_assert_eq!(
                self.in_buckets, 0,
                "wheel scan passed a live bucket"
            );
        }
    }

    /// Re-base the rotation window onto the earliest overflow quantum
    /// and promote every now-in-window item into its bucket.
    fn rebase_onto_overflow(&mut self) {
        let min_q = self
            .overflow
            .iter()
            .map(|i| i.at / QUANTUM_US)
            .min()
            .expect("re-base on non-empty overflow");
        self.wheel_base = min_q;
        self.cursor = min_q;
        let horizon = min_q + WHEEL_SLOTS as u64;
        let mut i = 0;
        while i < self.overflow.len() {
            let q = self.overflow[i].at / QUANTUM_US;
            if q < horizon {
                let it = self.overflow.swap_remove(i);
                self.buckets[(q % WHEEL_SLOTS as u64) as usize].push(it);
                self.in_buckets += 1;
            } else {
                i += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.active.len() + self.in_buckets + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total element capacity reserved across the active batch, every
    /// wheel bucket, the overflow list and the task arena. Two
    /// consecutive identical runs on one queue must report the same
    /// footprint — the steady-state zero-allocation contract
    /// (`queue_reuse_keeps_allocation_footprint` in
    /// `tests/queue_differential.rs`).
    pub fn allocation_footprint(&self) -> usize {
        self.active.capacity()
            + self.buckets.iter().map(|b| b.capacity()).sum::<usize>()
            + self.overflow.capacity()
            + self.tasks.capacity()
    }
}

/// The engine's previous comparison-based queue (`BinaryHeap` over
/// `(at, seq)`), kept as the reference implementation for the
/// heap-vs-wheel differential harness (`tests/queue_differential.rs`)
/// and the queue micro-bench (`benches/end_to_end.rs`). Same push/pop
/// API and the same `(time, push order)` contract; no task arena — the
/// harness threads [`TaskSlot`]-free events through both queues.
#[derive(Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Reverse<Item>>,
    seq: u64,
    scope: u32,
}

impl HeapQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set_scope(&mut self, scope: u32) {
        self.scope = scope;
    }

    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.scope = 0;
    }

    pub fn push(&mut self, at: Micros, event: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Item {
            at,
            seq: self.seq,
            scope: self.scope,
            event,
        }));
    }

    pub fn pop(&mut self) -> Option<(Micros, Event)> {
        self.heap.pop().map(|Reverse(i)| (i.at, i.event))
    }

    pub fn pop_scoped(&mut self) -> Option<(Micros, u32, Event)> {
        self.heap.pop().map(|Reverse(i)| (i.at, i.scope, i.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// How long past the nominal duration in-flight work may settle before the
/// run is hard-drained (matches the paper counting late completions of the
/// last segments).
pub const SETTLE: Micros = secs(5);

/// Run one platform against a workload; returns the final metrics.
///
/// This is the single-edge convenience wrapper over the cluster engine: it
/// seeds the arrival stream with `seed ^ 0x5EED_F1EE7` (as every study in
/// the repo always has) and drives a one-edge [`Cluster`].
pub fn run<S: Scheduler>(platform: Platform<S>, workload: &Workload,
                         seed: u64) -> Metrics {
    let cluster = Cluster::from_parts(vec![platform], workload.clone(),
                                      vec![seed ^ ARRIVAL_SEED_XOR]);
    let mut cm = cluster.run();
    cm.per_edge.pop().expect("one edge")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(200, Event::EdgeDone);
        q.push(100, Event::CloudTrigger);
        q.push(100, Event::EdgeDone);
        let (t1, e1) = q.pop().unwrap();
        assert_eq!(t1, 100);
        assert!(matches!(e1, Event::CloudTrigger)); // pushed first at t=100
        let (t2, e2) = q.pop().unwrap();
        assert_eq!(t2, 100);
        assert!(matches!(e2, Event::EdgeDone));
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 200);
        assert!(q.pop().is_none());
    }

    #[test]
    fn scope_is_stamped_and_recovered() {
        let mut q = EventQueue::new();
        q.set_scope(3);
        q.push(100, Event::EdgeDone);
        q.set_scope(1);
        q.push(100, Event::CloudTrigger);
        let (_, s1, e1) = q.pop_scoped().unwrap();
        assert_eq!(s1, 3);
        assert!(matches!(e1, Event::EdgeDone));
        let (_, s2, _) = q.pop_scoped().unwrap();
        assert_eq!(s2, 1);
    }

    #[test]
    fn cross_edge_equal_timestamp_ties_break_by_push_order() {
        // Federation determinism pin: a steal arrival for edge 1 pushed
        // *before* edge 0's local cloud dispatch at the same timestamp
        // pops first, and vice versa — (time, push seq) is the whole
        // order; the scope stamp never reorders equal timestamps.
        use crate::model::DnnKind;
        use crate::task::VideoSegment;
        let mktask = || Task {
            id: 1,
            model: DnnKind::Hv,
            segment: VideoSegment {
                id: 1,
                drone: 0,
                created_at: 0,
                bytes: 38_000,
            },
            pipeline: None,
        };
        let mut q = EventQueue::new();
        q.set_scope(1);
        let slot = q.stash_task(mktask());
        q.push(100, Event::FedArrive { task: slot });
        q.set_scope(0);
        q.push(100, Event::CloudTrigger);
        let (t, s, e) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (100, 1));
        assert!(matches!(e, Event::FedArrive { .. }));
        let (t, s, e) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (100, 0));
        assert!(matches!(e, Event::CloudTrigger));
        // Reversed push order reverses the winner at the same instant.
        let mut q = EventQueue::new();
        q.set_scope(0);
        q.push(100, Event::CloudTrigger);
        q.set_scope(1);
        let slot = q.stash_task(mktask());
        q.push(100, Event::FedArrive { task: slot });
        let (_, s, e) = q.pop_scoped().unwrap();
        assert_eq!(s, 0);
        assert!(matches!(e, Event::CloudTrigger));
        // And a handover pushed at setup precedes a same-instant local
        // event pushed later (the "re-home exactly at the window edge"
        // boundary).
        let mut q = EventQueue::new();
        q.set_scope(1);
        q.push(200, Event::Handover { drone: 0, to_edge: 1 });
        q.set_scope(0);
        q.push(200, Event::Segment { drone: 0, tick: 3 });
        let (_, _, e) = q.pop_scoped().unwrap();
        assert!(matches!(e, Event::Handover { .. }));
    }

    #[test]
    fn scope_does_not_affect_ordering() {
        let mut q = EventQueue::new();
        q.set_scope(9);
        q.push(200, Event::EdgeDone);
        q.set_scope(0);
        q.push(100, Event::EdgeDone);
        let (t, s, _) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (100, 0));
        let (t, s, _) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (200, 9));
    }

    #[test]
    fn stash_take_round_trips_and_reuses_slots() {
        use crate::model::DnnKind;
        use crate::task::VideoSegment;
        let mktask = |id: u64| Task {
            id,
            model: DnnKind::Hv,
            segment: VideoSegment {
                id,
                drone: 0,
                created_at: 0,
                bytes: 38_000,
            },
            pipeline: None,
        };
        let mut q = EventQueue::new();
        let a = q.stash_task(mktask(1));
        let b = q.stash_task(mktask(2));
        assert_eq!(q.tasks_in_flight(), 2);
        assert_eq!(q.take_task(a).id, 1);
        assert_eq!(q.take_task(b).id, 2);
        assert_eq!(q.tasks_in_flight(), 0);
        // Freed slots are recycled, so steady-state stash/take cycles
        // never grow the arena.
        let c = q.stash_task(mktask(3));
        assert!(c == a || c == b);
    }

    #[test]
    fn bucket_boundary_orders_across_the_quantum_edge() {
        // 999 µs and 1000 µs land in adjacent buckets; 1000 and 1001
        // share one. All orderings must be exact regardless.
        let mut q = EventQueue::new();
        q.push(QUANTUM_US + 1, Event::EdgeDone);
        q.push(QUANTUM_US - 1, Event::CloudTrigger);
        q.push(QUANTUM_US, Event::Segment { drone: 0, tick: 0 });
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().0, QUANTUM_US - 1);
        assert_eq!(q.pop().unwrap().0, QUANTUM_US);
        assert_eq!(q.pop().unwrap().0, QUANTUM_US + 1);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn same_tick_pushes_during_drain_stay_ordered() {
        // A handler at t pushing more work at t (EdgeDone chains do
        // this) must see it pop after already-pending same-tick events —
        // push-order FIFO inside the activated bucket.
        let mut q = EventQueue::new();
        q.push(5_500, Event::EdgeDone);
        q.push(5_500, Event::CloudTrigger);
        let (t, e) = q.pop().unwrap();
        assert_eq!(t, 5_500);
        assert!(matches!(e, Event::EdgeDone));
        // Same-instant push while the bucket is active.
        q.push(5_500, Event::Segment { drone: 1, tick: 0 });
        // An earlier-microsecond push within the same quantum jumps the
        // line, exactly as the heap would order it.
        q.push(5_400, Event::CloudDone { key: 7 });
        assert!(matches!(q.pop().unwrap().1, Event::CloudDone { .. }));
        assert!(matches!(q.pop().unwrap().1, Event::CloudTrigger));
        assert!(matches!(q.pop().unwrap().1, Event::Segment { .. }));
        assert!(q.is_empty());
    }

    #[test]
    fn overflow_promotion_preserves_order() {
        // Far-future events (beyond the wheel window) park in overflow
        // and must pop in exact (time, push-order) sequence after the
        // wheel re-bases — including ties inside the overflow list.
        let span = WHEEL_SLOTS as u64 * QUANTUM_US;
        let mut q = EventQueue::new();
        q.push(3 * span + 500, Event::EdgeDone);
        q.push(span + 250, Event::CloudTrigger);
        q.push(span + 250, Event::CloudDone { key: 1 });
        q.push(100, Event::Segment { drone: 0, tick: 0 });
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop().unwrap().0, 100);
        let (t, e) = q.pop().unwrap();
        assert_eq!(t, span + 250);
        assert!(matches!(e, Event::CloudTrigger), "overflow FIFO tie");
        assert_eq!(q.pop().unwrap().0, span + 250);
        // Second re-base: the remaining event is two windows further out.
        assert_eq!(q.pop().unwrap().0, 3 * span + 500);
        assert!(q.pop().is_none());
    }

    #[test]
    fn wheel_wraps_around_slot_indices() {
        // Quanta mapping to the same slot index modulo WHEEL_SLOTS must
        // never collide within one window, and successive windows reuse
        // the slots cleanly.
        let span = WHEEL_SLOTS as u64 * QUANTUM_US;
        let mut q = EventQueue::new();
        // Slot 5 of window 0 and slot 5 of window 1 (same index).
        q.push(5 * QUANTUM_US + 10, Event::EdgeDone);
        q.push(span + 5 * QUANTUM_US + 20, Event::CloudTrigger);
        assert_eq!(q.pop().unwrap().0, 5 * QUANTUM_US + 10);
        assert_eq!(q.pop().unwrap().0, span + 5 * QUANTUM_US + 20);
        assert!(q.pop().is_none());
        // Long interleaved stream marching through several rotations.
        let mut q = EventQueue::new();
        let mut expect = Vec::new();
        for i in 0..3_000u64 {
            let at = i * 700; // strides across quantum + slot boundaries
            q.push(at, Event::Segment { drone: 0, tick: i });
            expect.push(at);
        }
        let mut got = Vec::new();
        while let Some((t, _)) = q.pop() {
            got.push(t);
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn clear_resets_state_but_keeps_capacity() {
        let mut q = EventQueue::new();
        let span = WHEEL_SLOTS as u64 * QUANTUM_US;
        for i in 0..500u64 {
            q.push(i * 2_000, Event::EdgeDone);
        }
        q.push(2 * span, Event::CloudTrigger); // overflow
        for _ in 0..200 {
            q.pop();
        }
        let footprint = q.allocation_footprint();
        assert!(footprint > 0);
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none());
        assert_eq!(
            q.allocation_footprint(),
            footprint,
            "clear must keep every backing allocation"
        );
        // Post-clear pushes start from a fresh clock: seq and wheel
        // position reset, so a replay is bit-identical to a new queue.
        q.push(100, Event::EdgeDone);
        q.push(100, Event::CloudTrigger);
        assert!(matches!(q.pop().unwrap().1, Event::EdgeDone));
        assert!(matches!(q.pop().unwrap().1, Event::CloudTrigger));
    }

    #[test]
    fn heap_reference_matches_on_a_smoke_sequence() {
        // The full randomized differential lives in
        // tests/queue_differential.rs; this is the in-module smoke pin.
        let mut h = HeapQueue::new();
        let mut w = EventQueue::new();
        let pushes = [
            (500u64, 2u32),
            (100, 0),
            (100, 1),
            (2_000_000, 3),
            (100, 2),
            (999, 0),
            (1_000, 1),
        ];
        for (i, &(at, scope)) in pushes.iter().enumerate() {
            h.set_scope(scope);
            w.set_scope(scope);
            let ev = Event::Segment { drone: scope, tick: i as u64 };
            h.push(at, ev);
            w.push(at, ev);
        }
        loop {
            let a = h.pop_scoped();
            let b = w.pop_scoped();
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }
}
