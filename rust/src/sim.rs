//! Discrete-event engine driving a [`Platform`](crate::platform::Platform)
//! over virtual time.
//!
//! A 300 s × 4-drone × 6-model experiment (7 200 tasks) runs in a few
//! milliseconds here, which is what makes the full Fig. 8–18 reproduction
//! sweep tractable. The same platform state machine is also driven by the
//! real-time serving loop in [`crate::serve`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::fleet::Workload;
use crate::metrics::Metrics;
use crate::platform::Platform;
use crate::rng::Rng;
use crate::task::{Task, VideoSegment};
use crate::time::{secs, Micros};

/// Platform events, ordered by virtual time.
#[derive(Clone, Debug)]
pub enum Event {
    /// A video segment tick for one drone (self-rescheduling).
    Segment { drone: u32, tick: u64 },
    /// The edge executor finished its current task.
    EdgeDone,
    /// A cloud-queue trigger time arrived.
    CloudTrigger,
    /// An in-flight FaaS invocation completed.
    CloudDone { key: u64 },
    /// A model's tumbling QoE window closed.
    WindowClose { model_idx: usize },
}

struct Item {
    at: Micros,
    seq: u64,
    event: Event,
}

impl PartialEq for Item {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Item {}
impl PartialOrd for Item {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Item {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Time-ordered event queue (min-heap, FIFO among equal timestamps).
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Item>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: Micros, event: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Item { at, seq: self.seq, event }));
    }

    pub fn pop(&mut self) -> Option<(Micros, Event)> {
        self.heap.pop().map(|Reverse(i)| (i.at, i.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// How long past the nominal duration in-flight work may settle before the
/// run is hard-drained (matches the paper counting late completions of the
/// last segments).
const SETTLE: Micros = secs(5);

/// Run one platform against a workload; returns the final metrics.
pub fn run(mut platform: Platform, workload: &Workload, seed: u64) -> Metrics {
    let mut q = EventQueue::new();
    let mut rng = Rng::new(seed ^ 0x5EED_F1EE7);
    let mut segment_id: u64 = 0;

    // Stagger drone streams slightly so segment arrivals don't collide on
    // identical microsecond ticks (real streams are never phase-locked).
    for d in 0..workload.drones {
        let phase = (d as Micros * 37_003) % workload.segment_period;
        q.push(phase, Event::Segment { drone: d, tick: 0 });
    }
    platform.schedule_windows(&mut q);

    let horizon = workload.duration + SETTLE;
    while let Some((now, ev)) = q.pop() {
        if now > horizon {
            break;
        }
        match ev {
            Event::Segment { drone, tick } => {
                if now < workload.duration {
                    segment_id += 1;
                    emit_segment(&mut platform, workload, now, drone, tick,
                                 segment_id, &mut rng, &mut q);
                    q.push(now + workload.segment_period,
                           Event::Segment { drone, tick: tick + 1 });
                }
            }
            Event::EdgeDone => platform.on_edge_done(now, &mut q),
            Event::CloudTrigger => platform.on_cloud_trigger(now, &mut q),
            Event::CloudDone { key } => {
                platform.on_cloud_done(now, key, &mut q)
            }
            Event::WindowClose { model_idx } => {
                if now <= workload.duration {
                    platform.on_window_close(now, model_idx, &mut q);
                }
            }
        }
    }
    platform.drain(horizon, &mut q);
    let mut metrics = platform.metrics;
    metrics.duration = workload.duration;
    metrics
}

/// Create the per-model tasks for one segment tick, in randomized order
/// (§3.3), and submit them to the platform's task scheduler.
#[allow(clippy::too_many_arguments)]
fn emit_segment(platform: &mut Platform, workload: &Workload, now: Micros,
                drone: u32, tick: u64, segment_id: u64, rng: &mut Rng,
                q: &mut EventQueue) {
    let segment = VideoSegment {
        id: segment_id,
        drone,
        created_at: now,
        bytes: workload.segment_bytes,
    };
    let mut due: Vec<usize> = (0..platform.models.len())
        .filter(|&i| {
            let every = workload.model_every.get(i).copied().unwrap_or(1);
            tick % every as u64 == 0
        })
        .collect();
    rng.shuffle(&mut due);
    for i in due {
        let model = platform.models[i].kind;
        let id = platform.fresh_task_id();
        let task = Task { id, model, segment: segment.clone() };
        platform.submit_task(now, task, q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(200, Event::EdgeDone);
        q.push(100, Event::CloudTrigger);
        q.push(100, Event::EdgeDone);
        let (t1, e1) = q.pop().unwrap();
        assert_eq!(t1, 100);
        assert!(matches!(e1, Event::CloudTrigger)); // pushed first at t=100
        let (t2, e2) = q.pop().unwrap();
        assert_eq!(t2, 100);
        assert!(matches!(e2, Event::EdgeDone));
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 200);
        assert!(q.pop().is_none());
    }
}
